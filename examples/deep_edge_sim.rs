//! Deep-edge constrained-device simulation (paper §7).
//!
//! ```bash
//! cargo run --release --example deep_edge_sim
//! ```
//!
//! The paper deploys 12 OpenWrt Archer C7 routers where RSA private-key
//! operations are very slow, so the aggregation uses §5.8 symmetric-key
//! pre-negotiation and a single random seed for the whole mask. This
//! example reproduces that configuration under the `DeviceProfile::
//! deep_edge()` cost model (DESIGN.md §3) and contrasts it with naive
//! hybrid encryption on the same simulated hardware, then shows the §5.5
//! subgrouping speedup (the paper's 1×12 → 4×3 comparison, Figs 19–20).

use std::time::Duration;

use safe_agg::config::{DeviceProfile, SessionConfig};
use safe_agg::crypto::envelope::CipherMode;
use safe_agg::learner::faults::FaultPlan;
use safe_agg::protocols::SafeSession;

fn cfg(mode: CipherMode, groups: usize) -> SessionConfig {
    SessionConfig {
        n_nodes: 12,
        features: 20,
        groups,
        mode,
        rsa_bits: 1024,
        profile: DeviceProfile::deep_edge(),
        poll_time: Duration::from_millis(250),
        aggregation_timeout: Duration::from_secs(60),
        progress_timeout: Duration::from_secs(20),
        ..Default::default()
    }
}

fn run(label: &str, mode: CipherMode, groups: usize) -> anyhow::Result<f64> {
    let c = cfg(mode, groups);
    let session = SafeSession::new(c.clone())?;
    let inputs: Vec<Vec<f64>> = (1..=c.n_nodes)
        .map(|i| (0..c.features).map(|f| i as f64 + f as f64).collect())
        .collect();
    let result = session.run_round(&inputs, &FaultPlan::none())?;
    println!(
        "  {label:<28} {:>7.3}s  ({} msgs)",
        result.metrics.secs(),
        result.metrics.messages
    );
    Ok(result.metrics.secs())
}

fn main() -> anyhow::Result<()> {
    println!("deep-edge simulation: 12 learners, 20 features, Archer C7 cost model\n");

    println!("encryption mode on constrained devices (the §5.8 motivation):");
    let hybrid = run("hybrid (RSA on hot path)", CipherMode::Hybrid, 1)?;
    let preneg = run("pre-negotiated symmetric", CipherMode::PreNegotiated, 1)?;
    println!(
        "  → pre-negotiation is {:.1}x faster (RSA decrypts moved off the chain)\n",
        hybrid / preneg
    );

    println!("subgrouping (§5.5, Figs 19-20): parallel chains on 12 nodes:");
    let mut single = 0.0;
    for groups in [1usize, 2, 3, 4] {
        let t = run(&format!("{}x{} grouping", groups, 12 / groups), CipherMode::PreNegotiated, groups)?;
        if groups == 1 {
            single = t;
        } else if groups == 4 {
            println!("  → 4x3 is {:.1}x faster than 1x12", single / t);
        }
    }

    println!("\ndeep_edge_sim OK");
    Ok(())
}
