//! Failover walkthrough: the two recovery paths of §5.3 / §5.4.
//!
//! ```bash
//! cargo run --release --example failover_demo
//! ```
//!
//! Scenario A — progress failover: nodes 4–6 of a 9-node chain are taken
//! out after key exchange (exactly the paper's §6.3 methodology). The
//! external monitor detects each stall and re-routes the chain; the final
//! average covers the 6 survivors and costs 4(n−f) + 2f messages.
//!
//! Scenario B — initiator failover: the initiator crashes after posting
//! its masked vector. Everyone times out, `should_initiate` elects a new
//! initiator, the round restarts, and the dead initiator is later skipped
//! by a progress failover on the second pass.

use std::time::Duration;

use safe_agg::config::SessionConfig;
use safe_agg::crypto::envelope::CipherMode;
use safe_agg::learner::faults::{FailPoint, FaultPlan};
use safe_agg::protocols::SafeSession;

fn cfg(n: usize) -> SessionConfig {
    SessionConfig {
        n_nodes: n,
        features: 4,
        mode: CipherMode::Hybrid,
        rsa_bits: 1024,
        poll_time: Duration::from_millis(200),
        aggregation_timeout: Duration::from_secs(3),
        progress_timeout: Duration::from_millis(700),
        monitor_interval: Duration::from_millis(100),
        ..Default::default()
    }
}

fn inputs(n: usize) -> Vec<Vec<f64>> {
    (1..=n).map(|i| vec![i as f64; 4]).collect()
}

fn main() -> anyhow::Result<()> {
    println!("=== Scenario A: progress failover (nodes 4-6 down, §5.3) ===");
    let session = SafeSession::new(cfg(9))?;
    let result = session.run_round(&inputs(9), &FaultPlan::kill_range(4, 6))?;
    let m = &result.metrics;
    println!("  completed in {:.3}s", m.secs());
    println!("  progress failovers: {} (expected 3)", m.progress_failovers);
    println!("  contributors      : {} of 9", m.contributors);
    // Note: with short long-poll windows each retry counts as a message;
    // the §5.3 formula 4(n−f)+2f counts logical messages and is verified
    // exactly (no-retry polling) in `cargo bench --bench microbench`.
    println!(
        "  messages          : {} incl. poll retries (logical formula 4(n−f)+2f = {})",
        m.messages,
        4 * 6 + 2 * 3
    );
    let expect = (1 + 2 + 3 + 7 + 8 + 9) as f64 / 6.0;
    println!("  average           : {:.4} (expected {:.4})", m.average[0], expect);
    assert!((m.average[0] - expect).abs() < 1e-6);
    assert_eq!(m.contributors, 6);

    println!("\n=== Scenario B: initiator failover (initiator crashes, §5.4) ===");
    let session = SafeSession::new(cfg(5))?;
    let faults = FaultPlan::none().kill(1, FailPoint::InitiatorAfterPost);
    let result = session.run_round(&inputs(5), &faults)?;
    let m = &result.metrics;
    println!("  completed in {:.3}s (includes the {}s election timeout)", m.secs(), 3);
    println!("  initiator failovers: {}", m.initiator_failovers);
    println!("  contributors       : {} of 5", m.contributors);
    let new_initiator = result
        .outcomes
        .iter()
        .find(|o| !o.died && o.was_initiator)
        .map(|o| o.node)
        .unwrap();
    println!("  new initiator      : node {new_initiator}");
    let expect = (2 + 3 + 4 + 5) as f64 / 4.0;
    println!("  average            : {:.4} (expected {:.4})", m.average[0], expect);
    assert!((m.average[0] - expect).abs() < 1e-6);
    assert!(m.initiator_failovers >= 1);
    assert_ne!(new_initiator, 1);

    println!("\nfailover_demo OK");
    Ok(())
}
