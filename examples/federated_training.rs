//! End-to-end driver (EXPERIMENTS.md E19): federated training of an MLP
//! across 6 learners where every round's parameter averaging runs through
//! a full SAFE secure-aggregation round — weighted by local sample counts
//! (§5.6) and executed through the AOT-compiled PJRT train step when
//! `make artifacts` has been run (pure-Rust oracle otherwise).
//!
//! ```bash
//! make artifacts && cargo run --release --example federated_training
//! ```
//!
//! Prints the validation-loss curve; EXPERIMENTS.md records a reference
//! run. All three layers compose here: L1 Pallas matmuls inside the L2
//! train step, loaded and executed from the L3 coordinator, with the
//! parameters protected by the L3 chain protocol in between.

use std::time::Duration;

use safe_agg::config::SessionConfig;
use safe_agg::crypto::envelope::CipherMode;
use safe_agg::fl::{self, FlConfig};

fn main() -> anyhow::Result<()> {
    let session_cfg = SessionConfig {
        n_nodes: 6,
        mode: CipherMode::Hybrid,
        rsa_bits: 1024,
        poll_time: Duration::from_millis(300),
        aggregation_timeout: Duration::from_secs(60),
        progress_timeout: Duration::from_secs(10),
        ..Default::default()
    };
    let fl_cfg = FlConfig {
        rounds: 40,
        local_steps: 4,
        lr: 0.05,
        rows_per_node: 512,
        non_iid: true,
        seed: 42,
    };
    let trainer = fl::default_trainer()?;
    println!(
        "federated training: {} nodes, {} rounds x {} local steps, trainer={} ({} params)",
        session_cfg.n_nodes,
        fl_cfg.rounds,
        fl_cfg.local_steps,
        trainer.name(),
        trainer.param_count(),
    );
    println!("secure aggregation: SAFE hybrid encryption, weighted averaging (§5.6)\n");

    let result = fl::run_federated(&session_cfg, &fl_cfg, trainer)?;

    println!("round | val_loss | mean_local_loss | agg_secs | agg_msgs");
    for r in &result.curve {
        if r.round % 4 == 0 || r.round + 1 == result.curve.len() {
            println!(
                "{:>5} | {:>8.5} | {:>15.5} | {:>8.4} | {:>8}",
                r.round, r.val_loss, r.mean_local_loss, r.agg_wall_secs, r.agg_messages
            );
        }
    }
    let first = result.curve.first().unwrap().val_loss;
    let last = result.curve.last().unwrap().val_loss;
    println!(
        "\nvalidation loss {first:.5} → {last:.5} ({}x reduction) via {}",
        first / last.max(1e-9),
        result.trainer_name
    );
    assert!(last < first, "training must improve validation loss");
    println!("federated_training OK");
    Ok(())
}
