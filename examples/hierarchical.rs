//! Hierarchical federation (paper §5.10): two independent SAFE
//! deployments (child controllers), each aggregating its own learner
//! chain, post their anonymized averages up to a parent controller over
//! HTTP; the parent releases the contributor-weighted global average.
//!
//! ```bash
//! cargo run --release --example hierarchical
//! ```

use std::sync::Arc;
use std::time::Duration;

use safe_agg::config::SessionConfig;
use safe_agg::controller::{Controller, ControllerConfig};
use safe_agg::crypto::envelope::CipherMode;
use safe_agg::json::Value;
use safe_agg::learner::faults::FaultPlan;
use safe_agg::proto;
use safe_agg::protocols::hierarchy::FederationBridge;
use safe_agg::protocols::SafeSession;
use safe_agg::transport::http::{HttpServer, HttpTransport};
use safe_agg::transport::ClientTransport;

fn child_cfg(n: usize) -> SessionConfig {
    SessionConfig {
        n_nodes: n,
        features: 3,
        mode: CipherMode::Hybrid,
        rsa_bits: 1024,
        seed: Some(n as u64), // different keys per child org
        ..Default::default()
    }
}

fn main() -> anyhow::Result<()> {
    // Parent controller serves real HTTP (the cross-organization link).
    let parent = Arc::new(Controller::new(ControllerConfig {
        poll_time: Duration::from_millis(300),
        ..Default::default()
    }));
    let server = HttpServer::start("127.0.0.1:0", parent.clone())?;
    println!("parent controller on {}", server.url());
    let admin = HttpTransport::connect(&server.url())?;
    admin.call(
        proto::CONFIGURE,
        &Value::object(vec![("fed_expected_children", Value::from(2u64))]),
    )?;

    // Two child organizations run their own SAFE chains in parallel.
    let mut handles = Vec::new();
    for (child_id, n) in [(1u64, 4usize), (2u64, 6usize)] {
        let url = server.url();
        handles.push(std::thread::spawn(move || -> anyhow::Result<(u64, Vec<f64>)> {
            let cfg = child_cfg(n);
            let session = SafeSession::new(cfg.clone())?;
            let inputs: Vec<Vec<f64>> = (1..=n)
                .map(|i| vec![(child_id * 100 + i as u64) as f64; cfg.features])
                .collect();
            let result = session.run_round(&inputs, &FaultPlan::none())?;
            let child_avg = result
                .average()
                .ok_or_else(|| anyhow::anyhow!("no surviving learners"))?;
            println!(
                "child {child_id}: {} learners aggregated in {:.3}s → {:?}",
                n,
                result.metrics.secs(),
                &child_avg[..1]
            );
            // §5.10: post the (already anonymized) child average upward.
            let parent_link: Arc<dyn ClientTransport> =
                Arc::new(HttpTransport::connect(&url)?);
            let bridge = FederationBridge::new(child_id, parent_link);
            bridge.post_child_average(child_avg, result.metrics.contributors)?;
            let (global, total) = bridge.get_global_average(Duration::from_secs(10))?;
            println!("child {child_id}: received global average over {total} learners");
            Ok((child_id, global))
        }));
    }
    let mut globals = Vec::new();
    for h in handles {
        globals.push(h.join().unwrap()?);
    }
    // Both children converged on the same global average.
    assert_eq!(globals[0].1, globals[1].1);
    // Check the weighted math: child1 mean=102.5 (4 nodes), child2
    // mean=203.5 (6 nodes) → global (102.5*4 + 203.5*6)/10 = 163.1.
    let expect = (102.5 * 4.0 + 203.5 * 6.0) / 10.0;
    println!("\nglobal average = {:.2} (expected {:.2})", globals[0].1[0], expect);
    assert!((globals[0].1[0] - expect).abs() < 1e-6);
    println!("hierarchical OK");
    Ok(())
}
