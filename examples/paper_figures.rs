//! Regenerate every figure in the paper's evaluation (§6 Figs 6–14, §7
//! Figs 15–20) plus the headline BON/SAFE ratio table.
//!
//! ```bash
//! cargo run --release --example paper_figures            # quick sweeps
//! SAFE_BENCH_FULL=1 SAFE_BENCH_REPEATS=30 \
//! cargo run --release --example paper_figures            # paper scale
//! ```
//!
//! Tables print to stdout; CSVs land in bench_out/. EXPERIMENTS.md records
//! a reference run with the paper-vs-measured comparison for every figure.

use safe_agg::harness::figures as f;

fn main() -> anyhow::Result<()> {
    println!("regenerating paper figures (quick mode unless SAFE_BENCH_FULL=1)\n");

    // ---- §6 edge platform ----
    f::fig6()?.emit(None);
    f::fig7()?.emit(None);
    f::fig8()?.emit(None);
    f::fig9()?.emit(None);
    f::fig10()?.emit(None);
    f::fig11()?.emit(None);
    f::fig12()?.emit(None);

    let fig13 = f::fig13()?;
    fig13.emit(None);
    f::fig14(&fig13).emit(None);

    println!("── headline — BON/SAFE aggregation-time ratios (abstract, §6.3) ──");
    println!("{:>15} {:>20} {:>20}", "completed", "no-failover", "with-failover");
    for (x, plain, failover) in f::headline_ratios(&fig13) {
        println!(
            "{:>15} {:>19.1}x {:>19.1}x",
            x,
            plain.unwrap_or(f64::NAN),
            failover.unwrap_or(f64::NAN)
        );
    }
    println!("  (paper: 38x/42x at 24 completed nodes; 56x/70x at 36)\n");

    // ---- §7 deep-edge platform (simulated Archer C7 profile) ----
    f::deep_edge_nodes("fig15", "Deep-Edge. 1 Feature.", 1)?.emit(None);
    f::deep_edge_nodes("fig16", "Deep-Edge. 20 Features.", 20)?.emit(None);
    f::deep_edge_features("fig17", "Deep-Edge. 3 Nodes.", 3)?.emit(None);
    f::deep_edge_features("fig18", "Deep-Edge. 12 Nodes.", 12)?.emit(None);
    f::subgroup_figure("fig19", "Deep-Edge. 12 Nodes 1 Feature.", 1)?.emit(None);
    f::subgroup_figure("fig20", "Deep-Edge. 12 Nodes 20 Features.", 20)?.emit(None);

    println!("all figures written to bench_out/*.csv");
    Ok(())
}
