//! Perf probe: instant-profile rounds isolate real code cost.
use safe_agg::config::{DeviceProfile, SessionConfig};
use safe_agg::crypto::envelope::CipherMode;
use safe_agg::learner::faults::FaultPlan;
use safe_agg::protocols::SafeSession;
use std::time::{Duration, Instant};

fn run(n: usize, feats: usize, reps: usize) -> f64 {
    let cfg = SessionConfig {
        n_nodes: n,
        features: feats,
        mode: CipherMode::Hybrid,
        rsa_bits: 1024,
        profile: DeviceProfile::instant(),
        poll_time: Duration::from_secs(5),
        aggregation_timeout: Duration::from_secs(60),
        progress_timeout: Duration::from_secs(30),
        ..Default::default()
    };
    let session = SafeSession::new(cfg).unwrap();
    let inputs: Vec<Vec<f64>> = (0..n).map(|i| (0..feats).map(|f| (i+f) as f64).collect()).collect();
    session.run_round(&inputs, &FaultPlan::none()).unwrap(); // warm
    let t = Instant::now();
    for _ in 0..reps { session.run_round(&inputs, &FaultPlan::none()).unwrap(); }
    t.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    for (n, f, reps) in [(36usize, 1usize, 10usize), (36, 10_000, 5), (100, 1, 5), (100, 10_000, 3)] {
        println!("SAFE n={n:<4} feats={f:<6}: {:.4}s", run(n, f, reps));
    }
}
