//! Quickstart: one SAFE secure-aggregation round with 5 learners.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Builds an in-process deployment (controller + 5 learner threads +
//! progress monitor), exchanges RSA keys (round 0), then runs the chain
//! aggregation: the initiator masks its vector, each learner adds its own
//! under hybrid RSA+AES encryption, and the initiator publishes the
//! average. The controller only ever sees ciphertext.

use safe_agg::config::SessionConfig;
use safe_agg::crypto::envelope::CipherMode;
use safe_agg::learner::faults::FaultPlan;
use safe_agg::protocols::SafeSession;

fn main() -> anyhow::Result<()> {
    let cfg = SessionConfig {
        n_nodes: 5,
        features: 8,
        mode: CipherMode::Hybrid, // "SAFE" — RSA-sealed AES key + compressed payload
        rsa_bits: 1024,
        ..Default::default()
    };

    println!("setting up: {} learners, {} features, hybrid encryption", cfg.n_nodes, cfg.features);
    let session = SafeSession::new(cfg.clone())?;
    println!("round 0 done: {} key-exchange messages\n", session.round0_messages);

    // Each learner's private vector: node i contributes [i, i+0.1, ...].
    let inputs: Vec<Vec<f64>> = (1..=cfg.n_nodes)
        .map(|i| (0..cfg.features).map(|f| i as f64 + f as f64 / 10.0).collect())
        .collect();

    let result = session.run_round(&inputs, &FaultPlan::none())?;
    let m = &result.metrics;

    println!("aggregation complete in {:.3}s", m.secs());
    println!("  messages      : {} (= 4n = {})", m.messages, 4 * cfg.n_nodes);
    println!("  bytes on wire : {}", m.bytes_sent);
    println!("  contributors  : {}", m.contributors);
    println!("  average       : {:?}", &m.average[..4.min(m.average.len())]);

    // Verify against the cleartext mean.
    let expect: Vec<f64> = (0..cfg.features)
        .map(|f| inputs.iter().map(|v| v[f]).sum::<f64>() / cfg.n_nodes as f64)
        .collect();
    let max_err = m
        .average
        .iter()
        .zip(&expect)
        .map(|(a, e)| (a - e).abs())
        .fold(0.0f64, f64::max);
    println!("  max error vs cleartext mean: {max_err:.2e}");
    assert!(max_err < 1e-6);
    println!("\nquickstart OK");
    Ok(())
}
