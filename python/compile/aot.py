"""AOT pipeline: lower the L2 graphs to HLO text under artifacts/.

HLO *text* is the interchange format, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts`` (no-op when inputs are unchanged). Python
never runs on the aggregation path — the Rust binary loads these files
through PJRT.

Usage: python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

jax.config.update("jax_enable_x64", True)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for the Rust
    side's ``to_tuple`` unwrap)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(out_dir: str, name: str, fn, example_args) -> int:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output dir")
    args = parser.parse_args()
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)

    f64 = jnp.float64
    total = 0

    # Chain ops per bucket (f64 so Rust-side protocol math is exact).
    for bucket in model.BUCKETS:
        vec = jax.ShapeDtypeStruct((bucket,), f64)
        scalar = jax.ShapeDtypeStruct((1,), f64)
        total += emit(out_dir, f"chain_add_{bucket}", model.chain_add, (vec, vec))
        total += emit(
            out_dir, f"finalize_{bucket}", model.finalize, (vec, vec, scalar)
        )
        print(f"  chain ops bucket {bucket}: ok")

    # Train step + loss (f32).
    shapes = model.train_step_shapes()
    total += emit(out_dir, "train_step", model.train_step_flat, shapes)
    total += emit(out_dir, "predict_loss", model.predict_loss_flat, shapes[:6])
    print("  train_step / predict_loss: ok")

    manifest = {
        "buckets": list(model.BUCKETS),
        "dtype_chain": "f64",
        "train_step": {
            "in": model.DIM_IN,
            "hidden": model.DIM_HIDDEN,
            "out": model.DIM_OUT,
            "batch": model.BATCH,
            "dtype": "f32",
            "params": model.DIM_IN * model.DIM_HIDDEN
            + model.DIM_HIDDEN
            + model.DIM_HIDDEN * model.DIM_OUT
            + model.DIM_OUT,
        },
        "format": "hlo-text",
        "jax_version": jax.__version__,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {total} chars of HLO + manifest.json to {out_dir}")


if __name__ == "__main__":
    sys.exit(main())
