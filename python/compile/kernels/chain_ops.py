"""L1 Pallas kernels for the SAFE chain's vector arithmetic.

The aggregation hot path does three elementwise vector ops per learner per
round (mask, chain-add, finalize). They are written as Pallas kernels with
an explicit HBM→VMEM tiling schedule via ``BlockSpec`` so the same code
lowers to an efficient TPU loop; on this CPU-only image they MUST run with
``interpret=True`` (real TPU lowering emits a Mosaic custom-call the CPU
PJRT plugin cannot execute — see /opt/xla-example/README.md).

Hardware adaptation (DESIGN.md §2): the paper targets constrained CPUs, so
there is no CUDA mapping to undo; the TPU tiling story is simply "stream
the feature vector through VMEM in BLOCK-sized tiles". BLOCK=512 f64 lanes
= 4 KiB/operand per tile, far under the ~16 MiB VMEM budget even with
double buffering; the grid dimension covers arbitrarily long vectors.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile width (f64 lanes). 512×8 B = 4 KiB per operand per tile.
BLOCK = 512


def _add_kernel(a_ref, b_ref, o_ref):
    """o = a + b, one VMEM tile at a time."""
    o_ref[...] = a_ref[...] + b_ref[...]


def _finalize_kernel(agg_ref, mask_ref, div_ref, o_ref):
    """o = (agg - mask) / divisor; divisor is a scalar broadcast."""
    o_ref[...] = (agg_ref[...] - mask_ref[...]) / div_ref[0]


def _grid(n: int) -> int:
    return (n + BLOCK - 1) // BLOCK


@functools.partial(jax.jit, static_argnames=())
def chain_add(agg, x):
    """Pallas chain-add: the non-initiator 'add my vector' step."""
    n = agg.shape[0]
    if n % BLOCK != 0:
        # Pads are compiled into the artifact for bucket sizes; runtime
        # buckets are multiples of BLOCK except the smallest — fall back
        # to one whole-array tile for tiny vectors.
        return pl.pallas_call(
            _add_kernel,
            out_shape=jax.ShapeDtypeStruct(agg.shape, agg.dtype),
            interpret=True,
        )(agg, x)
    return pl.pallas_call(
        _add_kernel,
        grid=(_grid(n),),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(agg.shape, agg.dtype),
        interpret=True,
    )(agg, x)


# Masking is the same elementwise add; exposed under the protocol name so
# the L2 graph reads like the paper.
mask_add = chain_add


@jax.jit
def finalize(agg, mask, divisor):
    """Pallas finalize: (agg − R) / contributors (initiator step 4)."""
    n = agg.shape[0]
    div = jnp.reshape(divisor, (1,)).astype(agg.dtype)
    if n % BLOCK != 0:
        return pl.pallas_call(
            _finalize_kernel,
            out_shape=jax.ShapeDtypeStruct(agg.shape, agg.dtype),
            interpret=True,
        )(agg, mask, div)
    return pl.pallas_call(
        _finalize_kernel,
        grid=(_grid(n),),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            # The scalar divisor is replicated to every tile.
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(agg.shape, agg.dtype),
        interpret=True,
    )(agg, mask, div)
