"""L1 Pallas kernel for the learner-local MLP compute hot-spot.

The FL workload's inner loop is the dense layer ``x @ w + b`` (forward and
the matching transposed matmuls in backward). This kernel expresses it as
an MXU-shaped tiled matmul: TILE_M×TILE_K and TILE_K×TILE_N VMEM tiles
accumulated over the K grid dimension — the standard TPU schedule (the
128×128 MXU systolic array wants ≥128-wide tiles; our model dims are
smaller, so a single tile per axis suffices and the grid handles batch).

interpret=True as everywhere (CPU-only image); the BlockSpec structure is
what a real TPU build would compile via Mosaic.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_M = 32  # batch tile
TILE_K = 32  # contraction tile
TILE_N = 32  # output-feature tile


def _matmul_bias_kernel(x_ref, w_ref, b_ref, o_ref, *, k_tiles):
    """o[m, n] = sum_k x[m, k] w[k, n] + b[n], accumulated over grid dim 2."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.broadcast_to(b_ref[...], o_ref.shape)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=o_ref.dtype
    )
    del k_tiles


def _pad_to(a, m, axis):
    pad = (-a.shape[axis]) % m
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=())
def matmul_bias(x, w, b):
    """Tiled ``x @ w + b`` through Pallas (f32)."""
    m0, k0 = x.shape
    k0b, n0 = w.shape
    assert k0 == k0b, "contraction mismatch"
    xp = _pad_to(_pad_to(x, TILE_M, 0), TILE_K, 1)
    wp = _pad_to(_pad_to(w, TILE_K, 0), TILE_N, 1)
    bp = _pad_to(b, TILE_N, 0)
    m, k = xp.shape
    _, n = wp.shape
    k_tiles = k // TILE_K
    out = pl.pallas_call(
        functools.partial(_matmul_bias_kernel, k_tiles=k_tiles),
        grid=(m // TILE_M, n // TILE_N, k_tiles),
        in_specs=[
            pl.BlockSpec((TILE_M, TILE_K), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((TILE_K, TILE_N), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((TILE_N,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((TILE_M, TILE_N), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(xp, wp, bp)
    return out[:m0, :n0]
