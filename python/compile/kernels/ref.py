"""Pure-jnp oracles for every Pallas kernel (the L1 correctness signal).

Each function here is the mathematical definition the kernels in
``chain_ops.py`` / ``mlp.py`` must reproduce bit-for-bit (f64 chain ops)
or to float tolerance (f32 MLP). pytest sweeps shapes and dtypes against
these via hypothesis (``python/tests/test_kernels.py``).
"""

import jax.numpy as jnp


def chain_add(agg, x):
    """Non-initiator step: running aggregate + local vector (paper 5.1.2)."""
    return agg + x


def mask_add(x, mask):
    """Initiator step: local vector + large random mask R (paper 5.1.1)."""
    return x + mask


def finalize(agg, mask, divisor):
    """Initiator finish: subtract R, divide by contributor count."""
    return (agg - mask) / divisor


def weighted_encode(x, weight):
    """Weighted averaging (5.6): [x*w, w] as one vector."""
    return jnp.concatenate([x * weight, jnp.reshape(weight, (1,))])


def mlp_forward(w1, b1, w2, b2, x):
    """2-layer MLP: tanh hidden, linear output."""
    h = jnp.tanh(x @ w1 + b1)
    return h @ w2 + b2


def mlp_loss(w1, b1, w2, b2, x, y):
    out = mlp_forward(w1, b1, w2, b2, x)
    return jnp.mean((out - y) ** 2)


def sgd_step(w1, b1, w2, b2, x, y, lr):
    """One SGD step on the MSE loss, returning updated params + loss.

    Written out with manual gradients so the oracle is independent of
    jax.grad (which the L2 model uses) — the two derivations must agree.
    """
    n = x.shape[0] * y.shape[1]
    h_pre = x @ w1 + b1
    h = jnp.tanh(h_pre)
    out = h @ w2 + b2
    diff = out - y
    loss = jnp.mean(diff**2)
    dout = 2.0 * diff / n
    gw2 = h.T @ dout
    gb2 = jnp.sum(dout, axis=0)
    dh = (dout @ w2.T) * (1.0 - h**2)
    gw1 = x.T @ dh
    gb1 = jnp.sum(dh, axis=0)
    return (
        w1 - lr * gw1,
        b1 - lr * gb1,
        w2 - lr * gw2,
        b2 - lr * gb2,
        loss,
    )
