"""L2: the JAX compute graphs AOT-compiled for the Rust coordinator.

Two graph families, both calling the L1 Pallas kernels so they lower into
the same HLO module:

* **Chain ops** (f64) — ``chain_add`` / ``finalize`` over the bucket sizes
  in ``BUCKETS``; the vector arithmetic on SAFE's aggregation hot path.
* **Train step** (f32) — one SGD update of the learner-local 2-layer MLP
  (tanh hidden, MSE loss). Forward matmuls run through the Pallas
  ``matmul_bias`` kernel; the backward pass comes from ``jax.grad``
  through the kernel (interpret-mode Pallas is differentiable).

The architecture constants here are the single source of truth — aot.py
writes them into ``artifacts/manifest.json`` and the Rust side
(`runtime::xla_exec::TrainStepExecutable`) reads them back.
"""

import jax
import jax.numpy as jnp

from .kernels import chain_ops
from .kernels.mlp import matmul_bias

# Feature-size buckets for the chain ops (must match
# rust/src/runtime/xla_exec.rs::BUCKETS).
BUCKETS = (16, 256, 4096, 16384)

# MLP architecture (must match fl::trainer::NativeTrainer::default_arch).
DIM_IN = 16
DIM_HIDDEN = 32
DIM_OUT = 4
BATCH = 64


def chain_add(agg, x):
    """Non-initiator: running aggregate + local vector (paper 5.1.2)."""
    return (chain_ops.chain_add(agg, x),)


def finalize(agg, mask, divisor):
    """Initiator: (agg − R) / contributors (paper 5.1.1 step 4)."""
    return (chain_ops.finalize(agg, mask, divisor),)


def mlp_forward(w1, b1, w2, b2, x):
    h = jnp.tanh(matmul_bias(x, w1, b1))
    return matmul_bias(h, w2, b2)


def mlp_loss(w1, b1, w2, b2, x, y):
    out = mlp_forward(w1, b1, w2, b2, x)
    return jnp.mean((out - y) ** 2)


def predict_loss(w1, b1, w2, b2, x, y):
    """Loss-only graph (validation curves). Returns a 1-element tuple."""
    return (jnp.reshape(mlp_loss(w1, b1, w2, b2, x, y), (1,)),)


def train_step(w1, b1, w2, b2, x, y, lr):
    """One SGD step; returns (w1', b1', w2', b2', loss[1]).

    The backward pass is written out manually (same derivation as
    ``kernels/ref.py::sgd_step``) rather than via ``jax.grad`` because
    interpret-mode ``pallas_call`` with an accumulating grid is not
    differentiable under this jax version; every matmul — forward AND
    backward — still runs through the Pallas ``matmul_bias`` kernel.
    """
    n = jnp.asarray(x.shape[0] * y.shape[1], x.dtype)
    zeros_h = jnp.zeros((w1.shape[1],), x.dtype)
    zeros_o = jnp.zeros((w2.shape[1],), x.dtype)
    zeros_i = jnp.zeros((w1.shape[0],), x.dtype)
    h = jnp.tanh(matmul_bias(x, w1, b1))
    out = matmul_bias(h, w2, b2)
    diff = out - y
    loss = jnp.mean(diff**2)
    dout = 2.0 * diff / n
    gw2 = matmul_bias(h.T, dout, zeros_o)
    gb2 = jnp.sum(dout, axis=0)
    dh = matmul_bias(dout, w2.T, zeros_h) * (1.0 - h**2)
    gw1 = matmul_bias(x.T, dh, zeros_h)
    gb1 = jnp.sum(dh, axis=0)
    del zeros_i
    lr = lr[0]
    return (
        w1 - lr * gw1,
        b1 - lr * gb1,
        w2 - lr * gw2,
        b2 - lr * gb2,
        jnp.reshape(loss, (1,)),
    )


def train_step_shapes():
    """Example args for lowering train_step (flat f32 vectors reshaped
    inside wrappers on the aot side keep the Rust call convention simple:
    every argument is a rank-1 array)."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((DIM_IN * DIM_HIDDEN,), f32),
        jax.ShapeDtypeStruct((DIM_HIDDEN,), f32),
        jax.ShapeDtypeStruct((DIM_HIDDEN * DIM_OUT,), f32),
        jax.ShapeDtypeStruct((DIM_OUT,), f32),
        jax.ShapeDtypeStruct((BATCH * DIM_IN,), f32),
        jax.ShapeDtypeStruct((BATCH * DIM_OUT,), f32),
        jax.ShapeDtypeStruct((1,), f32),
    )


def train_step_flat(w1f, b1, w2f, b2, xf, yf, lr):
    """Rank-1 calling convention wrapper around train_step."""
    w1 = jnp.reshape(w1f, (DIM_IN, DIM_HIDDEN))
    w2 = jnp.reshape(w2f, (DIM_HIDDEN, DIM_OUT))
    x = jnp.reshape(xf, (BATCH, DIM_IN))
    y = jnp.reshape(yf, (BATCH, DIM_OUT))
    nw1, nb1, nw2, nb2, loss = train_step(w1, b1, w2, b2, x, y, lr)
    return (
        jnp.reshape(nw1, (-1,)),
        nb1,
        jnp.reshape(nw2, (-1,)),
        nb2,
        loss,
    )


def predict_loss_flat(w1f, b1, w2f, b2, xf, yf):
    w1 = jnp.reshape(w1f, (DIM_IN, DIM_HIDDEN))
    w2 = jnp.reshape(w2f, (DIM_HIDDEN, DIM_OUT))
    x = jnp.reshape(xf, (BATCH, DIM_IN))
    y = jnp.reshape(yf, (BATCH, DIM_OUT))
    return predict_loss(w1, b1, w2, b2, x, y)
