"""AOT pipeline checks: the emitted HLO text parses, has the expected
entry computation shapes, and the manifest is consistent."""

import json
import os
import subprocess
import sys
import tempfile

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
ARTIFACTS = os.path.join(REPO, "artifacts")


def test_aot_emits_all_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    res = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out)],
        cwd=os.path.join(REPO, "python"),
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert res.returncode == 0, res.stderr
    names = sorted(p.name for p in out.iterdir())
    for bucket in (16, 256, 4096, 16384):
        assert f"chain_add_{bucket}.hlo.txt" in names
        assert f"finalize_{bucket}.hlo.txt" in names
    assert "train_step.hlo.txt" in names
    assert "predict_loss.hlo.txt" in names
    assert "manifest.json" in names
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["train_step"]["params"] == 676
    assert manifest["format"] == "hlo-text"


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_existing_artifacts_are_hlo_text():
    for name in os.listdir(ARTIFACTS):
        if not name.endswith(".hlo.txt"):
            continue
        text = open(os.path.join(ARTIFACTS, name)).read()
        assert text.startswith("HloModule"), f"{name} is not HLO text"
        assert "ENTRY" in text, f"{name} missing entry computation"
        # f64 chain ops carry f64 shapes; train step is f32.
        if name.startswith(("chain_add", "finalize")):
            assert "f64[" in text, f"{name} should be f64"
        if name.startswith(("train_step", "predict_loss")):
            assert "f32[" in text, f"{name} should be f32"


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built",
)
def test_manifest_matches_model_constants():
    from compile import model

    manifest = json.loads(open(os.path.join(ARTIFACTS, "manifest.json")).read())
    assert manifest["buckets"] == list(model.BUCKETS)
    ts = manifest["train_step"]
    assert ts["in"] == model.DIM_IN
    assert ts["hidden"] == model.DIM_HIDDEN
    assert ts["out"] == model.DIM_OUT
    assert ts["batch"] == model.BATCH
