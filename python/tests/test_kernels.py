"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

hypothesis sweeps shapes and values; chain ops must match bit-for-bit in
f64, the MLP kernel to f32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import chain_ops, ref
from compile.kernels.mlp import matmul_bias

jax.config.update("jax_enable_x64", True)

BUCKETS = [16, 256, 4096, 16384]
ODD_SIZES = [1, 3, 7, 100, 513]


def vec_strategy(n, lo=-1e6, hi=1e6):
    return st.lists(
        st.floats(min_value=lo, max_value=hi, allow_nan=False, width=64),
        min_size=n,
        max_size=n,
    )


@pytest.mark.parametrize("n", BUCKETS + ODD_SIZES)
def test_chain_add_matches_ref_exact(n):
    rng = np.random.default_rng(n)
    agg = jnp.asarray(rng.uniform(-1e6, 1e6, n), dtype=jnp.float64)
    x = jnp.asarray(rng.uniform(-1e3, 1e3, n), dtype=jnp.float64)
    got = chain_ops.chain_add(agg, x)
    want = ref.chain_add(agg, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n", BUCKETS + ODD_SIZES)
def test_finalize_matches_ref_exact(n):
    rng = np.random.default_rng(n + 1)
    agg = jnp.asarray(rng.uniform(-1e6, 1e6, n), dtype=jnp.float64)
    mask = jnp.asarray(rng.uniform(-1e6, 1e6, n), dtype=jnp.float64)
    div = jnp.asarray([7.0], dtype=jnp.float64)
    got = chain_ops.finalize(agg, mask, div)
    want = ref.finalize(agg, mask, div[0])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=30, deadline=None)
@given(data=st.data(), n=st.sampled_from([4, 16, 100, 256]))
def test_chain_add_hypothesis(data, n):
    agg = jnp.asarray(data.draw(vec_strategy(n)), dtype=jnp.float64)
    x = jnp.asarray(data.draw(vec_strategy(n, -1e3, 1e3)), dtype=jnp.float64)
    got = chain_ops.chain_add(agg, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.chain_add(agg, x)))


@settings(max_examples=30, deadline=None)
@given(
    data=st.data(),
    n=st.sampled_from([4, 16, 100, 256]),
    div=st.floats(min_value=1.0, max_value=100.0, allow_nan=False),
)
def test_finalize_hypothesis(data, n, div):
    agg = jnp.asarray(data.draw(vec_strategy(n)), dtype=jnp.float64)
    mask = jnp.asarray(data.draw(vec_strategy(n)), dtype=jnp.float64)
    d = jnp.asarray([div], dtype=jnp.float64)
    got = chain_ops.finalize(agg, mask, d)
    want = ref.finalize(agg, mask, d[0])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mask_unmask_roundtrip_protocol_invariant():
    """The SAFE invariant: finalize(mask(x)+Σothers, R, n) == mean."""
    rng = np.random.default_rng(5)
    n_feat, n_nodes = 256, 5
    xs = [
        jnp.asarray(rng.uniform(-2, 2, n_feat), dtype=jnp.float64)
        for _ in range(n_nodes)
    ]
    mask = jnp.asarray(rng.uniform(-1e6, 1e6, n_feat), dtype=jnp.float64)
    agg = chain_ops.mask_add(xs[0], mask)
    for x in xs[1:]:
        agg = chain_ops.chain_add(agg, x)
    avg = chain_ops.finalize(agg, mask, jnp.asarray([float(n_nodes)]))
    want = sum(np.asarray(x) for x in xs) / n_nodes
    np.testing.assert_allclose(np.asarray(avg), want, atol=1e-9)


@pytest.mark.parametrize(
    "m,k,n", [(1, 1, 1), (4, 8, 2), (32, 32, 32), (64, 16, 32), (33, 17, 5), (64, 100, 40)]
)
def test_matmul_bias_matches_jnp(m, k, n):
    rng = np.random.default_rng(m * 100 + k * 10 + n)
    x = jnp.asarray(rng.standard_normal((m, k)), dtype=jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), dtype=jnp.float32)
    b = jnp.asarray(rng.standard_normal(n), dtype=jnp.float32)
    got = matmul_bias(x, w, b)
    want = x @ w + b
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=70),
    k=st.integers(min_value=1, max_value=70),
    n=st.integers(min_value=1, max_value=70),
    seed=st.integers(min_value=0, max_value=1000),
)
def test_matmul_bias_hypothesis(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)), dtype=jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), dtype=jnp.float32)
    b = jnp.asarray(rng.standard_normal(n), dtype=jnp.float32)
    got = matmul_bias(x, w, b)
    want = x @ w + b
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)
