"""L2 correctness: the train-step graph vs the manual-gradient oracle and
vs jax.grad on a kernel-free forward (three independent derivations)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def init(seed=0):
    rng = np.random.default_rng(seed)
    f32 = jnp.float32
    w1 = jnp.asarray(rng.standard_normal((model.DIM_IN, model.DIM_HIDDEN)) * 0.2, f32)
    b1 = jnp.asarray(rng.standard_normal(model.DIM_HIDDEN) * 0.1, f32)
    w2 = jnp.asarray(rng.standard_normal((model.DIM_HIDDEN, model.DIM_OUT)) * 0.2, f32)
    b2 = jnp.asarray(rng.standard_normal(model.DIM_OUT) * 0.1, f32)
    x = jnp.asarray(rng.standard_normal((model.BATCH, model.DIM_IN)), f32)
    y = jnp.asarray(rng.standard_normal((model.BATCH, model.DIM_OUT)), f32)
    return w1, b1, w2, b2, x, y


def test_forward_matches_ref():
    w1, b1, w2, b2, x, _ = init(1)
    got = model.mlp_forward(w1, b1, w2, b2, x)
    want = ref.mlp_forward(w1, b1, w2, b2, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_train_step_matches_manual_ref():
    w1, b1, w2, b2, x, y = init(2)
    lr = jnp.asarray([0.05], jnp.float32)
    got = model.train_step(w1, b1, w2, b2, x, y, lr)
    want = ref.sgd_step(w1, b1, w2, b2, x, y, lr[0])
    for g, w in zip(got[:4], want[:4]):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(got[4][0]), float(want[4]), rtol=1e-5)


def test_train_step_matches_jax_grad():
    """Third derivation: jax.grad on a plain-jnp forward."""
    w1, b1, w2, b2, x, y = init(3)
    lr = 0.05

    def loss_fn(w1, b1, w2, b2):
        return ref.mlp_loss(w1, b1, w2, b2, x, y)

    grads = jax.grad(loss_fn, argnums=(0, 1, 2, 3))(w1, b1, w2, b2)
    got = model.train_step(w1, b1, w2, b2, x, y, jnp.asarray([lr], jnp.float32))
    for g, (p, gr) in zip(got[:4], zip((w1, b1, w2, b2), grads)):
        want = p - lr * gr
        np.testing.assert_allclose(np.asarray(g), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_flat_wrappers_roundtrip():
    w1, b1, w2, b2, x, y = init(4)
    lr = jnp.asarray([0.05], jnp.float32)
    flat = model.train_step_flat(
        jnp.reshape(w1, (-1,)),
        b1,
        jnp.reshape(w2, (-1,)),
        b2,
        jnp.reshape(x, (-1,)),
        jnp.reshape(y, (-1,)),
        lr,
    )
    full = model.train_step(w1, b1, w2, b2, x, y, lr)
    np.testing.assert_allclose(
        np.asarray(flat[0]), np.asarray(jnp.reshape(full[0], (-1,))), rtol=1e-6
    )
    np.testing.assert_allclose(np.asarray(flat[4]), np.asarray(full[4]), rtol=1e-6)


def test_training_reduces_loss():
    w1, b1, w2, b2, x, _ = init(5)
    # Learnable target from a fixed teacher.
    tw1, tb1, tw2, tb2, _, _ = init(99)
    y = ref.mlp_forward(tw1, tb1, tw2, tb2, x)
    lr = jnp.asarray([0.1], jnp.float32)
    l0 = float(ref.mlp_loss(w1, b1, w2, b2, x, y))
    for _ in range(60):
        w1, b1, w2, b2, _ = model.train_step(w1, b1, w2, b2, x, y, lr)
    l1 = float(ref.mlp_loss(w1, b1, w2, b2, x, y))
    assert l1 < 0.5 * l0, f"loss {l0} -> {l1}"


def test_manifest_constants_consistent():
    """The Rust side depends on these exact numbers (manifest.json)."""
    params = (
        model.DIM_IN * model.DIM_HIDDEN
        + model.DIM_HIDDEN
        + model.DIM_HIDDEN * model.DIM_OUT
        + model.DIM_OUT
    )
    assert params == 676
    assert model.BUCKETS == (16, 256, 4096, 16384)
