//! Ablations (E20): design choices DESIGN.md calls out.
//!  * vector engine: native loops vs AOT XLA kernels (by feature count);
//!  * payload compression on/off at 10k features;
//!  * long-poll vs staggered polling (§5.9).
use std::sync::Arc;
use std::time::{Duration, Instant};

use safe_agg::config::{DeviceProfile, VectorEngine};
use safe_agg::crypto::envelope::CipherMode;
use safe_agg::harness::figures::edge_cfg;
use safe_agg::learner::faults::FaultPlan;
use safe_agg::protocols::SafeSession;
use safe_agg::runtime::{ArtifactRuntime, NativeMath, VectorMath, XlaMath};

fn engine_ablation() {
    println!("── E20a: vector engine (native vs XLA artifacts) ──");
    let dir = ArtifactRuntime::default_dir();
    if !ArtifactRuntime::available(&dir) {
        println!("  artifacts not built — run `make artifacts` (skipping)");
        return;
    }
    let rt = Arc::new(ArtifactRuntime::new(dir).unwrap());
    let xla = XlaMath::new(rt);
    let native = NativeMath;
    println!("{:>10} {:>14} {:>14}", "features", "native", "xla");
    for n in [16usize, 256, 4096, 16384] {
        let a: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let b = a.clone();
        let iters = 200;
        let t0 = Instant::now();
        for _ in 0..iters {
            let mut acc = a.clone();
            native.add_assign(&mut acc, &b);
            std::hint::black_box(&acc);
        }
        let tn = t0.elapsed() / iters;
        // warm compile
        let _ = xla.mask(&a, &b);
        let t1 = Instant::now();
        for _ in 0..iters {
            let r = xla.mask(&a, &b);
            std::hint::black_box(&r);
        }
        let tx = t1.elapsed() / iters;
        println!("{:>10} {:>14.2?} {:>14.2?}", n, tn, tx);
    }
    println!();
}

fn compression_ablation() -> anyhow::Result<()> {
    println!("── E20b: §5.7 compression, 10000 features, 8 nodes ──");
    for (label, compress) in [("compress=on", true), ("compress=off", false)] {
        let mut cfg = edge_cfg(8, 10_000);
        cfg.mode = CipherMode::Hybrid;
        cfg.compress = compress;
        cfg.profile = DeviceProfile::instant();
        let session = SafeSession::new(cfg)?;
        let inputs: Vec<Vec<f64>> =
            (0..8).map(|i| (0..10_000).map(|f| (i + f) as f64).collect()).collect();
        let r = session.run_round(&inputs, &FaultPlan::none())?;
        println!(
            "  {label}: {:.4}s, {} bytes on wire",
            r.metrics.secs(),
            r.metrics.bytes_sent
        );
    }
    println!();
    Ok(())
}

fn engine_session_ablation() -> anyhow::Result<()> {
    println!("── E20c: whole-round engine choice, 16384 features, 5 nodes ──");
    for engine in [VectorEngine::Native, VectorEngine::Auto] {
        let mut cfg = edge_cfg(5, 16_384);
        cfg.engine = engine;
        cfg.profile = DeviceProfile::instant();
        cfg.poll_time = Duration::from_secs(5);
        let session = SafeSession::new(cfg)?;
        let inputs: Vec<Vec<f64>> =
            (0..5).map(|i| (0..16_384).map(|f| (i + f) as f64 * 0.5).collect()).collect();
        let r = session.run_round(&inputs, &FaultPlan::none())?;
        println!("  {:?}: {:.4}s", engine, r.metrics.secs());
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    engine_ablation();
    compression_ablation()?;
    engine_session_ablation()?;
    Ok(())
}
