//! Paper Figs 15–18 (E11–E14): the simulated deep-edge (OpenWrt Archer
//! C7) platform — §5.8 pre-negotiated keys, single-seed masking, device
//! cost model from DESIGN.md §3.
use safe_agg::harness::figures as f;

fn main() -> anyhow::Result<()> {
    f::deep_edge_nodes("fig15", "Deep-Edge. 1 Feature.", 1)?.emit(None);
    f::deep_edge_nodes("fig16", "Deep-Edge. 20 Features.", 20)?.emit(None);
    f::deep_edge_features("fig17", "Deep-Edge. 3 Nodes.", 3)?.emit(None);
    f::deep_edge_features("fig18", "Deep-Edge. 12 Nodes.", 12)?.emit(None);
    Ok(())
}
