//! Paper Figs 10–12: edge-platform feature scalability (E5–E7).
//! Watch for the SAFE-vs-INSEC crossovers the paper reports: ~2000
//! features at 15 nodes, ~100 features at 100 nodes.
use safe_agg::harness::figures as f;

fn main() -> anyhow::Result<()> {
    f::fig10()?.emit(None);
    f::fig11()?.emit(None);
    f::fig12()?.emit(None);
    Ok(())
}
