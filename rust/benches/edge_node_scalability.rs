//! Paper Figs 6–9: edge-platform node scalability (E1–E4 in DESIGN.md).
//! `SAFE_BENCH_FULL=1 SAFE_BENCH_REPEATS=30` reproduces the paper's exact
//! sweeps; the default is a trimmed quick pass.
use safe_agg::harness::figures as f;

fn main() -> anyhow::Result<()> {
    f::fig6()?.emit(None);
    f::fig7()?.emit(None);
    f::fig8()?.emit(None);
    f::fig9()?.emit(None);
    Ok(())
}
