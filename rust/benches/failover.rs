//! Paper Figs 13–14 + the headline 70x/56x claim (E8–E10): SAFE vs BON
//! aggregation time with and without node failures, following §6.3's
//! normalization (n completed nodes vs n+3 nodes with 3 failures).
use safe_agg::harness::figures as f;

fn main() -> anyhow::Result<()> {
    let fig13 = f::fig13()?;
    fig13.emit(None);
    f::fig14(&fig13).emit(None);
    println!("── headline — BON/SAFE ratios ──");
    for (x, plain, failover) in f::headline_ratios(&fig13) {
        println!(
            "{:>4} completed: {:>6.1}x no-failover, {:>6.1}x with-failover",
            x,
            plain.unwrap_or(f64::NAN),
            failover.unwrap_or(f64::NAN)
        );
    }
    println!("(paper: 38x/42x at 24; 56x/70x at 36)");
    Ok(())
}
