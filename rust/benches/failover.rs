//! Paper Figs 13–14 + the headline 70x/56x claim (E8–E10): SAFE vs BON
//! aggregation time with and without node failures, following §6.3's
//! normalization (n completed nodes vs n+3 nodes with 3 failures) — plus
//! the multi-round churn scenario (die round 1 / rejoin round 3) with its
//! per-round failover cost and amortized-setup table, written to
//! `BENCH_multiround.json` for cross-PR tracking.
use safe_agg::harness::{figures as f, full_scale, multiround};

fn main() -> anyhow::Result<()> {
    // CI's bench smoke wants just the multi-round table + artifact
    // without paying for the full Fig 13/14 sweep.
    if std::env::var("SAFE_BENCH_MULTIROUND_ONLY").map_or(false, |v| v == "1") {
        return multi_round_table();
    }
    let fig13 = f::fig13()?;
    fig13.emit(None);
    f::fig14(&fig13).emit(None);
    println!("── headline — BON/SAFE ratios ──");
    for (x, plain, failover) in f::headline_ratios(&fig13) {
        println!(
            "{:>4} completed: {:>6.1}x no-failover, {:>6.1}x with-failover",
            x,
            plain.unwrap_or(f64::NAN),
            failover.unwrap_or(f64::NAN)
        );
    }
    println!("(paper: 38x/42x at 24; 56x/70x at 36)");
    multi_round_table()
}

/// Multi-round churn: the engine pays round 0 once and re-keys only the
/// rejoining node; amortized setup messages/round must fall as R grows.
fn multi_round_table() -> anyhow::Result<()> {
    let rounds = if full_scale() { 10 } else { 4 };
    let report = multiround::multi_round_failover(9, rounds)?;
    report.emit(None);
    let rekey_round = &report.rows[2]; // rejoin lands in round 3
    assert!(
        rekey_round.rekey_messages > 0,
        "round 3 must pay the rejoiner's re-key"
    );
    assert!(
        report.amortized_setup_per_round()
            < (report.setup_messages + report.rekey_total()) as f64,
        "amortization must beat paying setup every round"
    );
    std::fs::write("BENCH_multiround.json", report.to_json().to_string())?;
    println!("wrote BENCH_multiround.json");
    Ok(())
}
