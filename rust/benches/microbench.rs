//! Microbenches (E17–E18): message-count formula verification across
//! configurations, and the §4 crypto complexity sweep (RSA key size vs
//! encrypt/decrypt cost; hybrid vs RSA-only envelope).
use std::time::{Duration, Instant};

use safe_agg::config::DeviceProfile;
use safe_agg::crypto::envelope::{CipherMode, Envelope};
use safe_agg::crypto::rng::DeterministicRng;
use safe_agg::crypto::rsa::RsaKeyPair;
use safe_agg::crypto::{Big, DefaultBig, ModContext};
use safe_agg::harness::figures::{edge_cfg, run_variant, Variant};
use safe_agg::learner::faults::FaultPlan;

fn messages_table() -> anyhow::Result<()> {
    println!("── E17: message-count formulas (§5.2–§5.5) ──");
    println!("{:>6} {:>3} {:>3} {:>10} {:>10}", "nodes", "f", "g", "measured", "formula");
    for (n, fail, groups) in [
        (5usize, 0u64, 1usize),
        (8, 0, 1),
        (12, 0, 1),
        (8, 2, 1),
        (12, 3, 1),
        (12, 0, 3),
        (12, 0, 4),
    ] {
        let mut cfg = edge_cfg(n, 1);
        cfg.groups = groups;
        cfg.profile = DeviceProfile::instant();
        cfg.poll_time = Duration::from_secs(10);
        cfg.progress_timeout = Duration::from_millis(400);
        let faults = if fail > 0 {
            FaultPlan::kill_range(4, 3 + fail)
        } else {
            FaultPlan::none()
        };
        let rounds = run_variant(Variant::Safe, cfg, &faults, 1)?;
        let measured = rounds[0].messages;
        // 4(n−f) + 2f (+g when subgrouped)
        let formula =
            4 * (n as u64 - fail) + 2 * fail + if groups > 1 { groups as u64 } else { 0 };
        println!("{:>6} {:>3} {:>3} {:>10} {:>10}", n, fail, groups, measured, formula);
        assert_eq!(measured, formula, "message formula violated");
    }
    println!();
    Ok(())
}

fn crypto_table() {
    println!(
        "── E18: RSA complexity (§4: O(k²) encrypt / O(k³) decrypt) — backend: {} ──",
        <DefaultBig as Big>::NAME
    );
    println!("{:>6} {:>12} {:>12} {:>12}", "bits", "keygen", "encrypt", "decrypt");
    let mut rng = DeterministicRng::seed(7);
    for bits in [512usize, 1024, 2048] {
        let t0 = Instant::now();
        let kp = RsaKeyPair::generate(bits, &mut rng);
        let keygen = t0.elapsed();
        let msg = vec![0x5au8; kp.public.max_block_payload()];
        let iters = 20;
        let t1 = Instant::now();
        let mut blocks = Vec::new();
        for _ in 0..iters {
            blocks.push(kp.public.encrypt_block(&msg, &mut rng).unwrap());
        }
        let enc = t1.elapsed() / iters;
        let t2 = Instant::now();
        for b in &blocks {
            kp.private.decrypt_block(b).unwrap();
        }
        let dec = t2.elapsed() / iters;
        println!("{:>6} {:>12.2?} {:>12.2?} {:>12.2?}", bits, keygen, enc, dec);
    }
    println!();
    println!("── E18b: envelope cost, 10000 features (hybrid §5.7 vs RSA-only) ──");
    let mut rng = DeterministicRng::seed(8);
    let kp = RsaKeyPair::generate(1024, &mut rng);
    let vector: Vec<f64> = (0..10_000).map(|i| i as f64 * 0.01).collect();
    for (label, mode, compress) in [
        ("rsa-only", CipherMode::RsaOnly, false),
        ("hybrid", CipherMode::Hybrid, false),
        ("hybrid+deflate", CipherMode::Hybrid, true),
    ] {
        let t = Instant::now();
        let iters = 5;
        let mut wire = 0usize;
        let mut legacy = 0usize;
        for _ in 0..iters {
            let env =
                Envelope::seal(&vector, mode, Some(&kp.public), None, compress, &mut rng).unwrap();
            // What actually ships since the blob framing landed; the
            // legacy base64-text size is the pre-PR-2 comparison column.
            wire = env.blob_len();
            legacy = env.wire_len();
            env.open(Some(&kp.private), None).unwrap();
        }
        println!(
            "{:>16}: {:>10.2?} per seal+open, {:>8} wire bytes ({:>8} as legacy b64 text)",
            label,
            t.elapsed() / iters,
            wire,
            legacy
        );
    }
    modexp_table();
}

/// E18c: what the Montgomery context buys. One 2048-bit modulus and a
/// node's worth of 256-bit exponents, folded three ways — a fresh
/// context per exponentiation (the pre-PR shape), one shared context
/// (the §5.8 re-key shape after this PR), and `modpow_product` doing
/// the whole chain in one call.
fn modexp_table() {
    println!();
    println!(
        "── E18c: modexp context reuse (backend: {}) ──",
        <DefaultBig as Big>::NAME
    );
    let mut rng = DeterministicRng::seed(11);
    let modulus = {
        // An odd 2048-bit modulus keeps the native backend on its
        // Montgomery path, like a real RSA or RFC 3526 modulus.
        let mut m = DefaultBig::random_bits(2048, &mut rng);
        if DefaultBig::is_even(&m) {
            m = DefaultBig::add_u64(&m, 1);
        }
        m
    };
    let base = DefaultBig::random_below(&modulus, &mut rng);
    let links = 8usize; // one node's §5.8 link set
    let exps: Vec<_> = (0..links)
        .map(|_| DefaultBig::random_bits(256, &mut rng))
        .collect();
    let iters = 20u32;

    let t = Instant::now();
    let mut fresh_out = base.clone();
    for _ in 0..iters {
        let mut acc = base.clone();
        for e in &exps {
            acc = DefaultBig::modpow(&acc, e, &modulus);
        }
        fresh_out = acc;
    }
    let fresh = t.elapsed() / iters;

    let t = Instant::now();
    let mut shared_out = base.clone();
    for _ in 0..iters {
        let ctx = DefaultBig::ctx(&modulus);
        let mut acc = base.clone();
        for e in &exps {
            acc = ctx.modpow(&acc, e);
        }
        shared_out = acc;
    }
    let shared = t.elapsed() / iters;

    let t = Instant::now();
    let mut product_out = base.clone();
    for _ in 0..iters {
        product_out = DefaultBig::modpow_product(&base, exps.iter(), &modulus);
    }
    let product = t.elapsed() / iters;

    assert_eq!(fresh_out, shared_out, "shared ctx changed the result");
    assert_eq!(fresh_out, product_out, "modpow_product changed the result");
    println!(
        "{} chained exps × 2048-bit modulus, 256-bit exponents:\n\
         {:>16}: {:>10.2?}\n{:>16}: {:>10.2?}\n{:>16}: {:>10.2?}",
        links, "fresh ctx/call", fresh, "shared ctx", shared, "modpow_product", product
    );
}

fn main() -> anyhow::Result<()> {
    messages_table()?;
    crypto_table();
    Ok(())
}
