//! Hostile-network bench: the failure matrix and a paper-scale
//! Poisson-churn session per network profile, with seeded run-twice
//! determinism asserts (see `harness::netbench`). Renders the per-cell
//! table, appends `bench_out/netbench.csv`, and writes `BENCH_net.json`
//! for cross-PR tracking.
//!
//! Knobs (for CI's lighter smoke run): `SAFE_NET_PROFILES`
//! (semicolon-separated `--net`-style specs — semicolons because one
//! spec may itself contain commas, e.g. `lossy,loss-req=0.2;lan`),
//! `SAFE_NET_MATRIX_NODES`, `SAFE_NET_NODES`, `SAFE_NET_GROUPS`,
//! `SAFE_NET_ROUNDS`, `SAFE_NET_DIE`, `SAFE_NET_REJOIN`,
//! `SAFE_NET_SEED`, `SAFE_NET_WORKERS`,
//! `SAFE_NET_RUNTIME=threads|events`.

use safe_agg::config::RuntimeKind;
use safe_agg::harness::netbench::{self, NetBenchConfig};

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let defaults = NetBenchConfig::default();
    let profiles = match std::env::var("SAFE_NET_PROFILES") {
        Ok(v) => v
            .split(';')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect(),
        Err(_) => defaults.profiles.clone(),
    };
    let runtime = match std::env::var("SAFE_NET_RUNTIME").as_deref() {
        Ok("threads") => RuntimeKind::Threads,
        _ => RuntimeKind::Events,
    };
    let nc = NetBenchConfig {
        profiles,
        matrix_nodes: env_or("SAFE_NET_MATRIX_NODES", defaults.matrix_nodes),
        nodes: env_or("SAFE_NET_NODES", defaults.nodes),
        groups: env_or("SAFE_NET_GROUPS", defaults.groups),
        rounds: env_or("SAFE_NET_ROUNDS", defaults.rounds),
        lambda_die: env_or("SAFE_NET_DIE", defaults.lambda_die),
        lambda_rejoin: env_or("SAFE_NET_REJOIN", defaults.lambda_rejoin),
        seed: env_or("SAFE_NET_SEED", defaults.seed),
        runtime,
        workers: env_or("SAFE_NET_WORKERS", defaults.workers),
    };
    // run() errors out on any non-determinism, wedged round, or empty
    // contributor set — a failing exit code IS the regression signal.
    let report = netbench::run(&nc)?;
    report.emit(None);
    std::fs::write("BENCH_net.json", report.to_json().to_string())?;
    println!("wrote BENCH_net.json");
    Ok(())
}
