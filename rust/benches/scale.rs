//! Paper-scale Poisson churn bench (topology subsystem + event
//! runtime): n nodes in ~n/5 subgroups, seeded Poisson
//! arrival/departure with privacy-floor merge re-balancing on,
//! verifying `4n + 2f (+ g)` per round with merge/reassignment re-keys
//! accounted separately — then an n=10,000-class single-round smoke —
//! and writing `BENCH_scale.json` (per-round wall-clock, messages/sec,
//! peak process threads) for cross-PR tracking.
//!
//! Knobs (for CI's lighter smoke run): `SAFE_SCALE_NODES`,
//! `SAFE_SCALE_GROUPS`, `SAFE_SCALE_ROUNDS`, `SAFE_SCALE_DIE`,
//! `SAFE_SCALE_REJOIN`, `SAFE_SCALE_SEED`, `SAFE_SCALE_WORKERS`,
//! `SAFE_SCALE_RUNTIME=threads|events`; `SAFE_SCALE_NET` takes a
//! `--net`-style profile spec (`lossy`, `wan,loss-req=0.05`, …) and
//! stretches every timeout budget to match; `SAFE_SCALE_SHARDS` sets the
//! controller plane width K for the main run; `SAFE_SCALE_SWEEP=1,2,4`
//! additionally re-runs the same scenario at each listed K and records a
//! `shard_sweep` section (strict mode requires the widest K to beat
//! K = 1 wall-clock); `SAFE_SMOKE_NODES` / `SAFE_SMOKE_GROUPS` size the
//! single-round smoke (`SAFE_SMOKE_NODES=0` skips it); set
//! `SAFE_SCALE_NO_ASSERT=1` to report formula deltas without failing on
//! them.
//!
//! The crypto pass ([`crypto_scale`]: §5.1 round-0 setup + §5.8 re-key
//! under the active bigint backend) runs after the churn bench and
//! merges into `BENCH_scale.json` under `crypto.<backend>` — so a
//! second invocation built with `--features bigint-dig` adds its
//! numbers *alongside* the default backend's instead of clobbering
//! them. `SAFE_SCALE_CRYPTO_ONLY=1` skips the churn/smoke passes and
//! does only that read-merge-write (the CI feature leg uses this);
//! `SAFE_SCALE_CRYPTO_NODES=0` skips the crypto pass entirely.

use safe_agg::config::RuntimeKind;
use safe_agg::harness::scale::{
    crypto_scale, poisson_scale, shard_sweep, single_round_smoke, CryptoScaleConfig, ScaleConfig,
};
use safe_agg::json::Value;

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Run the crypto pass and fold its numbers into `json` under
/// `crypto.<backend>`, preserving any sibling backends already there.
fn run_crypto_pass(json: &mut Value) -> anyhow::Result<()> {
    let cdefaults = CryptoScaleConfig::default();
    let n = env_or("SAFE_SCALE_CRYPTO_NODES", cdefaults.n_nodes);
    if n == 0 {
        println!("crypto: skipped");
        return Ok(());
    }
    let report = crypto_scale(&CryptoScaleConfig {
        n_nodes: n,
        groups: env_or("SAFE_SCALE_CRYPTO_GROUPS", (n / 5).max(1)),
        rsa_bits: env_or("SAFE_SCALE_CRYPTO_RSA_BITS", cdefaults.rsa_bits),
        seed: env_or("SAFE_SCALE_SEED", cdefaults.seed),
    })?;
    print!("{}", report.to_table());
    let mut crypto = json.get("crypto").cloned().unwrap_or_else(Value::obj);
    crypto.set(&report.backend, report.to_json());
    json.set("crypto", crypto);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    if std::env::var("SAFE_SCALE_CRYPTO_ONLY").as_deref() == Ok("1") {
        // Read-merge-write: keep whatever an earlier (other-backend)
        // invocation already recorded.
        let mut json = std::fs::read_to_string("BENCH_scale.json")
            .ok()
            .and_then(|s| safe_agg::json::parse(&s).ok())
            .unwrap_or_else(Value::obj);
        run_crypto_pass(&mut json)?;
        std::fs::write("BENCH_scale.json", json.to_string())?;
        println!("wrote BENCH_scale.json (crypto only)");
        return Ok(());
    }
    let defaults = ScaleConfig::default();
    let n_nodes = env_or("SAFE_SCALE_NODES", defaults.n_nodes);
    let runtime = match std::env::var("SAFE_SCALE_RUNTIME").as_deref() {
        Ok("threads") => RuntimeKind::Threads,
        _ => RuntimeKind::Events,
    };
    let net = match std::env::var("SAFE_SCALE_NET") {
        Ok(spec) => safe_agg::transport::NetProfile::parse(&spec)
            .map_err(|e| anyhow::anyhow!("bad SAFE_SCALE_NET: {e:#}"))?,
        Err(_) => defaults.net.clone(),
    };
    let sc = ScaleConfig {
        n_nodes,
        // Chains of ~5 keep privacy-floor merges observable under churn.
        groups: env_or("SAFE_SCALE_GROUPS", (n_nodes / 5).max(1)),
        rounds: env_or("SAFE_SCALE_ROUNDS", defaults.rounds),
        lambda_die: env_or("SAFE_SCALE_DIE", defaults.lambda_die),
        lambda_rejoin: env_or("SAFE_SCALE_REJOIN", defaults.lambda_rejoin),
        seed: env_or("SAFE_SCALE_SEED", defaults.seed),
        runtime,
        workers: env_or("SAFE_SCALE_WORKERS", defaults.workers),
        net,
        shards: env_or("SAFE_SCALE_SHARDS", defaults.shards),
        ..defaults
    };
    let report = poisson_scale(&sc)?;
    report.emit(None);

    // Every round completed (poisson_scale would have errored on an
    // abort) — now hold the per-round accounting to the paper's
    // formulas. The probe must actually have exercised the
    // latency-modeled transport.
    assert!(report.probe_samples > 0, "status probe never completed a poll");
    let strict = std::env::var("SAFE_SCALE_NO_ASSERT").map_or(true, |v| v != "1");
    for row in &report.rows {
        if row.formula_delta() != 0 {
            let msg = format!(
                "round {}: {} messages vs {} expected (Δ{})",
                row.round,
                row.messages,
                row.expected_messages,
                row.formula_delta()
            );
            if strict && row.initiator_failovers == 0 {
                anyhow::bail!("{msg}");
            }
            println!("warning: {msg}");
        }
        // The fan-in tier's surcharge is bounded: one partial post + one
        // global fetch per live shard per round.
        if strict {
            anyhow::ensure!(
                row.fanin_messages <= 2 * sc.shards as u64,
                "round {}: {} fan-in messages exceeds 2K = {}",
                row.round,
                row.fanin_messages,
                2 * sc.shards
            );
        }
    }
    // The event runtime's whole point: the process runs O(workers)
    // threads, not O(n). The slack covers main + monitor + probe + timer
    // + HTTP/test scaffolding; 0 means /proc was unreadable.
    if report.runtime == "events" && report.peak_threads > 0 && strict {
        let cap = report.workers + 16;
        anyhow::ensure!(
            report.peak_threads <= cap,
            "peak threads {} exceeds workers+16 = {}",
            report.peak_threads,
            cap
        );
    }

    // Shard K-sweep: re-run the identical churn scenario at each listed
    // plane width and compare end-to-end wall-clock. The sharded plane's
    // claim is that splitting the controller lock K ways beats one broker
    // serializing every chain op — strict mode holds the widest K to
    // strictly less total wall-clock than K = 1.
    let sweep = match std::env::var("SAFE_SCALE_SWEEP") {
        Ok(spec) => {
            let ks: Vec<usize> = spec
                .split(',')
                .filter_map(|s| s.trim().parse().ok())
                .filter(|&k| k >= 1)
                .collect();
            anyhow::ensure!(!ks.is_empty(), "SAFE_SCALE_SWEEP has no shard counts: {spec}");
            let reports = shard_sweep(&sc, &ks)?;
            let mut entries = Vec::new();
            for (k, rep) in ks.iter().zip(&reports) {
                rep.emit(None);
                let total_secs: f64 = rep.rows.iter().map(|r| r.secs).sum();
                let fanin_total: u64 = rep.rows.iter().map(|r| r.fanin_messages).sum();
                let max_fanin_latency =
                    rep.rows.iter().map(|r| r.fanin_latency_secs).fold(0.0, f64::max);
                println!(
                    "sweep K={k}: {total_secs:.3}s total, {fanin_total} fan-in messages, \
                     max fan-in latency {max_fanin_latency:.4}s"
                );
                entries.push(Value::object(vec![
                    ("shards", Value::from(*k)),
                    ("id", Value::from(rep.id.as_str())),
                    ("total_secs", Value::from(total_secs)),
                    ("fanin_messages_total", Value::from(fanin_total)),
                    ("max_fanin_latency_secs", Value::from(max_fanin_latency)),
                ]));
            }
            let secs_of = |k: usize| {
                ks.iter()
                    .position(|&x| x == k)
                    .map(|i| reports[i].rows.iter().map(|r| r.secs).sum::<f64>())
            };
            if strict {
                if let (Some(base), Some(&widest)) = (secs_of(1), ks.iter().max()) {
                    if widest > 1 {
                        let wide = secs_of(widest).unwrap();
                        anyhow::ensure!(
                            wide < base,
                            "K={widest} total wall-clock {wide:.3}s is not below K=1's \
                             {base:.3}s"
                        );
                    }
                }
            }
            Some(Value::Arr(entries))
        }
        Err(_) => None,
    };

    // n=10,000-class single-round smoke, event runtime only.
    let smoke_nodes: usize = env_or("SAFE_SMOKE_NODES", 10_000);
    let smoke = if smoke_nodes > 0 && runtime == RuntimeKind::Events {
        let smoke_groups = env_or("SAFE_SMOKE_GROUPS", (smoke_nodes / 10).max(1));
        let s = single_round_smoke(smoke_nodes, smoke_groups, sc.workers, &sc.net)?;
        println!(
            "smoke: n={} g={} in {:.3}s — {} messages (expected {}), peak threads {} \
             ({} workers)",
            s.n_nodes, s.groups, s.secs, s.messages, s.expected_messages, s.peak_threads,
            s.workers
        );
        if s.peak_threads > 0 && strict {
            anyhow::ensure!(
                s.peak_threads <= s.workers + 16,
                "smoke peak threads {} exceeds workers+16 = {}",
                s.peak_threads,
                s.workers + 16
            );
        }
        Some(s)
    } else {
        println!("smoke: skipped");
        None
    };

    let mut json = report.to_json();
    json.set(
        "smoke",
        smoke.map(|s| s.to_json()).unwrap_or(Value::Null),
    );
    if let Some(s) = sweep {
        json.set("shard_sweep", s);
    }
    // Preserve crypto numbers an earlier invocation (possibly built with
    // the other backend) already wrote, then add this build's own.
    if let Some(prev) = std::fs::read_to_string("BENCH_scale.json")
        .ok()
        .and_then(|s| safe_agg::json::parse(&s).ok())
        .and_then(|v| v.get("crypto").cloned())
    {
        json.set("crypto", prev);
    }
    run_crypto_pass(&mut json)?;
    std::fs::write("BENCH_scale.json", json.to_string())?;
    println!("wrote BENCH_scale.json");
    // The raw /metrics scrape of every plane controller, captured while
    // the session was live — uploaded next to BENCH_scale.json by CI.
    std::fs::write("metrics_snapshot.txt", &report.metrics_snapshot)?;
    println!("wrote metrics_snapshot.txt");
    Ok(())
}
