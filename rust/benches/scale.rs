//! Paper-scale Poisson churn bench (topology subsystem): 120 nodes in
//! 24 subgroups, 5 rounds of seeded Poisson arrival/departure with
//! privacy-floor merge re-balancing on, verifying `4n + 2f (+ g)` per
//! round with merge/reassignment re-keys accounted separately — and
//! writing `BENCH_scale.json` for cross-PR tracking.
//!
//! Knobs (for CI's lighter smoke run): `SAFE_SCALE_NODES`,
//! `SAFE_SCALE_GROUPS`, `SAFE_SCALE_ROUNDS`, `SAFE_SCALE_DIE`,
//! `SAFE_SCALE_REJOIN`, `SAFE_SCALE_SEED`; set `SAFE_SCALE_NO_ASSERT=1`
//! to report formula deltas without failing on them.

use safe_agg::harness::scale::{poisson_scale, ScaleConfig};

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let defaults = ScaleConfig::default();
    let n_nodes = env_or("SAFE_SCALE_NODES", defaults.n_nodes);
    let sc = ScaleConfig {
        n_nodes,
        // Chains of ~5 keep privacy-floor merges observable under churn.
        groups: env_or("SAFE_SCALE_GROUPS", (n_nodes / 5).max(1)),
        rounds: env_or("SAFE_SCALE_ROUNDS", defaults.rounds),
        lambda_die: env_or("SAFE_SCALE_DIE", defaults.lambda_die),
        lambda_rejoin: env_or("SAFE_SCALE_REJOIN", defaults.lambda_rejoin),
        seed: env_or("SAFE_SCALE_SEED", defaults.seed),
        ..defaults
    };
    let report = poisson_scale(&sc)?;
    report.emit(None);

    // Every round completed (poisson_scale would have errored on an
    // abort) — now hold the per-round accounting to the paper's
    // formulas. The probe must actually have exercised the
    // latency-modeled transport.
    assert!(report.probe_samples > 0, "status probe never completed a poll");
    let strict = std::env::var("SAFE_SCALE_NO_ASSERT").map_or(true, |v| v != "1");
    for row in &report.rows {
        if row.formula_delta() != 0 {
            let msg = format!(
                "round {}: {} messages vs {} expected (Δ{})",
                row.round,
                row.messages,
                row.expected_messages,
                row.formula_delta()
            );
            if strict && row.initiator_failovers == 0 {
                anyhow::bail!("{msg}");
            }
            println!("warning: {msg}");
        }
    }
    std::fs::write("BENCH_scale.json", report.to_json().to_string())?;
    println!("wrote BENCH_scale.json");
    Ok(())
}
