//! Paper Figs 19–20 (E15–E16): §5.5 subgrouping on 12 deep-edge nodes —
//! 1×12 / 2×6 / 3×4 / 4×3 parallel chains.
use safe_agg::harness::figures as f;

fn main() -> anyhow::Result<()> {
    let fig19 = f::subgroup_figure("fig19", "Deep-Edge. 12 Nodes 1 Feature.", 1)?;
    fig19.emit(None);
    let fig20 = f::subgroup_figure("fig20", "Deep-Edge. 12 Nodes 20 Features.", 20)?;
    fig20.emit(None);
    for (fig, label) in [(&fig19, "1 feature"), (&fig20, "20 features")] {
        if let (Some(one), Some(four)) =
            (fig.ratio_at("SAFE", "SAFE", 1.0), fig.ratio_at("SAFE", "SAFE", 4.0))
        {
            let _ = (one, four);
        }
        let s = &fig.series[0];
        let t1 = s.points.iter().find(|p| p.x == 1.0).map(|p| p.stats.mean_secs);
        let t4 = s.points.iter().find(|p| p.x == 4.0).map(|p| p.stats.mean_secs);
        if let (Some(t1), Some(t4)) = (t1, t4) {
            println!("{label}: 1x12 {t1:.3}s → 4x3 {t4:.3}s ({:.2}x speedup; paper ~2.2x)", t1 / t4);
        }
    }
    Ok(())
}
