//! Wire-codec bench (E21): the four codec stacks — json, binary,
//! json+deflate, binary+deflate — compared on encode/decode cost for the
//! hot message shapes, on the aggregate-path framing (raw blob vs PR 1's
//! base64 text), and on whole-round wire bytes broken down by endpoint.
//!
//! Emits a machine-readable `BENCH_wire.json` (bytes/round and
//! encode/decode ns per codec) so the perf trajectory is tracked across
//! PRs. The JSON column is the paper-parity default; the other stacks are
//! what a deployment that controls both endpoints can switch on with
//! `SessionConfig::wire` / `--wire`.

use std::collections::BTreeMap;
use std::time::Instant;

use safe_agg::config::{DeviceProfile, SessionConfig, WireFormat};
use safe_agg::crypto::envelope::{CipherMode, Envelope};
use safe_agg::harness::bench_repeats;
use safe_agg::json::Value;
use safe_agg::learner::faults::FaultPlan;
use safe_agg::proto;
use safe_agg::proto::codec::{BinaryCodec, WireCodec};
use safe_agg::protocols::SafeSession;

/// Per-codec measurement of one message shape.
struct CodecCost {
    encode_ns: f64,
    decode_ns: f64,
    bytes: usize,
}

fn measure(codec: &dyn WireCodec, msg: &Value, iters: u32) -> CodecCost {
    let mut bytes = 0usize;
    let t = Instant::now();
    let mut encoded = Vec::new();
    for _ in 0..iters {
        encoded = codec.encode(msg);
        bytes = encoded.len();
    }
    let encode_ns = t.elapsed().as_nanos() as f64 / iters as f64;
    let t = Instant::now();
    for _ in 0..iters {
        codec.decode(&encoded).unwrap();
    }
    let decode_ns = t.elapsed().as_nanos() as f64 / iters as f64;
    CodecCost { encode_ns, decode_ns, bytes }
}

fn encode_decode_table(report: &mut Value) {
    println!("── E21a: codec encode+decode cost (post_average shape) ──");
    println!(
        "{:>9} {:>15} {:>13} {:>10} {:>8}",
        "features", "codec", "enc+dec ns", "bytes", "vs json"
    );
    let mut shapes = Value::obj();
    for features in [64usize, 1024, 10_000, 100_000] {
        let avg: Vec<f64> = (0..features).map(|i| i as f64 * 0.12345 + 0.67).collect();
        let msg = proto::PostAverage { node: 1, group: 1, average: avg, contributors: 15 }
            .to_value();
        let iters = (1_000_000 / features.max(1)).clamp(3, 200) as u32;
        let mut json_bytes = 0usize;
        let mut row = Value::obj();
        for fmt in WireFormat::ALL {
            let cost = measure(fmt.codec(), &msg, iters);
            if fmt == WireFormat::Json {
                json_bytes = cost.bytes;
            }
            println!(
                "{:>9} {:>15} {:>13.0} {:>10} {:>7.2}x",
                features,
                fmt.name(),
                cost.encode_ns + cost.decode_ns,
                cost.bytes,
                json_bytes as f64 / cost.bytes as f64
            );
            row.set(
                fmt.name(),
                Value::object(vec![
                    ("encode_ns", Value::from(cost.encode_ns)),
                    ("decode_ns", Value::from(cost.decode_ns)),
                    ("bytes", Value::from(cost.bytes)),
                ]),
            );
        }
        shapes.set(&features.to_string(), row);
    }
    report.set("post_average_codec_cost", shapes);
    println!();
}

/// The aggregate path itself: a sealed 1024-feature payload as the new raw
/// blob framing vs PR 1's `mode:keyB64:bodyB64` text framing, both under
/// the binary codec.
fn aggregate_framing_table(report: &mut Value) {
    println!("── E21a': aggregate framing, raw blob vs PR 1 base64 text ──");
    let mut rng = safe_agg::crypto::rng::DeterministicRng::seed(7);
    let mut payload = vec![0u8; 1024 * 8];
    use safe_agg::crypto::rng::SecureRng;
    rng.fill_bytes(&mut payload);
    let env = Envelope {
        mode: CipherMode::Hybrid,
        sealed_key: payload[..64].to_vec(),
        body: payload.clone(),
    };
    let new_field = BinaryCodec.encode(&Value::Bytes(env.to_blob())).len();
    let pr1_field = BinaryCodec.encode(&Value::from(env.encode())).len();
    let reduction = 100.0 * (1.0 - new_field as f64 / pr1_field as f64);
    println!(
        "aggregate field (1024-feature sealed payload): raw {new_field} B vs \
         base64-text {pr1_field} B ({reduction:.1}% fewer)"
    );
    assert!(
        new_field * 4 <= pr1_field * 3,
        "raw framing must be ≥25% below PR 1's base64 framing"
    );
    report.set(
        "aggregate_framing",
        Value::object(vec![
            ("raw_blob_bytes", Value::from(new_field)),
            ("pr1_base64_bytes", Value::from(pr1_field)),
            ("reduction_pct", Value::from(reduction)),
        ]),
    );
    println!();
}

fn session_ratio_table(report: &mut Value) -> anyhow::Result<()> {
    println!("── E21b: whole-round wire bytes, SAFE 4 nodes (all codec stacks) ──");
    println!(
        "{:>9} {:>15} {:>12} {:>7} {:>9}",
        "features", "codec", "bytes", "ratio", "messages"
    );
    let repeats = bench_repeats(1).max(1);
    let mut sessions_out = Value::obj();
    for features in [64usize, 1024, 10_000] {
        let mut json_total = 0u64;
        let mut ref_msgs: Option<u64> = None;
        let mut per_endpoint: BTreeMap<&'static str, BTreeMap<String, u64>> = BTreeMap::new();
        let mut row = Value::obj();
        for fmt in WireFormat::ALL {
            let cfg = SessionConfig {
                n_nodes: 4,
                features,
                rsa_bits: 512,
                profile: DeviceProfile::instant(),
                poll_time: std::time::Duration::from_secs(5),
                // Keep failover out of the picture so message counts stay
                // comparable even on a loaded machine.
                progress_timeout: std::time::Duration::from_secs(30),
                aggregation_timeout: std::time::Duration::from_secs(60),
                wire: fmt,
                ..Default::default()
            };
            let session = SafeSession::new(cfg)?;
            // Full-mantissa inputs: realistic model weights serialize at
            // ~17 significant digits as JSON, which is what raw-f64
            // binary framing is up against.
            let inputs: Vec<Vec<f64>> = (1..=4)
                .map(|n| {
                    (0..features)
                        .map(|f| n as f64 + f as f64 * 0.707_106_781_186_547_6)
                        .collect()
                })
                .collect();
            let before = session.stats().per_path_stats();
            let mut total = 0u64;
            let mut msgs = 0u64;
            for _ in 0..repeats {
                let round = session.run_round(&inputs, &FaultPlan::none())?;
                total += round.metrics.bytes_sent + round.metrics.bytes_received;
                msgs = round.metrics.messages;
            }
            let after = session.stats().per_path_stats();
            // Sanity: all traffic was attributed to the session's codec.
            assert!(session.stats().codec_bytes(fmt) > 0);
            if fmt == WireFormat::Json {
                json_total = total;
            }
            match ref_msgs {
                None => ref_msgs = Some(msgs),
                Some(m) => assert_eq!(m, msgs, "codec must not change message counts"),
            }
            println!(
                "{:>9} {:>15} {:>12} {:>6.2}x {:>9}",
                features,
                fmt.name(),
                total,
                json_total as f64 / total as f64,
                msgs
            );
            if fmt != WireFormat::Json {
                assert!(total < json_total, "{} must ship fewer bytes than json", fmt.name());
            }
            // Per-endpoint byte deltas (sent + received) for the breakdown.
            let mut eps = BTreeMap::new();
            for (path, stat) in &after {
                let b = before.get(path).copied().unwrap_or_default();
                let bytes = (stat.bytes_sent - b.bytes_sent)
                    + (stat.bytes_received - b.bytes_received);
                if bytes > 0 {
                    eps.insert(path.clone(), bytes);
                }
            }
            per_endpoint.insert(fmt.name(), eps);
            row.set(fmt.name(), Value::from(total));
        }
        sessions_out.set(&features.to_string(), row);

        // Endpoint breakdown at this feature count (the per-path byte
        // counters in MessageStats, surfaced per codec).
        println!("  per-endpoint bytes (sent+received, {features} features):");
        let mut all_paths: Vec<String> = Vec::new();
        for eps in per_endpoint.values() {
            for p in eps.keys() {
                if !all_paths.contains(p) {
                    all_paths.push(p.clone());
                }
            }
        }
        all_paths.sort();
        print!("  {:>20}", "path");
        for fmt in WireFormat::ALL {
            print!(" {:>15}", fmt.name());
        }
        println!();
        for p in &all_paths {
            print!("  {:>20}", p);
            for fmt in WireFormat::ALL {
                let v = per_endpoint
                    .get(fmt.name())
                    .and_then(|eps| eps.get(p))
                    .copied()
                    .unwrap_or(0);
                print!(" {:>15}", v);
            }
            println!();
        }
        println!();

        if features == 1024 {
            let mut per_path_json = Value::obj();
            for (codec, eps) in &per_endpoint {
                let mut obj = Value::obj();
                for (p, b) in eps {
                    obj.set(p, Value::from(*b));
                }
                per_path_json.set(codec, obj);
            }
            report.set("per_path_bytes_1024_features", per_path_json);
        }
    }
    report.set("session_bytes", sessions_out);
    report.set("repeats", Value::from(repeats));
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let mut report = Value::obj();
    encode_decode_table(&mut report);
    aggregate_framing_table(&mut report);
    session_ratio_table(&mut report)?;
    let path = "BENCH_wire.json";
    std::fs::write(path, report.to_string())?;
    println!("wrote {path}");
    Ok(())
}
