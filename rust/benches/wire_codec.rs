//! Wire-codec bench (E21): JSON vs binary codec — encode/decode cost for
//! the hot message shapes, and whole-round wire-size ratios at growing
//! feature counts. The JSON column is the paper-parity default; the
//! binary column is what a deployment that controls both endpoints can
//! switch on with `SessionConfig::wire`.
use std::time::Instant;

use safe_agg::config::{DeviceProfile, SessionConfig, WireFormat};
use safe_agg::harness::bench_repeats;
use safe_agg::learner::faults::FaultPlan;
use safe_agg::proto;
use safe_agg::proto::codec::{BinaryCodec, JsonCodec, WireCodec};
use safe_agg::protocols::SafeSession;
use safe_agg::util::b64_encode;

fn encode_decode_table() {
    println!("── E21a: codec encode+decode cost (post_average shape) ──");
    println!(
        "{:>9} {:>12} {:>12} {:>10} {:>10} {:>7}",
        "features", "json", "binary", "json B", "bin B", "ratio"
    );
    for features in [64usize, 1024, 10_000, 100_000] {
        let avg: Vec<f64> = (0..features).map(|i| i as f64 * 0.12345 + 0.67).collect();
        let msg = proto::PostAverage { node: 1, group: 1, average: avg, contributors: 15 }
            .to_value();
        let iters = (1_000_000 / features.max(1)).clamp(3, 200) as u32;
        let t = Instant::now();
        let mut jlen = 0;
        for _ in 0..iters {
            let bytes = JsonCodec.encode(&msg);
            jlen = bytes.len();
            JsonCodec.decode(&bytes).unwrap();
        }
        let json_cost = t.elapsed() / iters;
        let t = Instant::now();
        let mut blen = 0;
        for _ in 0..iters {
            let bytes = BinaryCodec.encode(&msg);
            blen = bytes.len();
            BinaryCodec.decode(&bytes).unwrap();
        }
        let bin_cost = t.elapsed() / iters;
        println!(
            "{:>9} {:>12.2?} {:>12.2?} {:>10} {:>10} {:>6.2}x",
            features,
            json_cost,
            bin_cost,
            jlen,
            blen,
            jlen as f64 / blen as f64
        );
    }
    // The ciphertext-carrying path: a sealed aggregate rides as a string
    // either way; binary drops the JSON quoting/field framing.
    let payload = vec![0x5au8; 8192];
    let agg = proto::PostAggregate {
        from_node: 1,
        to_node: 2,
        group: 1,
        aggregate: format!("safe:{}:{}", b64_encode(&payload[..64]), b64_encode(&payload)),
        round_id: Some(0),
    }
    .to_value();
    let j = JsonCodec.encode(&agg).len();
    let b = BinaryCodec.encode(&agg).len();
    println!("post_aggregate (1024-feature sealed payload): json {j} B, binary {b} B");
    println!();
}

fn session_ratio_table() -> anyhow::Result<()> {
    println!("── E21b: whole-round wire bytes, SAFE 4 nodes (json vs binary) ──");
    println!(
        "{:>9} {:>12} {:>12} {:>7} {:>9}",
        "features", "json B", "binary B", "ratio", "messages"
    );
    let repeats = bench_repeats(1).max(1);
    for features in [64usize, 1024, 10_000] {
        let mut totals = [0u64; 2];
        let mut msgs = [0u64; 2];
        for (i, wire) in [WireFormat::Json, WireFormat::Binary].into_iter().enumerate() {
            let cfg = SessionConfig {
                n_nodes: 4,
                features,
                rsa_bits: 512,
                profile: DeviceProfile::instant(),
                poll_time: std::time::Duration::from_secs(5),
                // Keep failover out of the picture so message counts stay
                // comparable even on a loaded machine.
                progress_timeout: std::time::Duration::from_secs(30),
                aggregation_timeout: std::time::Duration::from_secs(60),
                wire,
                ..Default::default()
            };
            let session = SafeSession::new(cfg)?;
            // Full-mantissa inputs: realistic model weights serialize at
            // ~17 significant digits as JSON, which is what raw-f64
            // binary framing is up against.
            let inputs: Vec<Vec<f64>> = (1..=4)
                .map(|n| {
                    (0..features)
                        .map(|f| n as f64 + f as f64 * 0.707_106_781_186_547_6)
                        .collect()
                })
                .collect();
            for _ in 0..repeats {
                let round = session.run_round(&inputs, &FaultPlan::none())?;
                totals[i] += round.metrics.bytes_sent + round.metrics.bytes_received;
                msgs[i] = round.metrics.messages;
            }
            // Sanity: all traffic was attributed to the session's codec.
            assert!(session.stats().codec_bytes(wire) > 0);
        }
        println!(
            "{:>9} {:>12} {:>12} {:>6.2}x {:>9}",
            features,
            totals[0],
            totals[1],
            totals[0] as f64 / totals[1] as f64,
            msgs[1]
        );
        assert_eq!(msgs[0], msgs[1], "codec must not change message counts");
        assert!(totals[1] < totals[0], "binary must ship fewer bytes");
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    encode_decode_table();
    session_ratio_table()
}
