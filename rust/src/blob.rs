//! [`Blob`] — a cheaply-cloneable, immutable byte buffer for opaque wire
//! payloads (sealed aggregates, sealed symmetric keys, SMPC share blobs).
//!
//! The controller is "a mere message broker": the hottest thing it does is
//! store a ciphertext and hand it back out. `Blob` is an `Arc<[u8]>`, so
//! that store-and-forward path clones a pointer, never the payload — the
//! bytes decoded off the wire are the very same allocation delivered to
//! the next node (`Blob::ptr_eq` lets tests assert exactly that). Codecs
//! decide the byte representation: raw length-prefixed bytes under the
//! binary codec, base64 text only at the JSON boundary (see
//! `proto::codec`).

use std::ops::Deref;
use std::sync::Arc;

/// Immutable shared bytes. Equality is by content; `ptr_eq` checks
/// whether two blobs share one allocation (the zero-copy property).
#[derive(Clone, PartialEq, Eq)]
pub struct Blob(Arc<[u8]>);

impl Blob {
    pub fn new(bytes: Vec<u8>) -> Blob {
        Blob(Arc::from(bytes))
    }

    pub fn from_slice(bytes: &[u8]) -> Blob {
        Blob(Arc::from(bytes))
    }

    pub fn empty() -> Blob {
        Blob(Arc::from(Vec::new()))
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.0
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// True iff both blobs are the same allocation (not merely equal
    /// bytes) — the controller pass-through guarantee.
    pub fn ptr_eq(a: &Blob, b: &Blob) -> bool {
        Arc::ptr_eq(&a.0, &b.0)
    }
}

impl Deref for Blob {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        &self.0
    }
}

impl From<Vec<u8>> for Blob {
    fn from(bytes: Vec<u8>) -> Blob {
        Blob::new(bytes)
    }
}

impl From<&[u8]> for Blob {
    fn from(bytes: &[u8]) -> Blob {
        Blob::from_slice(bytes)
    }
}

impl std::fmt::Debug for Blob {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Blob({} bytes)", self.0.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equality_is_by_content_ptr_eq_by_allocation() {
        let a = Blob::new(vec![1, 2, 3]);
        let b = Blob::from_slice(&[1, 2, 3]);
        assert_eq!(a, b);
        assert!(!Blob::ptr_eq(&a, &b));
        let c = a.clone();
        assert!(Blob::ptr_eq(&a, &c), "clone must share the allocation");
    }

    #[test]
    fn deref_and_len() {
        let b = Blob::from_slice(b"xyz");
        assert_eq!(&b[..], b"xyz");
        assert_eq!(b.len(), 3);
        assert!(!b.is_empty());
        assert!(Blob::empty().is_empty());
    }
}
