//! Configuration system: session parameters, device profiles, CLI parsing.
//!
//! Everything a deployment needs in one typed struct, buildable from the
//! CLI (`safe run --nodes 36 --features 1000 --mode safe ...`), from a
//! JSON config file, or programmatically from the benches.

pub mod profile;

use std::time::Duration;

use crate::crypto::envelope::CipherMode;
use crate::transport::NetProfile;
pub use crate::proto::codec::WireFormat;
pub use profile::DeviceProfile;

/// How learners talk to the controller.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TransportKind {
    /// Direct in-process calls (paper's single-machine edge benchmark).
    InProc,
    /// Loopback/remote HTTP (the paper's REST deployment).
    Http { url: String },
}

/// Which vector math engine learners use for `agg + x` etc.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VectorEngine {
    /// Plain Rust loops.
    Native,
    /// AOT-compiled XLA executables via PJRT (L1/L2 artifacts).
    Xla,
    /// Pick per call: XLA for vectors ≥ threshold, native below.
    Auto,
}

/// Which executor drives the learners of an in-proc session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RuntimeKind {
    /// One OS thread per learner (the original executor; also the
    /// fallback for HTTP transports, whose blocking client calls need a
    /// thread to park).
    Threads,
    /// Worker-pool event runtime: learners are resumable state machines
    /// multiplexed over `workers` threads (`runtime_exec`). Default —
    /// this is what takes the scale harness past thread-per-learner
    /// limits (n=10,000 single-round smoke).
    Events,
}

/// Full description of one aggregation session (one or more rounds).
#[derive(Debug, Clone)]
pub struct SessionConfig {
    /// Total number of learners.
    pub n_nodes: usize,
    /// Feature-vector length each learner contributes.
    pub features: usize,
    /// Number of subgroups (§5.5); nodes are split evenly, chain order
    /// within a group follows node id.
    pub groups: usize,
    /// Payload protection (SAF / RSA / SAFE / pre-negotiated).
    pub mode: CipherMode,
    /// RSA modulus bits for learner keys.
    pub rsa_bits: usize,
    /// Compress payloads before sealing (§5.7/§6.2 — SAFE's compression).
    pub compress: bool,
    /// Weighted averaging (§5.6): the weight rides as an extra feature.
    pub weighted: bool,
    /// Device cost model (§6 edge vs §7 deep-edge).
    pub profile: DeviceProfile,
    /// Controller transport.
    pub transport: TransportKind,
    /// Wire codec for message bodies (JSON = paper parity, the default;
    /// binary = length-prefixed fields, raw little-endian f64 vectors and
    /// raw ciphertext framing; `json+deflate` / `binary+deflate` wrap the
    /// inner codec in transparent DEFLATE compression).
    pub wire: WireFormat,
    /// Vector math engine.
    pub engine: VectorEngine,
    /// Max single long-poll block at the controller.
    pub poll_time: Duration,
    /// Whole-aggregation timeout → initiator failover (§5.4).
    pub aggregation_timeout: Duration,
    /// Link-silence threshold → progress failover (§5.3).
    pub progress_timeout: Duration,
    /// How often the external monitor pings the controller.
    pub monitor_interval: Duration,
    /// Deterministic seed for data/keys (None → OS entropy).
    pub seed: Option<u64>,
    /// §5.9 staggered polling: node i delays its first `get_aggregate`
    /// poll by `i × stagger_step` so the whole chain doesn't camp on the
    /// controller's long-poll slots at once.
    pub stagger_step: Duration,
    /// Randomize the chain order between rounds (paper §8 discussion:
    /// "randomize the order between each round to limit the likelihood of
    /// two colluding nodes being able to get useful data").
    pub shuffle_chain_each_round: bool,
    /// Privacy-floor re-balancing (`--merge-floor on|off`, default on):
    /// when churn leaves a group with fewer than 3 live nodes, the
    /// topology planner merges its survivors into the smallest
    /// neighbouring group (only moved nodes re-key) instead of aborting.
    /// The abort path remains when the *total* live population drops
    /// below 3, or when this is off.
    pub merge_floor: bool,
    /// Learner executor (`--runtime threads|events`). `Events` (default)
    /// drives all learners as state machines on a small worker pool;
    /// `Threads` keeps one OS thread per learner. HTTP transports always
    /// fall back to `Threads`.
    pub runtime: RuntimeKind,
    /// Worker threads for the event runtime (`--workers N`); 0 = auto
    /// (available parallelism).
    pub workers: usize,
    /// Controller shards (`--shards K`, default 1): the aggregation plane
    /// splits the configured groups across K independent `Controller`
    /// shards, each owning its groups' chains, mailboxes and epoch state,
    /// with a fan-in parent combining contributor-weighted shard partials
    /// into the global average (§5.10 generalized). `1` keeps today's
    /// single-controller wiring bit-identically; values above the group
    /// count are clamped to it. In-proc transports only.
    pub shards: usize,
    /// Hostile-network profile (`--net PRESET[,FIELD=VALUE]*`): injected
    /// per-link latency/jitter, request/response packet loss,
    /// bandwidth-proportional delay and designated stragglers, all drawn
    /// deterministically from the profile seed. The default (`ideal`) is
    /// a byte-for-byte no-op. Parsed via
    /// [`NetProfile::parse`](crate::transport::netprofile::NetProfile::parse);
    /// malformed specs are a hard CLI error, never silently ignored.
    pub net: NetProfile,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            n_nodes: 3,
            features: 1,
            groups: 1,
            mode: CipherMode::Hybrid,
            rsa_bits: 1024,
            compress: true,
            weighted: false,
            profile: DeviceProfile::edge(),
            transport: TransportKind::InProc,
            wire: WireFormat::Json,
            engine: VectorEngine::Native,
            poll_time: Duration::from_millis(250),
            aggregation_timeout: Duration::from_secs(30),
            progress_timeout: Duration::from_millis(1500),
            monitor_interval: Duration::from_millis(200),
            seed: Some(42),
            stagger_step: Duration::ZERO,
            shuffle_chain_each_round: false,
            merge_floor: true,
            runtime: RuntimeKind::Events,
            workers: 0,
            shards: 1,
            net: NetProfile::default(),
        }
    }
}

impl SessionConfig {
    /// Split nodes 1..=n into `groups` chains round-robin-free (contiguous
    /// blocks, like the paper's 2×6 / 3×4 / 4×3 groupings).
    ///
    /// Deprecated shim: group/chain planning is now the topology
    /// subsystem's job. This delegates to
    /// [`GroupPlanner::even_split`](crate::topology::GroupPlanner::even_split)
    /// and returns the *configured* membership only — per-round state
    /// (churn re-formation, shuffling, privacy-floor merges) lives in
    /// [`GroupPlanner::plan_round`](crate::topology::GroupPlanner::plan_round).
    #[deprecated(
        note = "use topology::GroupPlanner (base_plan / plan_round); this \
                shim only reflects the static configured split"
    )]
    pub fn group_chains(&self) -> Vec<(u64, Vec<u64>)> {
        crate::topology::GroupPlanner::even_split(self.n_nodes, self.groups)
    }

    /// Effective vector length on the wire (weighted adds one feature).
    pub fn wire_features(&self) -> usize {
        self.features + if self.weighted { 1 } else { 0 }
    }
}

/// Tiny CLI argument parser (clap is not in the offline crate cache).
/// Supports `--key value`, `--key=value` and boolean `--flag`.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut args = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if iter.peek().map_or(false, |n| !n.starts_with("--")) {
                    args.flags.insert(rest.to_string(), iter.next().unwrap());
                } else {
                    args.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Build a session config from parsed flags (shared by CLI + examples).
    pub fn to_session_config(&self) -> SessionConfig {
        let mut cfg = SessionConfig::default();
        cfg.n_nodes = self.get_usize("nodes", cfg.n_nodes);
        cfg.features = self.get_usize("features", cfg.features);
        cfg.groups = self.get_usize("groups", cfg.groups).max(1);
        cfg.rsa_bits = self.get_usize("rsa-bits", cfg.rsa_bits);
        cfg.weighted = self.get_bool("weighted");
        if self.get_bool("no-compress") {
            cfg.compress = false;
        }
        cfg.mode = match self.get("mode") {
            Some("saf") => CipherMode::None,
            Some("rsa") => CipherMode::RsaOnly,
            Some("prenegotiated") | Some("preneg") => CipherMode::PreNegotiated,
            _ => CipherMode::Hybrid,
        };
        cfg.profile = match self.get("profile") {
            Some("deep-edge") | Some("deepedge") => DeviceProfile::deep_edge(),
            _ => DeviceProfile::edge(),
        };
        cfg.engine = match self.get("engine") {
            Some("xla") => VectorEngine::Xla,
            Some("auto") => VectorEngine::Auto,
            _ => VectorEngine::Native,
        };
        if let Some(url) = self.get("controller-url") {
            cfg.transport = TransportKind::Http { url: url.to_string() };
        }
        if let Some(w) = self.get("wire").and_then(WireFormat::from_name) {
            cfg.wire = w;
        }
        if let Some(s) = self.get("seed") {
            cfg.seed = s.parse().ok();
        }
        if let Some(v) = self.get("merge-floor") {
            cfg.merge_floor = matches!(v, "on" | "true" | "1" | "yes");
        }
        cfg.shuffle_chain_each_round =
            cfg.shuffle_chain_each_round || self.get_bool("shuffle-chain");
        cfg.runtime = match self.get("runtime") {
            Some("threads") | Some("thread") => RuntimeKind::Threads,
            _ => RuntimeKind::Events,
        };
        cfg.workers = self.get_usize("workers", cfg.workers);
        cfg.shards = self.get_usize("shards", cfg.shards).max(1);
        cfg
    }
}

#[cfg(test)]
mod tests {
    // The deprecated `group_chains` shim stays pinned by these tests
    // until external callers migrate to topology::GroupPlanner.
    #![allow(deprecated)]
    use super::*;

    #[test]
    fn group_chains_even_split() {
        let mut cfg = SessionConfig::default();
        cfg.n_nodes = 12;
        cfg.groups = 4;
        let chains = cfg.group_chains();
        assert_eq!(chains.len(), 4);
        assert_eq!(chains[0], (1, vec![1, 2, 3]));
        assert_eq!(chains[3], (4, vec![10, 11, 12]));
    }

    #[test]
    fn group_chains_uneven_split() {
        let mut cfg = SessionConfig::default();
        cfg.n_nodes = 7;
        cfg.groups = 2;
        let chains = cfg.group_chains();
        assert_eq!(chains[0].1, vec![1, 2, 3, 4]);
        assert_eq!(chains[1].1, vec![5, 6, 7]);
    }

    #[test]
    fn single_group_is_whole_chain() {
        let mut cfg = SessionConfig::default();
        cfg.n_nodes = 5;
        cfg.groups = 1;
        let chains = cfg.group_chains();
        assert_eq!(chains, vec![(1, vec![1, 2, 3, 4, 5])]);
    }

    #[test]
    fn args_parsing() {
        let a = Args::parse(
            ["run", "--nodes", "36", "--mode=saf", "--weighted", "--seed", "7"]
                .iter()
                .map(|s| s.to_string()),
        );
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get_usize("nodes", 0), 36);
        assert_eq!(a.get("mode"), Some("saf"));
        assert!(a.get_bool("weighted"));
        let cfg = a.to_session_config();
        assert_eq!(cfg.n_nodes, 36);
        assert_eq!(cfg.mode, CipherMode::None);
        assert!(cfg.weighted);
        assert_eq!(cfg.seed, Some(7));
    }

    #[test]
    fn wire_flag_selects_codec() {
        let a = Args::parse(["run", "--wire", "binary"].iter().map(|s| s.to_string()));
        assert_eq!(a.to_session_config().wire, WireFormat::Binary);
        let a = Args::parse(
            ["run", "--wire", "binary+deflate"].iter().map(|s| s.to_string()),
        );
        assert_eq!(a.to_session_config().wire, WireFormat::BinaryDeflate);
        let a = Args::parse(["run", "--wire=json+deflate"].iter().map(|s| s.to_string()));
        assert_eq!(a.to_session_config().wire, WireFormat::JsonDeflate);
        let a = Args::parse(["run"].iter().map(|s| s.to_string()));
        assert_eq!(a.to_session_config().wire, WireFormat::Json);
        let a = Args::parse(["run", "--wire", "bogus"].iter().map(|s| s.to_string()));
        assert_eq!(a.to_session_config().wire, WireFormat::Json);
    }

    #[test]
    fn merge_floor_flag() {
        let a = Args::parse(["run"].iter().map(|s| s.to_string()));
        assert!(a.to_session_config().merge_floor, "merging is the default");
        let a = Args::parse(["run", "--merge-floor", "off"].iter().map(|s| s.to_string()));
        assert!(!a.to_session_config().merge_floor);
        let a = Args::parse(["run", "--merge-floor=on"].iter().map(|s| s.to_string()));
        assert!(a.to_session_config().merge_floor);
    }

    #[test]
    fn runtime_flag_selects_executor() {
        let a = Args::parse(["run"].iter().map(|s| s.to_string()));
        assert_eq!(a.to_session_config().runtime, RuntimeKind::Events);
        assert_eq!(a.to_session_config().workers, 0, "0 = auto-size the pool");
        let a = Args::parse(["run", "--runtime", "threads"].iter().map(|s| s.to_string()));
        assert_eq!(a.to_session_config().runtime, RuntimeKind::Threads);
        let a = Args::parse(
            ["run", "--runtime=events", "--workers", "8"].iter().map(|s| s.to_string()),
        );
        let cfg = a.to_session_config();
        assert_eq!(cfg.runtime, RuntimeKind::Events);
        assert_eq!(cfg.workers, 8);
    }

    #[test]
    fn shards_flag_selects_plane_width() {
        let a = Args::parse(["run"].iter().map(|s| s.to_string()));
        assert_eq!(a.to_session_config().shards, 1, "single shard is the default");
        let a = Args::parse(["run", "--shards", "4"].iter().map(|s| s.to_string()));
        assert_eq!(a.to_session_config().shards, 4);
        let a = Args::parse(["run", "--shards=0"].iter().map(|s| s.to_string()));
        assert_eq!(a.to_session_config().shards, 1, "0 clamps to 1");
    }

    #[test]
    fn wire_features_weighted() {
        let mut cfg = SessionConfig::default();
        cfg.features = 10;
        assert_eq!(cfg.wire_features(), 10);
        cfg.weighted = true;
        assert_eq!(cfg.wire_features(), 11);
    }
}
