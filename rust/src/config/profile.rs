//! Device cost profiles — the §6 "edge" vs §7 "deep-edge" platforms.
//!
//! The paper's deep-edge evaluation runs on twelve TP-Link Archer C7
//! OpenWrt routers where "RSA key decryption is very slow" and "generating
//! random numbers is also quite slow" (§7). We do not have the routers, so
//! the profile injects per-operation delays calibrated to the relative op
//! costs those constraints imply (see DESIGN.md §3 Substitutions). The
//! *code path* exercised is identical — only the simulated CPU is slower.
//!
//! Calibration notes (approximate Archer C7 numbers from openssl speed on
//! a 720 MHz MIPS 74Kc, scaled):
//!   rsa1024 private op ≈ 25 ms, public op ≈ 1.5 ms, AES ≈ 8 MB/s,
//!   /dev/urandom reads ≈ 1 MB/s. The edge profile injects nothing and a
//!   2 ms REST hop; the deep-edge profile injects the above plus a 4 ms
//!   Wi-Fi-router LAN hop.

use std::time::Duration;

/// Cost model for a learner device class.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// One-way controller hop latency added per message.
    pub network_hop: Duration,
    /// Additional transfer cost per KiB of message body (the REST/JSON
    /// stack's per-byte handling; dominant for the bash+curl deep-edge
    /// client, mild for localhost HTTP).
    pub network_per_kib: Duration,
    /// Extra cost per RSA private-key operation (decrypt).
    pub rsa_private_op: Duration,
    /// Extra cost per RSA public-key operation (encrypt).
    pub rsa_public_op: Duration,
    /// Extra cost per KiB of symmetric cipher work.
    pub aes_per_kib: Duration,
    /// Extra cost per KiB of random bytes generated.
    pub random_per_kib: Duration,
}

impl DeviceProfile {
    /// §6 platform: desktop-class CPU; crypto at native speed, small
    /// localhost REST hop.
    pub fn edge() -> Self {
        DeviceProfile {
            name: "edge",
            network_hop: Duration::from_micros(500),
            network_per_kib: Duration::from_micros(4),
            rsa_private_op: Duration::ZERO,
            rsa_public_op: Duration::ZERO,
            aes_per_kib: Duration::ZERO,
            random_per_kib: Duration::ZERO,
        }
    }

    /// §7 platform: OpenWrt Archer C7 class device (simulated).
    pub fn deep_edge() -> Self {
        DeviceProfile {
            name: "deep-edge",
            network_hop: Duration::from_millis(2),
            network_per_kib: Duration::from_millis(2),
            rsa_private_op: Duration::from_millis(25),
            rsa_public_op: Duration::from_micros(1500),
            aes_per_kib: Duration::from_micros(125),
            random_per_kib: Duration::from_millis(1),
        }
    }

    /// Zero-cost profile for unit tests.
    pub fn instant() -> Self {
        DeviceProfile {
            name: "instant",
            network_hop: Duration::ZERO,
            network_per_kib: Duration::ZERO,
            rsa_private_op: Duration::ZERO,
            rsa_public_op: Duration::ZERO,
            aes_per_kib: Duration::ZERO,
            random_per_kib: Duration::ZERO,
        }
    }

    /// Simulate the cost of one crypto op of `kind` over `bytes` payload.
    pub fn charge(&self, kind: OpKind, bytes: usize) {
        let d = self.cost(kind, bytes);
        if !d.is_zero() {
            std::thread::sleep(d);
        }
    }

    /// The delay `charge` would sleep (exposed for tests/benches).
    pub fn cost(&self, kind: OpKind, bytes: usize) -> Duration {
        let kib = |per: Duration| per.mul_f64(bytes as f64 / 1024.0);
        match kind {
            OpKind::RsaPrivate => self.rsa_private_op,
            OpKind::RsaPublic => self.rsa_public_op,
            OpKind::Aes => kib(self.aes_per_kib),
            OpKind::RandomBytes => kib(self.random_per_kib),
        }
    }
}

/// Operation kinds a profile can tax.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    RsaPrivate,
    RsaPublic,
    Aes,
    RandomBytes,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_is_free_crypto() {
        let p = DeviceProfile::edge();
        assert_eq!(p.cost(OpKind::RsaPrivate, 0), Duration::ZERO);
        assert_eq!(p.cost(OpKind::Aes, 4096), Duration::ZERO);
    }

    #[test]
    fn deep_edge_charges_scale_with_bytes() {
        let p = DeviceProfile::deep_edge();
        assert!(p.cost(OpKind::RsaPrivate, 0) > Duration::from_millis(10));
        let one = p.cost(OpKind::Aes, 1024);
        let four = p.cost(OpKind::Aes, 4096);
        assert_eq!(four, one * 4);
        assert!(p.cost(OpKind::RandomBytes, 1024) >= Duration::from_micros(900));
    }

    #[test]
    fn rsa_private_much_slower_than_public_on_deep_edge() {
        // The §5.8 motivation: private ops dominate → pre-negotiate keys.
        let p = DeviceProfile::deep_edge();
        let priv_cost = p.cost(OpKind::RsaPrivate, 0);
        let pub_cost = p.cost(OpKind::RsaPublic, 0);
        assert!(priv_cost > pub_cost * 10);
    }
}
