//! BON baseline, server side — Bonawitz et al. 2017, "Practical Secure
//! Aggregation for Privacy-Preserving Machine Learning".
//!
//! The paper's §2/§6 comparison target. Unlike SAFE's broker, the BON
//! server *participates* in the aggregation: it collects masked inputs,
//! gathers Shamir shares after dropouts, reconstructs self-mask seeds
//! (for survivors) and DH secret keys (for dropped nodes), expands PRG
//! masks, unmasks the sum and computes the average. This O(n²) mask
//! structure and server-side crypto is exactly the overhead SAFE avoids.
//!
//! Rounds (matching §2's four-round description):
//!  0. advertise   — each node posts its two DH public keys (c^PK, s^PK)
//!  1. post_shares — Shamir shares of b_u and s_u^SK, one sealed blob per
//!                   peer, routed through the server
//!  2. post_masked — y_u = x_u + PRG(b_u) ± Σ PRG(s_{u,v})
//!  3. post_unmask — survivors reveal shares; the server reconstructs and
//!                   unmasks
//!
//! Sign convention for pairwise masks: for a pair (u, v) with u < v, node
//! u ADDS PRG(s_{u,v}) and node v SUBTRACTS it.

use std::collections::{BTreeMap, BTreeSet};
use std::time::Instant;

use super::Controller;
use crate::blob::Blob;
use crate::crypto::dh::DhGroup;
use crate::crypto::{Big, DefaultBig, Int, ModContext};
use crate::crypto::rng::prg_expand_f64;
use crate::crypto::shamir;
use crate::json::Value;
use crate::proto;

pub struct BonState {
    /// Expected participants (node ids).
    pub expected: BTreeSet<u64>,
    /// Shamir threshold t (default ⌈2n/3⌉).
    pub threshold: usize,
    /// DH group parameters shared by everyone.
    pub group: DhGroup,
    /// Round 0: node → (c_pk_hex, s_pk_hex).
    pub keys: BTreeMap<u64, (String, String)>,
    /// Round 1: recipient → sender → sealed share blob (opaque to the
    /// server, stored and forwarded as the posted allocation).
    pub shares: BTreeMap<u64, BTreeMap<u64, Blob>>,
    /// Round 2: node → masked input y_u.
    pub masked: BTreeMap<u64, Vec<f64>>,
    pub round2_closed: bool,
    pub survivors: BTreeSet<u64>,
    pub last_masked_at: Option<Instant>,
    /// Round 3: shares of b_u for surviving u (node-being-reconstructed →
    /// collected shares).
    pub b_shares: BTreeMap<u64, Vec<shamir::Share>>,
    /// Round 3: shares of s_d^SK for dropped d.
    pub s_shares: BTreeMap<u64, Vec<shamir::Share>>,
    pub average: Option<Vec<f64>>,
}

impl Default for BonState {
    fn default() -> Self {
        BonState {
            expected: BTreeSet::new(),
            threshold: 0,
            group: DhGroup::standard(),
            keys: BTreeMap::new(),
            shares: BTreeMap::new(),
            masked: BTreeMap::new(),
            round2_closed: false,
            survivors: BTreeSet::new(),
            last_masked_at: None,
            b_shares: BTreeMap::new(),
            s_shares: BTreeMap::new(),
            average: None,
        }
    }
}

impl BonState {
    pub fn configure(&mut self, expected: BTreeSet<u64>) {
        let n = expected.len();
        *self = BonState {
            expected,
            threshold: (2 * n + 2) / 3, // ⌈2n/3⌉
            ..BonState::default()
        };
    }

    /// Close round 2 if everyone posted, or the timeout elapsed with at
    /// least `threshold` inputs.
    fn maybe_close_round2(&mut self, timeout: std::time::Duration) {
        if self.round2_closed || self.expected.is_empty() {
            return;
        }
        let all = self.masked.len() == self.expected.len();
        let timed_out = self
            .last_masked_at
            .map_or(false, |t| t.elapsed() > timeout && self.masked.len() >= self.threshold);
        if all || timed_out {
            self.round2_closed = true;
            self.survivors = self.masked.keys().copied().collect();
        }
    }

    fn dropped(&self) -> Vec<u64> {
        self.expected.iter().copied().filter(|n| !self.survivors.contains(n)).collect()
    }

    /// Try to unmask once all needed shares are in.
    fn maybe_unmask(&mut self) {
        if self.average.is_some() || !self.round2_closed || self.survivors.is_empty() {
            return;
        }
        // Need ≥ t shares of b_u for every survivor u, and ≥ t shares of
        // s_d^SK for every dropped d.
        for u in &self.survivors {
            if self.b_shares.get(u).map_or(0, |s| s.len()) < self.threshold {
                return;
            }
        }
        let dropped = self.dropped();
        for d in &dropped {
            if self.s_shares.get(d).map_or(0, |s| s.len()) < self.threshold {
                return;
            }
        }
        let n_feat = match self.masked.values().next() {
            Some(v) => v.len(),
            None => return,
        };
        // Sum masked inputs over survivors.
        let mut sum = vec![0.0f64; n_feat];
        for u in &self.survivors {
            let y = &self.masked[u];
            for (a, b) in sum.iter_mut().zip(y) {
                *a += b;
            }
        }
        // Subtract each survivor's self-mask PRG(b_u).
        for u in &self.survivors {
            let b_seed = match shamir::reconstruct_secret(&self.b_shares[u][..self.threshold]) {
                Ok(s) => s,
                Err(_) => return,
            };
            let mask = prg_expand_f64(&b_seed, n_feat);
            for (a, m) in sum.iter_mut().zip(&mask) {
                *a -= m;
            }
        }
        // Cancel residual pairwise masks involving dropped nodes. One
        // exponentiation context for the group modulus serves every
        // dropped×survivor pair.
        let gctx = self.group.ctx();
        for d in &dropped {
            let sk_bytes = match shamir::reconstruct_secret(&self.s_shares[d][..self.threshold]) {
                Ok(s) => s,
                Err(_) => return,
            };
            let s_sk = DefaultBig::from_bytes_be(&sk_bytes);
            for v in &self.survivors {
                let Some((_, spk_hex)) = self.keys.get(v) else { continue };
                let Ok(spk) = DefaultBig::from_hex(spk_hex) else { continue };
                // Recompute the pairwise seed exactly like the clients:
                // KDF(spk_v ^ s_d^SK mod p).
                let shared = gctx.modpow(&spk, &s_sk);
                let seed = pairwise_seed(&shared);
                let mask = prg_expand_f64(&seed, n_feat);
                if *d < *v {
                    // v subtracted PRG(s_{d,v}); add it back.
                    for (a, m) in sum.iter_mut().zip(&mask) {
                        *a += m;
                    }
                } else {
                    // v added it; subtract.
                    for (a, m) in sum.iter_mut().zip(&mask) {
                        *a -= m;
                    }
                }
            }
        }
        let k = self.survivors.len() as f64;
        for a in sum.iter_mut() {
            *a /= k;
        }
        self.average = Some(sum);
    }
}

/// KDF from a DH shared value to a 32-byte PRG seed — must match the
/// client side in `protocols::bon`.
pub fn pairwise_seed(shared: &Int) -> [u8; 32] {
    use sha2::{Digest, Sha256};
    let mut h = Sha256::new();
    h.update(b"bon-pairwise");
    h.update(DefaultBig::to_bytes_be(shared));
    h.finalize().into()
}

pub fn advertise(ctrl: &Controller, body: &Value) -> Value {
    let req = match proto::BonAdvertise::from_value(body) {
        Ok(r) => r,
        Err(e) => return proto::status(&e.to_string()),
    };
    let mut inner = ctrl.inner.lock().unwrap();
    inner.bon.keys.insert(req.node, (req.cpk, req.spk));
    ctrl.cv.notify_all();
    proto::status("ok")
}

pub fn get_keys(ctrl: &Controller, body: &Value) -> Value {
    let _ = body;
    let poll = ctrl.inner.lock().unwrap().config.poll_time;
    let res = ctrl.wait_until(poll, |inner| {
        if !inner.bon.expected.is_empty() && inner.bon.keys.len() == inner.bon.expected.len() {
            Some(inner.bon.keys.clone())
        } else {
            None
        }
    });
    match res {
        Some(keys) => {
            let mut obj = Value::obj();
            for (node, (cpk, spk)) in keys {
                obj.set(
                    &node.to_string(),
                    Value::object(vec![
                        ("cpk", Value::from(cpk)),
                        ("spk", Value::from(spk)),
                    ]),
                );
            }
            Value::object(vec![("status", Value::from("ok")), ("keys", obj)])
        }
        None => proto::status("empty"),
    }
}

pub fn post_shares(ctrl: &Controller, body: &Value) -> Value {
    let from = match body.u64_of("node") {
        Some(n) => n,
        None => return proto::status("missing node"),
    };
    let shares = match body.get("shares") {
        Some(Value::Obj(m)) => m.clone(),
        _ => return proto::status("missing shares"),
    };
    let mut inner = ctrl.inner.lock().unwrap();
    for (to_str, blob) in shares {
        if let (Ok(to), Some(b)) = (to_str.parse::<u64>(), blob.as_blob()) {
            inner.bon.shares.entry(to).or_default().insert(from, b);
        }
    }
    ctrl.cv.notify_all();
    proto::status("ok")
}

pub fn get_shares(ctrl: &Controller, body: &Value) -> Value {
    let node = match body.u64_of("node") {
        Some(n) => n,
        None => return proto::status("missing node"),
    };
    let poll = ctrl.inner.lock().unwrap().config.poll_time;
    let res = ctrl.wait_until(poll, |inner| {
        let needed = inner.bon.expected.len().saturating_sub(1);
        let got = inner.bon.shares.get(&node).map_or(0, |m| m.len());
        if needed > 0 && got >= needed {
            Some(inner.bon.shares.get(&node).cloned().unwrap_or_default())
        } else {
            None
        }
    });
    match res {
        Some(m) => {
            let mut obj = Value::obj();
            for (from, blob) in m {
                obj.set(&from.to_string(), Value::Bytes(blob));
            }
            Value::object(vec![("status", Value::from("ok")), ("shares", obj)])
        }
        None => proto::status("empty"),
    }
}

pub fn post_masked(ctrl: &Controller, body: &Value) -> Value {
    let req = match proto::BonPostMasked::from_value(body) {
        Ok(r) => r,
        Err(e) => return proto::status(&e.to_string()),
    };
    let mut inner = ctrl.inner.lock().unwrap();
    if inner.bon.round2_closed {
        return proto::status("round_closed");
    }
    inner.bon.masked.insert(req.node, req.y);
    inner.bon.last_masked_at = Some(Instant::now());
    let timeout = inner.config.bon_round2_timeout;
    inner.bon.maybe_close_round2(timeout);
    ctrl.cv.notify_all();
    proto::status("ok")
}

pub fn get_survivors(ctrl: &Controller, body: &Value) -> Value {
    let _ = body;
    let (poll, timeout) = {
        let inner = ctrl.inner.lock().unwrap();
        (inner.config.poll_time, inner.config.bon_round2_timeout)
    };
    let res = ctrl.wait_until(poll, |inner| {
        inner.bon.maybe_close_round2(timeout);
        if inner.bon.round2_closed {
            Some((inner.bon.survivors.clone(), inner.bon.dropped()))
        } else {
            None
        }
    });
    match res {
        Some((survivors, dropped)) => Value::object(vec![
            ("status", Value::from("ok")),
            (
                "survivors",
                Value::Arr(survivors.iter().map(|&n| Value::from(n)).collect()),
            ),
            (
                "dropped",
                Value::Arr(dropped.iter().map(|&n| Value::from(n)).collect()),
            ),
        ]),
        None => proto::status("empty"),
    }
}

pub fn post_unmask(ctrl: &Controller, body: &Value) -> Value {
    let node = match body.u64_of("node") {
        Some(n) => n,
        None => return proto::status("missing node"),
    };
    let _ = node;
    let mut inner = ctrl.inner.lock().unwrap();
    if let Some(Value::Obj(m)) = body.get("b_shares") {
        for (about_str, share_v) in m {
            if let (Ok(about), Ok(share)) =
                (about_str.parse::<u64>(), shamir::Share::from_json(share_v))
            {
                inner.bon.b_shares.entry(about).or_default().push(share);
            }
        }
    }
    if let Some(Value::Obj(m)) = body.get("s_shares") {
        for (about_str, share_v) in m {
            if let (Ok(about), Ok(share)) =
                (about_str.parse::<u64>(), shamir::Share::from_json(share_v))
            {
                inner.bon.s_shares.entry(about).or_default().push(share);
            }
        }
    }
    inner.bon.maybe_unmask();
    ctrl.cv.notify_all();
    proto::status("ok")
}

pub fn get_average(ctrl: &Controller, body: &Value) -> Value {
    let _ = body;
    let poll = ctrl.inner.lock().unwrap().config.poll_time;
    match ctrl.wait_until(poll, |inner| {
        inner.bon.maybe_unmask();
        inner.bon.average.clone()
    }) {
        Some(avg) => Value::object(vec![
            ("status", Value::from("ok")),
            ("average", Value::from(avg)),
        ]),
        None => proto::status("empty"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_is_two_thirds_ceil() {
        let mut s = BonState::default();
        s.configure((1..=3u64).collect());
        assert_eq!(s.threshold, 2);
        s.configure((1..=8u64).collect());
        assert_eq!(s.threshold, 6);
        s.configure((1..=15u64).collect());
        assert_eq!(s.threshold, 10);
    }

    #[test]
    fn round2_closes_when_all_posted() {
        let mut s = BonState::default();
        s.configure((1..=3u64).collect());
        for n in 1..=3u64 {
            s.masked.insert(n, vec![1.0]);
            s.last_masked_at = Some(Instant::now());
        }
        s.maybe_close_round2(std::time::Duration::from_secs(10));
        assert!(s.round2_closed);
        assert_eq!(s.survivors.len(), 3);
        assert!(s.dropped().is_empty());
    }

    #[test]
    fn round2_timeout_closes_with_threshold() {
        let mut s = BonState::default();
        s.configure((1..=3u64).collect());
        s.masked.insert(1, vec![1.0]);
        s.masked.insert(2, vec![2.0]);
        s.last_masked_at = Some(Instant::now() - std::time::Duration::from_secs(5));
        s.maybe_close_round2(std::time::Duration::from_millis(100));
        assert!(s.round2_closed);
        assert_eq!(s.dropped(), vec![3]);
    }

    #[test]
    fn round2_does_not_close_below_threshold() {
        let mut s = BonState::default();
        s.configure((1..=6u64).collect()); // t = 4
        s.masked.insert(1, vec![1.0]);
        s.last_masked_at = Some(Instant::now() - std::time::Duration::from_secs(5));
        s.maybe_close_round2(std::time::Duration::from_millis(100));
        assert!(!s.round2_closed);
    }
}
