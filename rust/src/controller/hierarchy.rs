//! Hierarchical federation (paper §5.10): child controllers post their
//! (already anonymized) aggregate averages to a parent controller, which
//! combines them into a global average — "this posting does not have to be
//! encrypted as it is already anonymized over learners, but it needs to be
//! coordinated".
//!
//! The parent side lives here (two endpoints on the regular controller);
//! the child side is a small client in `protocols::hierarchy` that bridges
//! a completed local aggregation up one level.

use std::collections::BTreeMap;

use super::Controller;
use crate::json::Value;
use crate::proto;

#[derive(Default)]
pub struct FedState {
    /// How many child controllers must report before the global average is
    /// released.
    pub expected_children: usize,
    /// child id → (average, contributor count).
    pub child_averages: BTreeMap<u64, (Vec<f64>, u64)>,
}

impl FedState {
    /// Contributor-weighted global average across children.
    fn global(&self) -> Option<(Vec<f64>, u64)> {
        if self.expected_children == 0 || self.child_averages.len() < self.expected_children {
            return None;
        }
        let mut total_w = 0u64;
        let mut acc: Option<Vec<f64>> = None;
        for (avg, w) in self.child_averages.values() {
            let w = (*w).max(1);
            total_w += w;
            match &mut acc {
                None => acc = Some(avg.iter().map(|x| x * w as f64).collect()),
                Some(a) => {
                    for (x, y) in a.iter_mut().zip(avg) {
                        *x += y * w as f64;
                    }
                }
            }
        }
        let mut avg = acc?;
        for x in avg.iter_mut() {
            *x /= total_w as f64;
        }
        Some((avg, total_w))
    }
}

pub fn post_child_average(ctrl: &Controller, body: &Value) -> Value {
    let req = match proto::FedChildAverage::from_value(body) {
        Ok(r) => r,
        Err(e) => return proto::status(&e.to_string()),
    };
    let mut inner = ctrl.inner.lock().unwrap();
    inner
        .fed
        .child_averages
        .insert(req.child, (req.average, req.contributors));
    ctrl.cv.notify_all();
    proto::status("ok")
}

pub fn get_global_average(ctrl: &Controller, body: &Value) -> Value {
    let _ = body;
    let poll = ctrl.inner.lock().unwrap().config.poll_time;
    match ctrl.wait_until(poll, |inner| inner.fed.global()) {
        Some((avg, total)) => {
            proto::FedGlobalAverage { average: avg, contributors: total }.into_value()
        }
        None => proto::status("empty"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::ControllerConfig;
    use crate::transport::Handler;
    use std::time::Duration;

    #[test]
    fn weighted_global_average() {
        let c = Controller::new(ControllerConfig {
            poll_time: Duration::from_millis(100),
            ..Default::default()
        });
        c.handle(
            proto::CONFIGURE,
            &Value::object(vec![("fed_expected_children", Value::from(2u64))]),
        );
        c.handle(
            proto::FED_POST_CHILD_AVERAGE,
            &Value::object(vec![
                ("child", Value::from(1u64)),
                ("average", Value::from(vec![1.0])),
                ("contributors", Value::from(3u64)),
            ]),
        );
        let r = c.handle(proto::FED_GET_GLOBAL_AVERAGE, &Value::obj());
        assert_eq!(r.str_of("status"), Some("empty"));
        c.handle(
            proto::FED_POST_CHILD_AVERAGE,
            &Value::object(vec![
                ("child", Value::from(2u64)),
                ("average", Value::from(vec![5.0])),
                ("contributors", Value::from(1u64)),
            ]),
        );
        let r = c.handle(proto::FED_GET_GLOBAL_AVERAGE, &Value::obj());
        assert_eq!(r.str_of("status"), Some("ok"));
        // (1*3 + 5*1) / 4 = 2
        assert_eq!(r.f64_arr_of("average").unwrap(), vec![2.0]);
        assert_eq!(r.u64_of("contributors"), Some(4));
    }
}
