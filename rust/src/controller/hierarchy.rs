//! Hierarchical federation (paper §5.10): child controllers post their
//! (already anonymized) aggregate averages to a parent controller, which
//! combines them into a global average — "this posting does not have to be
//! encrypted as it is already anonymized over learners, but it needs to be
//! coordinated".
//!
//! The parent side lives here (two endpoints on the regular controller);
//! the child side is a small client in `protocols::hierarchy` that bridges
//! a completed local aggregation up one level. The sharded aggregation
//! plane reuses this tier as its fan-in: each shard's fan-in worker is a
//! `FederationBridge` child, and the parent's contributor-weighted combine
//! is the global average the shards install back for their learners.

use std::collections::BTreeMap;

use super::Controller;
use crate::json::Value;
use crate::proto;
use crate::transport::PollKey;

#[derive(Default)]
pub struct FedState {
    /// How many child controllers must report before the global average is
    /// released.
    pub expected_children: usize,
    /// child id → (average, contributor count).
    pub child_averages: BTreeMap<u64, (Vec<f64>, u64)>,
}

impl FedState {
    /// Contributor-weighted combine over `children`. `None` when the
    /// iterator is empty. Zero-weight children cannot occur here — the
    /// post endpoint rejects `contributors == 0` with a typed error
    /// instead of silently re-weighting it.
    fn combine<'a>(
        children: impl Iterator<Item = &'a (Vec<f64>, u64)>,
    ) -> Option<(Vec<f64>, u64)> {
        let mut total_w = 0u64;
        let mut acc: Option<Vec<f64>> = None;
        for (avg, w) in children {
            total_w += w;
            match &mut acc {
                None => acc = Some(avg.iter().map(|x| x * *w as f64).collect()),
                Some(a) => {
                    for (x, y) in a.iter_mut().zip(avg) {
                        *x += y * *w as f64;
                    }
                }
            }
        }
        let mut avg = acc?;
        for x in avg.iter_mut() {
            *x /= total_w as f64;
        }
        Some((avg, total_w))
    }

    /// Contributor-weighted global average across all expected children
    /// (the §5.10 fan-in barrier): `None` until every child reported.
    pub(crate) fn global(&self) -> Option<(Vec<f64>, u64)> {
        if self.expected_children == 0 || self.child_averages.len() < self.expected_children {
            return None;
        }
        Self::combine(self.child_averages.values())
    }

    /// Degraded combine over whichever children have reported (a shard
    /// died and the fan-in barrier timed out): `None` only when nobody
    /// posted at all.
    pub(crate) fn partial(&self) -> Option<(Vec<f64>, u64)> {
        Self::combine(self.child_averages.values())
    }

    /// Has every expected child posted (cheap wake predicate)?
    fn barrier_complete(&self) -> bool {
        self.expected_children > 0 && self.child_averages.len() >= self.expected_children
    }
}

pub fn post_child_average(ctrl: &Controller, body: &Value) -> Value {
    let req = match proto::FedChildAverage::from_value(body) {
        Ok(r) => r,
        Err(e) => return proto::status(&e.to_string()),
    };
    // A zero-contributor child has nothing to combine: weighting it in
    // (the old `w.max(1)`) would skew the global toward an average built
    // from nobody. Reject it so the child can degrade explicitly.
    if req.contributors == 0 {
        return proto::status("zero_contributors");
    }
    let mut inner = ctrl.inner.lock().unwrap();
    inner
        .fed
        .child_averages
        .insert(req.child, (req.average, req.contributors));
    let complete = inner.fed.barrier_complete();
    drop(inner);
    ctrl.cv.notify_all();
    if complete {
        ctrl.hub.wake(PollKey::FedGlobal);
    }
    proto::status("ok")
}

pub fn get_global_average(ctrl: &Controller, body: &Value) -> Value {
    // `partial: true` is the degraded fetch a fan-in client falls back to
    // after its completion long-poll timed out: combine whatever children
    // have posted instead of waiting out the barrier.
    if body.bool_of("partial").unwrap_or(false) {
        let inner = ctrl.inner.lock().unwrap();
        return match inner.fed.partial() {
            Some((avg, total)) => {
                let mut v =
                    proto::FedGlobalAverage { average: avg, contributors: total }.into_value();
                v.set("partial", Value::from(true));
                v
            }
            None => proto::status("empty"),
        };
    }
    let poll = ctrl.inner.lock().unwrap().config.poll_time;
    match ctrl.wait_until(poll, |inner| inner.fed.global()) {
        Some((avg, total)) => {
            proto::FedGlobalAverage { average: avg, contributors: total }.into_value()
        }
        None => proto::status("empty"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::ControllerConfig;
    use crate::transport::Handler;
    use std::time::Duration;

    fn parent(children: u64) -> Controller {
        let c = Controller::new(ControllerConfig {
            poll_time: Duration::from_millis(100),
            ..Default::default()
        });
        c.handle(
            proto::CONFIGURE,
            &Value::object(vec![("fed_expected_children", Value::from(children))]),
        );
        c
    }

    fn post(c: &Controller, child: u64, avg: &[f64], contributors: u64) -> Value {
        c.handle(
            proto::FED_POST_CHILD_AVERAGE,
            &proto::FedChildAverage::body(child, avg, contributors),
        )
    }

    #[test]
    fn weighted_global_average() {
        let c = parent(2);
        post(&c, 1, &[1.0], 3);
        let r = c.handle(proto::FED_GET_GLOBAL_AVERAGE, &Value::obj());
        assert_eq!(r.str_of("status"), Some("empty"));
        post(&c, 2, &[5.0], 1);
        let r = c.handle(proto::FED_GET_GLOBAL_AVERAGE, &Value::obj());
        assert_eq!(r.str_of("status"), Some("ok"));
        // (1*3 + 5*1) / 4 = 2
        assert_eq!(r.f64_arr_of("average").unwrap(), vec![2.0]);
        assert_eq!(r.u64_of("contributors"), Some(4));
        assert_eq!(r.bool_of("partial"), None);
    }

    #[test]
    fn zero_contributor_child_is_rejected() {
        let c = parent(2);
        let r = post(&c, 1, &[9.0], 0);
        assert_eq!(r.str_of("status"), Some("zero_contributors"));
        // The rejected post left no state behind: the barrier still needs
        // two children, and the global is unskewed by the phantom child.
        post(&c, 1, &[1.0], 3);
        let r = c.handle(proto::FED_GET_GLOBAL_AVERAGE, &Value::obj());
        assert_eq!(r.str_of("status"), Some("empty"));
        post(&c, 2, &[5.0], 1);
        let r = c.handle(proto::FED_GET_GLOBAL_AVERAGE, &Value::obj());
        assert_eq!(r.f64_arr_of("average").unwrap(), vec![2.0]);
    }

    #[test]
    fn partial_fetch_combines_posted_children_only() {
        // Expected 3 children but one shard died: the barrier never
        // completes, yet a partial fetch serves the degraded combine of
        // the two that did post — flagged so the caller knows.
        let c = parent(3);
        let r = c.handle(
            proto::FED_GET_GLOBAL_AVERAGE,
            &Value::object(vec![("partial", Value::from(true))]),
        );
        assert_eq!(r.str_of("status"), Some("empty"), "nothing posted yet");
        post(&c, 1, &[10.0], 4);
        post(&c, 2, &[20.0], 6);
        let r = c.handle(proto::FED_GET_GLOBAL_AVERAGE, &Value::obj());
        assert_eq!(r.str_of("status"), Some("empty"), "barrier incomplete");
        let r = c.handle(
            proto::FED_GET_GLOBAL_AVERAGE,
            &Value::object(vec![("partial", Value::from(true))]),
        );
        assert_eq!(r.str_of("status"), Some("ok"));
        assert_eq!(r.bool_of("partial"), Some(true));
        assert_eq!(r.u64_of("contributors"), Some(10));
        assert!((r.f64_arr_of("average").unwrap()[0] - 16.0).abs() < 1e-12);
    }
}
