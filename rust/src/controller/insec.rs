//! INSEC baseline: the insecure central aggregator the paper benchmarks
//! against (§6: "a benchmark approach that simply posts parameters to a
//! central controller and retrieves averages").
//!
//! Each node posts its cleartext vector; when all expected nodes of a
//! group have posted, the controller computes the group mean; the global
//! mean is averaged across groups like SAFE's.

use std::collections::BTreeMap;

use super::Controller;
use crate::json::Value;
use crate::proto;

#[derive(Default)]
pub struct InsecState {
    /// group → expected number of posts.
    pub expected: BTreeMap<u64, usize>,
    /// group → node → vector.
    pub posts: BTreeMap<u64, BTreeMap<u64, Vec<f64>>>,
    /// group → computed group average.
    pub averages: BTreeMap<u64, Vec<f64>>,
}

impl InsecState {
    pub fn configure_group(&mut self, group: u64, expected: usize) {
        self.expected.insert(group, expected);
        self.posts.remove(&group);
        self.averages.remove(&group);
    }

    fn try_close(&mut self, group: u64) {
        let Some(&expected) = self.expected.get(&group) else { return };
        let Some(posts) = self.posts.get(&group) else { return };
        if posts.len() < expected || self.averages.contains_key(&group) {
            return;
        }
        let mut it = posts.values();
        let first = it.next().expect("non-empty").clone();
        let mut acc = first;
        for v in it {
            for (a, b) in acc.iter_mut().zip(v) {
                *a += b;
            }
        }
        for a in acc.iter_mut() {
            *a /= posts.len() as f64;
        }
        self.averages.insert(group, acc);
    }

    fn global_average(&self) -> Option<(Vec<f64>, u64)> {
        if self.expected.is_empty() || self.averages.len() < self.expected.len() {
            return None;
        }
        let mut acc: Option<Vec<f64>> = None;
        for avg in self.averages.values() {
            match &mut acc {
                None => acc = Some(avg.clone()),
                Some(a) => {
                    for (x, y) in a.iter_mut().zip(avg) {
                        *x += y;
                    }
                }
            }
        }
        let mut avg = acc?;
        let g = self.averages.len();
        for x in avg.iter_mut() {
            *x /= g as f64;
        }
        Some((avg, g as u64))
    }
}

pub fn post(ctrl: &Controller, body: &Value) -> Value {
    let req = match proto::InsecPost::from_value(body) {
        Ok(r) => r,
        Err(e) => return proto::status(&e.to_string()),
    };
    let mut inner = ctrl.inner.lock().unwrap();
    inner.insec.posts.entry(req.group).or_default().insert(req.node, req.vector);
    inner.insec.try_close(req.group);
    ctrl.cv.notify_all();
    proto::status("ok")
}

pub fn get_average(ctrl: &Controller, body: &Value) -> Value {
    let _ = body;
    let poll = ctrl.inner.lock().unwrap().config.poll_time;
    match ctrl.wait_until(poll, |inner| inner.insec.global_average()) {
        Some((avg, groups)) => proto::AverageReady { average: avg, groups }.into_value(),
        None => proto::status("empty"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::ControllerConfig;
    use crate::transport::Handler;
    use std::time::Duration;

    fn ctrl(groups: &[(u64, usize)]) -> Controller {
        let c = Controller::new(ControllerConfig {
            poll_time: Duration::from_millis(150),
            ..Default::default()
        });
        {
            let mut inner = c.inner.lock().unwrap();
            for &(g, n) in groups {
                inner.insec.configure_group(g, n);
                inner.expected_groups.insert(g);
            }
        }
        c
    }

    fn post_body(node: u64, group: u64, v: &[f64]) -> Value {
        Value::object(vec![
            ("node", Value::from(node)),
            ("group", Value::from(group)),
            ("vector", Value::from(v)),
        ])
    }

    #[test]
    fn averages_when_all_posted() {
        let c = ctrl(&[(1, 3)]);
        c.handle(proto::INSEC_POST, &post_body(1, 1, &[1.0, 10.0]));
        c.handle(proto::INSEC_POST, &post_body(2, 1, &[2.0, 20.0]));
        let r = c.handle(proto::INSEC_GET_AVERAGE, &Value::obj());
        assert_eq!(r.str_of("status"), Some("empty"), "not all posted yet");
        c.handle(proto::INSEC_POST, &post_body(3, 1, &[3.0, 30.0]));
        let r = c.handle(proto::INSEC_GET_AVERAGE, &Value::obj());
        assert_eq!(r.f64_arr_of("average").unwrap(), vec![2.0, 20.0]);
    }

    #[test]
    fn duplicate_posts_overwrite_not_double_count() {
        let c = ctrl(&[(1, 2)]);
        c.handle(proto::INSEC_POST, &post_body(1, 1, &[0.0]));
        c.handle(proto::INSEC_POST, &post_body(1, 1, &[4.0])); // resend
        c.handle(proto::INSEC_POST, &post_body(2, 1, &[2.0]));
        let r = c.handle(proto::INSEC_GET_AVERAGE, &Value::obj());
        assert_eq!(r.f64_arr_of("average").unwrap(), vec![3.0]);
    }

    #[test]
    fn multi_group_global_average() {
        let c = ctrl(&[(1, 2), (2, 2)]);
        c.handle(proto::INSEC_POST, &post_body(1, 1, &[1.0]));
        c.handle(proto::INSEC_POST, &post_body(2, 1, &[3.0]));
        c.handle(proto::INSEC_POST, &post_body(3, 2, &[5.0]));
        c.handle(proto::INSEC_POST, &post_body(4, 2, &[7.0]));
        let r = c.handle(proto::INSEC_GET_AVERAGE, &Value::obj());
        // group means 2 and 6 → global 4
        assert_eq!(r.f64_arr_of("average").unwrap(), vec![4.0]);
        assert_eq!(r.u64_of("groups"), Some(2));
    }
}
