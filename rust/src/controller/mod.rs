//! The SAFE controller: a message broker + progress tracker.
//!
//! Paper §5.1.3: the controller (a) stores messages sent to target nodes
//! until retrieved, (b) monitors progress and requests reposts around
//! failed nodes, (c) distributes the computed average (averaging across
//! subgroups when used), and (d) picks a new initiator when the current
//! one fails. Crucially it never participates in the aggregation math and
//! never sees a plaintext aggregate — reducing it to "a mere message
//! broker".
//!
//! The controller also hosts the two baselines used throughout the paper's
//! evaluation (§6): INSEC (cleartext post/average — [`insec`]) and the BON
//! protocol of Bonawitz et al. ([`bon`]), where the server *does* have to
//! do cryptographic work, which is exactly the overhead the paper measures.

pub mod bon;
pub mod hierarchy;
pub mod insec;
pub mod state;

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicI64, Ordering as AtomicOrdering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::blob::Blob;
use crate::json::Value;
use crate::proto;
use crate::topology::Reassignment;
use crate::transport::{Handler, NonBlockingHandler, PollKey, TryHandle, WaitHub};
use state::{CheckStatus, GroupState, PostedAggregate};

/// Controller timing knobs (paper Appendix A: `poll_time`, `yield_time`,
/// `aggregation_timeout`; §5.3's monitor adds `progress_timeout`).
#[derive(Debug, Clone)]
pub struct ControllerConfig {
    /// Max time a single long-poll call blocks before returning "empty".
    pub poll_time: Duration,
    /// Whole-aggregation timeout triggering initiator failover (§5.4).
    pub aggregation_timeout: Duration,
    /// Per-link silence threshold before the monitor declares a node
    /// failed (§5.3).
    pub progress_timeout: Duration,
    /// BON round-2 close timeout (dropout detection for the baseline).
    pub bon_round2_timeout: Duration,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            poll_time: Duration::from_millis(500),
            aggregation_timeout: Duration::from_secs(30),
            progress_timeout: Duration::from_secs(2),
            bon_round2_timeout: Duration::from_secs(2),
        }
    }
}

pub(crate) struct Inner {
    pub groups: BTreeMap<u64, GroupState>,
    pub expected_groups: BTreeSet<u64>,
    /// Session round-epoch (multi-round engine): bumped by `begin_round`,
    /// which resets per-round chain state while keys/stats/HTTP survive.
    /// Posts carrying an older epoch are rejected as `stale_epoch`.
    pub epoch: u64,
    /// Privacy-floor merging enabled for the current session (set by
    /// `begin_round`): a mid-round floor violation answers `merge_groups`
    /// instead of `abort_privacy_floor` while another group exists.
    pub merge_floor: bool,
    /// The current round's topology merge deltas, as announced by
    /// `begin_round` (surfaced via `/status`).
    pub reassigned: Vec<Reassignment>,
    /// Node → serialized RSA public key (round 0 registry).
    pub keys: BTreeMap<u64, Value>,
    /// (owner, for_node) → RSA-sealed symmetric key blob (§5.8). Stored
    /// encoded, handed back as the same allocation.
    pub preneg: BTreeMap<(u64, u64), Blob>,
    pub insec: insec::InsecState,
    pub bon: bon::BonState,
    pub fed: hierarchy::FedState,
    /// This controller is a *shard* of a sharded plane (set per round by
    /// `begin_round`): the global average is installed by the fan-in
    /// worker, not derived from the local §5.5 barrier, so `get_average`
    /// must wait for the installed value.
    pub fanin: bool,
    /// The fan-in result installed by [`Controller::install_global_average`]
    /// (`(average, weight)`), released to `get_average` pollers when
    /// `fanin` is set.
    pub global_average: Option<(Vec<f64>, u64)>,
    pub config: ControllerConfig,
}

/// The controller service. Thread-safe; all ops go through [`Handler`].
pub struct Controller {
    pub(crate) inner: Mutex<Inner>,
    pub(crate) cv: Condvar,
    /// Completion-side mirror of `cv`: parked event-runtime long-polls,
    /// woken at the same mutation points that notify the condvar.
    hub: Arc<WaitHub>,
    /// Currently-blocked long-poll calls (connection pressure, §5.9).
    waiting: AtomicI64,
    /// High-water mark of `waiting` since the last reset.
    peak_waiting: AtomicI64,
    /// The session registry this controller's `GET /metrics` renders,
    /// installed by [`Controller::install_metrics`]. `None` (stand-alone
    /// controllers, unit tests) answers an empty exposition.
    metrics: Mutex<Option<Arc<crate::metrics::MetricRegistry>>>,
}

impl Controller {
    pub fn new(config: ControllerConfig) -> Self {
        Controller {
            inner: Mutex::new(Inner {
                groups: BTreeMap::new(),
                expected_groups: BTreeSet::new(),
                epoch: 0,
                merge_floor: false,
                reassigned: Vec::new(),
                keys: BTreeMap::new(),
                preneg: BTreeMap::new(),
                insec: insec::InsecState::default(),
                bon: bon::BonState::default(),
                fed: hierarchy::FedState::default(),
                fanin: false,
                global_average: None,
                config,
            }),
            cv: Condvar::new(),
            hub: Arc::new(WaitHub::default()),
            waiting: AtomicI64::new(0),
            peak_waiting: AtomicI64::new(0),
            metrics: Mutex::new(None),
        }
    }

    /// Wire this controller's scrape endpoint to `registry` and publish
    /// its identity/pressure gauges under the `shard` label:
    /// `safe_controller_info{shard}` is the constant-1 presence series,
    /// and a scrape-time collector mirrors the §5.9 `waiting` /
    /// `peak_waiting` atomics into the poll-pressure gauges. The
    /// collector reads atomics only — never the `Inner` lock — so a
    /// scrape can never contend with (or deadlock against) protocol
    /// handlers.
    pub fn install_metrics(
        self: &Arc<Self>,
        registry: Arc<crate::metrics::MetricRegistry>,
        shard: &str,
    ) {
        use crate::metrics::names;
        registry
            .gauge(
                names::CONTROLLER_INFO,
                "Constant 1 per controller, carrying the shard label.",
                &[("shard", shard)],
            )
            .set(1);
        let waiting = registry.gauge(
            names::CONTROLLER_WAITING_POLLS,
            "Learner long-polls blocked right now (section 5.9 pressure).",
            &[("shard", shard)],
        );
        let peak = registry.gauge(
            names::CONTROLLER_PEAK_WAITING_POLLS,
            "High-water mark of concurrently blocked long-polls.",
            &[("shard", shard)],
        );
        let me = Arc::downgrade(self);
        registry.register_collector(move || {
            if let Some(c) = me.upgrade() {
                waiting.set(c.waiting.load(AtomicOrdering::SeqCst));
                peak.set(c.peak_waiting.load(AtomicOrdering::SeqCst));
            }
        });
        *self.metrics.lock().unwrap() = Some(registry);
    }

    /// Render the installed registry's Prometheus text (empty without
    /// [`Controller::install_metrics`]).
    pub fn render_metrics(&self) -> String {
        let registry = self.metrics.lock().unwrap().clone();
        registry.map(|r| r.render()).unwrap_or_default()
    }

    /// The wait registry the event runtime parks long-polls in.
    pub fn wait_hub(&self) -> Arc<WaitHub> {
        self.hub.clone()
    }

    /// Peak number of simultaneously-parked long-polls (the §5.9
    /// connection-pressure metric; staggered polling lowers it).
    pub fn peak_concurrent_polls(&self) -> i64 {
        self.peak_waiting.load(AtomicOrdering::SeqCst)
    }

    pub fn reset_poll_gauge(&self) {
        self.peak_waiting.store(0, AtomicOrdering::SeqCst);
    }

    /// Long-poll helper: evaluate `f` under the lock until it yields
    /// `Some`, waking on every state change, up to `timeout`.
    pub(crate) fn wait_until<T>(
        &self,
        timeout: Duration,
        f: impl FnMut(&mut Inner) -> Option<T>,
    ) -> Option<T> {
        self.wait_until_inner(timeout, f, false)
    }

    /// Like `wait_until` but counted in the §5.9 connection-pressure gauge
    /// (used by the aggregate-phase polls, which staggering targets).
    pub(crate) fn wait_until_gauged<T>(
        &self,
        timeout: Duration,
        f: impl FnMut(&mut Inner) -> Option<T>,
    ) -> Option<T> {
        self.wait_until_inner(timeout, f, true)
    }

    fn wait_until_inner<T>(
        &self,
        timeout: Duration,
        mut f: impl FnMut(&mut Inner) -> Option<T>,
        gauged: bool,
    ) -> Option<T> {
        let deadline = Instant::now() + timeout;
        let mut guard = self.inner.lock().unwrap();
        let mut counted = false;
        let result = loop {
            if let Some(v) = f(&mut guard) {
                break Some(v);
            }
            if gauged && !counted {
                counted = true;
                let now_waiting = self.waiting.fetch_add(1, AtomicOrdering::SeqCst) + 1;
                self.peak_waiting.fetch_max(now_waiting, AtomicOrdering::SeqCst);
            }
            let now = Instant::now();
            if now >= deadline {
                break None;
            }
            let (g, _timeout) = self.cv.wait_timeout(guard, deadline - now).unwrap();
            guard = g;
        };
        if counted {
            self.waiting.fetch_sub(1, AtomicOrdering::SeqCst);
        }
        result
    }

    // ---- long-poll predicates ----
    //
    // Each `poll_*` evaluates one long-poll predicate against `Inner`
    // exactly once. The blocking [`Handler`] path re-runs them under
    // `wait_until`; the event runtime's [`NonBlockingHandler`] path runs
    // them once per probe — both therefore answer identically from the
    // same state.

    fn poll_aggregate(
        inner: &mut Inner,
        op: &proto::NodeOp,
    ) -> Option<(PostedAggregate, u64, u64)> {
        let gs = inner.groups.get_mut(&op.group)?;
        let posted = gs.mailbox.remove(&op.node)?;
        Some((posted, gs.posters.len() as u64, gs.round_id))
    }

    fn aggregate_response((posted, contributors, round_id): (PostedAggregate, u64, u64)) -> Value {
        proto::AggregateDelivery {
            aggregate: posted.aggregate,
            from_node: posted.from_node,
            posted: Some(contributors),
            round_id: Some(round_id),
        }
        .into_value()
    }

    fn poll_check(inner: &mut Inner, op: &proto::NodeOp) -> Option<CheckStatus> {
        let gs = inner.groups.get_mut(&op.group)?;
        gs.check.remove(&op.node)
    }

    fn check_response(status: CheckStatus) -> Value {
        match status {
            CheckStatus::Consumed => proto::CheckOutcome::Consumed.to_value(),
            CheckStatus::Repost { new_target } => {
                proto::CheckOutcome::Repost { to_node: new_target }.to_value()
            }
        }
    }

    fn poll_average(inner: &Inner) -> Option<(Vec<f64>, u64)> {
        // Sharded plane: this controller only brokers a shard — the
        // global average is whatever the fan-in worker installed, and the
        // local §5.5 barrier alone must not release pollers.
        if inner.fanin {
            return inner.global_average.clone();
        }
        // Global average is ready when every expected group posted its
        // group average (§5.5 barrier). Equal-weight mean of means.
        if inner.expected_groups.is_empty() {
            return None;
        }
        let mut acc: Option<Vec<f64>> = None;
        let mut count = 0usize;
        for gid in &inner.expected_groups {
            let gs = inner.groups.get(gid)?;
            let avg = gs.average.as_ref()?;
            match &mut acc {
                None => acc = Some(avg.clone()),
                Some(a) => {
                    if a.len() != avg.len() {
                        return None; // inconsistent; keep waiting
                    }
                    for (x, y) in a.iter_mut().zip(avg) {
                        *x += y;
                    }
                }
            }
            count += 1;
        }
        let mut avg = acc?;
        for x in avg.iter_mut() {
            *x /= count as f64;
        }
        Some((avg, count as u64))
    }

    /// Cheap form of the §5.5 barrier check (no mean computed): used to
    /// decide whether a `post_average` should wake [`PollKey::Average`]
    /// waiters — waking per-post would stampede every parked learner
    /// through an O(groups) probe at each group completion.
    fn average_barrier_complete(inner: &Inner) -> bool {
        !inner.expected_groups.is_empty()
            && inner.expected_groups.iter().all(|gid| {
                inner.groups.get(gid).map_or(false, |gs| gs.average.is_some())
            })
    }

    /// The shard partial over whichever expected groups have posted so
    /// far: the §5.5 equal-weight mean of their group means, plus the
    /// summed contributor count the fan-in parent weights the shard by.
    /// `None` until at least one group posted. When the barrier is
    /// complete this equals [`Controller::poll_average`]'s mean.
    fn partial_over_posted(inner: &Inner) -> Option<(Vec<f64>, u64)> {
        let mut acc: Option<Vec<f64>> = None;
        let mut count = 0usize;
        let mut contributors = 0u64;
        for gid in &inner.expected_groups {
            let Some(gs) = inner.groups.get(gid) else { continue };
            let Some(avg) = gs.average.as_ref() else { continue };
            match &mut acc {
                None => acc = Some(avg.clone()),
                Some(a) => {
                    if a.len() != avg.len() {
                        continue;
                    }
                    for (x, y) in a.iter_mut().zip(avg) {
                        *x += y;
                    }
                }
            }
            count += 1;
            contributors += gs.average_contributors;
        }
        let mut avg = acc?;
        for x in avg.iter_mut() {
            *x /= count as f64;
        }
        Some((avg, contributors))
    }

    /// Fan-in worker entry (sharded plane): wait up to `timeout` for this
    /// shard's §5.5 barrier, then return the shard partial to post to the
    /// fan-in parent. On barrier timeout the partial covers only the
    /// groups that did post (a degraded round); `None` means no group
    /// posted at all — a dead shard contributes nothing.
    pub fn shard_partial(&self, timeout: Duration) -> Option<(Vec<f64>, u64)> {
        let _ = self.wait_until(timeout, |inner| {
            Self::average_barrier_complete(inner).then_some(())
        });
        let inner = self.inner.lock().unwrap();
        Self::partial_over_posted(&inner)
    }

    /// Install the fan-in tier's combined result on this shard and release
    /// its parked `get_average` pollers (the sharded-plane counterpart of
    /// the §5.5 barrier completing). `weight` rides in the response's
    /// `groups` field.
    pub fn install_global_average(&self, average: Vec<f64>, weight: u64) {
        let mut inner = self.inner.lock().unwrap();
        inner.global_average = Some((average, weight));
        drop(inner);
        self.cv.notify_all();
        self.hub.wake(PollKey::Average);
    }

    fn poll_key(inner: &Inner, node: u64) -> Option<Value> {
        inner.keys.get(&node).cloned()
    }

    fn poll_preneg(inner: &Inner, owner: u64, node: u64) -> Option<Blob> {
        inner.preneg.get(&(owner, node)).cloned()
    }

    fn configure(&self, body: &Value) -> Value {
        let mut inner = self.inner.lock().unwrap();
        if let Some(Value::Obj(groups)) = body.get("groups") {
            // A (re)configure is a session build: restart the round-epoch
            // clock so a fresh session against a long-lived controller
            // isn't rejected as stale by a previous session's epochs.
            inner.epoch = 0;
            inner.merge_floor = false;
            inner.reassigned.clear();
            inner.groups.clear();
            inner.expected_groups.clear();
            for (gid_str, chain_v) in groups {
                let gid: u64 = match gid_str.parse() {
                    Ok(g) => g,
                    Err(_) => return proto::status("bad group id"),
                };
                let chain: Vec<u64> = match chain_v.as_arr() {
                    Some(arr) => arr.iter().filter_map(|v| v.as_u64()).collect(),
                    None => return proto::status("bad chain"),
                };
                let mut gs = GroupState::new(chain.clone());
                gs.initiator = chain.first().copied();
                inner.groups.insert(gid, gs);
                inner.expected_groups.insert(gid);
                inner.insec.configure_group(gid, chain.len());
            }
        }
        if let Some(ms) = body.u64_of("aggregation_timeout_ms") {
            inner.config.aggregation_timeout = Duration::from_millis(ms);
        }
        if let Some(ms) = body.u64_of("progress_timeout_ms") {
            inner.config.progress_timeout = Duration::from_millis(ms);
        }
        if let Some(ms) = body.u64_of("poll_time_ms") {
            inner.config.poll_time = Duration::from_millis(ms);
        }
        if let Some(nodes) = body.get("bon_nodes").and_then(|v| v.as_arr()) {
            let ids: BTreeSet<u64> = nodes.iter().filter_map(|v| v.as_u64()).collect();
            inner.bon.configure(ids);
        }
        if let Some(ms) = body.u64_of("bon_round2_timeout_ms") {
            inner.config.bon_round2_timeout = Duration::from_millis(ms);
        }
        if let Some(n) = body.u64_of("fed_expected_children") {
            inner.fed.expected_children = n as usize;
            inner.fed.child_averages.clear();
        }
        self.cv.notify_all();
        self.hub.wake_all();
        proto::status("ok")
    }

    /// Open a new session round-epoch (multi-round engine): install the
    /// round's chains with fresh per-round state, keep everything a round
    /// should not tear down — key registry, §5.8 pre-negotiated keys, the
    /// HTTP listener and `MessageStats` (which live outside this struct),
    /// and the baseline states' configured membership.
    fn begin_round(&self, body: &Value) -> Value {
        let req = match proto::BeginRound::from_value(body) {
            Ok(r) => r,
            Err(e) => return proto::status(&e.to_string()),
        };
        let mut inner = self.inner.lock().unwrap();
        if req.epoch < inner.epoch {
            return proto::status("stale_epoch");
        }
        inner.epoch = req.epoch;
        inner.merge_floor = req.merge_floor;
        inner.reassigned = req.reassigned;
        inner.groups.clear();
        inner.expected_groups.clear();
        // Sharded plane: a round boundary resets the fan-in state — the
        // shard's installed global, and (on the parent) the previous
        // round's child partials plus the expected-children barrier.
        inner.fanin = req.fanin;
        inner.global_average = None;
        inner.fed.child_averages.clear();
        if let Some(children) = req.fed_children {
            inner.fed.expected_children = children as usize;
        }
        for (gid, chain) in req.groups {
            let mut gs = GroupState::new(chain.clone());
            gs.initiator = chain.first().copied();
            inner.expected_groups.insert(gid);
            inner.insec.configure_group(gid, chain.len());
            inner.groups.insert(gid, gs);
        }
        self.cv.notify_all();
        self.hub.wake_all();
        proto::status("ok")
    }

    fn reset(&self) -> Value {
        let mut inner = self.inner.lock().unwrap();
        inner.epoch = 0;
        inner.merge_floor = false;
        inner.reassigned.clear();
        inner.groups.clear();
        inner.expected_groups.clear();
        inner.keys.clear();
        inner.preneg.clear();
        inner.insec = insec::InsecState::default();
        inner.bon = bon::BonState::default();
        inner.fed = hierarchy::FedState::default();
        inner.fanin = false;
        inner.global_average = None;
        self.cv.notify_all();
        self.hub.wake_all();
        proto::status("ok")
    }

    // ---- SAFE core ops ----

    fn post_aggregate(&self, body: &Value) -> Value {
        let req = match proto::PostAggregate::from_value(body) {
            Ok(r) => r,
            Err(e) => return proto::status(&e.to_string()),
        };
        let mut inner = self.inner.lock().unwrap();
        // Reject posts from a previous session round-epoch (a straggler
        // thread must never pollute the next round's mailboxes).
        if let Some(e) = req.epoch {
            if e != inner.epoch {
                return proto::status("stale_epoch");
            }
        }
        let gs = match inner.groups.get_mut(&req.group) {
            Some(g) => g,
            None => return proto::status("unknown group"),
        };
        // Reject posts from nodes already declared failed (late/stale posts
        // after a repost was issued would double-count their contribution).
        if gs.failed.contains(&req.from_node) {
            return proto::status("stale");
        }
        // Reject posts from a previous round (pre-initiator-failover).
        if let Some(r) = req.round_id {
            if r != gs.round_id {
                return proto::status("stale_round");
            }
        }
        // Attempt dedup: a client whose post was applied but whose ack
        // was lost resends the same token; answer `duplicate` with no
        // state change instead of double-counting the contribution.
        if let Some(t) = req.token {
            if !gs.seen_tokens.insert(t) {
                return proto::status("duplicate");
            }
        }
        let now = Instant::now();
        gs.mailbox.insert(
            req.to_node,
            PostedAggregate { aggregate: req.aggregate, from_node: req.from_node, posted_at: now },
        );
        gs.posters.insert(req.from_node);
        // `from` has done its part: whoever is checking on `from` learns
        // the chain advanced through it.
        gs.check.insert(req.from_node, CheckStatus::Consumed);
        gs.last_activity = now;
        self.cv.notify_all();
        self.hub.wake(PollKey::Aggregate { group: req.group, node: req.to_node });
        self.hub.wake(PollKey::Check { group: req.group, node: req.from_node });
        proto::status("ok")
    }

    fn get_aggregate(&self, body: &Value) -> Value {
        let op = match proto::NodeOp::from_value(body) {
            Ok(o) => o,
            Err(e) => return proto::status(&e.to_string()),
        };
        let poll = self.inner.lock().unwrap().config.poll_time;
        let res = self.wait_until_gauged(poll, |inner| Self::poll_aggregate(inner, &op));
        match res {
            Some(hit) => Self::aggregate_response(hit),
            None => proto::status("empty"),
        }
    }

    fn check_aggregate(&self, body: &Value) -> Value {
        let op = match proto::NodeOp::from_value(body) {
            Ok(o) => o,
            Err(e) => return proto::status(&e.to_string()),
        };
        let poll = self.inner.lock().unwrap().config.poll_time;
        let res = self.wait_until(poll, |inner| Self::poll_check(inner, &op));
        match res {
            Some(status) => Self::check_response(status),
            None => proto::status("empty"),
        }
    }

    fn post_average(&self, body: &Value) -> Value {
        let req = match proto::PostAverage::from_value(body) {
            Ok(r) => r,
            Err(e) => return proto::status(&e.to_string()),
        };
        let mut inner = self.inner.lock().unwrap();
        let gs = match inner.groups.get_mut(&req.group) {
            Some(g) => g,
            None => return proto::status("unknown group"),
        };
        gs.average = Some(req.average);
        gs.average_contributors = req.contributors;
        gs.last_activity = Instant::now();
        self.cv.notify_all();
        // On a shard, the barrier completing readies the *fan-in worker*
        // (`shard_partial`), not the learners' `get_average` pollers —
        // those wait for the installed global.
        if !inner.fanin && Self::average_barrier_complete(&inner) {
            self.hub.wake(PollKey::Average);
        }
        proto::status("ok")
    }

    fn get_average(&self, body: &Value) -> Value {
        let poll = self.inner.lock().unwrap().config.poll_time;
        let _ = body;
        let res = self.wait_until(poll, |inner| Self::poll_average(inner));
        match res {
            Some((avg, groups)) => proto::AverageReady { average: avg, groups }.into_value(),
            None => proto::status("empty"),
        }
    }

    fn should_initiate(&self, body: &Value) -> Value {
        let op = match proto::NodeOp::from_value(body) {
            Ok(o) => o,
            Err(e) => return proto::status(&e.to_string()),
        };
        let mut inner = self.inner.lock().unwrap();
        let timeout = inner.config.aggregation_timeout;
        let gs = match inner.groups.get_mut(&op.group) {
            Some(g) => g,
            None => return proto::status("unknown group"),
        };
        if gs.failed.contains(&op.node) {
            return proto::InitiateDecision { init: false, round_id: gs.round_id }.to_value();
        }
        let elected = if gs.initiator.is_none() {
            gs.initiator = Some(op.node);
            gs.round_start = Instant::now();
            true
        } else if gs.average.is_none() && gs.round_start.elapsed() > timeout {
            // Initiator failover (§5.4): first caller after the timeout
            // wins and the whole round restarts.
            gs.restart_round(op.node);
            true
        } else {
            false
        };
        if elected {
            self.cv.notify_all();
        }
        proto::InitiateDecision { init: elected, round_id: gs.round_id }.to_value()
    }

    /// Monitor entry point (§5.3): detect stuck links and issue reposts.
    /// Returns the actions taken so the monitor can log them.
    fn progress_check(&self) -> Value {
        let mut inner = self.inner.lock().unwrap();
        let progress_timeout = inner.config.progress_timeout;
        // Other groups' live populations, for picking a merge target when
        // a group trips the privacy floor mid-round (computed up front so
        // the per-group loop can borrow groups mutably).
        let live_sizes: Vec<(u64, usize)> = inner
            .groups
            .iter()
            .map(|(gid, gs)| (*gid, gs.live_count()))
            .collect();
        let merge_floor = inner.merge_floor;
        let mut actions = Vec::new();
        let mut wakes = Vec::new();
        for (gid, gs) in inner.groups.iter_mut() {
            if gs.average.is_some() {
                continue;
            }
            if gs.last_activity.elapsed() < progress_timeout {
                continue;
            }
            let Some((checker, failed)) = gs.stuck_link() else { continue };
            if Some(failed) == gs.initiator {
                // Initiator failure is handled by the aggregation timeout
                // (§5.4), not by chain re-routing.
                continue;
            }
            if gs.live_count() <= 3 {
                // Dropping below 3 live nodes would let neighbours infer
                // each other's values (§5.3: need n − f ≥ 3). With
                // privacy-floor merging enabled, answer `merge_groups`
                // naming the smallest group that can actually absorb the
                // survivors and restore the floor (the engine's planner
                // performs the merge at the next re-plan).
                // `abort_privacy_floor` remains the fallback when no such
                // group exists — merging with a dead or equally-starved
                // group cannot restore the floor.
                let survivors = gs.live_count().saturating_sub(1);
                let target = if merge_floor {
                    live_sizes
                        .iter()
                        .filter(|(g, live)| g != gid && *live > 0 && live + survivors >= 3)
                        .min_by_key(|(g, live)| (*live, *g))
                        .map(|(g, _)| *g)
                } else {
                    None
                };
                match target {
                    Some(into) => actions.push(Value::object(vec![
                        ("group", Value::from(*gid)),
                        ("action", Value::from("merge_groups")),
                        ("failed", Value::from(failed)),
                        ("into", Value::from(into)),
                    ])),
                    None => actions.push(Value::object(vec![
                        ("group", Value::from(*gid)),
                        ("action", Value::from("abort_privacy_floor")),
                        ("failed", Value::from(failed)),
                    ])),
                }
                continue;
            }
            gs.failed.insert(failed);
            gs.mailbox.remove(&failed);
            gs.check.remove(&failed);
            if let Some(new_target) = gs.next_alive_after(failed) {
                gs.check.insert(failed, CheckStatus::Repost { new_target });
                gs.last_activity = Instant::now();
                wakes.push(PollKey::Check { group: *gid, node: failed });
                actions.push(Value::object(vec![
                    ("group", Value::from(*gid)),
                    ("action", Value::from("repost")),
                    ("checker", Value::from(checker)),
                    ("failed", Value::from(failed)),
                    ("new_target", Value::from(new_target)),
                ]));
            }
        }
        if !actions.is_empty() {
            self.cv.notify_all();
        }
        for key in wakes {
            self.hub.wake(key);
        }
        Value::object(vec![("actions", Value::Arr(actions))])
    }

    // ---- key registry (round 0) ----

    fn register_key(&self, body: &Value) -> Value {
        let req = match proto::RegisterKey::from_value(body) {
            Ok(r) => r,
            Err(e) => return proto::status(&e.to_string()),
        };
        let mut inner = self.inner.lock().unwrap();
        inner.keys.insert(req.node, req.key);
        self.cv.notify_all();
        self.hub.wake(PollKey::Key { node: req.node });
        proto::status("ok")
    }

    fn get_key(&self, body: &Value) -> Value {
        let req = match proto::GetKey::from_value(body) {
            Ok(r) => r,
            Err(e) => return proto::status(&e.to_string()),
        };
        let poll = self.inner.lock().unwrap().config.poll_time;
        match self.wait_until(poll, |inner| Self::poll_key(inner, req.node)) {
            Some(k) => proto::KeyDelivery { key: k }.to_value(),
            None => proto::status("empty"),
        }
    }

    fn post_preneg_keys(&self, body: &Value) -> Value {
        let req = match proto::PostPrenegKeys::from_value(body) {
            Ok(r) => r,
            Err(e) => return proto::status(&e.to_string()),
        };
        let mut inner = self.inner.lock().unwrap();
        let mut wakes = Vec::new();
        for (to, blob) in req.keys {
            inner.preneg.insert((req.node, to), blob);
            wakes.push(PollKey::Preneg { owner: req.node, node: to });
        }
        self.cv.notify_all();
        for key in wakes {
            self.hub.wake(key);
        }
        proto::status("ok")
    }

    fn get_preneg_key(&self, body: &Value) -> Value {
        let req = match proto::GetPrenegKey::from_value(body) {
            Ok(r) => r,
            Err(e) => return proto::status(&e.to_string()),
        };
        let poll = self.inner.lock().unwrap().config.poll_time;
        match self.wait_until(poll, |inner| Self::poll_preneg(inner, req.owner, req.node)) {
            Some(k) => proto::PrenegKeyDelivery { key: k }.to_value(),
            None => proto::status("empty"),
        }
    }

    fn status(&self) -> Value {
        let inner = self.inner.lock().unwrap();
        let groups: Vec<Value> = inner
            .groups
            .iter()
            .map(|(gid, gs)| {
                Value::object(vec![
                    ("group", Value::from(*gid)),
                    ("chain_len", Value::from(gs.chain.len())),
                    ("posters", Value::from(gs.posters.len())),
                    ("failed", Value::from(gs.failed.len())),
                    ("round_id", Value::from(gs.round_id)),
                    ("average_ready", Value::from(gs.average.is_some())),
                    (
                        "initiator",
                        gs.initiator.map(Value::from).unwrap_or(Value::Null),
                    ),
                ])
            })
            .collect();
        Value::object(vec![
            ("groups", Value::Arr(groups)),
            ("keys_registered", Value::from(inner.keys.len())),
            ("epoch", Value::from(inner.epoch)),
            ("merge_floor", Value::from(inner.merge_floor)),
            ("reassigned_this_round", Value::from(inner.reassigned.len())),
        ])
    }
}

impl Handler for Controller {
    fn handle(&self, path: &str, body: &Value) -> Value {
        match path {
            proto::CONFIGURE => self.configure(body),
            proto::BEGIN_ROUND => self.begin_round(body),
            proto::RESET => self.reset(),
            proto::POST_AGGREGATE => self.post_aggregate(body),
            proto::GET_AGGREGATE => self.get_aggregate(body),
            proto::CHECK_AGGREGATE => self.check_aggregate(body),
            proto::POST_AVERAGE => self.post_average(body),
            proto::GET_AVERAGE => self.get_average(body),
            proto::SHOULD_INITIATE => self.should_initiate(body),
            proto::PROGRESS_CHECK => self.progress_check(),
            proto::REGISTER_KEY => self.register_key(body),
            proto::GET_KEY => self.get_key(body),
            proto::POST_PRENEG_KEYS => self.post_preneg_keys(body),
            proto::GET_PRENEG_KEY => self.get_preneg_key(body),
            proto::STATUS => self.status(),
            proto::METRICS => {
                let mut v = proto::status("ok");
                v.set("text", Value::from(self.render_metrics()));
                v
            }
            proto::INSEC_POST => insec::post(self, body),
            proto::INSEC_GET_AVERAGE => insec::get_average(self, body),
            proto::BON_ADVERTISE => bon::advertise(self, body),
            proto::BON_GET_KEYS => bon::get_keys(self, body),
            proto::BON_POST_SHARES => bon::post_shares(self, body),
            proto::BON_GET_SHARES => bon::get_shares(self, body),
            proto::BON_POST_MASKED => bon::post_masked(self, body),
            proto::BON_GET_SURVIVORS => bon::get_survivors(self, body),
            proto::BON_POST_UNMASK => bon::post_unmask(self, body),
            proto::BON_GET_AVERAGE => bon::get_average(self, body),
            proto::FED_POST_CHILD_AVERAGE => hierarchy::post_child_average(self, body),
            proto::FED_GET_GLOBAL_AVERAGE => hierarchy::get_global_average(self, body),
            _ => proto::status("unknown op"),
        }
    }
}

/// Completion-style view for the event runtime: the SAFE long-poll ops
/// (plus the fan-in tier's global-average fetch) probe their predicate
/// exactly once and report the [`PollKey`] to wait on instead of parking
/// the calling thread. Every other op answers immediately through the
/// blocking [`Handler`] (posts and elections never park; the baseline
/// ops are only driven by thread-based sessions).
impl NonBlockingHandler for Controller {
    fn try_handle(&self, path: &str, body: &Value) -> TryHandle {
        match path {
            proto::GET_AGGREGATE => {
                let op = match proto::NodeOp::from_value(body) {
                    Ok(o) => o,
                    Err(e) => return TryHandle::Ready(proto::status(&e.to_string())),
                };
                let mut inner = self.inner.lock().unwrap();
                match Self::poll_aggregate(&mut inner, &op) {
                    Some(hit) => TryHandle::Ready(Self::aggregate_response(hit)),
                    None => TryHandle::WouldBlock(PollKey::Aggregate {
                        group: op.group,
                        node: op.node,
                    }),
                }
            }
            proto::CHECK_AGGREGATE => {
                let op = match proto::NodeOp::from_value(body) {
                    Ok(o) => o,
                    Err(e) => return TryHandle::Ready(proto::status(&e.to_string())),
                };
                let mut inner = self.inner.lock().unwrap();
                match Self::poll_check(&mut inner, &op) {
                    Some(status) => TryHandle::Ready(Self::check_response(status)),
                    None => TryHandle::WouldBlock(PollKey::Check {
                        group: op.group,
                        node: op.node,
                    }),
                }
            }
            proto::GET_AVERAGE => {
                let inner = self.inner.lock().unwrap();
                match Self::poll_average(&inner) {
                    Some((avg, groups)) => TryHandle::Ready(
                        proto::AverageReady { average: avg, groups }.into_value(),
                    ),
                    None => TryHandle::WouldBlock(PollKey::Average),
                }
            }
            proto::GET_KEY => {
                let req = match proto::GetKey::from_value(body) {
                    Ok(r) => r,
                    Err(e) => return TryHandle::Ready(proto::status(&e.to_string())),
                };
                let inner = self.inner.lock().unwrap();
                match Self::poll_key(&inner, req.node) {
                    Some(k) => TryHandle::Ready(proto::KeyDelivery { key: k }.to_value()),
                    None => TryHandle::WouldBlock(PollKey::Key { node: req.node }),
                }
            }
            proto::GET_PRENEG_KEY => {
                let req = match proto::GetPrenegKey::from_value(body) {
                    Ok(r) => r,
                    Err(e) => return TryHandle::Ready(proto::status(&e.to_string())),
                };
                let inner = self.inner.lock().unwrap();
                match Self::poll_preneg(&inner, req.owner, req.node) {
                    Some(k) => TryHandle::Ready(proto::PrenegKeyDelivery { key: k }.to_value()),
                    None => TryHandle::WouldBlock(PollKey::Preneg {
                        owner: req.owner,
                        node: req.node,
                    }),
                }
            }
            proto::FED_GET_GLOBAL_AVERAGE => {
                let inner = self.inner.lock().unwrap();
                match inner.fed.global() {
                    Some((avg, total)) => TryHandle::Ready(
                        proto::FedGlobalAverage { average: avg, contributors: total }
                            .into_value(),
                    ),
                    None => TryHandle::WouldBlock(PollKey::FedGlobal),
                }
            }
            _ => TryHandle::Ready(self.handle(path, body)),
        }
    }

    /// §5.9 connection-pressure gauge, event-runtime edition: a parked
    /// aggregate-phase submission counts exactly like a thread blocked in
    /// `wait_until_gauged` — so `peak_concurrent_polls` remains comparable
    /// across `--runtime threads|events`.
    fn poll_parked(&self, path: &str) {
        if path == proto::GET_AGGREGATE {
            let now_waiting = self.waiting.fetch_add(1, AtomicOrdering::SeqCst) + 1;
            self.peak_waiting.fetch_max(now_waiting, AtomicOrdering::SeqCst);
        }
    }

    fn poll_unparked(&self, path: &str) {
        if path == proto::GET_AGGREGATE {
            self.waiting.fetch_sub(1, AtomicOrdering::SeqCst);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn controller() -> Arc<Controller> {
        let cfg = ControllerConfig {
            poll_time: Duration::from_millis(200),
            aggregation_timeout: Duration::from_secs(5),
            progress_timeout: Duration::from_millis(100),
            bon_round2_timeout: Duration::from_millis(200),
        };
        let c = Arc::new(Controller::new(cfg));
        let groups = Value::object(vec![(
            "groups",
            Value::object(vec![(
                "1",
                Value::Arr(vec![1u64.into(), 2u64.into(), 3u64.into()]),
            )]),
        )]);
        c.handle(proto::CONFIGURE, &groups);
        c
    }

    #[test]
    fn post_then_get_aggregate_delivers() {
        let c = controller();
        let r = c.handle(proto::POST_AGGREGATE, &proto::post_aggregate(1, 2, b"blob", 1));
        assert_eq!(r.str_of("status"), Some("ok"));
        let r = c.handle(proto::GET_AGGREGATE, &proto::node_op(2, 1));
        assert_eq!(r.str_of("status"), Some("ok"));
        assert_eq!(r.blob_of("aggregate").unwrap().as_bytes(), b"blob");
        assert_eq!(r.u64_of("from_node"), Some(1));
        assert_eq!(r.u64_of("posted"), Some(1));
        // Second get times out empty.
        let r = c.handle(proto::GET_AGGREGATE, &proto::node_op(2, 1));
        assert_eq!(r.str_of("status"), Some("empty"));
    }

    #[test]
    fn aggregate_pass_through_shares_the_posted_allocation() {
        // The "mere message broker" guarantee, mechanically: the blob the
        // controller delivers from get_aggregate is the very allocation
        // that arrived in post_aggregate — stored and forwarded with Arc
        // clones, never decoded, copied or re-encoded.
        let c = controller();
        let blob = Blob::new(vec![0xa5u8; 4096]);
        let body = proto::PostAggregate {
            from_node: 1,
            to_node: 2,
            group: 1,
            aggregate: blob.clone(),
            round_id: None,
            epoch: None,
            token: None,
        }
        .to_value();
        c.handle(proto::POST_AGGREGATE, &body);
        let r = c.handle(proto::GET_AGGREGATE, &proto::node_op(2, 1));
        assert_eq!(r.str_of("status"), Some("ok"));
        let delivered = match r.get("aggregate") {
            Some(Value::Bytes(b)) => b.clone(),
            other => panic!("expected Bytes aggregate, got {other:?}"),
        };
        assert!(Blob::ptr_eq(&blob, &delivered), "controller must not copy the blob");
    }

    #[test]
    fn duplicate_post_token_is_absorbed_without_state_change() {
        let c = controller();
        let post = |token| {
            proto::PostAggregate {
                from_node: 1,
                to_node: 2,
                group: 1,
                aggregate: Blob::from_slice(b"sealed"),
                round_id: Some(0),
                epoch: None,
                token: Some(token),
            }
            .to_value()
        };
        let r = c.handle(proto::POST_AGGREGATE, &post(77));
        assert_eq!(r.str_of("status"), Some("ok"));
        // The recipient consumes the delivery.
        let r = c.handle(proto::GET_AGGREGATE, &proto::node_op(2, 1));
        assert_eq!(r.str_of("status"), Some("ok"));
        // A retry of the same logical post (same token) after the ack was
        // lost must NOT re-park the aggregate for node 2.
        let r = c.handle(proto::POST_AGGREGATE, &post(77));
        assert_eq!(r.str_of("status"), Some("duplicate"));
        let r = c.handle(proto::GET_AGGREGATE, &proto::node_op(2, 1));
        assert_eq!(r.str_of("status"), Some("empty"), "duplicate must not refill the mailbox");
        // A different token is a genuinely new post and is accepted.
        let r = c.handle(proto::POST_AGGREGATE, &post(78));
        assert_eq!(r.str_of("status"), Some("ok"));
        // Token-less legacy posts are never deduplicated.
        let legacy = proto::post_aggregate(1, 2, b"legacy", 1);
        assert_eq!(c.handle(proto::POST_AGGREGATE, &legacy).str_of("status"), Some("ok"));
        assert_eq!(c.handle(proto::POST_AGGREGATE, &legacy).str_of("status"), Some("ok"));
    }

    #[test]
    fn check_aggregate_sees_consumed_after_forward() {
        let c = controller();
        c.handle(proto::POST_AGGREGATE, &proto::post_aggregate(1, 2, b"a1", 1));
        // node 2 forwards — that marks node 2 as consumed for node 1's check
        c.handle(proto::POST_AGGREGATE, &proto::post_aggregate(2, 3, b"a2", 1));
        let r = c.handle(proto::CHECK_AGGREGATE, &proto::node_op(2, 1));
        assert_eq!(r.str_of("status"), Some("consumed"));
    }

    #[test]
    fn long_poll_wakes_on_post() {
        let c = controller();
        let c2 = c.clone();
        let t = std::thread::spawn(move || {
            c2.handle(proto::GET_AGGREGATE, &proto::node_op(2, 1))
        });
        std::thread::sleep(Duration::from_millis(30));
        c.handle(proto::POST_AGGREGATE, &proto::post_aggregate(1, 2, b"late", 1));
        let r = t.join().unwrap();
        assert_eq!(r.str_of("status"), Some("ok"));
        assert_eq!(r.blob_of("aggregate").unwrap().as_bytes(), b"late");
    }

    #[test]
    fn average_flow() {
        let c = controller();
        let avg = vec![1.0, 2.0];
        c.handle(proto::POST_AVERAGE, &proto::post_average(1, 1, &avg, 3));
        let r = c.handle(proto::GET_AVERAGE, &proto::node_op(2, 1));
        assert_eq!(r.str_of("status"), Some("ok"));
        assert_eq!(r.f64_arr_of("average").unwrap(), avg);
        assert_eq!(r.u64_of("groups"), Some(1));
    }

    #[test]
    fn multi_group_average_barrier() {
        let cfg = ControllerConfig {
            poll_time: Duration::from_millis(150),
            ..Default::default()
        };
        let c = Controller::new(cfg);
        c.handle(
            proto::CONFIGURE,
            &Value::object(vec![(
                "groups",
                Value::object(vec![
                    ("1", Value::Arr(vec![1u64.into(), 2u64.into(), 3u64.into()])),
                    ("2", Value::Arr(vec![4u64.into(), 5u64.into(), 6u64.into()])),
                ]),
            )]),
        );
        c.handle(proto::POST_AVERAGE, &proto::post_average(1, 1, &[2.0], 3));
        // Only one group posted: still empty.
        let r = c.handle(proto::GET_AVERAGE, &proto::node_op(1, 1));
        assert_eq!(r.str_of("status"), Some("empty"));
        c.handle(proto::POST_AVERAGE, &proto::post_average(4, 2, &[4.0], 3));
        let r = c.handle(proto::GET_AVERAGE, &proto::node_op(1, 1));
        assert_eq!(r.str_of("status"), Some("ok"));
        assert_eq!(r.f64_arr_of("average").unwrap(), vec![3.0]); // mean of 2,4
        assert_eq!(r.u64_of("groups"), Some(2));
    }

    #[test]
    fn progress_failover_issues_repost() {
        let c = controller();
        c.handle(proto::POST_AGGREGATE, &proto::post_aggregate(1, 2, b"a1", 1));
        // Node 2 goes silent; wait past progress_timeout.
        std::thread::sleep(Duration::from_millis(150));
        let r = c.handle(proto::PROGRESS_CHECK, &Value::obj());
        let actions = r.get("actions").unwrap().as_arr().unwrap();
        // chain is 3 nodes; failing one leaves 2 < 3 → privacy abort
        assert_eq!(actions.len(), 1);
        assert_eq!(actions[0].str_of("action"), Some("abort_privacy_floor"));
    }

    #[test]
    fn progress_failover_with_enough_nodes() {
        let cfg = ControllerConfig {
            poll_time: Duration::from_millis(100),
            progress_timeout: Duration::from_millis(80),
            ..Default::default()
        };
        let c = Controller::new(cfg);
        c.handle(
            proto::CONFIGURE,
            &Value::object(vec![(
                "groups",
                Value::object(vec![(
                    "1",
                    Value::Arr(vec![1u64.into(), 2u64.into(), 3u64.into(), 4u64.into(), 5u64.into()]),
                )]),
            )]),
        );
        c.handle(proto::POST_AGGREGATE, &proto::post_aggregate(1, 2, b"a1", 1));
        std::thread::sleep(Duration::from_millis(120));
        let r = c.handle(proto::PROGRESS_CHECK, &Value::obj());
        let actions = r.get("actions").unwrap().as_arr().unwrap();
        assert_eq!(actions.len(), 1);
        assert_eq!(actions[0].str_of("action"), Some("repost"));
        assert_eq!(actions[0].u64_of("failed"), Some(2));
        assert_eq!(actions[0].u64_of("new_target"), Some(3));
        assert_eq!(actions[0].u64_of("checker"), Some(1));
        // The checker (node 1) now sees the repost command.
        let r = c.handle(proto::CHECK_AGGREGATE, &proto::node_op(2, 1));
        assert_eq!(r.str_of("status"), Some("repost"));
        assert_eq!(r.u64_of("to_node"), Some(3));
        // Stale post from the failed node is rejected.
        let r = c.handle(proto::POST_AGGREGATE, &proto::post_aggregate(2, 3, b"late", 1));
        assert_eq!(r.str_of("status"), Some("stale"));
    }

    #[test]
    fn should_initiate_elects_once_after_timeout() {
        let cfg = ControllerConfig {
            poll_time: Duration::from_millis(100),
            aggregation_timeout: Duration::from_millis(80),
            ..Default::default()
        };
        let c = Controller::new(cfg);
        c.handle(
            proto::CONFIGURE,
            &Value::object(vec![(
                "groups",
                Value::object(vec![(
                    "1",
                    Value::Arr(vec![1u64.into(), 2u64.into(), 3u64.into()]),
                )]),
            )]),
        );
        // Initiator is configured as node 1; before timeout nobody else wins.
        let r = c.handle(proto::SHOULD_INITIATE, &proto::node_op(2, 1));
        assert_eq!(r.bool_of("init"), Some(false));
        std::thread::sleep(Duration::from_millis(120));
        let r2 = c.handle(proto::SHOULD_INITIATE, &proto::node_op(2, 1));
        assert_eq!(r2.bool_of("init"), Some(true));
        assert_eq!(r2.u64_of("round_id"), Some(1));
        // Immediately after, another node does NOT win.
        let r3 = c.handle(proto::SHOULD_INITIATE, &proto::node_op(3, 1));
        assert_eq!(r3.bool_of("init"), Some(false));
    }

    #[test]
    fn key_registry_roundtrip() {
        let c = controller();
        let key = Value::object(vec![("n", Value::from("abcd")), ("e", Value::from("10001"))]);
        c.handle(
            proto::REGISTER_KEY,
            &Value::object(vec![("node", Value::from(2u64)), ("key", key.clone())]),
        );
        let r = c.handle(proto::GET_KEY, &Value::object(vec![("node", Value::from(2u64))]));
        assert_eq!(r.str_of("status"), Some("ok"));
        assert_eq!(r.get("key"), Some(&key));
        // Unregistered key times out empty.
        let r = c.handle(proto::GET_KEY, &Value::object(vec![("node", Value::from(9u64))]));
        assert_eq!(r.str_of("status"), Some("empty"));
    }

    #[test]
    fn preneg_key_store() {
        let c = controller();
        // Node 2 generates keys for its predecessors.
        let sealed = Blob::from_slice(b"sealed-for-1");
        c.handle(
            proto::POST_PRENEG_KEYS,
            &Value::object(vec![
                ("node", Value::from(2u64)),
                (
                    "keys",
                    Value::object(vec![("1", Value::Bytes(sealed.clone()))]),
                ),
            ]),
        );
        let r = c.handle(
            proto::GET_PRENEG_KEY,
            &Value::object(vec![("node", Value::from(1u64)), ("owner", Value::from(2u64))]),
        );
        assert_eq!(r.str_of("status"), Some("ok"));
        let delivered = r.blob_of("key").unwrap();
        assert_eq!(delivered, sealed);
        // Zero-copy pass-through: the delivered blob is the allocation we
        // posted, not a re-encoded copy.
        assert!(Blob::ptr_eq(&sealed, &delivered));
    }

    #[test]
    fn stale_round_posts_rejected_after_restart() {
        let cfg = ControllerConfig {
            poll_time: Duration::from_millis(100),
            aggregation_timeout: Duration::from_millis(50),
            ..Default::default()
        };
        let c = Controller::new(cfg);
        c.handle(
            proto::CONFIGURE,
            &Value::object(vec![(
                "groups",
                Value::object(vec![(
                    "1",
                    Value::Arr(vec![1u64.into(), 2u64.into(), 3u64.into()]),
                )]),
            )]),
        );
        std::thread::sleep(Duration::from_millis(80));
        let r = c.handle(proto::SHOULD_INITIATE, &proto::node_op(2, 1));
        assert_eq!(r.bool_of("init"), Some(true));
        // A message from round 0 arrives late.
        let mut stale = proto::post_aggregate(1, 2, b"old", 1);
        stale.set("round_id", Value::from(0u64));
        let r = c.handle(proto::POST_AGGREGATE, &stale);
        assert_eq!(r.str_of("status"), Some("stale_round"));
        // Current-round message is fine.
        let mut fresh = proto::post_aggregate(2, 3, b"new", 1);
        fresh.set("round_id", Value::from(1u64));
        let r = c.handle(proto::POST_AGGREGATE, &fresh);
        assert_eq!(r.str_of("status"), Some("ok"));
    }

    #[test]
    fn begin_round_resets_chain_state_but_keeps_keys() {
        let c = controller();
        // Round-0 artifacts that must survive a round boundary.
        let key = Value::object(vec![("n", Value::from("abcd"))]);
        c.handle(
            proto::REGISTER_KEY,
            &Value::object(vec![("node", Value::from(1u64)), ("key", key.clone())]),
        );
        let sealed = Blob::from_slice(b"sealed");
        c.handle(
            proto::POST_PRENEG_KEYS,
            &Value::object(vec![
                ("node", Value::from(2u64)),
                ("keys", Value::object(vec![("1", Value::Bytes(sealed.clone()))])),
            ]),
        );
        // Per-round transients that must NOT survive.
        c.handle(proto::POST_AGGREGATE, &proto::post_aggregate(1, 2, b"a1", 1));
        c.handle(proto::POST_AVERAGE, &proto::post_average(1, 1, &[2.0], 3));

        let br = proto::BeginRound::new(
            1,
            std::collections::BTreeMap::from([(1u64, vec![1u64, 2, 3])]),
        );
        let r = c.handle(proto::BEGIN_ROUND, &br.to_value());
        assert_eq!(r.str_of("status"), Some("ok"));
        // Mailbox and average are gone.
        let r = c.handle(proto::GET_AGGREGATE, &proto::node_op(2, 1));
        assert_eq!(r.str_of("status"), Some("empty"));
        let r = c.handle(proto::GET_AVERAGE, &proto::node_op(2, 1));
        assert_eq!(r.str_of("status"), Some("empty"));
        // Keys survive.
        let r = c.handle(proto::GET_KEY, &Value::object(vec![("node", Value::from(1u64))]));
        assert_eq!(r.get("key"), Some(&key));
        let r = c.handle(
            proto::GET_PRENEG_KEY,
            &Value::object(vec![("node", Value::from(1u64)), ("owner", Value::from(2u64))]),
        );
        assert_eq!(r.blob_of("key").unwrap(), sealed);
        // Epoch surfaced in status; rewinding the epoch is rejected.
        let st = c.handle(proto::STATUS, &Value::obj());
        assert_eq!(st.u64_of("epoch"), Some(1));
        let old = proto::BeginRound::new(0, Default::default());
        assert_eq!(
            c.handle(proto::BEGIN_ROUND, &old.to_value()).str_of("status"),
            Some("stale_epoch")
        );
    }

    #[test]
    fn privacy_floor_answers_merge_groups_when_mergeable() {
        // Two 3-node groups, merge_floor on (via begin_round). Group 1
        // loses a node mid-round → merge_groups naming the smallest other
        // group, not abort_privacy_floor.
        let cfg = ControllerConfig {
            poll_time: Duration::from_millis(100),
            progress_timeout: Duration::from_millis(80),
            ..Default::default()
        };
        let c = Controller::new(cfg);
        let br = proto::BeginRound {
            epoch: 1,
            groups: std::collections::BTreeMap::from([
                (1u64, vec![1u64, 2, 3]),
                (2u64, vec![4u64, 5, 6]),
            ]),
            merge_floor: true,
            reassigned: vec![],
            fanin: false,
            fed_children: None,
        };
        c.handle(proto::BEGIN_ROUND, &br.to_value());
        let mut post = proto::post_aggregate(1, 2, b"a1", 1);
        post.set("epoch", Value::from(1u64));
        c.handle(proto::POST_AGGREGATE, &post);
        std::thread::sleep(Duration::from_millis(120));
        let r = c.handle(proto::PROGRESS_CHECK, &Value::obj());
        let actions = r.get("actions").unwrap().as_arr().unwrap();
        assert_eq!(actions.len(), 1);
        assert_eq!(actions[0].str_of("action"), Some("merge_groups"));
        assert_eq!(actions[0].u64_of("group"), Some(1));
        assert_eq!(actions[0].u64_of("failed"), Some(2));
        assert_eq!(actions[0].u64_of("into"), Some(2));
        // Status surfaces the session's merge capability.
        let st = c.handle(proto::STATUS, &Value::obj());
        assert_eq!(st.bool_of("merge_floor"), Some(true));
    }

    #[test]
    fn privacy_floor_aborts_when_no_group_can_absorb() {
        // merge_floor is on, but the only other group has nobody live —
        // merging cannot restore the floor, so the fallback must be
        // abort_privacy_floor, not a merge_groups naming a dead group.
        let cfg = ControllerConfig {
            poll_time: Duration::from_millis(100),
            progress_timeout: Duration::from_millis(80),
            ..Default::default()
        };
        let c = Controller::new(cfg);
        let br = proto::BeginRound {
            epoch: 1,
            groups: std::collections::BTreeMap::from([
                (1u64, vec![1u64, 2, 3]),
                (2u64, vec![]),
            ]),
            merge_floor: true,
            reassigned: vec![],
            fanin: false,
            fed_children: None,
        };
        c.handle(proto::BEGIN_ROUND, &br.to_value());
        c.handle(proto::POST_AGGREGATE, &proto::post_aggregate(1, 2, b"a1", 1));
        std::thread::sleep(Duration::from_millis(120));
        let r = c.handle(proto::PROGRESS_CHECK, &Value::obj());
        let actions = r.get("actions").unwrap().as_arr().unwrap();
        assert_eq!(actions.len(), 1);
        assert_eq!(actions[0].str_of("action"), Some("abort_privacy_floor"));
    }

    #[test]
    fn stale_epoch_posts_rejected() {
        let c = controller();
        let br = proto::BeginRound::new(
            2,
            std::collections::BTreeMap::from([(1u64, vec![1u64, 2, 3])]),
        );
        c.handle(proto::BEGIN_ROUND, &br.to_value());
        // A straggler from epoch 1 is refused; the current epoch lands.
        let mut stale = proto::post_aggregate(1, 2, b"old", 1);
        stale.set("epoch", Value::from(1u64));
        assert_eq!(
            c.handle(proto::POST_AGGREGATE, &stale).str_of("status"),
            Some("stale_epoch")
        );
        let mut fresh = proto::post_aggregate(1, 2, b"new", 1);
        fresh.set("epoch", Value::from(2u64));
        assert_eq!(
            c.handle(proto::POST_AGGREGATE, &fresh).str_of("status"),
            Some("ok")
        );
    }

    #[test]
    fn unknown_op_and_reset() {
        let c = controller();
        let r = c.handle("/nope", &Value::obj());
        assert_eq!(r.str_of("status"), Some("unknown op"));
        c.handle(proto::RESET, &Value::obj());
        let st = c.handle(proto::STATUS, &Value::obj());
        assert_eq!(st.get("groups").unwrap().as_arr().unwrap().len(), 0);
    }
}
