//! Controller state: the store-and-forward broker at the centre of SAFE.
//!
//! The controller never decrypts anything — it stores opaque `aggregate`
//! strings, routes them between chain neighbours, tracks progress, elects
//! replacement initiators and distributes the final (cleartext) average,
//! exactly as in the paper's Flask reference (Appendix A) but with condvar
//! wakeups instead of `sleep(yield_time)` spin-polling (see DESIGN §Perf).

use std::collections::{BTreeMap, BTreeSet};
use std::time::Instant;

use crate::blob::Blob;

/// An aggregate parked for `to_node` until it polls. The blob is the
/// encoded envelope exactly as posted — stored and later delivered as the
/// same shared allocation, never decoded or re-encoded (the zero-copy
/// pass-through the paper's "mere message broker" implies).
#[derive(Debug, Clone)]
pub struct PostedAggregate {
    pub aggregate: Blob,
    pub from_node: u64,
    pub posted_at: Instant,
}

/// Answer to `check_aggregate(node)`: has `node` progressed, or must the
/// checker repost around it?
#[derive(Debug, Clone, PartialEq)]
pub enum CheckStatus {
    /// `node` posted its own aggregate onward — chain advanced.
    Consumed,
    /// The progress monitor declared `node` failed; re-encrypt for
    /// `new_target` and repost (paper §5.3, Fig 4 step 5).
    Repost { new_target: u64 },
}

/// Per-group (per-chain) aggregation state. One SAFE chain per group
/// (§5.5: subgroups aggregate in parallel with an initiator each).
#[derive(Debug)]
pub struct GroupState {
    /// Chain order for this group (node ids, aggregation order).
    pub chain: Vec<u64>,
    /// Nodes declared failed by the monitor this round.
    pub failed: BTreeSet<u64>,
    /// Mailbox: to_node → parked aggregate.
    pub mailbox: BTreeMap<u64, PostedAggregate>,
    /// check_aggregate statuses keyed by the node being checked.
    pub check: BTreeMap<u64, CheckStatus>,
    /// Distinct nodes that posted an aggregate this round (contributors).
    pub posters: BTreeSet<u64>,
    /// The group average posted by this group's initiator.
    pub average: Option<Vec<f64>>,
    /// Contributor count reported with the average (for weighted schemes).
    pub average_contributors: u64,
    /// Current initiator (elected or configured).
    pub initiator: Option<u64>,
    /// When the current aggregation round started.
    pub round_start: Instant,
    /// Time of the last post_aggregate (progress tracking).
    pub last_activity: Instant,
    /// Monotonic round counter — bumped on initiator-failover restart.
    pub round_id: u64,
    /// Attempt-dedup tokens already applied this round: a post carrying a
    /// seen token is answered `duplicate` with no state change, so a
    /// client resending after response-leg loss never double-counts.
    pub seen_tokens: BTreeSet<u64>,
}

impl GroupState {
    pub fn new(chain: Vec<u64>) -> Self {
        let now = Instant::now();
        GroupState {
            chain,
            failed: BTreeSet::new(),
            mailbox: BTreeMap::new(),
            check: BTreeMap::new(),
            posters: BTreeSet::new(),
            average: None,
            average_contributors: 0,
            initiator: None,
            round_start: now,
            last_activity: now,
            round_id: 0,
            seen_tokens: BTreeSet::new(),
        }
    }

    /// Reset for a fresh attempt (initiator failover, §5.4). The chain and
    /// failure knowledge survive; mailbox/average state does not.
    pub fn restart_round(&mut self, new_initiator: u64) {
        self.mailbox.clear();
        self.check.clear();
        self.posters.clear();
        self.average = None;
        self.average_contributors = 0;
        self.initiator = Some(new_initiator);
        self.round_start = Instant::now();
        self.last_activity = self.round_start;
        self.round_id += 1;
        // Tokens from the aborted attempt can never be accepted anyway
        // (their round_id is stale); dropping them bounds the set.
        self.seen_tokens.clear();
    }

    /// Next node after `node` in chain order, skipping known-failed nodes.
    /// Wraps around. Returns None if fewer than 2 live nodes remain.
    pub fn next_alive_after(&self, node: u64) -> Option<u64> {
        let pos = self.chain.iter().position(|&n| n == node)?;
        let len = self.chain.len();
        for step in 1..len {
            let cand = self.chain[(pos + step) % len];
            if !self.failed.contains(&cand) {
                return Some(cand);
            }
        }
        None
    }

    /// Number of live nodes in the chain.
    pub fn live_count(&self) -> usize {
        self.chain.iter().filter(|n| !self.failed.contains(n)).count()
    }

    /// The node whose silence is blocking the chain, if any: the recipient
    /// of the most recent undelivered-or-unanswered post. Returns the
    /// (checker, failed) pair the monitor needs.
    pub fn stuck_link(&self) -> Option<(u64, u64)> {
        // Find the most recent poster whose successor has not posted.
        // The mailbox entry may or may not have been pulled already; what
        // matters is that the recipient never posted onward.
        let mut best: Option<(&PostedAggregate, u64)> = None;
        for (to, posted) in &self.mailbox {
            if best.as_ref().map_or(true, |(b, _)| posted.posted_at > b.posted_at) {
                best = Some((posted, *to));
            }
        }
        if let Some((posted, to)) = best {
            if !self.posters.contains(&to) && self.average.is_none() {
                return Some((posted.from_node, to));
            }
        }
        // Mailbox already drained: recipient pulled the aggregate, then
        // died without posting. Reconstruct from the poster set: the last
        // poster in chain order whose successor is silent.
        if self.average.is_some() || self.posters.is_empty() {
            return None;
        }
        // Walk the chain from the initiator; find the last consecutive poster.
        let init = self.initiator?;
        let pos = self.chain.iter().position(|&n| n == init)?;
        let len = self.chain.len();
        let mut last_poster = None;
        for step in 0..len {
            let n = self.chain[(pos + step) % len];
            if self.failed.contains(&n) {
                continue;
            }
            if self.posters.contains(&n) {
                last_poster = Some(n);
            } else {
                // First live node that hasn't posted: stuck on it — unless
                // it's the initiator waiting to finish (step 0 handled by
                // posters check).
                if let Some(lp) = last_poster {
                    return Some((lp, n));
                }
                return None;
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gs(chain: &[u64]) -> GroupState {
        GroupState::new(chain.to_vec())
    }

    #[test]
    fn next_alive_wraps_and_skips_failed() {
        let mut g = gs(&[1, 2, 3, 4, 5]);
        assert_eq!(g.next_alive_after(2), Some(3));
        assert_eq!(g.next_alive_after(5), Some(1));
        g.failed.insert(3);
        assert_eq!(g.next_alive_after(2), Some(4));
        g.failed.insert(4);
        assert_eq!(g.next_alive_after(2), Some(5));
        g.failed.insert(5);
        g.failed.insert(1);
        assert_eq!(g.next_alive_after(2), None);
    }

    #[test]
    fn live_count_tracks_failures() {
        let mut g = gs(&[1, 2, 3]);
        assert_eq!(g.live_count(), 3);
        g.failed.insert(2);
        assert_eq!(g.live_count(), 2);
    }

    #[test]
    fn stuck_link_via_mailbox() {
        let mut g = gs(&[1, 2, 3]);
        g.initiator = Some(1);
        g.posters.insert(1);
        g.mailbox.insert(
            2,
            PostedAggregate {
                aggregate: Blob::from_slice(b"x"),
                from_node: 1,
                posted_at: Instant::now(),
            },
        );
        // Node 2 never posted onward → stuck on 2, checker is 1.
        assert_eq!(g.stuck_link(), Some((1, 2)));
        // Once 2 posts, it's no longer stuck on 2.
        g.posters.insert(2);
        g.mailbox.remove(&2);
        g.mailbox.insert(
            3,
            PostedAggregate {
                aggregate: Blob::from_slice(b"y"),
                from_node: 2,
                posted_at: Instant::now(),
            },
        );
        assert_eq!(g.stuck_link(), Some((2, 3)));
    }

    #[test]
    fn stuck_link_after_mailbox_drained() {
        // Node pulled the message then died before posting.
        let mut g = gs(&[1, 2, 3, 4]);
        g.initiator = Some(1);
        g.posters.insert(1);
        g.posters.insert(2);
        // mailbox empty: 3 consumed but never posted.
        assert_eq!(g.stuck_link(), Some((2, 3)));
    }

    #[test]
    fn no_stuck_link_when_average_posted() {
        let mut g = gs(&[1, 2, 3]);
        g.initiator = Some(1);
        g.posters.extend([1, 2, 3]);
        g.average = Some(vec![1.0]);
        assert_eq!(g.stuck_link(), None);
    }

    #[test]
    fn restart_round_clears_transients_keeps_chain() {
        let mut g = gs(&[1, 2, 3]);
        g.posters.insert(1);
        g.mailbox.insert(
            2,
            PostedAggregate { aggregate: Blob::from_slice(b"x"), from_node: 1, posted_at: Instant::now() },
        );
        g.average = Some(vec![0.5]);
        g.failed.insert(2);
        let old_round = g.round_id;
        g.restart_round(3);
        assert!(g.posters.is_empty());
        assert!(g.mailbox.is_empty());
        assert!(g.average.is_none());
        assert_eq!(g.initiator, Some(3));
        assert_eq!(g.round_id, old_round + 1);
        assert!(g.failed.contains(&2), "failure knowledge survives restart");
        assert_eq!(g.chain, vec![1, 2, 3]);
    }
}
