//! AES-256-CTR + HMAC-SHA256 authenticated encryption (encrypt-then-MAC).
//!
//! The paper's §5.7 hybrid scheme: a random symmetric key encrypts the
//! (large) feature-vector payload, while RSA only covers the small key.
//! The `aes` RustCrypto crate (in the offline cache) provides the block
//! cipher; CTR mode, key derivation and the MAC are built here.

use aes::cipher::{BlockEncrypt, KeyInit};
use aes::Aes256;
use anyhow::{bail, Result};
use hmac::{Hmac, Mac};
use sha2::{Digest, Sha256};

use super::rng::SecureRng;

type HmacSha256 = Hmac<Sha256>;

/// Symmetric key material: 32-byte AES key + 32-byte MAC key, derived from
/// one 32-byte master via SHA-256 domain separation.
#[derive(Clone, PartialEq, Eq)]
pub struct SymmetricKey {
    pub master: [u8; 32],
}

impl std::fmt::Debug for SymmetricKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SymmetricKey(****)")
    }
}

impl SymmetricKey {
    pub fn generate(rng: &mut dyn SecureRng) -> Self {
        let mut master = [0u8; 32];
        rng.fill_bytes(&mut master);
        SymmetricKey { master }
    }

    pub fn from_bytes(b: &[u8]) -> Result<Self> {
        if b.len() != 32 {
            bail!("symmetric key must be 32 bytes, got {}", b.len());
        }
        let mut master = [0u8; 32];
        master.copy_from_slice(b);
        Ok(SymmetricKey { master })
    }

    fn enc_key(&self) -> [u8; 32] {
        let mut h = Sha256::new();
        h.update(b"safe-enc");
        h.update(self.master);
        h.finalize().into()
    }

    fn mac_key(&self) -> [u8; 32] {
        let mut h = Sha256::new();
        h.update(b"safe-mac");
        h.update(self.master);
        h.finalize().into()
    }

    /// Encrypt-then-MAC. Output layout: nonce(16) || ciphertext || tag(32).
    pub fn seal(&self, plaintext: &[u8], rng: &mut dyn SecureRng) -> Vec<u8> {
        let mut nonce = [0u8; 16];
        rng.fill_bytes(&mut nonce);
        let mut out = Vec::with_capacity(16 + plaintext.len() + 32);
        out.extend_from_slice(&nonce);
        let mut ct = plaintext.to_vec();
        ctr_xor(&self.enc_key(), &nonce, &mut ct);
        out.extend_from_slice(&ct);
        let mut mac = <HmacSha256 as Mac>::new_from_slice(&self.mac_key()).unwrap();
        mac.update(&out);
        let tag = mac.finalize().into_bytes();
        out.extend_from_slice(&tag);
        out
    }

    /// Verify MAC and decrypt. Errors on truncation or tampering.
    pub fn open(&self, sealed: &[u8]) -> Result<Vec<u8>> {
        if sealed.len() < 16 + 32 {
            bail!("sealed blob too short ({} bytes)", sealed.len());
        }
        let (body, tag) = sealed.split_at(sealed.len() - 32);
        let mut mac = <HmacSha256 as Mac>::new_from_slice(&self.mac_key()).unwrap();
        mac.update(body);
        mac.verify_slice(tag).map_err(|_| anyhow::anyhow!("MAC verification failed"))?;
        let (nonce, ct) = body.split_at(16);
        let mut pt = ct.to_vec();
        let nonce16: [u8; 16] = nonce.try_into().unwrap();
        ctr_xor(&self.enc_key(), &nonce16, &mut pt);
        Ok(pt)
    }
}

/// AES-256 CTR keystream XOR, in place. The 16-byte nonce is the initial
/// counter block; we increment the trailing 64 bits big-endian.
fn ctr_xor(key: &[u8; 32], nonce: &[u8; 16], data: &mut [u8]) {
    let cipher = Aes256::new_from_slice(key).unwrap();
    let mut counter_block = *nonce;
    let mut offset = 0usize;
    let mut ctr: u64 = u64::from_be_bytes(nonce[8..16].try_into().unwrap());
    while offset < data.len() {
        counter_block[8..16].copy_from_slice(&ctr.to_be_bytes());
        let mut ks = aes::Block::clone_from_slice(&counter_block);
        cipher.encrypt_block(&mut ks);
        let take = (data.len() - offset).min(16);
        for i in 0..take {
            data[offset + i] ^= ks[i];
        }
        offset += take;
        ctr = ctr.wrapping_add(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::DeterministicRng;

    #[test]
    fn seal_open_roundtrip() {
        let mut rng = DeterministicRng::seed(1);
        let key = SymmetricKey::generate(&mut rng);
        for len in [0usize, 1, 15, 16, 17, 1000, 65536] {
            let msg: Vec<u8> = (0..len).map(|i| (i * 7 % 256) as u8).collect();
            let sealed = key.seal(&msg, &mut rng);
            assert_eq!(key.open(&sealed).unwrap(), msg, "len={}", len);
        }
    }

    #[test]
    fn tampering_detected() {
        let mut rng = DeterministicRng::seed(2);
        let key = SymmetricKey::generate(&mut rng);
        let mut sealed = key.seal(b"attack at dawn", &mut rng);
        for idx in [0usize, 16, sealed.len() - 1] {
            sealed[idx] ^= 1;
            assert!(key.open(&sealed).is_err(), "tamper at {}", idx);
            sealed[idx] ^= 1;
        }
        assert!(key.open(&sealed).is_ok());
    }

    #[test]
    fn wrong_key_rejected() {
        let mut rng = DeterministicRng::seed(3);
        let k1 = SymmetricKey::generate(&mut rng);
        let k2 = SymmetricKey::generate(&mut rng);
        let sealed = k1.seal(b"secret", &mut rng);
        assert!(k2.open(&sealed).is_err());
    }

    #[test]
    fn nonce_randomized() {
        let mut rng = DeterministicRng::seed(4);
        let key = SymmetricKey::generate(&mut rng);
        let s1 = key.seal(b"m", &mut rng);
        let s2 = key.seal(b"m", &mut rng);
        assert_ne!(s1, s2);
    }

    #[test]
    fn truncated_blob_rejected() {
        let mut rng = DeterministicRng::seed(5);
        let key = SymmetricKey::generate(&mut rng);
        let sealed = key.seal(b"hello", &mut rng);
        assert!(key.open(&sealed[..10]).is_err());
        assert!(key.open(&[]).is_err());
    }

    #[test]
    fn ctr_keystream_is_position_dependent() {
        // Same plaintext at different offsets must not produce equal ct.
        let key = [7u8; 32];
        let nonce = [1u8; 16];
        let mut a = vec![0u8; 32];
        ctr_xor(&key, &nonce, &mut a);
        assert_ne!(a[..16], a[16..]);
    }

    #[test]
    fn key_from_bytes_validates_length() {
        assert!(SymmetricKey::from_bytes(&[0u8; 31]).is_err());
        assert!(SymmetricKey::from_bytes(&[0u8; 32]).is_ok());
    }
}
