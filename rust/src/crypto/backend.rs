//! Pluggable big-integer backends behind the [`Big`] trait.
//!
//! Modeled on the fission-suite `Big` trait (wnfs-nameaccumulator): a
//! backend is a unit struct whose associated `Num` type carries the
//! arbitrary-precision values, with every operation a static method on
//! the backend. Generic crypto code (`rsa`, `dh`, `prime`, `shamir`
//! cross-checks) is written against `B: Big`, so a whole protocol stack
//! can be re-pointed at another bignum implementation by switching one
//! type parameter — and the cross-backend differential suite
//! (`tests/crypto_differential.rs`) holds every backend bit-identical to
//! the others before it is allowed near a key.
//!
//! Two backends ship in-tree:
//!
//! * [`NativeBig`] — the default: [`super::bigint::BigUint`] (u64 limbs,
//!   Karatsuba, Knuth-D division, Montgomery CIOS multiplication with a
//!   dedicated squaring path and 4-bit fixed-window modexp).
//! * [`super::bigint_dig::DigBig`] — a vendored, dependency-free port of
//!   the `num-bigint-dig` arithmetic surface (u32 limbs, schoolbook
//!   multiply, binary modexp — deliberately *different* algorithms, so
//!   differential tests compare genuinely independent code paths). The
//!   `bigint-dig` cargo feature makes it the session default; the real
//!   crate can be dropped behind the same impl when a crate cache is
//!   available.
//!
//! Modular-exponentiation state is reified as [`Big::Ctx`]: one context
//! per modulus, reused across every exponentiation against it. For the
//! native backend that is a Montgomery context (R² and the window table
//! amortized), which is what the §5.8 re-key path batches across a
//! node's links.

use std::cmp::Ordering;

use super::bigint::BigUint;
use super::rng::SecureRng;

/// Reusable per-modulus exponentiation state. Backends with Montgomery
/// arithmetic keep R², n′ and scratch here; plain backends just hold the
/// modulus. Contexts are cheap to clone relative to rebuilding.
pub trait ModContext<N>: Clone + Send + Sync {
    /// The modulus this context was built for.
    fn modulus(&self) -> &N;
    /// `base^exp mod modulus` using the precomputed state.
    fn modpow(&self, base: &N, exp: &N) -> N;
}

/// A big-integer backend. All operations are non-negative; subtraction
/// underflow panics (matching the in-tree `BigUint` contract).
pub trait Big: Clone + Copy + std::fmt::Debug + Default + PartialEq + Eq + Send + Sync {
    /// The arbitrary-precision value type.
    type Num: Clone + std::fmt::Debug + PartialEq + Eq + Send + Sync + 'static;
    /// Reusable per-modulus exponentiation state.
    type Ctx: ModContext<Self::Num>;

    /// Stable backend name, used to key per-backend bench records.
    const NAME: &'static str;

    fn zero() -> Self::Num;
    fn one() -> Self::Num;
    fn from_u64(v: u64) -> Self::Num;
    /// `Some(v)` when the value fits in a u64.
    fn as_u64(n: &Self::Num) -> Option<u64>;
    fn from_bytes_be(bytes: &[u8]) -> Self::Num;
    fn to_bytes_be(n: &Self::Num) -> Vec<u8>;
    fn from_hex(s: &str) -> anyhow::Result<Self::Num>;
    fn to_hex(n: &Self::Num) -> String;

    fn is_zero(n: &Self::Num) -> bool;
    fn is_one(n: &Self::Num) -> bool;
    fn is_even(n: &Self::Num) -> bool;
    fn bit_length(n: &Self::Num) -> usize;
    /// Test bit `i` (0 = LSB).
    fn bit(n: &Self::Num, i: usize) -> bool;
    fn cmp(a: &Self::Num, b: &Self::Num) -> Ordering;

    fn add(a: &Self::Num, b: &Self::Num) -> Self::Num;
    /// `a - b`; panics when `b > a`.
    fn sub(a: &Self::Num, b: &Self::Num) -> Self::Num;
    fn mul(a: &Self::Num, b: &Self::Num) -> Self::Num;
    /// `(quotient, remainder)`; panics on division by zero.
    fn div_rem(a: &Self::Num, b: &Self::Num) -> (Self::Num, Self::Num);
    fn modinv(a: &Self::Num, m: &Self::Num) -> Option<Self::Num>;
    fn gcd(a: &Self::Num, b: &Self::Num) -> Self::Num;
    fn modpow(base: &Self::Num, exp: &Self::Num, m: &Self::Num) -> Self::Num;
    /// Build a reusable exponentiation context for `modulus`.
    fn ctx(modulus: &Self::Num) -> Self::Ctx;

    // ── Provided combinators ────────────────────────────────────────────

    fn add_u64(a: &Self::Num, v: u64) -> Self::Num {
        Self::add(a, &Self::from_u64(v))
    }

    fn sub_u64(a: &Self::Num, v: u64) -> Self::Num {
        Self::sub(a, &Self::from_u64(v))
    }

    fn rem(a: &Self::Num, m: &Self::Num) -> Self::Num {
        Self::div_rem(a, m).1
    }

    fn div_rem_u64(a: &Self::Num, d: u64) -> (Self::Num, u64) {
        let (q, r) = Self::div_rem(a, &Self::from_u64(d));
        (q, Self::as_u64(&r).expect("remainder below a u64 divisor fits u64"))
    }

    /// `(a + b) mod m` — inputs must already be `< m`.
    fn addmod(a: &Self::Num, b: &Self::Num, m: &Self::Num) -> Self::Num {
        let s = Self::add(a, b);
        if Self::cmp(&s, m) != Ordering::Less {
            Self::sub(&s, m)
        } else {
            s
        }
    }

    /// `(a - b) mod m` — inputs must already be `< m`.
    fn submod(a: &Self::Num, b: &Self::Num, m: &Self::Num) -> Self::Num {
        if Self::cmp(a, b) != Ordering::Less {
            Self::sub(a, b)
        } else {
            Self::sub(&Self::add(a, m), b)
        }
    }

    fn mulmod(a: &Self::Num, b: &Self::Num, m: &Self::Num) -> Self::Num {
        Self::rem(&Self::mul(a, b), m)
    }

    /// `a² mod m`. Backends with a dedicated squaring path override this.
    fn squaremod(a: &Self::Num, m: &Self::Num) -> Self::Num {
        Self::mulmod(a, a, m)
    }

    /// Batched exponentiation: `base^(e₁·e₂·…·eₖ) mod m`, computed as
    /// `(((base^e₁)^e₂)…)^eₖ` in one shared context (the fission-suite
    /// `modpow_product` shape). The empty product is 1, so no exponents
    /// returns `base mod m`.
    fn modpow_product<'a, I>(base: &Self::Num, exponents: I, m: &Self::Num) -> Self::Num
    where
        I: IntoIterator<Item = &'a Self::Num>,
    {
        let ctx = Self::ctx(m);
        exponents
            .into_iter()
            .fold(Self::rem(base, m), |acc, e| ctx.modpow(&acc, e))
    }

    /// To big-endian bytes, left-padded with zeros to exactly `len`.
    /// Panics when the value doesn't fit.
    fn to_bytes_be_padded(n: &Self::Num, len: usize) -> Vec<u8> {
        let raw = Self::to_bytes_be(n);
        assert!(raw.len() <= len, "value too large for padded length");
        let mut out = vec![0u8; len - raw.len()];
        out.extend_from_slice(&raw);
        out
    }

    // ── Canonical randomness ────────────────────────────────────────────
    //
    // These are provided (not per-backend) ON PURPOSE: both decode the
    // same big-endian byte stream the same way, so a seeded RNG drives
    // every backend through identical draws — the property the
    // byte-stable cross-backend keygen regression pins.

    /// Uniform value in `[0, bound)` by rejection sampling. Draws
    /// `ceil(bits/8)` bytes per attempt and masks the excess high bits.
    fn random_below(bound: &Self::Num, rng: &mut dyn SecureRng) -> Self::Num {
        assert!(!Self::is_zero(bound));
        let bits = Self::bit_length(bound);
        let bytes = (bits + 7) / 8;
        loop {
            let mut buf = vec![0u8; bytes];
            rng.fill_bytes(&mut buf);
            let excess = bytes * 8 - bits;
            if excess > 0 {
                buf[0] &= 0xffu8 >> excess;
            }
            let v = Self::from_bytes_be(&buf);
            if Self::cmp(&v, bound) == Ordering::Less {
                return v;
            }
        }
    }

    /// Random value with exactly `bits` bits (MSB forced).
    fn random_bits(bits: usize, rng: &mut dyn SecureRng) -> Self::Num {
        assert!(bits > 0);
        let bytes = (bits + 7) / 8;
        let mut buf = vec![0u8; bytes];
        rng.fill_bytes(&mut buf);
        let excess = bytes * 8 - bits;
        buf[0] &= 0xffu8 >> excess;
        buf[0] |= 0x80u8 >> excess;
        Self::from_bytes_be(&buf)
    }
}

/// The in-tree default backend: [`BigUint`] with Montgomery CIOS
/// multiplication, a squaring specialization and 4-bit fixed-window
/// exponentiation.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NativeBig;

impl Big for NativeBig {
    type Num = BigUint;
    type Ctx = super::bigint::NativeCtx;

    const NAME: &'static str = "native";

    fn zero() -> BigUint {
        BigUint::zero()
    }
    fn one() -> BigUint {
        BigUint::one()
    }
    fn from_u64(v: u64) -> BigUint {
        BigUint::from_u64(v)
    }
    fn as_u64(n: &BigUint) -> Option<u64> {
        n.as_u64()
    }
    fn from_bytes_be(bytes: &[u8]) -> BigUint {
        BigUint::from_bytes_be(bytes)
    }
    fn to_bytes_be(n: &BigUint) -> Vec<u8> {
        n.to_bytes_be()
    }
    fn from_hex(s: &str) -> anyhow::Result<BigUint> {
        BigUint::from_hex(s)
    }
    fn to_hex(n: &BigUint) -> String {
        n.to_hex()
    }
    fn is_zero(n: &BigUint) -> bool {
        n.is_zero()
    }
    fn is_one(n: &BigUint) -> bool {
        n.is_one()
    }
    fn is_even(n: &BigUint) -> bool {
        n.is_even()
    }
    fn bit_length(n: &BigUint) -> usize {
        n.bit_length()
    }
    fn bit(n: &BigUint, i: usize) -> bool {
        n.bit(i)
    }
    fn cmp(a: &BigUint, b: &BigUint) -> Ordering {
        a.cmp(b)
    }
    fn add(a: &BigUint, b: &BigUint) -> BigUint {
        a.add(b)
    }
    fn sub(a: &BigUint, b: &BigUint) -> BigUint {
        a.sub(b)
    }
    fn mul(a: &BigUint, b: &BigUint) -> BigUint {
        a.mul(b)
    }
    fn div_rem(a: &BigUint, b: &BigUint) -> (BigUint, BigUint) {
        a.div_rem(b)
    }
    fn div_rem_u64(a: &BigUint, d: u64) -> (BigUint, u64) {
        a.div_rem_u64(d)
    }
    fn modinv(a: &BigUint, m: &BigUint) -> Option<BigUint> {
        a.modinv(m)
    }
    fn gcd(a: &BigUint, b: &BigUint) -> BigUint {
        a.gcd(b)
    }
    fn modpow(base: &BigUint, exp: &BigUint, m: &BigUint) -> BigUint {
        base.modpow(exp, m)
    }
    fn squaremod(a: &BigUint, m: &BigUint) -> BigUint {
        a.squaremod(m)
    }
    fn ctx(modulus: &BigUint) -> Self::Ctx {
        super::bigint::NativeCtx::new(modulus)
    }
}

/// The backend the non-generic protocol surface (session drivers, BON,
/// envelopes) compiles against. The `bigint-dig` cargo feature swaps the
/// whole stack onto the vendored reference backend — that build is what
/// CI's `crypto-differential` job runs the full test suite under.
#[cfg(not(feature = "bigint-dig"))]
pub type DefaultBig = NativeBig;
#[cfg(feature = "bigint-dig")]
pub type DefaultBig = super::bigint_dig::DigBig;

/// The default backend's value type. Non-generic call sites (BON key
/// wrangling, JSON key serialization) use this alias so they compile
/// unchanged under either default backend.
pub type Int = <DefaultBig as Big>::Num;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::bigint_dig::DigBig;
    use crate::crypto::rng::DeterministicRng;

    fn modpow_product_suite<B: Big>() {
        let m = B::from_u64(1_000_000_007);
        let base = B::from_u64(12345);
        let exps = [B::from_u64(3), B::from_u64(5), B::from_u64(7)];
        // base^(3·5·7) = base^105
        let expect = B::modpow(&base, &B::from_u64(105), &m);
        assert_eq!(B::modpow_product(&base, exps.iter(), &m), expect);
        // Empty product → base mod m.
        assert_eq!(B::modpow_product(&base, [].iter(), &m), B::rem(&base, &m));
    }

    #[test]
    fn modpow_product_is_product_of_exponents() {
        modpow_product_suite::<NativeBig>();
        modpow_product_suite::<DigBig>();
    }

    fn ctx_reuse_suite<B: Big>() {
        let mut rng = DeterministicRng::seed(77);
        let mut m = B::random_bits(256, &mut rng);
        if B::is_even(&m) {
            m = B::add_u64(&m, 1);
        }
        let ctx = B::ctx(&m);
        assert_eq!(B::cmp(ctx.modulus(), &m), Ordering::Equal);
        for _ in 0..4 {
            let b = B::random_below(&m, &mut rng);
            let e = B::random_bits(64, &mut rng);
            assert_eq!(ctx.modpow(&b, &e), B::modpow(&b, &e, &m));
        }
    }

    #[test]
    fn ctx_matches_one_shot_modpow() {
        ctx_reuse_suite::<NativeBig>();
        ctx_reuse_suite::<DigBig>();
    }

    #[test]
    fn canonical_randomness_is_backend_independent() {
        // Same seed, same draw sequence ⇒ byte-identical values across
        // backends (the property the keygen regression depends on).
        let mut r1 = DeterministicRng::seed(99);
        let mut r2 = DeterministicRng::seed(99);
        for bits in [8usize, 64, 65, 127, 256] {
            let a = NativeBig::random_bits(bits, &mut r1);
            let b = DigBig::random_bits(bits, &mut r2);
            assert_eq!(a.to_bytes_be(), b.to_bytes_be(), "bits={bits}");
        }
        let bound_a = NativeBig::from_u64(1 << 40);
        let bound_b = DigBig::from_u64(1 << 40);
        for _ in 0..16 {
            let a = NativeBig::random_below(&bound_a, &mut r1);
            let b = DigBig::random_below(&bound_b, &mut r2);
            assert_eq!(a.to_bytes_be(), b.to_bytes_be());
        }
    }
}
