//! Arbitrary-precision unsigned integers — the bignum substrate for RSA/DH.
//!
//! The offline crate cache has no `num-bigint` or `rsa`, so SAFE's
//! public-key layer (paper §4, §5.7) is built on this from-scratch
//! implementation: little-endian `u64` limbs, schoolbook + Karatsuba
//! multiplication, Knuth Algorithm-D division, and Montgomery (CIOS)
//! modular exponentiation for the RSA/DH hot path.

use std::cmp::Ordering;

/// Unsigned big integer, little-endian `u64` limbs, no leading zero limbs
/// (zero is an empty limb vector).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct BigUint {
    limbs: Vec<u64>,
}

impl BigUint {
    pub fn zero() -> Self {
        BigUint { limbs: vec![] }
    }

    pub fn one() -> Self {
        BigUint { limbs: vec![1] }
    }

    pub fn from_u64(v: u64) -> Self {
        if v == 0 {
            Self::zero()
        } else {
            BigUint { limbs: vec![v] }
        }
    }

    pub fn from_u128(v: u128) -> Self {
        let lo = v as u64;
        let hi = (v >> 64) as u64;
        let mut b = BigUint { limbs: vec![lo, hi] };
        b.trim();
        b
    }

    /// From big-endian bytes.
    pub fn from_bytes_be(bytes: &[u8]) -> Self {
        let mut limbs = Vec::with_capacity(bytes.len() / 8 + 1);
        let mut chunk_iter = bytes.rchunks(8);
        while let Some(chunk) = chunk_iter.next() {
            let mut limb = 0u64;
            for &b in chunk {
                limb = (limb << 8) | b as u64;
            }
            limbs.push(limb);
        }
        let mut v = BigUint { limbs };
        v.trim();
        v
    }

    /// To big-endian bytes (minimal length; zero → empty).
    pub fn to_bytes_be(&self) -> Vec<u8> {
        if self.is_zero() {
            return vec![];
        }
        let mut out = Vec::with_capacity(self.limbs.len() * 8);
        for (i, &limb) in self.limbs.iter().enumerate().rev() {
            let bytes = limb.to_be_bytes();
            if i == self.limbs.len() - 1 {
                // Skip leading zeros of the most significant limb.
                let skip = bytes.iter().take_while(|&&b| b == 0).count();
                out.extend_from_slice(&bytes[skip..]);
            } else {
                out.extend_from_slice(&bytes);
            }
        }
        out
    }

    /// To big-endian bytes, left-padded with zeros to exactly `len` bytes.
    /// Panics if the value doesn't fit.
    pub fn to_bytes_be_padded(&self, len: usize) -> Vec<u8> {
        let raw = self.to_bytes_be();
        assert!(raw.len() <= len, "value too large for padded length");
        let mut out = vec![0u8; len - raw.len()];
        out.extend_from_slice(&raw);
        out
    }

    /// Parse a hex string (no 0x prefix).
    pub fn from_hex(s: &str) -> anyhow::Result<Self> {
        let s: String = s.chars().filter(|c| !c.is_whitespace()).collect();
        let s = if s.len() % 2 == 1 { format!("0{}", s) } else { s };
        Ok(Self::from_bytes_be(&crate::util::hex_decode(&s)?))
    }

    pub fn to_hex(&self) -> String {
        if self.is_zero() {
            return "0".to_string();
        }
        crate::util::hex_encode(&self.to_bytes_be())
            .trim_start_matches('0')
            .to_string()
    }

    pub fn is_zero(&self) -> bool {
        self.limbs.is_empty()
    }

    pub fn is_one(&self) -> bool {
        self.limbs.len() == 1 && self.limbs[0] == 1
    }

    pub fn is_even(&self) -> bool {
        self.limbs.first().map_or(true, |l| l & 1 == 0)
    }

    /// Number of significant bits.
    pub fn bit_length(&self) -> usize {
        match self.limbs.last() {
            None => 0,
            Some(&top) => (self.limbs.len() - 1) * 64 + (64 - top.leading_zeros() as usize),
        }
    }

    /// Test bit `i` (0 = LSB).
    pub fn bit(&self, i: usize) -> bool {
        let limb = i / 64;
        let off = i % 64;
        self.limbs.get(limb).map_or(false, |l| (l >> off) & 1 == 1)
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self.limbs.len() {
            0 => Some(0),
            1 => Some(self.limbs[0]),
            _ => None,
        }
    }

    fn trim(&mut self) {
        while self.limbs.last() == Some(&0) {
            self.limbs.pop();
        }
    }

    pub fn cmp(&self, other: &BigUint) -> Ordering {
        if self.limbs.len() != other.limbs.len() {
            return self.limbs.len().cmp(&other.limbs.len());
        }
        for i in (0..self.limbs.len()).rev() {
            match self.limbs[i].cmp(&other.limbs[i]) {
                Ordering::Equal => continue,
                o => return o,
            }
        }
        Ordering::Equal
    }

    pub fn lt(&self, other: &BigUint) -> bool {
        self.cmp(other) == Ordering::Less
    }

    pub fn ge(&self, other: &BigUint) -> bool {
        self.cmp(other) != Ordering::Less
    }

    pub fn add(&self, other: &BigUint) -> BigUint {
        let (long, short) = if self.limbs.len() >= other.limbs.len() {
            (&self.limbs, &other.limbs)
        } else {
            (&other.limbs, &self.limbs)
        };
        let mut out = Vec::with_capacity(long.len() + 1);
        let mut carry = 0u64;
        for i in 0..long.len() {
            let b = short.get(i).copied().unwrap_or(0);
            let (s1, c1) = long[i].overflowing_add(b);
            let (s2, c2) = s1.overflowing_add(carry);
            out.push(s2);
            carry = (c1 as u64) + (c2 as u64);
        }
        if carry > 0 {
            out.push(carry);
        }
        let mut v = BigUint { limbs: out };
        v.trim();
        v
    }

    pub fn add_u64(&self, v: u64) -> BigUint {
        self.add(&BigUint::from_u64(v))
    }

    /// self - other; panics if other > self.
    pub fn sub(&self, other: &BigUint) -> BigUint {
        assert!(self.ge(other), "BigUint::sub underflow");
        let mut out = Vec::with_capacity(self.limbs.len());
        let mut borrow = 0u64;
        for i in 0..self.limbs.len() {
            let b = other.limbs.get(i).copied().unwrap_or(0);
            let (d1, b1) = self.limbs[i].overflowing_sub(b);
            let (d2, b2) = d1.overflowing_sub(borrow);
            out.push(d2);
            borrow = (b1 as u64) + (b2 as u64);
        }
        debug_assert_eq!(borrow, 0);
        let mut v = BigUint { limbs: out };
        v.trim();
        v
    }

    pub fn sub_u64(&self, v: u64) -> BigUint {
        self.sub(&BigUint::from_u64(v))
    }

    /// Karatsuba threshold in limbs (tuned in the perf pass; schoolbook wins
    /// below ~32 limbs = 2048 bits on this CPU).
    const KARATSUBA_THRESHOLD: usize = 32;

    pub fn mul(&self, other: &BigUint) -> BigUint {
        if self.is_zero() || other.is_zero() {
            return BigUint::zero();
        }
        if self.limbs.len().min(other.limbs.len()) >= Self::KARATSUBA_THRESHOLD {
            return self.mul_karatsuba(other);
        }
        self.mul_schoolbook(other)
    }

    fn mul_schoolbook(&self, other: &BigUint) -> BigUint {
        let mut out = vec![0u64; self.limbs.len() + other.limbs.len()];
        for (i, &a) in self.limbs.iter().enumerate() {
            if a == 0 {
                continue;
            }
            let mut carry = 0u128;
            for (j, &b) in other.limbs.iter().enumerate() {
                let cur = out[i + j] as u128 + (a as u128) * (b as u128) + carry;
                out[i + j] = cur as u64;
                carry = cur >> 64;
            }
            let mut k = i + other.limbs.len();
            while carry > 0 {
                let cur = out[k] as u128 + carry;
                out[k] = cur as u64;
                carry = cur >> 64;
                k += 1;
            }
        }
        let mut v = BigUint { limbs: out };
        v.trim();
        v
    }

    fn mul_karatsuba(&self, other: &BigUint) -> BigUint {
        let n = self.limbs.len().max(other.limbs.len());
        let half = n / 2;
        let (a0, a1) = self.split_at(half);
        let (b0, b1) = other.split_at(half);
        let z0 = a0.mul(&b0);
        let z2 = a1.mul(&b1);
        let z1 = a0.add(&a1).mul(&b0.add(&b1)).sub(&z0).sub(&z2);
        // result = z2 << (2*half*64) + z1 << (half*64) + z0
        z2.shl_limbs(2 * half).add(&z1.shl_limbs(half)).add(&z0)
    }

    fn split_at(&self, k: usize) -> (BigUint, BigUint) {
        if self.limbs.len() <= k {
            (self.clone(), BigUint::zero())
        } else {
            let mut lo = BigUint { limbs: self.limbs[..k].to_vec() };
            lo.trim();
            let mut hi = BigUint { limbs: self.limbs[k..].to_vec() };
            hi.trim();
            (lo, hi)
        }
    }

    fn shl_limbs(&self, k: usize) -> BigUint {
        if self.is_zero() {
            return BigUint::zero();
        }
        let mut limbs = vec![0u64; k];
        limbs.extend_from_slice(&self.limbs);
        BigUint { limbs }
    }

    pub fn mul_u64(&self, v: u64) -> BigUint {
        if v == 0 || self.is_zero() {
            return BigUint::zero();
        }
        let mut out = Vec::with_capacity(self.limbs.len() + 1);
        let mut carry = 0u128;
        for &a in &self.limbs {
            let cur = (a as u128) * (v as u128) + carry;
            out.push(cur as u64);
            carry = cur >> 64;
        }
        if carry > 0 {
            out.push(carry as u64);
        }
        let mut r = BigUint { limbs: out };
        r.trim();
        r
    }

    /// Left shift by `bits`.
    pub fn shl(&self, bits: usize) -> BigUint {
        if self.is_zero() {
            return BigUint::zero();
        }
        let limb_shift = bits / 64;
        let bit_shift = bits % 64;
        let mut limbs = vec![0u64; limb_shift];
        if bit_shift == 0 {
            limbs.extend_from_slice(&self.limbs);
        } else {
            let mut carry = 0u64;
            for &l in &self.limbs {
                limbs.push((l << bit_shift) | carry);
                carry = l >> (64 - bit_shift);
            }
            if carry > 0 {
                limbs.push(carry);
            }
        }
        let mut v = BigUint { limbs };
        v.trim();
        v
    }

    /// Right shift by `bits`.
    pub fn shr(&self, bits: usize) -> BigUint {
        let limb_shift = bits / 64;
        if limb_shift >= self.limbs.len() {
            return BigUint::zero();
        }
        let bit_shift = bits % 64;
        let src = &self.limbs[limb_shift..];
        let mut limbs = Vec::with_capacity(src.len());
        if bit_shift == 0 {
            limbs.extend_from_slice(src);
        } else {
            for i in 0..src.len() {
                let hi = src.get(i + 1).copied().unwrap_or(0);
                limbs.push((src[i] >> bit_shift) | (hi << (64 - bit_shift)));
            }
        }
        let mut v = BigUint { limbs };
        v.trim();
        v
    }

    /// Division with remainder (Knuth Algorithm D). Returns (quotient,
    /// remainder). Panics on division by zero.
    pub fn div_rem(&self, divisor: &BigUint) -> (BigUint, BigUint) {
        assert!(!divisor.is_zero(), "division by zero");
        if self.lt(divisor) {
            return (BigUint::zero(), self.clone());
        }
        if divisor.limbs.len() == 1 {
            let (q, r) = self.div_rem_u64(divisor.limbs[0]);
            return (q, BigUint::from_u64(r));
        }
        // Normalize: shift so divisor's top limb has its high bit set.
        let shift = divisor.limbs.last().unwrap().leading_zeros() as usize;
        let u = self.shl(shift);
        let v = divisor.shl(shift);
        let n = v.limbs.len();
        let m = u.limbs.len() - n;
        let mut un = u.limbs.clone();
        un.push(0); // extra limb for the algorithm
        let vn = &v.limbs;
        let mut q = vec![0u64; m + 1];

        let vtop = vn[n - 1] as u128;
        let vsecond = vn[n - 2] as u128;

        for j in (0..=m).rev() {
            // Estimate q̂ = (u[j+n]·B + u[j+n-1]) / v[n-1]
            let num = ((un[j + n] as u128) << 64) | (un[j + n - 1] as u128);
            let mut qhat = num / vtop;
            let mut rhat = num % vtop;
            // Correct q̂ (at most 2 decrements).
            while qhat >= (1u128 << 64)
                || qhat * vsecond > ((rhat << 64) | (un[j + n - 2] as u128))
            {
                qhat -= 1;
                rhat += vtop;
                if rhat >= (1u128 << 64) {
                    break;
                }
            }
            // Multiply-subtract: u[j..j+n] -= q̂ * v
            let mut borrow = 0i128;
            let mut carry = 0u128;
            for i in 0..n {
                let p = qhat * (vn[i] as u128) + carry;
                carry = p >> 64;
                let sub = (un[j + i] as i128) - ((p as u64) as i128) + borrow;
                un[j + i] = sub as u64;
                borrow = sub >> 64;
            }
            let sub = (un[j + n] as i128) - (carry as i128) + borrow;
            un[j + n] = sub as u64;
            borrow = sub >> 64;

            q[j] = qhat as u64;
            if borrow < 0 {
                // q̂ was one too large: add v back.
                q[j] -= 1;
                let mut carry = 0u128;
                for i in 0..n {
                    let s = (un[j + i] as u128) + (vn[i] as u128) + carry;
                    un[j + i] = s as u64;
                    carry = s >> 64;
                }
                un[j + n] = un[j + n].wrapping_add(carry as u64);
            }
        }

        let mut quot = BigUint { limbs: q };
        quot.trim();
        let mut rem = BigUint { limbs: un[..n].to_vec() };
        rem.trim();
        (quot, rem.shr(shift))
    }

    pub fn div_rem_u64(&self, d: u64) -> (BigUint, u64) {
        assert!(d != 0, "division by zero");
        let mut out = vec![0u64; self.limbs.len()];
        let mut rem = 0u128;
        for i in (0..self.limbs.len()).rev() {
            let cur = (rem << 64) | self.limbs[i] as u128;
            out[i] = (cur / d as u128) as u64;
            rem = cur % d as u128;
        }
        let mut q = BigUint { limbs: out };
        q.trim();
        (q, rem as u64)
    }

    pub fn rem(&self, m: &BigUint) -> BigUint {
        self.div_rem(m).1
    }

    /// (self + other) mod m — inputs must already be < m.
    pub fn addmod(&self, other: &BigUint, m: &BigUint) -> BigUint {
        let s = self.add(other);
        if s.ge(m) {
            s.sub(m)
        } else {
            s
        }
    }

    /// (self - other) mod m — inputs must already be < m.
    pub fn submod(&self, other: &BigUint, m: &BigUint) -> BigUint {
        if self.ge(other) {
            self.sub(other)
        } else {
            self.add(m).sub(other)
        }
    }

    pub fn mulmod(&self, other: &BigUint, m: &BigUint) -> BigUint {
        self.mul(other).rem(m)
    }

    /// `self² mod m`. One-shot squaring goes through plain mulmod (a
    /// Montgomery context costs more to build than it saves on a single
    /// square); the Montgomery squaring specialization lives inside
    /// [`MontgomeryCtx`], where repeated squarings amortize it.
    pub fn squaremod(&self, m: &BigUint) -> BigUint {
        self.mulmod(self, m)
    }

    /// Modular exponentiation. Uses Montgomery CIOS when the modulus is odd
    /// (the RSA/DH case), falling back to square-and-multiply otherwise.
    pub fn modpow(&self, exp: &BigUint, modulus: &BigUint) -> BigUint {
        assert!(!modulus.is_zero(), "modpow: zero modulus");
        if modulus.is_one() {
            return BigUint::zero();
        }
        if !modulus.is_even() {
            return MontgomeryCtx::new(modulus).modpow(self, exp);
        }
        modpow_plain(self, exp, modulus)
    }

    pub fn gcd(&self, other: &BigUint) -> BigUint {
        let mut a = self.clone();
        let mut b = other.clone();
        while !b.is_zero() {
            let r = a.rem(&b);
            a = b;
            b = r;
        }
        a
    }

    /// Modular inverse via extended Euclid. Returns None if gcd != 1.
    pub fn modinv(&self, m: &BigUint) -> Option<BigUint> {
        // Track signed Bezout coefficients as (sign, magnitude).
        let mut r0 = m.clone();
        let mut r1 = self.rem(m);
        let mut t0: (bool, BigUint) = (false, BigUint::zero()); // 0
        let mut t1: (bool, BigUint) = (false, BigUint::one()); // 1
        while !r1.is_zero() {
            let (q, r2) = r0.div_rem(&r1);
            // t2 = t0 - q*t1
            let qt = q.mul(&t1.1);
            let t2 = signed_sub(t0.clone(), (t1.0, qt));
            r0 = r1;
            r1 = r2;
            t0 = t1;
            t1 = t2;
        }
        if !r0.is_one() {
            return None;
        }
        let inv = if t0.0 {
            m.sub(&t0.1.rem(m))
        } else {
            t0.1.rem(m)
        };
        Some(inv.rem(m))
    }

    /// Uniform random integer in [0, bound) using rejection sampling.
    pub fn random_below(bound: &BigUint, rng: &mut dyn crate::crypto::rng::SecureRng) -> BigUint {
        assert!(!bound.is_zero());
        let bits = bound.bit_length();
        let bytes = (bits + 7) / 8;
        loop {
            let mut buf = vec![0u8; bytes];
            rng.fill_bytes(&mut buf);
            // Mask off excess high bits.
            let excess = bytes * 8 - bits;
            if excess > 0 {
                buf[0] &= 0xffu8 >> excess;
            }
            let v = BigUint::from_bytes_be(&buf);
            if v.lt(bound) {
                return v;
            }
        }
    }

    /// Random integer with exactly `bits` bits (MSB set).
    pub fn random_bits(bits: usize, rng: &mut dyn crate::crypto::rng::SecureRng) -> BigUint {
        assert!(bits > 0);
        let bytes = (bits + 7) / 8;
        let mut buf = vec![0u8; bytes];
        rng.fill_bytes(&mut buf);
        let excess = bytes * 8 - bits;
        buf[0] &= 0xffu8 >> excess;
        buf[0] |= 0x80u8 >> excess; // force MSB
        BigUint::from_bytes_be(&buf)
    }
}

/// (sign, magnitude) subtraction: a - b.
fn signed_sub(a: (bool, BigUint), b: (bool, BigUint)) -> (bool, BigUint) {
    match (a.0, b.0) {
        (false, true) => (false, a.1.add(&b.1)),  // a - (-b) = a + b
        (true, false) => (true, a.1.add(&b.1)),   // -a - b = -(a+b)
        (false, false) => {
            if a.1.ge(&b.1) {
                (false, a.1.sub(&b.1))
            } else {
                (true, b.1.sub(&a.1))
            }
        }
        (true, true) => {
            // -a - (-b) = b - a
            if b.1.ge(&a.1) {
                (false, b.1.sub(&a.1))
            } else {
                (true, a.1.sub(&b.1))
            }
        }
    }
}

/// Binary square-and-multiply for even moduli (rare; not on the RSA hot
/// path). Shared by [`BigUint::modpow`] and the even-modulus arm of
/// [`NativeCtx`].
fn modpow_plain(base: &BigUint, exp: &BigUint, modulus: &BigUint) -> BigUint {
    let mut base = base.rem(modulus);
    let mut result = BigUint::one();
    for i in 0..exp.bit_length() {
        if exp.bit(i) {
            result = result.mulmod(&base, modulus);
        }
        base = base.squaremod(modulus);
    }
    result
}

/// Reusable per-modulus exponentiation context for the native backend:
/// a [`MontgomeryCtx`] for odd moduli, a plain square-and-multiply
/// fallback otherwise. This is what [`crate::crypto::backend::Big::ctx`]
/// hands out — build once per modulus, reuse across every
/// exponentiation (blob chunks, a node's §5.8 links, Miller–Rabin
/// witnesses).
#[derive(Clone)]
pub enum NativeCtx {
    Mont(MontgomeryCtx),
    Plain(BigUint),
}

impl NativeCtx {
    pub fn new(modulus: &BigUint) -> Self {
        assert!(!modulus.is_zero(), "zero modulus");
        if modulus.is_even() {
            NativeCtx::Plain(modulus.clone())
        } else {
            NativeCtx::Mont(MontgomeryCtx::new(modulus))
        }
    }
}

impl crate::crypto::backend::ModContext<BigUint> for NativeCtx {
    fn modulus(&self) -> &BigUint {
        match self {
            NativeCtx::Mont(ctx) => ctx.modulus(),
            NativeCtx::Plain(m) => m,
        }
    }

    fn modpow(&self, base: &BigUint, exp: &BigUint) -> BigUint {
        match self {
            NativeCtx::Mont(ctx) => ctx.modpow(base, exp),
            NativeCtx::Plain(m) => {
                if m.is_one() {
                    BigUint::zero()
                } else {
                    modpow_plain(base, exp, m)
                }
            }
        }
    }
}

/// Montgomery context for a fixed odd modulus (CIOS multiplication).
/// This is the RSA/DH hot path: one context per exponentiation, reused
/// across all the squarings/multiplications.
#[derive(Clone)]
pub struct MontgomeryCtx {
    n: Vec<u64>,     // modulus limbs
    n0inv: u64,      // -n^{-1} mod 2^64
    rr: Vec<u64>,    // R^2 mod n (R = 2^(64*len))
    modulus: BigUint,
}

impl MontgomeryCtx {
    pub fn new(modulus: &BigUint) -> Self {
        assert!(!modulus.is_even() && !modulus.is_zero());
        let n = modulus.limbs.clone();
        let n0inv = inv64(n[0]).wrapping_neg();
        // R^2 mod n where R = 2^(64*len)
        let r2 = BigUint::one().shl(n.len() * 64 * 2).rem(modulus);
        let mut rr = r2.limbs.clone();
        rr.resize(n.len(), 0);
        MontgomeryCtx { n, n0inv, rr, modulus: modulus.clone() }
    }

    pub fn modulus(&self) -> &BigUint {
        &self.modulus
    }

    /// CIOS Montgomery multiplication: returns a*b*R^{-1} mod n.
    fn mont_mul(&self, a: &[u64], b: &[u64]) -> Vec<u64> {
        let len = self.n.len();
        let mut t = vec![0u64; len + 2];
        for i in 0..len {
            // t += a[i] * b
            let ai = a[i] as u128;
            let mut carry = 0u128;
            for j in 0..len {
                let cur = t[j] as u128 + ai * (b[j] as u128) + carry;
                t[j] = cur as u64;
                carry = cur >> 64;
            }
            let cur = t[len] as u128 + carry;
            t[len] = cur as u64;
            t[len + 1] = (cur >> 64) as u64;

            // m = t[0] * n0inv mod 2^64
            let m = t[0].wrapping_mul(self.n0inv) as u128;
            // t += m * n; then shift right one limb
            let cur = t[0] as u128 + m * (self.n[0] as u128);
            let mut carry = cur >> 64;
            for j in 1..len {
                let cur = t[j] as u128 + m * (self.n[j] as u128) + carry;
                t[j - 1] = cur as u64;
                carry = cur >> 64;
            }
            let cur = t[len] as u128 + carry;
            t[len - 1] = cur as u64;
            t[len] = t[len + 1] + ((cur >> 64) as u64);
            t[len + 1] = 0;
        }
        // Final conditional subtraction.
        let needs_sub = t[len] > 0 || ge_limbs(&t[..len], &self.n);
        if needs_sub {
            let mut borrow = 0u64;
            for j in 0..len {
                let (d1, b1) = t[j].overflowing_sub(self.n[j]);
                let (d2, b2) = d1.overflowing_sub(borrow);
                t[j] = d2;
                borrow = (b1 as u64) + (b2 as u64);
            }
        }
        t.truncate(len);
        t
    }

    /// Montgomery squaring: a·a·R⁻¹ mod n. The cross products a_i·a_j
    /// (i < j) are computed once and doubled, then the diagonal squares
    /// added and a single REDC pass applied — roughly 1.5× faster than
    /// `mont_mul(a, a)` at RSA limb counts. Requires a < n (every
    /// Montgomery residue this context produces satisfies that).
    fn mont_sqr(&self, a: &[u64]) -> Vec<u64> {
        let len = self.n.len();
        let mut t = vec![0u64; 2 * len + 1];
        // Cross products, each pair once.
        for i in 0..len {
            let ai = a[i] as u128;
            if ai == 0 {
                continue;
            }
            let mut carry = 0u128;
            for j in (i + 1)..len {
                let cur = t[i + j] as u128 + ai * (a[j] as u128) + carry;
                t[i + j] = cur as u64;
                carry = cur >> 64;
            }
            t[i + len] = carry as u64;
        }
        // Double the cross part (shift left one bit across all limbs)...
        let mut carry_bit = 0u64;
        for limb in t.iter_mut() {
            let new = (*limb << 1) | carry_bit;
            carry_bit = *limb >> 63;
            *limb = new;
        }
        // ...then add the diagonal squares a_i² at positions (2i, 2i+1).
        let mut carry = 0u128;
        for i in 0..len {
            let sq = (a[i] as u128) * (a[i] as u128);
            let lo = t[2 * i] as u128 + (sq as u64 as u128) + carry;
            t[2 * i] = lo as u64;
            let hi = t[2 * i + 1] as u128 + (sq >> 64) + (lo >> 64);
            t[2 * i + 1] = hi as u64;
            carry = hi >> 64;
        }
        if carry > 0 {
            t[2 * len] = t[2 * len].wrapping_add(carry as u64);
        }
        self.redc(t)
    }

    /// One Montgomery reduction pass over a double-width value t < n·R:
    /// returns t·R⁻¹ mod n in `len` limbs.
    fn redc(&self, mut t: Vec<u64>) -> Vec<u64> {
        let len = self.n.len();
        debug_assert!(t.len() == 2 * len + 1);
        for i in 0..len {
            let m = t[i].wrapping_mul(self.n0inv) as u128;
            let mut carry = 0u128;
            for j in 0..len {
                let cur = t[i + j] as u128 + m * (self.n[j] as u128) + carry;
                t[i + j] = cur as u64;
                carry = cur >> 64;
            }
            let mut k = i + len;
            while carry > 0 {
                let cur = t[k] as u128 + carry;
                t[k] = cur as u64;
                carry = cur >> 64;
                k += 1;
            }
        }
        let needs_sub = t[2 * len] > 0 || ge_limbs(&t[len..2 * len], &self.n);
        let mut out = t[len..2 * len].to_vec();
        if needs_sub {
            let mut borrow = 0u64;
            for j in 0..len {
                let (d1, b1) = out[j].overflowing_sub(self.n[j]);
                let (d2, b2) = d1.overflowing_sub(borrow);
                out[j] = d2;
                borrow = (b1 as u64) + (b2 as u64);
            }
        }
        out
    }

    fn to_mont(&self, a: &BigUint) -> Vec<u64> {
        let mut al = a.rem(&self.modulus).limbs;
        al.resize(self.n.len(), 0);
        self.mont_mul(&al, &self.rr)
    }

    fn from_mont(&self, a: &[u64]) -> BigUint {
        let one = {
            let mut v = vec![0u64; self.n.len()];
            v[0] = 1;
            v
        };
        let out = self.mont_mul(a, &one);
        let mut v = BigUint { limbs: out };
        v.trim();
        v
    }

    /// Left-to-right 4-bit windowed exponentiation.
    pub fn modpow(&self, base: &BigUint, exp: &BigUint) -> BigUint {
        if exp.is_zero() {
            return BigUint::one().rem(&self.modulus);
        }
        let bm = self.to_mont(base);
        // Precompute powers table: bm^0 .. bm^15 (even entries squared).
        let mut table = Vec::with_capacity(16);
        let one_m = self.to_mont(&BigUint::one());
        table.push(one_m.clone());
        table.push(bm.clone());
        for i in 2..16 {
            if i % 2 == 0 {
                table.push(self.mont_sqr(&table[i / 2]));
            } else {
                table.push(self.mont_mul(&table[i - 1], &bm));
            }
        }
        let bits = exp.bit_length();
        let mut acc = one_m;
        let mut i = bits as isize - 1;
        while i >= 0 {
            // Take up to 4 bits.
            let take = (i + 1).min(4) as usize;
            let mut window = 0usize;
            for _ in 0..take {
                acc = self.mont_sqr(&acc);
                window = (window << 1) | (exp.bit(i as usize) as usize);
                i -= 1;
            }
            if window != 0 {
                acc = self.mont_mul(&acc, &table[window]);
            }
        }
        self.from_mont(&acc)
    }
}

fn ge_limbs(a: &[u64], b: &[u64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    for i in (0..a.len()).rev() {
        if a[i] != b[i] {
            return a[i] > b[i];
        }
    }
    true
}

/// Inverse of odd x mod 2^64 (Newton iteration).
fn inv64(x: u64) -> u64 {
    debug_assert!(x & 1 == 1);
    let mut inv = x; // 3 bits correct
    for _ in 0..5 {
        inv = inv.wrapping_mul(2u64.wrapping_sub(x.wrapping_mul(inv)));
    }
    debug_assert_eq!(x.wrapping_mul(inv), 1);
    inv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::DeterministicRng;

    fn n(v: u64) -> BigUint {
        BigUint::from_u64(v)
    }

    #[test]
    fn bytes_roundtrip() {
        let cases: Vec<Vec<u8>> = vec![
            vec![],
            vec![1],
            vec![0xff; 8],
            vec![1, 0, 0, 0, 0, 0, 0, 0, 0], // 2^64
            (1..=33).collect(),
        ];
        for c in cases {
            let v = BigUint::from_bytes_be(&c);
            let back = v.to_bytes_be();
            // Leading zeros are not preserved.
            let stripped: Vec<u8> = c.iter().copied().skip_while(|&b| b == 0).collect();
            assert_eq!(back, stripped);
        }
    }

    #[test]
    fn add_sub_identities() {
        let mut rng = DeterministicRng::seed(42);
        for _ in 0..50 {
            let a = BigUint::random_bits(200, &mut rng);
            let b = BigUint::random_bits(150, &mut rng);
            assert_eq!(a.add(&b).sub(&b), a);
            assert_eq!(a.add(&b).sub(&a), b);
            assert_eq!(a.add(&BigUint::zero()), a);
        }
    }

    #[test]
    fn mul_div_identities() {
        let mut rng = DeterministicRng::seed(7);
        for bits in [10usize, 64, 65, 128, 500, 2000] {
            let a = BigUint::random_bits(bits, &mut rng);
            let b = BigUint::random_bits(bits / 2 + 1, &mut rng);
            let p = a.mul(&b);
            let (q, r) = p.div_rem(&b);
            assert_eq!(q, a, "bits={}", bits);
            assert!(r.is_zero());
            // (a*b + c) / b == a rem c  when c < b
            let c = BigUint::random_below(&b, &mut rng);
            let (q2, r2) = p.add(&c).div_rem(&b);
            assert_eq!(q2, a);
            assert_eq!(r2, c);
        }
    }

    #[test]
    fn karatsuba_matches_schoolbook() {
        let mut rng = DeterministicRng::seed(99);
        for _ in 0..5 {
            let a = BigUint::random_bits(64 * 80, &mut rng);
            let b = BigUint::random_bits(64 * 70, &mut rng);
            assert_eq!(a.mul_karatsuba(&b), a.mul_schoolbook(&b));
        }
    }

    #[test]
    fn shifts() {
        let a = BigUint::from_hex("123456789abcdef0123456789abcdef").unwrap();
        assert_eq!(a.shl(64).shr(64), a);
        assert_eq!(a.shl(3).shr(3), a);
        assert_eq!(a.shl(127).shr(127), a);
        assert_eq!(n(1).shl(64), BigUint::from_u128(1u128 << 64));
    }

    #[test]
    fn known_division() {
        // 2^128 / (2^64 + 1) = 2^64 - 1 rem 1
        let a = BigUint::one().shl(128);
        let b = BigUint::from_u128((1u128 << 64) + 1);
        let (q, r) = a.div_rem(&b);
        assert_eq!(q, n(u64::MAX));
        assert_eq!(r, n(1));
    }

    #[test]
    fn modpow_small_cases() {
        // 3^4 mod 7 = 4 ; 5^0 mod 11 = 1 ; 2^10 mod 1024+1 ...
        assert_eq!(n(3).modpow(&n(4), &n(7)), n(4));
        assert_eq!(n(5).modpow(&n(0), &n(11)), n(1));
        assert_eq!(n(2).modpow(&n(10), &n(1025)), n(1024 % 1025));
        // Fermat: a^(p-1) = 1 mod p for prime p
        let p = n(1_000_000_007);
        for a in [2u64, 3, 12345, 999999999] {
            assert_eq!(n(a).modpow(&p.sub_u64(1), &p), n(1));
        }
    }

    #[test]
    fn modpow_matches_naive_big() {
        let mut rng = DeterministicRng::seed(123);
        // odd modulus (Montgomery path) vs naive mulmod loop
        for _ in 0..5 {
            let mut m = BigUint::random_bits(192, &mut rng);
            if m.is_even() {
                m = m.add_u64(1);
            }
            let b = BigUint::random_below(&m, &mut rng);
            let e = BigUint::random_bits(24, &mut rng);
            // naive
            let mut expect = BigUint::one();
            for i in (0..e.bit_length()).rev() {
                expect = expect.mulmod(&expect, &m);
                if e.bit(i) {
                    expect = expect.mulmod(&b, &m);
                }
            }
            assert_eq!(b.modpow(&e, &m), expect);
        }
    }

    #[test]
    fn modpow_even_modulus() {
        assert_eq!(n(3).modpow(&n(5), &n(100)), n(43)); // 243 mod 100
        assert_eq!(n(7).modpow(&n(2), &n(48)), n(1));
    }

    #[test]
    fn modinv_works() {
        let m = n(1_000_000_007);
        for a in [2u64, 3, 999, 123456] {
            let inv = n(a).modinv(&m).unwrap();
            assert_eq!(n(a).mulmod(&inv, &m), n(1));
        }
        // No inverse when gcd != 1
        assert!(n(6).modinv(&n(9)).is_none());
        // Big case
        let mut rng = DeterministicRng::seed(5);
        let m = BigUint::random_bits(256, &mut rng).add_u64(1);
        let a = BigUint::random_below(&m, &mut rng);
        if a.gcd(&m).is_one() {
            let inv = a.modinv(&m).unwrap();
            assert_eq!(a.mulmod(&inv, &m), BigUint::one());
        }
    }

    #[test]
    fn gcd_works() {
        assert_eq!(n(48).gcd(&n(18)), n(6));
        assert_eq!(n(17).gcd(&n(13)), n(1));
        assert_eq!(n(0).gcd(&n(5)), n(5));
    }

    #[test]
    fn hex_roundtrip() {
        let h = "deadbeef00112233445566778899aabbccddeeff";
        let v = BigUint::from_hex(h).unwrap();
        assert_eq!(v.to_hex(), h);
    }

    #[test]
    fn bit_length_and_bits() {
        assert_eq!(BigUint::zero().bit_length(), 0);
        assert_eq!(n(1).bit_length(), 1);
        assert_eq!(n(255).bit_length(), 8);
        assert_eq!(n(256).bit_length(), 9);
        assert_eq!(BigUint::one().shl(1000).bit_length(), 1001);
        let v = n(0b1010);
        assert!(!v.bit(0));
        assert!(v.bit(1));
        assert!(!v.bit(2));
        assert!(v.bit(3));
        assert!(!v.bit(100));
    }

    #[test]
    fn squaremod_matches_mulmod() {
        let mut rng = DeterministicRng::seed(17);
        for bits in [33usize, 64, 65, 127, 256, 1024] {
            let m = BigUint::random_bits(bits, &mut rng).add_u64(1);
            let a = BigUint::random_below(&m, &mut rng);
            assert_eq!(a.squaremod(&m), a.mulmod(&a, &m), "bits={}", bits);
        }
    }

    #[test]
    fn mont_sqr_matches_mont_mul() {
        let mut rng = DeterministicRng::seed(31);
        for bits in [64usize, 65, 127, 192, 512, 1024, 2048] {
            let mut m = BigUint::random_bits(bits, &mut rng);
            if m.is_even() {
                m = m.add_u64(1);
            }
            let ctx = MontgomeryCtx::new(&m);
            for _ in 0..4 {
                let a = BigUint::random_below(&m, &mut rng);
                let am = ctx.to_mont(&a);
                assert_eq!(
                    ctx.mont_sqr(&am),
                    ctx.mont_mul(&am, &am),
                    "bits={}",
                    bits
                );
            }
            // Edge: a = 0 and a = m-1 (largest residue).
            let zero = vec![0u64; ctx.n.len()];
            assert_eq!(ctx.mont_sqr(&zero), ctx.mont_mul(&zero, &zero));
            let top = ctx.to_mont(&m.sub_u64(1));
            assert_eq!(ctx.mont_sqr(&top), ctx.mont_mul(&top, &top));
        }
    }

    #[test]
    fn native_ctx_matches_modpow() {
        use crate::crypto::backend::ModContext;
        let mut rng = DeterministicRng::seed(77);
        // Odd (Montgomery) and even (plain) moduli through the same ctx API.
        for want_even in [false, true] {
            let mut m = BigUint::random_bits(160, &mut rng);
            if m.is_even() != want_even {
                m = m.add_u64(1);
            }
            let ctx = NativeCtx::new(&m);
            for _ in 0..3 {
                let b = BigUint::random_below(&m, &mut rng);
                let e = BigUint::random_bits(40, &mut rng);
                assert_eq!(ctx.modpow(&b, &e), b.modpow(&e, &m));
            }
            assert_eq!(ctx.modulus(), &m);
        }
    }

    #[test]
    fn random_below_in_range() {
        let mut rng = DeterministicRng::seed(1);
        let bound = n(1000);
        for _ in 0..100 {
            let v = BigUint::random_below(&bound, &mut rng);
            assert!(v.lt(&bound));
        }
    }

    #[test]
    fn random_bits_exact() {
        let mut rng = DeterministicRng::seed(2);
        for bits in [1usize, 7, 8, 64, 65, 1024] {
            let v = BigUint::random_bits(bits, &mut rng);
            assert_eq!(v.bit_length(), bits);
        }
    }
}
