//! Vendored port of the `num-bigint-dig` arithmetic surface.
//!
//! The offline build has no crate cache, so the "fast build" backend the
//! `bigint-dig` feature selects cannot pull the real crate. Instead this
//! module carries a dependency-free port of the crate's arithmetic
//! surface ([`RefUint`]): **u32** limbs (the crate's default digit on
//! 32-bit targets), schoolbook multiplication, Knuth Algorithm-D
//! division over u32 digits, and plain binary square-and-multiply
//! modexp. Every algorithm choice is deliberately *different* from
//! [`super::bigint::BigUint`] (u64 limbs, Karatsuba, Montgomery CIOS
//! with a squaring specialization) so the differential suite in
//! `tests/crypto_differential.rs` compares two genuinely independent
//! code paths — a carry bug in one cannot mask the same bug in the
//! other.
//!
//! The module is compiled unconditionally: differential tests need both
//! backends in one binary. The `bigint-dig` cargo feature only switches
//! [`crate::crypto::backend::DefaultBig`] so the whole protocol stack —
//! RSA chains, §5.8 pre-negotiated keys, BON pairwise masks — runs on
//! this backend instead. When a crate cache is available, the real
//! `num-bigint-dig` can replace [`RefUint`] behind the same [`DigBig`]
//! impl without touching any caller.

use std::cmp::Ordering;

/// Unsigned big integer, little-endian `u32` limbs, no leading zero
/// limbs (zero is an empty limb vector). Mirrors the public surface of
/// [`super::bigint::BigUint`] so `crate::crypto::Int` call sites compile
/// against either type.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct RefUint {
    limbs: Vec<u32>,
}

impl RefUint {
    pub fn zero() -> Self {
        RefUint { limbs: vec![] }
    }

    pub fn one() -> Self {
        RefUint { limbs: vec![1] }
    }

    pub fn from_u64(v: u64) -> Self {
        let mut b = RefUint { limbs: vec![v as u32, (v >> 32) as u32] };
        b.trim();
        b
    }

    /// From big-endian bytes.
    pub fn from_bytes_be(bytes: &[u8]) -> Self {
        let mut limbs = Vec::with_capacity(bytes.len() / 4 + 1);
        for chunk in bytes.rchunks(4) {
            let mut limb = 0u32;
            for &b in chunk {
                limb = (limb << 8) | b as u32;
            }
            limbs.push(limb);
        }
        let mut v = RefUint { limbs };
        v.trim();
        v
    }

    /// To big-endian bytes (minimal length; zero → empty).
    pub fn to_bytes_be(&self) -> Vec<u8> {
        if self.is_zero() {
            return vec![];
        }
        let mut out = Vec::with_capacity(self.limbs.len() * 4);
        for (i, &limb) in self.limbs.iter().enumerate().rev() {
            let bytes = limb.to_be_bytes();
            if i == self.limbs.len() - 1 {
                let skip = bytes.iter().take_while(|&&b| b == 0).count();
                out.extend_from_slice(&bytes[skip..]);
            } else {
                out.extend_from_slice(&bytes);
            }
        }
        out
    }

    /// To big-endian bytes, left-padded with zeros to exactly `len`.
    pub fn to_bytes_be_padded(&self, len: usize) -> Vec<u8> {
        let raw = self.to_bytes_be();
        assert!(raw.len() <= len, "value too large for padded length");
        let mut out = vec![0u8; len - raw.len()];
        out.extend_from_slice(&raw);
        out
    }

    /// Parse a hex string (no 0x prefix).
    pub fn from_hex(s: &str) -> anyhow::Result<Self> {
        let s: String = s.chars().filter(|c| !c.is_whitespace()).collect();
        let s = if s.len() % 2 == 1 { format!("0{}", s) } else { s };
        Ok(Self::from_bytes_be(&crate::util::hex_decode(&s)?))
    }

    pub fn to_hex(&self) -> String {
        if self.is_zero() {
            return "0".to_string();
        }
        crate::util::hex_encode(&self.to_bytes_be())
            .trim_start_matches('0')
            .to_string()
    }

    pub fn is_zero(&self) -> bool {
        self.limbs.is_empty()
    }

    pub fn is_one(&self) -> bool {
        self.limbs.len() == 1 && self.limbs[0] == 1
    }

    pub fn is_even(&self) -> bool {
        self.limbs.first().map_or(true, |l| l & 1 == 0)
    }

    /// Number of significant bits.
    pub fn bit_length(&self) -> usize {
        match self.limbs.last() {
            None => 0,
            Some(&top) => (self.limbs.len() - 1) * 32 + (32 - top.leading_zeros() as usize),
        }
    }

    /// Test bit `i` (0 = LSB).
    pub fn bit(&self, i: usize) -> bool {
        let limb = i / 32;
        let off = i % 32;
        self.limbs.get(limb).map_or(false, |l| (l >> off) & 1 == 1)
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self.limbs.len() {
            0 => Some(0),
            1 => Some(self.limbs[0] as u64),
            2 => Some(self.limbs[0] as u64 | ((self.limbs[1] as u64) << 32)),
            _ => None,
        }
    }

    fn trim(&mut self) {
        while self.limbs.last() == Some(&0) {
            self.limbs.pop();
        }
    }

    pub fn cmp(&self, other: &RefUint) -> Ordering {
        if self.limbs.len() != other.limbs.len() {
            return self.limbs.len().cmp(&other.limbs.len());
        }
        for i in (0..self.limbs.len()).rev() {
            match self.limbs[i].cmp(&other.limbs[i]) {
                Ordering::Equal => continue,
                o => return o,
            }
        }
        Ordering::Equal
    }

    pub fn lt(&self, other: &RefUint) -> bool {
        self.cmp(other) == Ordering::Less
    }

    pub fn ge(&self, other: &RefUint) -> bool {
        self.cmp(other) != Ordering::Less
    }

    pub fn add(&self, other: &RefUint) -> RefUint {
        let (long, short) = if self.limbs.len() >= other.limbs.len() {
            (&self.limbs, &other.limbs)
        } else {
            (&other.limbs, &self.limbs)
        };
        let mut out = Vec::with_capacity(long.len() + 1);
        let mut carry = 0u64;
        for i in 0..long.len() {
            let b = short.get(i).copied().unwrap_or(0);
            let s = long[i] as u64 + b as u64 + carry;
            out.push(s as u32);
            carry = s >> 32;
        }
        if carry > 0 {
            out.push(carry as u32);
        }
        let mut v = RefUint { limbs: out };
        v.trim();
        v
    }

    pub fn add_u64(&self, v: u64) -> RefUint {
        self.add(&RefUint::from_u64(v))
    }

    /// self - other; panics if other > self.
    pub fn sub(&self, other: &RefUint) -> RefUint {
        assert!(self.ge(other), "RefUint::sub underflow");
        let mut out = Vec::with_capacity(self.limbs.len());
        let mut borrow = 0i64;
        for i in 0..self.limbs.len() {
            let b = other.limbs.get(i).copied().unwrap_or(0);
            let d = self.limbs[i] as i64 - b as i64 + borrow;
            out.push(d as u32);
            borrow = d >> 32;
        }
        debug_assert_eq!(borrow, 0);
        let mut v = RefUint { limbs: out };
        v.trim();
        v
    }

    pub fn sub_u64(&self, v: u64) -> RefUint {
        self.sub(&RefUint::from_u64(v))
    }

    /// Schoolbook multiplication only — no Karatsuba, on purpose (see the
    /// module doc on algorithm diversity).
    pub fn mul(&self, other: &RefUint) -> RefUint {
        if self.is_zero() || other.is_zero() {
            return RefUint::zero();
        }
        let mut out = vec![0u32; self.limbs.len() + other.limbs.len()];
        for (i, &a) in self.limbs.iter().enumerate() {
            if a == 0 {
                continue;
            }
            let mut carry = 0u64;
            for (j, &b) in other.limbs.iter().enumerate() {
                let cur = out[i + j] as u64 + (a as u64) * (b as u64) + carry;
                out[i + j] = cur as u32;
                carry = cur >> 32;
            }
            let mut k = i + other.limbs.len();
            while carry > 0 {
                let cur = out[k] as u64 + carry;
                out[k] = cur as u32;
                carry = cur >> 32;
                k += 1;
            }
        }
        let mut v = RefUint { limbs: out };
        v.trim();
        v
    }

    /// Left shift by `bits`.
    pub fn shl(&self, bits: usize) -> RefUint {
        if self.is_zero() {
            return RefUint::zero();
        }
        let limb_shift = bits / 32;
        let bit_shift = bits % 32;
        let mut limbs = vec![0u32; limb_shift];
        if bit_shift == 0 {
            limbs.extend_from_slice(&self.limbs);
        } else {
            let mut carry = 0u32;
            for &l in &self.limbs {
                limbs.push((l << bit_shift) | carry);
                carry = l >> (32 - bit_shift);
            }
            if carry > 0 {
                limbs.push(carry);
            }
        }
        let mut v = RefUint { limbs };
        v.trim();
        v
    }

    /// Right shift by `bits`.
    pub fn shr(&self, bits: usize) -> RefUint {
        let limb_shift = bits / 32;
        if limb_shift >= self.limbs.len() {
            return RefUint::zero();
        }
        let bit_shift = bits % 32;
        let src = &self.limbs[limb_shift..];
        let mut limbs = Vec::with_capacity(src.len());
        if bit_shift == 0 {
            limbs.extend_from_slice(src);
        } else {
            for i in 0..src.len() {
                let hi = src.get(i + 1).copied().unwrap_or(0);
                limbs.push((src[i] >> bit_shift) | (hi << (32 - bit_shift)));
            }
        }
        let mut v = RefUint { limbs };
        v.trim();
        v
    }

    /// Division with remainder — Knuth Algorithm D over u32 digits (the
    /// native backend runs the same algorithm over u64 digits, so the two
    /// exercise different normalization shifts and q̂-correction paths).
    pub fn div_rem(&self, divisor: &RefUint) -> (RefUint, RefUint) {
        assert!(!divisor.is_zero(), "division by zero");
        if self.lt(divisor) {
            return (RefUint::zero(), self.clone());
        }
        if divisor.limbs.len() == 1 {
            let (q, r) = self.div_rem_u32(divisor.limbs[0]);
            return (q, RefUint::from_u64(r as u64));
        }
        let shift = divisor.limbs.last().unwrap().leading_zeros() as usize;
        let u = self.shl(shift);
        let v = divisor.shl(shift);
        let n = v.limbs.len();
        let m = u.limbs.len() - n;
        let mut un = u.limbs.clone();
        un.push(0);
        let vn = &v.limbs;
        let mut q = vec![0u32; m + 1];

        let vtop = vn[n - 1] as u64;
        let vsecond = vn[n - 2] as u64;

        for j in (0..=m).rev() {
            // Estimate q̂ = (u[j+n]·B + u[j+n-1]) / v[n-1]
            let num = ((un[j + n] as u64) << 32) | (un[j + n - 1] as u64);
            let mut qhat = num / vtop;
            let mut rhat = num % vtop;
            while qhat >= (1u64 << 32)
                || qhat * vsecond > ((rhat << 32) | (un[j + n - 2] as u64))
            {
                qhat -= 1;
                rhat += vtop;
                if rhat >= (1u64 << 32) {
                    break;
                }
            }
            // Multiply-subtract: u[j..j+n] -= q̂ * v
            let mut borrow = 0i64;
            let mut carry = 0u64;
            for i in 0..n {
                let p = qhat * (vn[i] as u64) + carry;
                carry = p >> 32;
                let sub = (un[j + i] as i64) - ((p as u32) as i64) + borrow;
                un[j + i] = sub as u32;
                borrow = sub >> 32;
            }
            let sub = (un[j + n] as i64) - (carry as i64) + borrow;
            un[j + n] = sub as u32;
            borrow = sub >> 32;

            q[j] = qhat as u32;
            if borrow < 0 {
                // q̂ was one too large: add v back.
                q[j] -= 1;
                let mut carry = 0u64;
                for i in 0..n {
                    let s = (un[j + i] as u64) + (vn[i] as u64) + carry;
                    un[j + i] = s as u32;
                    carry = s >> 32;
                }
                un[j + n] = un[j + n].wrapping_add(carry as u32);
            }
        }

        let mut quot = RefUint { limbs: q };
        quot.trim();
        let mut rem = RefUint { limbs: un[..n].to_vec() };
        rem.trim();
        (quot, rem.shr(shift))
    }

    fn div_rem_u32(&self, d: u32) -> (RefUint, u32) {
        assert!(d != 0, "division by zero");
        let mut out = vec![0u32; self.limbs.len()];
        let mut rem = 0u64;
        for i in (0..self.limbs.len()).rev() {
            let cur = (rem << 32) | self.limbs[i] as u64;
            out[i] = (cur / d as u64) as u32;
            rem = cur % d as u64;
        }
        let mut q = RefUint { limbs: out };
        q.trim();
        (q, rem as u32)
    }

    pub fn div_rem_u64(&self, d: u64) -> (RefUint, u64) {
        assert!(d != 0, "division by zero");
        if d <= u32::MAX as u64 {
            let (q, r) = self.div_rem_u32(d as u32);
            return (q, r as u64);
        }
        let (q, r) = self.div_rem(&RefUint::from_u64(d));
        (q, r.as_u64().expect("remainder below a u64 divisor fits u64"))
    }

    pub fn rem(&self, m: &RefUint) -> RefUint {
        self.div_rem(m).1
    }

    /// (self + other) mod m — inputs must already be < m.
    pub fn addmod(&self, other: &RefUint, m: &RefUint) -> RefUint {
        let s = self.add(other);
        if s.ge(m) {
            s.sub(m)
        } else {
            s
        }
    }

    /// (self - other) mod m — inputs must already be < m.
    pub fn submod(&self, other: &RefUint, m: &RefUint) -> RefUint {
        if self.ge(other) {
            self.sub(other)
        } else {
            self.add(m).sub(other)
        }
    }

    pub fn mulmod(&self, other: &RefUint, m: &RefUint) -> RefUint {
        self.mul(other).rem(m)
    }

    /// Modular exponentiation: plain right-to-left binary
    /// square-and-multiply, every modulus parity — no Montgomery, no
    /// window (see the module doc on algorithm diversity).
    pub fn modpow(&self, exp: &RefUint, modulus: &RefUint) -> RefUint {
        assert!(!modulus.is_zero(), "modpow: zero modulus");
        if modulus.is_one() {
            return RefUint::zero();
        }
        let mut base = self.rem(modulus);
        let mut result = RefUint::one();
        for i in 0..exp.bit_length() {
            if exp.bit(i) {
                result = result.mulmod(&base, modulus);
            }
            base = base.mulmod(&base, modulus);
        }
        result
    }

    pub fn gcd(&self, other: &RefUint) -> RefUint {
        let mut a = self.clone();
        let mut b = other.clone();
        while !b.is_zero() {
            let r = a.rem(&b);
            a = b;
            b = r;
        }
        a
    }

    /// Modular inverse via extended Euclid. Returns None if gcd != 1.
    pub fn modinv(&self, m: &RefUint) -> Option<RefUint> {
        let mut r0 = m.clone();
        let mut r1 = self.rem(m);
        let mut t0: (bool, RefUint) = (false, RefUint::zero());
        let mut t1: (bool, RefUint) = (false, RefUint::one());
        while !r1.is_zero() {
            let (q, r2) = r0.div_rem(&r1);
            let qt = q.mul(&t1.1);
            let t2 = signed_sub(t0.clone(), (t1.0, qt));
            r0 = r1;
            r1 = r2;
            t0 = t1;
            t1 = t2;
        }
        if !r0.is_one() {
            return None;
        }
        let inv = if t0.0 {
            m.sub(&t0.1.rem(m))
        } else {
            t0.1.rem(m)
        };
        Some(inv.rem(m))
    }

    /// Uniform random integer in [0, bound) using rejection sampling.
    /// Byte-for-byte the same draw pattern as the native backend (see the
    /// canonical-randomness note in `backend.rs`).
    pub fn random_below(bound: &RefUint, rng: &mut dyn crate::crypto::rng::SecureRng) -> RefUint {
        <DigBig as crate::crypto::backend::Big>::random_below(bound, rng)
    }

    /// Random integer with exactly `bits` bits (MSB set).
    pub fn random_bits(bits: usize, rng: &mut dyn crate::crypto::rng::SecureRng) -> RefUint {
        <DigBig as crate::crypto::backend::Big>::random_bits(bits, rng)
    }
}

/// (sign, magnitude) subtraction: a - b.
fn signed_sub(a: (bool, RefUint), b: (bool, RefUint)) -> (bool, RefUint) {
    match (a.0, b.0) {
        (false, true) => (false, a.1.add(&b.1)),
        (true, false) => (true, a.1.add(&b.1)),
        (false, false) => {
            if a.1.ge(&b.1) {
                (false, a.1.sub(&b.1))
            } else {
                (true, b.1.sub(&a.1))
            }
        }
        (true, true) => {
            if b.1.ge(&a.1) {
                (false, b.1.sub(&a.1))
            } else {
                (true, a.1.sub(&b.1))
            }
        }
    }
}

/// Per-modulus context for the reference backend. There is no Montgomery
/// state to amortize — the context just pins the modulus so generic code
/// that batches exponentiations through [`ModContext`] stays correct
/// (and measurably slower, which is exactly what the per-backend bench
/// rows in `BENCH_scale.json` exist to show).
#[derive(Clone)]
pub struct DigCtx {
    modulus: RefUint,
}

impl crate::crypto::backend::ModContext<RefUint> for DigCtx {
    fn modulus(&self) -> &RefUint {
        &self.modulus
    }

    fn modpow(&self, base: &RefUint, exp: &RefUint) -> RefUint {
        base.modpow(exp, &self.modulus)
    }
}

/// The vendored reference backend (`num-bigint-dig` surface).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DigBig;

impl crate::crypto::backend::Big for DigBig {
    type Num = RefUint;
    type Ctx = DigCtx;

    const NAME: &'static str = "bigint-dig";

    fn zero() -> RefUint {
        RefUint::zero()
    }
    fn one() -> RefUint {
        RefUint::one()
    }
    fn from_u64(v: u64) -> RefUint {
        RefUint::from_u64(v)
    }
    fn as_u64(n: &RefUint) -> Option<u64> {
        n.as_u64()
    }
    fn from_bytes_be(bytes: &[u8]) -> RefUint {
        RefUint::from_bytes_be(bytes)
    }
    fn to_bytes_be(n: &RefUint) -> Vec<u8> {
        n.to_bytes_be()
    }
    fn from_hex(s: &str) -> anyhow::Result<RefUint> {
        RefUint::from_hex(s)
    }
    fn to_hex(n: &RefUint) -> String {
        n.to_hex()
    }
    fn is_zero(n: &RefUint) -> bool {
        n.is_zero()
    }
    fn is_one(n: &RefUint) -> bool {
        n.is_one()
    }
    fn is_even(n: &RefUint) -> bool {
        n.is_even()
    }
    fn bit_length(n: &RefUint) -> usize {
        n.bit_length()
    }
    fn bit(n: &RefUint, i: usize) -> bool {
        n.bit(i)
    }
    fn cmp(a: &RefUint, b: &RefUint) -> Ordering {
        a.cmp(b)
    }
    fn add(a: &RefUint, b: &RefUint) -> RefUint {
        a.add(b)
    }
    fn sub(a: &RefUint, b: &RefUint) -> RefUint {
        a.sub(b)
    }
    fn mul(a: &RefUint, b: &RefUint) -> RefUint {
        a.mul(b)
    }
    fn div_rem(a: &RefUint, b: &RefUint) -> (RefUint, RefUint) {
        a.div_rem(b)
    }
    fn div_rem_u64(a: &RefUint, d: u64) -> (RefUint, u64) {
        a.div_rem_u64(d)
    }
    fn modinv(a: &RefUint, m: &RefUint) -> Option<RefUint> {
        a.modinv(m)
    }
    fn gcd(a: &RefUint, b: &RefUint) -> RefUint {
        a.gcd(b)
    }
    fn modpow(base: &RefUint, exp: &RefUint, m: &RefUint) -> RefUint {
        base.modpow(exp, m)
    }
    fn ctx(modulus: &RefUint) -> DigCtx {
        assert!(!modulus.is_zero(), "zero modulus");
        DigCtx { modulus: modulus.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::bigint::BigUint;
    use crate::crypto::rng::DeterministicRng;

    fn n(v: u64) -> RefUint {
        RefUint::from_u64(v)
    }

    /// Native value with the same big-endian bytes.
    fn to_native(v: &RefUint) -> BigUint {
        BigUint::from_bytes_be(&v.to_bytes_be())
    }

    #[test]
    fn bytes_and_hex_roundtrip() {
        let cases: Vec<Vec<u8>> = vec![
            vec![],
            vec![1],
            vec![0xff; 4],
            vec![1, 0, 0, 0, 0], // 2^32
            (1..=17).collect(),
        ];
        for c in cases {
            let v = RefUint::from_bytes_be(&c);
            let stripped: Vec<u8> = c.iter().copied().skip_while(|&b| b == 0).collect();
            assert_eq!(v.to_bytes_be(), stripped);
            assert_eq!(RefUint::from_hex(&v.to_hex()).unwrap(), v);
        }
        assert_eq!(n(0xdead_beef_0011_2233).to_hex(), "deadbeef00112233");
    }

    #[test]
    fn u64_boundaries() {
        for v in [0u64, 1, u32::MAX as u64, u32::MAX as u64 + 1, u64::MAX] {
            assert_eq!(n(v).as_u64(), Some(v));
        }
        assert_eq!(n(u64::MAX).add_u64(1).as_u64(), None);
        assert_eq!(n(u64::MAX).bit_length(), 64);
        assert_eq!(n(u32::MAX as u64 + 1).bit_length(), 33);
    }

    #[test]
    fn arithmetic_matches_native() {
        let mut rng = DeterministicRng::seed(123);
        for bits in [16usize, 31, 32, 33, 64, 65, 257, 1024] {
            let a = RefUint::random_bits(bits, &mut rng);
            let b = RefUint::random_bits(bits / 2 + 1, &mut rng);
            let (na, nb) = (to_native(&a), to_native(&b));
            assert_eq!(a.add(&b).to_bytes_be(), na.add(&nb).to_bytes_be());
            assert_eq!(a.mul(&b).to_bytes_be(), na.mul(&nb).to_bytes_be());
            let (q, r) = a.mul(&b).add(&a).div_rem(&b);
            let (nq, nr) = na.mul(&nb).add(&na).div_rem(&nb);
            assert_eq!(q.to_bytes_be(), nq.to_bytes_be(), "bits={}", bits);
            assert_eq!(r.to_bytes_be(), nr.to_bytes_be(), "bits={}", bits);
        }
    }

    #[test]
    fn known_division() {
        // 2^64 / (2^32 + 1) = 2^32 - 1 rem 1
        let a = RefUint::one().shl(64);
        let b = n((1u64 << 32) + 1);
        let (q, r) = a.div_rem(&b);
        assert_eq!(q, n(u32::MAX as u64));
        assert_eq!(r, n(1));
        // u64-divisor path above u32::MAX
        let (q2, r2) = a.div_rem_u64((1u64 << 32) + 1);
        assert_eq!(q2, n(u32::MAX as u64));
        assert_eq!(r2, 1);
    }

    #[test]
    fn modpow_small_and_fermat() {
        assert_eq!(n(3).modpow(&n(4), &n(7)), n(4));
        assert_eq!(n(5).modpow(&n(0), &n(11)), n(1));
        assert_eq!(n(3).modpow(&n(5), &n(100)), n(43)); // even modulus
        let p = n(1_000_000_007);
        for a in [2u64, 3, 12345] {
            assert_eq!(n(a).modpow(&p.sub_u64(1), &p), n(1));
        }
    }

    #[test]
    fn modinv_and_gcd() {
        let m = n(1_000_000_007);
        for a in [2u64, 3, 999, 123456] {
            let inv = n(a).modinv(&m).unwrap();
            assert_eq!(n(a).mulmod(&inv, &m), n(1));
        }
        assert!(n(6).modinv(&n(9)).is_none());
        assert_eq!(n(48).gcd(&n(18)), n(6));
    }

    #[test]
    fn shifts_roundtrip() {
        let a = RefUint::from_hex("123456789abcdef0123456789abcdef").unwrap();
        assert_eq!(a.shl(32).shr(32), a);
        assert_eq!(a.shl(3).shr(3), a);
        assert_eq!(a.shl(63).shr(63), a);
    }
}
