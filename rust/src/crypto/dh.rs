//! Diffie–Hellman key agreement over the RFC 3526 2048-bit MODP group.
//!
//! This is a BON-baseline substrate: Bonawitz et al. Round 0 has every
//! client advertise two DH public keys (c_u^PK for pairwise channel
//! encryption, s_u^PK for pairwise mask agreement). The shared secret is
//! hashed to a 32-byte seed used as a PRG seed / symmetric key.

use once_cell::sync::Lazy;
use sha2::{Digest, Sha256};

use super::bigint::BigUint;
use super::rng::SecureRng;

/// RFC 3526 group 14 prime (2048-bit MODP), generator g = 2.
const MODP_2048_HEX: &str = concat!(
    "FFFFFFFFFFFFFFFFC90FDAA22168C234C4C6628B80DC1CD1",
    "29024E088A67CC74020BBEA63B139B22514A08798E3404DD",
    "EF9519B3CD3A431B302B0A6DF25F14374FE1356D6D51C245",
    "E485B576625E7EC6F44C42E9A637ED6B0BFF5CB6F406B7ED",
    "EE386BFB5A899FA5AE9F24117C4B1FE649286651ECE45B3D",
    "C2007CB8A163BF0598DA48361C55D39A69163FA8FD24CF5F",
    "83655D23DCA3AD961C62F356208552BB9ED529077096966D",
    "670C354E4ABC9804F1746C08CA18217C32905E462E36CE3B",
    "E39E772C180E86039B2783A2EC07A28FB5C55DF06F4C52C9",
    "DE2BCBF6955817183995497CEA956AE515D2261898FA0510",
    "15728E5A8AACAA68FFFFFFFFFFFFFFFF"
);

static MODP_2048: Lazy<BigUint> =
    Lazy::new(|| BigUint::from_hex(MODP_2048_HEX).expect("constant prime parses"));

/// A DH group (prime modulus + generator). `standard()` is the production
/// group; `small_for_tests` trades security for speed in unit tests.
#[derive(Debug, Clone)]
pub struct DhGroup {
    pub p: BigUint,
    pub g: BigUint,
    /// Private exponent size in bits (256 is plenty for a 2048-bit group).
    pub exp_bits: usize,
}

impl DhGroup {
    pub fn standard() -> Self {
        DhGroup { p: MODP_2048.clone(), g: BigUint::from_u64(2), exp_bits: 256 }
    }

    /// A 256-bit random group for fast tests (NOT secure).
    pub fn small_for_tests(rng: &mut dyn SecureRng) -> Self {
        let p = super::prime::gen_prime_3mod4(256, rng);
        DhGroup { p, g: BigUint::from_u64(2), exp_bits: 128 }
    }
}

/// A DH keypair within a group.
#[derive(Debug, Clone)]
pub struct DhKeyPair {
    pub secret: BigUint,
    pub public: BigUint,
}

impl DhKeyPair {
    pub fn generate(group: &DhGroup, rng: &mut dyn SecureRng) -> Self {
        let secret = BigUint::random_bits(group.exp_bits, rng);
        let public = group.g.modpow(&secret, &group.p);
        DhKeyPair { secret, public }
    }

    /// Compute the shared secret with a peer's public value and hash it to
    /// a 32-byte seed.
    pub fn agree(&self, group: &DhGroup, peer_public: &BigUint) -> [u8; 32] {
        let shared = peer_public.modpow(&self.secret, &group.p);
        let mut h = Sha256::new();
        h.update(b"safe-dh-kdf");
        h.update(shared.to_bytes_be());
        h.finalize().into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::DeterministicRng;

    #[test]
    fn agreement_is_symmetric_small_group() {
        let mut rng = DeterministicRng::seed(1);
        let group = DhGroup::small_for_tests(&mut rng);
        let a = DhKeyPair::generate(&group, &mut rng);
        let b = DhKeyPair::generate(&group, &mut rng);
        assert_eq!(a.agree(&group, &b.public), b.agree(&group, &a.public));
    }

    #[test]
    fn different_peers_different_secrets() {
        let mut rng = DeterministicRng::seed(2);
        let group = DhGroup::small_for_tests(&mut rng);
        let a = DhKeyPair::generate(&group, &mut rng);
        let b = DhKeyPair::generate(&group, &mut rng);
        let c = DhKeyPair::generate(&group, &mut rng);
        assert_ne!(a.agree(&group, &b.public), a.agree(&group, &c.public));
    }

    #[test]
    fn standard_group_loads_and_agrees() {
        let mut rng = DeterministicRng::seed(3);
        let group = DhGroup::standard();
        assert_eq!(group.p.bit_length(), 2048);
        let a = DhKeyPair::generate(&group, &mut rng);
        let b = DhKeyPair::generate(&group, &mut rng);
        assert_eq!(a.agree(&group, &b.public), b.agree(&group, &a.public));
    }
}
