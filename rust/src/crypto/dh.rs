//! Diffie–Hellman key agreement over the RFC 3526 2048-bit MODP group.
//!
//! This is a BON-baseline substrate: Bonawitz et al. Round 0 has every
//! client advertise two DH public keys (c_u^PK for pairwise channel
//! encryption, s_u^PK for pairwise mask agreement). The shared secret is
//! hashed to a 32-byte seed used as a PRG seed / symmetric key.
//!
//! Generic over the [`Big`] backend. A node agreeing with many peers
//! shares one exponentiation context for the group modulus
//! ([`DhGroup::ctx`] + [`DhKeyPair::agree_with`]): on the native backend
//! that amortizes the Montgomery setup across all n-1 pairwise
//! agreements of BON round 0.

use sha2::{Digest, Sha256};

use super::backend::{Big, DefaultBig, ModContext};
use super::rng::SecureRng;

/// RFC 3526 group 14 prime (2048-bit MODP), generator g = 2. Public so
/// the differential/KAT suite can pin it as a fixture.
pub const MODP_2048_HEX: &str = concat!(
    "FFFFFFFFFFFFFFFFC90FDAA22168C234C4C6628B80DC1CD1",
    "29024E088A67CC74020BBEA63B139B22514A08798E3404DD",
    "EF9519B3CD3A431B302B0A6DF25F14374FE1356D6D51C245",
    "E485B576625E7EC6F44C42E9A637ED6B0BFF5CB6F406B7ED",
    "EE386BFB5A899FA5AE9F24117C4B1FE649286651ECE45B3D",
    "C2007CB8A163BF0598DA48361C55D39A69163FA8FD24CF5F",
    "83655D23DCA3AD961C62F356208552BB9ED529077096966D",
    "670C354E4ABC9804F1746C08CA18217C32905E462E36CE3B",
    "E39E772C180E86039B2783A2EC07A28FB5C55DF06F4C52C9",
    "DE2BCBF6955817183995497CEA956AE515D2261898FA0510",
    "15728E5A8AACAA68FFFFFFFFFFFFFFFF"
);

/// A DH group (prime modulus + generator). `standard()` is the production
/// group; `small_for_tests` trades security for speed in unit tests.
#[derive(Debug, Clone)]
pub struct DhGroup<B: Big = DefaultBig> {
    pub p: B::Num,
    pub g: B::Num,
    /// Private exponent size in bits (256 is plenty for a 2048-bit group).
    pub exp_bits: usize,
}

impl<B: Big> DhGroup<B> {
    pub fn standard() -> Self {
        let p = B::from_hex(MODP_2048_HEX).expect("constant prime parses");
        DhGroup { p, g: B::from_u64(2), exp_bits: 256 }
    }

    /// A 256-bit random group for fast tests (NOT secure).
    pub fn small_for_tests(rng: &mut dyn SecureRng) -> Self {
        let p = super::prime::gen_prime_3mod4::<B>(256, rng);
        DhGroup { p, g: B::from_u64(2), exp_bits: 128 }
    }

    /// Reusable exponentiation context for the group modulus — build once
    /// per node, share across every keygen/agreement in the group.
    pub fn ctx(&self) -> B::Ctx {
        B::ctx(&self.p)
    }
}

/// A DH keypair within a group.
#[derive(Debug, Clone)]
pub struct DhKeyPair<B: Big = DefaultBig> {
    pub secret: B::Num,
    pub public: B::Num,
}

impl<B: Big> DhKeyPair<B> {
    pub fn generate(group: &DhGroup<B>, rng: &mut dyn SecureRng) -> Self {
        Self::generate_with(&group.ctx(), group, rng)
    }

    /// Like [`Self::generate`] but reusing a prebuilt group context.
    pub fn generate_with(ctx: &B::Ctx, group: &DhGroup<B>, rng: &mut dyn SecureRng) -> Self {
        let secret = B::random_bits(group.exp_bits, rng);
        let public = ctx.modpow(&group.g, &secret);
        DhKeyPair { secret, public }
    }

    /// Compute the shared secret with a peer's public value and hash it to
    /// a 32-byte seed.
    pub fn agree(&self, group: &DhGroup<B>, peer_public: &B::Num) -> [u8; 32] {
        self.agree_with(&group.ctx(), peer_public)
    }

    /// Like [`Self::agree`] but reusing a prebuilt group context — the
    /// BON round-0 path calls this once per peer with one shared context.
    pub fn agree_with(&self, ctx: &B::Ctx, peer_public: &B::Num) -> [u8; 32] {
        let shared = ctx.modpow(peer_public, &self.secret);
        let mut h = Sha256::new();
        h.update(b"safe-dh-kdf");
        h.update(B::to_bytes_be(&shared));
        h.finalize().into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::backend::NativeBig;
    use crate::crypto::bigint_dig::DigBig;
    use crate::crypto::rng::DeterministicRng;

    #[test]
    fn agreement_is_symmetric_small_group() {
        let mut rng = DeterministicRng::seed(1);
        let group = DhGroup::<DefaultBig>::small_for_tests(&mut rng);
        let a = DhKeyPair::generate(&group, &mut rng);
        let b = DhKeyPair::generate(&group, &mut rng);
        assert_eq!(a.agree(&group, &b.public), b.agree(&group, &a.public));
    }

    #[test]
    fn different_peers_different_secrets() {
        let mut rng = DeterministicRng::seed(2);
        let group = DhGroup::<DefaultBig>::small_for_tests(&mut rng);
        let a = DhKeyPair::generate(&group, &mut rng);
        let b = DhKeyPair::generate(&group, &mut rng);
        let c = DhKeyPair::generate(&group, &mut rng);
        assert_ne!(a.agree(&group, &b.public), a.agree(&group, &c.public));
    }

    #[test]
    fn standard_group_loads_and_agrees() {
        let mut rng = DeterministicRng::seed(3);
        let group = DhGroup::<DefaultBig>::standard();
        assert_eq!(DefaultBig::bit_length(&group.p), 2048);
        let a = DhKeyPair::generate(&group, &mut rng);
        let b = DhKeyPair::generate(&group, &mut rng);
        assert_eq!(a.agree(&group, &b.public), b.agree(&group, &a.public));
    }

    #[test]
    fn shared_ctx_matches_per_call_ctx() {
        let mut rng = DeterministicRng::seed(4);
        let group = DhGroup::<DefaultBig>::small_for_tests(&mut rng);
        let ctx = group.ctx();
        let a = DhKeyPair::generate_with(&ctx, &group, &mut rng);
        let b = DhKeyPair::generate_with(&ctx, &group, &mut rng);
        assert_eq!(a.agree_with(&ctx, &b.public), a.agree(&group, &b.public));
    }

    #[test]
    fn backends_agree_on_standard_group() {
        // Same seed ⇒ same secret bytes ⇒ same public value and shared
        // seed on both backends over the RFC 3526 fixture.
        let ga = DhGroup::<NativeBig>::standard();
        let gb = DhGroup::<DigBig>::standard();
        let a1 = DhKeyPair::generate(&ga, &mut DeterministicRng::seed(5));
        let b1 = DhKeyPair::generate(&gb, &mut DeterministicRng::seed(5));
        assert_eq!(
            NativeBig::to_bytes_be(&a1.public),
            DigBig::to_bytes_be(&b1.public)
        );
        let a2 = DhKeyPair::generate(&ga, &mut DeterministicRng::seed(6));
        let b2 = DhKeyPair::generate(&gb, &mut DeterministicRng::seed(6));
        assert_eq!(a1.agree(&ga, &a2.public), b1.agree(&gb, &b2.public));
    }
}
