//! Payload envelopes — how a learner protects an aggregate for the next
//! node on the chain.
//!
//! Four modes, exactly the paper's design space:
//!  * [`CipherMode::None`] — the **SAF** variant (§6: "with (SAFE) and
//!    without (SAF) encryption"). Payload is the serialized vector.
//!  * [`CipherMode::RsaOnly`] — every byte RSA-encrypted in k−11 chunks.
//!    Kept as an ablation; this is what §5.7 calls too slow for large
//!    payloads.
//!  * [`CipherMode::Hybrid`] — **SAFE** (§5.7): random AES key sealed with
//!    the receiver's RSA public key; payload DEFLATE-compressed then
//!    AES-CTR+HMAC sealed. Compression is why SAFE beats INSEC at large
//!    feature counts (§6.2).
//!  * [`CipherMode::PreNegotiated`] — §5.8: payload sealed with a symmetric
//!    key agreed out-of-band; no RSA on the aggregation path at all
//!    (the deep-edge/OpenWrt configuration).
//!
//! Vectors are serialized as little-endian f64 (8 bytes/feature) — compact
//! and exact, mirroring the paper's opaque-JSON-payload contract.

use anyhow::{bail, Context, Result};

use super::aescipher::SymmetricKey;
use super::rng::SecureRng;
use super::rsa::{RsaDecryptCtx, RsaPrivateKey, RsaPublicKey};
use crate::blob::Blob;

// Deflate helpers live in `util` (shared with the codec-layer
// `CompressedCodec` wrapper); re-exported here for the existing callers.
pub use crate::util::{compress, decompress};

/// Which protection to apply to chain payloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CipherMode {
    /// No encryption (paper's SAF).
    None,
    /// Chunked RSA over the whole payload (pre-§5.7 strawman, ablation).
    RsaOnly,
    /// RSA-sealed AES key + compressed AES payload (paper's SAFE, §5.7).
    Hybrid,
    /// Pre-negotiated symmetric key (§5.8, deep-edge devices).
    PreNegotiated,
}

impl CipherMode {
    pub fn name(&self) -> &'static str {
        match self {
            CipherMode::None => "saf",
            CipherMode::RsaOnly => "rsa",
            CipherMode::Hybrid => "safe",
            CipherMode::PreNegotiated => "prenegotiated",
        }
    }
}

/// Serialize an f64 vector as little-endian bytes.
pub fn vec_to_bytes(v: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 8);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Deserialize little-endian f64 bytes.
pub fn bytes_to_vec(b: &[u8]) -> Result<Vec<f64>> {
    if b.len() % 8 != 0 {
        bail!("payload length {} not a multiple of 8", b.len());
    }
    Ok(b
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Wire envelope: mode tag + sealed key + opaque body. On the wire it is a
/// [`Blob`] in the compact binary framing of [`Envelope::to_blob`] (raw
/// ciphertext, no base64 — the codec layer base64s only at a JSON
/// boundary); the legacy `mode:keyB64:bodyB64` text form remains for
/// paper-parity tooling. Either way the controller never inspects it —
/// §6.2 "the aggregation payload is opaque to the controller".
#[derive(Debug, Clone, PartialEq)]
pub struct Envelope {
    pub mode: CipherMode,
    /// For Hybrid: RSA-sealed symmetric key.
    pub sealed_key: Vec<u8>,
    /// Payload bytes (possibly sealed/compressed per mode).
    pub body: Vec<u8>,
}

impl Envelope {
    /// Protect `vector` for the holder of `recipient` / `preneg` key.
    pub fn seal(
        vector: &[f64],
        mode: CipherMode,
        recipient: Option<&RsaPublicKey>,
        preneg: Option<&SymmetricKey>,
        compress_payload: bool,
        rng: &mut dyn SecureRng,
    ) -> Result<Envelope> {
        let raw = vec_to_bytes(vector);
        match mode {
            CipherMode::None => {
                // SAF sends cleartext — and like the paper's bash/python
                // clients, the cleartext wire format is JSON float text
                // (larger than binary; §6.2's compression argument).
                let body = crate::json::Value::from(vector).to_string().into_bytes();
                Ok(Envelope { mode, sealed_key: vec![], body })
            }
            CipherMode::RsaOnly => {
                let pk = recipient.context("RsaOnly mode requires recipient public key")?;
                Ok(Envelope { mode, sealed_key: vec![], body: pk.encrypt_blob(&raw, rng)? })
            }
            CipherMode::Hybrid => {
                let pk = recipient.context("Hybrid mode requires recipient public key")?;
                let key = SymmetricKey::generate(rng);
                let sealed_key = pk.encrypt_block(&key.master, rng)?;
                let payload = if compress_payload { compress(&raw) } else { raw };
                let mut body = Vec::with_capacity(payload.len() + 49);
                body.push(compress_payload as u8);
                body.extend_from_slice(&key.seal(&payload, rng));
                Ok(Envelope { mode, sealed_key, body })
            }
            CipherMode::PreNegotiated => {
                let key = preneg.context("PreNegotiated mode requires a shared key")?;
                let payload = if compress_payload { compress(&raw) } else { raw };
                let mut body = Vec::with_capacity(payload.len() + 49);
                body.push(compress_payload as u8);
                body.extend_from_slice(&key.seal(&payload, rng));
                Ok(Envelope { mode, sealed_key: vec![], body })
            }
        }
    }

    /// Recover the vector using our private / pre-negotiated key.
    pub fn open(
        &self,
        our_key: Option<&RsaPrivateKey>,
        preneg: Option<&SymmetricKey>,
    ) -> Result<Vec<f64>> {
        match self.mode {
            CipherMode::None => {
                let text = std::str::from_utf8(&self.body).context("SAF body not UTF-8")?;
                let v = crate::json::parse(text)?;
                v.as_arr()
                    .context("SAF body not an array")?
                    .iter()
                    .map(|e| e.as_f64().context("SAF element not a number"))
                    .collect()
            }
            CipherMode::RsaOnly => {
                let sk = our_key.context("RsaOnly envelope requires our private key")?;
                bytes_to_vec(&sk.decrypt_blob(&self.body)?)
            }
            CipherMode::Hybrid => {
                let sk = our_key.context("Hybrid envelope requires our private key")?;
                let master = sk.decrypt_block(&self.sealed_key)?;
                let key = SymmetricKey::from_bytes(&master)?;
                self.open_symmetric(&key)
            }
            CipherMode::PreNegotiated => {
                let key = preneg.context("PreNegotiated envelope requires the shared key")?;
                self.open_symmetric(key)
            }
        }
    }

    /// Like [`Envelope::open`] but with a prebuilt [`RsaDecryptCtx`], so a
    /// node opening a stream of envelopes (one per round, per chain hop)
    /// pays the CRT Montgomery setup once instead of per envelope.
    pub fn open_with(
        &self,
        dec: Option<&RsaDecryptCtx>,
        preneg: Option<&SymmetricKey>,
    ) -> Result<Vec<f64>> {
        match self.mode {
            CipherMode::None | CipherMode::PreNegotiated => self.open(None, preneg),
            CipherMode::RsaOnly => {
                let dec = dec.context("RsaOnly envelope requires our private key")?;
                bytes_to_vec(&dec.decrypt_blob(&self.body)?)
            }
            CipherMode::Hybrid => {
                let dec = dec.context("Hybrid envelope requires our private key")?;
                let master = dec.decrypt_block(&self.sealed_key)?;
                let key = SymmetricKey::from_bytes(&master)?;
                self.open_symmetric(&key)
            }
        }
    }

    fn open_symmetric(&self, key: &SymmetricKey) -> Result<Vec<f64>> {
        if self.body.is_empty() {
            bail!("empty envelope body");
        }
        let compressed = self.body[0] != 0;
        let payload = key.open(&self.body[1..])?;
        let raw = if compressed { decompress(&payload)? } else { payload };
        bytes_to_vec(&raw)
    }

    /// Legacy text encoding (the paper's JSON `aggregate` field):
    /// `mode:keyB64:bodyB64`.
    pub fn encode(&self) -> String {
        format!(
            "{}:{}:{}",
            self.mode.name(),
            crate::util::b64_encode(&self.sealed_key),
            crate::util::b64_encode(&self.body)
        )
    }

    pub fn decode(s: &str) -> Result<Envelope> {
        let mut parts = s.splitn(3, ':');
        let mode = match parts.next().context("missing mode")? {
            "saf" => CipherMode::None,
            "rsa" => CipherMode::RsaOnly,
            "safe" => CipherMode::Hybrid,
            "prenegotiated" => CipherMode::PreNegotiated,
            other => bail!("unknown envelope mode {:?}", other),
        };
        let sealed_key = crate::util::b64_decode(parts.next().context("missing key part")?)?;
        let body = crate::util::b64_decode(parts.next().context("missing body part")?)?;
        Ok(Envelope { mode, sealed_key, body })
    }

    /// One-byte mode tag for the binary framing. Values stay below 0x20 so
    /// a framed blob can never be confused with the text encoding (whose
    /// first byte is an ASCII mode letter).
    fn mode_tag(&self) -> u8 {
        match self.mode {
            CipherMode::None => 0,
            CipherMode::RsaOnly => 1,
            CipherMode::Hybrid => 2,
            CipherMode::PreNegotiated => 3,
        }
    }

    /// Compact binary framing: `mode tag + varint key length + sealed key
    /// + body` (the body runs to the end of the blob — no length needed).
    /// This is the raw ciphertext framing the wire carries: zero base64,
    /// ~3 bytes of header on top of the ciphertext itself.
    pub fn to_blob(&self) -> Blob {
        let mut out = Vec::with_capacity(1 + 5 + self.sealed_key.len() + self.body.len());
        out.push(self.mode_tag());
        crate::util::write_varint(self.sealed_key.len() as u64, &mut out);
        out.extend_from_slice(&self.sealed_key);
        out.extend_from_slice(&self.body);
        Blob::new(out)
    }

    /// Parse either wire form: the binary framing of [`Envelope::to_blob`]
    /// (first byte is a sub-0x20 mode tag) or the legacy UTF-8 text
    /// encoding (first byte is an ASCII letter).
    pub fn from_blob(blob: &Blob) -> Result<Envelope> {
        let b = blob.as_bytes();
        match b.first() {
            None => bail!("empty envelope blob"),
            Some(&tag) if tag < 0x20 => {
                let mode = match tag {
                    0 => CipherMode::None,
                    1 => CipherMode::RsaOnly,
                    2 => CipherMode::Hybrid,
                    3 => CipherMode::PreNegotiated,
                    other => bail!("unknown envelope mode tag {other:#x}"),
                };
                let mut pos = 1usize;
                let key_len = crate::util::read_varint(b, &mut pos)
                    .context("envelope key length")? as usize;
                if key_len > b.len() - pos {
                    bail!(
                        "envelope key length {key_len} exceeds remaining {} bytes",
                        b.len() - pos
                    );
                }
                let sealed_key = b[pos..pos + key_len].to_vec();
                let body = b[pos + key_len..].to_vec();
                Ok(Envelope { mode, sealed_key, body })
            }
            _ => Envelope::decode(
                std::str::from_utf8(b).context("text envelope not UTF-8")?,
            ),
        }
    }

    /// Wire size in bytes of the legacy text encoding — computed
    /// arithmetically (base64 is ⌈n/3⌉·4 per part plus the mode word and
    /// two colons), never by materializing the encoding just to measure it.
    pub fn wire_len(&self) -> usize {
        fn b64_len(n: usize) -> usize {
            (n + 2) / 3 * 4
        }
        self.mode.name().len() + 2 + b64_len(self.sealed_key.len()) + b64_len(self.body.len())
    }

    /// Wire size in bytes of the binary framing of [`Envelope::to_blob`].
    pub fn blob_len(&self) -> usize {
        1 + crate::util::varint_len(self.sealed_key.len() as u64)
            + self.sealed_key.len()
            + self.body.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::DeterministicRng;
    use crate::crypto::rsa::RsaKeyPair;

    fn vecf(n: usize) -> Vec<f64> {
        (0..n).map(|i| (i as f64) * 0.5 - 3.0).collect()
    }

    #[test]
    fn vec_bytes_roundtrip() {
        let v = vec![0.0, -1.5, f64::MAX, f64::MIN_POSITIVE, 1e-300];
        assert_eq!(bytes_to_vec(&vec_to_bytes(&v)).unwrap(), v);
        assert!(bytes_to_vec(&[1, 2, 3]).is_err());
    }

    #[test]
    fn compression_roundtrip_and_shrinks_redundant() {
        let data = vec![42u8; 10_000];
        let c = compress(&data);
        assert!(c.len() < data.len() / 10);
        assert_eq!(decompress(&c).unwrap(), data);
    }

    #[test]
    fn saf_mode_roundtrip() {
        let mut rng = DeterministicRng::seed(1);
        let v = vecf(17);
        let env = Envelope::seal(&v, CipherMode::None, None, None, false, &mut rng).unwrap();
        assert_eq!(env.open(None, None).unwrap(), v);
    }

    #[test]
    fn hybrid_mode_roundtrip() {
        let mut rng = DeterministicRng::seed(2);
        let kp = RsaKeyPair::generate(512, &mut rng);
        let v = vecf(100);
        let env =
            Envelope::seal(&v, CipherMode::Hybrid, Some(&kp.public), None, true, &mut rng).unwrap();
        assert_eq!(env.open(Some(&kp.private), None).unwrap(), v);
        // Encoded roundtrip too.
        let enc = env.encode();
        let dec = Envelope::decode(&enc).unwrap();
        assert_eq!(dec.open(Some(&kp.private), None).unwrap(), v);
    }

    #[test]
    fn rsa_only_mode_roundtrip() {
        let mut rng = DeterministicRng::seed(3);
        let kp = RsaKeyPair::generate(512, &mut rng);
        let v = vecf(40); // forces multiple RSA blocks at 512-bit modulus
        let env =
            Envelope::seal(&v, CipherMode::RsaOnly, Some(&kp.public), None, false, &mut rng)
                .unwrap();
        assert_eq!(env.open(Some(&kp.private), None).unwrap(), v);
    }

    #[test]
    fn preneg_mode_roundtrip() {
        let mut rng = DeterministicRng::seed(4);
        let key = SymmetricKey::generate(&mut rng);
        let v = vecf(33);
        let env =
            Envelope::seal(&v, CipherMode::PreNegotiated, None, Some(&key), true, &mut rng)
                .unwrap();
        assert_eq!(env.open(None, Some(&key)).unwrap(), v);
    }

    #[test]
    fn open_with_cached_ctx_matches_open() {
        let mut rng = DeterministicRng::seed(14);
        let kp = RsaKeyPair::generate(512, &mut rng);
        let dec = kp.private.decrypt_ctx();
        let v = vecf(50);
        for (mode, compress) in
            [(CipherMode::RsaOnly, false), (CipherMode::Hybrid, true), (CipherMode::None, false)]
        {
            let env = Envelope::seal(&v, mode, Some(&kp.public), None, compress, &mut rng).unwrap();
            assert_eq!(
                env.open_with(Some(&dec), None).unwrap(),
                env.open(Some(&kp.private), None).unwrap(),
                "{mode:?}"
            );
        }
    }

    #[test]
    fn hybrid_rejects_wrong_private_key() {
        let mut rng = DeterministicRng::seed(5);
        let kp1 = RsaKeyPair::generate(512, &mut rng);
        let kp2 = RsaKeyPair::generate(512, &mut rng);
        let v = vecf(10);
        let env =
            Envelope::seal(&v, CipherMode::Hybrid, Some(&kp1.public), None, true, &mut rng)
                .unwrap();
        assert!(env.open(Some(&kp2.private), None).is_err());
    }

    #[test]
    fn missing_key_material_errors() {
        let mut rng = DeterministicRng::seed(6);
        let v = vecf(3);
        assert!(Envelope::seal(&v, CipherMode::Hybrid, None, None, true, &mut rng).is_err());
        assert!(Envelope::seal(&v, CipherMode::PreNegotiated, None, None, true, &mut rng).is_err());
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Envelope::decode("not-an-envelope").is_err());
        assert!(Envelope::decode("bogus:AA==:AA==").is_err());
    }

    #[test]
    fn blob_framing_roundtrips_all_modes() {
        let mut rng = DeterministicRng::seed(11);
        let kp = RsaKeyPair::generate(512, &mut rng);
        let key = SymmetricKey::generate(&mut rng);
        let v = vecf(64);
        for (mode, pk, sym) in [
            (CipherMode::None, None, None),
            (CipherMode::RsaOnly, Some(&kp.public), None),
            (CipherMode::Hybrid, Some(&kp.public), None),
            (CipherMode::PreNegotiated, None, Some(&key)),
        ] {
            let env = Envelope::seal(&v, mode, pk, sym, true, &mut rng).unwrap();
            let blob = env.to_blob();
            let back = Envelope::from_blob(&blob).unwrap();
            assert_eq!(back, env, "{mode:?} framing roundtrip");
            assert_eq!(blob.len(), env.blob_len(), "{mode:?} blob_len");
            // And the framed envelope still opens.
            assert_eq!(back.open(Some(&kp.private), Some(&key)).unwrap(), v);
        }
    }

    #[test]
    fn from_blob_accepts_legacy_text_encoding() {
        let mut rng = DeterministicRng::seed(12);
        let kp = RsaKeyPair::generate(512, &mut rng);
        let v = vecf(8);
        let env =
            Envelope::seal(&v, CipherMode::Hybrid, Some(&kp.public), None, true, &mut rng)
                .unwrap();
        let text_blob = crate::blob::Blob::new(env.encode().into_bytes());
        assert_eq!(Envelope::from_blob(&text_blob).unwrap(), env);
        // Garbage is rejected either way.
        assert!(Envelope::from_blob(&crate::blob::Blob::empty()).is_err());
        assert!(Envelope::from_blob(&crate::blob::Blob::from_slice(&[9, 0])).is_err());
        assert!(Envelope::from_blob(&crate::blob::Blob::from_slice(b"bogus:AA==:AA==")).is_err());
        // Truncated binary framing: declared key length exceeds the blob.
        assert!(Envelope::from_blob(&crate::blob::Blob::from_slice(&[2, 50, 1, 2])).is_err());
    }

    #[test]
    fn blob_framing_beats_text_by_a_third() {
        // The point of raw framing: the text form pays 4/3 base64 on both
        // parts; the binary form pays a ~3-byte header.
        let mut rng = DeterministicRng::seed(13);
        let kp = RsaKeyPair::generate(512, &mut rng);
        let v = vecf(1024);
        let env =
            Envelope::seal(&v, CipherMode::Hybrid, Some(&kp.public), None, false, &mut rng)
                .unwrap();
        assert!(
            env.blob_len() * 4 <= env.wire_len() * 3 + 64,
            "blob {} vs text {}",
            env.blob_len(),
            env.wire_len()
        );
    }

    #[test]
    fn wire_len_is_arithmetic_not_materialized() {
        // Exercise every length-mod-3 combination of key/body.
        for key_len in 0..5usize {
            for body_len in [0usize, 1, 2, 3, 47, 48, 49, 1000] {
                let env = Envelope {
                    mode: CipherMode::Hybrid,
                    sealed_key: vec![0xab; key_len],
                    body: vec![0xcd; body_len],
                };
                assert_eq!(
                    env.wire_len(),
                    env.encode().len(),
                    "key={key_len} body={body_len}"
                );
            }
        }
    }

    #[test]
    fn hybrid_compression_beats_uncompressed_for_large_vectors() {
        // The §6.2 claim: encryption-with-compression shrinks big payloads.
        let mut rng = DeterministicRng::seed(7);
        let kp = RsaKeyPair::generate(512, &mut rng);
        let v = vec![1.0f64; 10_000];
        let comp =
            Envelope::seal(&v, CipherMode::Hybrid, Some(&kp.public), None, true, &mut rng).unwrap();
        let raw =
            Envelope::seal(&v, CipherMode::Hybrid, Some(&kp.public), None, false, &mut rng)
                .unwrap();
        assert!(comp.wire_len() < raw.wire_len() / 4);
    }
}
