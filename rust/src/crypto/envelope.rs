//! Payload envelopes — how a learner protects an aggregate for the next
//! node on the chain.
//!
//! Four modes, exactly the paper's design space:
//!  * [`CipherMode::None`] — the **SAF** variant (§6: "with (SAFE) and
//!    without (SAF) encryption"). Payload is the serialized vector.
//!  * [`CipherMode::RsaOnly`] — every byte RSA-encrypted in k−11 chunks.
//!    Kept as an ablation; this is what §5.7 calls too slow for large
//!    payloads.
//!  * [`CipherMode::Hybrid`] — **SAFE** (§5.7): random AES key sealed with
//!    the receiver's RSA public key; payload DEFLATE-compressed then
//!    AES-CTR+HMAC sealed. Compression is why SAFE beats INSEC at large
//!    feature counts (§6.2).
//!  * [`CipherMode::PreNegotiated`] — §5.8: payload sealed with a symmetric
//!    key agreed out-of-band; no RSA on the aggregation path at all
//!    (the deep-edge/OpenWrt configuration).
//!
//! Vectors are serialized as little-endian f64 (8 bytes/feature) — compact
//! and exact, mirroring the paper's opaque-JSON-payload contract.

use anyhow::{bail, Context, Result};
use flate2::read::DeflateDecoder;
use flate2::write::DeflateEncoder;
use flate2::Compression;
use std::io::{Read, Write};

use super::aescipher::SymmetricKey;
use super::rng::SecureRng;
use super::rsa::{RsaPrivateKey, RsaPublicKey};

/// Which protection to apply to chain payloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CipherMode {
    /// No encryption (paper's SAF).
    None,
    /// Chunked RSA over the whole payload (pre-§5.7 strawman, ablation).
    RsaOnly,
    /// RSA-sealed AES key + compressed AES payload (paper's SAFE, §5.7).
    Hybrid,
    /// Pre-negotiated symmetric key (§5.8, deep-edge devices).
    PreNegotiated,
}

impl CipherMode {
    pub fn name(&self) -> &'static str {
        match self {
            CipherMode::None => "saf",
            CipherMode::RsaOnly => "rsa",
            CipherMode::Hybrid => "safe",
            CipherMode::PreNegotiated => "prenegotiated",
        }
    }
}

/// Serialize an f64 vector as little-endian bytes.
pub fn vec_to_bytes(v: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 8);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Deserialize little-endian f64 bytes.
pub fn bytes_to_vec(b: &[u8]) -> Result<Vec<f64>> {
    if b.len() % 8 != 0 {
        bail!("payload length {} not a multiple of 8", b.len());
    }
    Ok(b
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

pub fn compress(data: &[u8]) -> Vec<u8> {
    let mut enc = DeflateEncoder::new(Vec::new(), Compression::fast());
    enc.write_all(data).expect("in-memory deflate cannot fail");
    enc.finish().expect("in-memory deflate cannot fail")
}

pub fn decompress(data: &[u8]) -> Result<Vec<u8>> {
    let mut dec = DeflateDecoder::new(data);
    let mut out = Vec::new();
    dec.read_to_end(&mut out).context("deflate decompression failed")?;
    Ok(out)
}

/// Wire envelope: mode tag + opaque body, carried as base64 inside the JSON
/// `aggregate` field (the controller never inspects it — §6.2 "the
/// aggregation payload is opaque to the controller").
#[derive(Debug, Clone, PartialEq)]
pub struct Envelope {
    pub mode: CipherMode,
    /// For Hybrid: RSA-sealed symmetric key.
    pub sealed_key: Vec<u8>,
    /// Payload bytes (possibly sealed/compressed per mode).
    pub body: Vec<u8>,
}

impl Envelope {
    /// Protect `vector` for the holder of `recipient` / `preneg` key.
    pub fn seal(
        vector: &[f64],
        mode: CipherMode,
        recipient: Option<&RsaPublicKey>,
        preneg: Option<&SymmetricKey>,
        compress_payload: bool,
        rng: &mut dyn SecureRng,
    ) -> Result<Envelope> {
        let raw = vec_to_bytes(vector);
        match mode {
            CipherMode::None => {
                // SAF sends cleartext — and like the paper's bash/python
                // clients, the cleartext wire format is JSON float text
                // (larger than binary; §6.2's compression argument).
                let body = crate::json::Value::from(vector).to_string().into_bytes();
                Ok(Envelope { mode, sealed_key: vec![], body })
            }
            CipherMode::RsaOnly => {
                let pk = recipient.context("RsaOnly mode requires recipient public key")?;
                Ok(Envelope { mode, sealed_key: vec![], body: pk.encrypt_blob(&raw, rng)? })
            }
            CipherMode::Hybrid => {
                let pk = recipient.context("Hybrid mode requires recipient public key")?;
                let key = SymmetricKey::generate(rng);
                let sealed_key = pk.encrypt_block(&key.master, rng)?;
                let payload = if compress_payload { compress(&raw) } else { raw };
                let mut body = Vec::with_capacity(payload.len() + 49);
                body.push(compress_payload as u8);
                body.extend_from_slice(&key.seal(&payload, rng));
                Ok(Envelope { mode, sealed_key, body })
            }
            CipherMode::PreNegotiated => {
                let key = preneg.context("PreNegotiated mode requires a shared key")?;
                let payload = if compress_payload { compress(&raw) } else { raw };
                let mut body = Vec::with_capacity(payload.len() + 49);
                body.push(compress_payload as u8);
                body.extend_from_slice(&key.seal(&payload, rng));
                Ok(Envelope { mode, sealed_key: vec![], body })
            }
        }
    }

    /// Recover the vector using our private / pre-negotiated key.
    pub fn open(
        &self,
        our_key: Option<&RsaPrivateKey>,
        preneg: Option<&SymmetricKey>,
    ) -> Result<Vec<f64>> {
        match self.mode {
            CipherMode::None => {
                let text = std::str::from_utf8(&self.body).context("SAF body not UTF-8")?;
                let v = crate::json::parse(text)?;
                v.as_arr()
                    .context("SAF body not an array")?
                    .iter()
                    .map(|e| e.as_f64().context("SAF element not a number"))
                    .collect()
            }
            CipherMode::RsaOnly => {
                let sk = our_key.context("RsaOnly envelope requires our private key")?;
                bytes_to_vec(&sk.decrypt_blob(&self.body)?)
            }
            CipherMode::Hybrid => {
                let sk = our_key.context("Hybrid envelope requires our private key")?;
                let master = sk.decrypt_block(&self.sealed_key)?;
                let key = SymmetricKey::from_bytes(&master)?;
                self.open_symmetric(&key)
            }
            CipherMode::PreNegotiated => {
                let key = preneg.context("PreNegotiated envelope requires the shared key")?;
                self.open_symmetric(key)
            }
        }
    }

    fn open_symmetric(&self, key: &SymmetricKey) -> Result<Vec<f64>> {
        if self.body.is_empty() {
            bail!("empty envelope body");
        }
        let compressed = self.body[0] != 0;
        let payload = key.open(&self.body[1..])?;
        let raw = if compressed { decompress(&payload)? } else { payload };
        bytes_to_vec(&raw)
    }

    /// Encode for the JSON `aggregate` field: `mode:keyB64:bodyB64`.
    pub fn encode(&self) -> String {
        format!(
            "{}:{}:{}",
            self.mode.name(),
            crate::util::b64_encode(&self.sealed_key),
            crate::util::b64_encode(&self.body)
        )
    }

    pub fn decode(s: &str) -> Result<Envelope> {
        let mut parts = s.splitn(3, ':');
        let mode = match parts.next().context("missing mode")? {
            "saf" => CipherMode::None,
            "rsa" => CipherMode::RsaOnly,
            "safe" => CipherMode::Hybrid,
            "prenegotiated" => CipherMode::PreNegotiated,
            other => bail!("unknown envelope mode {:?}", other),
        };
        let sealed_key = crate::util::b64_decode(parts.next().context("missing key part")?)?;
        let body = crate::util::b64_decode(parts.next().context("missing body part")?)?;
        Ok(Envelope { mode, sealed_key, body })
    }

    /// Wire size in bytes of the encoded envelope.
    pub fn wire_len(&self) -> usize {
        self.encode().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::DeterministicRng;
    use crate::crypto::rsa::RsaKeyPair;

    fn vecf(n: usize) -> Vec<f64> {
        (0..n).map(|i| (i as f64) * 0.5 - 3.0).collect()
    }

    #[test]
    fn vec_bytes_roundtrip() {
        let v = vec![0.0, -1.5, f64::MAX, f64::MIN_POSITIVE, 1e-300];
        assert_eq!(bytes_to_vec(&vec_to_bytes(&v)).unwrap(), v);
        assert!(bytes_to_vec(&[1, 2, 3]).is_err());
    }

    #[test]
    fn compression_roundtrip_and_shrinks_redundant() {
        let data = vec![42u8; 10_000];
        let c = compress(&data);
        assert!(c.len() < data.len() / 10);
        assert_eq!(decompress(&c).unwrap(), data);
    }

    #[test]
    fn saf_mode_roundtrip() {
        let mut rng = DeterministicRng::seed(1);
        let v = vecf(17);
        let env = Envelope::seal(&v, CipherMode::None, None, None, false, &mut rng).unwrap();
        assert_eq!(env.open(None, None).unwrap(), v);
    }

    #[test]
    fn hybrid_mode_roundtrip() {
        let mut rng = DeterministicRng::seed(2);
        let kp = RsaKeyPair::generate(512, &mut rng);
        let v = vecf(100);
        let env =
            Envelope::seal(&v, CipherMode::Hybrid, Some(&kp.public), None, true, &mut rng).unwrap();
        assert_eq!(env.open(Some(&kp.private), None).unwrap(), v);
        // Encoded roundtrip too.
        let enc = env.encode();
        let dec = Envelope::decode(&enc).unwrap();
        assert_eq!(dec.open(Some(&kp.private), None).unwrap(), v);
    }

    #[test]
    fn rsa_only_mode_roundtrip() {
        let mut rng = DeterministicRng::seed(3);
        let kp = RsaKeyPair::generate(512, &mut rng);
        let v = vecf(40); // forces multiple RSA blocks at 512-bit modulus
        let env =
            Envelope::seal(&v, CipherMode::RsaOnly, Some(&kp.public), None, false, &mut rng)
                .unwrap();
        assert_eq!(env.open(Some(&kp.private), None).unwrap(), v);
    }

    #[test]
    fn preneg_mode_roundtrip() {
        let mut rng = DeterministicRng::seed(4);
        let key = SymmetricKey::generate(&mut rng);
        let v = vecf(33);
        let env =
            Envelope::seal(&v, CipherMode::PreNegotiated, None, Some(&key), true, &mut rng)
                .unwrap();
        assert_eq!(env.open(None, Some(&key)).unwrap(), v);
    }

    #[test]
    fn hybrid_rejects_wrong_private_key() {
        let mut rng = DeterministicRng::seed(5);
        let kp1 = RsaKeyPair::generate(512, &mut rng);
        let kp2 = RsaKeyPair::generate(512, &mut rng);
        let v = vecf(10);
        let env =
            Envelope::seal(&v, CipherMode::Hybrid, Some(&kp1.public), None, true, &mut rng)
                .unwrap();
        assert!(env.open(Some(&kp2.private), None).is_err());
    }

    #[test]
    fn missing_key_material_errors() {
        let mut rng = DeterministicRng::seed(6);
        let v = vecf(3);
        assert!(Envelope::seal(&v, CipherMode::Hybrid, None, None, true, &mut rng).is_err());
        assert!(Envelope::seal(&v, CipherMode::PreNegotiated, None, None, true, &mut rng).is_err());
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Envelope::decode("not-an-envelope").is_err());
        assert!(Envelope::decode("bogus:AA==:AA==").is_err());
    }

    #[test]
    fn hybrid_compression_beats_uncompressed_for_large_vectors() {
        // The §6.2 claim: encryption-with-compression shrinks big payloads.
        let mut rng = DeterministicRng::seed(7);
        let kp = RsaKeyPair::generate(512, &mut rng);
        let v = vec![1.0f64; 10_000];
        let comp =
            Envelope::seal(&v, CipherMode::Hybrid, Some(&kp.public), None, true, &mut rng).unwrap();
        let raw =
            Envelope::seal(&v, CipherMode::Hybrid, Some(&kp.public), None, false, &mut rng)
                .unwrap();
        assert!(comp.wire_len() < raw.wire_len() / 4);
    }
}
