//! Cryptographic substrate for SAFE and the BON baseline.
//!
//! Everything here is built from scratch (or on the few RustCrypto
//! primitives present in the offline crate cache) because the sandbox has
//! no `rsa`, `num-bigint`, `ring`, or `openssl` equivalents:
//!
//! * [`backend`] — the pluggable [`backend::Big`] bignum-backend trait;
//!   `--features bigint-dig` swaps the default backend stack-wide.
//! * [`bigint`] — arbitrary-precision integers (Montgomery modpow), the
//!   zero-dependency default backend.
//! * [`bigint_dig`] — vendored `num-bigint-dig` surface (u32 limbs,
//!   schoolbook/binary algorithms), the differential reference backend.
//! * [`prime`] — Miller–Rabin and prime generation.
//! * [`rsa`] — RSA keygen / PKCS#1 v1.5 block + blob encryption (paper §4).
//! * [`aescipher`] — AES-256-CTR + HMAC-SHA256 envelope (paper §5.7).
//! * [`envelope`] — the four payload protection modes (SAF/RSA/SAFE/§5.8).
//! * [`dh`] — Diffie–Hellman (RFC 3526) for the BON baseline.
//! * [`shamir`] — t-of-n secret sharing over GF(2^61−1) for BON.
//! * [`rng`] — ChaCha20 CSPRNG, OS entropy, deterministic test RNG, and the
//!   PRG mask expansion BON uses.

pub mod aescipher;
pub mod backend;
pub mod bigint;
pub mod bigint_dig;
pub mod dh;
pub mod envelope;
pub mod prime;
pub mod rng;
pub mod rsa;
pub mod shamir;

pub use aescipher::SymmetricKey;
pub use backend::{Big, DefaultBig, Int, ModContext, NativeBig};
pub use bigint::BigUint;
pub use envelope::{CipherMode, Envelope};
pub use rng::{DeterministicRng, SecureRng, SystemRng};
pub use rsa::{RsaDecryptCtx, RsaEncryptCtx, RsaKeyPair, RsaPrivateKey, RsaPublicKey};
