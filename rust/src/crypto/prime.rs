//! Prime generation for RSA keygen: trial division + Miller–Rabin.
//!
//! Generic over the [`Big`] backend. The draw sequence — candidate bits,
//! then one `random_below(n-3)` per Miller–Rabin witness, 32 witnesses
//! per surviving candidate — is part of the cross-backend contract:
//! under a fixed seed every backend consumes the identical byte stream,
//! so keygen is byte-stable across backends (pinned by the regression in
//! `tests/crypto_differential.rs`). Don't reorder the draws.

use super::backend::{Big, ModContext};
use super::rng::SecureRng;

/// Small primes for fast trial-division pre-filtering.
const SMALL_PRIMES: [u64; 54] = [
    2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53, 59, 61, 67, 71, 73, 79, 83, 89,
    97, 101, 103, 107, 109, 113, 127, 131, 137, 139, 149, 151, 157, 163, 167, 173, 179, 181, 191,
    193, 197, 199, 211, 223, 227, 229, 233, 239, 241, 251,
];

/// Miller–Rabin probabilistic primality test with `rounds` random bases
/// drawn from the session RNG abstraction. For the key sizes we generate
/// (512–2048 bit primes) 32 rounds gives a failure probability < 2^-64.
///
/// One exponentiation context is built per candidate and shared by all
/// witness exponentiations and squarings — on the native backend that is
/// a single Montgomery setup for up to `rounds` modexps.
pub fn is_probable_prime<B: Big>(n: &B::Num, rounds: usize, rng: &mut dyn SecureRng) -> bool {
    if B::is_zero(n) || B::is_one(n) {
        return false;
    }
    if let Some(v) = B::as_u64(n) {
        if v < 4 {
            return v == 2 || v == 3;
        }
    }
    if B::is_even(n) {
        return false;
    }
    for &p in &SMALL_PRIMES {
        let pb = B::from_u64(p);
        if B::cmp(n, &pb) == std::cmp::Ordering::Equal {
            return true;
        }
        let (_, r) = B::div_rem_u64(n, p);
        if r == 0 {
            return false;
        }
    }
    // Write n-1 = d * 2^s with d odd.
    let n_minus_1 = B::sub_u64(n, 1);
    let mut d = n_minus_1.clone();
    let mut s = 0usize;
    while B::is_even(&d) {
        d = halve::<B>(&d);
        s += 1;
    }
    let two = B::from_u64(2);
    let n_minus_3 = B::sub_u64(n, 3);
    let ctx = B::ctx(n);
    'witness: for _ in 0..rounds {
        // a in [2, n-2]
        let a = B::add(&B::random_below(&n_minus_3, rng), &two);
        let mut x = ctx.modpow(&a, &d);
        if B::is_one(&x) || x == n_minus_1 {
            continue 'witness;
        }
        for _ in 0..s - 1 {
            x = ctx.modpow(&x, &two);
            if x == n_minus_1 {
                continue 'witness;
            }
        }
        return false;
    }
    true
}

/// `n / 2` for an even `n` (backends expose division, not shifts).
fn halve<B: Big>(n: &B::Num) -> B::Num {
    B::div_rem_u64(n, 2).0
}

/// Generate a random prime with exactly `bits` bits.
pub fn gen_prime<B: Big>(bits: usize, rng: &mut dyn SecureRng) -> B::Num {
    assert!(bits >= 16, "prime too small for RSA use");
    loop {
        let mut cand = B::random_bits(bits, rng);
        if B::is_even(&cand) {
            cand = B::add_u64(&cand, 1);
        }
        if is_probable_prime::<B>(&cand, 32, rng) {
            return cand;
        }
    }
}

/// Generate a "safe-ish" prime p where p ≡ 3 (mod 4); used for DH test
/// groups (production DH uses the fixed RFC 3526 group).
pub fn gen_prime_3mod4<B: Big>(bits: usize, rng: &mut dyn SecureRng) -> B::Num {
    loop {
        let p = gen_prime::<B>(bits, rng);
        let (_, r) = B::div_rem_u64(&p, 4);
        if r == 3 {
            return p;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::backend::NativeBig;
    use crate::crypto::bigint::BigUint;
    use crate::crypto::bigint_dig::DigBig;
    use crate::crypto::rng::DeterministicRng;

    #[test]
    fn small_primes_detected() {
        let mut rng = DeterministicRng::seed(1);
        for p in [2u64, 3, 5, 7, 11, 97, 251, 257, 65537, 1_000_000_007] {
            assert!(
                is_probable_prime::<NativeBig>(&BigUint::from_u64(p), 16, &mut rng),
                "{}",
                p
            );
        }
        for c in [0u64, 1, 4, 6, 9, 15, 91, 561, 65536, 1_000_000_000] {
            assert!(
                !is_probable_prime::<NativeBig>(&BigUint::from_u64(c), 16, &mut rng),
                "{}",
                c
            );
        }
    }

    #[test]
    fn carmichael_numbers_rejected() {
        let mut rng = DeterministicRng::seed(2);
        // 561, 1105, 1729, 2465, 2821, 6601 are Carmichael (fool Fermat).
        for c in [561u64, 1105, 1729, 2465, 2821, 6601, 8911] {
            assert!(
                !is_probable_prime::<NativeBig>(&BigUint::from_u64(c), 16, &mut rng),
                "{}",
                c
            );
        }
    }

    #[test]
    fn known_large_prime() {
        let mut rng = DeterministicRng::seed(3);
        // 2^127 - 1 is a Mersenne prime.
        let m127 = BigUint::one().shl(127).sub_u64(1);
        assert!(is_probable_prime::<NativeBig>(&m127, 16, &mut rng));
        // 2^128 - 1 is composite.
        let m128 = BigUint::one().shl(128).sub_u64(1);
        assert!(!is_probable_prime::<NativeBig>(&m128, 16, &mut rng));
    }

    #[test]
    fn gen_prime_has_exact_bits_and_is_odd() {
        let mut rng = DeterministicRng::seed(4);
        for bits in [64usize, 128, 256] {
            let p = gen_prime::<NativeBig>(bits, &mut rng);
            assert_eq!(p.bit_length(), bits);
            assert!(!p.is_even());
        }
    }

    #[test]
    fn gen_prime_is_seed_deterministic_and_backend_stable() {
        // Same seed ⇒ same prime; and both backends land on the same
        // bytes because every draw goes through canonical randomness.
        let p1 = gen_prime::<NativeBig>(128, &mut DeterministicRng::seed(5));
        let p2 = gen_prime::<NativeBig>(128, &mut DeterministicRng::seed(5));
        assert_eq!(p1, p2);
        let pd = gen_prime::<DigBig>(128, &mut DeterministicRng::seed(5));
        assert_eq!(p1.to_bytes_be(), pd.to_bytes_be());
    }
}
