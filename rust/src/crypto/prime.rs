//! Prime generation for RSA keygen: trial division + Miller–Rabin.

use super::bigint::BigUint;
use super::rng::SecureRng;

/// Small primes for fast trial-division pre-filtering.
const SMALL_PRIMES: [u64; 54] = [
    2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53, 59, 61, 67, 71, 73, 79, 83, 89,
    97, 101, 103, 107, 109, 113, 127, 131, 137, 139, 149, 151, 157, 163, 167, 173, 179, 181, 191,
    193, 197, 199, 211, 223, 227, 229, 233, 239, 241, 251,
];

/// Miller–Rabin probabilistic primality test with `rounds` random bases.
/// For the key sizes we generate (512–2048 bit primes) 32 rounds gives a
/// failure probability < 2^-64.
pub fn is_probable_prime(n: &BigUint, rounds: usize, rng: &mut dyn SecureRng) -> bool {
    if n.is_zero() || n.is_one() {
        return false;
    }
    if let Some(v) = n.as_u64() {
        if v < 4 {
            return v == 2 || v == 3;
        }
    }
    if n.is_even() {
        return false;
    }
    for &p in &SMALL_PRIMES {
        let pb = BigUint::from_u64(p);
        if n.cmp(&pb) == std::cmp::Ordering::Equal {
            return true;
        }
        let (_, r) = n.div_rem_u64(p);
        if r == 0 {
            return false;
        }
    }
    // Write n-1 = d * 2^s with d odd.
    let n_minus_1 = n.sub_u64(1);
    let mut d = n_minus_1.clone();
    let mut s = 0usize;
    while d.is_even() {
        d = d.shr(1);
        s += 1;
    }
    let two = BigUint::from_u64(2);
    let n_minus_3 = n.sub_u64(3);
    'witness: for _ in 0..rounds {
        // a in [2, n-2]
        let a = BigUint::random_below(&n_minus_3, rng).add(&two);
        let mut x = a.modpow(&d, n);
        if x.is_one() || x == n_minus_1 {
            continue 'witness;
        }
        for _ in 0..s - 1 {
            x = x.modpow(&two, n);
            if x == n_minus_1 {
                continue 'witness;
            }
        }
        return false;
    }
    true
}

/// Generate a random prime with exactly `bits` bits.
pub fn gen_prime(bits: usize, rng: &mut dyn SecureRng) -> BigUint {
    assert!(bits >= 16, "prime too small for RSA use");
    loop {
        let mut cand = BigUint::random_bits(bits, rng);
        if cand.is_even() {
            cand = cand.add_u64(1);
        }
        if is_probable_prime(&cand, 32, rng) {
            return cand;
        }
    }
}

/// Generate a "safe-ish" prime p where p ≡ 3 (mod 4); used for DH test
/// groups (production DH uses the fixed RFC 3526 group).
pub fn gen_prime_3mod4(bits: usize, rng: &mut dyn SecureRng) -> BigUint {
    loop {
        let p = gen_prime(bits, rng);
        let (_, r) = p.div_rem_u64(4);
        if r == 3 {
            return p;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::DeterministicRng;

    #[test]
    fn small_primes_detected() {
        let mut rng = DeterministicRng::seed(1);
        for p in [2u64, 3, 5, 7, 11, 97, 251, 257, 65537, 1_000_000_007] {
            assert!(is_probable_prime(&BigUint::from_u64(p), 16, &mut rng), "{}", p);
        }
        for c in [0u64, 1, 4, 6, 9, 15, 91, 561, 65536, 1_000_000_000] {
            assert!(!is_probable_prime(&BigUint::from_u64(c), 16, &mut rng), "{}", c);
        }
    }

    #[test]
    fn carmichael_numbers_rejected() {
        let mut rng = DeterministicRng::seed(2);
        // 561, 1105, 1729, 2465, 2821, 6601 are Carmichael (fool Fermat).
        for c in [561u64, 1105, 1729, 2465, 2821, 6601, 8911] {
            assert!(!is_probable_prime(&BigUint::from_u64(c), 16, &mut rng), "{}", c);
        }
    }

    #[test]
    fn known_large_prime() {
        let mut rng = DeterministicRng::seed(3);
        // 2^127 - 1 is a Mersenne prime.
        let m127 = BigUint::one().shl(127).sub_u64(1);
        assert!(is_probable_prime(&m127, 16, &mut rng));
        // 2^128 - 1 is composite.
        let m128 = BigUint::one().shl(128).sub_u64(1);
        assert!(!is_probable_prime(&m128, 16, &mut rng));
    }

    #[test]
    fn gen_prime_has_exact_bits_and_is_odd() {
        let mut rng = DeterministicRng::seed(4);
        for bits in [64usize, 128, 256] {
            let p = gen_prime(bits, &mut rng);
            assert_eq!(p.bit_length(), bits);
            assert!(!p.is_even());
        }
    }
}
