//! Random number generation: ChaCha20-based CSPRNG + OS entropy.
//!
//! Three layers:
//!  * [`ChaCha20Core`] — the raw ChaCha20 block function (RFC 8439), used as
//!    a PRG. BON expands pairwise/self-mask seeds into full mask vectors with
//!    it (paper §2: "PRG(s_{u,v})").
//!  * [`SystemRng`] — OS entropy (`/dev/urandom`, no crates), reseeding a
//!    ChaCha20 stream. Used for RSA/DH keygen and the SAFE initiator mask `R`.
//!  * [`DeterministicRng`] — seedable, for reproducible tests/benches.

/// Minimal trait so bigint/RSA can take any of our RNGs via dyn dispatch.
pub trait SecureRng {
    fn fill_bytes(&mut self, dest: &mut [u8]);

    fn next_u64(&mut self) -> u64 {
        let mut b = [0u8; 8];
        self.fill_bytes(&mut b);
        u64::from_le_bytes(b)
    }

    /// Uniform f64 in [0, 1).
    fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform usize in [0, bound).
    fn next_below(&mut self, bound: usize) -> usize {
        assert!(bound > 0);
        // Rejection sampling to avoid modulo bias.
        let zone = usize::MAX - (usize::MAX % bound);
        loop {
            let v = self.next_u64() as usize;
            if v < zone {
                return v % bound;
            }
        }
    }
}

/// The ChaCha20 block function (RFC 8439).
pub struct ChaCha20Core {
    state: [u32; 16],
    buf: [u8; 64],
    buf_pos: usize,
}

const CHACHA_CONST: [u32; 4] = [0x61707865, 0x3320646e, 0x79622d32, 0x6b206574];

impl ChaCha20Core {
    /// Create from a 32-byte key and 12-byte nonce.
    pub fn new(key: &[u8; 32], nonce: &[u8; 12]) -> Self {
        let mut state = [0u32; 16];
        state[..4].copy_from_slice(&CHACHA_CONST);
        for i in 0..8 {
            state[4 + i] = u32::from_le_bytes(key[i * 4..i * 4 + 4].try_into().unwrap());
        }
        state[12] = 0; // counter
        for i in 0..3 {
            state[13 + i] = u32::from_le_bytes(nonce[i * 4..i * 4 + 4].try_into().unwrap());
        }
        ChaCha20Core { state, buf: [0; 64], buf_pos: 64 }
    }

    /// Create from an arbitrary-length seed (hashed to key material).
    pub fn from_seed(seed: &[u8]) -> Self {
        use sha2::{Digest, Sha256};
        let key: [u8; 32] = Sha256::digest(seed).into();
        Self::new(&key, &[0u8; 12])
    }

    fn quarter_round(s: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize) {
        s[a] = s[a].wrapping_add(s[b]);
        s[d] = (s[d] ^ s[a]).rotate_left(16);
        s[c] = s[c].wrapping_add(s[d]);
        s[b] = (s[b] ^ s[c]).rotate_left(12);
        s[a] = s[a].wrapping_add(s[b]);
        s[d] = (s[d] ^ s[a]).rotate_left(8);
        s[c] = s[c].wrapping_add(s[d]);
        s[b] = (s[b] ^ s[c]).rotate_left(7);
    }

    fn refill(&mut self) {
        let mut working = self.state;
        for _ in 0..10 {
            // column rounds
            Self::quarter_round(&mut working, 0, 4, 8, 12);
            Self::quarter_round(&mut working, 1, 5, 9, 13);
            Self::quarter_round(&mut working, 2, 6, 10, 14);
            Self::quarter_round(&mut working, 3, 7, 11, 15);
            // diagonal rounds
            Self::quarter_round(&mut working, 0, 5, 10, 15);
            Self::quarter_round(&mut working, 1, 6, 11, 12);
            Self::quarter_round(&mut working, 2, 7, 8, 13);
            Self::quarter_round(&mut working, 3, 4, 9, 14);
        }
        for i in 0..16 {
            let v = working[i].wrapping_add(self.state[i]);
            self.buf[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
        }
        self.state[12] = self.state[12].wrapping_add(1);
        self.buf_pos = 0;
    }
}

impl SecureRng for ChaCha20Core {
    fn fill_bytes(&mut self, dest: &mut [u8]) {
        let mut written = 0;
        while written < dest.len() {
            if self.buf_pos >= 64 {
                self.refill();
            }
            let take = (64 - self.buf_pos).min(dest.len() - written);
            dest[written..written + take]
                .copy_from_slice(&self.buf[self.buf_pos..self.buf_pos + take]);
            self.buf_pos += take;
            written += take;
        }
    }
}

/// OS-seeded CSPRNG (`/dev/urandom` → ChaCha20 stream). Reading the
/// device through std keeps the crate dependency-free; if the device is
/// unavailable (exotic sandbox), fall back to hashing time + pid — good
/// enough to keep simulations running, never silently constant.
pub struct SystemRng {
    core: ChaCha20Core,
}

fn os_entropy(dest: &mut [u8]) {
    use std::io::Read;
    if let Ok(mut f) = std::fs::File::open("/dev/urandom") {
        if f.read_exact(dest).is_ok() {
            return;
        }
    }
    // Fallback: hash wall clock + monotonic-ish counter + pid.
    use sha2::{Digest, Sha256};
    let mut h = Sha256::new();
    let now = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap_or_default();
    h.update(now.as_nanos().to_le_bytes());
    h.update(std::process::id().to_le_bytes());
    h.update((dest.as_ptr() as usize).to_le_bytes()); // ASLR jitter
    let digest = h.finalize();
    for (i, b) in dest.iter_mut().enumerate() {
        *b = digest[i % digest.len()];
    }
}

impl SystemRng {
    pub fn new() -> Self {
        let mut key = [0u8; 32];
        let mut nonce = [0u8; 12];
        os_entropy(&mut key);
        os_entropy(&mut nonce);
        SystemRng { core: ChaCha20Core::new(&key, &nonce) }
    }
}

impl Default for SystemRng {
    fn default() -> Self {
        Self::new()
    }
}

impl SecureRng for SystemRng {
    fn fill_bytes(&mut self, dest: &mut [u8]) {
        self.core.fill_bytes(dest)
    }
}

/// Seedable deterministic RNG for tests and reproducible benchmarks.
pub struct DeterministicRng {
    core: ChaCha20Core,
}

impl DeterministicRng {
    pub fn seed(seed: u64) -> Self {
        DeterministicRng { core: ChaCha20Core::from_seed(&seed.to_le_bytes()) }
    }

    pub fn from_bytes(seed: &[u8]) -> Self {
        DeterministicRng { core: ChaCha20Core::from_seed(seed) }
    }
}

impl SecureRng for DeterministicRng {
    fn fill_bytes(&mut self, dest: &mut [u8]) {
        self.core.fill_bytes(dest)
    }
}

/// PRG expansion used by the BON baseline: expand a 32-byte seed into `n`
/// pseudo-random f64 mask values in a fixed range. Both parties expanding
/// the same seed get identical masks, so pairwise masks cancel.
pub fn prg_expand_f64(seed: &[u8], n: usize) -> Vec<f64> {
    let mut core = ChaCha20Core::from_seed(seed);
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        // Masks in [-2^20, 2^20): large relative to model weights but exact
        // in f64 so that masks cancel to the last bit when summed in the
        // same order.
        let v = core.next_u64() >> 32; // 32 bits
        let signed = v as i64 - (1i64 << 31);
        out.push(signed as f64 / 2048.0);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hex_encode;

    #[test]
    fn chacha20_rfc8439_vector() {
        // RFC 8439 §2.3.2 test vector: key = 00..1f, nonce 000000090000004a00000000,
        // counter=1. Our stream starts at counter 0 so skip the first block.
        let key: [u8; 32] = (0u8..32).collect::<Vec<_>>().try_into().unwrap();
        let nonce: [u8; 12] = [0, 0, 0, 9, 0, 0, 0, 0x4a, 0, 0, 0, 0];
        let mut core = ChaCha20Core::new(&key, &nonce);
        let mut block0 = [0u8; 64];
        core.fill_bytes(&mut block0);
        let mut block1 = [0u8; 64];
        core.fill_bytes(&mut block1);
        assert_eq!(
            hex_encode(&block1[..32]),
            "10f1e7e4d13b5915500fdd1fa32071c4c7d1f4c733c068030422aa9ac3d46c4e"
        );
    }

    #[test]
    fn deterministic_rng_reproducible() {
        let mut a = DeterministicRng::seed(1234);
        let mut b = DeterministicRng::seed(1234);
        for _ in 0..10 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = DeterministicRng::seed(1235);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn system_rng_nonconstant() {
        let mut r = SystemRng::new();
        let a = r.next_u64();
        let b = r.next_u64();
        assert_ne!(a, b); // astronomically unlikely to fail
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut r = DeterministicRng::seed(9);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn next_below_unbiased_range() {
        let mut r = DeterministicRng::seed(10);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.next_below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn prg_expand_deterministic_and_cancelling() {
        let seed = [7u8; 32];
        let a = prg_expand_f64(&seed, 100);
        let b = prg_expand_f64(&seed, 100);
        assert_eq!(a, b);
        // Masks cancel exactly: x + m - m == x for representable values.
        for (x, m) in a.iter().zip(b.iter()) {
            let v = 3.25f64 + x - m;
            assert_eq!(v, 3.25);
        }
        let c = prg_expand_f64(&[8u8; 32], 100);
        assert_ne!(a, c);
    }
}
