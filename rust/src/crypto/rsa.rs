//! RSA public-key cryptography (from scratch on our bignum substrate).
//!
//! The paper (§4) encrypts every chain message with the receiver's public
//! key and analyses RSA complexity explicitly (O(k²) encrypt / O(k³)
//! decrypt for a k-bit modulus). We implement:
//!
//!  * key generation (two random primes, e = 65537, CRT parameters),
//!  * PKCS#1 v1.5 type-2 style padding for encryption blocks,
//!  * CRT-accelerated decryption (~4× faster than plain d exponentiation),
//!  * PKCS#1 v1.5 type-1 digest signatures (key-exchange authenticity),
//!  * chunked blob encryption so the RSA-only mode can carry feature
//!    vectors larger than one block (what SAF→SAFE §5.7 improves on).
//!
//! Everything is generic over [`Big`], so the whole RSA layer runs on
//! whichever bignum backend the build selects (the differential suite
//! pins the backends byte-identical). The owned [`RsaEncryptCtx`] /
//! [`RsaDecryptCtx`] reify the per-modulus exponentiation state: the
//! §5.8 paths decrypt one sealed key *per peer* with the *same* private
//! key, so hoisting one context out of the loop amortizes the Montgomery
//! setup across every link of a node.

use super::backend::{Big, DefaultBig, ModContext};
use super::rng::SecureRng;
use anyhow::{bail, Context, Result};

/// RSA public key (n, e).
#[derive(Debug, Clone, PartialEq)]
pub struct RsaPublicKey<B: Big = DefaultBig> {
    pub n: B::Num,
    pub e: B::Num,
}

/// RSA private key with CRT parameters.
#[derive(Debug, Clone)]
pub struct RsaPrivateKey<B: Big = DefaultBig> {
    pub n: B::Num,
    pub e: B::Num,
    pub d: B::Num,
    pub p: B::Num,
    pub q: B::Num,
    pub dp: B::Num,   // d mod (p-1)
    pub dq: B::Num,   // d mod (q-1)
    pub qinv: B::Num, // q^{-1} mod p
}

/// A full keypair.
#[derive(Debug, Clone)]
pub struct RsaKeyPair<B: Big = DefaultBig> {
    pub public: RsaPublicKey<B>,
    pub private: RsaPrivateKey<B>,
}

/// PKCS#1 v1.5 type-2 padding: EM = 00 02 PS(nonzero random) 00 M.
fn pad_encrypt_block(k: usize, msg: &[u8], rng: &mut dyn SecureRng) -> Result<Vec<u8>> {
    if msg.len() + 11 > k {
        bail!("message too long for RSA block: {} > {}", msg.len(), k - 11);
    }
    let ps_len = k - 3 - msg.len();
    let mut em = Vec::with_capacity(k);
    em.push(0x00);
    em.push(0x02);
    for _ in 0..ps_len {
        // non-zero random byte
        loop {
            let mut b = [0u8; 1];
            rng.fill_bytes(&mut b);
            if b[0] != 0 {
                em.push(b[0]);
                break;
            }
        }
    }
    em.push(0x00);
    em.extend_from_slice(msg);
    Ok(em)
}

/// Strip PKCS#1 v1.5 type-2 padding from a decrypted block.
fn unpad_encrypt_block(em: &[u8]) -> Result<Vec<u8>> {
    if em[0] != 0x00 || em[1] != 0x02 {
        bail!("invalid PKCS#1 padding header");
    }
    let sep = em[2..]
        .iter()
        .position(|&b| b == 0)
        .context("missing PKCS#1 separator")?;
    if sep < 8 {
        bail!("PKCS#1 padding string too short");
    }
    Ok(em[2 + sep + 1..].to_vec())
}

/// PKCS#1 v1.5 type-1 padding (signatures): EM = 00 01 FF…FF 00 D.
fn pad_sign_block(k: usize, digest: &[u8]) -> Result<Vec<u8>> {
    if digest.len() + 11 > k {
        bail!("digest too long for RSA block: {} > {}", digest.len(), k - 11);
    }
    let mut em = Vec::with_capacity(k);
    em.push(0x00);
    em.push(0x01);
    em.resize(k - digest.len() - 1, 0xff);
    em.push(0x00);
    em.extend_from_slice(digest);
    Ok(em)
}

impl<B: Big> RsaPublicKey<B> {
    /// Modulus size in bytes.
    pub fn modulus_len(&self) -> usize {
        (B::bit_length(&self.n) + 7) / 8
    }

    /// Max plaintext bytes per block under PKCS#1 v1.5 (k - 11).
    pub fn max_block_payload(&self) -> usize {
        self.modulus_len().saturating_sub(11)
    }

    /// Build a reusable encryption context (one Montgomery setup for n,
    /// shared by every block sealed under this key).
    pub fn encrypt_ctx(&self) -> RsaEncryptCtx<B> {
        RsaEncryptCtx { key: self.clone(), n_ctx: B::ctx(&self.n) }
    }

    /// Encrypt one block (PKCS#1 v1.5 type 2 padding).
    pub fn encrypt_block(&self, msg: &[u8], rng: &mut dyn SecureRng) -> Result<Vec<u8>> {
        self.encrypt_ctx().encrypt_block(msg, rng)
    }

    /// Encrypt an arbitrary-length blob by chunking into blocks.
    /// This is the "RSA-only" mode whose cost motivates §5.7.
    pub fn encrypt_blob(&self, data: &[u8], rng: &mut dyn SecureRng) -> Result<Vec<u8>> {
        self.encrypt_ctx().encrypt_blob(data, rng)
    }

    /// Verify a PKCS#1 v1.5 type-1 signature over `digest`.
    pub fn verify_digest(&self, digest: &[u8], sig: &[u8]) -> bool {
        let k = self.modulus_len();
        if sig.len() != k {
            return false;
        }
        let s = B::from_bytes_be(sig);
        if B::cmp(&s, &self.n) != std::cmp::Ordering::Less {
            return false;
        }
        let em = B::to_bytes_be_padded(&B::modpow(&s, &self.e, &self.n), k);
        match pad_sign_block(k, digest) {
            Ok(expect) => em == expect,
            Err(_) => false,
        }
    }

    /// Serialize as JSON-friendly hex.
    pub fn to_json(&self) -> crate::json::Value {
        crate::json::Value::object(vec![
            ("n", crate::json::Value::from(B::to_hex(&self.n))),
            ("e", crate::json::Value::from(B::to_hex(&self.e))),
        ])
    }

    pub fn from_json(v: &crate::json::Value) -> Result<Self> {
        let n = B::from_hex(v.str_of("n").context("missing n")?)?;
        let e = B::from_hex(v.str_of("e").context("missing e")?)?;
        Ok(RsaPublicKey { n, e })
    }
}

/// Owned, cloneable encryption context: the public key plus one
/// prebuilt exponentiation context for n.
#[derive(Clone)]
pub struct RsaEncryptCtx<B: Big = DefaultBig> {
    key: RsaPublicKey<B>,
    n_ctx: B::Ctx,
}

impl<B: Big> RsaEncryptCtx<B> {
    pub fn public_key(&self) -> &RsaPublicKey<B> {
        &self.key
    }

    /// Encrypt one block reusing the prebuilt modulus context.
    pub fn encrypt_block(&self, msg: &[u8], rng: &mut dyn SecureRng) -> Result<Vec<u8>> {
        let k = self.key.modulus_len();
        let em = pad_encrypt_block(k, msg, rng)?;
        let m = B::from_bytes_be(&em);
        let c = self.n_ctx.modpow(&m, &self.key.e);
        Ok(B::to_bytes_be_padded(&c, k))
    }

    /// Encrypt a blob; all chunks share this context's Montgomery state.
    pub fn encrypt_blob(&self, data: &[u8], rng: &mut dyn SecureRng) -> Result<Vec<u8>> {
        let chunk = self.key.max_block_payload();
        let mut out = Vec::new();
        for part in data.chunks(chunk.max(1)) {
            out.extend_from_slice(&self.encrypt_block(part, rng)?);
        }
        Ok(out)
    }
}

impl<B: Big> RsaPrivateKey<B> {
    pub fn modulus_len(&self) -> usize {
        (B::bit_length(&self.n) + 7) / 8
    }

    /// Build a reusable decryption context: Montgomery state for p and q,
    /// shared by every block this key opens. The §5.8 pull loops (round-0
    /// setup, re-key) hoist one of these out of their per-peer loops.
    pub fn decrypt_ctx(&self) -> RsaDecryptCtx<B> {
        RsaDecryptCtx {
            n: self.n.clone(),
            p: self.p.clone(),
            q: self.q.clone(),
            dp: self.dp.clone(),
            dq: self.dq.clone(),
            qinv: self.qinv.clone(),
            p_ctx: B::ctx(&self.p),
            q_ctx: B::ctx(&self.q),
        }
    }

    /// RSA-CRT exponentiation: m = c^d mod n via the two half-size moduli.
    fn decrypt_raw(&self, c: &B::Num) -> B::Num {
        self.decrypt_ctx().decrypt_raw(c)
    }

    /// Decrypt one PKCS#1 v1.5 block.
    pub fn decrypt_block(&self, block: &[u8]) -> Result<Vec<u8>> {
        self.decrypt_ctx().decrypt_block(block)
    }

    /// Decrypt a chunked blob produced by [`RsaPublicKey::encrypt_blob`].
    /// One CRT context is shared across all chunks.
    pub fn decrypt_blob(&self, data: &[u8]) -> Result<Vec<u8>> {
        self.decrypt_ctx().decrypt_blob(data)
    }

    /// Sign a digest (PKCS#1 v1.5 type-1) with the CRT private key.
    pub fn sign_digest(&self, digest: &[u8]) -> Result<Vec<u8>> {
        let k = self.modulus_len();
        let em = pad_sign_block(k, digest)?;
        let m = B::from_bytes_be(&em);
        let s = self.decrypt_raw(&m);
        Ok(B::to_bytes_be_padded(&s, k))
    }
}

/// Owned, cloneable CRT decryption context. Storable (e.g. in a
/// `OnceCell` inside a learner context) because it borrows nothing.
#[derive(Clone)]
pub struct RsaDecryptCtx<B: Big = DefaultBig> {
    n: B::Num,
    p: B::Num,
    q: B::Num,
    dp: B::Num,
    dq: B::Num,
    qinv: B::Num,
    p_ctx: B::Ctx,
    q_ctx: B::Ctx,
}

impl<B: Big> RsaDecryptCtx<B> {
    pub fn modulus_len(&self) -> usize {
        (B::bit_length(&self.n) + 7) / 8
    }

    /// CRT: m1 = c^dp mod p, m2 = c^dq mod q, recombine via qinv.
    fn decrypt_raw(&self, c: &B::Num) -> B::Num {
        let m1 = self.p_ctx.modpow(&B::rem(c, &self.p), &self.dp);
        let m2 = self.q_ctx.modpow(&B::rem(c, &self.q), &self.dq);
        // h = qinv * (m1 - m2) mod p
        let diff = B::submod(&m1, &B::rem(&m2, &self.p), &self.p);
        let h = B::mulmod(&self.qinv, &diff, &self.p);
        B::add(&m2, &B::mul(&h, &self.q))
    }

    /// Decrypt one PKCS#1 v1.5 block reusing the CRT contexts.
    pub fn decrypt_block(&self, block: &[u8]) -> Result<Vec<u8>> {
        let k = self.modulus_len();
        if block.len() != k {
            bail!("ciphertext block length {} != modulus length {}", block.len(), k);
        }
        let c = B::from_bytes_be(block);
        if B::cmp(&c, &self.n) != std::cmp::Ordering::Less {
            bail!("ciphertext out of range");
        }
        let m = self.decrypt_raw(&c);
        let em = B::to_bytes_be_padded(&m, k);
        unpad_encrypt_block(&em)
    }

    /// Decrypt a chunked blob; all chunks share the CRT contexts.
    pub fn decrypt_blob(&self, data: &[u8]) -> Result<Vec<u8>> {
        let k = self.modulus_len();
        if data.len() % k != 0 {
            bail!("blob length {} not a multiple of block size {}", data.len(), k);
        }
        let mut out = Vec::with_capacity(data.len());
        for block in data.chunks(k) {
            out.extend_from_slice(&self.decrypt_block(block)?);
        }
        Ok(out)
    }
}

impl<B: Big> RsaKeyPair<B> {
    /// Generate a keypair with a `bits`-bit modulus and e = 65537.
    ///
    /// The RNG consumption order (p then q, full redraw of both on any
    /// failure) is part of the cross-backend contract: a fixed seed must
    /// yield byte-identical keys on every backend (pinned by the keygen
    /// regression in `tests/crypto_differential.rs`). Don't reorder.
    pub fn generate(bits: usize, rng: &mut dyn SecureRng) -> Self {
        assert!(bits >= 128, "modulus too small");
        let e = B::from_u64(65537);
        loop {
            let p = super::prime::gen_prime::<B>(bits / 2, rng);
            let q = super::prime::gen_prime::<B>(bits - bits / 2, rng);
            if p == q {
                continue;
            }
            let n = B::mul(&p, &q);
            if B::bit_length(&n) != bits {
                continue;
            }
            let p1 = B::sub_u64(&p, 1);
            let q1 = B::sub_u64(&q, 1);
            let phi = B::mul(&p1, &q1);
            let d = match B::modinv(&e, &phi) {
                Some(d) => d,
                None => continue, // gcd(e, phi) != 1; re-draw primes
            };
            let dp = B::rem(&d, &p1);
            let dq = B::rem(&d, &q1);
            let qinv = match B::modinv(&q, &p) {
                Some(v) => v,
                None => continue,
            };
            return RsaKeyPair {
                public: RsaPublicKey { n: n.clone(), e: e.clone() },
                private: RsaPrivateKey { n, e: e.clone(), d, p, q, dp, dq, qinv },
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::backend::NativeBig;
    use crate::crypto::bigint_dig::DigBig;
    use crate::crypto::rng::DeterministicRng;

    fn test_keypair(bits: usize, seed: u64) -> RsaKeyPair {
        let mut rng = DeterministicRng::seed(seed);
        RsaKeyPair::generate(bits, &mut rng)
    }

    fn sha256(data: &[u8]) -> Vec<u8> {
        use sha2::{Digest, Sha256};
        Sha256::digest(data).to_vec()
    }

    #[test]
    fn keygen_properties() {
        let kp = test_keypair(512, 1);
        assert_eq!(DefaultBig::bit_length(&kp.public.n), 512);
        assert_eq!(DefaultBig::mul(&kp.private.p, &kp.private.q), kp.public.n);
        // e*d ≡ 1 mod phi
        let phi = DefaultBig::mul(
            &DefaultBig::sub_u64(&kp.private.p, 1),
            &DefaultBig::sub_u64(&kp.private.q, 1),
        );
        assert!(DefaultBig::is_one(&DefaultBig::mulmod(&kp.public.e, &kp.private.d, &phi)));
    }

    #[test]
    fn encrypt_decrypt_roundtrip() {
        let kp = test_keypair(512, 2);
        let mut rng = DeterministicRng::seed(3);
        for msg in [&b""[..], b"x", b"hello world", &[0u8, 1, 2, 0, 0, 255]] {
            let c = kp.public.encrypt_block(msg, &mut rng).unwrap();
            assert_eq!(c.len(), kp.public.modulus_len());
            let m = kp.private.decrypt_block(&c).unwrap();
            assert_eq!(m, msg);
        }
    }

    #[test]
    fn ciphertext_is_randomized() {
        let kp = test_keypair(512, 4);
        let mut rng = DeterministicRng::seed(5);
        let c1 = kp.public.encrypt_block(b"same message", &mut rng).unwrap();
        let c2 = kp.public.encrypt_block(b"same message", &mut rng).unwrap();
        assert_ne!(c1, c2, "PKCS#1 v1.5 must be randomized");
    }

    #[test]
    fn blob_roundtrip_multiblock() {
        let kp = test_keypair(512, 6);
        let mut rng = DeterministicRng::seed(7);
        let data: Vec<u8> = (0..1000u32).map(|i| (i % 251) as u8).collect();
        let blob = kp.public.encrypt_blob(&data, &mut rng).unwrap();
        assert!(blob.len() > data.len());
        assert_eq!(kp.private.decrypt_blob(&blob).unwrap(), data);
    }

    #[test]
    fn oversize_block_rejected() {
        let kp = test_keypair(512, 8);
        let mut rng = DeterministicRng::seed(9);
        let too_big = vec![1u8; kp.public.max_block_payload() + 1];
        assert!(kp.public.encrypt_block(&too_big, &mut rng).is_err());
    }

    #[test]
    fn tampered_ciphertext_detected() {
        let kp = test_keypair(512, 10);
        let mut rng = DeterministicRng::seed(11);
        let mut c = kp.public.encrypt_block(b"secret", &mut rng).unwrap();
        c[10] ^= 0xff;
        // Either padding fails or the plaintext differs.
        match kp.private.decrypt_block(&c) {
            Err(_) => {}
            Ok(m) => assert_ne!(m, b"secret"),
        }
    }

    #[test]
    fn wrong_key_cannot_decrypt() {
        let kp1 = test_keypair(512, 12);
        let kp2 = test_keypair(512, 13);
        let mut rng = DeterministicRng::seed(14);
        let c = kp1.public.encrypt_block(b"for kp1 only", &mut rng).unwrap();
        match kp2.private.decrypt_block(&c) {
            Err(_) => {}
            Ok(m) => assert_ne!(m, b"for kp1 only"),
        }
    }

    #[test]
    fn public_key_json_roundtrip() {
        let kp = test_keypair(256, 15);
        let j = kp.public.to_json();
        let back = RsaPublicKey::from_json(&j).unwrap();
        assert_eq!(back, kp.public);
    }

    #[test]
    fn crt_matches_plain_exponentiation() {
        let kp = test_keypair(512, 16);
        let mut rng = DeterministicRng::seed(17);
        let m = DefaultBig::random_below(&kp.public.n, &mut rng);
        let c = DefaultBig::modpow(&m, &kp.public.e, &kp.public.n);
        let plain = DefaultBig::modpow(&c, &kp.private.d, &kp.private.n);
        let crt = kp.private.decrypt_raw(&c);
        assert_eq!(plain, crt);
        assert_eq!(plain, m);
    }

    #[test]
    fn shared_ctx_matches_fresh_key_calls() {
        let kp = test_keypair(512, 18);
        let enc = kp.public.encrypt_ctx();
        let dec = kp.private.decrypt_ctx();
        let data: Vec<u8> = (0..300u32).map(|i| (i * 7 % 256) as u8).collect();
        // Same RNG seed both ways ⇒ identical ciphertexts.
        let mut r1 = DeterministicRng::seed(19);
        let mut r2 = DeterministicRng::seed(19);
        let via_key = kp.public.encrypt_blob(&data, &mut r1).unwrap();
        let via_ctx = enc.encrypt_blob(&data, &mut r2).unwrap();
        assert_eq!(via_key, via_ctx);
        assert_eq!(dec.decrypt_blob(&via_ctx).unwrap(), data);
        assert_eq!(kp.private.decrypt_blob(&via_key).unwrap(), data);
    }

    #[test]
    fn sign_verify_roundtrip() {
        let kp = test_keypair(512, 20);
        let digest = sha256(b"signed payload");
        let sig = kp.private.sign_digest(&digest).unwrap();
        assert_eq!(sig.len(), kp.public.modulus_len());
        assert!(kp.public.verify_digest(&digest, &sig));
        // Wrong digest, tampered signature, wrong key all fail.
        assert!(!kp.public.verify_digest(&sha256(b"other"), &sig));
        let mut bad = sig.clone();
        bad[5] ^= 1;
        assert!(!kp.public.verify_digest(&digest, &bad));
        let kp2 = test_keypair(512, 21);
        assert!(!kp2.public.verify_digest(&digest, &sig));
    }

    /// The generic surface compiles and round-trips on the non-default
    /// backend too (small modulus: the reference backend is slow in
    /// debug builds; the differential suite covers it at full width).
    fn roundtrip_on<B: crate::crypto::backend::Big>() {
        let mut rng = DeterministicRng::seed(22);
        let kp = RsaKeyPair::<B>::generate(256, &mut rng);
        let c = kp.public.encrypt_block(b"backend check", &mut rng).unwrap();
        assert_eq!(kp.private.decrypt_block(&c).unwrap(), b"backend check");
        let digest = sha256(b"x");
        let sig = kp.private.sign_digest(&digest).unwrap();
        assert!(kp.public.verify_digest(&digest, &sig));
    }

    #[test]
    fn roundtrip_both_backends() {
        roundtrip_on::<NativeBig>();
        roundtrip_on::<DigBig>();
    }
}
