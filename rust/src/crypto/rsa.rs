//! RSA public-key cryptography (from scratch on our bignum substrate).
//!
//! The paper (§4) encrypts every chain message with the receiver's public
//! key and analyses RSA complexity explicitly (O(k²) encrypt / O(k³)
//! decrypt for a k-bit modulus). We implement:
//!
//!  * key generation (two random primes, e = 65537, CRT parameters),
//!  * PKCS#1 v1.5 type-2 style padding for encryption blocks,
//!  * CRT-accelerated decryption (~4× faster than plain d exponentiation),
//!  * chunked blob encryption so the RSA-only mode can carry feature
//!    vectors larger than one block (what SAF→SAFE §5.7 improves on).

use super::bigint::BigUint;
use super::rng::SecureRng;
use anyhow::{bail, Context, Result};

/// RSA public key (n, e).
#[derive(Debug, Clone, PartialEq)]
pub struct RsaPublicKey {
    pub n: BigUint,
    pub e: BigUint,
}

/// RSA private key with CRT parameters.
#[derive(Debug, Clone)]
pub struct RsaPrivateKey {
    pub n: BigUint,
    pub e: BigUint,
    pub d: BigUint,
    pub p: BigUint,
    pub q: BigUint,
    pub dp: BigUint,   // d mod (p-1)
    pub dq: BigUint,   // d mod (q-1)
    pub qinv: BigUint, // q^{-1} mod p
}

/// A full keypair.
#[derive(Debug, Clone)]
pub struct RsaKeyPair {
    pub public: RsaPublicKey,
    pub private: RsaPrivateKey,
}

impl RsaPublicKey {
    /// Modulus size in bytes.
    pub fn modulus_len(&self) -> usize {
        (self.n.bit_length() + 7) / 8
    }

    /// Max plaintext bytes per block under PKCS#1 v1.5 (k - 11).
    pub fn max_block_payload(&self) -> usize {
        self.modulus_len().saturating_sub(11)
    }

    /// Encrypt one block (PKCS#1 v1.5 type 2 padding).
    pub fn encrypt_block(&self, msg: &[u8], rng: &mut dyn SecureRng) -> Result<Vec<u8>> {
        let k = self.modulus_len();
        if msg.len() > k - 11 {
            bail!("message too long for RSA block: {} > {}", msg.len(), k - 11);
        }
        // EM = 0x00 || 0x02 || PS (nonzero random) || 0x00 || M
        let ps_len = k - 3 - msg.len();
        let mut em = Vec::with_capacity(k);
        em.push(0x00);
        em.push(0x02);
        for _ in 0..ps_len {
            // non-zero random byte
            loop {
                let mut b = [0u8; 1];
                rng.fill_bytes(&mut b);
                if b[0] != 0 {
                    em.push(b[0]);
                    break;
                }
            }
        }
        em.push(0x00);
        em.extend_from_slice(msg);
        let m = BigUint::from_bytes_be(&em);
        let c = m.modpow(&self.e, &self.n);
        Ok(c.to_bytes_be_padded(k))
    }

    /// Encrypt an arbitrary-length blob by chunking into blocks.
    /// This is the "RSA-only" mode whose cost motivates §5.7.
    pub fn encrypt_blob(&self, data: &[u8], rng: &mut dyn SecureRng) -> Result<Vec<u8>> {
        let chunk = self.max_block_payload();
        let mut out = Vec::new();
        for part in data.chunks(chunk.max(1)) {
            out.extend_from_slice(&self.encrypt_block(part, rng)?);
        }
        Ok(out)
    }

    /// Serialize as JSON-friendly hex.
    pub fn to_json(&self) -> crate::json::Value {
        crate::json::Value::object(vec![
            ("n", crate::json::Value::from(self.n.to_hex())),
            ("e", crate::json::Value::from(self.e.to_hex())),
        ])
    }

    pub fn from_json(v: &crate::json::Value) -> Result<Self> {
        let n = BigUint::from_hex(v.str_of("n").context("missing n")?)?;
        let e = BigUint::from_hex(v.str_of("e").context("missing e")?)?;
        Ok(RsaPublicKey { n, e })
    }
}

impl RsaPrivateKey {
    pub fn modulus_len(&self) -> usize {
        (self.n.bit_length() + 7) / 8
    }

    /// RSA-CRT exponentiation: m = c^d mod n via the two half-size moduli.
    fn decrypt_raw(&self, c: &BigUint) -> BigUint {
        let m1 = c.rem(&self.p).modpow(&self.dp, &self.p);
        let m2 = c.rem(&self.q).modpow(&self.dq, &self.q);
        // h = qinv * (m1 - m2) mod p
        let diff = m1.submod(&m2.rem(&self.p), &self.p);
        let h = self.qinv.mulmod(&diff, &self.p);
        m2.add(&h.mul(&self.q))
    }

    /// Decrypt one PKCS#1 v1.5 block.
    pub fn decrypt_block(&self, block: &[u8]) -> Result<Vec<u8>> {
        let k = self.modulus_len();
        if block.len() != k {
            bail!("ciphertext block length {} != modulus length {}", block.len(), k);
        }
        let c = BigUint::from_bytes_be(block);
        if c.ge(&self.n) {
            bail!("ciphertext out of range");
        }
        let m = self.decrypt_raw(&c);
        let em = m.to_bytes_be_padded(k);
        if em[0] != 0x00 || em[1] != 0x02 {
            bail!("invalid PKCS#1 padding header");
        }
        let sep = em[2..]
            .iter()
            .position(|&b| b == 0)
            .context("missing PKCS#1 separator")?;
        if sep < 8 {
            bail!("PKCS#1 padding string too short");
        }
        Ok(em[2 + sep + 1..].to_vec())
    }

    /// Decrypt a chunked blob produced by [`RsaPublicKey::encrypt_blob`].
    pub fn decrypt_blob(&self, data: &[u8]) -> Result<Vec<u8>> {
        let k = self.modulus_len();
        if data.len() % k != 0 {
            bail!("blob length {} not a multiple of block size {}", data.len(), k);
        }
        let mut out = Vec::with_capacity(data.len());
        for block in data.chunks(k) {
            out.extend_from_slice(&self.decrypt_block(block)?);
        }
        Ok(out)
    }
}

impl RsaKeyPair {
    /// Generate a keypair with a `bits`-bit modulus and e = 65537.
    pub fn generate(bits: usize, rng: &mut dyn SecureRng) -> Self {
        assert!(bits >= 128, "modulus too small");
        let e = BigUint::from_u64(65537);
        loop {
            let p = super::prime::gen_prime(bits / 2, rng);
            let q = super::prime::gen_prime(bits - bits / 2, rng);
            if p == q {
                continue;
            }
            let n = p.mul(&q);
            if n.bit_length() != bits {
                continue;
            }
            let p1 = p.sub_u64(1);
            let q1 = q.sub_u64(1);
            let phi = p1.mul(&q1);
            let d = match e.modinv(&phi) {
                Some(d) => d,
                None => continue, // gcd(e, phi) != 1; re-draw primes
            };
            let dp = d.rem(&p1);
            let dq = d.rem(&q1);
            let qinv = match q.modinv(&p) {
                Some(v) => v,
                None => continue,
            };
            return RsaKeyPair {
                public: RsaPublicKey { n: n.clone(), e: e.clone() },
                private: RsaPrivateKey { n, e: e.clone(), d, p, q, dp, dq, qinv },
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::DeterministicRng;

    fn test_keypair(bits: usize, seed: u64) -> RsaKeyPair {
        let mut rng = DeterministicRng::seed(seed);
        RsaKeyPair::generate(bits, &mut rng)
    }

    #[test]
    fn keygen_properties() {
        let kp = test_keypair(512, 1);
        assert_eq!(kp.public.n.bit_length(), 512);
        assert_eq!(kp.private.p.mul(&kp.private.q), kp.public.n);
        // e*d ≡ 1 mod phi
        let phi = kp.private.p.sub_u64(1).mul(&kp.private.q.sub_u64(1));
        assert!(kp.public.e.mulmod(&kp.private.d, &phi).is_one());
    }

    #[test]
    fn encrypt_decrypt_roundtrip() {
        let kp = test_keypair(512, 2);
        let mut rng = DeterministicRng::seed(3);
        for msg in [&b""[..], b"x", b"hello world", &[0u8, 1, 2, 0, 0, 255]] {
            let c = kp.public.encrypt_block(msg, &mut rng).unwrap();
            assert_eq!(c.len(), kp.public.modulus_len());
            let m = kp.private.decrypt_block(&c).unwrap();
            assert_eq!(m, msg);
        }
    }

    #[test]
    fn ciphertext_is_randomized() {
        let kp = test_keypair(512, 4);
        let mut rng = DeterministicRng::seed(5);
        let c1 = kp.public.encrypt_block(b"same message", &mut rng).unwrap();
        let c2 = kp.public.encrypt_block(b"same message", &mut rng).unwrap();
        assert_ne!(c1, c2, "PKCS#1 v1.5 must be randomized");
    }

    #[test]
    fn blob_roundtrip_multiblock() {
        let kp = test_keypair(512, 6);
        let mut rng = DeterministicRng::seed(7);
        let data: Vec<u8> = (0..1000u32).map(|i| (i % 251) as u8).collect();
        let blob = kp.public.encrypt_blob(&data, &mut rng).unwrap();
        assert!(blob.len() > data.len());
        assert_eq!(kp.private.decrypt_blob(&blob).unwrap(), data);
    }

    #[test]
    fn oversize_block_rejected() {
        let kp = test_keypair(512, 8);
        let mut rng = DeterministicRng::seed(9);
        let too_big = vec![1u8; kp.public.max_block_payload() + 1];
        assert!(kp.public.encrypt_block(&too_big, &mut rng).is_err());
    }

    #[test]
    fn tampered_ciphertext_detected() {
        let kp = test_keypair(512, 10);
        let mut rng = DeterministicRng::seed(11);
        let mut c = kp.public.encrypt_block(b"secret", &mut rng).unwrap();
        c[10] ^= 0xff;
        // Either padding fails or the plaintext differs.
        match kp.private.decrypt_block(&c) {
            Err(_) => {}
            Ok(m) => assert_ne!(m, b"secret"),
        }
    }

    #[test]
    fn wrong_key_cannot_decrypt() {
        let kp1 = test_keypair(512, 12);
        let kp2 = test_keypair(512, 13);
        let mut rng = DeterministicRng::seed(14);
        let c = kp1.public.encrypt_block(b"for kp1 only", &mut rng).unwrap();
        match kp2.private.decrypt_block(&c) {
            Err(_) => {}
            Ok(m) => assert_ne!(m, b"for kp1 only"),
        }
    }

    #[test]
    fn public_key_json_roundtrip() {
        let kp = test_keypair(256, 15);
        let j = kp.public.to_json();
        let back = RsaPublicKey::from_json(&j).unwrap();
        assert_eq!(back, kp.public);
    }

    #[test]
    fn crt_matches_plain_exponentiation() {
        let kp = test_keypair(512, 16);
        let mut rng = DeterministicRng::seed(17);
        let m = BigUint::random_below(&kp.public.n, &mut rng);
        let c = m.modpow(&kp.public.e, &kp.public.n);
        let plain = c.modpow(&kp.private.d, &kp.private.n);
        let crt = kp.private.decrypt_raw(&c);
        assert_eq!(plain, crt);
        assert_eq!(plain, m);
    }
}
