//! Shamir t-of-n secret sharing over GF(2^61 − 1).
//!
//! BON-baseline substrate (paper §2: "no k-of-n secret sharing is
//! necessary [in SAFE]" — but BON needs it). Bonawitz Round 1 shares each
//! client's self-mask seed `b_u` and DH secret key `s_u^SK` among all
//! peers so the server can recover them after dropouts.
//!
//! Secrets are byte strings; we split them into 7-byte (56-bit) chunks,
//! each shared independently over the Mersenne field p = 2^61 − 1 where
//! `u128` arithmetic gives exact mulmod.

use anyhow::{bail, Result};

use super::rng::SecureRng;

/// Field modulus: Mersenne prime 2^61 - 1.
pub const P: u64 = (1u64 << 61) - 1;

/// Reduce a u128 modulo 2^61-1 using the Mersenne identity
/// x = (x >> 61) + (x & P) (mod P).
#[inline]
fn reduce(mut x: u128) -> u64 {
    while x >= (1u128 << 61) {
        x = (x >> 61) + (x & P as u128);
    }
    let v = x as u64;
    if v >= P {
        v - P
    } else {
        v
    }
}

#[inline]
pub fn add(a: u64, b: u64) -> u64 {
    reduce(a as u128 + b as u128)
}

#[inline]
pub fn sub(a: u64, b: u64) -> u64 {
    add(a, P - (b % P))
}

#[inline]
pub fn mul(a: u64, b: u64) -> u64 {
    reduce(a as u128 * b as u128)
}

/// Fermat inverse: a^(p-2) mod p.
pub fn inv(a: u64) -> u64 {
    assert!(a % P != 0, "no inverse of zero");
    pow(a, P - 2)
}

pub fn pow(mut base: u64, mut e: u64) -> u64 {
    base %= P;
    let mut acc = 1u64;
    while e > 0 {
        if e & 1 == 1 {
            acc = mul(acc, base);
        }
        base = mul(base, base);
        e >>= 1;
    }
    acc
}

/// One share: the evaluation point x (= participant id, non-zero) and the
/// polynomial evaluations for every secret chunk.
#[derive(Debug, Clone, PartialEq)]
pub struct Share {
    pub x: u64,
    pub ys: Vec<u64>,
}

impl Share {
    /// Serialize as hex chunks for the wire.
    pub fn to_json(&self) -> crate::json::Value {
        crate::json::Value::object(vec![
            ("x", crate::json::Value::from(self.x)),
            (
                "ys",
                crate::json::Value::Arr(
                    self.ys.iter().map(|&y| crate::json::Value::from(format!("{:x}", y))).collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &crate::json::Value) -> Result<Share> {
        let x = v.u64_of("x").ok_or_else(|| anyhow::anyhow!("share missing x"))?;
        let ys = v
            .get("ys")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow::anyhow!("share missing ys"))?
            .iter()
            .map(|e| {
                e.as_str()
                    .ok_or_else(|| anyhow::anyhow!("bad ys entry"))
                    .and_then(|s| u64::from_str_radix(s, 16).map_err(|e| anyhow::anyhow!("{e}")))
            })
            .collect::<Result<Vec<u64>>>()?;
        Ok(Share { x, ys })
    }
}

const CHUNK: usize = 7; // 56-bit chunks fit comfortably below 2^61-1

/// Split `secret` into `n` shares with threshold `t` (any `t` reconstruct).
/// `xs` are the n distinct non-zero evaluation points (participant ids).
pub fn share_secret(
    secret: &[u8],
    t: usize,
    xs: &[u64],
    rng: &mut dyn SecureRng,
) -> Result<Vec<Share>> {
    if t == 0 || xs.len() < t {
        bail!("invalid threshold {} for {} participants", t, xs.len());
    }
    for &x in xs {
        if x == 0 || x >= P {
            bail!("evaluation points must be in [1, P)");
        }
    }
    {
        let mut sorted: Vec<u64> = xs.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        if sorted.len() != xs.len() {
            bail!("duplicate evaluation points");
        }
    }
    // Prefix the secret with its length so reconstruction can strip padding.
    let mut padded = Vec::with_capacity(secret.len() + 4);
    padded.extend_from_slice(&(secret.len() as u32).to_le_bytes());
    padded.extend_from_slice(secret);
    while padded.len() % CHUNK != 0 {
        padded.push(0);
    }
    let chunks: Vec<u64> = padded
        .chunks(CHUNK)
        .map(|c| {
            let mut v = [0u8; 8];
            v[..c.len()].copy_from_slice(c);
            u64::from_le_bytes(v)
        })
        .collect();

    let mut shares: Vec<Share> =
        xs.iter().map(|&x| Share { x, ys: Vec::with_capacity(chunks.len()) }).collect();

    for &chunk in &chunks {
        // Random degree-(t-1) polynomial with constant term = chunk.
        let mut coeffs = Vec::with_capacity(t);
        coeffs.push(chunk % P);
        for _ in 1..t {
            coeffs.push(rng.next_u64() % P);
        }
        for share in shares.iter_mut() {
            // Horner evaluation at x.
            let mut acc = 0u64;
            for &c in coeffs.iter().rev() {
                acc = add(mul(acc, share.x), c);
            }
            share.ys.push(acc);
        }
    }
    Ok(shares)
}

/// Reconstruct the secret from ≥ t shares (Lagrange interpolation at 0).
pub fn reconstruct_secret(shares: &[Share]) -> Result<Vec<u8>> {
    if shares.is_empty() {
        bail!("no shares provided");
    }
    let n_chunks = shares[0].ys.len();
    if shares.iter().any(|s| s.ys.len() != n_chunks) {
        bail!("shares have inconsistent chunk counts");
    }
    {
        let mut sorted: Vec<u64> = shares.iter().map(|s| s.x).collect();
        sorted.sort_unstable();
        sorted.dedup();
        if sorted.len() != shares.len() {
            bail!("duplicate share points");
        }
    }
    // Lagrange basis at 0: L_i = Π_{j≠i} x_j / (x_j - x_i)
    let xs: Vec<u64> = shares.iter().map(|s| s.x).collect();
    let mut lagrange = Vec::with_capacity(xs.len());
    for i in 0..xs.len() {
        let mut num = 1u64;
        let mut den = 1u64;
        for j in 0..xs.len() {
            if i == j {
                continue;
            }
            num = mul(num, xs[j]);
            den = mul(den, sub(xs[j], xs[i]));
        }
        lagrange.push(mul(num, inv(den)));
    }

    let mut padded = Vec::with_capacity(n_chunks * CHUNK);
    for c in 0..n_chunks {
        let mut v = 0u64;
        for (share, &l) in shares.iter().zip(lagrange.iter()) {
            v = add(v, mul(share.ys[c], l));
        }
        let bytes = v.to_le_bytes();
        padded.extend_from_slice(&bytes[..CHUNK]);
    }
    if padded.len() < 4 {
        bail!("reconstructed data too short");
    }
    let len = u32::from_le_bytes(padded[..4].try_into().unwrap()) as usize;
    if padded.len() < 4 + len {
        bail!("reconstructed length {} exceeds data", len);
    }
    Ok(padded[4..4 + len].to_vec())
}

/// Evaluate the Lagrange interpolation of `shares` at point `x0`
/// (`x0` must not collide with a share point).
fn interpolate_at(shares: &[Share], x0: u64, chunk: usize) -> u64 {
    let mut acc = 0u64;
    for i in 0..shares.len() {
        let mut num = 1u64;
        let mut den = 1u64;
        for j in 0..shares.len() {
            if i == j {
                continue;
            }
            num = mul(num, sub(x0, shares[j].x));
            den = mul(den, sub(shares[i].x, shares[j].x));
        }
        acc = add(acc, mul(shares[i].ys[chunk], mul(num, inv(den))));
    }
    acc
}

/// Reconstruct with corrupted-share detection: interpolate the degree-
/// (t-1) polynomial from the first `t` shares, then check every
/// remaining share lies on it. With at most `shares.len() - t` corrupted
/// shares *outside* the first `t`, corruption is detected; with
/// `shares.len() == t` there is no redundancy and this degrades to plain
/// reconstruction (any corruption silently yields garbage — exactly the
/// Shamir guarantee).
pub fn reconstruct_secret_checked(shares: &[Share], t: usize) -> Result<Vec<u8>> {
    if t == 0 || shares.len() < t {
        bail!("need at least t={} shares, got {}", t, shares.len());
    }
    {
        let mut sorted: Vec<u64> = shares.iter().map(|s| s.x).collect();
        sorted.sort_unstable();
        sorted.dedup();
        if sorted.len() != shares.len() {
            bail!("duplicate share points");
        }
    }
    if shares.iter().any(|s| s.ys.len() != shares[0].ys.len()) {
        bail!("shares have inconsistent chunk counts");
    }
    let base = &shares[..t];
    let secret = reconstruct_secret(base)?;
    let n_chunks = base[0].ys.len();
    for extra in &shares[t..] {
        for c in 0..n_chunks {
            if interpolate_at(base, extra.x, c) != extra.ys[c] {
                bail!(
                    "share x={} inconsistent with interpolated polynomial (corrupted share?)",
                    extra.x
                );
            }
        }
    }
    Ok(secret)
}

/// Differential reference: Lagrange reconstruction with every field
/// operation carried out in [`Big`] arithmetic (values lifted to bignums,
/// reduced mod P, inverse via Fermat exponentiation). Exists so the
/// cross-backend suite can hold the u64 Mersenne field and both bignum
/// backends to the same answers.
pub fn reconstruct_secret_via<B: crate::crypto::backend::Big>(shares: &[Share]) -> Result<Vec<u8>> {
    if shares.is_empty() {
        bail!("no shares provided");
    }
    let n_chunks = shares[0].ys.len();
    if shares.iter().any(|s| s.ys.len() != n_chunks) {
        bail!("shares have inconsistent chunk counts");
    }
    let p = B::from_u64(P);
    let ctx = B::ctx(&p);
    let p_minus_2 = B::from_u64(P - 2);
    let inv_b = |a: &B::Num| ctx.modpow(a, &p_minus_2); // Fermat
    let xs: Vec<B::Num> = shares.iter().map(|s| B::from_u64(s.x)).collect();
    let mut lagrange = Vec::with_capacity(xs.len());
    for i in 0..xs.len() {
        let mut num = B::one();
        let mut den = B::one();
        for j in 0..xs.len() {
            if i == j {
                continue;
            }
            num = B::mulmod(&num, &xs[j], &p);
            den = B::mulmod(&den, &B::submod(&xs[j], &xs[i], &p), &p);
        }
        lagrange.push(B::mulmod(&num, &inv_b(&den), &p));
    }
    let mut padded = Vec::with_capacity(n_chunks * CHUNK);
    for c in 0..n_chunks {
        let mut v = B::zero();
        for (share, l) in shares.iter().zip(lagrange.iter()) {
            v = B::addmod(&v, &B::mulmod(&B::from_u64(share.ys[c]), l, &p), &p);
        }
        let bytes = B::as_u64(&v).expect("field element fits u64").to_le_bytes();
        padded.extend_from_slice(&bytes[..CHUNK]);
    }
    if padded.len() < 4 {
        bail!("reconstructed data too short");
    }
    let len = u32::from_le_bytes(padded[..4].try_into().unwrap()) as usize;
    if padded.len() < 4 + len {
        bail!("reconstructed length {} exceeds data", len);
    }
    Ok(padded[4..4 + len].to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::rng::DeterministicRng;

    #[test]
    fn field_arithmetic_basics() {
        assert_eq!(add(P - 1, 1), 0);
        assert_eq!(sub(0, 1), P - 1);
        assert_eq!(mul(2, P - 1), P - 2); // 2(P-1) = 2P-2 ≡ P-2
        for a in [1u64, 2, 12345, P - 1] {
            assert_eq!(mul(a, inv(a)), 1, "a={}", a);
        }
        assert_eq!(pow(3, 4), 81);
    }

    #[test]
    fn share_reconstruct_exact_threshold() {
        let mut rng = DeterministicRng::seed(1);
        let secret = b"the initiator's 32-byte mask key";
        let xs: Vec<u64> = (1..=5).collect();
        let shares = share_secret(secret, 3, &xs, &mut rng).unwrap();
        // Any 3 of 5 reconstruct.
        let rec = reconstruct_secret(&shares[..3]).unwrap();
        assert_eq!(rec, secret);
        let rec = reconstruct_secret(&[shares[0].clone(), shares[2].clone(), shares[4].clone()])
            .unwrap();
        assert_eq!(rec, secret);
        // All 5 also fine.
        assert_eq!(reconstruct_secret(&shares).unwrap(), secret);
    }

    #[test]
    fn below_threshold_gives_garbage() {
        let mut rng = DeterministicRng::seed(2);
        let secret = b"super secret";
        let xs: Vec<u64> = (1..=4).collect();
        let shares = share_secret(secret, 3, &xs, &mut rng).unwrap();
        // 2 < t shares: reconstruction must NOT yield the secret.
        match reconstruct_secret(&shares[..2]) {
            Ok(rec) => assert_ne!(rec, secret),
            Err(_) => {}
        }
    }

    #[test]
    fn odd_lengths_and_empty() {
        let mut rng = DeterministicRng::seed(3);
        let xs: Vec<u64> = (1..=3).collect();
        for secret in [&b""[..], b"a", b"abcdefg", b"abcdefgh", &[0u8; 100]] {
            let shares = share_secret(secret, 2, &xs, &mut rng).unwrap();
            assert_eq!(reconstruct_secret(&shares[..2]).unwrap(), secret);
        }
    }

    #[test]
    fn rejects_bad_params() {
        let mut rng = DeterministicRng::seed(4);
        assert!(share_secret(b"s", 0, &[1, 2], &mut rng).is_err());
        assert!(share_secret(b"s", 3, &[1, 2], &mut rng).is_err());
        assert!(share_secret(b"s", 2, &[0, 1], &mut rng).is_err());
        assert!(share_secret(b"s", 2, &[1, 1], &mut rng).is_err());
        assert!(reconstruct_secret(&[]).is_err());
    }

    #[test]
    fn share_json_roundtrip() {
        let mut rng = DeterministicRng::seed(5);
        let xs: Vec<u64> = (1..=3).collect();
        let shares = share_secret(b"wire format", 2, &xs, &mut rng).unwrap();
        let j = shares[0].to_json();
        let back = Share::from_json(&j).unwrap();
        assert_eq!(back, shares[0]);
    }

    #[test]
    fn threshold_equals_shares() {
        // t == n: every share is required, none redundant.
        let mut rng = DeterministicRng::seed(7);
        let secret = b"all-or-nothing";
        let xs: Vec<u64> = (1..=4).collect();
        let shares = share_secret(secret, 4, &xs, &mut rng).unwrap();
        assert_eq!(reconstruct_secret(&shares).unwrap(), secret);
        assert_eq!(reconstruct_secret_checked(&shares, 4).unwrap(), secret);
        match reconstruct_secret(&shares[..3]) {
            Ok(rec) => assert_ne!(rec, secret),
            Err(_) => {}
        }
    }

    #[test]
    fn corrupted_share_detected_with_redundancy() {
        let mut rng = DeterministicRng::seed(8);
        let secret = b"detect me";
        let xs: Vec<u64> = (1..=5).collect();
        let t = 3;
        let shares = share_secret(secret, t, &xs, &mut rng).unwrap();
        // Clean set passes with full redundancy checked.
        assert_eq!(reconstruct_secret_checked(&shares, t).unwrap(), secret);
        // Corrupt a redundant share: must be detected.
        let mut bad = shares.clone();
        bad[4].ys[0] = add(bad[4].ys[0], 1);
        assert!(reconstruct_secret_checked(&bad, t).is_err());
        // Corrupting a base share flips the polynomial, so the (clean)
        // redundant shares no longer lie on it — also detected.
        let mut bad2 = shares.clone();
        bad2[0].ys[0] = add(bad2[0].ys[0], 1);
        assert!(reconstruct_secret_checked(&bad2, t).is_err());
        // Exactly t shares: no redundancy, corruption yields garbage
        // without an error (the documented degradation).
        let mut bad3 = shares[..t].to_vec();
        bad3[0].ys[0] = add(bad3[0].ys[0], 1);
        match reconstruct_secret_checked(&bad3, t) {
            Ok(rec) => assert_ne!(rec, secret),
            Err(_) => {}
        }
    }

    #[test]
    fn checked_rejects_bad_inputs() {
        let mut rng = DeterministicRng::seed(9);
        let xs: Vec<u64> = (1..=3).collect();
        let shares = share_secret(b"s", 2, &xs, &mut rng).unwrap();
        assert!(reconstruct_secret_checked(&shares, 0).is_err());
        assert!(reconstruct_secret_checked(&shares[..1], 2).is_err());
        let dup = vec![shares[0].clone(), shares[0].clone(), shares[1].clone()];
        assert!(reconstruct_secret_checked(&dup, 2).is_err());
    }

    fn backend_reference_suite<B: crate::crypto::backend::Big>() {
        let mut rng = DeterministicRng::seed(10);
        let secret = b"cross-backend field check 001122";
        let xs: Vec<u64> = [3, 11, 42, 97, 1_000_003].to_vec();
        for t in [1usize, 2, 5] {
            let shares = share_secret(secret, t, &xs, &mut rng).unwrap();
            // Exactly-threshold subset and the full set, u64 field vs the
            // bignum-backend reference.
            for subset in [&shares[..t], &shares[..]] {
                let via_u64 = reconstruct_secret(subset).unwrap();
                let via_big = reconstruct_secret_via::<B>(subset).unwrap();
                assert_eq!(via_u64, via_big, "t={}", t);
                assert_eq!(via_u64, secret, "t={}", t);
            }
        }
    }

    #[test]
    fn backend_reference_matches_u64_field() {
        backend_reference_suite::<crate::crypto::backend::NativeBig>();
        backend_reference_suite::<crate::crypto::bigint_dig::DigBig>();
    }

    #[test]
    fn t_of_n_many_combinations() {
        let mut rng = DeterministicRng::seed(6);
        let secret = b"bonawitz b_u seed 0123456789abcdef";
        let xs: Vec<u64> = (1..=8).collect();
        let t = 6; // ceil(2n/3) for n=8
        let shares = share_secret(secret, t, &xs, &mut rng).unwrap();
        // Drop any two shares: still reconstructs.
        for drop1 in 0..8 {
            let subset: Vec<Share> = shares
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != drop1 && *i != (drop1 + 3) % 8)
                .map(|(_, s)| s.clone())
                .collect();
            assert_eq!(reconstruct_secret(&subset).unwrap(), secret);
        }
    }
}
