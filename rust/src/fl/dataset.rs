//! Synthetic federated dataset: a regression task partitioned across
//! learners, with optional non-IID skew (each node sees a shifted slice of
//! the input distribution — the situation federated averaging must cope
//! with).

use crate::crypto::rng::{DeterministicRng, SecureRng};

/// One node's local shard.
#[derive(Debug, Clone)]
pub struct Shard {
    pub x: Vec<f32>, // rows × dim_in
    pub y: Vec<f32>, // rows × dim_out
    pub rows: usize,
}

/// The ground-truth generating model: y = tanh(x·A)·B + noise, so a
/// 2-layer MLP can fit it well but not trivially.
pub struct SyntheticTask {
    pub dim_in: usize,
    pub dim_out: usize,
    a: Vec<f32>, // dim_in × dim_hidden_true
    b: Vec<f32>, // dim_hidden_true × dim_out
    hidden: usize,
}

impl SyntheticTask {
    pub fn new(dim_in: usize, dim_out: usize, seed: u64) -> SyntheticTask {
        let hidden = 8;
        let mut rng = DeterministicRng::seed(seed);
        let mut draw = |n: usize, scale: f32| -> Vec<f32> {
            (0..n).map(|_| ((rng.next_f64() as f32) - 0.5) * 2.0 * scale).collect()
        };
        SyntheticTask {
            dim_in,
            dim_out,
            a: draw(dim_in * hidden, 1.0),
            b: draw(hidden * dim_out, 1.5),
            hidden,
        }
    }

    fn label(&self, x: &[f32]) -> Vec<f32> {
        let mut h = vec![0.0f32; self.hidden];
        for j in 0..self.hidden {
            let mut acc = 0.0;
            for i in 0..self.dim_in {
                acc += x[i] * self.a[i * self.hidden + j];
            }
            h[j] = acc.tanh();
        }
        let mut y = vec![0.0f32; self.dim_out];
        for k in 0..self.dim_out {
            let mut acc = 0.0;
            for j in 0..self.hidden {
                acc += h[j] * self.b[j * self.dim_out + k];
            }
            y[k] = acc;
        }
        y
    }

    /// Generate `nodes` shards of `rows_per_node` samples each. With
    /// `non_iid`, node i's inputs are shifted by a node-specific offset.
    pub fn shards(
        &self,
        nodes: usize,
        rows_per_node: usize,
        non_iid: bool,
        seed: u64,
    ) -> Vec<Shard> {
        let mut rng = DeterministicRng::seed(seed ^ 0xDA7A);
        let mut out = Vec::with_capacity(nodes);
        for node in 0..nodes {
            let offset = if non_iid {
                (node as f32 / nodes as f32 - 0.5) * 1.5
            } else {
                0.0
            };
            let mut x = Vec::with_capacity(rows_per_node * self.dim_in);
            let mut y = Vec::with_capacity(rows_per_node * self.dim_out);
            for _ in 0..rows_per_node {
                let row: Vec<f32> = (0..self.dim_in)
                    .map(|_| ((rng.next_f64() as f32) - 0.5) * 2.0 + offset)
                    .collect();
                let mut label = self.label(&row);
                for v in label.iter_mut() {
                    *v += ((rng.next_f64() as f32) - 0.5) * 0.02; // small noise
                }
                x.extend_from_slice(&row);
                y.extend_from_slice(&label);
            }
            out.push(Shard { x, y, rows: rows_per_node });
        }
        out
    }

    /// A held-out IID validation set.
    pub fn validation(&self, rows: usize, seed: u64) -> Shard {
        let mut shards = self.shards(1, rows, false, seed ^ 0x7E57);
        shards.remove(0)
    }
}

impl Shard {
    /// Slice a training batch (wrapping) as (x, y).
    pub fn batch(&self, dim_in: usize, dim_out: usize, batch: usize, step: usize) -> (Vec<f32>, Vec<f32>) {
        let mut x = Vec::with_capacity(batch * dim_in);
        let mut y = Vec::with_capacity(batch * dim_out);
        for b in 0..batch {
            let row = (step * batch + b) % self.rows;
            x.extend_from_slice(&self.x[row * dim_in..(row + 1) * dim_in]);
            y.extend_from_slice(&self.y[row * dim_out..(row + 1) * dim_out]);
        }
        (x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_are_deterministic_and_shaped() {
        let task = SyntheticTask::new(16, 4, 7);
        let s1 = task.shards(3, 32, false, 1);
        let s2 = task.shards(3, 32, false, 1);
        assert_eq!(s1.len(), 3);
        assert_eq!(s1[0].x.len(), 32 * 16);
        assert_eq!(s1[0].y.len(), 32 * 4);
        assert_eq!(s1[0].x, s2[0].x);
        // Different seeds differ.
        let s3 = task.shards(3, 32, false, 2);
        assert_ne!(s1[0].x, s3[0].x);
    }

    #[test]
    fn non_iid_shifts_node_means() {
        let task = SyntheticTask::new(8, 2, 9);
        let shards = task.shards(4, 256, true, 3);
        let mean = |s: &Shard| s.x.iter().sum::<f32>() / s.x.len() as f32;
        assert!(mean(&shards[0]) < mean(&shards[3]), "non-IID shift missing");
    }

    #[test]
    fn batch_wraps() {
        let task = SyntheticTask::new(4, 2, 1);
        let shard = &task.shards(1, 10, false, 1)[0];
        let (x, y) = shard.batch(4, 2, 8, 5); // wraps past 10 rows
        assert_eq!(x.len(), 32);
        assert_eq!(y.len(), 16);
    }
}
