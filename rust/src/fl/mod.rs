//! Federated-learning harness: the end-to-end workload that proves all
//! three layers compose (EXPERIMENTS.md E19).
//!
//! Each round: every learner trains its local MLP replica for a few SGD
//! steps on its private shard (through the PJRT train step when artifacts
//! are built, else the native oracle), then the parameter vectors are
//! combined with a **SAFE secure aggregation round** — weighted by local
//! sample counts (§5.6) — and the global model is broadcast back. The
//! controller never sees an individual learner's parameters.

pub mod dataset;
pub mod trainer;

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::SessionConfig;
use crate::learner::faults::FaultPlan;
use crate::protocols::{weighted, SafeSession};
use crate::runtime::ArtifactRuntime;
use dataset::{Shard, SyntheticTask};
use trainer::{init_params, NativeTrainer, Trainer, XlaTrainer};

/// Configuration of a federated training run.
#[derive(Debug, Clone)]
pub struct FlConfig {
    pub rounds: usize,
    /// Local SGD steps per round.
    pub local_steps: usize,
    pub lr: f32,
    pub rows_per_node: usize,
    pub non_iid: bool,
    pub seed: u64,
}

impl Default for FlConfig {
    fn default() -> Self {
        FlConfig {
            rounds: 20,
            local_steps: 4,
            lr: 0.05,
            rows_per_node: 256,
            non_iid: true,
            seed: 42,
        }
    }
}

/// One round's record for the loss curve.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: usize,
    pub val_loss: f32,
    pub mean_local_loss: f32,
    pub agg_wall_secs: f64,
    pub agg_messages: u64,
}

/// Result of a whole federated run.
#[derive(Debug)]
pub struct FlRunResult {
    pub curve: Vec<RoundRecord>,
    pub final_params: Vec<f32>,
    pub trainer_name: &'static str,
}

/// Pick the best available trainer: XLA artifacts if built, else native.
pub fn default_trainer() -> Result<Arc<dyn Trainer>> {
    let dir = ArtifactRuntime::default_dir();
    if ArtifactRuntime::available(&dir) {
        let rt = Arc::new(ArtifactRuntime::new(dir)?);
        Ok(Arc::new(XlaTrainer::load(rt)?))
    } else {
        Ok(Arc::new(NativeTrainer::default_arch()))
    }
}

/// Run federated training with SAFE aggregation between rounds.
pub fn run_federated(
    session_cfg: &SessionConfig,
    fl_cfg: &FlConfig,
    trainer: Arc<dyn Trainer>,
) -> Result<FlRunResult> {
    let n = session_cfg.n_nodes;
    let task = SyntheticTask::new(trainer.dim_in(), trainer.dim_out(), fl_cfg.seed);
    let shards = task.shards(n, fl_cfg.rows_per_node, fl_cfg.non_iid, fl_cfg.seed);
    let val = task.validation(512.max(trainer.batch()), fl_cfg.seed);

    // SAFE session aggregates the weighted-encoded parameter vector:
    // param_count features + 1 weight feature.
    let mut agg_cfg = session_cfg.clone();
    agg_cfg.features = trainer.param_count();
    agg_cfg.weighted = true;
    let session = SafeSession::new(agg_cfg).context("build SAFE session")?;

    let mut params = init_params(trainer.param_count(), 0.15, fl_cfg.seed ^ 0xFEED);
    let mut curve = Vec::with_capacity(fl_cfg.rounds);

    for round in 0..fl_cfg.rounds {
        // Local training on every node (sequentially here; learner-side
        // wall time is not what E19 measures).
        let mut locals: Vec<Vec<f64>> = Vec::with_capacity(n);
        let mut local_losses = Vec::with_capacity(n);
        for (node, shard) in shards.iter().enumerate() {
            let (p, l) =
                local_train(&*trainer, &params, shard, fl_cfg, round * 7919 + node)?;
            local_losses.push(l);
            let as_f64: Vec<f64> = p.iter().map(|&v| v as f64).collect();
            locals.push(weighted::encode(&as_f64, shard.rows as f64));
        }
        // SAFE aggregation round (weighted by sample counts, §5.6).
        let result = session.run_round(&locals, &FaultPlan::none())?;
        let agreed = result.average().context("no surviving learners")?;
        let global = weighted::decode(agreed)?;
        params = global.iter().map(|&v| v as f32).collect();

        // Validation loss on the shared model.
        let (vx, vy) = val.batch(trainer.dim_in(), trainer.dim_out(), trainer.batch(), 0);
        let val_loss = trainer.loss(&params, &vx, &vy)?;
        curve.push(RoundRecord {
            round,
            val_loss,
            mean_local_loss: local_losses.iter().sum::<f32>() / local_losses.len() as f32,
            agg_wall_secs: result.metrics.secs(),
            agg_messages: result.metrics.messages,
        });
    }
    Ok(FlRunResult { curve, final_params: params, trainer_name: trainer.name() })
}

fn local_train(
    trainer: &dyn Trainer,
    start: &[f32],
    shard: &Shard,
    cfg: &FlConfig,
    step_seed: usize,
) -> Result<(Vec<f32>, f32)> {
    let mut params = start.to_vec();
    let mut last_loss = 0.0f32;
    for s in 0..cfg.local_steps {
        let (x, y) = shard.batch(trainer.dim_in(), trainer.dim_out(), trainer.batch(), step_seed + s);
        let (p, l) = trainer.step(&params, &x, &y, cfg.lr)?;
        params = p;
        last_loss = l;
    }
    Ok((params, last_loss))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceProfile;
    use crate::crypto::envelope::CipherMode;
    use std::time::Duration;

    #[test]
    fn federated_training_reduces_validation_loss() {
        let session_cfg = SessionConfig {
            n_nodes: 4,
            mode: CipherMode::Hybrid,
            rsa_bits: 512,
            profile: DeviceProfile::instant(),
            poll_time: Duration::from_millis(200),
            aggregation_timeout: Duration::from_secs(20),
            progress_timeout: Duration::from_secs(5),
            ..Default::default()
        };
        let fl_cfg = FlConfig { rounds: 8, local_steps: 4, ..Default::default() };
        let trainer: Arc<dyn Trainer> = Arc::new(NativeTrainer::default_arch());
        let result = run_federated(&session_cfg, &fl_cfg, trainer).unwrap();
        let first = result.curve.first().unwrap().val_loss;
        let last = result.curve.last().unwrap().val_loss;
        assert!(
            last < first * 0.7,
            "validation loss did not improve: {first} -> {last}"
        );
        // Aggregation really ran through SAFE each round.
        assert!(result.curve.iter().all(|r| r.agg_messages > 0));
    }
}
