//! Local learner training engines.
//!
//! [`XlaTrainer`] executes the AOT-compiled L2 train step through PJRT —
//! the production path (Python never runs at training time).
//! [`NativeTrainer`] is a pure-Rust implementation of the *same* MLP
//! forward/backward used (a) as a fallback when artifacts are not built
//! and (b) as an independent cross-check oracle in the integration tests.

use std::sync::Arc;

use anyhow::Result;

use crate::runtime::{ArtifactRuntime, TrainStepExecutable};

/// A model trainer over flattened parameter vectors.
pub trait Trainer: Send + Sync {
    fn dim_in(&self) -> usize;
    fn dim_out(&self) -> usize;
    fn batch(&self) -> usize;
    fn param_count(&self) -> usize;
    /// One SGD step; returns (updated params, batch loss).
    fn step(&self, params: &[f32], x: &[f32], y: &[f32], lr: f32) -> Result<(Vec<f32>, f32)>;
    /// Loss without update.
    fn loss(&self, params: &[f32], x: &[f32], y: &[f32]) -> Result<f32>;
    fn name(&self) -> &'static str;
}

/// PJRT-backed trainer (the L2/L1 path).
pub struct XlaTrainer {
    exe: TrainStepExecutable,
}

impl XlaTrainer {
    pub fn load(rt: Arc<ArtifactRuntime>) -> Result<XlaTrainer> {
        Ok(XlaTrainer { exe: TrainStepExecutable::load(rt)? })
    }
}

impl Trainer for XlaTrainer {
    fn dim_in(&self) -> usize {
        self.exe.dim_in
    }
    fn dim_out(&self) -> usize {
        self.exe.dim_out
    }
    fn batch(&self) -> usize {
        self.exe.batch
    }
    fn param_count(&self) -> usize {
        self.exe.param_count()
    }
    fn step(&self, params: &[f32], x: &[f32], y: &[f32], lr: f32) -> Result<(Vec<f32>, f32)> {
        self.exe.step(params, x, y, lr)
    }
    fn loss(&self, params: &[f32], x: &[f32], y: &[f32]) -> Result<f32> {
        self.exe.loss(params, x, y)
    }
    fn name(&self) -> &'static str {
        "xla"
    }
}

/// Pure-Rust MLP (in→hidden tanh→out, MSE) mirroring
/// `python/compile/model.py` exactly; serves as the oracle.
pub struct NativeTrainer {
    pub dim_in: usize,
    pub dim_hidden: usize,
    pub dim_out: usize,
    pub batch_size: usize,
}

impl NativeTrainer {
    pub fn new(dim_in: usize, dim_hidden: usize, dim_out: usize, batch: usize) -> NativeTrainer {
        NativeTrainer { dim_in, dim_hidden, dim_out, batch_size: batch }
    }

    /// Same architecture the artifacts use (manifest defaults).
    pub fn default_arch() -> NativeTrainer {
        NativeTrainer::new(16, 32, 4, 64)
    }

    #[allow(clippy::type_complexity)]
    fn forward(
        &self,
        params: &[f32],
        x: &[f32],
    ) -> (Vec<f32>, Vec<f32>) {
        let (i, h, o, b) = (self.dim_in, self.dim_hidden, self.dim_out, self.batch_size);
        let w1 = &params[..i * h];
        let b1 = &params[i * h..i * h + h];
        let w2 = &params[i * h + h..i * h + h + h * o];
        let b2 = &params[i * h + h + h * o..];
        let mut hid = vec![0.0f32; b * h];
        for r in 0..b {
            for j in 0..h {
                let mut acc = b1[j];
                for k in 0..i {
                    acc += x[r * i + k] * w1[k * h + j];
                }
                hid[r * h + j] = acc.tanh();
            }
        }
        let mut out = vec![0.0f32; b * o];
        for r in 0..b {
            for c in 0..o {
                let mut acc = b2[c];
                for j in 0..h {
                    acc += hid[r * h + j] * w2[j * o + c];
                }
                out[r * o + c] = acc;
            }
        }
        (hid, out)
    }
}

impl Trainer for NativeTrainer {
    fn dim_in(&self) -> usize {
        self.dim_in
    }
    fn dim_out(&self) -> usize {
        self.dim_out
    }
    fn batch(&self) -> usize {
        self.batch_size
    }
    fn param_count(&self) -> usize {
        self.dim_in * self.dim_hidden
            + self.dim_hidden
            + self.dim_hidden * self.dim_out
            + self.dim_out
    }

    fn step(&self, params: &[f32], x: &[f32], y: &[f32], lr: f32) -> Result<(Vec<f32>, f32)> {
        let (i, h, o, b) = (self.dim_in, self.dim_hidden, self.dim_out, self.batch_size);
        let (hid, out) = self.forward(params, x);
        let w1 = &params[..i * h];
        let w2 = &params[i * h + h..i * h + h + h * o];
        let n = (b * o) as f32;
        // loss = mean((out - y)^2); dL/dout = 2(out - y)/n
        let mut loss = 0.0f32;
        let mut dout = vec![0.0f32; b * o];
        for idx in 0..b * o {
            let d = out[idx] - y[idx];
            loss += d * d;
            dout[idx] = 2.0 * d / n;
        }
        loss /= n;
        // Grads.
        let mut gw2 = vec![0.0f32; h * o];
        let mut gb2 = vec![0.0f32; o];
        for r in 0..b {
            for c in 0..o {
                let g = dout[r * o + c];
                gb2[c] += g;
                for j in 0..h {
                    gw2[j * o + c] += hid[r * h + j] * g;
                }
            }
        }
        // dhid = dout·W2ᵀ ⊙ (1 − hid²)
        let mut gw1 = vec![0.0f32; i * h];
        let mut gb1 = vec![0.0f32; h];
        for r in 0..b {
            for j in 0..h {
                let mut g = 0.0f32;
                for c in 0..o {
                    g += dout[r * o + c] * w2[j * o + c];
                }
                let hv = hid[r * h + j];
                g *= 1.0 - hv * hv;
                gb1[j] += g;
                for k in 0..i {
                    gw1[k * h + j] += x[r * i + k] * g;
                }
            }
        }
        let _ = w1;
        // SGD update on the flattened layout [W1|b1|W2|b2].
        let mut new = params.to_vec();
        let mut cursor = 0;
        for g in gw1 {
            new[cursor] -= lr * g;
            cursor += 1;
        }
        for g in gb1 {
            new[cursor] -= lr * g;
            cursor += 1;
        }
        for g in gw2 {
            new[cursor] -= lr * g;
            cursor += 1;
        }
        for g in gb2 {
            new[cursor] -= lr * g;
            cursor += 1;
        }
        Ok((new, loss))
    }

    fn loss(&self, params: &[f32], x: &[f32], y: &[f32]) -> Result<f32> {
        let (_, out) = self.forward(params, x);
        let n = out.len() as f32;
        Ok(out.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / n)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Initialize a parameter vector (uniform ±scale), deterministic per seed.
pub fn init_params(count: usize, scale: f32, seed: u64) -> Vec<f32> {
    use crate::crypto::rng::SecureRng;
    let mut rng = crate::crypto::DeterministicRng::seed(seed);
    (0..count).map(|_| ((rng.next_f64() as f32) - 0.5) * 2.0 * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::dataset::SyntheticTask;

    #[test]
    fn native_trainer_learns() {
        let t = NativeTrainer::default_arch();
        let task = SyntheticTask::new(t.dim_in, t.dim_out, 11);
        let shard = &task.shards(1, 256, false, 5)[0];
        let mut params = init_params(t.param_count(), 0.15, 42);
        let (x0, y0) = shard.batch(t.dim_in, t.dim_out, t.batch_size, 0);
        let l0 = t.loss(&params, &x0, &y0).unwrap();
        for step in 0..120 {
            let (x, y) = shard.batch(t.dim_in, t.dim_out, t.batch_size, step);
            let (p, _l) = t.step(&params, &x, &y, 0.05).unwrap();
            params = p;
        }
        let l1 = t.loss(&params, &x0, &y0).unwrap();
        assert!(l1 < l0 * 0.5, "loss {l0} -> {l1} did not halve");
    }

    #[test]
    fn gradients_match_finite_differences() {
        let t = NativeTrainer::new(3, 4, 2, 8);
        let task = SyntheticTask::new(3, 2, 13);
        let shard = &task.shards(1, 8, false, 1)[0];
        let (x, y) = shard.batch(3, 2, 8, 0);
        let params = init_params(t.param_count(), 0.3, 9);
        let (updated, _) = t.step(&params, &x, &y, 1.0).unwrap();
        // grad = params - updated (lr = 1)
        let eps = 1e-3f32;
        for idx in [0usize, 5, t.param_count() / 2, t.param_count() - 1] {
            let mut pp = params.clone();
            pp[idx] += eps;
            let lp = t.loss(&pp, &x, &y).unwrap();
            pp[idx] -= 2.0 * eps;
            let lm = t.loss(&pp, &x, &y).unwrap();
            let fd = (lp - lm) / (2.0 * eps);
            let analytic = params[idx] - updated[idx];
            assert!(
                (fd - analytic).abs() < 2e-2 * (1.0 + fd.abs()),
                "idx {idx}: fd {fd} vs analytic {analytic}"
            );
        }
    }
}
