//! Figure generators: one function per paper figure (6–20) plus the
//! headline ratio table. Shared by the `cargo bench` targets and the
//! `paper_figures` example so both print identical series.
//!
//! Quick mode (default) uses trimmed sweeps and `SAFE_BENCH_REPEATS`
//! (default 5) repeats; `SAFE_BENCH_FULL=1` restores the paper's exact
//! sweeps (30 repeats edge / 5 deep-edge, 100-node maxima).

use std::time::Duration;

use anyhow::Result;

use super::{bench_repeats, full_scale, Figure};
use crate::config::{DeviceProfile, SessionConfig};
use crate::crypto::envelope::CipherMode;
use crate::learner::faults::FaultPlan;
use crate::metrics::RoundMetrics;
use crate::protocols::bon::BonSession;
use crate::protocols::insec::InsecSession;
use crate::protocols::SafeSession;
use crate::transport::NetProfile;

/// Which protocol/variant a series runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    Insec,
    Saf,  // SAFE minus encryption
    Safe, // hybrid encryption
    Bon,
}

impl Variant {
    pub fn label(&self) -> &'static str {
        match self {
            Variant::Insec => "INSEC",
            Variant::Saf => "SAF",
            Variant::Safe => "SAFE",
            Variant::Bon => "BON",
        }
    }
}

/// Base session config for the edge platform (§6).
pub fn edge_cfg(n: usize, features: usize) -> SessionConfig {
    SessionConfig {
        n_nodes: n,
        features,
        rsa_bits: 1024,
        profile: DeviceProfile::edge(),
        poll_time: Duration::from_millis(400),
        aggregation_timeout: Duration::from_secs(120),
        progress_timeout: Duration::from_secs(30),
        monitor_interval: Duration::from_millis(200),
        seed: Some(42),
        ..Default::default()
    }
}

/// Base config for the simulated deep-edge platform (§7).
pub fn deep_edge_cfg(n: usize, features: usize) -> SessionConfig {
    SessionConfig {
        profile: DeviceProfile::deep_edge(),
        mode: CipherMode::PreNegotiated,
        ..edge_cfg(n, features)
    }
}

/// Run `repeats` rounds of `variant` and return the metrics.
pub fn run_variant(
    variant: Variant,
    mut cfg: SessionConfig,
    faults: &FaultPlan,
    repeats: usize,
) -> Result<Vec<RoundMetrics>> {
    let inputs: Vec<Vec<f64>> = (0..cfg.n_nodes)
        .map(|i| (0..cfg.features).map(|f| (i + 1) as f64 + 0.001 * f as f64).collect())
        .collect();
    match variant {
        Variant::Insec => {
            let session = InsecSession::new(cfg)?;
            (0..repeats).map(|_| session.run_round(&inputs, faults)).collect()
        }
        Variant::Saf => {
            cfg.mode = CipherMode::None;
            let session = SafeSession::new(cfg)?;
            (0..repeats)
                .map(|_| session.run_round(&inputs, faults).map(|r| r.metrics))
                .collect()
        }
        Variant::Safe => {
            if cfg.profile.name != "deep-edge" {
                cfg.mode = CipherMode::Hybrid;
            }
            let session = SafeSession::new(cfg)?;
            (0..repeats)
                .map(|_| session.run_round(&inputs, faults).map(|r| r.metrics))
                .collect()
        }
        Variant::Bon => {
            let session = BonSession::new(cfg)?;
            (0..repeats).map(|_| session.run_round(&inputs, faults)).collect()
        }
    }
}

fn node_sweep_small() -> Vec<usize> {
    if full_scale() {
        vec![3, 4, 5, 6, 8, 10, 12, 15]
    } else {
        vec![3, 5, 8, 10, 15]
    }
}

fn node_sweep_large() -> Vec<usize> {
    if full_scale() {
        vec![3, 10, 25, 50, 75, 100]
    } else {
        vec![3, 10, 20, 36]
    }
}

fn feature_sweep() -> Vec<usize> {
    if full_scale() {
        vec![1, 10, 100, 1000, 2000, 5000, 10000]
    } else {
        vec![1, 10, 100, 1000, 10000]
    }
}

fn node_sweep_figure(
    id: &str,
    title: &str,
    nodes: &[usize],
    features: usize,
    variants: &[Variant],
    repeats: usize,
) -> Result<Figure> {
    let mut fig = Figure::new(id, title, "nodes", 3.0);
    for &n in nodes {
        for &v in variants {
            let cfg = edge_cfg(n, features);
            let rounds = run_variant(v, cfg, &FaultPlan::none(), repeats)?;
            fig.push_point(v.label(), n as f64, &rounds);
        }
    }
    Ok(fig)
}

/// Fig 6 — Edge, 1 feature, 3–15 nodes, INSEC/SAF/SAFE/BON.
pub fn fig6() -> Result<Figure> {
    node_sweep_figure(
        "fig6",
        "Edge. BON 1 Feature.",
        &node_sweep_small(),
        1,
        &[Variant::Insec, Variant::Saf, Variant::Safe, Variant::Bon],
        bench_repeats(5),
    )
}

/// Fig 7 — Edge, 1 feature, up to 100 nodes, INSEC/SAF/SAFE.
pub fn fig7() -> Result<Figure> {
    node_sweep_figure(
        "fig7",
        "Edge. 1 Feature.",
        &node_sweep_large(),
        1,
        &[Variant::Insec, Variant::Saf, Variant::Safe],
        bench_repeats(5),
    )
}

/// Fig 8 — Edge, 10000 features, 3–15 nodes incl. BON.
pub fn fig8() -> Result<Figure> {
    node_sweep_figure(
        "fig8",
        "Edge. BON 10000 Features.",
        &node_sweep_small(),
        10_000,
        &[Variant::Insec, Variant::Saf, Variant::Safe, Variant::Bon],
        bench_repeats(3),
    )
}

/// Fig 9 — Edge, 10000 features, up to 100 nodes.
pub fn fig9() -> Result<Figure> {
    node_sweep_figure(
        "fig9",
        "Edge. 10000 Features.",
        &node_sweep_large(),
        10_000,
        &[Variant::Insec, Variant::Saf, Variant::Safe],
        bench_repeats(3),
    )
}

fn feature_sweep_figure(
    id: &str,
    title: &str,
    n: usize,
    variants: &[Variant],
    repeats: usize,
) -> Result<Figure> {
    let mut fig = Figure::new(id, title, "features", 3.0);
    for &f in &feature_sweep() {
        for &v in variants {
            let cfg = edge_cfg(n, f);
            let rounds = run_variant(v, cfg, &FaultPlan::none(), repeats)?;
            fig.push_point(v.label(), f as f64, &rounds);
        }
    }
    Ok(fig)
}

/// Fig 10 — Edge, 3 nodes, feature sweep incl. BON.
pub fn fig10() -> Result<Figure> {
    feature_sweep_figure(
        "fig10",
        "Edge. BON 3 Nodes.",
        3,
        &[Variant::Insec, Variant::Saf, Variant::Safe, Variant::Bon],
        bench_repeats(3),
    )
}

/// Fig 11 — Edge, 15 nodes, feature sweep incl. BON (crossover ~2000).
pub fn fig11() -> Result<Figure> {
    feature_sweep_figure(
        "fig11",
        "Edge. BON 15 Nodes.",
        15,
        &[Variant::Insec, Variant::Saf, Variant::Safe, Variant::Bon],
        bench_repeats(3),
    )
}

/// Fig 12 — Edge, 100 nodes (36 quick), feature sweep (crossover ~100).
pub fn fig12() -> Result<Figure> {
    let n = if full_scale() { 100 } else { 36 };
    feature_sweep_figure(
        "fig12",
        "Edge. 100 Nodes.",
        n,
        &[Variant::Insec, Variant::Saf, Variant::Safe],
        bench_repeats(3),
    )
}

/// Failover node sweep used by Figs 13/14 and the headline table.
/// Follows §6.3: compare `n` completed nodes without failures against
/// `n + 3` nodes where nodes 4–6 fail, so contributor counts match.
pub fn failover_points() -> Vec<usize> {
    if full_scale() {
        vec![9, 15, 21, 27, 33]
    } else {
        vec![9, 21, 33]
    }
}

/// §6.3 timeout budgets (paper: predicted completion + safety margin,
/// with ΣSAFE per-node timeouts == BON global timeout). These are the
/// clean-LAN floors; call [`safe_node_timeout`] / [`bon_global_timeout`]
/// to get the budget honest under the active [`NetProfile`].
pub const SAFE_NODE_TIMEOUT: Duration = Duration::from_millis(200);
pub const BON_GLOBAL_TIMEOUT: Duration = Duration::from_millis(600);

/// §6.3 per-node progress timeout derived from the network profile: the
/// 200 ms clean-LAN constant, stretched to 16 expected RTTs when the
/// profile is slower than that (a progress check spans several
/// poll + post exchanges, each costing an RTT plus retry backoffs).
/// Identical to [`SAFE_NODE_TIMEOUT`] under the ideal profile.
pub fn safe_node_timeout(net: &NetProfile) -> Duration {
    net.budget(SAFE_NODE_TIMEOUT, 16)
}

/// BON's global round-2 close timeout under `net`: three SAFE per-node
/// budgets, preserving the paper's ΣSAFE == BON comparison rule at every
/// profile. Identical to [`BON_GLOBAL_TIMEOUT`] under the ideal profile.
pub fn bon_global_timeout(net: &NetProfile) -> Duration {
    net.budget(BON_GLOBAL_TIMEOUT, 48)
}

/// Fig 13 — aggregation time vs completed nodes, SAFE/BON ± failover.
pub fn fig13() -> Result<Figure> {
    let repeats = bench_repeats(3);
    let mut fig = Figure::new("fig13", "Edge. Failover.", "completed_nodes", 3.0);
    for &completed in &failover_points() {
        // No-failure runs with exactly `completed` nodes.
        let safe = run_variant(Variant::Safe, edge_cfg(completed, 1), &FaultPlan::none(), repeats)?;
        fig.push_point("SAFE", completed as f64, &safe);
        let bon = run_variant(Variant::Bon, edge_cfg(completed, 1), &FaultPlan::none(), repeats)?;
        fig.push_point("BON", completed as f64, &bon);
        // Failure runs with completed+3 nodes, killing 4..6 (§6.3). The
        // paper's apples-to-apples rule: "we kept the sum of all failed
        // node timeouts in SAFE the same as the global BON timeout" —
        // SAFE gets 3 × 200 ms per-node progress timeouts, BON one 600 ms
        // round-2 close timeout.
        let faults = FaultPlan::kill_range(4, 6);
        let mut cfg = edge_cfg(completed + 3, 1);
        cfg.progress_timeout = safe_node_timeout(&cfg.net);
        cfg.monitor_interval = Duration::from_millis(50);
        let safe_f = run_variant(Variant::Safe, cfg, &faults, repeats)?;
        fig.push_point("SAFE+failover", completed as f64, &safe_f);
        let mut cfg = edge_cfg(completed + 3, 1);
        cfg.progress_timeout = bon_global_timeout(&cfg.net);
        let bon_f = run_variant(Variant::Bon, cfg, &faults, repeats)?;
        fig.push_point("BON+failover", completed as f64, &bon_f);
    }
    Ok(fig)
}

/// Fig 14 — failover *overhead*: failure-run time minus the failure
/// timeout budget (§6.3 subtracts the expected timeout wait).
pub fn fig14(fig13: &Figure) -> Figure {
    let mut fig = Figure::new(
        "fig14",
        "Edge. Failover Overhead.",
        "completed_nodes",
        3.0,
    );
    // Timeout budget: SAFE waits progress_timeout per failed node; BON
    // waits one round-2 close timeout. Subtract those from the failover
    // series to isolate protocol overhead, like the paper (§6.3: "we
    // subtract the expected failure timeout time ... from the overall
    // aggregation time").
    // The fig13 runs use edge_cfg's default (ideal) profile, so the
    // derived budgets equal the clean-LAN constants there.
    let safe_budget = safe_node_timeout(&NetProfile::default()).as_secs_f64() * 3.0;
    let bon_budget = bon_global_timeout(&NetProfile::default()).as_secs_f64();
    for series in &fig13.series {
        let (label, budget) = match series.label.as_str() {
            "SAFE+failover" => ("SAFE overhead", safe_budget),
            "BON+failover" => ("BON overhead", bon_budget),
            _ => continue,
        };
        for p in &series.points {
            let mut stats = p.stats.clone();
            stats.mean_secs = (stats.mean_secs - budget).max(0.0);
            fig.series
                .iter_mut()
                .find(|s| s.label == label)
                .map(|s| s.points.push(super::SeriesPoint { x: p.x, stats: stats.clone() }))
                .unwrap_or_else(|| {
                    fig.series.push(super::Series {
                        label: label.to_string(),
                        points: vec![super::SeriesPoint { x: p.x, stats }],
                    })
                });
        }
    }
    fig
}

/// Deep-edge node sweep (Figs 15/16): SAFE = pre-negotiated symmetric.
pub fn deep_edge_nodes(id: &str, title: &str, features: usize) -> Result<Figure> {
    let repeats = bench_repeats(3);
    let mut fig = Figure::new(id, title, "nodes", 4.0);
    let nodes: Vec<usize> = if full_scale() { vec![3, 6, 9, 12] } else { vec![3, 6, 12] };
    for &n in &nodes {
        for v in [Variant::Insec, Variant::Saf, Variant::Safe] {
            let mut cfg = deep_edge_cfg(n, features);
            if v == Variant::Saf {
                cfg.mode = CipherMode::None;
            }
            let rounds = run_variant(v, cfg, &FaultPlan::none(), repeats)?;
            fig.push_point(v.label(), n as f64, &rounds);
        }
    }
    Ok(fig)
}

/// Deep-edge feature sweep (Figs 17/18).
pub fn deep_edge_features(id: &str, title: &str, n: usize) -> Result<Figure> {
    let repeats = bench_repeats(3);
    let mut fig = Figure::new(id, title, "features", 4.0);
    for &f in &[1usize, 5, 10, 20] {
        for v in [Variant::Saf, Variant::Safe] {
            let mut cfg = deep_edge_cfg(n, f);
            if v == Variant::Saf {
                cfg.mode = CipherMode::None;
            }
            let rounds = run_variant(v, cfg, &FaultPlan::none(), repeats)?;
            fig.push_point(v.label(), f as f64, &rounds);
        }
    }
    Ok(fig)
}

/// Subgrouping figures (19/20): 12 deep-edge nodes, 1×12 → 4×3.
pub fn subgroup_figure(id: &str, title: &str, features: usize) -> Result<Figure> {
    let repeats = bench_repeats(3);
    let mut fig = Figure::new(id, title, "groups", 4.0);
    for groups in [1usize, 2, 3, 4] {
        let mut cfg = deep_edge_cfg(12, features);
        cfg.groups = groups;
        let rounds = run_variant(Variant::Safe, cfg, &FaultPlan::none(), repeats)?;
        fig.push_point("SAFE", groups as f64, &rounds);
    }
    Ok(fig)
}

/// The headline claim (abstract / §6.3): BON/SAFE time ratios at 24 and
/// 36 nodes, with and without failover. Returns rows of
/// (completed_nodes, ratio_no_failover, ratio_failover).
pub fn headline_ratios(fig13: &Figure) -> Vec<(f64, Option<f64>, Option<f64>)> {
    let xs: Vec<f64> = fig13
        .series
        .first()
        .map(|s| s.points.iter().map(|p| p.x).collect())
        .unwrap_or_default();
    xs.into_iter()
        .map(|x| {
            (
                x,
                fig13.ratio_at("BON", "SAFE", x),
                fig13.ratio_at("BON+failover", "SAFE+failover", x),
            )
        })
        .collect()
}
