//! Benchmark harness (criterion is not in the offline crate cache).
//!
//! Runs each condition for a configured number of repeats, reports mean ±
//! k·σ exactly like the paper's figures (30 repeats / 3σ edge, 5 repeats /
//! 4σ deep-edge), prints aligned tables to stdout and appends CSV rows to
//! `bench_out/` for regeneration of every figure.

pub mod figures;
pub mod multiround;
pub mod netbench;
pub mod scale;

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::PathBuf;

use crate::metrics::{RepeatStats, RoundMetrics};

/// One measured condition (a point on a paper figure).
#[derive(Debug, Clone)]
pub struct SeriesPoint {
    /// x value (nodes or features).
    pub x: f64,
    pub stats: RepeatStats,
}

/// A labelled line on a figure (e.g. "SAFE", "BON", "INSEC").
#[derive(Debug, Clone)]
pub struct Series {
    pub label: String,
    pub points: Vec<SeriesPoint>,
}

/// A whole figure: title + x-axis label + one or more series.
#[derive(Debug, Clone, Default)]
pub struct Figure {
    pub id: String,
    pub title: String,
    pub x_label: String,
    pub sigma_band: f64,
    pub series: Vec<Series>,
}

impl Figure {
    pub fn new(id: &str, title: &str, x_label: &str, sigma_band: f64) -> Figure {
        Figure {
            id: id.to_string(),
            title: title.to_string(),
            x_label: x_label.to_string(),
            sigma_band,
            series: Vec::new(),
        }
    }

    pub fn push_point(&mut self, label: &str, x: f64, rounds: &[RoundMetrics]) {
        let stats = RepeatStats::from_rounds(rounds);
        if let Some(s) = self.series.iter_mut().find(|s| s.label == label) {
            s.points.push(SeriesPoint { x, stats });
        } else {
            self.series.push(Series {
                label: label.to_string(),
                points: vec![SeriesPoint { x, stats }],
            });
        }
    }

    /// Render as an aligned text table (the "rows the paper reports").
    pub fn to_table(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "── {} — {} ──", self.id, self.title);
        let _ = write!(out, "{:>10}", self.x_label);
        for s in &self.series {
            let _ = write!(out, "  {:>22}", s.label);
        }
        let _ = writeln!(out);
        // Collect the x values from the longest series.
        let xs: Vec<f64> = self
            .series
            .iter()
            .max_by_key(|s| s.points.len())
            .map(|s| s.points.iter().map(|p| p.x).collect())
            .unwrap_or_default();
        for x in xs {
            let _ = write!(out, "{:>10}", x);
            for s in &self.series {
                match s.points.iter().find(|p| p.x == x) {
                    Some(p) => {
                        let _ = write!(
                            out,
                            "  {:>12.4}s ±{:>7.4}",
                            p.stats.mean_secs,
                            p.stats.band(self.sigma_band)
                        );
                    }
                    None => {
                        let _ = write!(out, "  {:>22}", "—");
                    }
                }
            }
            let _ = writeln!(out);
        }
        out
    }

    /// CSV rows: figure,series,x,mean_secs,stddev_secs,band,mean_messages,repeats
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "figure,series,x,mean_secs,stddev_secs,band,mean_messages,repeats\n",
        );
        for s in &self.series {
            for p in &s.points {
                let _ = writeln!(
                    out,
                    "{},{},{},{:.6},{:.6},{:.6},{:.1},{}",
                    self.id,
                    s.label,
                    p.x,
                    p.stats.mean_secs,
                    p.stats.stddev_secs,
                    p.stats.band(self.sigma_band),
                    p.stats.mean_messages,
                    p.stats.repeats
                );
            }
        }
        out
    }

    /// Write CSV under `bench_out/<id>.csv` and print the table.
    pub fn emit(&self, out_dir: Option<&str>) {
        println!("{}", self.to_table());
        let dir = PathBuf::from(out_dir.unwrap_or("bench_out"));
        if std::fs::create_dir_all(&dir).is_ok() {
            let path = dir.join(format!("{}.csv", self.id));
            if let Ok(mut f) = std::fs::File::create(&path) {
                let _ = f.write_all(self.to_csv().as_bytes());
            }
        }
    }

    /// Ratio of two series' means at a given x (e.g. BON/SAFE at 36 nodes).
    pub fn ratio_at(&self, numerator: &str, denominator: &str, x: f64) -> Option<f64> {
        let get = |label: &str| {
            self.series
                .iter()
                .find(|s| s.label == label)?
                .points
                .iter()
                .find(|p| p.x == x)
                .map(|p| p.stats.mean_secs)
        };
        Some(get(numerator)? / get(denominator)?)
    }
}

/// Repeat a round-producing closure `repeats` times.
pub fn repeat_rounds(
    repeats: usize,
    mut f: impl FnMut(usize) -> anyhow::Result<RoundMetrics>,
) -> anyhow::Result<Vec<RoundMetrics>> {
    let mut out = Vec::with_capacity(repeats);
    for i in 0..repeats {
        out.push(f(i)?);
    }
    Ok(out)
}

/// Bench-wide knobs from the environment so `cargo bench` stays fast by
/// default but can reproduce the paper's full repeat counts:
/// `SAFE_BENCH_REPEATS` (default 5), `SAFE_BENCH_FULL=1` (paper scale).
pub fn bench_repeats(default: usize) -> usize {
    std::env::var("SAFE_BENCH_REPEATS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

pub fn full_scale() -> bool {
    std::env::var("SAFE_BENCH_FULL").map_or(false, |v| v == "1")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn rounds(secs: &[f64]) -> Vec<RoundMetrics> {
        secs.iter()
            .map(|&s| RoundMetrics {
                wall_time: Duration::from_secs_f64(s),
                messages: 12,
                bytes_sent: 0,
                bytes_received: 0,
                average: vec![],
                contributors: 3,
                progress_failovers: 0,
                initiator_failovers: 0,
                rekey_messages: 0,
                merged_groups: 0,
                reassigned_nodes: 0,
                deadline_exceeded: 0,
                net_retries: 0,
                net_drops: 0,
                dedup_posts: 0,
                per_path: Default::default(),
                fanin_messages: 0,
                fanin_latency: Duration::ZERO,
                shard_messages: vec![],
            })
            .collect()
    }

    #[test]
    fn figure_table_and_csv() {
        let mut fig = Figure::new("fig6", "Edge. BON 1 Feature.", "nodes", 3.0);
        fig.push_point("SAFE", 3.0, &rounds(&[0.1, 0.12, 0.11]));
        fig.push_point("SAFE", 5.0, &rounds(&[0.2, 0.21, 0.19]));
        fig.push_point("BON", 3.0, &rounds(&[0.5, 0.55, 0.52]));
        let table = fig.to_table();
        assert!(table.contains("fig6"));
        assert!(table.contains("SAFE"));
        assert!(table.contains("BON"));
        let csv = fig.to_csv();
        assert_eq!(csv.lines().count(), 4); // header + 3 rows
        assert!(csv.contains("fig6,SAFE,3,"));
    }

    #[test]
    fn ratio_at_works() {
        let mut fig = Figure::new("f", "t", "nodes", 3.0);
        fig.push_point("BON", 36.0, &rounds(&[5.6]));
        fig.push_point("SAFE", 36.0, &rounds(&[0.1]));
        let r = fig.ratio_at("BON", "SAFE", 36.0).unwrap();
        assert!((r - 56.0).abs() < 1e-9);
        assert!(fig.ratio_at("BON", "SAFE", 99.0).is_none());
    }
}
