//! Multi-round failover reporting: per-round cost of churn and the
//! amortized setup accounting the multi-round engine exists to improve.
//!
//! The paper prices one aggregation at `4n + 2f` messages (§5.2/§5.3)
//! and key exchange at a separate, one-time round 0 (footnote 3). A
//! session that aggregates R rounds over persistent learners pays round 0
//! once, plus a per-rejoin re-key when churned-out nodes return — so the
//! *amortized* setup cost per round is `(round0 + Σ rekey) / R`, which
//! shrinks as R grows. This module runs an R-round churn scenario and
//! renders exactly that table (text, CSV under `bench_out/`, and a JSON
//! value for `BENCH_multiround.json`).

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::PathBuf;

use anyhow::Result;

use crate::json::Value;
use crate::learner::faults::ChurnSchedule;
use crate::metrics::RoundMetrics;
use crate::protocols::SafeSession;

/// One row of the per-round failover table.
#[derive(Debug, Clone)]
pub struct RoundRow {
    /// 1-based round number.
    pub round: u64,
    pub secs: f64,
    /// Protocol messages this round (monitor + rekey excluded).
    pub messages: u64,
    /// Key re-exchange messages (nonzero only on rejoin or merge rounds).
    pub rekey_messages: u64,
    pub contributors: u64,
    pub progress_failovers: u64,
    pub initiator_failovers: u64,
    /// Groups dissolved by privacy-floor merge re-balancing this round.
    pub merged_groups: u64,
    /// Nodes aggregated outside their home group this round.
    pub reassigned_nodes: u64,
    /// Attempts re-sent after retryable transport faults this round.
    pub net_retries: u64,
    /// Injected packet drops observed by the transport this round.
    pub net_drops: u64,
    /// Duplicate posts absorbed by the controller's dedup token.
    pub dedup_posts: u64,
}

impl RoundRow {
    /// Messages beyond the failure-free `4·contributors` floor — the
    /// per-round failover cost (`2f` plus any subgroup pulls). Transport
    /// retries are physical resends of the same logical message, so they
    /// are subtracted first: the paper's formulas bound logical traffic.
    pub fn failover_extra(&self) -> i64 {
        self.messages as i64 - self.net_retries as i64 - 4 * self.contributors as i64
    }
}

/// An R-round churn scenario's results plus the setup amortization.
#[derive(Debug, Clone)]
pub struct MultiRoundReport {
    pub id: String,
    pub rows: Vec<RoundRow>,
    /// One-time round-0 key-exchange messages at session build.
    pub setup_messages: u64,
}

impl MultiRoundReport {
    pub fn from_rounds(id: &str, setup_messages: u64, rounds: &[RoundMetrics]) -> Self {
        MultiRoundReport {
            id: id.to_string(),
            setup_messages,
            rows: rounds
                .iter()
                .enumerate()
                .map(|(i, m)| RoundRow {
                    round: (i + 1) as u64,
                    secs: m.secs(),
                    messages: m.messages,
                    rekey_messages: m.rekey_messages,
                    contributors: m.contributors,
                    progress_failovers: m.progress_failovers,
                    initiator_failovers: m.initiator_failovers,
                    merged_groups: m.merged_groups,
                    reassigned_nodes: m.reassigned_nodes,
                    net_retries: m.net_retries,
                    net_drops: m.net_drops,
                    dedup_posts: m.dedup_posts,
                })
                .collect(),
        }
    }

    /// Total rejoin re-key messages across all rounds.
    pub fn rekey_total(&self) -> u64 {
        self.rows.iter().map(|r| r.rekey_messages).sum()
    }

    /// `(round0 + Σ rekey) / R` — the number the multi-round engine
    /// drives down as R grows.
    pub fn amortized_setup_per_round(&self) -> f64 {
        (self.setup_messages + self.rekey_total()) as f64 / self.rows.len().max(1) as f64
    }

    /// Round wall-time quantiles `(p50, p95, p99)` in seconds, estimated
    /// through the same fixed-bucket histogram layout the session
    /// registry uses for `safe_round_duration_seconds` — so the table
    /// and `BENCH_multiround.json` agree with a `/metrics` scrape of the
    /// run, at bucket (not sample) resolution.
    pub fn round_quantiles(&self) -> (f64, f64, f64) {
        let edges: Vec<f64> =
            crate::metrics::DEFAULT_LATENCY_EDGES.iter().map(|e| e * 10.0).collect();
        let h = crate::metrics::Histogram::new(&edges);
        for r in &self.rows {
            h.observe(r.secs);
        }
        (h.quantile(0.5), h.quantile(0.95), h.quantile(0.99))
    }

    /// Aligned text table, one row per round plus the amortization line.
    pub fn to_table(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "── {} — per-round failover cost ──", self.id);
        let _ = writeln!(
            out,
            "{:>5} {:>9} {:>9} {:>8} {:>7} {:>13} {:>11} {:>7} {:>7} {:>10} {:>7} {:>6} {:>6}",
            "round",
            "secs",
            "messages",
            "extra",
            "rekey",
            "contributors",
            "progress_f",
            "init_f",
            "merges",
            "reassigned",
            "retries",
            "drops",
            "dedup"
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{:>5} {:>9.4} {:>9} {:>8} {:>7} {:>13} {:>11} {:>7} {:>7} {:>10} {:>7} {:>6} {:>6}",
                r.round,
                r.secs,
                r.messages,
                r.failover_extra(),
                r.rekey_messages,
                r.contributors,
                r.progress_failovers,
                r.initiator_failovers,
                r.merged_groups,
                r.reassigned_nodes,
                r.net_retries,
                r.net_drops,
                r.dedup_posts
            );
        }
        let _ = writeln!(
            out,
            "setup: {} round-0 + {} rekey messages over {} rounds = {:.2} amortized/round",
            self.setup_messages,
            self.rekey_total(),
            self.rows.len(),
            self.amortized_setup_per_round()
        );
        let (p50, p95, p99) = self.round_quantiles();
        let _ = writeln!(
            out,
            "round wall time: p50 {p50:.4}s p95 {p95:.4}s p99 {p99:.4}s (histogram-bucketed)"
        );
        out
    }

    /// CSV rows mirroring [`MultiRoundReport::to_table`].
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "id,round,secs,messages,failover_extra,rekey_messages,contributors,\
             progress_failovers,initiator_failovers,merged_groups,reassigned_nodes,\
             net_retries,net_drops,dedup_posts\n",
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{},{},{:.6},{},{},{},{},{},{},{},{},{},{},{}",
                self.id,
                r.round,
                r.secs,
                r.messages,
                r.failover_extra(),
                r.rekey_messages,
                r.contributors,
                r.progress_failovers,
                r.initiator_failovers,
                r.merged_groups,
                r.reassigned_nodes,
                r.net_retries,
                r.net_drops,
                r.dedup_posts
            );
        }
        out
    }

    /// Machine-readable form for `BENCH_multiround.json`.
    pub fn to_json(&self) -> Value {
        let rows: Vec<Value> = self
            .rows
            .iter()
            .map(|r| {
                Value::object(vec![
                    ("round", Value::from(r.round)),
                    ("secs", Value::from(r.secs)),
                    ("messages", Value::from(r.messages)),
                    ("failover_extra", Value::from(r.failover_extra() as f64)),
                    ("rekey_messages", Value::from(r.rekey_messages)),
                    ("contributors", Value::from(r.contributors)),
                    ("progress_failovers", Value::from(r.progress_failovers)),
                    ("initiator_failovers", Value::from(r.initiator_failovers)),
                    ("merged_groups", Value::from(r.merged_groups)),
                    ("reassigned_nodes", Value::from(r.reassigned_nodes)),
                    ("net_retries", Value::from(r.net_retries)),
                    ("net_drops", Value::from(r.net_drops)),
                    ("dedup_posts", Value::from(r.dedup_posts)),
                ])
            })
            .collect();
        let (p50, p95, p99) = self.round_quantiles();
        Value::object(vec![
            ("id", Value::from(self.id.as_str())),
            ("setup_messages", Value::from(self.setup_messages)),
            ("rekey_total", Value::from(self.rekey_total())),
            ("amortized_setup_per_round", Value::from(self.amortized_setup_per_round())),
            ("round_secs_p50", Value::from(p50)),
            ("round_secs_p95", Value::from(p95)),
            ("round_secs_p99", Value::from(p99)),
            ("rounds", Value::Arr(rows)),
        ])
    }

    /// Print the table and write `bench_out/<id>.csv` (same convention as
    /// [`super::Figure::emit`]).
    pub fn emit(&self, out_dir: Option<&str>) {
        println!("{}", self.to_table());
        let dir = PathBuf::from(out_dir.unwrap_or("bench_out"));
        if std::fs::create_dir_all(&dir).is_ok() {
            let path = dir.join(format!("{}.csv", self.id));
            if let Ok(mut f) = std::fs::File::create(&path) {
                let _ = f.write_all(self.to_csv().as_bytes());
            }
        }
    }
}

/// Run the canonical multi-round churn scenario: `n` edge nodes, `rounds`
/// rounds, node 4 dying in round 1 and rejoining in round 3 (the
/// die → re-form → rejoin arc every multi-round deployment must survive).
pub fn multi_round_failover(n: usize, rounds: usize) -> Result<MultiRoundReport> {
    use crate::learner::faults::FailPoint;
    let mut cfg = super::figures::edge_cfg(n, 1);
    cfg.progress_timeout = super::figures::safe_node_timeout(&cfg.net);
    cfg.monitor_interval = std::time::Duration::from_millis(50);
    let churn = ChurnSchedule::none().die(4, 1, FailPoint::NeverStart).rejoin(4, 3);
    run_schedule("multiround_failover", cfg, rounds, &churn)
}

/// Run `rounds` rounds of `cfg` under `churn` and package the report.
pub fn run_schedule(
    id: &str,
    cfg: crate::config::SessionConfig,
    rounds: usize,
    churn: &ChurnSchedule,
) -> Result<MultiRoundReport> {
    let inputs: Vec<Vec<f64>> = (0..cfg.n_nodes)
        .map(|i| (0..cfg.features).map(|f| (i + 1) as f64 + 0.001 * f as f64).collect())
        .collect();
    let per_round: Vec<Vec<Vec<f64>>> = (0..rounds).map(|_| inputs.clone()).collect();
    let session = SafeSession::new(cfg)?;
    let setup = session.round0_messages;
    let results = session.run_rounds(&per_round, churn)?;
    let metrics: Vec<RoundMetrics> = results.into_iter().map(|r| r.metrics).collect();
    Ok(MultiRoundReport::from_rounds(id, setup, &metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn rows() -> Vec<RoundMetrics> {
        (0..3)
            .map(|i| RoundMetrics {
                wall_time: Duration::from_millis(100 + i * 10),
                messages: 20,
                bytes_sent: 0,
                bytes_received: 0,
                average: vec![],
                contributors: 5,
                progress_failovers: u64::from(i == 0),
                initiator_failovers: 0,
                rekey_messages: if i == 2 { 9 } else { 0 },
                merged_groups: u64::from(i == 1),
                reassigned_nodes: if i == 1 { 2 } else { 0 },
                deadline_exceeded: 0,
                net_retries: u64::from(i == 2),
                net_drops: u64::from(i == 2),
                dedup_posts: 0,
                per_path: Default::default(),
                fanin_messages: 0,
                fanin_latency: Duration::ZERO,
                shard_messages: vec![],
            })
            .collect()
    }

    #[test]
    fn report_table_csv_json_agree() {
        let rep = MultiRoundReport::from_rounds("t", 40, &rows());
        assert_eq!(rep.rekey_total(), 9);
        assert!((rep.amortized_setup_per_round() - 49.0 / 3.0).abs() < 1e-9);
        let table = rep.to_table();
        assert!(table.contains("amortized/round"));
        assert_eq!(rep.to_csv().lines().count(), 4); // header + 3 rounds
        let json = rep.to_json();
        assert_eq!(json.u64_of("setup_messages"), Some(40));
        assert_eq!(json.u64_of("rekey_total"), Some(9));
        assert_eq!(json.get("rounds").unwrap().as_arr().unwrap().len(), 3);
        // Registry-bucketed round wall-time quantiles ride along in the
        // table and JSON; the rows span 0.10–0.12s so every quantile must
        // land inside the enclosing histogram bucket (0.1, 0.25].
        assert!(table.contains("round wall time: p50"));
        let p50 = json.get("round_secs_p50").and_then(|v| v.as_f64()).unwrap();
        let p99 = json.get("round_secs_p99").and_then(|v| v.as_f64()).unwrap();
        assert!(p50 > 0.1 && p50 <= 0.25, "p50 {p50} outside enclosing bucket");
        assert!(p99 >= p50 && p99 <= 0.25);
    }

    #[test]
    fn failover_extra_is_2f_shaped() {
        let r = RoundRow {
            round: 1,
            secs: 0.1,
            messages: 4 * 5 + 2,
            rekey_messages: 0,
            contributors: 5,
            progress_failovers: 1,
            initiator_failovers: 0,
            merged_groups: 0,
            reassigned_nodes: 0,
            net_retries: 0,
            net_drops: 0,
            dedup_posts: 0,
        };
        assert_eq!(r.failover_extra(), 2);
        // A retried attempt is a physical resend, not extra logical
        // traffic: the floor comparison subtracts it back out.
        let retried = RoundRow { messages: 4 * 5 + 2 + 3, net_retries: 3, ..r };
        assert_eq!(retried.failover_extra(), 2);
    }
}
