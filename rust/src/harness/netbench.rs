//! Hostile-network bench: the §5.3/§5.4 failover machinery exercised
//! under injected transport faults ([`NetProfile`]) instead of only
//! scheduled deaths.
//!
//! For each profile in [`NetBenchConfig::profiles`] the bench runs
//!
//! 1. the single-round failure matrix at small n — one scenario per
//!    fault position the paper calls out (clean chain, mid-chain death
//!    before/after the pull, tail death, initiator crash) — each run
//!    **twice** with the same seeds, asserting the retry/drop/dedup
//!    counters and round outcomes are bit-identical (the determinism
//!    contract of the fault model); and
//! 2. a paper-scale Poisson-churn session (default 120 nodes across 24
//!    groups, 5 rounds), where injected loss and scheduled churn
//!    overlap — the regime where retry exhaustion must degrade into an
//!    ordinary §5.3/§5.4 live failure rather than a wedged round.
//!
//! Timeout budgets are derived from the profile's expected RTT
//! ([`NetProfile::budget`]), so slow profiles get honest deadlines and
//! the ideal profile reproduces the historical constants. The `net`
//! bench target renders the table and writes `BENCH_net.json` for
//! cross-PR tracking.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::PathBuf;
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use crate::config::{DeviceProfile, RuntimeKind, SessionConfig};
use crate::crypto::envelope::CipherMode;
use crate::json::Value;
use crate::learner::faults::{ChurnSchedule, FailPoint, FaultPlan};
use crate::metrics::RoundMetrics;
use crate::protocols::SafeSession;
use crate::transport::NetProfile;

/// Knobs for one hostile-network bench run.
#[derive(Debug, Clone)]
pub struct NetBenchConfig {
    /// `--net`-style profile specs to sweep (a preset name or
    /// `preset,field=value,…` overrides).
    pub profiles: Vec<String>,
    /// Chain length for the single-round failure matrix.
    pub matrix_nodes: usize,
    /// Total learners for the churn session.
    pub nodes: usize,
    /// Configured subgroups for the churn session.
    pub groups: usize,
    /// Rounds in the churn session.
    pub rounds: usize,
    /// Poisson death rate per node per round (churn session).
    pub lambda_die: f64,
    /// Poisson rejoin rate per dead node per round (churn session).
    pub lambda_rejoin: f64,
    /// Seed for churn, keys and data (the whole run is reproducible).
    pub seed: u64,
    /// Learner executor for the churn session (the matrix runs both ways
    /// implicitly via the differential tests; here events is the default).
    pub runtime: RuntimeKind,
    /// Worker threads for the event runtime; 0 = available parallelism.
    pub workers: usize,
}

impl Default for NetBenchConfig {
    fn default() -> Self {
        NetBenchConfig {
            profiles: vec![
                "lan".to_string(),
                "wan".to_string(),
                "lte".to_string(),
                "lossy".to_string(),
            ],
            matrix_nodes: 5,
            nodes: 120,
            groups: 24,
            rounds: 5,
            lambda_die: 0.12,
            lambda_rejoin: 0.35,
            seed: 42,
            runtime: RuntimeKind::Events,
            workers: 0,
        }
    }
}

/// One measured (profile, scenario) cell of the bench table. Counter
/// fields are summed across the scenario's rounds.
#[derive(Debug, Clone)]
pub struct NetRow {
    /// Profile spec the cell ran under.
    pub profile: String,
    /// Scenario id (`matrix:*` or `churn`).
    pub scenario: String,
    /// Rounds the scenario ran.
    pub rounds: u64,
    /// Total wall-clock over those rounds.
    pub secs: f64,
    /// Chain data-plane messages (physical attempts, retries included).
    pub messages: u64,
    /// Contributors in the final round.
    pub contributors: u64,
    /// Transport retries the resilience layer issued.
    pub net_retries: u64,
    /// Injected request/response drops.
    pub net_drops: u64,
    /// Duplicate posts the controller absorbed via the dedup token.
    pub dedup_posts: u64,
    /// §5.3 progress failovers across the scenario.
    pub progress_failovers: u64,
    /// §5.4 initiator failovers across the scenario.
    pub initiator_failovers: u64,
}

/// The per-round values that must be bit-identical between two runs with
/// the same seeds — everything except wall-clock.
fn fingerprint(rounds: &[RoundMetrics]) -> Vec<[u64; 7]> {
    rounds
        .iter()
        .map(|m| {
            [
                m.messages,
                m.contributors,
                m.net_retries,
                m.net_drops,
                m.dedup_posts,
                m.progress_failovers,
                m.initiator_failovers,
            ]
        })
        .collect()
}

fn row_from(profile: &str, scenario: &str, rounds: &[RoundMetrics]) -> NetRow {
    NetRow {
        profile: profile.to_string(),
        scenario: scenario.to_string(),
        rounds: rounds.len() as u64,
        secs: rounds.iter().map(|m| m.secs()).sum(),
        messages: rounds.iter().map(|m| m.messages).sum(),
        contributors: rounds.last().map_or(0, |m| m.contributors),
        net_retries: rounds.iter().map(|m| m.net_retries).sum(),
        net_drops: rounds.iter().map(|m| m.net_drops).sum(),
        dedup_posts: rounds.iter().map(|m| m.dedup_posts).sum(),
        progress_failovers: rounds.iter().map(|m| m.progress_failovers).sum(),
        initiator_failovers: rounds.iter().map(|m| m.initiator_failovers).sum(),
    }
}

/// A full hostile-network sweep: one row per (profile, scenario).
#[derive(Debug, Clone)]
pub struct NetReport {
    /// Output id (`netbench`): names the CSV artifact.
    pub id: String,
    /// The knobs the run used.
    pub config: NetBenchConfig,
    /// Per-cell measurements.
    pub rows: Vec<NetRow>,
}

impl NetReport {
    /// Aligned text table, one row per (profile, scenario).
    pub fn to_table(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "── {} — matrix n={} · churn n={} g={} λ_die={} λ_rejoin={} seed={} ──",
            self.id,
            self.config.matrix_nodes,
            self.config.nodes,
            self.config.groups,
            self.config.lambda_die,
            self.config.lambda_rejoin,
            self.config.seed
        );
        let _ = writeln!(
            out,
            "{:>8} {:>22} {:>6} {:>8} {:>8} {:>7} {:>7} {:>6} {:>6} {:>9} {:>9}",
            "profile", "scenario", "rounds", "secs", "messages", "contrib", "retries",
            "drops", "dedup", "prog_fo", "init_fo"
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{:>8} {:>22} {:>6} {:>8.3} {:>8} {:>7} {:>7} {:>6} {:>6} {:>9} {:>9}",
                r.profile,
                r.scenario,
                r.rounds,
                r.secs,
                r.messages,
                r.contributors,
                r.net_retries,
                r.net_drops,
                r.dedup_posts,
                r.progress_failovers,
                r.initiator_failovers
            );
        }
        out
    }

    /// CSV rows mirroring [`NetReport::to_table`].
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "id,profile,scenario,rounds,secs,messages,contributors,net_retries,net_drops,\
             dedup_posts,progress_failovers,initiator_failovers\n",
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{},{},{},{},{:.6},{},{},{},{},{},{},{}",
                self.id,
                r.profile,
                r.scenario,
                r.rounds,
                r.secs,
                r.messages,
                r.contributors,
                r.net_retries,
                r.net_drops,
                r.dedup_posts,
                r.progress_failovers,
                r.initiator_failovers
            );
        }
        out
    }

    /// Machine-readable form for `BENCH_net.json`.
    pub fn to_json(&self) -> Value {
        let rows: Vec<Value> = self
            .rows
            .iter()
            .map(|r| {
                Value::object(vec![
                    ("profile", Value::from(r.profile.as_str())),
                    ("scenario", Value::from(r.scenario.as_str())),
                    ("rounds", Value::from(r.rounds)),
                    ("secs", Value::from(r.secs)),
                    ("messages", Value::from(r.messages)),
                    ("contributors", Value::from(r.contributors)),
                    ("net_retries", Value::from(r.net_retries)),
                    ("net_drops", Value::from(r.net_drops)),
                    ("dedup_posts", Value::from(r.dedup_posts)),
                    ("progress_failovers", Value::from(r.progress_failovers)),
                    ("initiator_failovers", Value::from(r.initiator_failovers)),
                ])
            })
            .collect();
        let profiles: Vec<Value> =
            self.config.profiles.iter().map(|p| Value::from(p.as_str())).collect();
        Value::object(vec![
            ("id", Value::from(self.id.as_str())),
            ("profiles", Value::Arr(profiles)),
            ("matrix_nodes", Value::from(self.config.matrix_nodes)),
            ("nodes", Value::from(self.config.nodes)),
            ("groups", Value::from(self.config.groups)),
            ("rounds", Value::from(self.config.rounds)),
            ("lambda_die", Value::from(self.config.lambda_die)),
            ("lambda_rejoin", Value::from(self.config.lambda_rejoin)),
            ("seed", Value::from(self.config.seed)),
            ("cells", Value::Arr(rows)),
        ])
    }

    /// Print the table and write `bench_out/<id>.csv`.
    pub fn emit(&self, out_dir: Option<&str>) {
        println!("{}", self.to_table());
        let dir = PathBuf::from(out_dir.unwrap_or("bench_out"));
        if std::fs::create_dir_all(&dir).is_ok() {
            let path = dir.join(format!("{}.csv", self.id));
            if let Ok(mut f) = std::fs::File::create(&path) {
                let _ = f.write_all(self.to_csv().as_bytes());
            }
        }
    }
}

/// The single-round fault positions the paper singles out (§5.3/§5.4),
/// at chain length `n`: a clean run, a mid-chain death before and after
/// the pull, the chain-closing tail death, and an initiator crash.
pub fn matrix_scenarios(n: usize) -> Vec<(&'static str, FaultPlan)> {
    let mid = (n / 2).max(2) as u64;
    vec![
        ("matrix:clean", FaultPlan::none()),
        ("matrix:mid_never_start", FaultPlan::none().kill(mid, FailPoint::NeverStart)),
        ("matrix:mid_after_get", FaultPlan::none().kill(mid, FailPoint::AfterGet)),
        ("matrix:tail_never_start", FaultPlan::none().kill(n as u64, FailPoint::NeverStart)),
        ("matrix:initiator_crash", FaultPlan::none().kill(1, FailPoint::InitiatorAfterPost)),
    ]
}

/// Session config for the failure matrix: real crypto at small n, with
/// every timeout budget stretched to the profile's expected RTT.
fn matrix_cfg(n: usize, seed: u64, net: &NetProfile) -> SessionConfig {
    SessionConfig {
        n_nodes: n,
        features: 2,
        mode: CipherMode::Hybrid,
        rsa_bits: 512,
        profile: DeviceProfile::instant(),
        poll_time: net.budget(Duration::from_secs(5), 512),
        aggregation_timeout: net.budget(Duration::from_secs(30), 4096),
        progress_timeout: net.budget(Duration::from_millis(500), 48),
        monitor_interval: Duration::from_millis(60),
        seed: Some(seed),
        net: net.clone(),
        ..Default::default()
    }
}

/// Session config for the churn session: SAF mode (the bench measures
/// the fault/failover machinery, not crypto) at paper scale.
fn churn_cfg(nc: &NetBenchConfig, net: &NetProfile) -> SessionConfig {
    SessionConfig {
        n_nodes: nc.nodes,
        features: 4,
        groups: nc.groups,
        mode: CipherMode::None,
        rsa_bits: 512,
        runtime: nc.runtime,
        workers: nc.workers,
        profile: DeviceProfile::instant(),
        poll_time: net.budget(Duration::from_secs(30), 2048),
        aggregation_timeout: net.budget(Duration::from_secs(120), 8192),
        progress_timeout: net.budget(Duration::from_millis(500), 48),
        monitor_interval: Duration::from_millis(60),
        seed: Some(nc.seed),
        merge_floor: true,
        net: net.clone(),
        ..Default::default()
    }
}

fn inputs_for(cfg: &SessionConfig) -> Vec<Vec<f64>> {
    (0..cfg.n_nodes)
        .map(|i| (0..cfg.features).map(|f| (i + 1) as f64 + 0.001 * f as f64).collect())
        .collect()
}

/// Run one matrix scenario under `net` twice and hold the two runs to
/// the determinism contract: identical message/retry/drop/dedup counts
/// and round outcomes. Returns the first run's row.
pub fn run_matrix_case(
    spec: &str,
    net: &NetProfile,
    n: usize,
    seed: u64,
    scenario: &str,
    faults: &FaultPlan,
) -> Result<NetRow> {
    let run = || -> Result<Vec<RoundMetrics>> {
        let cfg = matrix_cfg(n, seed, net);
        let session = SafeSession::new(cfg.clone())
            .with_context(|| format!("building {scenario} under {spec}"))?;
        let result = session
            .run_round(&inputs_for(&cfg), faults)
            .with_context(|| format!("running {scenario} under {spec}"))?;
        ensure!(
            result.metrics.contributors > 0,
            "{scenario} under {spec}: no contributors"
        );
        Ok(vec![result.metrics])
    };
    let first = run()?;
    let second = run()?;
    ensure!(
        fingerprint(&first) == fingerprint(&second),
        "{scenario} under {spec}: two seeded runs disagree \
         ({:?} vs {:?}) — fault injection is not deterministic",
        fingerprint(&first),
        fingerprint(&second)
    );
    Ok(row_from(spec, scenario, &first))
}

/// Run the paper-scale Poisson-churn session under `net`. When
/// `check_determinism` is set the whole multi-round session runs twice
/// and the per-round fingerprints must match.
pub fn run_churn_case(
    spec: &str,
    net: &NetProfile,
    nc: &NetBenchConfig,
    check_determinism: bool,
) -> Result<NetRow> {
    let run = || -> Result<Vec<RoundMetrics>> {
        let cfg = churn_cfg(nc, net);
        let churn = ChurnSchedule::poisson(
            nc.seed,
            nc.nodes,
            nc.rounds as u64,
            nc.lambda_die,
            nc.lambda_rejoin,
        );
        let inputs = inputs_for(&cfg);
        let per_round: Vec<Vec<Vec<f64>>> = (0..nc.rounds).map(|_| inputs.clone()).collect();
        let session = SafeSession::new(cfg)
            .with_context(|| format!("building churn session under {spec}"))?;
        let results = session
            .run_rounds(&per_round, &churn)
            .with_context(|| format!("running churn session under {spec}"))?;
        ensure!(
            results.len() == nc.rounds,
            "churn under {spec}: {} of {} rounds completed",
            results.len(),
            nc.rounds
        );
        Ok(results.into_iter().map(|r| r.metrics).collect())
    };
    let first = run()?;
    if check_determinism {
        let second = run()?;
        ensure!(
            fingerprint(&first) == fingerprint(&second),
            "churn under {spec}: two seeded sessions disagree — \
             fault injection is not deterministic across full sessions"
        );
    }
    Ok(row_from(spec, "churn", &first))
}

/// Run the full sweep: for every profile, the failure matrix (each cell
/// run twice for the determinism assert) and the Poisson-churn session
/// (run twice for the loss-heaviest profile).
pub fn run(nc: &NetBenchConfig) -> Result<NetReport> {
    let mut rows = Vec::new();
    // Only the loss-heaviest profile pays the double-length churn run;
    // the matrix covers determinism for every profile.
    let heaviest = nc
        .profiles
        .iter()
        .map(|spec| (spec, NetProfile::parse(spec).map(|p| p.loss_request).unwrap_or(0.0)))
        .max_by(|a, b| a.1.total_cmp(&b.1))
        .map(|(spec, _)| spec.clone());
    for spec in &nc.profiles {
        let net = NetProfile::parse(spec)
            .with_context(|| format!("netbench profile {spec:?}"))?;
        for (scenario, faults) in matrix_scenarios(nc.matrix_nodes) {
            rows.push(run_matrix_case(
                spec,
                &net,
                nc.matrix_nodes,
                nc.seed,
                scenario,
                &faults,
            )?);
        }
        let check = heaviest.as_deref() == Some(spec.as_str());
        rows.push(run_churn_case(spec, &net, nc, check)?);
    }
    Ok(NetReport { id: "netbench".to_string(), config: nc.clone(), rows })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> NetReport {
        NetReport {
            id: "t".into(),
            config: NetBenchConfig {
                profiles: vec!["lossy".into()],
                nodes: 10,
                groups: 2,
                rounds: 2,
                ..Default::default()
            },
            rows: vec![
                NetRow {
                    profile: "lossy".into(),
                    scenario: "matrix:clean".into(),
                    rounds: 1,
                    secs: 0.2,
                    messages: 23,
                    contributors: 5,
                    net_retries: 3,
                    net_drops: 3,
                    dedup_posts: 1,
                    progress_failovers: 0,
                    initiator_failovers: 0,
                },
                NetRow {
                    profile: "lossy".into(),
                    scenario: "churn".into(),
                    rounds: 2,
                    secs: 1.5,
                    messages: 90,
                    contributors: 9,
                    net_retries: 7,
                    net_drops: 8,
                    dedup_posts: 2,
                    progress_failovers: 1,
                    initiator_failovers: 0,
                },
            ],
        }
    }

    #[test]
    fn report_renderings_agree() {
        let r = report();
        let table = r.to_table();
        assert!(table.contains("matrix:clean"));
        assert!(table.contains("churn"));
        assert!(table.contains("dedup"));
        assert_eq!(r.to_csv().lines().count(), 3); // header + 2 cells
        let json = r.to_json();
        assert_eq!(json.str_of("id"), Some("t"));
        let cells = json.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].u64_of("net_retries"), Some(3));
        assert_eq!(cells[1].u64_of("dedup_posts"), Some(2));
        assert_eq!(
            json.get("profiles").unwrap().as_arr().unwrap().len(),
            1
        );
    }

    #[test]
    fn matrix_covers_the_paper_fault_positions() {
        let scenarios = matrix_scenarios(5);
        assert_eq!(scenarios.len(), 5);
        let names: Vec<_> = scenarios.iter().map(|(n, _)| *n).collect();
        assert!(names.contains(&"matrix:mid_after_get"), "{names:?}");
        assert!(names.contains(&"matrix:initiator_crash"), "{names:?}");
        // Scenario ids are unique (they key rows and CSV lines).
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len());
    }

    /// End-to-end determinism of the whole stack (fault model → retry →
    /// dedup → failover) for the loss-heaviest preset: run_matrix_case
    /// runs the round twice internally and fails unless the counters are
    /// bit-identical.
    #[test]
    fn lossy_matrix_case_is_deterministic() {
        let net = NetProfile::parse("lossy").unwrap();
        let faults = FaultPlan::none().kill(3, FailPoint::NeverStart);
        let row = run_matrix_case(
            "lossy",
            &net,
            5,
            42,
            "matrix:mid_never_start",
            &faults,
        )
        .unwrap();
        assert_eq!(row.rounds, 1);
        assert!(row.contributors >= 3, "privacy floor holds: {row:?}");
        // Every retry is caused by an injected drop (the in-proc
        // transport has no other failure source), and every absorbed
        // duplicate post is caused by a lost response, so the counters
        // must be ordered whatever the seed drew.
        assert!(row.net_retries <= row.net_drops, "{row:?}");
        assert!(row.dedup_posts <= row.net_drops, "{row:?}");
    }
}
