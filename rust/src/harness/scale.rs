//! Paper-scale topology bench: 100+ nodes, Poisson churn, privacy-floor
//! merge re-balancing — the §5.3/§5.5 scalability story at the size the
//! paper argues for (Figs 9/12) rather than the 12-node figure sweeps.
//!
//! Runs an `n`-node, `rounds`-round session under
//! [`ChurnSchedule::poisson`] with `--merge-floor` semantics on, and
//! checks every round's message count against the paper's formula
//! `4·contributors + 2f (+ g when subgrouped)`, with merge/reassignment
//! re-keys reported separately (footnote 3 discipline). While the
//! session runs, a side client built with
//! [`InProcTransport::with_latency`] — the modeled REST hop — polls the
//! controller's `/status` endpoint, so the latency-injecting transport
//! is exercised at scale alongside the learners.
//!
//! The `scale` bench target renders the table and writes
//! `BENCH_scale.json` for cross-PR tracking.

use std::collections::BTreeSet;
use std::fmt::Write as _;
use std::io::Write as _;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use crate::config::{DeviceProfile, RuntimeKind, SessionConfig};
use crate::crypto::envelope::CipherMode;
use crate::json::Value;
use crate::learner::faults::{ChurnSchedule, FailPoint};
use crate::proto;
use crate::protocols::SafeSession;
use crate::topology::GroupPlanner;
use crate::transport::{InProcTransport, NetProfile};

/// Knobs for one paper-scale churn run.
#[derive(Debug, Clone)]
pub struct ScaleConfig {
    /// Total learners (the acceptance scenario runs 120).
    pub n_nodes: usize,
    /// Configured subgroups (chains of ~5 keep merges observable).
    pub groups: usize,
    /// Aggregation rounds.
    pub rounds: usize,
    /// Poisson death rate per node per round.
    pub lambda_die: f64,
    /// Poisson rejoin rate per dead node per round.
    pub lambda_rejoin: f64,
    /// Seed for churn, keys and data (the whole run is reproducible).
    pub seed: u64,
    /// Modeled one-way REST hop for the side status probe
    /// ([`InProcTransport::with_latency`]).
    pub probe_hop: Duration,
    /// Learner executor: the event runtime (default) multiplexes all n
    /// learners over a worker pool; `Threads` reproduces the old
    /// thread-per-learner numbers for comparison.
    pub runtime: RuntimeKind,
    /// Worker threads for the event runtime; 0 = available parallelism.
    pub workers: usize,
    /// Network fault profile for the run. The session's timeout budgets
    /// are derived from this profile's expected RTT (identical to the
    /// historical hardcoded values under the default ideal profile).
    pub net: NetProfile,
    /// Controller shards (`--shards`): 1 = the classic single-controller
    /// plane; K > 1 spreads the groups over K shard controllers with a
    /// fan-in tier combining shard partials.
    pub shards: usize,
}

impl Default for ScaleConfig {
    fn default() -> Self {
        ScaleConfig {
            n_nodes: 120,
            groups: 24,
            rounds: 5,
            lambda_die: 0.12,
            lambda_rejoin: 0.35,
            seed: 42,
            probe_hop: Duration::from_micros(500),
            runtime: RuntimeKind::Events,
            workers: 0,
            net: NetProfile::default(),
            shards: 1,
        }
    }
}

/// One round of the scale table.
#[derive(Debug, Clone)]
pub struct ScaleRow {
    /// 1-based round number.
    pub round: u64,
    pub secs: f64,
    /// Nodes present at round start (absent nodes excluded).
    pub present: u64,
    /// Groups the topology plan ran this round (after merges).
    pub groups: u64,
    pub contributors: u64,
    /// Scheduled in-round deaths (the `f` of `4n + 2f`).
    pub deaths: u64,
    /// Nodes that rejoined at round start (each re-keys alone).
    pub rejoins: u64,
    /// Groups dissolved by privacy-floor merges this round.
    pub merged_groups: u64,
    /// Nodes aggregated outside their home group this round.
    pub reassigned_nodes: u64,
    /// Rejoin + reassignment key traffic (excluded from `messages`).
    pub rekey_messages: u64,
    pub messages: u64,
    /// The §5.2/§5.3/§5.5 prediction: `4·contributors + 2f (+ g)`.
    pub expected_messages: u64,
    pub progress_failovers: u64,
    pub initiator_failovers: u64,
    /// Transport-level retries this round (physical resends of a logical
    /// message — excluded from the formula check).
    pub net_retries: u64,
    /// Injected request/response drops this round.
    pub net_drops: u64,
    /// Fan-in tier messages this round (≤ 2K, counted outside the
    /// `4n + 2f (+ g)` formula like rekey traffic; 0 when K = 1).
    pub fanin_messages: u64,
    /// Slowest shard's partial-post → global-install span (0 when K = 1).
    pub fanin_latency_secs: f64,
    /// Per-shard learner-path message counts (empty when K = 1).
    pub shard_messages: Vec<u64>,
}

impl ScaleRow {
    /// Measured minus predicted messages (0 when the formulas hold).
    /// Retried attempts are physical resends of one logical message, so
    /// they are subtracted before comparing against `4n + 2f (+ g)`.
    pub fn formula_delta(&self) -> i64 {
        self.messages as i64 - self.net_retries as i64 - self.expected_messages as i64
    }

    /// Protocol-message throughput this round.
    pub fn messages_per_sec(&self) -> f64 {
        if self.secs > 0.0 {
            self.messages as f64 / self.secs
        } else {
            0.0
        }
    }

    /// Per-shard learner-path throughput this round (empty when K = 1).
    pub fn shard_messages_per_sec(&self) -> Vec<f64> {
        self.shard_messages
            .iter()
            .map(|&m| if self.secs > 0.0 { m as f64 / self.secs } else { 0.0 })
            .collect()
    }
}

/// Latency quantiles for one `safe_request_duration_seconds` series —
/// one registry histogram, keyed by its `path`/`shard`/`class` labels.
/// Quantile estimates interpolate within the enclosing bucket, so they
/// carry bucket-resolution (not sample-resolution) accuracy.
#[derive(Debug, Clone)]
pub struct PathLatency {
    /// Protocol path the histogram observed (`path` label).
    pub path: String,
    /// Which controller served the calls (`shard` label: `"0"`..`"K-1"`
    /// or `"parent"`).
    pub shard: String,
    /// Path class (`class` label: chain/key/fanin/monitor/ops).
    pub class: String,
    /// Observations recorded.
    pub count: u64,
    /// Median request latency, seconds.
    pub p50_secs: f64,
    /// 95th-percentile request latency, seconds.
    pub p95_secs: f64,
    /// 99th-percentile request latency, seconds.
    pub p99_secs: f64,
}

impl PathLatency {
    /// Machine-readable form for the report's `latency` array.
    pub fn to_json(&self) -> Value {
        Value::object(vec![
            ("path", Value::from(self.path.as_str())),
            ("shard", Value::from(self.shard.as_str())),
            ("class", Value::from(self.class.as_str())),
            ("count", Value::from(self.count)),
            ("p50_secs", Value::from(self.p50_secs)),
            ("p95_secs", Value::from(self.p95_secs)),
            ("p99_secs", Value::from(self.p99_secs)),
        ])
    }
}

/// Per-path latency quantiles out of a session's metric registry — the
/// single source the live table, `BENCH_scale.json` and `/metrics` all
/// render from. Sorted by (class, path, shard) for stable output.
pub fn latency_quantiles(registry: &crate::metrics::MetricRegistry) -> Vec<PathLatency> {
    fn label(ls: &[(String, String)], key: &str) -> String {
        ls.iter().find(|(k, _)| k == key).map(|(_, v)| v.clone()).unwrap_or_default()
    }
    let mut out: Vec<PathLatency> = registry
        .histogram_series(crate::metrics::names::REQUEST_DURATION_SECONDS)
        .into_iter()
        .map(|(ls, h)| PathLatency {
            path: label(&ls, "path"),
            shard: label(&ls, "shard"),
            class: label(&ls, "class"),
            count: h.count(),
            p50_secs: h.quantile(0.5),
            p95_secs: h.quantile(0.95),
            p99_secs: h.quantile(0.99),
        })
        .collect();
    out.sort_by(|a, b| {
        (&a.class, &a.path, &a.shard).cmp(&(&b.class, &b.path, &b.shard))
    });
    out
}

/// Current thread count of this process (Linux `/proc/self/status`
/// `Threads:` line). Returns 0 where unreadable, which disables the
/// peak-thread assertions rather than failing them.
pub fn current_thread_count() -> u64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|s| {
            s.lines()
                .find_map(|l| l.strip_prefix("Threads:").and_then(|v| v.trim().parse().ok()))
        })
        .unwrap_or(0)
}

/// A full paper-scale churn run: per-round rows plus run metadata.
#[derive(Debug, Clone)]
pub struct ScaleReport {
    /// Output id (`scale_poisson`): names the CSV and JSON artifacts.
    pub id: String,
    /// The knobs the run used.
    pub config: ScaleConfig,
    /// One-time round-0 key-exchange messages at session build.
    pub setup_messages: u64,
    /// Per-round measurements.
    pub rows: Vec<ScaleRow>,
    /// `/status` polls completed by the latency-modeled probe client.
    pub probe_samples: u64,
    /// Executor that drove the learners (`"events"` or `"threads"`).
    pub runtime: String,
    /// Event-runtime pool size after resolving `workers: 0` (0 under the
    /// thread runtime).
    pub workers: u64,
    /// Highest process thread count sampled while the session ran — the
    /// headline of the event runtime: O(workers), not O(n). 0 when
    /// `/proc/self/status` is unreadable.
    pub peak_threads: u64,
    /// Per-path latency quantiles from the session's metric registry
    /// ([`latency_quantiles`]) — the same histograms `GET /metrics`
    /// exposes, re-rendered into the table and `BENCH_scale.json`.
    pub latency: Vec<PathLatency>,
    /// Prometheus-text scrape of every plane controller (`GET /metrics`
    /// against each shard and, when K > 1, the fan-in parent), captured
    /// while the session was still alive. Written to
    /// `metrics_snapshot.txt` by the bench target.
    pub metrics_snapshot: String,
}

impl ScaleReport {
    /// Total privacy-floor merges across the run.
    pub fn merges_total(&self) -> u64 {
        self.rows.iter().map(|r| r.merged_groups).sum()
    }

    /// Total rejoin/reassignment re-key messages across the run.
    pub fn rekey_total(&self) -> u64 {
        self.rows.iter().map(|r| r.rekey_messages).sum()
    }

    /// Aligned text table, one row per round.
    pub fn to_table(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "── {} — n={} g={} λ_die={} λ_rejoin={} seed={} ──",
            self.id,
            self.config.n_nodes,
            self.config.groups,
            self.config.lambda_die,
            self.config.lambda_rejoin,
            self.config.seed
        );
        let _ = writeln!(
            out,
            "{:>5} {:>8} {:>7} {:>6} {:>7} {:>6} {:>7} {:>6} {:>10} {:>6} {:>8} {:>8} {:>5} \
             {:>7} {:>6} {:>6} {:>8}",
            "round", "secs", "present", "groups", "contrib", "deaths", "rejoins", "merges",
            "reassigned", "rekey", "messages", "expected", "Δ", "retries", "drops", "fanin",
            "fanin_s"
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{:>5} {:>8.3} {:>7} {:>6} {:>7} {:>6} {:>7} {:>6} {:>10} {:>6} {:>8} {:>8} {:>5} \
                 {:>7} {:>6} {:>6} {:>8.4}",
                r.round,
                r.secs,
                r.present,
                r.groups,
                r.contributors,
                r.deaths,
                r.rejoins,
                r.merged_groups,
                r.reassigned_nodes,
                r.rekey_messages,
                r.messages,
                r.expected_messages,
                r.formula_delta(),
                r.net_retries,
                r.net_drops,
                r.fanin_messages,
                r.fanin_latency_secs
            );
        }
        let _ = writeln!(
            out,
            "setup: {} round-0 messages; {} merges, {} rekey messages over {} rounds; \
             probe: {} /status polls over a {}µs modeled hop",
            self.setup_messages,
            self.merges_total(),
            self.rekey_total(),
            self.rows.len(),
            self.probe_samples,
            self.config.probe_hop.as_micros()
        );
        let _ = writeln!(
            out,
            "runtime: {} ({} workers), peak process threads {}",
            self.runtime, self.workers, self.peak_threads
        );
        if !self.latency.is_empty() {
            let _ = writeln!(
                out,
                "{:>28} {:>6} {:>6} {:>8} {:>9} {:>9} {:>9}",
                "path", "shard", "class", "calls", "p50_ms", "p95_ms", "p99_ms"
            );
            for l in &self.latency {
                let _ = writeln!(
                    out,
                    "{:>28} {:>6} {:>6} {:>8} {:>9.3} {:>9.3} {:>9.3}",
                    l.path,
                    l.shard,
                    l.class,
                    l.count,
                    l.p50_secs * 1e3,
                    l.p95_secs * 1e3,
                    l.p99_secs * 1e3
                );
            }
        }
        out
    }

    /// CSV rows mirroring [`ScaleReport::to_table`].
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "id,round,secs,present,groups,contributors,deaths,rejoins,merged_groups,\
             reassigned_nodes,rekey_messages,messages,expected_messages,formula_delta,\
             progress_failovers,initiator_failovers,net_retries,net_drops,fanin_messages,\
             fanin_latency_secs\n",
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{},{},{:.6},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{:.6}",
                self.id,
                r.round,
                r.secs,
                r.present,
                r.groups,
                r.contributors,
                r.deaths,
                r.rejoins,
                r.merged_groups,
                r.reassigned_nodes,
                r.rekey_messages,
                r.messages,
                r.expected_messages,
                r.formula_delta(),
                r.progress_failovers,
                r.initiator_failovers,
                r.net_retries,
                r.net_drops,
                r.fanin_messages,
                r.fanin_latency_secs
            );
        }
        out
    }

    /// Machine-readable form for `BENCH_scale.json`.
    pub fn to_json(&self) -> Value {
        let rows: Vec<Value> = self
            .rows
            .iter()
            .map(|r| {
                Value::object(vec![
                    ("round", Value::from(r.round)),
                    ("secs", Value::from(r.secs)),
                    ("present", Value::from(r.present)),
                    ("groups", Value::from(r.groups)),
                    ("contributors", Value::from(r.contributors)),
                    ("deaths", Value::from(r.deaths)),
                    ("rejoins", Value::from(r.rejoins)),
                    ("merged_groups", Value::from(r.merged_groups)),
                    ("reassigned_nodes", Value::from(r.reassigned_nodes)),
                    ("rekey_messages", Value::from(r.rekey_messages)),
                    ("messages", Value::from(r.messages)),
                    ("messages_per_sec", Value::from(r.messages_per_sec())),
                    ("expected_messages", Value::from(r.expected_messages)),
                    ("formula_delta", Value::from(r.formula_delta() as f64)),
                    ("progress_failovers", Value::from(r.progress_failovers)),
                    ("initiator_failovers", Value::from(r.initiator_failovers)),
                    ("net_retries", Value::from(r.net_retries)),
                    ("net_drops", Value::from(r.net_drops)),
                    ("fanin_messages", Value::from(r.fanin_messages)),
                    ("fanin_latency_secs", Value::from(r.fanin_latency_secs)),
                    (
                        "shard_messages",
                        Value::Arr(r.shard_messages.iter().map(|&m| Value::from(m)).collect()),
                    ),
                    (
                        "shard_messages_per_sec",
                        Value::Arr(
                            r.shard_messages_per_sec().into_iter().map(Value::from).collect(),
                        ),
                    ),
                ])
            })
            .collect();
        Value::object(vec![
            ("id", Value::from(self.id.as_str())),
            ("n_nodes", Value::from(self.config.n_nodes)),
            ("shards", Value::from(self.config.shards)),
            ("groups_configured", Value::from(self.config.groups)),
            ("rounds", Value::from(self.config.rounds)),
            ("lambda_die", Value::from(self.config.lambda_die)),
            ("lambda_rejoin", Value::from(self.config.lambda_rejoin)),
            ("seed", Value::from(self.config.seed)),
            ("setup_messages", Value::from(self.setup_messages)),
            ("merges_total", Value::from(self.merges_total())),
            ("rekey_total", Value::from(self.rekey_total())),
            ("probe_samples", Value::from(self.probe_samples)),
            (
                "probe_hop_us",
                Value::from(self.config.probe_hop.as_micros() as u64),
            ),
            ("runtime", Value::from(self.runtime.as_str())),
            ("workers", Value::from(self.workers)),
            ("peak_threads", Value::from(self.peak_threads)),
            ("net", Value::from(self.config.net.name.as_str())),
            ("per_round", Value::Arr(rows)),
            (
                "latency",
                Value::Arr(self.latency.iter().map(PathLatency::to_json).collect()),
            ),
        ])
    }

    /// Print the table and write `bench_out/<id>.csv`.
    pub fn emit(&self, out_dir: Option<&str>) {
        println!("{}", self.to_table());
        let dir = PathBuf::from(out_dir.unwrap_or("bench_out"));
        if std::fs::create_dir_all(&dir).is_ok() {
            let path = dir.join(format!("{}.csv", self.id));
            if let Ok(mut f) = std::fs::File::create(&path) {
                let _ = f.write_all(self.to_csv().as_bytes());
            }
        }
    }
}

/// Run the paper-scale Poisson churn scenario and build the report.
///
/// Every round the churn schedule leaves with at least 3 total live
/// nodes must complete: under-floor groups merge into a neighbour (the
/// planner refuses only when *no* merge can restore the floor), and the
/// per-round message count must match `4·contributors + 2f (+ g)`
/// exactly — rejoin/reassignment key traffic is accounted separately.
pub fn poisson_scale(sc: &ScaleConfig) -> Result<ScaleReport> {
    let cfg = SessionConfig {
        n_nodes: sc.n_nodes,
        features: 4,
        groups: sc.groups,
        // SAF mode: the scale bench measures topology and runtime
        // behaviour, not crypto — per-node RSA keygen alone would
        // dominate the n=1,000 build otherwise.
        mode: CipherMode::None,
        rsa_bits: 512,
        runtime: sc.runtime,
        workers: sc.workers,
        profile: DeviceProfile::instant(),
        // Generous long-poll budget: a retried (empty) poll counts as a
        // message, and a merged chain detecting several deaths in series
        // can legitimately take seconds — the §5.2 formula check needs
        // every poll answered within one call. All budgets stretch with
        // the net profile's expected RTT (unchanged under ideal).
        poll_time: sc.net.budget(Duration::from_secs(30), 2048),
        aggregation_timeout: sc.net.budget(Duration::from_secs(120), 8192),
        progress_timeout: sc.net.budget(Duration::from_millis(500), 32),
        monitor_interval: Duration::from_millis(60),
        seed: Some(sc.seed),
        merge_floor: true,
        net: sc.net.clone(),
        shards: sc.shards,
        ..Default::default()
    };
    let churn = ChurnSchedule::poisson(
        sc.seed,
        sc.n_nodes,
        sc.rounds as u64,
        sc.lambda_die,
        sc.lambda_rejoin,
    );
    let inputs: Vec<Vec<f64>> = (0..cfg.n_nodes)
        .map(|i| (0..cfg.features).map(|f| (i + 1) as f64 + 0.001 * f as f64).collect())
        .collect();
    let per_round: Vec<Vec<Vec<f64>>> = (0..sc.rounds).map(|_| inputs.clone()).collect();

    let session = SafeSession::new(cfg.clone())?;
    let setup_messages = session.round0_messages;

    // Side probe over the latency-modeled transport: the documented REST
    // hop (`InProcTransport::with_latency`) exercised at n=120 while the
    // learners aggregate.
    let probe_stop = Arc::new(AtomicBool::new(false));
    let probe_count = Arc::new(AtomicU64::new(0));
    let peak_threads = Arc::new(AtomicU64::new(current_thread_count()));
    let probe = InProcTransport::with_latency(session.controller.clone(), sc.probe_hop);
    let probe_thread = {
        let stop = probe_stop.clone();
        let count = probe_count.clone();
        let peak = peak_threads.clone();
        std::thread::Builder::new().name("scale-probe".into()).spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                use crate::transport::ClientTransport;
                if probe.call(proto::STATUS, &Value::obj()).is_ok() {
                    count.fetch_add(1, Ordering::SeqCst);
                }
                // Live scrape alongside the status polls: the registry
                // must serve (and its collectors must run) while the
                // learners aggregate, not only at quiescence.
                let _ = probe.call(proto::METRICS, &Value::obj());
                peak.fetch_max(current_thread_count(), Ordering::SeqCst);
                std::thread::sleep(Duration::from_millis(25));
            }
        })?
    };

    let run = session.run_rounds(&per_round, &churn);
    probe_stop.store(true, Ordering::SeqCst);
    let _ = probe_thread.join();
    let results = run?;

    // Scrape every plane controller through the real endpoint while the
    // session is still alive: each must serve typed Prometheus text.
    let mut metrics_snapshot = String::new();
    for (label, ctrl) in session.plane_controllers() {
        use crate::transport::ClientTransport;
        let resp = InProcTransport::new(ctrl)
            .call(proto::METRICS, &Value::obj())
            .with_context(|| format!("scraping /metrics on controller {label}"))?;
        let text = resp.str_of("text").unwrap_or_default();
        ensure!(
            text.contains("# TYPE"),
            "controller {label}: /metrics served no typed metric families"
        );
        let _ = writeln!(metrics_snapshot, "# ==== controller {label} ====");
        metrics_snapshot.push_str(text);
    }
    let latency = latency_quantiles(session.session_metrics().registry());

    // Rebuild each round's plan from the same deterministic inputs the
    // engine used, to derive the per-round group count and cross-check
    // the engine's merge accounting.
    let planner = GroupPlanner::from_config(&cfg);
    let membership = planner.membership();
    let mut rows = Vec::with_capacity(results.len());
    for (i, res) in results.iter().enumerate() {
        let round = (i + 1) as u64;
        let faults = churn.fault_plan_for(round);
        let absent: BTreeSet<u64> = membership
            .iter()
            .copied()
            .filter(|&n| churn.absent_in(round, n))
            .collect();
        let plan = planner
            .plan_round(i as u64, &absent, &faults)
            .with_context(|| format!("re-planning round {round}"))?;
        let m = &res.metrics;
        ensure!(
            m.merged_groups == plan.merges().len() as u64
                && m.reassigned_nodes == plan.reassignments().len() as u64,
            "round {round}: engine and re-planned merge accounting disagree"
        );
        let deaths: u64 = membership
            .iter()
            .filter(|&&n| {
                matches!(
                    faults.point(n),
                    Some(FailPoint::NeverStart) | Some(FailPoint::AfterGet)
                ) && plan.contains(n)
            })
            .count() as u64;
        let groups = plan.groups().len() as u64;
        let expected = 4 * m.contributors
            + 2 * deaths
            + if groups > 1 { groups } else { 0 };
        rows.push(ScaleRow {
            round,
            secs: m.secs(),
            present: plan.total_live() as u64,
            groups,
            contributors: m.contributors,
            deaths,
            rejoins: churn
                .rejoining_in(round)
                .into_iter()
                .filter(|&j| plan.contains(j))
                .count() as u64,
            merged_groups: m.merged_groups,
            reassigned_nodes: m.reassigned_nodes,
            rekey_messages: m.rekey_messages,
            messages: m.messages,
            expected_messages: expected,
            progress_failovers: m.progress_failovers,
            initiator_failovers: m.initiator_failovers,
            net_retries: m.net_retries,
            net_drops: m.net_drops,
            fanin_messages: m.fanin_messages,
            fanin_latency_secs: m.fanin_latency.as_secs_f64(),
            shard_messages: m.shard_messages.clone(),
        });
    }
    Ok(ScaleReport {
        id: if sc.shards > 1 {
            format!("scale_poisson_k{}", sc.shards)
        } else {
            "scale_poisson".to_string()
        },
        config: sc.clone(),
        setup_messages,
        rows,
        probe_samples: probe_count.load(Ordering::SeqCst),
        runtime: runtime_name(sc.runtime).to_string(),
        workers: resolved_workers_for(sc.runtime, sc.workers),
        peak_threads: peak_threads.load(Ordering::SeqCst),
        latency,
        metrics_snapshot,
    })
}

/// Run the same Poisson churn scenario at each plane width in
/// `shard_counts` (e.g. `[1, 2, 4]`), holding every other knob fixed —
/// the `--shards` K-sweep the scale bench renders side by side.
pub fn shard_sweep(base: &ScaleConfig, shard_counts: &[usize]) -> Result<Vec<ScaleReport>> {
    shard_counts
        .iter()
        .map(|&k| poisson_scale(&ScaleConfig { shards: k.max(1), ..base.clone() }))
        .collect()
}

fn runtime_name(r: RuntimeKind) -> &'static str {
    match r {
        RuntimeKind::Events => "events",
        RuntimeKind::Threads => "threads",
    }
}

/// Pool size the event runtime will actually use; 0 under threads (the
/// thread runtime has no pool — it spawns one thread per learner).
fn resolved_workers_for(r: RuntimeKind, workers: usize) -> u64 {
    match r {
        RuntimeKind::Events => crate::runtime_exec::resolve_workers(workers) as u64,
        RuntimeKind::Threads => 0,
    }
}

/// Result of one single-round, fault-free session at smoke scale.
#[derive(Debug, Clone)]
pub struct SmokeResult {
    pub n_nodes: usize,
    pub groups: usize,
    pub secs: f64,
    pub messages: u64,
    pub expected_messages: u64,
    /// Pool size used (events runtime only — the smoke refuses threads).
    pub workers: u64,
    /// Highest process thread count sampled during the round (0 when
    /// unmeasurable).
    pub peak_threads: u64,
}

impl SmokeResult {
    pub fn to_json(&self) -> Value {
        Value::object(vec![
            ("n_nodes", Value::from(self.n_nodes)),
            ("groups", Value::from(self.groups)),
            ("secs", Value::from(self.secs)),
            ("messages", Value::from(self.messages)),
            ("expected_messages", Value::from(self.expected_messages)),
            ("workers", Value::from(self.workers)),
            ("peak_threads", Value::from(self.peak_threads)),
        ])
    }
}

/// n=10,000-class smoke: one fault-free aggregation round under the
/// event runtime, checking the §5.2/§5.5 formula (`4n + g`) and that the
/// process never grew anywhere near n threads. SAF mode + instant
/// profile: this measures the executor, not crypto or modeled network.
pub fn single_round_smoke(
    n_nodes: usize,
    groups: usize,
    workers: usize,
    net: &NetProfile,
) -> Result<SmokeResult> {
    let cfg = SessionConfig {
        n_nodes,
        features: 2,
        groups,
        mode: CipherMode::None,
        rsa_bits: 512,
        runtime: RuntimeKind::Events,
        workers,
        profile: DeviceProfile::instant(),
        // One poll per blocking point: empty-poll retries would break the
        // exact formula check, and at n=10,000 every retry is n messages.
        // Budgets stretch with the profile RTT (unchanged under ideal).
        poll_time: net.budget(Duration::from_secs(120), 8192),
        aggregation_timeout: net.budget(Duration::from_secs(600), 32768),
        progress_timeout: net.budget(Duration::from_secs(60), 4096),
        monitor_interval: Duration::from_secs(5),
        seed: Some(7),
        net: net.clone(),
        ..Default::default()
    };
    let inputs: Vec<Vec<f64>> = (0..n_nodes)
        .map(|i| (0..cfg.features).map(|f| (i + 1) as f64 + 0.5 * f as f64).collect())
        .collect();

    let session = SafeSession::new(cfg)?;
    let sampler_stop = Arc::new(AtomicBool::new(false));
    let peak = Arc::new(AtomicU64::new(current_thread_count()));
    let sampler = {
        let stop = sampler_stop.clone();
        let peak = peak.clone();
        std::thread::Builder::new().name("smoke-sampler".into()).spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                peak.fetch_max(current_thread_count(), Ordering::SeqCst);
                std::thread::sleep(Duration::from_millis(20));
            }
        })?
    };
    let watch = crate::util::Stopwatch::start();
    let run = session.run_round(&inputs, &crate::learner::faults::FaultPlan::none());
    let secs = watch.elapsed().as_secs_f64();
    sampler_stop.store(true, Ordering::SeqCst);
    let _ = sampler.join();
    let result = run?;

    let expected = 4 * n_nodes as u64 + if groups > 1 { groups as u64 } else { 0 };
    ensure!(
        result.metrics.messages - result.metrics.net_retries == expected,
        "smoke n={n_nodes}: {} messages ({} retries), expected {expected}",
        result.metrics.messages,
        result.metrics.net_retries
    );
    ensure!(
        result.metrics.contributors == n_nodes as u64,
        "smoke n={n_nodes}: {} contributors",
        result.metrics.contributors
    );
    Ok(SmokeResult {
        n_nodes,
        groups,
        secs,
        messages: result.metrics.messages,
        expected_messages: expected,
        workers: crate::runtime_exec::resolve_workers(workers) as u64,
        peak_threads: peak.load(Ordering::SeqCst),
    })
}

/// Knobs for the crypto-layer scale measurement: §5.1 round-0 key
/// exchange and the §5.8 rejoiner re-key, timed under the *active*
/// bigint backend (the whole point: run it once per backend and compare
/// the `crypto.<backend>` entries in `BENCH_scale.json`).
#[derive(Debug, Clone)]
pub struct CryptoScaleConfig {
    /// Total learners (the acceptance scenario runs 120).
    pub n_nodes: usize,
    /// Configured subgroups (chains of ~5, like the churn bench).
    pub groups: usize,
    /// RSA modulus size (512 keeps keygen for 120 nodes tractable).
    pub rsa_bits: usize,
    /// Seed for keys and data — the run is reproducible per backend.
    pub seed: u64,
}

impl Default for CryptoScaleConfig {
    fn default() -> Self {
        CryptoScaleConfig { n_nodes: 120, groups: 24, rsa_bits: 512, seed: 42 }
    }
}

/// Crypto-layer numbers for one backend at paper scale.
#[derive(Debug, Clone)]
pub struct CryptoScaleReport {
    /// `Big::NAME` of the backend the binary was built with.
    pub backend: String,
    pub config: CryptoScaleConfig,
    /// Wall-clock of `SafeSession::new` under §5.8 pre-negotiation:
    /// per-node RSA keygen, peer public-key fetch, and every pairwise
    /// symmetric key sealed + unsealed.
    pub setup_secs: f64,
    /// Round-0 messages that setup exchanged.
    pub setup_messages: u64,
    /// Wall-clock of the round in which one node rejoined — dominated
    /// by the §5.8 re-key (fresh RSA keypair + every touched link's
    /// symmetric key regenerated, re-sealed, re-pulled).
    pub rekey_round_secs: f64,
    /// Re-key messages that round (the engine accounts them outside the
    /// `4n + 2f` formula, per footnote 3).
    pub rekey_messages: u64,
    /// Per-link §5.8 seal (PKCS#1 encrypt of a symmetric master key)
    /// with the modulus context shared across calls, microseconds.
    pub seal_us: f64,
    /// Per-link §5.8 unseal (CRT decrypt) with the cached context,
    /// microseconds.
    pub unseal_us: f64,
}

impl CryptoScaleReport {
    pub fn to_json(&self) -> Value {
        Value::object(vec![
            ("backend", Value::from(self.backend.as_str())),
            ("n_nodes", Value::from(self.config.n_nodes)),
            ("groups", Value::from(self.config.groups)),
            ("rsa_bits", Value::from(self.config.rsa_bits)),
            ("seed", Value::from(self.config.seed)),
            ("setup_secs", Value::from(self.setup_secs)),
            ("setup_messages", Value::from(self.setup_messages)),
            ("rekey_round_secs", Value::from(self.rekey_round_secs)),
            ("rekey_messages", Value::from(self.rekey_messages)),
            ("seal_us", Value::from(self.seal_us)),
            ("unseal_us", Value::from(self.unseal_us)),
        ])
    }

    pub fn to_table(&self) -> String {
        format!(
            "── crypto @ n={} g={} rsa={} backend={} ──\n\
             round-0 setup: {:.3}s ({} messages)\n\
             rejoin re-key round: {:.3}s ({} rekey messages)\n\
             per-link §5.8: seal {:.1}µs, unseal {:.1}µs (shared contexts)\n",
            self.config.n_nodes,
            self.config.groups,
            self.config.rsa_bits,
            self.backend,
            self.setup_secs,
            self.setup_messages,
            self.rekey_round_secs,
            self.rekey_messages,
            self.seal_us,
            self.unseal_us
        )
    }
}

/// Measure §5.1 round-0 setup and the §5.8 re-key at paper scale under
/// the active bigint backend.
///
/// Two passes: an engine pass (a real `PreNegotiated` session built at
/// `n` nodes, then two rounds where node 1 dies in round 1 and rejoins
/// in round 2 — the round-2 wall-clock is the full rejoiner re-key),
/// and a primitive pass timing one §5.8 link seal/unseal with the
/// contexts shared exactly the way the protocol now shares them.
pub fn crypto_scale(sc: &CryptoScaleConfig) -> Result<CryptoScaleReport> {
    use crate::crypto::rng::DeterministicRng;
    use crate::crypto::rsa::RsaKeyPair;
    use crate::crypto::SymmetricKey;
    use crate::crypto::{Big, DefaultBig};

    let cfg = SessionConfig {
        n_nodes: sc.n_nodes,
        features: 4,
        groups: sc.groups,
        mode: CipherMode::PreNegotiated,
        rsa_bits: sc.rsa_bits,
        profile: DeviceProfile::instant(),
        poll_time: Duration::from_secs(30),
        aggregation_timeout: Duration::from_secs(120),
        progress_timeout: Duration::from_millis(500),
        monitor_interval: Duration::from_millis(60),
        seed: Some(sc.seed),
        ..Default::default()
    };
    let inputs: Vec<Vec<f64>> = (0..cfg.n_nodes)
        .map(|i| (0..cfg.features).map(|f| (i + 1) as f64 + 0.001 * f as f64).collect())
        .collect();
    let per_round = vec![inputs.clone(), inputs];

    let watch = crate::util::Stopwatch::start();
    let session = SafeSession::new(cfg)?;
    let setup_secs = watch.elapsed().as_secs_f64();
    let setup_messages = session.round0_messages;

    let churn = ChurnSchedule::none()
        .die(1, 1, FailPoint::NeverStart)
        .rejoin(1, 2);
    let results = session.run_rounds(&per_round, &churn)?;
    let rekey_round = results.last().context("re-key run produced no rounds")?;
    ensure!(
        rekey_round.metrics.rekey_messages > 0,
        "rejoin round recorded no re-key messages — churn schedule broken?"
    );

    // Primitive pass: average one §5.8 link over `iters` fresh symmetric
    // keys, sharing the encrypt context (sender side: one modulus, many
    // peers' keys sealed to us) and the CRT decrypt context (receiver
    // side: our own modulus for every pull).
    let mut rng = DeterministicRng::seed(sc.seed ^ 0x5ea1);
    let kp = RsaKeyPair::generate(sc.rsa_bits, &mut rng);
    let enc = kp.public.encrypt_ctx();
    let dec = kp.private.decrypt_ctx();
    let iters = 64usize;
    let mut sealed = Vec::with_capacity(iters);
    let watch = crate::util::Stopwatch::start();
    for _ in 0..iters {
        let k = SymmetricKey::generate(&mut rng);
        sealed.push(enc.encrypt_block(&k.master, &mut rng)?);
    }
    let seal_us = watch.elapsed().as_secs_f64() * 1e6 / iters as f64;
    let watch = crate::util::Stopwatch::start();
    for s in &sealed {
        let _ = dec.decrypt_block(s)?;
    }
    let unseal_us = watch.elapsed().as_secs_f64() * 1e6 / iters as f64;

    Ok(CryptoScaleReport {
        backend: <DefaultBig as Big>::NAME.to_string(),
        config: sc.clone(),
        setup_secs,
        setup_messages,
        rekey_round_secs: rekey_round.metrics.secs(),
        rekey_messages: rekey_round.metrics.rekey_messages,
        seal_us,
        unseal_us,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> ScaleReport {
        ScaleReport {
            id: "t".into(),
            config: ScaleConfig { n_nodes: 10, groups: 2, rounds: 2, ..Default::default() },
            setup_messages: 50,
            rows: (1..=2)
                .map(|round| ScaleRow {
                    round,
                    secs: 0.1,
                    present: 10,
                    groups: 2,
                    contributors: 9,
                    deaths: 1,
                    rejoins: 0,
                    merged_groups: u64::from(round == 2),
                    reassigned_nodes: if round == 2 { 2 } else { 0 },
                    rekey_messages: if round == 2 { 12 } else { 0 },
                    messages: 4 * 9 + 2 + 2,
                    expected_messages: 4 * 9 + 2 + 2,
                    progress_failovers: 1,
                    initiator_failovers: 0,
                    net_retries: 0,
                    net_drops: u64::from(round == 2),
                    fanin_messages: 4,
                    fanin_latency_secs: 0.01,
                    shard_messages: vec![20, 18],
                })
                .collect(),
            probe_samples: 7,
            runtime: "events".into(),
            workers: 4,
            peak_threads: 13,
            latency: vec![PathLatency {
                path: "/post_aggregate".into(),
                shard: "0".into(),
                class: "chain".into(),
                count: 36,
                p50_secs: 0.0005,
                p95_secs: 0.002,
                p99_secs: 0.004,
            }],
            metrics_snapshot: "# TYPE safe_requests_total counter\n".into(),
        }
    }

    #[test]
    fn report_rollups_and_renderings_agree() {
        let r = report();
        assert_eq!(r.merges_total(), 1);
        assert_eq!(r.rekey_total(), 12);
        assert_eq!(r.rows[0].formula_delta(), 0);
        let table = r.to_table();
        assert!(table.contains("reassigned"));
        assert!(table.contains("/status polls"));
        assert_eq!(r.to_csv().lines().count(), 3); // header + 2 rounds
        let json = r.to_json();
        assert_eq!(json.u64_of("merges_total"), Some(1));
        assert_eq!(json.u64_of("probe_samples"), Some(7));
        assert_eq!(json.u64_of("peak_threads"), Some(13));
        assert_eq!(json.str_of("runtime"), Some("events"));
        assert_eq!(json.get("per_round").unwrap().as_arr().unwrap().len(), 2);
        let row = &json.get("per_round").unwrap().as_arr().unwrap()[0];
        let mps = row.get("messages_per_sec").and_then(|v| v.as_f64()).unwrap();
        assert!((mps - (4.0 * 9.0 + 4.0) / 0.1).abs() < 1e-6);
        // Sharded-plane columns ride along in every rendering.
        assert_eq!(json.u64_of("shards"), Some(1));
        assert_eq!(row.u64_of("fanin_messages"), Some(4));
        assert_eq!(row.get("shard_messages").unwrap().as_arr().unwrap().len(), 2);
        let smps = row.get("shard_messages_per_sec").unwrap().as_arr().unwrap();
        assert!((smps[0].as_f64().unwrap() - 200.0).abs() < 1e-6);
        assert!(r.to_csv().lines().next().unwrap().contains("fanin_messages"));
        assert!(r.to_table().contains("fanin"));
        // Registry-sourced latency quantiles ride along in table + JSON
        // (but not the CSV, whose row count is pinned above).
        assert!(r.to_table().contains("p95_ms"));
        assert!(r.to_table().contains("/post_aggregate"));
        let lat = json.get("latency").unwrap().as_arr().unwrap();
        assert_eq!(lat.len(), 1);
        assert_eq!(lat[0].str_of("path"), Some("/post_aggregate"));
        assert_eq!(lat[0].str_of("shard"), Some("0"));
        assert_eq!(lat[0].u64_of("count"), Some(36));
        assert!((lat[0].get("p95_secs").and_then(|v| v.as_f64()).unwrap() - 0.002).abs() < 1e-12);
    }

    #[test]
    fn latency_quantiles_read_back_recorded_series() {
        use crate::metrics::{names, MetricRegistry};
        use std::time::Duration;
        let reg = MetricRegistry::new();
        let rec = crate::metrics::LatencyRecorder::new(reg.clone(), "0");
        for _ in 0..10 {
            rec.observe(proto::POST_AGGREGATE, Duration::from_micros(300));
        }
        rec.observe(proto::PROGRESS_CHECK, Duration::from_micros(80));
        let rows = latency_quantiles(&reg);
        assert_eq!(rows.len(), 2);
        // Sorted by (class, path, shard): chain before monitor.
        assert_eq!(rows[0].path, proto::POST_AGGREGATE);
        assert_eq!(rows[0].class, "chain");
        assert_eq!(rows[0].count, 10);
        assert!(rows[0].p50_secs > 0.0 && rows[0].p50_secs <= rows[0].p99_secs);
        assert_eq!(rows[1].class, "monitor");
        // And the same registry renders those series as exposition text.
        let text = reg.render();
        assert!(text.contains(&format!("# TYPE {} histogram", names::REQUEST_DURATION_SECONDS)));
    }

    #[test]
    fn crypto_scale_smoke() {
        use crate::crypto::{Big, DefaultBig};
        let r = crypto_scale(&CryptoScaleConfig {
            n_nodes: 8,
            groups: 2,
            rsa_bits: 512,
            seed: 9,
        })
        .unwrap();
        assert_eq!(r.backend, <DefaultBig as Big>::NAME);
        assert!(r.setup_messages > 0);
        assert!(r.rekey_messages > 0);
        assert!(r.seal_us > 0.0 && r.unseal_us > 0.0);
        let j = r.to_json();
        assert_eq!(j.u64_of("n_nodes"), Some(8));
        assert_eq!(j.str_of("backend"), Some(<DefaultBig as Big>::NAME));
        assert!(r.to_table().contains("round-0 setup"));
    }

    #[test]
    fn thread_count_readable_on_linux() {
        // On Linux this must see at least the main thread; elsewhere the
        // helper degrades to 0 (assertions off) rather than erroring.
        let n = current_thread_count();
        if cfg!(target_os = "linux") {
            assert!(n >= 1);
        }
    }
}
