//! From-scratch JSON codec — the SAFE wire format.
//!
//! The paper's controller is a Flask app exchanging JSON bodies
//! (`{"from_node": 1, "to_node": 2, "aggregate": "..."}`); we reproduce the
//! same wire format. `serde`/`serde_json` are not in the offline crate
//! cache, so this is a complete hand-rolled recursive-descent parser and
//! serializer covering the full JSON grammar (RFC 8259): objects, arrays,
//! strings with escapes (incl. `\uXXXX` surrogate pairs), numbers, bools,
//! null.

use std::collections::BTreeMap;
use std::fmt;

pub use crate::blob::Blob;

/// A JSON value. Object keys are ordered (BTreeMap) for deterministic
/// serialization — handy for tests and cache keys.
///
/// [`Value::Bytes`] extends the strict JSON grammar with an opaque binary
/// payload ([`Blob`]): the JSON serializer emits it as base64 text (the
/// paper's REST contract — ciphertext crosses a JSON wire as base64), the
/// binary codec ships it as raw length-prefixed bytes with no base64 at
/// all. The JSON parser has no way to tell base64 text from any other
/// string, so a decoded `Bytes` comes back as `Str`; equality treats the
/// two representations of the same bytes as equal so `decode ∘ encode`
/// stays an identity under every codec.
#[derive(Debug, Clone)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
    /// Opaque bytes: base64 text on a JSON wire, raw bytes on a binary one.
    Bytes(Blob),
}

impl PartialEq for Value {
    fn eq(&self, other: &Value) -> bool {
        match (self, other) {
            (Value::Null, Value::Null) => true,
            (Value::Bool(a), Value::Bool(b)) => a == b,
            (Value::Num(a), Value::Num(b)) => a == b,
            (Value::Str(a), Value::Str(b)) => a == b,
            (Value::Arr(a), Value::Arr(b)) => a == b,
            (Value::Obj(a), Value::Obj(b)) => a == b,
            (Value::Bytes(a), Value::Bytes(b)) => a == b,
            // Same wire value, two in-memory shapes (see the enum docs).
            (Value::Bytes(b), Value::Str(s)) | (Value::Str(s), Value::Bytes(b)) => {
                crate::util::b64_encode(b.as_bytes()) == *s
            }
            _ => false,
        }
    }
}

impl Value {
    pub fn obj() -> Value {
        Value::Obj(BTreeMap::new())
    }

    /// Build an object from key/value pairs.
    pub fn object(pairs: Vec<(&str, Value)>) -> Value {
        let mut m = BTreeMap::new();
        for (k, v) in pairs {
            m.insert(k.to_string(), v);
        }
        Value::Obj(m)
    }

    pub fn set(&mut self, key: &str, v: Value) {
        if let Value::Obj(m) = self {
            m.insert(key.to_string(), v);
        }
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The value as an opaque byte blob. `Bytes` clones the `Arc` (no
    /// byte copy); `Str` is treated as base64 — the only way bytes arrive
    /// off a JSON wire.
    pub fn as_blob(&self) -> Option<Blob> {
        match self {
            Value::Bytes(b) => Some(b.clone()),
            Value::Str(s) => crate::util::b64_decode(s).ok().map(Blob::new),
            _ => None,
        }
    }

    /// Convenience: `get(key)` then `as_blob`.
    pub fn blob_of(&self, key: &str) -> Option<Blob> {
        self.get(key).and_then(|v| v.as_blob())
    }

    /// Convenience: `get(key)` then `as_str`.
    pub fn str_of(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(|v| v.as_str())
    }

    pub fn u64_of(&self, key: &str) -> Option<u64> {
        self.get(key).and_then(|v| v.as_u64())
    }

    pub fn f64_of(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.as_f64())
    }

    pub fn bool_of(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(|v| v.as_bool())
    }

    /// Parse an f64 array field.
    pub fn f64_arr_of(&self, key: &str) -> Option<Vec<f64>> {
        let arr = self.get(key)?.as_arr()?;
        arr.iter().map(|v| v.as_f64()).collect()
    }

    /// Serialize to a compact JSON string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(n) => write_num(*n, out),
            Value::Str(s) => write_str(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
            Value::Bytes(b) => {
                // Base64 needs no JSON escaping — push the quoted text
                // straight into the buffer.
                out.push('"');
                out.push_str(&crate::util::b64_encode(b.as_bytes()));
                out.push('"');
            }
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<u64> for Value {
    fn from(n: u64) -> Self {
        Value::Num(n as f64)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<Blob> for Value {
    fn from(b: Blob) -> Self {
        Value::Bytes(b)
    }
}
impl From<Vec<f64>> for Value {
    fn from(v: Vec<f64>) -> Self {
        Value::Arr(v.into_iter().map(Value::Num).collect())
    }
}
impl From<&[f64]> for Value {
    fn from(v: &[f64]) -> Self {
        Value::Arr(v.iter().copied().map(Value::Num).collect())
    }
}

fn write_num(n: f64, out: &mut String) {
    use std::fmt::Write;
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; encode as null like most tolerant encoders.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.007199254740992e15 {
        // write! straight into the buffer — no per-element String alloc
        // (hot for the 10k-float average responses; see EXPERIMENTS §Perf).
        let _ = write!(out, "{}", n as i64);
    } else {
        // {:?} on f64 is Rust's shortest round-trippable representation.
        let _ = write!(out, "{:?}", n);
    }
}

fn write_str(s: &str, out: &mut String) {
    use std::fmt::Write;
    out.push('"');
    // Fast path: copy maximal runs of chars that need no escaping in one
    // push_str (envelope payloads are long base64 strings — per-char
    // pushes dominated the serializer before this; see EXPERIMENTS §Perf).
    let bytes = s.as_bytes();
    let mut start = 0;
    let mut i = 0;
    while i < bytes.len() {
        let b = bytes[i];
        if b == b'"' || b == b'\\' || b < 0x20 {
            out.push_str(&s[start..i]);
            match b {
                b'"' => out.push_str("\\\""),
                b'\\' => out.push_str("\\\\"),
                b'\n' => out.push_str("\\n"),
                b'\r' => out.push_str("\\r"),
                b'\t' => out.push_str("\\t"),
                0x08 => out.push_str("\\b"),
                0x0c => out.push_str("\\f"),
                c => {
                    let _ = write!(out, "\\u{:04x}", c);
                }
            }
            i += 1;
            start = i;
        } else {
            i += 1;
        }
    }
    out.push_str(&s[start..]);
    out.push('"');
}

/// Parse a JSON document. Trailing whitespace allowed; trailing garbage is
/// an error.
pub fn parse(input: &str) -> anyhow::Result<Value> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.parse_value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        anyhow::bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> anyhow::Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow::anyhow!("unexpected end of JSON"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        let b = self.bump()?;
        if b != c {
            anyhow::bail!("expected {:?} at byte {}, found {:?}", c as char, self.pos - 1, b as char);
        }
        Ok(())
    }

    fn parse_value(&mut self) -> anyhow::Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.parse_obj(),
            Some(b'[') => self.parse_arr(),
            Some(b'"') => Ok(Value::Str(self.parse_string()?)),
            Some(b't') => self.parse_lit("true", Value::Bool(true)),
            Some(b'f') => self.parse_lit("false", Value::Bool(false)),
            Some(b'n') => self.parse_lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.parse_num(),
            Some(c) => anyhow::bail!("unexpected character {:?} at byte {}", c as char, self.pos),
            None => anyhow::bail!("unexpected end of JSON"),
        }
    }

    fn parse_lit(&mut self, lit: &str, v: Value) -> anyhow::Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn parse_obj(&mut self) -> anyhow::Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.parse_string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.parse_value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => break,
                c => anyhow::bail!("expected ',' or '}}' at byte {}, found {:?}", self.pos - 1, c as char),
            }
        }
        Ok(Value::Obj(m))
    }

    fn parse_arr(&mut self) -> anyhow::Result<Value> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            let v = self.parse_value()?;
            a.push(v);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => break,
                c => anyhow::bail!("expected ',' or ']' at byte {}, found {:?}", self.pos - 1, c as char),
            }
        }
        Ok(Value::Arr(a))
    }

    fn parse_string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            // Fast path: bulk-copy the maximal clean run (no quote,
            // escape, or control byte). Long base64 payloads take this
            // branch almost exclusively.
            let run_start = self.pos;
            while let Some(&b) = self.bytes.get(self.pos) {
                if b == b'"' || b == b'\\' || b < 0x20 || b >= 0x80 {
                    break;
                }
                self.pos += 1;
            }
            if self.pos > run_start {
                // ASCII-only run — valid UTF-8 by construction.
                s.push_str(unsafe {
                    std::str::from_utf8_unchecked(&self.bytes[run_start..self.pos])
                });
            }
            let b = self.bump()?;
            match b {
                b'"' => break,
                b'\\' => {
                    let e = self.bump()?;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\x08'),
                        b'f' => s.push('\x0c'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.parse_u4()?;
                            // Handle UTF-16 surrogate pairs.
                            if (0xD800..0xDC00).contains(&cp) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.parse_u4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    anyhow::bail!("invalid low surrogate");
                                }
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                s.push(char::from_u32(c).ok_or_else(|| anyhow::anyhow!("bad codepoint"))?);
                            } else if (0xDC00..0xE000).contains(&cp) {
                                anyhow::bail!("unexpected low surrogate");
                            } else {
                                s.push(char::from_u32(cp).ok_or_else(|| anyhow::anyhow!("bad codepoint"))?);
                            }
                        }
                        c => anyhow::bail!("invalid escape \\{}", c as char),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence starting at pos-1.
                    let start = self.pos - 1;
                    let len = utf8_len(b)?;
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        anyhow::bail!("truncated UTF-8 sequence");
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..self.pos])?;
                    s.push_str(chunk);
                }
            }
        }
        Ok(s)
    }

    fn parse_u4(&mut self) -> anyhow::Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump()?;
            let d = match c {
                b'0'..=b'9' => (c - b'0') as u32,
                b'a'..=b'f' => (c - b'a') as u32 + 10,
                b'A'..=b'F' => (c - b'A') as u32 + 10,
                _ => anyhow::bail!("invalid \\u escape"),
            };
            v = (v << 4) | d;
        }
        Ok(v)
    }

    fn parse_num(&mut self) -> anyhow::Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = text.parse().map_err(|e| anyhow::anyhow!("bad number {:?}: {}", text, e))?;
        Ok(Value::Num(n))
    }
}

fn utf8_len(first: u8) -> anyhow::Result<usize> {
    match first {
        0x00..=0x7f => Ok(1),
        0xc0..=0xdf => Ok(2),
        0xe0..=0xef => Ok(3),
        0xf0..=0xf7 => Ok(4),
        _ => anyhow::bail!("invalid UTF-8 lead byte {:#x}", first),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic_types() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Value::Null));
    }

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"agg":"AbC+/=","from_node":1,"to_node":2,"vec":[1,2.5,-3]}"#;
        let v = parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\nb\t\"q\"Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\"Aé"));
        // Surrogate pair: U+1F600
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn escape_roundtrip() {
        let s = "line1\nline2\t\"quoted\" \\slash\\ unicode é 😀 \u{1}";
        let v = Value::Str(s.to_string());
        assert_eq!(parse(&v.to_string()).unwrap().as_str(), Some(s));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn big_float_roundtrip() {
        let n = 1.2345678901234567e-12;
        let v = Value::Num(n);
        let parsed = parse(&v.to_string()).unwrap();
        assert_eq!(parsed.as_f64(), Some(n));
    }

    #[test]
    fn f64_vec_field() {
        let v = Value::object(vec![("average", Value::from(vec![1.0, 2.0, 3.0]))]);
        assert_eq!(v.f64_arr_of("average").unwrap(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn bytes_serialize_as_base64_and_roundtrip() {
        let b = Value::Bytes(Blob::from_slice(b"foobar"));
        assert_eq!(b.to_string(), "\"Zm9vYmFy\"");
        // The parser yields Str (base64 text is indistinguishable from any
        // other string), but equality bridges the two shapes.
        let parsed = parse(&b.to_string()).unwrap();
        assert_eq!(parsed, b);
        assert_eq!(b, parsed);
        assert_eq!(parsed.as_blob().unwrap().as_bytes(), b"foobar");
        assert_ne!(b, Value::Str("Zm9v".into()));
    }

    #[test]
    fn blob_of_reads_both_shapes() {
        let raw = vec![0u8, 255, 7, 128];
        let v = Value::object(vec![("agg", Value::Bytes(Blob::new(raw.clone())))]);
        let rt = parse(&v.to_string()).unwrap();
        assert_eq!(rt, v);
        assert_eq!(rt.blob_of("agg").unwrap().as_bytes(), &raw[..]);
        assert_eq!(v.blob_of("agg").unwrap().as_bytes(), &raw[..]);
        // Non-base64 strings are not blobs.
        assert!(Value::from("not base64!").as_blob().is_none());
    }
}
