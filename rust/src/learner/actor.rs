//! Persistent learner actors — the worker threads of the multi-round
//! session engine.
//!
//! `run_round` used to spawn one throwaway thread per learner per round.
//! Under the multi-round engine each learner is an *actor*: a thread
//! spawned once that lives across rounds, receiving one `RoundTask` per
//! round over a channel and sending the `LearnerOutcome` back. The
//! expensive per-node state (RSA keys, §5.8 pre-negotiated keys) lives in
//! the session's long-lived `LearnerContext`s; the actor receives a
//! cheaply-forked per-round view of that context (chain order, epoch,
//! stagger slot), so keys are exchanged once and reused round after round
//! (paper §5, footnote 3).
//!
//! The channel protocol is strictly lock-step per actor: the engine sends
//! exactly one task per round to each *active* actor and collects exactly
//! one outcome; absent (churned-out) nodes get no task and the engine
//! synthesizes [`LearnerOutcome::absent`] for them. Dropping the
//! [`LearnerActor`] closes the task channel, which ends the thread.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Result;

use super::faults::FaultPlan;
use super::{run_learner, LearnerContext, LearnerOutcome};

/// One round's worth of work for an actor.
struct RoundTask {
    /// Per-round fork of the learner's context (chain/epoch/stagger for
    /// this round; key material shared with the session's master copy).
    ctx: Arc<LearnerContext>,
    /// The node's local feature vector this round.
    input: Vec<f64>,
    /// Fault injection for this round (the round's `ChurnSchedule` slice).
    faults: FaultPlan,
}

/// Handle to one persistent learner thread.
pub struct LearnerActor {
    pub node: u64,
    /// `Some` while the actor is alive; taken (closing the channel, which
    /// ends the thread's recv loop) on drop.
    task_tx: Option<Sender<RoundTask>>,
    outcome_rx: Receiver<Result<LearnerOutcome>>,
    handle: Option<JoinHandle<()>>,
}

impl LearnerActor {
    /// Spawn the actor thread for `node`. The thread parks on its task
    /// channel between rounds (no spinning) and exits when the actor is
    /// dropped.
    pub fn spawn(node: u64) -> Result<LearnerActor> {
        let (task_tx, task_rx) = channel::<RoundTask>();
        let (outcome_tx, outcome_rx) = channel();
        let handle = std::thread::Builder::new()
            .name(format!("learner-{node}"))
            .spawn(move || {
                while let Ok(task) = task_rx.recv() {
                    let outcome = run_learner(&task.ctx, &task.input, &task.faults);
                    if outcome_tx.send(outcome).is_err() {
                        break; // engine gone; shut down
                    }
                }
            })?;
        Ok(LearnerActor { node, task_tx: Some(task_tx), outcome_rx, handle: Some(handle) })
    }

    /// Hand the actor its work for the round. Returns an error only if
    /// the actor thread died (a bug, not a protocol failure).
    pub fn dispatch(
        &self,
        ctx: Arc<LearnerContext>,
        input: Vec<f64>,
        faults: FaultPlan,
    ) -> Result<()> {
        self.task_tx
            .as_ref()
            .expect("actor already shut down")
            .send(RoundTask { ctx, input, faults })
            .map_err(|_| anyhow::anyhow!("learner actor {} is gone", self.node))
    }

    /// Block until the actor reports its outcome for the dispatched round.
    pub fn collect(&self) -> Result<LearnerOutcome> {
        self.outcome_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("learner actor {} died mid-round", self.node))?
    }
}

impl Drop for LearnerActor {
    fn drop(&mut self) {
        // Closing the channel ends the thread's recv loop.
        self.task_tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
