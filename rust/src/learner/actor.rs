//! Persistent learner actors — the dispatch/collect handles of the
//! multi-round session engine.
//!
//! Under `--runtime threads` each actor owns one OS thread spawned once
//! that lives across rounds, receiving one `RoundTask` per round over a
//! channel and sending the `LearnerOutcome` back. Under `--runtime
//! events` the actor is a thin handle over the session's shared
//! [`EventExecutor`]: `dispatch` enqueues a resumable state machine on
//! the worker pool and `collect` receives its outcome — same call sites,
//! no thread per learner. The expensive per-node state (RSA keys, §5.8
//! pre-negotiated keys) lives in the session's long-lived
//! `LearnerContext`s; the actor receives a cheaply-forked per-round view
//! of that context (chain order, epoch, stagger slot), so keys are
//! exchanged once and reused round after round (paper §5, footnote 3).
//!
//! The protocol is strictly lock-step per actor: the engine sends exactly
//! one task per round to each *active* actor and collects exactly one
//! outcome; absent (churned-out) nodes get no task and the engine
//! synthesizes [`LearnerOutcome::absent`] for them. Dropping a
//! thread-backed [`LearnerActor`] closes the task channel, which ends
//! the thread; an event-backed actor owns nothing to tear down.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::faults::FaultPlan;
use super::{run_learner, LearnerContext, LearnerOutcome};
use crate::runtime_exec::EventExecutor;

/// One round's worth of work for an actor.
struct RoundTask {
    /// Per-round fork of the learner's context (chain/epoch/stagger for
    /// this round; key material shared with the session's master copy).
    ctx: Arc<LearnerContext>,
    /// The node's local feature vector this round.
    input: Vec<f64>,
    /// Fault injection for this round (the round's `ChurnSchedule` slice).
    faults: FaultPlan,
}

enum Backend {
    /// One dedicated OS thread, parked on its task channel between rounds.
    Thread {
        /// `Some` while the actor is alive; taken (closing the channel,
        /// which ends the thread's recv loop) on drop.
        task_tx: Option<Sender<RoundTask>>,
        outcome_rx: Receiver<Result<LearnerOutcome>>,
        handle: Option<JoinHandle<()>>,
    },
    /// A handle into the session's worker-pool executor; the per-round
    /// receiver is produced by `dispatch` and consumed by `collect`.
    Event {
        executor: Arc<EventExecutor>,
        round_rx: Mutex<Option<Receiver<Result<LearnerOutcome>>>>,
    },
}

/// Handle to one persistent learner (thread- or event-backed).
pub struct LearnerActor {
    pub node: u64,
    backend: Backend,
}

impl LearnerActor {
    /// Spawn the actor thread for `node`. The thread parks on its task
    /// channel between rounds (no spinning) and exits when the actor is
    /// dropped.
    pub fn spawn(node: u64) -> Result<LearnerActor> {
        let (task_tx, task_rx) = channel::<RoundTask>();
        let (outcome_tx, outcome_rx) = channel();
        let handle = std::thread::Builder::new()
            .name(format!("learner-{node}"))
            .spawn(move || {
                while let Ok(task) = task_rx.recv() {
                    let outcome = run_learner(&task.ctx, &task.input, &task.faults);
                    if outcome_tx.send(outcome).is_err() {
                        break; // engine gone; shut down
                    }
                }
            })?;
        Ok(LearnerActor {
            node,
            backend: Backend::Thread {
                task_tx: Some(task_tx),
                outcome_rx,
                handle: Some(handle),
            },
        })
    }

    /// Event-runtime actor: no thread of its own; rounds run as state
    /// machines on `executor`'s worker pool.
    pub fn event(node: u64, executor: Arc<EventExecutor>) -> LearnerActor {
        LearnerActor {
            node,
            backend: Backend::Event { executor, round_rx: Mutex::new(None) },
        }
    }

    /// Hand the actor its work for the round. Returns an error if the
    /// actor was already shut down or its thread died (a bug, not a
    /// protocol failure) — never panics.
    pub fn dispatch(
        &self,
        ctx: Arc<LearnerContext>,
        input: Vec<f64>,
        faults: FaultPlan,
    ) -> Result<()> {
        match &self.backend {
            Backend::Thread { task_tx, .. } => task_tx
                .as_ref()
                .ok_or_else(|| anyhow!("learner actor {} already shut down", self.node))?
                .send(RoundTask { ctx, input, faults })
                .map_err(|_| anyhow!("learner actor {} is gone", self.node)),
            Backend::Event { executor, round_rx } => {
                let rx = executor.spawn_learner(ctx, input, faults);
                *round_rx.lock().unwrap() = Some(rx);
                Ok(())
            }
        }
    }

    /// Block until the actor reports its outcome for the dispatched round.
    pub fn collect(&self) -> Result<LearnerOutcome> {
        match &self.backend {
            Backend::Thread { outcome_rx, .. } => outcome_rx
                .recv()
                .map_err(|_| anyhow!("learner actor {} died mid-round", self.node))?,
            Backend::Event { round_rx, .. } => {
                let rx = round_rx
                    .lock()
                    .unwrap()
                    .take()
                    .ok_or_else(|| anyhow!("learner actor {}: collect without dispatch", self.node))?;
                rx.recv()
                    .map_err(|_| anyhow!("learner actor {} died mid-round", self.node))?
            }
        }
    }
}

impl Drop for LearnerActor {
    fn drop(&mut self) {
        if let Backend::Thread { task_tx, handle, .. } = &mut self.backend {
            // Closing the channel ends the thread's recv loop.
            task_tx.take();
            if let Some(h) = handle.take() {
                let _ = h.join();
            }
        }
    }
}
