//! Failure injection — how the §6.3 failover experiments kill nodes.
//!
//! The paper simulates failure by "complet[ing] the public key exchange
//! step for all nodes before taking out nodes 4 to 6 in the chain and
//! starting the aggregation process". [`FailPoint::NeverStart`] is exactly
//! that; the other points kill a learner mid-protocol to exercise the
//! harder recovery paths (consumed-then-died, initiator crash).

use std::collections::BTreeMap;

/// Where in its state machine a learner dies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailPoint {
    /// Completes key exchange, then never participates (paper §6.3).
    NeverStart,
    /// Pulls its aggregate from the controller, then dies before adding
    /// and reposting (mailbox already drained — the hard monitor case).
    AfterGet,
    /// Adds its value and posts onward, then dies (still counted as a
    /// contributor; chain proceeds, node misses the average).
    AfterPost,
    /// Initiator-only: posts the masked start, then dies before the
    /// finalize step (§5.4 — forces initiator failover).
    InitiatorAfterPost,
}

/// Which nodes fail and where.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    pub faults: BTreeMap<u64, FailPoint>,
}

impl FaultPlan {
    pub fn none() -> Self {
        FaultPlan::default()
    }

    /// The §6.3 scenario: nodes 4..=6 (or any range) never start.
    pub fn kill_range(from: u64, to: u64) -> Self {
        let mut plan = FaultPlan::default();
        for n in from..=to {
            plan.faults.insert(n, FailPoint::NeverStart);
        }
        plan
    }

    pub fn kill(mut self, node: u64, at: FailPoint) -> Self {
        self.faults.insert(node, at);
        self
    }

    pub fn point(&self, node: u64) -> Option<FailPoint> {
        self.faults.get(&node).copied()
    }

    pub fn fails_at(&self, node: u64, at: FailPoint) -> bool {
        self.point(node) == Some(at)
    }

    pub fn failed_count(&self) -> usize {
        self.faults.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kill_range_marks_never_start() {
        let p = FaultPlan::kill_range(4, 6);
        assert_eq!(p.failed_count(), 3);
        for n in 4..=6 {
            assert!(p.fails_at(n, FailPoint::NeverStart));
        }
        assert!(p.point(3).is_none());
    }

    #[test]
    fn builder_composes() {
        let p = FaultPlan::none()
            .kill(1, FailPoint::InitiatorAfterPost)
            .kill(5, FailPoint::AfterGet);
        assert!(p.fails_at(1, FailPoint::InitiatorAfterPost));
        assert!(p.fails_at(5, FailPoint::AfterGet));
        assert!(!p.fails_at(5, FailPoint::AfterPost));
    }
}
