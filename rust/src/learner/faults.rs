//! Failure injection — how the failover experiments kill (and revive)
//! nodes.
//!
//! Two layers:
//!
//! * [`FaultPlan`] — the paper's §6.3 single-round scenario: a set of
//!   nodes each dying at one [`FailPoint`] within *one* aggregation
//!   round. The paper simulates failure by "complet[ing] the public key
//!   exchange step for all nodes before taking out nodes 4 to 6 in the
//!   chain and starting the aggregation process";
//!   [`FailPoint::NeverStart`] is exactly that, and the other points kill
//!   a learner mid-protocol to exercise the harder recovery paths
//!   (consumed-then-died, initiator crash).
//! * [`ChurnSchedule`] — the general, multi-round form used by
//!   `SafeSession::run_rounds`: per-round [`Die`](ChurnEvent::Die) and
//!   [`Rejoin`](ChurnEvent::Rejoin) events, so a node can fail in round
//!   1, sit out round 2, and return in round 3 (with chain re-formation
//!   and a key re-exchange for the returning node only). A `FaultPlan`
//!   is the round-1 slice of a `ChurnSchedule`; use
//!   [`ChurnSchedule::from_fault_plan`] to lift one. For paper-scale
//!   experiments, [`ChurnSchedule::poisson`] generates seeded per-round
//!   Poisson arrival/departure over the whole population (the CLI's
//!   `--churn poisson:λ_die,λ_rejoin`).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::crypto::rng::{DeterministicRng, SecureRng};

/// Where in its state machine a learner dies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailPoint {
    /// Completes key exchange, then never participates (paper §6.3).
    NeverStart,
    /// Pulls its aggregate from the controller, then dies before adding
    /// and reposting (mailbox already drained — the hard monitor case).
    AfterGet,
    /// Adds its value and posts onward, then dies (still counted as a
    /// contributor; chain proceeds, node misses the average).
    AfterPost,
    /// Initiator-only: posts the masked start, then dies before the
    /// finalize step (§5.4 — forces initiator failover).
    InitiatorAfterPost,
}

impl FailPoint {
    /// Stable spec name (used by the CLI `--churn` grammar).
    pub fn name(&self) -> &'static str {
        match self {
            FailPoint::NeverStart => "never-start",
            FailPoint::AfterGet => "after-get",
            FailPoint::AfterPost => "after-post",
            FailPoint::InitiatorAfterPost => "initiator-after-post",
        }
    }

    /// Parse a spec name (see [`FailPoint::name`]).
    pub fn from_name(s: &str) -> Option<FailPoint> {
        match s {
            "never-start" => Some(FailPoint::NeverStart),
            "after-get" => Some(FailPoint::AfterGet),
            "after-post" => Some(FailPoint::AfterPost),
            "initiator-after-post" => Some(FailPoint::InitiatorAfterPost),
            _ => None,
        }
    }
}

/// Which nodes fail and where, within a single aggregation round.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub faults: BTreeMap<u64, FailPoint>,
}

impl FaultPlan {
    /// The empty plan: nobody fails.
    #[must_use]
    pub fn none() -> Self {
        FaultPlan::default()
    }

    /// The §6.3 scenario: nodes `from..=to` never start.
    ///
    /// ```
    /// use safe_agg::learner::faults::{FailPoint, FaultPlan};
    ///
    /// let plan = FaultPlan::kill_range(4, 6);
    /// assert_eq!(plan.failed_count(), 3);
    /// assert!(plan.fails_at(5, FailPoint::NeverStart));
    /// ```
    #[must_use]
    pub fn kill_range(from: u64, to: u64) -> Self {
        let mut plan = FaultPlan::default();
        for n in from..=to {
            plan.faults.insert(n, FailPoint::NeverStart);
        }
        plan
    }

    /// Builder: additionally kill `node` at `at`.
    ///
    /// ```
    /// use safe_agg::learner::faults::{FailPoint, FaultPlan};
    ///
    /// let plan = FaultPlan::none()
    ///     .kill(1, FailPoint::InitiatorAfterPost)
    ///     .kill(5, FailPoint::AfterGet);
    /// assert!(plan.fails_at(1, FailPoint::InitiatorAfterPost));
    /// ```
    #[must_use]
    pub fn kill(mut self, node: u64, at: FailPoint) -> Self {
        self.faults.insert(node, at);
        self
    }

    /// The fail point configured for `node`, if any.
    #[must_use]
    pub fn point(&self, node: u64) -> Option<FailPoint> {
        self.faults.get(&node).copied()
    }

    #[must_use]
    pub fn fails_at(&self, node: u64, at: FailPoint) -> bool {
        self.point(node) == Some(at)
    }

    #[must_use]
    pub fn failed_count(&self) -> usize {
        self.faults.len()
    }
}

/// One scheduled churn event for a node. Rounds are 1-based: round 1 is
/// the first aggregation round of a `run_rounds` call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnEvent {
    /// Die during `round` at the given [`FailPoint`]; absent from every
    /// later round until a `Rejoin`.
    Die(u64, FailPoint),
    /// Return at the start of `round`: the node is re-inserted into its
    /// group chain and re-runs its key exchange before the round starts.
    Rejoin(u64),
}

impl ChurnEvent {
    fn round(&self) -> u64 {
        match self {
            ChurnEvent::Die(r, _) => *r,
            ChurnEvent::Rejoin(r) => *r,
        }
    }
}

/// Cross-round churn: per-node sequences of die/rejoin events, the
/// multi-round generalization of [`FaultPlan`].
///
/// Semantics (rounds are 1-based):
///
/// * `Die(r, at)` — the node participates in round `r` up to the fail
///   point `at`, then is **absent** from rounds `r+1, r+2, …`.
/// * `Rejoin(r)` — the node is **present again from round `r`**
///   (inclusive). Chains re-form around absent nodes each round, and a
///   rejoining node re-runs its key exchange (its key material only;
///   survivors' keys are reused untouched).
///
/// Events for one node must alternate die → rejoin → die … in strictly
/// increasing rounds; [`ChurnSchedule::die`]/[`ChurnSchedule::rejoin`]
/// and [`ChurnSchedule::parse`] enforce this.
///
/// ```
/// use safe_agg::learner::faults::{ChurnSchedule, FailPoint};
///
/// let churn = ChurnSchedule::none()
///     .die(4, 1, FailPoint::NeverStart)
///     .rejoin(4, 3);
/// assert!(!churn.absent_in(1, 4)); // dies *during* round 1
/// assert!(churn.absent_in(2, 4));  // sits out round 2
/// assert!(!churn.absent_in(3, 4)); // back for round 3
/// assert_eq!(churn.rejoining_in(3), vec![4]);
/// ```
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ChurnSchedule {
    /// node → events, kept sorted by round (alternating die/rejoin).
    events: BTreeMap<u64, Vec<ChurnEvent>>,
}

impl ChurnSchedule {
    /// The empty schedule: full membership every round.
    #[must_use]
    pub fn none() -> Self {
        ChurnSchedule::default()
    }

    /// Lift a single-round [`FaultPlan`] into a schedule: every planned
    /// fault becomes `Die(1, point)` with no rejoin — exactly what
    /// `run_round(inputs, faults)` means under the multi-round engine.
    #[must_use]
    pub fn from_fault_plan(plan: &FaultPlan) -> Self {
        let mut s = ChurnSchedule::none();
        for (&node, &at) in &plan.faults {
            s = s.die(node, 1, at);
        }
        s
    }

    /// Builder: `node` dies during `round` at `at`.
    ///
    /// # Panics
    /// Panics if the event does not extend the node's alternating
    /// die/rejoin sequence in increasing round order (a die directly
    /// after a die, or a round ≤ the previous event's round).
    #[must_use]
    pub fn die(mut self, node: u64, round: u64, at: FailPoint) -> Self {
        self.push(node, ChurnEvent::Die(round, at)).unwrap();
        self
    }

    /// Builder: `node` returns at the start of `round`.
    ///
    /// # Panics
    /// Panics under the same sequencing rules as [`ChurnSchedule::die`]
    /// (a rejoin must follow a die in a strictly later round).
    #[must_use]
    pub fn rejoin(mut self, node: u64, round: u64) -> Self {
        self.push(node, ChurnEvent::Rejoin(round)).unwrap();
        self
    }

    fn push(&mut self, node: u64, ev: ChurnEvent) -> Result<()> {
        if ev.round() == 0 {
            bail!("churn rounds are 1-based; round 0 is invalid");
        }
        let seq = self.events.entry(node).or_default();
        match (seq.last(), &ev) {
            (None, ChurnEvent::Die(..)) => {}
            (None, ChurnEvent::Rejoin(r)) => {
                bail!("node {node}: rejoin@{r} without a prior die")
            }
            // Same-round collisions get their own diagnostics: a repeated
            // event is almost always a copy/paste slip, and die+rejoin in
            // one round is ambiguous (which half of the round is the node
            // in?) — name the node and round so the spec is fixable.
            (Some(prev), _) if ev.round() == prev.round() => {
                let same_kind = matches!(
                    (prev, &ev),
                    (ChurnEvent::Die(..), ChurnEvent::Die(..))
                        | (ChurnEvent::Rejoin(_), ChurnEvent::Rejoin(_))
                );
                let r = ev.round();
                if same_kind {
                    let kind = match ev {
                        ChurnEvent::Die(..) => "die",
                        ChurnEvent::Rejoin(_) => "rejoin",
                    };
                    bail!("node {node}: duplicate {kind} event in round {r}")
                }
                bail!(
                    "node {node}: die and rejoin in the same round {r} \
                     (schedule the rejoin for a later round)"
                )
            }
            (Some(prev), _) if ev.round() < prev.round() => bail!(
                "node {node}: event at round {} must come after round {}",
                ev.round(),
                prev.round()
            ),
            (Some(ChurnEvent::Die(..)), ChurnEvent::Die(r, _)) => {
                bail!("node {node}: die@{r} while already dead (missing rejoin)")
            }
            (Some(ChurnEvent::Rejoin(_)), ChurnEvent::Rejoin(r)) => {
                bail!("node {node}: rejoin@{r} while already alive (missing die)")
            }
            _ => {}
        }
        seq.push(ev);
        Ok(())
    }

    /// Is `node` absent for the whole of `round` (died in an earlier
    /// round and has not rejoined by `round`)? A node dying *during*
    /// `round` is not absent — it participates up to its fail point.
    #[must_use]
    pub fn absent_in(&self, round: u64, node: u64) -> bool {
        let Some(seq) = self.events.get(&node) else { return false };
        // Last event strictly before `round` decides; a Die(r) takes
        // effect from r+1, a Rejoin(r) from r.
        let mut absent = false;
        for ev in seq {
            match ev {
                ChurnEvent::Die(r, _) if *r < round => absent = true,
                ChurnEvent::Rejoin(r) if *r <= round => absent = false,
                _ => break,
            }
        }
        absent
    }

    /// The [`FaultPlan`] slice for `round`: every node with a
    /// `Die(round, at)` event.
    #[must_use]
    pub fn fault_plan_for(&self, round: u64) -> FaultPlan {
        let mut plan = FaultPlan::none();
        for (&node, seq) in &self.events {
            for ev in seq {
                if let ChurnEvent::Die(r, at) = ev {
                    if *r == round {
                        plan.faults.insert(node, *at);
                    }
                }
            }
        }
        plan
    }

    /// Nodes with a `Rejoin(round)` event — the ones that must re-run
    /// their key exchange before `round` starts.
    #[must_use]
    pub fn rejoining_in(&self, round: u64) -> Vec<u64> {
        let mut out = Vec::new();
        for (&node, seq) in &self.events {
            if seq.iter().any(|ev| matches!(ev, ChurnEvent::Rejoin(r) if *r == round)) {
                out.push(node);
            }
        }
        out
    }

    /// Highest round any event references (0 for the empty schedule) —
    /// lets the CLI default `--rounds` to cover the whole schedule.
    #[must_use]
    pub fn max_round(&self) -> u64 {
        self.events
            .values()
            .flat_map(|seq| seq.iter().map(|ev| ev.round()))
            .max()
            .unwrap_or(0)
    }

    /// True when no events are scheduled.
    #[must_use]
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// True when `node` has any scheduled event (used to detect conflicts
    /// when merging a [`FaultPlan`] into an explicit schedule).
    #[must_use]
    pub fn schedules(&self, node: u64) -> bool {
        self.events.contains_key(&node)
    }

    /// Seeded paper-scale churn: per-round Poisson arrival/departure over
    /// `n_nodes` nodes for `rounds` rounds.
    ///
    /// Each round, every alive node dies during the round (at
    /// [`FailPoint::NeverStart`]) with probability `1 − e^(−λ_die)` — the
    /// probability a rate-`λ_die` Poisson process fires at least once in
    /// one round — and every dead node rejoins with probability
    /// `1 − e^(−λ_rejoin)`. All randomness comes from the repo's seeded
    /// ChaCha20 [`DeterministicRng`] (no wall clock, no external `rand`),
    /// so the same `(seed, n, rounds, λs)` always yields the same
    /// schedule:
    ///
    /// ```
    /// use safe_agg::learner::faults::ChurnSchedule;
    ///
    /// let a = ChurnSchedule::poisson(42, 120, 5, 0.1, 0.4);
    /// let b = ChurnSchedule::poisson(42, 120, 5, 0.1, 0.4);
    /// assert_eq!(a, b, "seeded generation is reproducible");
    /// assert!(a.max_round() <= 5);
    /// assert!(!a.is_empty(), "λ=0.1 over 120 nodes × 5 rounds churns");
    /// ```
    #[must_use]
    pub fn poisson(
        seed: u64,
        n_nodes: usize,
        rounds: u64,
        lambda_die: f64,
        lambda_rejoin: f64,
    ) -> ChurnSchedule {
        let mut rng = DeterministicRng::seed(seed ^ 0x706f_6973_736f_6e2d); // "poisson-"
        let p_die = 1.0 - (-lambda_die.max(0.0)).exp();
        let p_rejoin = 1.0 - (-lambda_rejoin.max(0.0)).exp();
        let mut schedule = ChurnSchedule::none();
        let mut alive = vec![true; n_nodes + 1];
        for round in 1..=rounds {
            // Fixed node order and exactly one draw per (node, round)
            // keep the stream alignment — and therefore the schedule —
            // independent of how many nodes happen to be dead.
            for node in 1..=n_nodes as u64 {
                let u = rng.next_f64();
                if alive[node as usize] {
                    if u < p_die {
                        schedule = schedule.die(node, round, FailPoint::NeverStart);
                        alive[node as usize] = false;
                    }
                } else if u < p_rejoin {
                    schedule = schedule.rejoin(node, round);
                    alive[node as usize] = true;
                }
            }
        }
        schedule
    }

    /// Parse the `--churn poisson:LAMBDA_DIE,LAMBDA_REJOIN` spec form.
    ///
    /// Returns `Ok(None)` when `spec` is not a poisson spec at all (the
    /// caller should fall back to the event grammar of
    /// [`ChurnSchedule::parse`]), `Ok(Some((λ_die, λ_rejoin)))` on
    /// success, and an error naming the problem for a malformed poisson
    /// spec.
    ///
    /// ```
    /// use safe_agg::learner::faults::ChurnSchedule;
    ///
    /// assert_eq!(
    ///     ChurnSchedule::parse_poisson_spec("poisson:0.1,0.4").unwrap(),
    ///     Some((0.1, 0.4))
    /// );
    /// assert_eq!(ChurnSchedule::parse_poisson_spec("die:4@1").unwrap(), None);
    /// assert!(ChurnSchedule::parse_poisson_spec("poisson:0.1").is_err());
    /// ```
    pub fn parse_poisson_spec(spec: &str) -> Result<Option<(f64, f64)>> {
        let Some(rest) = spec.trim().strip_prefix("poisson:") else {
            return Ok(None);
        };
        let (die_str, rejoin_str) = rest.split_once(',').with_context(|| {
            format!("poisson churn spec {spec:?}: expected poisson:LAMBDA_DIE,LAMBDA_REJOIN")
        })?;
        let lambda_die: f64 = die_str
            .trim()
            .parse()
            .with_context(|| format!("poisson churn spec {spec:?}: bad λ_die {die_str:?}"))?;
        let lambda_rejoin: f64 = rejoin_str.trim().parse().with_context(|| {
            format!("poisson churn spec {spec:?}: bad λ_rejoin {rejoin_str:?}")
        })?;
        if !lambda_die.is_finite() || !lambda_rejoin.is_finite() || lambda_die < 0.0
            || lambda_rejoin < 0.0
        {
            bail!("poisson churn spec {spec:?}: rates must be finite and non-negative");
        }
        Ok(Some((lambda_die, lambda_rejoin)))
    }

    /// Parse the CLI `--churn` grammar: comma-separated events,
    /// `die:NODE@ROUND[:FAILPOINT]` (fail point defaults to
    /// `never-start`) or `rejoin:NODE@ROUND`. Example:
    ///
    /// ```
    /// use safe_agg::learner::faults::{ChurnSchedule, FailPoint};
    ///
    /// let churn =
    ///     ChurnSchedule::parse("die:4@1,rejoin:4@3,die:5@2:after-get").unwrap();
    /// assert_eq!(churn.fault_plan_for(2).point(5), Some(FailPoint::AfterGet));
    /// assert_eq!(churn.max_round(), 3);
    /// ```
    pub fn parse(spec: &str) -> Result<ChurnSchedule> {
        let mut schedule = ChurnSchedule::none();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (kind, rest) = part
                .split_once(':')
                .with_context(|| format!("churn event {part:?}: expected kind:node@round"))?;
            let (node_str, round_rest) = rest
                .split_once('@')
                .with_context(|| format!("churn event {part:?}: missing @round"))?;
            let node: u64 = node_str
                .parse()
                .with_context(|| format!("churn event {part:?}: bad node id"))?;
            match kind {
                "die" => {
                    let (round_str, point) = match round_rest.split_once(':') {
                        Some((r, p)) => (
                            r,
                            FailPoint::from_name(p).with_context(|| {
                                format!("churn event {part:?}: unknown fail point {p:?}")
                            })?,
                        ),
                        None => (round_rest, FailPoint::NeverStart),
                    };
                    let round: u64 = round_str
                        .parse()
                        .with_context(|| format!("churn event {part:?}: bad round"))?;
                    schedule.push(node, ChurnEvent::Die(round, point))?;
                }
                "rejoin" => {
                    let round: u64 = round_rest
                        .parse()
                        .with_context(|| format!("churn event {part:?}: bad round"))?;
                    schedule.push(node, ChurnEvent::Rejoin(round))?;
                }
                other => bail!("churn event {part:?}: unknown kind {other:?}"),
            }
        }
        Ok(schedule)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kill_range_marks_never_start() {
        let p = FaultPlan::kill_range(4, 6);
        assert_eq!(p.failed_count(), 3);
        for n in 4..=6 {
            assert!(p.fails_at(n, FailPoint::NeverStart));
        }
        assert!(p.point(3).is_none());
    }

    #[test]
    fn builder_composes() {
        let p = FaultPlan::none()
            .kill(1, FailPoint::InitiatorAfterPost)
            .kill(5, FailPoint::AfterGet);
        assert!(p.fails_at(1, FailPoint::InitiatorAfterPost));
        assert!(p.fails_at(5, FailPoint::AfterGet));
        assert!(!p.fails_at(5, FailPoint::AfterPost));
    }

    #[test]
    fn churn_absent_window() {
        let c = ChurnSchedule::none().die(4, 1, FailPoint::NeverStart).rejoin(4, 3);
        assert!(!c.absent_in(1, 4), "dies during round 1, not absent from it");
        assert!(c.absent_in(2, 4));
        assert!(!c.absent_in(3, 4));
        assert!(!c.absent_in(4, 4));
        assert!(!c.absent_in(1, 9), "unscheduled nodes never absent");
    }

    #[test]
    fn churn_die_rejoin_die() {
        let c = ChurnSchedule::none()
            .die(2, 1, FailPoint::AfterGet)
            .rejoin(2, 2)
            .die(2, 3, FailPoint::NeverStart);
        assert!(!c.absent_in(1, 2));
        assert!(!c.absent_in(2, 2));
        assert!(!c.absent_in(3, 2), "present (and dying) in round 3");
        assert!(c.absent_in(4, 2));
        assert_eq!(c.fault_plan_for(1).point(2), Some(FailPoint::AfterGet));
        assert!(c.fault_plan_for(2).faults.is_empty());
        assert_eq!(c.fault_plan_for(3).point(2), Some(FailPoint::NeverStart));
        assert_eq!(c.rejoining_in(2), vec![2]);
        assert!(c.rejoining_in(3).is_empty());
        assert_eq!(c.max_round(), 3);
    }

    #[test]
    fn churn_from_fault_plan_is_round1_slice() {
        let plan = FaultPlan::kill_range(4, 5).kill(1, FailPoint::InitiatorAfterPost);
        let c = ChurnSchedule::from_fault_plan(&plan);
        assert_eq!(c.fault_plan_for(1).failed_count(), 3);
        assert!(c.fault_plan_for(2).faults.is_empty());
        assert!(c.absent_in(2, 4), "no rejoin scheduled");
    }

    #[test]
    fn churn_parse_grammar() {
        let c = ChurnSchedule::parse("die:4@1, rejoin:4@3 ,die:5@2:after-get").unwrap();
        assert!(c.absent_in(2, 4));
        assert_eq!(c.fault_plan_for(2).point(5), Some(FailPoint::AfterGet));
        assert_eq!(c.rejoining_in(3), vec![4]);
        assert!(ChurnSchedule::parse("").unwrap().is_empty());
        for bad in [
            "die:4",            // no round
            "die:x@1",          // bad node
            "die:4@0",          // rounds are 1-based
            "die:4@1:bogus",    // unknown fail point
            "rejoin:4@1",       // rejoin before any die
            "die:4@2,die:4@3",  // double die
            "die:4@2,rejoin:4@2", // rejoin not strictly later
            "fly:4@1",          // unknown kind
        ] {
            assert!(ChurnSchedule::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn parse_rejects_duplicate_event_naming_node_and_round() {
        let err = ChurnSchedule::parse("die:4@1,die:4@1").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("node 4"), "{msg}");
        assert!(msg.contains("round 1"), "{msg}");
        assert!(msg.contains("duplicate die"), "{msg}");
        // Duplicate rejoins are named the same way.
        let err = ChurnSchedule::parse("die:7@1,rejoin:7@2,rejoin:7@2").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("node 7"), "{msg}");
        assert!(msg.contains("round 2"), "{msg}");
        assert!(msg.contains("duplicate rejoin"), "{msg}");
    }

    #[test]
    fn parse_rejects_die_and_rejoin_same_round_naming_node_and_round() {
        let err = ChurnSchedule::parse("die:4@2,rejoin:4@2").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("node 4"), "{msg}");
        assert!(msg.contains("round 2"), "{msg}");
        assert!(msg.contains("die and rejoin in the same round"), "{msg}");
        // The reverse order (rejoin then die, after a prior die) too.
        let err = ChurnSchedule::parse("die:9@1,rejoin:9@3,die:9@3").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("node 9"), "{msg}");
        assert!(msg.contains("round 3"), "{msg}");
        assert!(msg.contains("die and rejoin in the same round"), "{msg}");
    }

    #[test]
    fn poisson_is_seeded_and_respects_alternation() {
        let a = ChurnSchedule::poisson(7, 50, 6, 0.2, 0.5);
        let b = ChurnSchedule::poisson(7, 50, 6, 0.2, 0.5);
        assert_eq!(a, b);
        let c = ChurnSchedule::poisson(8, 50, 6, 0.2, 0.5);
        assert_ne!(a, c, "different seeds give different schedules");
        assert!(a.max_round() <= 6);
        // The builder enforces die→rejoin alternation, so constructing
        // the schedule at all proves it; spot-check the visible effect:
        // no node both dies and is absent in its death round.
        for round in 1..=6u64 {
            for node in 1..=50u64 {
                if a.fault_plan_for(round).point(node).is_some() {
                    assert!(!a.absent_in(round, node));
                }
            }
        }
        // λ = 0 in both directions is the empty schedule.
        assert!(ChurnSchedule::poisson(7, 50, 6, 0.0, 0.0).is_empty());
    }

    #[test]
    fn poisson_spec_parses_and_rejects() {
        assert_eq!(
            ChurnSchedule::parse_poisson_spec("poisson:0.12,0.35").unwrap(),
            Some((0.12, 0.35))
        );
        assert_eq!(
            ChurnSchedule::parse_poisson_spec(" poisson:1,0 ").unwrap(),
            Some((1.0, 0.0))
        );
        assert_eq!(ChurnSchedule::parse_poisson_spec("die:4@1,rejoin:4@3").unwrap(), None);
        for bad in ["poisson:", "poisson:0.1", "poisson:x,0.2", "poisson:0.1,-0.2"] {
            assert!(ChurnSchedule::parse_poisson_spec(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn fail_point_names_roundtrip() {
        for p in [
            FailPoint::NeverStart,
            FailPoint::AfterGet,
            FailPoint::AfterPost,
            FailPoint::InitiatorAfterPost,
        ] {
            assert_eq!(FailPoint::from_name(p.name()), Some(p));
        }
        assert_eq!(FailPoint::from_name("nope"), None);
    }
}
