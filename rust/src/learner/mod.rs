//! Learner state machines — the client side of the SAFE chain (§5.1–5.4).
//!
//! A learner is either the *initiator* (masks its vector with random `R`,
//! starts the chain, unmasks and publishes the average) or a
//! *non-initiator* (pull → decrypt → add → re-encrypt → push). Both roles
//! handle the two failover paths:
//!
//! * **progress failover** (§5.3): a `check_aggregate` poll answers
//!   `repost` → re-encrypt the same aggregate for the node after the
//!   failed one and post again;
//! * **initiator failover** (§5.4): the whole-aggregation timeout expires
//!   → ask `should_initiate`; the first asker becomes the new initiator
//!   and everyone restarts their steps.

pub mod actor;
pub mod faults;

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::profile::{DeviceProfile, OpKind};
use crate::crypto::envelope::{CipherMode, Envelope};
use crate::crypto::rng::SecureRng;
use crate::crypto::rsa::{RsaKeyPair, RsaPublicKey};
use crate::crypto::SymmetricKey;
use crate::json::Value;
use crate::proto;
use crate::runtime::vector::VectorMath;
use crate::transport::{as_transport_error, ClientTransport, MessageStats, RetryPolicy};
use faults::{FailPoint, FaultPlan};

/// Everything one learner needs to participate in aggregations.
pub struct LearnerContext {
    pub node: u64,
    pub group: u64,
    /// Chain order of this learner's group (node ids).
    pub chain: Vec<u64>,
    /// Total learners across all groups (chain.len() < this ⇒ subgroups).
    pub expected_total_nodes: usize,
    /// Key material is `Arc`-shared: the multi-round engine forks a
    /// context per round, and only a rejoin re-key ever replaces these
    /// maps (clone-on-write), so a fork is pointer-cheap.
    pub keys: Arc<RsaKeyPair>,
    /// Lazily-built CRT decryption context for our private key, shared by
    /// every envelope this learner opens (and propagated through forks).
    /// Replaced alongside `keys` on a re-key.
    pub rsa_dec: once_cell::sync::OnceCell<crate::crypto::rsa::RsaDecryptCtx>,
    /// Public keys of the peers in this group (fetched in round 0).
    pub peer_keys: Arc<BTreeMap<u64, RsaPublicKey>>,
    /// §5.8 pre-negotiated keys: `send_keys[to]` = key the receiver `to`
    /// generated for us; `recv_keys[from]` = key we generated for `from`.
    pub send_keys: Arc<BTreeMap<u64, SymmetricKey>>,
    pub recv_keys: Arc<BTreeMap<u64, SymmetricKey>>,
    pub mode: CipherMode,
    pub compress: bool,
    pub profile: DeviceProfile,
    pub transport: Arc<dyn ClientTransport>,
    pub math: Arc<dyn VectorMath>,
    pub rng: std::sync::Mutex<Box<dyn SecureRng + Send>>,
    /// Whole-aggregation timeout (→ initiator failover, §5.4).
    pub aggregation_timeout: Duration,
    /// §7: constrained devices draw one random seed regardless of feature
    /// count ("only a single seed is used regardless of the number of
    /// features aggregated").
    pub single_seed_mask: bool,
    /// The initiator configured for round 0 (the chain head).
    pub initial_initiator: u64,
    /// §5.9 staggered polling: how long this node holds off before its
    /// first `get_aggregate` poll ("the nodes at the end of the chain only
    /// need to engage at the very end of the aggregation").
    pub stagger_delay: Duration,
    /// Session round-epoch this context participates in (multi-round
    /// engine). Stamped on every `post_aggregate` so the controller can
    /// reject stragglers from a finished round.
    pub epoch: u64,
    /// Retry policy for transport faults: bounded attempts with
    /// exponential backoff, derived from the active `NetProfile`'s
    /// expected RTT. Long-polls retry freely (idempotent); posts are made
    /// retry-safe by the attempt-dedup token below.
    pub retry: RetryPolicy,
    /// Session-wide message counters — the learner records its own
    /// retries here so they surface in `RoundMetrics`.
    pub stats: Arc<MessageStats>,
    /// Home controller shard brokering this learner's chain (sharded
    /// plane): the event executor routes the learner's calls through the
    /// shard's transport/hub pair. Always 0 when `--shards 1`.
    pub shard: usize,
    /// Monotonic per-context sequence for attempt-dedup tokens. Combined
    /// with the node id into a token that is unique per *logical* post but
    /// stable across retries of the same post, so a resend after
    /// response-leg loss is absorbed as `duplicate` instead of
    /// double-counted.
    pub post_seq: std::sync::atomic::AtomicU64,
}

/// What a learner reports after an aggregation completes.
#[derive(Debug, Clone)]
pub struct LearnerOutcome {
    pub node: u64,
    pub average: Vec<f64>,
    pub was_initiator: bool,
    /// Times this learner re-posted around a failed successor.
    pub reposts: u64,
    /// Initiator-failover restarts this learner went through.
    pub restarts: u64,
    /// Contributor count the initiator divided by (0 for non-initiators).
    pub contributors: u64,
    /// The learner died at an injected fault point before finishing.
    pub died: bool,
    /// The learner gave up because it blew through the hard-deadline
    /// safety net (see [`hard_deadline_for`]) — a distinct, reportable
    /// outcome rather than a session-aborting error.
    pub deadline_exceeded: bool,
}

impl LearnerOutcome {
    /// Outcome for a node that never participated this round — either it
    /// hit a [`FailPoint`] immediately, or the churn schedule kept it out
    /// of the round entirely (the multi-round engine synthesizes these
    /// for absent nodes).
    pub fn absent(node: u64) -> Self {
        LearnerOutcome {
            node,
            average: vec![],
            was_initiator: false,
            reposts: 0,
            restarts: 0,
            contributors: 0,
            died: true,
            deadline_exceeded: false,
        }
    }

    pub(crate) fn dead(node: u64) -> Self {
        LearnerOutcome::absent(node)
    }

    /// Outcome for a learner that exceeded its hard deadline: counts as
    /// died, with the accumulated failover counters preserved.
    pub(crate) fn timed_out(node: u64, reposts: u64, restarts: u64) -> Self {
        LearnerOutcome {
            node,
            average: vec![],
            was_initiator: false,
            reposts,
            restarts,
            contributors: 0,
            died: true,
            deadline_exceeded: true,
        }
    }
}

/// Hard-deadline safety net so a protocol bug can't hang a session: the
/// base allowance covers one full aggregation plus slack, and every
/// initiator-failover restart observed extends it by two more aggregation
/// timeouts (a restart legitimately consumes up to one timeout waiting
/// plus one retrying) — instead of the old flat `timeout × 8`, which
/// silently under-provisioned high-churn rounds and over-provisioned
/// quiet ones.
pub(crate) fn hard_deadline_for(start: Instant, timeout: Duration, restarts: u64) -> Instant {
    let scale = 2 + 2 * restarts.min(32) as u32;
    start + timeout * scale + Duration::from_secs(5)
}

impl LearnerContext {
    /// Clone this context with a fresh RNG (the one field that cannot be
    /// cloned). The session engine forks a learner's long-lived context
    /// once per round — same keys, new round view (chain order, epoch,
    /// stagger slot) — then tweaks the round-specific fields on the copy.
    /// Key material is shared, which is the point: keys are exchanged
    /// once and reused across rounds (paper §5, footnote 3).
    pub fn fork(&self, rng: Box<dyn SecureRng + Send>) -> LearnerContext {
        LearnerContext {
            node: self.node,
            group: self.group,
            chain: self.chain.clone(),
            expected_total_nodes: self.expected_total_nodes,
            keys: self.keys.clone(),
            rsa_dec: self.rsa_dec.clone(),
            peer_keys: self.peer_keys.clone(),
            send_keys: self.send_keys.clone(),
            recv_keys: self.recv_keys.clone(),
            mode: self.mode,
            compress: self.compress,
            profile: self.profile.clone(),
            transport: self.transport.clone(),
            math: self.math.clone(),
            rng: std::sync::Mutex::new(rng),
            aggregation_timeout: self.aggregation_timeout,
            single_seed_mask: self.single_seed_mask,
            initial_initiator: self.initial_initiator,
            stagger_delay: self.stagger_delay,
            epoch: self.epoch,
            retry: self.retry,
            stats: self.stats.clone(),
            shard: self.shard,
            // Fresh token space per fork is fine: the controller's
            // seen-token set is per (group, round) and resets with it.
            post_seq: std::sync::atomic::AtomicU64::new(0),
        }
    }

    pub(crate) fn successor(&self, of: u64) -> u64 {
        let pos = self.chain.iter().position(|&n| n == of).unwrap_or(0);
        self.chain[(pos + 1) % self.chain.len()]
    }

    pub(crate) fn multi_group(&self) -> bool {
        self.chain.len() < self.expected_total_nodes
    }

    /// Generate the initiator mask vector (charged to the device profile).
    pub(crate) fn gen_mask(&self, len: usize) -> Vec<f64> {
        let mut rng = self.rng.lock().unwrap();
        if self.single_seed_mask {
            // Deep-edge: one random draw, replicated (paper §7).
            self.profile.charge(OpKind::RandomBytes, 8);
            let r = mask_value(rng.next_u64());
            vec![r; len]
        } else {
            self.profile.charge(OpKind::RandomBytes, len * 8);
            (0..len).map(|_| mask_value(rng.next_u64())).collect()
        }
    }

    /// Seal `vector` for `to`, honouring cipher mode and device profile.
    pub(crate) fn seal_for(&self, vector: &[f64], to: u64) -> Result<Envelope> {
        let mut rng = self.rng.lock().unwrap();
        let payload_bytes = vector.len() * 8;
        match self.mode {
            CipherMode::None => {}
            CipherMode::RsaOnly => {
                let k = self
                    .peer_keys
                    .get(&to)
                    .map(|p| p.max_block_payload().max(1))
                    .unwrap_or(1);
                let blocks = (payload_bytes + k - 1) / k;
                for _ in 0..blocks {
                    self.profile.charge(OpKind::RsaPublic, 0);
                }
            }
            CipherMode::Hybrid => {
                self.profile.charge(OpKind::RsaPublic, 0); // seal the AES key
                self.profile.charge(OpKind::Aes, payload_bytes);
            }
            CipherMode::PreNegotiated => {
                self.profile.charge(OpKind::Aes, payload_bytes);
            }
        }
        Envelope::seal(
            vector,
            self.mode,
            self.peer_keys.get(&to),
            self.send_keys.get(&to),
            self.compress,
            rng.as_mut(),
        )
    }

    /// Open an envelope received from `from`.
    pub(crate) fn open_from(&self, env: &Envelope, from: u64) -> Result<Vec<f64>> {
        let payload_bytes = env.body.len();
        match self.mode {
            CipherMode::None => {}
            CipherMode::RsaOnly => {
                let k = self.keys.public.modulus_len().max(1);
                let blocks = (payload_bytes + k - 1) / k;
                for _ in 0..blocks {
                    self.profile.charge(OpKind::RsaPrivate, 0);
                }
            }
            CipherMode::Hybrid => {
                self.profile.charge(OpKind::RsaPrivate, 0); // unseal the AES key
                self.profile.charge(OpKind::Aes, payload_bytes);
            }
            CipherMode::PreNegotiated => {
                self.profile.charge(OpKind::Aes, payload_bytes);
            }
        }
        let dec = self.rsa_dec.get_or_init(|| self.keys.private.decrypt_ctx());
        env.open_with(Some(dec), self.recv_keys.get(&from))
    }

    /// One logical call = up to `retry.attempts` physical attempts. Only
    /// typed, retryable transport faults are retried (injected loss, lost
    /// connections); protocol-level errors and fatal HTTP statuses
    /// propagate immediately. Safe for every path the learner uses:
    /// long-polls are idempotent and chain posts carry a dedup token.
    fn call(&self, path: &str, body: &Value) -> Result<Value> {
        let mut attempt = 0u32;
        loop {
            match self.transport.call(path, body) {
                Ok(resp) => return Ok(resp),
                Err(e) => {
                    let retryable =
                        as_transport_error(&e).map_or(false, |t| t.retryable());
                    if !retryable || attempt + 1 >= self.retry.attempts.max(1) {
                        return Err(e);
                    }
                    self.stats.record_retry();
                    std::thread::sleep(self.retry.backoff(attempt));
                    attempt += 1;
                }
            }
        }
    }

    /// Long-poll wrapper: repeat `path` until status != empty or deadline.
    fn wait_for(&self, path: &str, body: &Value, deadline: Instant) -> Result<Option<Value>> {
        loop {
            let resp = self.call(path, body)?;
            if !proto::is_empty_status(&resp) {
                return Ok(Some(resp));
            }
            if Instant::now() >= deadline {
                return Ok(None);
            }
        }
    }
}

/// Map a u64 to a "large random number" mask: a value in ±2^20 quantized
/// to 1/1024. Large relative to model weights (which are O(1)), yet small
/// enough that f64 masking cancels to ≤2^20·ε ≈ 2.3e-10 absolute error.
pub fn mask_value(raw: u64) -> f64 {
    let v = (raw >> 33) as i64 - (1i64 << 30);
    v as f64 / 1024.0
}

/// Run one learner to completion (possibly across initiator-failover
/// restarts). `local` is this node's feature-vector contribution.
pub fn run_learner(
    ctx: &LearnerContext,
    local: &[f64],
    faults: &FaultPlan,
) -> Result<LearnerOutcome> {
    if faults.fails_at(ctx.node, FailPoint::NeverStart) {
        return Ok(LearnerOutcome::dead(ctx.node));
    }
    let mut restarts = 0u64;
    let mut reposts = 0u64;
    let mut round_id = 0u64;
    let mut is_initiator = ctx.node == ctx.initial_initiator;
    let started = Instant::now();

    loop {
        // Safety net (recomputed per attempt: the allowance scales with
        // restarts observed — see `hard_deadline_for`). Exceeding it is a
        // reportable outcome, not a session-aborting error.
        if Instant::now() > hard_deadline_for(started, ctx.aggregation_timeout, restarts) {
            return Ok(LearnerOutcome::timed_out(ctx.node, reposts, restarts));
        }
        let attempt = if is_initiator {
            run_initiator(ctx, local, faults, round_id, &mut reposts)
        } else {
            run_non_initiator(ctx, local, faults, round_id, &mut reposts)
        };
        let result = match attempt {
            Ok(r) => r,
            // Graceful degradation: retry exhaustion (or a fatal transport
            // fault) makes this node a live failure — the chain re-forms
            // around it via §5.3/§5.4 instead of the session wedging on an
            // error. Non-transport errors are real bugs and still abort.
            Err(e) if as_transport_error(&e).is_some() => {
                return Ok(LearnerOutcome::dead(ctx.node));
            }
            Err(e) => return Err(e),
        };
        match result {
            StepResult::Done { average, contributors } => {
                return Ok(LearnerOutcome {
                    node: ctx.node,
                    average,
                    was_initiator: is_initiator,
                    reposts,
                    restarts,
                    contributors,
                    died: false,
                    deadline_exceeded: false,
                });
            }
            StepResult::Died => return Ok(LearnerOutcome::dead(ctx.node)),
            StepResult::Restart { elected, new_round } => {
                restarts += 1;
                is_initiator = elected;
                round_id = new_round;
            }
        }
    }
}

enum StepResult {
    Done { average: Vec<f64>, contributors: u64 },
    Died,
    Restart { elected: bool, new_round: u64 },
}

/// Ask the controller whether we should take over as initiator (§5.4).
fn election(ctx: &LearnerContext) -> Result<StepResult> {
    let resp = ctx.call(
        proto::SHOULD_INITIATE,
        &proto::NodeOp::new(ctx.node, ctx.group).to_value(),
    )?;
    let decision = proto::InitiateDecision::from_value(&resp)?;
    Ok(StepResult::Restart { elected: decision.init, new_round: decision.round_id })
}

/// Body of a chain post — shared by the blocking path and the event
/// runtime's state machine so both stamp round/epoch identically.
pub(crate) fn post_body(ctx: &LearnerContext, to: u64, env: &Envelope, round_id: u64) -> Value {
    // Attempt-dedup token: unique per logical post (node ⊕ sequence),
    // stable across retries because the body is built once and re-sent
    // verbatim. A repost (§5.3) is a new logical post → a new token.
    let seq = ctx.post_seq.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    proto::PostAggregate {
        from_node: ctx.node,
        to_node: to,
        group: ctx.group,
        // Compact binary framing — raw ciphertext on a binary wire;
        // base64 happens only inside JsonCodec, if at all.
        aggregate: env.to_blob(),
        round_id: Some(round_id),
        epoch: Some(ctx.epoch),
        token: Some((ctx.node << 24) | (seq & 0xff_ffff)),
    }
    .to_value()
}

fn post_with_round(ctx: &LearnerContext, to: u64, env: &Envelope, round_id: u64) -> Result<Value> {
    ctx.call(proto::POST_AGGREGATE, &post_body(ctx, to, env, round_id))
}

/// Post to `to`, then watch `check_aggregate(to)` until the chain advances
/// past it, reposting around failures (§5.3). Returns Ok(false) if the
/// aggregation deadline passed (→ initiator-failover election).
fn post_and_watch(
    ctx: &LearnerContext,
    vector: &[f64],
    mut to: u64,
    round_id: u64,
    reposts: &mut u64,
    deadline: Instant,
) -> Result<bool> {
    let env = ctx.seal_for(vector, to)?;
    post_with_round(ctx, to, &env, round_id)?;
    loop {
        let check_body = proto::NodeOp::new(to, ctx.group).to_value();
        match ctx.wait_for(proto::CHECK_AGGREGATE, &check_body, deadline)? {
            None => return Ok(false),
            Some(resp) => match proto::CheckOutcome::from_value(&resp)? {
                proto::CheckOutcome::Consumed => return Ok(true),
                proto::CheckOutcome::Repost { to_node: new_target } => {
                    // §5.3: re-encrypt for the node after the failed one.
                    *reposts += 1;
                    let env = ctx.seal_for(vector, new_target)?;
                    post_with_round(ctx, new_target, &env, round_id)?;
                    to = new_target;
                }
            },
        }
    }
}

fn run_initiator(
    ctx: &LearnerContext,
    local: &[f64],
    faults: &FaultPlan,
    round_id: u64,
    reposts: &mut u64,
) -> Result<StepResult> {
    let deadline = Instant::now() + ctx.aggregation_timeout;
    // 1. Mask the local vector with the big random number R (§5.1.1).
    let mask = ctx.gen_mask(local.len());
    let masked = ctx.math.mask(local, &mask);
    // 2. Encrypt for the next node in the chain and post.
    let next = ctx.successor(ctx.node);
    if !post_and_watch(ctx, &masked, next, round_id, reposts, deadline)? {
        return election(ctx);
    }
    if faults.fails_at(ctx.node, FailPoint::InitiatorAfterPost) {
        return Ok(StepResult::Died);
    }
    // 3. Wait for the final aggregate from the last node in the chain.
    let poll_body = proto::NodeOp::new(ctx.node, ctx.group).to_value();
    let resp = match ctx.wait_for(proto::GET_AGGREGATE, &poll_body, deadline)? {
        Some(r) => r,
        None => return election(ctx),
    };
    let delivery = proto::AggregateDelivery::from_value(&resp)?;
    let contributors = delivery.posted.unwrap_or(ctx.chain.len() as u64);
    let env = Envelope::from_blob(&delivery.aggregate)?;
    let agg = ctx.open_from(&env, delivery.from_node)?;
    // 4. Unmask, divide by the contributor count the controller reported
    //    (n, or n−f after progress failovers), publish (§5.1.1, §5.3).
    let average = ctx.math.finalize(&agg, &mask, contributors as f64);
    ctx.call(
        proto::POST_AVERAGE,
        &proto::PostAverage::body(ctx.node, ctx.group, &average, contributors),
    )?;
    // With subgroups the initiator also pulls the global cross-group
    // average (§5.5 — the "+g" message in the formula).
    let final_avg = if ctx.multi_group() {
        match ctx.wait_for(proto::GET_AVERAGE, &poll_body, deadline)? {
            Some(r) => proto::AverageReady::from_value(&r)?.average,
            None => return election(ctx),
        }
    } else {
        average
    };
    Ok(StepResult::Done { average: final_avg, contributors })
}

fn run_non_initiator(
    ctx: &LearnerContext,
    local: &[f64],
    faults: &FaultPlan,
    round_id: u64,
    reposts: &mut u64,
) -> Result<StepResult> {
    let deadline = Instant::now() + ctx.aggregation_timeout;
    // §5.9: hold off engaging the controller until roughly our turn,
    // keeping the concurrent long-poll count low.
    if !ctx.stagger_delay.is_zero() {
        std::thread::sleep(ctx.stagger_delay);
    }
    // 1. Wait for the previous node's aggregate (§5.1.2).
    let poll_body = proto::NodeOp::new(ctx.node, ctx.group).to_value();
    let resp = match ctx.wait_for(proto::GET_AGGREGATE, &poll_body, deadline)? {
        Some(r) => r,
        None => return election(ctx),
    };
    if faults.fails_at(ctx.node, FailPoint::AfterGet) {
        return Ok(StepResult::Died);
    }
    let delivery = proto::AggregateDelivery::from_value(&resp)?;
    let msg_round = delivery.round_id.unwrap_or(round_id);
    let env = Envelope::from_blob(&delivery.aggregate)?;
    let mut agg = ctx.open_from(&env, delivery.from_node)?;
    // 2. Add the local vector, re-encrypt for our successor, post, watch.
    ctx.math.add_assign(&mut agg, local);
    let next = ctx.successor(ctx.node);
    if !post_and_watch(ctx, &agg, next, msg_round, reposts, deadline)? {
        return election(ctx);
    }
    if faults.fails_at(ctx.node, FailPoint::AfterPost) {
        return Ok(StepResult::Died);
    }
    // 3. Wait for the published average (§5.1.2 step 4).
    match ctx.wait_for(proto::GET_AVERAGE, &poll_body, deadline)? {
        Some(r) => {
            let avg = proto::AverageReady::from_value(&r)?.average;
            Ok(StepResult::Done { average: avg, contributors: 0 })
        }
        None => election(ctx),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_values_are_large_and_quantized() {
        let mut rng = crate::crypto::DeterministicRng::seed(1);
        let mut seen_large = false;
        for _ in 0..100 {
            let m = mask_value(rng.next_u64());
            assert!(m.abs() <= (1u64 << 20) as f64 + 1.0);
            // Quantized to 1/1024 → multiplying by 1024 gives an integer.
            assert_eq!((m * 1024.0).fract(), 0.0);
            if m.abs() > 1000.0 {
                seen_large = true;
            }
        }
        assert!(seen_large, "masks should usually dwarf O(1) weights");
    }

    #[test]
    fn mask_cancels_to_tiny_error() {
        let mut rng = crate::crypto::DeterministicRng::seed(2);
        for _ in 0..1000 {
            let m = mask_value(rng.next_u64());
            let x = 0.123456789;
            let err = ((x + m) - m - x).abs();
            assert!(err < 1e-9, "err={}", err);
        }
    }
}
