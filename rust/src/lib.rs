//! # SAFE: Secure Aggregation with Failover and Encryption
//!
//! Full-system reproduction of Sandholm, Mukherjee & Huberman (2021),
//! "SAFE: Secure Aggregation with Failover and Encryption" (CableLabs).
//!
//! SAFE organizes federated-learning participants in an ordered circular
//! chain. An *initiator* masks its local feature vector with a large random
//! number, encrypts it with the next node's public key and posts it to a
//! *controller* that acts as a mere message broker. Each *non-initiator*
//! decrypts, adds its local vector, re-encrypts for the next node, and posts.
//! The initiator finally unmasks and publishes the average. Failures are
//! handled by an external *progress monitor* (chain re-routing) and an
//! aggregation timeout (initiator re-election).
//!
//! Sessions are multi-round: [`protocols::SafeSession::run_rounds`] drives
//! R aggregation rounds over persistent learner actors (keys exchanged
//! once in round 0 and reused, paper §5 footnote 3), with a
//! [`learner::faults::ChurnSchedule`] scheduling per-round node deaths and
//! rejoins (including seeded Poisson churn at paper scale) — chains
//! re-form around absent nodes and a returning node re-keys alone. All
//! group/chain decisions flow through the [`topology`] subsystem: a
//! [`topology::GroupPlanner`] builds one immutable
//! [`topology::TopologyPlan`] per round, merging groups that churn pushed
//! below the §5.3 privacy floor into a neighbouring group instead of
//! aborting. See the repository `README.md` for the architecture map,
//! `docs/WIRE.md` for the wire-format specification and
//! `docs/TOPOLOGY.md` for the planner invariants.
//!
//! The crate is a three-layer system:
//!  * **L3 (this crate)** — the coordination contribution: controller broker,
//!    learner state machines, progress monitor, subgrouping, hierarchical
//!    federation, failover, plus the INSEC and BON (Bonawitz et al. 2017)
//!    baselines and every substrate they need (JSON codec, HTTP transport,
//!    bignum RSA, Shamir sharing, Diffie-Hellman, PRG).
//!  * **L2 (python/compile/model.py)** — JAX compute graphs for learner-local
//!    training and the aggregation vector math, AOT-lowered to HLO text.
//!  * **L1 (python/compile/kernels/)** — Pallas kernels for the compute
//!    hot-spots, lowered inside the L2 graphs (interpret mode on CPU).
//!
//! Python never runs on the aggregation path: `rust/src/runtime` loads the
//! AOT artifacts through PJRT and executes them from Rust.

pub mod util;
pub mod blob;
pub mod json;
pub mod crypto;
pub mod transport;
pub mod proto;
pub mod controller;
pub mod learner;
pub mod topology;
pub mod monitor;
pub mod protocols;
pub mod runtime;
pub mod runtime_exec;
pub mod fl;
pub mod metrics;
pub mod config;
pub mod harness;
pub mod testkit;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
