//! `safe` — the SAFE secure-aggregation CLI / launcher.
//!
//! Subcommands:
//!   controller  — serve the controller over HTTP
//!   run         — run one SAFE aggregation round in-process, print metrics
//!   insec       — same for the INSEC baseline
//!   bon         — same for the BON (Bonawitz) baseline
//!   train       — federated training with SAFE aggregation (E19)
//!   help        — this text

use std::sync::Arc;

use safe_agg::config::{Args, SessionConfig};
use safe_agg::controller::{Controller, ControllerConfig};
use safe_agg::fl::{self, FlConfig};
use safe_agg::harness::multiround::MultiRoundReport;
use safe_agg::learner::faults::{ChurnSchedule, FaultPlan};
use safe_agg::protocols::bon::BonSession;
use safe_agg::protocols::insec::InsecSession;
use safe_agg::protocols::SafeSession;
use safe_agg::transport::http::HttpServer;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(argv);
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "controller" => cmd_controller(&args),
        "run" => cmd_run(&args),
        "insec" => cmd_insec(&args),
        "bon" => cmd_bon(&args),
        "train" => cmd_train(&args),
        _ => {
            print_help();
            0
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "safe — SAFE: Secure Aggregation with Failover and Encryption\n\
         \n\
         USAGE: safe <command> [--flags]\n\
         \n\
         COMMANDS:\n\
           controller --listen ADDR       serve the controller over HTTP\n\
           run     --nodes N --features F --mode saf|safe|rsa|preneg\n\
                   [--groups G] [--profile edge|deep-edge] [--weighted]\n\
                   [--fail-from A --fail-to B] [--engine native|xla|auto]\n\
                   [--wire json|binary|json+deflate|binary+deflate]\n\
                                          wire codec (default json)\n\
                   [--rounds R] [--churn SPEC]\n\
                                          multi-round engine: R rounds over\n\
                                          persistent learners; SPEC is\n\
                                          comma-separated die:NODE@ROUND\n\
                                          [:never-start|after-get|after-post|\n\
                                          initiator-after-post] and\n\
                                          rejoin:NODE@ROUND events, or\n\
                                          poisson:LAMBDA_DIE,LAMBDA_REJOIN\n\
                                          for seeded per-round Poisson\n\
                                          arrival/departure at paper scale\n\
                   [--runtime events|threads]\n\
                                          learner executor (default events):\n\
                                          `events` multiplexes all learners\n\
                                          as state machines over a fixed\n\
                                          worker pool; `threads` keeps one\n\
                                          OS thread per learner (HTTP\n\
                                          transports always use threads)\n\
                   [--workers N]          event-runtime worker threads\n\
                                          (default 0 = available cores)\n\
                   [--net PROFILE]        hostile-network fault injection:\n\
                                          PRESET[,FIELD=VALUE]* with preset\n\
                                          ideal|lan|wan|lte|lossy|straggler\n\
                                          and fields lat-us, jitter-us,\n\
                                          per-kib-us, loss-req, loss-resp,\n\
                                          straggler-every, straggler-x,\n\
                                          seed; all faults are drawn\n\
                                          deterministically from the seed\n\
                                          (default ideal = no faults)\n\
                   [--merge-floor on|off] privacy-floor re-balancing\n\
                                          (default on): merge a group that\n\
                                          churn pushed below 3 live nodes\n\
                                          into its smallest neighbour (only\n\
                                          moved nodes re-key) instead of\n\
                                          aborting the round\n\
                   [--shards K]           controller shards (default 1):\n\
                                          spread the groups over K parallel\n\
                                          shard controllers with a fan-in\n\
                                          tier combining shard partials\n\
                                          (in-proc transport only; K is\n\
                                          clamped to the group count)\n\
           insec   --nodes N --features F   INSEC baseline round\n\
           bon     --nodes N --features F   BON (Bonawitz) baseline round\n\
           train   --nodes N --rounds R [--local-steps S] [--lr LR]\n\
                   federated training with SAFE aggregation each round\n"
    );
}

fn cmd_controller(args: &Args) -> i32 {
    let listen = args.get("listen").unwrap_or("127.0.0.1:7464");
    let ctrl = Arc::new(Controller::new(ControllerConfig::default()));
    match HttpServer::start(listen, ctrl) {
        Ok(server) => {
            println!("controller listening on {}", server.url());
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        Err(e) => {
            eprintln!("failed to start controller: {e:#}");
            1
        }
    }
}

fn inputs_for(cfg: &SessionConfig) -> Vec<Vec<f64>> {
    (0..cfg.n_nodes)
        .map(|i| {
            (0..cfg.wire_features())
                .map(|f| (i + 1) as f64 + 0.01 * f as f64)
                .collect()
        })
        .collect()
}

fn faults_from(args: &Args) -> FaultPlan {
    match (args.get("fail-from"), args.get("fail-to")) {
        (Some(a), Some(b)) => {
            FaultPlan::kill_range(a.parse().unwrap_or(0), b.parse().unwrap_or(0))
        }
        _ => FaultPlan::none(),
    }
}

fn cmd_run(args: &Args) -> i32 {
    let mut cfg = args.to_session_config();
    if let Some(spec) = args.get("net") {
        match safe_agg::transport::NetProfile::parse(spec) {
            Ok(p) => cfg.net = p,
            Err(e) => {
                eprintln!("bad --net profile: {e:#}");
                return 2;
            }
        }
    }
    let faults = faults_from(args);
    let rounds = args.get_usize("rounds", 0);
    // A poisson spec generates a schedule for an exact round count
    // (--rounds, default 5) — the session must run all of them even when
    // the last random event lands earlier (or no event fires at all).
    let mut poisson_rounds = None;
    let churn = match args.get("churn") {
        Some(spec) => match ChurnSchedule::parse_poisson_spec(spec) {
            Ok(Some((lambda_die, lambda_rejoin))) => {
                let r = if rounds > 0 { rounds } else { 5 };
                poisson_rounds = Some(r);
                Some(ChurnSchedule::poisson(
                    cfg.seed.unwrap_or(42),
                    cfg.n_nodes,
                    r as u64,
                    lambda_die,
                    lambda_rejoin,
                ))
            }
            Ok(None) => match ChurnSchedule::parse(spec) {
                Ok(c) => Some(c),
                Err(e) => {
                    eprintln!("bad --churn spec: {e:#}");
                    return 2;
                }
            },
            Err(e) => {
                eprintln!("bad --churn spec: {e:#}");
                return 2;
            }
        },
        None => None,
    };
    if rounds > 1 || churn.is_some() {
        // Multi-round engine: R rounds over persistent learner actors,
        // with optional cross-round churn. --fail-from/--fail-to folds in
        // as round-1 deaths (the single-round meaning) unless the --churn
        // spec already schedules that node.
        let mut churn = churn.unwrap_or_else(ChurnSchedule::none);
        for (&node, &at) in &faults.faults {
            if churn.schedules(node) {
                eprintln!(
                    "--fail-from/--fail-to conflicts with --churn for node {node}; \
                     schedule it in --churn only"
                );
                return 2;
            }
            churn = churn.die(node, 1, at);
        }
        let rounds = poisson_rounds
            .unwrap_or_else(|| rounds.max(churn.max_round() as usize).max(1));
        return cmd_run_rounds(&cfg, rounds, &churn);
    }
    println!(
        "SAFE round: {} nodes × {} features, mode={}, groups={}, profile={}, wire={}, net={}",
        cfg.n_nodes,
        cfg.features,
        cfg.mode.name(),
        cfg.groups,
        cfg.profile.name,
        cfg.wire.name(),
        cfg.net.name
    );
    match SafeSession::new(cfg.clone()).and_then(|s| s.run_round(&inputs_for(&cfg), &faults)) {
        Ok(result) => {
            let m = &result.metrics;
            println!(
                "ok: {:.4}s, {} messages ({} bytes), contributors={}, \
                 progress_failovers={}, initiator_failovers={}",
                m.secs(),
                m.messages,
                m.bytes_sent,
                m.contributors,
                m.progress_failovers,
                m.initiator_failovers
            );
            println!(
                "average[0..{}] = {:?}",
                m.average.len().min(4),
                &m.average[..m.average.len().min(4)]
            );
            0
        }
        Err(e) => {
            eprintln!("SAFE round failed: {e:#}");
            1
        }
    }
}

fn cmd_run_rounds(cfg: &SessionConfig, rounds: usize, churn: &ChurnSchedule) -> i32 {
    println!(
        "SAFE session: {} rounds × {} nodes × {} features, mode={}, groups={}, wire={}, \
         runtime={:?}, net={}",
        rounds,
        cfg.n_nodes,
        cfg.features,
        cfg.mode.name(),
        cfg.groups,
        cfg.wire.name(),
        cfg.runtime,
        cfg.net.name
    );
    let inputs = inputs_for(cfg);
    let per_round: Vec<Vec<Vec<f64>>> = (0..rounds).map(|_| inputs.clone()).collect();
    let session = match SafeSession::new(cfg.clone()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("session build failed: {e:#}");
            return 1;
        }
    };
    let setup_messages = session.round0_messages;
    match session.run_rounds(&per_round, churn) {
        Ok(results) => {
            // One renderer for the per-round table + amortized-setup line
            // (shared with the failover bench's BENCH_multiround.json).
            let metrics: Vec<_> = results.into_iter().map(|r| r.metrics).collect();
            let report =
                MultiRoundReport::from_rounds("session", setup_messages, &metrics);
            print!("{}", report.to_table());
            0
        }
        Err(e) => {
            eprintln!("SAFE session failed: {e:#}");
            1
        }
    }
}

fn cmd_insec(args: &Args) -> i32 {
    let cfg = args.to_session_config();
    match InsecSession::new(cfg.clone())
        .and_then(|s| s.run_round(&inputs_for(&cfg), &faults_from(args)))
    {
        Ok(m) => {
            println!(
                "INSEC: {:.4}s, {} messages, contributors={}",
                m.secs(),
                m.messages,
                m.contributors
            );
            0
        }
        Err(e) => {
            eprintln!("INSEC round failed: {e:#}");
            1
        }
    }
}

fn cmd_bon(args: &Args) -> i32 {
    let cfg = args.to_session_config();
    match BonSession::new(cfg.clone())
        .and_then(|s| s.run_round(&inputs_for(&cfg), &faults_from(args)))
    {
        Ok(m) => {
            println!(
                "BON: {:.4}s, {} messages, contributors={}",
                m.secs(),
                m.messages,
                m.contributors
            );
            0
        }
        Err(e) => {
            eprintln!("BON round failed: {e:#}");
            1
        }
    }
}

fn cmd_train(args: &Args) -> i32 {
    let mut cfg = args.to_session_config();
    cfg.n_nodes = args.get_usize("nodes", 4);
    let fl_cfg = FlConfig {
        rounds: args.get_usize("rounds", 20),
        local_steps: args.get_usize("local-steps", 4),
        lr: args.get("lr").and_then(|v| v.parse().ok()).unwrap_or(0.05),
        seed: args.get_u64("seed", 42),
        ..Default::default()
    };
    let trainer = match fl::default_trainer() {
        Ok(t) => t,
        Err(e) => {
            eprintln!("trainer init failed: {e:#}");
            return 1;
        }
    };
    println!(
        "federated training: {} nodes, {} rounds, trainer={}",
        cfg.n_nodes,
        fl_cfg.rounds,
        trainer.name()
    );
    match fl::run_federated(&cfg, &fl_cfg, trainer) {
        Ok(result) => {
            println!("round,val_loss,mean_local_loss,agg_secs,agg_messages");
            for r in &result.curve {
                println!(
                    "{},{:.5},{:.5},{:.4},{}",
                    r.round, r.val_loss, r.mean_local_loss, r.agg_wall_secs, r.agg_messages
                );
            }
            0
        }
        Err(e) => {
            eprintln!("federated training failed: {e:#}");
            1
        }
    }
}
