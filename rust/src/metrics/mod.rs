//! Metrics: what every run reports — aggregation wall time, message
//! counts (to verify the paper's `4n`-family formulas), bytes moved, and
//! failure bookkeeping — plus the production observability plane: the
//! typed [`registry::MetricRegistry`] behind every controller's
//! `GET /metrics` endpoint ([`crate::proto::METRICS`]), with the metric
//! schema ([`names`]), path classification ([`path_class`]) and the
//! session-level recording façade ([`SessionMetrics`]).

pub mod registry;

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

pub use registry::{Counter, Gauge, Histogram, MetricRegistry, DEFAULT_LATENCY_EDGES};

/// Canonical metric family names and their help strings. Every series
/// the session emits comes from this table — the conformance suite
/// rejects any scraped family not listed here. Label conventions:
/// `path` is the protocol path, `shard` identifies which controller's
/// stats a series mirrors (`"0"`..`"K-1"`, or `"parent"` for the fan-in
/// tier's parent on a K>1 plane), `class` is [`path_class`].
pub mod names {
    /// Requests per protocol path, per shard plane. Counter.
    pub const REQUESTS_TOTAL: &str = "safe_requests_total";
    /// Request-body bytes per path/shard. Counter.
    pub const REQUEST_BYTES_TOTAL: &str = "safe_request_bytes_total";
    /// Response-body bytes per path/shard. Counter.
    pub const RESPONSE_BYTES_TOTAL: &str = "safe_response_bytes_total";
    /// Attempts re-sent after a retryable transport failure. Counter.
    pub const NET_RETRIES_TOTAL: &str = "safe_net_retries_total";
    /// Injected packet drops observed by the transport. Counter.
    pub const NET_DROPS_TOTAL: &str = "safe_net_drops_total";
    /// Duplicate posts absorbed via the attempt-dedup token. Counter.
    pub const DEDUP_POSTS_TOTAL: &str = "safe_dedup_posts_total";
    /// Completed aggregation rounds. Counter.
    pub const ROUNDS_TOTAL: &str = "safe_rounds_total";
    /// §5.3 progress failovers (f in `4n + 2f`). Counter.
    pub const PROGRESS_FAILOVERS_TOTAL: &str = "safe_progress_failovers_total";
    /// §5.4 initiator failovers. Counter.
    pub const INITIATOR_FAILOVERS_TOTAL: &str = "safe_initiator_failovers_total";
    /// Key (re-)exchange messages (footnote-3 accounting). Counter.
    pub const REKEY_MESSAGES_TOTAL: &str = "safe_rekey_messages_total";
    /// Groups dissolved by privacy-floor merges. Counter.
    pub const MERGED_GROUPS_TOTAL: &str = "safe_merged_groups_total";
    /// Nodes that aggregated away from their home group. Counter.
    pub const REASSIGNED_NODES_TOTAL: &str = "safe_reassigned_nodes_total";
    /// Learners that hit the hard-deadline safety net. Counter.
    pub const DEADLINE_EXCEEDED_TOTAL: &str = "safe_deadline_exceeded_total";
    /// Fan-in tier messages (sharded plane surcharge). Counter.
    pub const FANIN_MESSAGES_TOTAL: &str = "safe_fanin_messages_total";
    /// Monitor-triggered reposts. Counter.
    pub const MONITOR_REPOSTS_TOTAL: &str = "safe_monitor_reposts_total";
    /// Monitor privacy-floor aborts. Counter.
    pub const MONITOR_ABORTS_TOTAL: &str = "safe_monitor_aborts_total";
    /// Monitor merge signals. Counter.
    pub const MONITOR_MERGE_SIGNALS_TOTAL: &str = "safe_monitor_merge_signals_total";
    /// Nodes that contributed to the most recent round. Gauge.
    pub const LIVE_NODES: &str = "safe_live_nodes";
    /// Most recently completed round number (1-based). Gauge.
    pub const CURRENT_ROUND: &str = "safe_current_round";
    /// §5.9 connection pressure: learner polls blocked right now. Gauge.
    pub const CONTROLLER_WAITING_POLLS: &str = "safe_controller_waiting_polls";
    /// §5.9 high-water mark of concurrently blocked polls. Gauge.
    pub const CONTROLLER_PEAK_WAITING_POLLS: &str = "safe_controller_peak_waiting_polls";
    /// Constant 1 per controller, carrying the shard label. Gauge.
    pub const CONTROLLER_INFO: &str = "safe_controller_info";
    /// Per-request latency by path/shard, observed at the transport
    /// completion points of both runtimes. Histogram.
    pub const REQUEST_DURATION_SECONDS: &str = "safe_request_duration_seconds";
    /// Whole-round wall time. Histogram.
    pub const ROUND_DURATION_SECONDS: &str = "safe_round_duration_seconds";
    /// Fan-in post→install span (slowest shard per round). Histogram.
    pub const FANIN_DURATION_SECONDS: &str = "safe_fanin_duration_seconds";
}

/// Classify a protocol path for the `class` label: `"chain"` for the
/// §5.2 aggregation chain ops the `4n + 2f (+g)` formula bounds,
/// `"key"` for §5.1/§5.8 key traffic (footnote-3 accounting), `"fanin"`
/// for the sharded plane's §5.10 fan-in tier, `"monitor"` for §5.3
/// progress pings, and `"ops"` for management/scrape traffic. The
/// per-round accounting in the session driver filters by this
/// classification instead of naming individual paths.
pub fn path_class(path: &str) -> &'static str {
    use crate::proto;
    match path {
        proto::PROGRESS_CHECK => "monitor",
        proto::REGISTER_KEY
        | proto::GET_KEY
        | proto::POST_PRENEG_KEYS
        | proto::GET_PRENEG_KEY => "key",
        proto::FED_POST_CHILD_AVERAGE | proto::FED_GET_GLOBAL_AVERAGE => "fanin",
        proto::CONFIGURE | proto::BEGIN_ROUND | proto::RESET | proto::STATUS
        | proto::METRICS => "ops",
        _ => "chain",
    }
}

/// Per-shard request-latency recorder: resolves and caches the
/// `safe_request_duration_seconds{path, shard, class}` histogram handle
/// per path so the transport hot path does one map lookup under a small
/// private lock, not a registry registration.
pub struct LatencyRecorder {
    registry: Arc<MetricRegistry>,
    shard: String,
    cache: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl std::fmt::Debug for LatencyRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LatencyRecorder").field("shard", &self.shard).finish()
    }
}

impl LatencyRecorder {
    /// A recorder tagging every observation with `shard`.
    pub fn new(registry: Arc<MetricRegistry>, shard: &str) -> Arc<LatencyRecorder> {
        Arc::new(LatencyRecorder {
            registry,
            shard: shard.to_string(),
            cache: Mutex::new(BTreeMap::new()),
        })
    }

    /// Record one request's completion latency on `path`.
    pub fn observe(&self, path: &str, latency: Duration) {
        let h = {
            let mut cache = self.cache.lock().unwrap();
            match cache.get(path) {
                Some(h) => h.clone(),
                None => {
                    let h = self.registry.histogram(
                        names::REQUEST_DURATION_SECONDS,
                        "Per-request completion latency by path and shard.",
                        &[
                            ("path", path),
                            ("shard", &self.shard),
                            ("class", path_class(path)),
                        ],
                        DEFAULT_LATENCY_EDGES,
                    );
                    cache.insert(path.to_string(), h.clone());
                    h
                }
            }
        };
        h.observe_duration(latency);
    }
}

/// The session's one registry plus pre-resolved handles for the
/// round-event metrics pushed by the multi-round engine. Transport
/// counters are *not* pushed through this type — they are mirrored from
/// `MessageStats` by scrape-time collectors the session registers, so
/// the registry can never disagree with the accounting the formula
/// tests pin.
pub struct SessionMetrics {
    registry: Arc<MetricRegistry>,
    rounds: Arc<Counter>,
    progress_failovers: Arc<Counter>,
    initiator_failovers: Arc<Counter>,
    rekey_messages: Arc<Counter>,
    merged_groups: Arc<Counter>,
    reassigned_nodes: Arc<Counter>,
    deadline_exceeded: Arc<Counter>,
    fanin_messages: Arc<Counter>,
    live_nodes: Arc<Gauge>,
    current_round: Arc<Gauge>,
    round_duration: Arc<Histogram>,
    fanin_duration: Arc<Histogram>,
}

impl std::fmt::Debug for SessionMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SessionMetrics").finish()
    }
}

impl SessionMetrics {
    /// Build a fresh registry with the round-event families registered.
    pub fn new() -> Arc<SessionMetrics> {
        let registry = MetricRegistry::new();
        // Round wall-times live on a coarser grid than request latencies:
        // the same shape, shifted up to cover multi-second rounds.
        let round_edges: Vec<f64> =
            DEFAULT_LATENCY_EDGES.iter().map(|e| e * 10.0).collect();
        let sm = SessionMetrics {
            rounds: registry.counter(names::ROUNDS_TOTAL, "Completed aggregation rounds.", &[]),
            progress_failovers: registry.counter(
                names::PROGRESS_FAILOVERS_TOTAL,
                "Progress failovers (f in 4n + 2f).",
                &[],
            ),
            initiator_failovers: registry.counter(
                names::INITIATOR_FAILOVERS_TOTAL,
                "Initiator failovers (section 5.4).",
                &[],
            ),
            rekey_messages: registry.counter(
                names::REKEY_MESSAGES_TOTAL,
                "Key re-exchange messages, accounted separately per footnote 3.",
                &[],
            ),
            merged_groups: registry.counter(
                names::MERGED_GROUPS_TOTAL,
                "Groups dissolved by privacy-floor merges.",
                &[],
            ),
            reassigned_nodes: registry.counter(
                names::REASSIGNED_NODES_TOTAL,
                "Nodes aggregated away from their home group.",
                &[],
            ),
            deadline_exceeded: registry.counter(
                names::DEADLINE_EXCEEDED_TOTAL,
                "Learners that hit the hard-deadline safety net.",
                &[],
            ),
            fanin_messages: registry.counter(
                names::FANIN_MESSAGES_TOTAL,
                "Fan-in tier messages (sharded plane surcharge).",
                &[],
            ),
            live_nodes: registry.gauge(
                names::LIVE_NODES,
                "Nodes that contributed to the most recent round.",
                &[],
            ),
            current_round: registry.gauge(
                names::CURRENT_ROUND,
                "Most recently completed round number (1-based).",
                &[],
            ),
            round_duration: registry.histogram(
                names::ROUND_DURATION_SECONDS,
                "Whole-round wall time.",
                &[],
                &round_edges,
            ),
            fanin_duration: registry.histogram(
                names::FANIN_DURATION_SECONDS,
                "Fan-in post-to-install span (slowest shard per round).",
                &[],
                DEFAULT_LATENCY_EDGES,
            ),
            registry,
        };
        Arc::new(sm)
    }

    /// The registry behind this session (what `/metrics` renders).
    pub fn registry(&self) -> &Arc<MetricRegistry> {
        &self.registry
    }

    /// A request-latency recorder labeled with `shard`.
    pub fn recorder(&self, shard: &str) -> Arc<LatencyRecorder> {
        LatencyRecorder::new(self.registry.clone(), shard)
    }

    /// The monitor's action counters (reposts, aborts, merge signals),
    /// incremented live by the progress-monitor thread.
    pub fn monitor_counters(&self) -> (Arc<Counter>, Arc<Counter>, Arc<Counter>) {
        (
            self.registry.counter(
                names::MONITOR_REPOSTS_TOTAL,
                "Monitor-triggered reposts.",
                &[],
            ),
            self.registry.counter(
                names::MONITOR_ABORTS_TOTAL,
                "Monitor privacy-floor aborts.",
                &[],
            ),
            self.registry.counter(
                names::MONITOR_MERGE_SIGNALS_TOTAL,
                "Monitor merge signals.",
                &[],
            ),
        )
    }

    /// Push one completed round's metrics into the registry.
    pub fn record_round(&self, round: usize, m: &RoundMetrics) {
        self.rounds.inc();
        self.progress_failovers.add(m.progress_failovers);
        self.initiator_failovers.add(m.initiator_failovers);
        self.rekey_messages.add(m.rekey_messages);
        self.merged_groups.add(m.merged_groups);
        self.reassigned_nodes.add(m.reassigned_nodes);
        self.deadline_exceeded.add(m.deadline_exceeded);
        self.fanin_messages.add(m.fanin_messages);
        self.live_nodes.set(m.contributors as i64);
        self.current_round.set(round as i64);
        self.round_duration.observe_duration(m.wall_time);
        if m.fanin_latency > Duration::ZERO {
            self.fanin_duration.observe_duration(m.fanin_latency);
        }
    }
}

/// Result of one aggregation round as observed by the session driver.
#[derive(Debug, Clone)]
pub struct RoundMetrics {
    /// Wall time from round start to every node holding the average.
    pub wall_time: Duration,
    /// Logical protocol messages (one REST call = one message, as in §5.2).
    pub messages: u64,
    /// Request-body bytes sent by all learners.
    pub bytes_sent: u64,
    /// Response-body bytes received by all learners.
    pub bytes_received: u64,
    /// The final average every node received.
    pub average: Vec<f64>,
    /// Distinct nodes whose values are in the average.
    pub contributors: u64,
    /// Progress failovers that occurred (f in `4n + 2f`).
    pub progress_failovers: u64,
    /// Initiator failovers that occurred (i in `(i+1)(4n+2f+in)`).
    pub initiator_failovers: u64,
    /// Key (re-)exchange messages spent inside this round's window by the
    /// multi-round engine — nonzero only when a churned-out node rejoined
    /// this round or a privacy-floor merge reassigned nodes to a new
    /// group. Reported separately from `messages`, mirroring the paper's
    /// footnote 3 (key exchange is not per-aggregation traffic), but
    /// still visible in `per_path`.
    pub rekey_messages: u64,
    /// Groups dissolved by privacy-floor merge re-balancing this round
    /// (their survivors aggregated under a neighbouring group's chain).
    pub merged_groups: u64,
    /// Nodes that aggregated under a group other than their configured
    /// home group this round — the only nodes that re-key after a merge.
    pub reassigned_nodes: u64,
    /// Learners that hit the hard-deadline safety net (`aggregation
    /// timeout × (2 + 2·restarts) + 5s`) and gave up this round. A bound
    /// trip is an outcome, not a crash: the node counts as died for this
    /// round and the session continues.
    pub deadline_exceeded: u64,
    /// Attempts re-sent after a retryable transport failure (injected
    /// loss under a `NetProfile`, or real connection faults over HTTP).
    /// Bounded by the retry policy; each retried attempt is also counted
    /// in `messages`, so `messages - net_retries` is the logical count
    /// the `4n + 2f (+g)` formulas bound.
    pub net_retries: u64,
    /// Injected packet drops observed by the transport (request or
    /// response leg) under the active `NetProfile`.
    pub net_drops: u64,
    /// Duplicate posts the controller absorbed via the attempt-dedup
    /// token (a resend after response-leg loss). Every one of these is a
    /// double-count that did NOT happen.
    pub dedup_posts: u64,
    /// Messages by path (for the message-accounting tests).
    pub per_path: std::collections::BTreeMap<String, u64>,
    /// Fan-in tier messages this round (sharded plane): each live shard's
    /// worker posts its partial and fetches the combined global — exactly
    /// 2 per live shard on a healthy round, ≤ 2K + the degraded partial
    /// fetches otherwise. Counted separately from `messages` (same
    /// discipline as `rekey_messages`): the `4n + 2f (+g)` bound covers
    /// learner traffic, and the fan-in term rides next to it.
    pub fanin_messages: u64,
    /// Fan-in latency: the slowest shard worker's post→install span (the
    /// serial tail the fan-in tier adds to the round). Zero when K=1.
    pub fanin_latency: Duration,
    /// Per-shard learner message counts this round, indexed by shard.
    /// Empty on a single-shard plane (no per-shard split is recorded —
    /// the totals are the single shard).
    pub shard_messages: Vec<u64>,
}

impl RoundMetrics {
    pub fn secs(&self) -> f64 {
        self.wall_time.as_secs_f64()
    }
}

/// Aggregated statistics over repeated rounds (the paper plots mean with
/// 3σ/4σ bands over 30/5 repeats).
#[derive(Debug, Clone)]
pub struct RepeatStats {
    pub mean_secs: f64,
    pub stddev_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
    pub mean_messages: f64,
    pub repeats: usize,
}

impl RepeatStats {
    pub fn from_rounds(rounds: &[RoundMetrics]) -> RepeatStats {
        let secs: Vec<f64> = rounds.iter().map(|r| r.secs()).collect();
        let msgs: Vec<f64> = rounds.iter().map(|r| r.messages as f64).collect();
        RepeatStats {
            mean_secs: crate::util::mean(&secs),
            stddev_secs: crate::util::stddev(&secs),
            min_secs: secs.iter().copied().fold(f64::INFINITY, f64::min),
            max_secs: secs.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            mean_messages: crate::util::mean(&msgs),
            repeats: rounds.len(),
        }
    }

    /// `k`-sigma band half-width (the paper displays 3σ edge / 4σ deep).
    pub fn band(&self, k: f64) -> f64 {
        self.stddev_secs * k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rm(secs: f64, msgs: u64) -> RoundMetrics {
        RoundMetrics {
            wall_time: Duration::from_secs_f64(secs),
            messages: msgs,
            bytes_sent: 0,
            bytes_received: 0,
            average: vec![],
            contributors: 0,
            progress_failovers: 0,
            initiator_failovers: 0,
            rekey_messages: 0,
            merged_groups: 0,
            reassigned_nodes: 0,
            deadline_exceeded: 0,
            net_retries: 0,
            net_drops: 0,
            dedup_posts: 0,
            per_path: Default::default(),
            fanin_messages: 0,
            fanin_latency: Duration::ZERO,
            shard_messages: vec![],
        }
    }

    #[test]
    fn repeat_stats_basics() {
        let rounds = vec![rm(1.0, 12), rm(2.0, 12), rm(3.0, 12)];
        let s = RepeatStats::from_rounds(&rounds);
        assert_eq!(s.mean_secs, 2.0);
        assert_eq!(s.min_secs, 1.0);
        assert_eq!(s.max_secs, 3.0);
        assert_eq!(s.mean_messages, 12.0);
        assert_eq!(s.repeats, 3);
        assert!(s.band(3.0) > s.band(1.0));
    }
}
