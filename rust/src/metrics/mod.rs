//! Metrics: what every run reports — aggregation wall time, message
//! counts (to verify the paper's `4n`-family formulas), bytes moved, and
//! failure bookkeeping.

use std::time::Duration;

/// Result of one aggregation round as observed by the session driver.
#[derive(Debug, Clone)]
pub struct RoundMetrics {
    /// Wall time from round start to every node holding the average.
    pub wall_time: Duration,
    /// Logical protocol messages (one REST call = one message, as in §5.2).
    pub messages: u64,
    /// Request-body bytes sent by all learners.
    pub bytes_sent: u64,
    /// Response-body bytes received by all learners.
    pub bytes_received: u64,
    /// The final average every node received.
    pub average: Vec<f64>,
    /// Distinct nodes whose values are in the average.
    pub contributors: u64,
    /// Progress failovers that occurred (f in `4n + 2f`).
    pub progress_failovers: u64,
    /// Initiator failovers that occurred (i in `(i+1)(4n+2f+in)`).
    pub initiator_failovers: u64,
    /// Key (re-)exchange messages spent inside this round's window by the
    /// multi-round engine — nonzero only when a churned-out node rejoined
    /// this round or a privacy-floor merge reassigned nodes to a new
    /// group. Reported separately from `messages`, mirroring the paper's
    /// footnote 3 (key exchange is not per-aggregation traffic), but
    /// still visible in `per_path`.
    pub rekey_messages: u64,
    /// Groups dissolved by privacy-floor merge re-balancing this round
    /// (their survivors aggregated under a neighbouring group's chain).
    pub merged_groups: u64,
    /// Nodes that aggregated under a group other than their configured
    /// home group this round — the only nodes that re-key after a merge.
    pub reassigned_nodes: u64,
    /// Learners that hit the hard-deadline safety net (`aggregation
    /// timeout × (2 + 2·restarts) + 5s`) and gave up this round. A bound
    /// trip is an outcome, not a crash: the node counts as died for this
    /// round and the session continues.
    pub deadline_exceeded: u64,
    /// Attempts re-sent after a retryable transport failure (injected
    /// loss under a `NetProfile`, or real connection faults over HTTP).
    /// Bounded by the retry policy; each retried attempt is also counted
    /// in `messages`, so `messages - net_retries` is the logical count
    /// the `4n + 2f (+g)` formulas bound.
    pub net_retries: u64,
    /// Injected packet drops observed by the transport (request or
    /// response leg) under the active `NetProfile`.
    pub net_drops: u64,
    /// Duplicate posts the controller absorbed via the attempt-dedup
    /// token (a resend after response-leg loss). Every one of these is a
    /// double-count that did NOT happen.
    pub dedup_posts: u64,
    /// Messages by path (for the message-accounting tests).
    pub per_path: std::collections::BTreeMap<String, u64>,
    /// Fan-in tier messages this round (sharded plane): each live shard's
    /// worker posts its partial and fetches the combined global — exactly
    /// 2 per live shard on a healthy round, ≤ 2K + the degraded partial
    /// fetches otherwise. Counted separately from `messages` (same
    /// discipline as `rekey_messages`): the `4n + 2f (+g)` bound covers
    /// learner traffic, and the fan-in term rides next to it.
    pub fanin_messages: u64,
    /// Fan-in latency: the slowest shard worker's post→install span (the
    /// serial tail the fan-in tier adds to the round). Zero when K=1.
    pub fanin_latency: Duration,
    /// Per-shard learner message counts this round, indexed by shard.
    /// Empty on a single-shard plane (no per-shard split is recorded —
    /// the totals are the single shard).
    pub shard_messages: Vec<u64>,
}

impl RoundMetrics {
    pub fn secs(&self) -> f64 {
        self.wall_time.as_secs_f64()
    }
}

/// Aggregated statistics over repeated rounds (the paper plots mean with
/// 3σ/4σ bands over 30/5 repeats).
#[derive(Debug, Clone)]
pub struct RepeatStats {
    pub mean_secs: f64,
    pub stddev_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
    pub mean_messages: f64,
    pub repeats: usize,
}

impl RepeatStats {
    pub fn from_rounds(rounds: &[RoundMetrics]) -> RepeatStats {
        let secs: Vec<f64> = rounds.iter().map(|r| r.secs()).collect();
        let msgs: Vec<f64> = rounds.iter().map(|r| r.messages as f64).collect();
        RepeatStats {
            mean_secs: crate::util::mean(&secs),
            stddev_secs: crate::util::stddev(&secs),
            min_secs: secs.iter().copied().fold(f64::INFINITY, f64::min),
            max_secs: secs.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            mean_messages: crate::util::mean(&msgs),
            repeats: rounds.len(),
        }
    }

    /// `k`-sigma band half-width (the paper displays 3σ edge / 4σ deep).
    pub fn band(&self, k: f64) -> f64 {
        self.stddev_secs * k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rm(secs: f64, msgs: u64) -> RoundMetrics {
        RoundMetrics {
            wall_time: Duration::from_secs_f64(secs),
            messages: msgs,
            bytes_sent: 0,
            bytes_received: 0,
            average: vec![],
            contributors: 0,
            progress_failovers: 0,
            initiator_failovers: 0,
            rekey_messages: 0,
            merged_groups: 0,
            reassigned_nodes: 0,
            deadline_exceeded: 0,
            net_retries: 0,
            net_drops: 0,
            dedup_posts: 0,
            per_path: Default::default(),
            fanin_messages: 0,
            fanin_latency: Duration::ZERO,
            shard_messages: vec![],
        }
    }

    #[test]
    fn repeat_stats_basics() {
        let rounds = vec![rm(1.0, 12), rm(2.0, 12), rm(3.0, 12)];
        let s = RepeatStats::from_rounds(&rounds);
        assert_eq!(s.mean_secs, 2.0);
        assert_eq!(s.min_secs, 1.0);
        assert_eq!(s.max_secs, 3.0);
        assert_eq!(s.mean_messages, 12.0);
        assert_eq!(s.repeats, 3);
        assert!(s.band(3.0) > s.band(1.0));
    }
}
