//! Typed metric registry: counters, gauges and fixed-bucket histograms
//! behind one process-local registry that renders the Prometheus text
//! exposition format (the `libs/metrics` registry idiom: typed handles
//! are registered once, cheap to update from hot paths, and collected
//! into one scrape).
//!
//! Design constraints, in order:
//!
//! 1. **Hot-path cheap.** A [`Counter`]/[`Gauge`] update is one relaxed
//!    atomic op; a [`Histogram`] observation is two atomic adds plus one
//!    CAS loop for the f64 sum. Handles are `Arc`s resolved once and
//!    cached by the recording site — the registry's maps are only locked
//!    at registration and scrape time.
//! 2. **Single source of truth.** Counters that mirror an existing
//!    accounting structure (e.g. the transport's `MessageStats`) are
//!    synced from it by a registered collector at scrape time via
//!    [`Counter::store`], so the registry can never drift from the
//!    numbers the formula tests pin.
//! 3. **Mergeable distributions.** Histograms use fixed bucket edges so
//!    two histograms of the same layout [`Histogram::merge`] exactly
//!    (bucket-count conservation is a tested invariant).
//!
//! Naming and label conventions are documented in `docs/OBSERVABILITY.md`
//! and enforced by `tests/metrics_conformance.rs`.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A monotonically increasing counter (Prometheus type `counter`).
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Increment by `n`.
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Overwrite with `v` — for collectors mirroring an external counter
    /// that is itself monotone (e.g. `MessageStats` totals). Callers own
    /// the monotonicity argument; mixing `store` and `add` on one counter
    /// forfeits it.
    pub fn store(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// A gauge: a value that can go up and down (Prometheus type `gauge`).
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicI64,
}

impl Gauge {
    /// Set the gauge to `v`.
    pub fn set(&self, v: i64) {
        self.value.store(v, Ordering::Relaxed);
    }

    /// Add `d` (may be negative).
    pub fn add(&self, d: i64) {
        self.value.fetch_add(d, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> i64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Lock-free f64 accumulator (f64 bits in an `AtomicU64`, CAS add).
#[derive(Debug, Default)]
struct AtomicF64 {
    bits: AtomicU64,
}

impl AtomicF64 {
    fn add(&self, v: f64) {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }

    fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Default latency bucket edges (seconds): roughly exponential from
/// 100 µs to 30 s, sized for the in-proc REST-hop model at the low end
/// and WAN/straggler rounds at the high end. The `+Inf` bucket is
/// implicit.
pub const DEFAULT_LATENCY_EDGES: &[f64] = &[
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0,
    2.5, 5.0, 10.0, 30.0,
];

/// A fixed-bucket histogram (Prometheus type `histogram`): cumulative
/// `le`-labeled buckets, an observation count and an observation sum.
///
/// Buckets are **upper-edge inclusive** (`v <= edge`), matching the
/// Prometheus `le` convention; everything above the last finite edge
/// lands in the implicit `+Inf` bucket. `observe(0.0)` therefore falls
/// in the first bucket (every default edge is positive) and
/// `observe(f64::INFINITY)` in the `+Inf` bucket — both are tested edge
/// cases, not errors.
#[derive(Debug)]
pub struct Histogram {
    /// Finite upper bucket edges, strictly increasing.
    edges: Vec<f64>,
    /// Per-bucket (non-cumulative) counts; `buckets[edges.len()]` is the
    /// `+Inf` overflow bucket.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicF64,
}

impl Histogram {
    /// Build a histogram over `edges` (finite, strictly increasing).
    pub fn new(edges: &[f64]) -> Histogram {
        assert!(
            edges.windows(2).all(|w| w[0] < w[1]),
            "histogram edges must be strictly increasing"
        );
        assert!(
            edges.iter().all(|e| e.is_finite()),
            "histogram edges must be finite (+Inf is implicit)"
        );
        Histogram {
            edges: edges.to_vec(),
            buckets: (0..=edges.len()).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicF64::default(),
        }
    }

    /// The finite bucket edges this histogram was built with.
    pub fn edges(&self) -> &[f64] {
        &self.edges
    }

    /// Record one observation.
    pub fn observe(&self, v: f64) {
        let idx = self
            .edges
            .iter()
            .position(|&e| v <= e)
            .unwrap_or(self.edges.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.add(v);
    }

    /// Record one duration observation, in seconds.
    pub fn observe_duration(&self, d: Duration) {
        self.observe(d.as_secs_f64());
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all observed values.
    pub fn sum(&self) -> f64 {
        self.sum.get()
    }

    /// Per-bucket (non-cumulative) counts; the last entry is the `+Inf`
    /// overflow bucket.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Fold `other`'s observations into `self`. Both histograms must
    /// share the same edge layout; `merge(a, b)` is then exactly
    /// equivalent (for counts and buckets) to having recorded the union
    /// of observations into one histogram.
    pub fn merge(&self, other: &Histogram) {
        assert_eq!(self.edges, other.edges, "cannot merge histograms with different edges");
        for (mine, theirs) in self.buckets.iter().zip(&other.buckets) {
            mine.fetch_add(theirs.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.count.fetch_add(other.count(), Ordering::Relaxed);
        self.sum.add(other.sum());
    }

    /// Estimate the `q`-quantile (`0.0..=1.0`) by linear interpolation
    /// inside the bucket containing the target rank — the estimate is
    /// always bounded by that bucket's edges. Observations in the `+Inf`
    /// bucket are reported as the largest finite edge (the histogram
    /// cannot resolve beyond it); an empty histogram reports `0.0`.
    pub fn quantile(&self, q: f64) -> f64 {
        let count = self.count();
        if count == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * count as f64).ceil() as u64).max(1);
        let counts = self.bucket_counts();
        let mut before = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            if c > 0 && before + c >= target {
                if i == self.edges.len() {
                    // Overflow bucket: clamp to the largest finite edge.
                    return self.edges.last().copied().unwrap_or(f64::INFINITY);
                }
                let upper = self.edges[i];
                // The first bucket spans (-Inf, edge0]; interpolate from 0
                // for the (typical) non-negative-domain histogram, from
                // the edge itself when even that is negative.
                let lower = if i == 0 { upper.min(0.0) } else { self.edges[i - 1] };
                let frac = (target - before) as f64 / c as f64;
                return lower + (upper - lower) * frac;
            }
            before += c;
        }
        self.edges.last().copied().unwrap_or(f64::INFINITY)
    }
}

/// What kind of metric a family is (drives the `# TYPE` line).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    /// Monotone counter.
    Counter,
    /// Up/down gauge.
    Gauge,
    /// Fixed-bucket histogram.
    Histogram,
}

impl MetricKind {
    fn as_str(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        }
    }
}

/// One metric family's metadata.
#[derive(Debug, Clone)]
struct Family {
    help: &'static str,
    kind: MetricKind,
}

/// Sorted label pairs — the identity of one series within a family.
type LabelSet = Vec<(String, String)>;

fn label_set(labels: &[(&str, &str)]) -> LabelSet {
    let mut v: LabelSet =
        labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
    v.sort();
    v
}

fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && name
            .bytes()
            .enumerate()
            .all(|(i, b)| b.is_ascii_alphabetic() || b == b'_' || b == b':' || (i > 0 && b.is_ascii_digit()))
}

/// The process-local metric registry: typed get-or-create registration,
/// scrape-time collectors, and Prometheus text rendering.
///
/// Families (name + help + kind) are registered implicitly by the first
/// [`MetricRegistry::counter`]/[`MetricRegistry::gauge`]/
/// [`MetricRegistry::histogram`] call; re-registering with the same name
/// returns the existing handle (and panics on a kind conflict — that is
/// always a programming error, never data-dependent).
#[derive(Default)]
pub struct MetricRegistry {
    families: Mutex<BTreeMap<String, Family>>,
    counters: Mutex<BTreeMap<(String, LabelSet), Arc<Counter>>>,
    gauges: Mutex<BTreeMap<(String, LabelSet), Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<(String, LabelSet), Arc<Histogram>>>,
    /// Scrape-time sync hooks: each collector refreshes the registry
    /// series it owns from its external source (see [`Counter::store`]).
    /// Collectors must not call [`MetricRegistry::render`]/
    /// [`MetricRegistry::collect`] (the collector lock is held) and must
    /// not block on protocol state.
    #[allow(clippy::type_complexity)]
    collectors: Mutex<Vec<Box<dyn Fn() + Send + Sync>>>,
}

impl std::fmt::Debug for MetricRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MetricRegistry")
            .field("families", &self.families.lock().unwrap().len())
            .field("collectors", &self.collectors.lock().unwrap().len())
            .finish()
    }
}

impl MetricRegistry {
    /// Fresh, empty registry.
    pub fn new() -> Arc<MetricRegistry> {
        Arc::new(MetricRegistry::default())
    }

    fn register_family(&self, name: &str, help: &'static str, kind: MetricKind) {
        assert!(valid_name(name), "invalid metric name: {name}");
        let mut fams = self.families.lock().unwrap();
        match fams.get(name) {
            Some(f) => assert_eq!(
                f.kind, kind,
                "metric {name} re-registered with a different kind"
            ),
            None => {
                fams.insert(name.to_string(), Family { help, kind });
            }
        }
    }

    /// Get-or-create the counter `name{labels}`.
    pub fn counter(
        &self,
        name: &str,
        help: &'static str,
        labels: &[(&str, &str)],
    ) -> Arc<Counter> {
        self.register_family(name, help, MetricKind::Counter);
        let key = (name.to_string(), label_set(labels));
        self.counters.lock().unwrap().entry(key).or_default().clone()
    }

    /// Get-or-create the gauge `name{labels}`.
    pub fn gauge(&self, name: &str, help: &'static str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        self.register_family(name, help, MetricKind::Gauge);
        let key = (name.to_string(), label_set(labels));
        self.gauges.lock().unwrap().entry(key).or_default().clone()
    }

    /// Get-or-create the histogram `name{labels}` over `edges`. The `le`
    /// label is reserved (rendered per bucket).
    pub fn histogram(
        &self,
        name: &str,
        help: &'static str,
        labels: &[(&str, &str)],
        edges: &[f64],
    ) -> Arc<Histogram> {
        assert!(
            labels.iter().all(|(k, _)| *k != "le"),
            "histogram label 'le' is reserved"
        );
        self.register_family(name, help, MetricKind::Histogram);
        let key = (name.to_string(), label_set(labels));
        self.histograms
            .lock()
            .unwrap()
            .entry(key)
            .or_insert_with(|| Arc::new(Histogram::new(edges)))
            .clone()
    }

    /// Register a scrape-time sync hook (runs before every render).
    pub fn register_collector(&self, f: impl Fn() + Send + Sync + 'static) {
        self.collectors.lock().unwrap().push(Box::new(f));
    }

    /// Run every registered collector, refreshing mirrored series.
    pub fn collect(&self) {
        for c in self.collectors.lock().unwrap().iter() {
            c();
        }
    }

    /// Value of the counter `name{labels}`, if it exists (does not run
    /// collectors — call [`MetricRegistry::collect`] first for mirrored
    /// counters).
    pub fn counter_value(&self, name: &str, labels: &[(&str, &str)]) -> Option<u64> {
        let key = (name.to_string(), label_set(labels));
        self.counters.lock().unwrap().get(&key).map(|c| c.get())
    }

    /// Value of the gauge `name{labels}`, if it exists.
    pub fn gauge_value(&self, name: &str, labels: &[(&str, &str)]) -> Option<i64> {
        let key = (name.to_string(), label_set(labels));
        self.gauges.lock().unwrap().get(&key).map(|g| g.get())
    }

    /// The histogram registered as `name{labels}`, if any.
    pub fn histogram_handle(&self, name: &str, labels: &[(&str, &str)]) -> Option<Arc<Histogram>> {
        let key = (name.to_string(), label_set(labels));
        self.histograms.lock().unwrap().get(&key).cloned()
    }

    /// Every series of the counter family `name`, as (sorted label set,
    /// value) pairs — the reconciliation tests' bulk view.
    pub fn counter_series(&self, name: &str) -> Vec<(LabelSet, u64)> {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .filter(|((n, _), _)| n == name)
            .map(|((_, ls), c)| (ls.clone(), c.get()))
            .collect()
    }

    /// Sum the counter family `name` grouped by one label's value —
    /// e.g. `sum_counter_by("safe_requests_total", "path")` gives the
    /// per-path request totals across shards.
    pub fn sum_counter_by(&self, name: &str, label: &str) -> BTreeMap<String, u64> {
        let mut out: BTreeMap<String, u64> = BTreeMap::new();
        for (ls, v) in self.counter_series(name) {
            if let Some((_, lv)) = ls.iter().find(|(k, _)| k == label) {
                *out.entry(lv.clone()).or_insert(0) += v;
            }
        }
        out
    }

    /// Every histogram series of family `name`, as (sorted label set,
    /// handle) pairs.
    pub fn histogram_series(&self, name: &str) -> Vec<(LabelSet, Arc<Histogram>)> {
        self.histograms
            .lock()
            .unwrap()
            .iter()
            .filter(|((n, _), _)| n == name)
            .map(|((_, ls), h)| (ls.clone(), h.clone()))
            .collect()
    }

    /// Run collectors, then render every family in the Prometheus text
    /// exposition format (version 0.0.4): `# HELP` / `# TYPE` headers,
    /// series sorted by label set, histograms as cumulative `le` buckets
    /// plus `_sum` and `_count`.
    pub fn render(&self) -> String {
        self.collect();
        let families = self.families.lock().unwrap().clone();
        let mut out = String::new();
        for (name, fam) in &families {
            let _ = writeln!(out, "# HELP {name} {}", fam.help);
            let _ = writeln!(out, "# TYPE {name} {}", fam.kind.as_str());
            match fam.kind {
                MetricKind::Counter => {
                    for ((n, ls), c) in self.counters.lock().unwrap().iter() {
                        if n == name {
                            let _ = writeln!(out, "{name}{} {}", fmt_labels(ls), c.get());
                        }
                    }
                }
                MetricKind::Gauge => {
                    for ((n, ls), g) in self.gauges.lock().unwrap().iter() {
                        if n == name {
                            let _ = writeln!(out, "{name}{} {}", fmt_labels(ls), g.get());
                        }
                    }
                }
                MetricKind::Histogram => {
                    for ((n, ls), h) in self.histograms.lock().unwrap().iter() {
                        if n == name {
                            render_histogram(&mut out, name, ls, h);
                        }
                    }
                }
            }
        }
        out
    }
}

fn render_histogram(out: &mut String, name: &str, ls: &LabelSet, h: &Histogram) {
    let counts = h.bucket_counts();
    let mut cum = 0u64;
    for (i, c) in counts.iter().enumerate() {
        cum += c;
        let le = if i == h.edges().len() {
            "+Inf".to_string()
        } else {
            fmt_f64(h.edges()[i])
        };
        let mut with_le = ls.clone();
        with_le.push(("le".to_string(), le));
        with_le.sort();
        let _ = writeln!(out, "{name}_bucket{} {cum}", fmt_labels(&with_le));
    }
    let _ = writeln!(out, "{name}_sum{} {}", fmt_labels(ls), fmt_f64(h.sum()));
    let _ = writeln!(out, "{name}_count{} {}", fmt_labels(ls), h.count());
}

fn fmt_f64(v: f64) -> String {
    if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

fn fmt_labels(ls: &LabelSet) -> String {
    if ls.is_empty() {
        return String::new();
    }
    let inner: Vec<String> = ls
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    format!("{{{}}}", inner.join(","))
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Small deterministic xorshift for the seeded property tests.
    struct Rng(u64);
    impl Rng {
        fn next(&mut self) -> u64 {
            let mut x = self.0;
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            self.0 = x;
            x
        }
        fn f64(&mut self) -> f64 {
            // ~[0, 64): spans several default buckets plus the overflow.
            (self.next() % 64_000) as f64 / 1000.0
        }
    }

    #[test]
    fn counter_and_gauge_basics() {
        let reg = MetricRegistry::new();
        let c = reg.counter("safe_test_total", "test counter", &[("path", "/a")]);
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // Same (name, labels) → same handle.
        let c2 = reg.counter("safe_test_total", "test counter", &[("path", "/a")]);
        assert_eq!(c2.get(), 5);
        let g = reg.gauge("safe_test_gauge", "test gauge", &[]);
        g.set(7);
        g.add(-3);
        assert_eq!(g.get(), 4);
        assert_eq!(reg.counter_value("safe_test_total", &[("path", "/a")]), Some(5));
        assert_eq!(reg.counter_value("safe_test_total", &[("path", "/b")]), None);
        assert_eq!(reg.gauge_value("safe_test_gauge", &[]), Some(4));
    }

    #[test]
    #[should_panic(expected = "different kind")]
    fn kind_conflict_panics() {
        let reg = MetricRegistry::new();
        let _ = reg.counter("safe_conflict", "as counter", &[]);
        let _ = reg.gauge("safe_conflict", "as gauge", &[]);
    }

    #[test]
    fn histogram_buckets_are_upper_edge_inclusive() {
        let h = Histogram::new(&[1.0, 2.0, 4.0]);
        h.observe(1.0); // exactly on an edge → that bucket (le semantics)
        h.observe(1.5);
        h.observe(4.0);
        h.observe(9.0); // overflow
        assert_eq!(h.bucket_counts(), vec![1, 1, 1, 1]);
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 15.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_zero_and_infinite_observations() {
        let h = Histogram::new(&[0.001, 1.0]);
        h.observe(0.0); // 0-duration: first bucket, not an error
        h.observe(f64::INFINITY); // +Inf: overflow bucket
        h.observe(f64::NEG_INFINITY); // -Inf: first bucket
        assert_eq!(h.bucket_counts(), vec![2, 0, 1]);
        assert_eq!(h.count(), 3);
        // Sum is +Inf + -Inf = NaN; count/bucket invariants are the ones
        // that must survive infinite observations.
        assert!(h.sum().is_nan());
        // Quantiles stay bounded: the overflow estimate clamps to the
        // largest finite edge.
        assert!(h.quantile(1.0) <= 1.0);
    }

    #[test]
    fn seeded_bucket_count_conservation() {
        let mut rng = Rng(0x5eed_0001);
        let h = Histogram::new(DEFAULT_LATENCY_EDGES);
        let n = 5_000;
        for _ in 0..n {
            h.observe(rng.f64());
        }
        // Conservation: every observation is in exactly one bucket.
        assert_eq!(h.bucket_counts().iter().sum::<u64>(), n);
        assert_eq!(h.count(), n);
        // Cumulativity: prefix sums are monotone and end at count.
        let mut cum = 0u64;
        for c in h.bucket_counts() {
            let next = cum + c;
            assert!(next >= cum);
            cum = next;
        }
        assert_eq!(cum, h.count());
    }

    #[test]
    fn seeded_merge_equals_union_recording() {
        let mut rng = Rng(0xfeed_beef);
        let a = Histogram::new(DEFAULT_LATENCY_EDGES);
        let b = Histogram::new(DEFAULT_LATENCY_EDGES);
        let union = Histogram::new(DEFAULT_LATENCY_EDGES);
        for i in 0..4_000 {
            let v = rng.f64();
            if i % 2 == 0 { a.observe(v) } else { b.observe(v) }
            union.observe(v);
        }
        a.merge(&b);
        assert_eq!(a.bucket_counts(), union.bucket_counts());
        assert_eq!(a.count(), union.count());
        // Sums differ only by f64 association order.
        assert!((a.sum() - union.sum()).abs() < 1e-6 * union.sum().abs().max(1.0));
    }

    #[test]
    fn seeded_quantiles_bounded_by_enclosing_bucket() {
        let mut rng = Rng(0xabcd_1234_5678_9abc);
        let h = Histogram::new(DEFAULT_LATENCY_EDGES);
        let mut values = Vec::new();
        for _ in 0..2_000 {
            let v = rng.f64();
            values.push(v);
            h.observe(v);
        }
        values.sort_by(f64::total_cmp);
        for q in [0.0, 0.1, 0.5, 0.9, 0.95, 0.99, 1.0] {
            let est = h.quantile(q);
            // Find the true rank-order statistic and its enclosing bucket.
            let rank = ((q * values.len() as f64).ceil() as usize).clamp(1, values.len());
            let truth = values[rank - 1];
            let bucket = DEFAULT_LATENCY_EDGES
                .iter()
                .position(|&e| truth <= e)
                .unwrap_or(DEFAULT_LATENCY_EDGES.len());
            let upper = DEFAULT_LATENCY_EDGES
                .get(bucket)
                .copied()
                .unwrap_or(*DEFAULT_LATENCY_EDGES.last().unwrap());
            let lower = if bucket == 0 { 0.0 } else { DEFAULT_LATENCY_EDGES[bucket - 1] };
            assert!(
                est >= lower && est <= upper,
                "q={q}: estimate {est} outside enclosing bucket [{lower}, {upper}] (truth {truth})"
            );
        }
    }

    #[test]
    fn empty_histogram_quantile_is_zero() {
        let h = Histogram::new(&[1.0]);
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    #[should_panic(expected = "different edges")]
    fn merging_different_layouts_panics() {
        let a = Histogram::new(&[1.0]);
        let b = Histogram::new(&[2.0]);
        a.merge(&b);
    }

    #[test]
    fn render_emits_prometheus_text() {
        let reg = MetricRegistry::new();
        reg.counter("safe_reqs_total", "requests", &[("path", "/x"), ("shard", "0")]).add(3);
        reg.gauge("safe_live", "live nodes", &[]).set(12);
        let h = reg.histogram("safe_lat_seconds", "latency", &[("path", "/x")], &[0.5, 1.0]);
        h.observe(0.2);
        h.observe(0.7);
        h.observe(3.0);
        let text = reg.render();
        assert!(text.contains("# TYPE safe_reqs_total counter"));
        assert!(text.contains("safe_reqs_total{path=\"/x\",shard=\"0\"} 3"));
        assert!(text.contains("# TYPE safe_live gauge"));
        assert!(text.contains("safe_live 12"));
        assert!(text.contains("# TYPE safe_lat_seconds histogram"));
        assert!(text.contains("safe_lat_seconds_bucket{le=\"0.5\",path=\"/x\"} 1"));
        assert!(text.contains("safe_lat_seconds_bucket{le=\"1\",path=\"/x\"} 2"));
        assert!(text.contains("safe_lat_seconds_bucket{le=\"+Inf\",path=\"/x\"} 3"));
        assert!(text.contains("safe_lat_seconds_count{path=\"/x\"} 3"));
    }

    #[test]
    fn collectors_run_before_render() {
        let reg = MetricRegistry::new();
        let external = Arc::new(AtomicU64::new(41));
        let mirrored = reg.counter("safe_mirrored_total", "mirrored", &[]);
        {
            let external = external.clone();
            let mirrored = mirrored.clone();
            reg.register_collector(move || {
                mirrored.store(external.load(Ordering::Relaxed));
            });
        }
        external.store(42, Ordering::Relaxed);
        let text = reg.render();
        assert!(text.contains("safe_mirrored_total 42"), "{text}");
    }

    #[test]
    fn label_values_are_escaped() {
        let reg = MetricRegistry::new();
        reg.counter("safe_esc_total", "escapes", &[("v", "a\"b\\c")]).inc();
        let text = reg.render();
        assert!(text.contains("safe_esc_total{v=\"a\\\"b\\\\c\"} 1"), "{text}");
    }
}
