//! The external progress monitor (paper §5.3).
//!
//! "For maximum flexibility we provide an external progress monitor that
//! periodically pings the controller to see if the aggregation got stuck.
//! If that is the case the progress monitor will ask the controller to
//! notify the last node to post an aggregate to repost its aggregate and
//! encrypt it for the node that is next in the chain after the failing
//! node." The detection logic itself lives in the controller
//! (`progress_check`); this module is the external pinger process.
//!
//! Under the multi-round engine one monitor spans all R rounds of a
//! `run_rounds` call: [`ProgressMonitor::reposts`] is cumulative, and the
//! engine takes per-round deltas for `RoundMetrics::progress_failovers`.
//! Between rounds the monitor's pings are harmless — a freshly
//! `begin_round`-reset group has no posters, so `progress_check` never
//! declares a stuck link before the round's first post.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::json::Value;
use crate::proto;
use crate::transport::ClientTransport;

/// Handle to a running monitor thread.
pub struct ProgressMonitor {
    stop: Arc<AtomicBool>,
    /// Interruptible sleep: `stop()` signals this instead of waiting out
    /// the ping interval (keeps round teardown off the latency path).
    wakeup: Arc<(Mutex<bool>, Condvar)>,
    reposts: Arc<AtomicU64>,
    aborts: Arc<AtomicU64>,
    merge_signals: Arc<AtomicU64>,
    thread: Option<JoinHandle<()>>,
}

impl ProgressMonitor {
    /// Start pinging `progress_check` every `interval` over `transport`.
    pub fn start(transport: Arc<dyn ClientTransport>, interval: Duration) -> ProgressMonitor {
        ProgressMonitor::start_with_metrics(transport, interval, None)
    }

    /// Like [`ProgressMonitor::start`], with the session's monitor
    /// counters (`reposts`, `aborts`, `merge_signals` — in that order)
    /// incremented live at the same sites as the local atomics.
    pub fn start_with_metrics(
        transport: Arc<dyn ClientTransport>,
        interval: Duration,
        counters: Option<(
            Arc<crate::metrics::Counter>,
            Arc<crate::metrics::Counter>,
            Arc<crate::metrics::Counter>,
        )>,
    ) -> ProgressMonitor {
        let stop = Arc::new(AtomicBool::new(false));
        let wakeup = Arc::new((Mutex::new(false), Condvar::new()));
        let reposts = Arc::new(AtomicU64::new(0));
        let aborts = Arc::new(AtomicU64::new(0));
        let merge_signals = Arc::new(AtomicU64::new(0));
        let (s, w, r, a) = (stop.clone(), wakeup.clone(), reposts.clone(), aborts.clone());
        let m = merge_signals.clone();
        let thread = std::thread::Builder::new()
            .name("progress-monitor".into())
            .spawn(move || {
                while !s.load(Ordering::SeqCst) {
                    if let Ok(resp) = transport.call(proto::PROGRESS_CHECK, &Value::obj()) {
                        if let Some(actions) = resp.get("actions").and_then(|v| v.as_arr()) {
                            for act in actions {
                                match act.str_of("action") {
                                    Some("repost") => {
                                        r.fetch_add(1, Ordering::SeqCst);
                                        if let Some((c, _, _)) = &counters {
                                            c.inc();
                                        }
                                    }
                                    Some("abort_privacy_floor") => {
                                        a.fetch_add(1, Ordering::SeqCst);
                                        if let Some((_, c, _)) = &counters {
                                            c.inc();
                                        }
                                    }
                                    Some("merge_groups") => {
                                        m.fetch_add(1, Ordering::SeqCst);
                                        if let Some((_, _, c)) = &counters {
                                            c.inc();
                                        }
                                    }
                                    _ => {}
                                }
                            }
                        }
                    }
                    // Interruptible sleep: wake immediately on stop().
                    let (lock, cv) = &*w;
                    let guard = lock.lock().unwrap();
                    let _ = cv
                        .wait_timeout_while(guard, interval, |stopped| !*stopped)
                        .unwrap();
                }
            })
            .expect("spawn monitor thread");
        ProgressMonitor { stop, wakeup, reposts, aborts, merge_signals, thread: Some(thread) }
    }

    /// Number of repost commands issued so far (= progress failovers f).
    pub fn reposts(&self) -> u64 {
        self.reposts.load(Ordering::SeqCst)
    }

    /// Number of privacy-floor aborts observed.
    pub fn aborts(&self) -> u64 {
        self.aborts.load(Ordering::SeqCst)
    }

    /// Number of `merge_groups` signals observed — mid-round privacy-floor
    /// trips the controller asked the topology planner to resolve by
    /// merging at the next re-plan (emitted instead of an abort when
    /// merging is possible).
    pub fn merge_signals(&self) -> u64 {
        self.merge_signals.load(Ordering::SeqCst)
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        {
            let (lock, cv) = &*self.wakeup;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for ProgressMonitor {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::{Controller, ControllerConfig};
    use crate::transport::InProcTransport;

    #[test]
    fn monitor_detects_stuck_chain_and_counts_repost() {
        let cfg = ControllerConfig {
            poll_time: Duration::from_millis(50),
            progress_timeout: Duration::from_millis(60),
            ..Default::default()
        };
        let ctrl = Arc::new(Controller::new(cfg));
        use crate::transport::Handler;
        ctrl.handle(
            proto::CONFIGURE,
            &Value::object(vec![(
                "groups",
                Value::object(vec![(
                    "1",
                    Value::Arr((1u64..=5).map(Value::from).collect()),
                )]),
            )]),
        );
        // Initiator posts; node 2 goes silent.
        ctrl.handle(proto::POST_AGGREGATE, &proto::post_aggregate(1, 2, b"x", 1));
        let transport: Arc<dyn ClientTransport> =
            Arc::new(InProcTransport::new(ctrl.clone()));
        let mut mon = ProgressMonitor::start(transport, Duration::from_millis(20));
        // Give the monitor time to notice the stall. Nobody acts on the
        // repost commands in this test, so the monitor may escalate past
        // the first failed node — assert on the first detection only.
        std::thread::sleep(Duration::from_millis(250));
        mon.stop();
        assert!(mon.reposts() >= 1, "monitor should detect the stall");
        // And the controller queued the repost command for the checker.
        let r = ctrl.handle(proto::CHECK_AGGREGATE, &proto::node_op(2, 1));
        assert_eq!(r.str_of("status"), Some("repost"));
        assert_eq!(r.u64_of("to_node"), Some(3));
    }
}
