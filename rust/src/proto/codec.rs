//! Pluggable wire codecs: how a message body becomes bytes on the wire.
//!
//! The paper's deployment speaks JSON (a Flask REST server), and JSON
//! stays the default wire format. Note one deliberate departure from
//! byte-level seed parity: aggregates now cross every wire in the compact
//! binary envelope framing (base64-wrapped on JSON), not the paper's
//! `mode:keyB64:bodyB64` text — the JSON *convention* (text bodies,
//! base64 for ciphertext) is preserved, the payload bytes are not, and
//! legacy text envelopes are still accepted and re-delivered verbatim
//! (see `proto::aggregate_blob`). The controller is "a mere message
//! broker", so the serialization tax *is* the system's hot path — and the
//! codec is a policy, not an assumption. The codec stack:
//!
//! * [`JsonCodec`] — the paper's format: UTF-8 JSON text, float vectors as
//!   decimal text, opaque payloads ([`Value::Bytes`]) as base64 strings.
//!   Base64 lives **only** at this boundary; nothing above the codec ever
//!   base64-encodes.
//! * [`BinaryCodec`] — a compact tagged binary encoding of the same
//!   message model: LEB128 varints for lengths and integral numbers,
//!   length-prefixed (unescaped) strings, two packed array forms — raw
//!   little-endian `f64` for real-valued vectors and varint packing for
//!   id lists — and **raw ciphertext framing**: a [`Value::Bytes`] blob is
//!   shipped as `TAG_BYTES + varint length + the bytes`, with zero base64
//!   anywhere. A sealed aggregate that PR 1 carried as a
//!   `mode:keyB64:bodyB64` string (4/3 inflation) is now a compact binary
//!   envelope header + the ciphertext itself (see
//!   `crypto::envelope::Envelope::to_blob`), ~25% fewer bytes on the
//!   hottest path of every round.
//! * [`CompressedCodec`] — a transparent DEFLATE wrapper around either
//!   inner codec: `encode = deflate ∘ inner`, `decode = inner ∘ inflate`.
//!   JSON bodies (decimal floats, base64 text) compress well; binary
//!   bodies still shed redundancy in large `f64` vectors. Selected as
//!   [`WireFormat::JsonDeflate`] / [`WireFormat::BinaryDeflate`]
//!   (`--wire json+deflate|binary+deflate`).
//!
//! All four stacks encode the *same* [`Value`] message model, so every
//! layer above the transport (typed messages, controller dispatch, learner
//! state machines) is codec-agnostic — and the controller stores and
//! forwards a decoded [`Value::Bytes`] blob as a shared allocation, never
//! re-materializing or re-encoding it (zero-copy pass-through). Transports
//! pick a codec from [`WireFormat`]; the HTTP layer negotiates it
//! per-request via `Content-Type` (see `transport::http`).

use anyhow::{bail, Context, Result};

use crate::blob::Blob;
use crate::json::Value;

/// Content type identifying JSON bodies on the HTTP transport.
pub const CONTENT_TYPE_JSON: &str = "application/json";
/// Content type identifying binary-codec bodies on the HTTP transport.
pub const CONTENT_TYPE_BINARY: &str = "application/x-safe-binary";
/// Content type for DEFLATE-compressed JSON bodies.
pub const CONTENT_TYPE_JSON_DEFLATE: &str = "application/x-safe-json-deflate";
/// Content type for DEFLATE-compressed binary-codec bodies.
pub const CONTENT_TYPE_BINARY_DEFLATE: &str = "application/x-safe-binary-deflate";

/// Which wire codec a session/transport uses. JSON is the default (the
/// paper's REST convention; see the module docs for the one departure on
/// aggregate framing); the `*Deflate` variants wrap the inner codec in
/// transparent DEFLATE compression ([`CompressedCodec`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WireFormat {
    #[default]
    Json,
    Binary,
    JsonDeflate,
    BinaryDeflate,
}

impl WireFormat {
    pub fn codec(self) -> &'static dyn WireCodec {
        match self {
            WireFormat::Json => &JsonCodec,
            WireFormat::Binary => &BinaryCodec,
            WireFormat::JsonDeflate => &JSON_DEFLATE,
            WireFormat::BinaryDeflate => &BINARY_DEFLATE,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            WireFormat::Json => "json",
            WireFormat::Binary => "binary",
            WireFormat::JsonDeflate => "json+deflate",
            WireFormat::BinaryDeflate => "binary+deflate",
        }
    }

    /// Every selectable format, in reporting order.
    pub const ALL: [WireFormat; 4] = [
        WireFormat::Json,
        WireFormat::Binary,
        WireFormat::JsonDeflate,
        WireFormat::BinaryDeflate,
    ];

    pub fn from_name(s: &str) -> Option<WireFormat> {
        match s {
            "json" => Some(WireFormat::Json),
            "binary" | "bin" => Some(WireFormat::Binary),
            "json+deflate" | "json-deflate" => Some(WireFormat::JsonDeflate),
            "binary+deflate" | "binary-deflate" | "bin+deflate" => {
                Some(WireFormat::BinaryDeflate)
            }
            _ => None,
        }
    }

    /// Map an HTTP `Content-Type` header to a format (JSON for anything
    /// unrecognized — the tolerant default a REST server needs). Media
    /// types are case-insensitive (RFC 9110) and may carry parameters.
    pub fn from_content_type(ct: &str) -> WireFormat {
        let media_type = ct.split(';').next().unwrap_or(ct).trim();
        if media_type.eq_ignore_ascii_case(CONTENT_TYPE_BINARY) {
            WireFormat::Binary
        } else if media_type.eq_ignore_ascii_case(CONTENT_TYPE_BINARY_DEFLATE) {
            WireFormat::BinaryDeflate
        } else if media_type.eq_ignore_ascii_case(CONTENT_TYPE_JSON_DEFLATE) {
            WireFormat::JsonDeflate
        } else {
            WireFormat::Json
        }
    }
}

/// A wire codec: turns message bodies into bytes and back. Implementations
/// must be pure (stateless) so one static instance serves every transport.
pub trait WireCodec: Send + Sync {
    fn format(&self) -> WireFormat;
    fn content_type(&self) -> &'static str;
    fn encode(&self, body: &Value) -> Vec<u8>;
    fn decode(&self, bytes: &[u8]) -> Result<Value>;
}

/// The paper's wire format: compact JSON text.
pub struct JsonCodec;

impl WireCodec for JsonCodec {
    fn format(&self) -> WireFormat {
        WireFormat::Json
    }

    fn content_type(&self) -> &'static str {
        CONTENT_TYPE_JSON
    }

    fn encode(&self, body: &Value) -> Vec<u8> {
        body.to_string().into_bytes()
    }

    fn decode(&self, bytes: &[u8]) -> Result<Value> {
        let text = std::str::from_utf8(bytes).context("JSON body not UTF-8")?;
        crate::json::parse(text)
    }
}

/// Transparent DEFLATE wrapper around an inner codec: compresses the
/// inner encoding on the way out, inflates before the inner decode on the
/// way in. Works around *any* inner codec — the two selectable stacks are
/// the [`JSON_DEFLATE`] and [`BINARY_DEFLATE`] statics.
pub struct CompressedCodec {
    inner: &'static dyn WireCodec,
    format: WireFormat,
    content_type: &'static str,
}

/// `deflate ∘ json` — the paper's wire format under transparent compression.
pub static JSON_DEFLATE: CompressedCodec = CompressedCodec {
    inner: &JsonCodec,
    format: WireFormat::JsonDeflate,
    content_type: CONTENT_TYPE_JSON_DEFLATE,
};

/// `deflate ∘ binary` — the smallest stack for large float vectors.
pub static BINARY_DEFLATE: CompressedCodec = CompressedCodec {
    inner: &BinaryCodec,
    format: WireFormat::BinaryDeflate,
    content_type: CONTENT_TYPE_BINARY_DEFLATE,
};

impl WireCodec for CompressedCodec {
    fn format(&self) -> WireFormat {
        self.format
    }

    fn content_type(&self) -> &'static str {
        self.content_type
    }

    fn encode(&self, body: &Value) -> Vec<u8> {
        crate::util::compress(&self.inner.encode(body))
    }

    fn decode(&self, bytes: &[u8]) -> Result<Value> {
        let raw = crate::util::decompress(bytes)?;
        self.inner.decode(&raw)
    }
}

// Binary codec value tags. One byte each, followed by the tag-specific
// payload. Lengths and counts are LEB128 varints.
const TAG_NULL: u8 = 0;
const TAG_FALSE: u8 = 1;
const TAG_TRUE: u8 = 2;
/// Raw little-endian f64 (8 bytes).
const TAG_F64: u8 = 3;
/// Non-negative integral number < 2^53 as a varint.
const TAG_UINT: u8 = 4;
/// Length-prefixed UTF-8 string (no escaping).
const TAG_STR: u8 = 5;
/// Generic array: count + encoded elements.
const TAG_ARR: u8 = 6;
/// Object: count + (key-length, key bytes, encoded value) per entry.
const TAG_OBJ: u8 = 7;
/// All-number array with a fractional/large element: count + raw LE f64s.
const TAG_F64_ARR: u8 = 8;
/// All-number array of non-negative integrals < 2^53: count + varints.
const TAG_UINT_ARR: u8 = 9;
/// Opaque byte blob ([`Value::Bytes`]): length + raw bytes. This is the
/// raw ciphertext framing — no base64 anywhere under the binary codec.
const TAG_BYTES: u8 = 10;

/// Largest f64 that is exactly representable as an integer (2^53); numbers
/// below this with zero fraction take the varint paths.
const MAX_EXACT_INT: f64 = 9_007_199_254_740_992.0;

fn is_varint_friendly(n: f64) -> bool {
    n >= 0.0 && n < MAX_EXACT_INT && n.fract() == 0.0
}

// The one shared LEB128 implementation (also used by the envelope's blob
// framing) lives in `util`.
use crate::util::write_varint;

/// Compact tagged binary codec (see module docs for the format).
pub struct BinaryCodec;

impl BinaryCodec {
    fn encode_value(v: &Value, out: &mut Vec<u8>) {
        match v {
            Value::Null => out.push(TAG_NULL),
            Value::Bool(false) => out.push(TAG_FALSE),
            Value::Bool(true) => out.push(TAG_TRUE),
            Value::Num(n) => {
                if !n.is_finite() {
                    // Match JsonCodec (which has no NaN/Inf and emits null)
                    // so both codecs encode the same message model and a
                    // session behaves identically under either wire format.
                    out.push(TAG_NULL);
                } else if is_varint_friendly(*n) {
                    out.push(TAG_UINT);
                    write_varint(*n as u64, out);
                } else {
                    out.push(TAG_F64);
                    out.extend_from_slice(&n.to_le_bytes());
                }
            }
            Value::Str(s) => {
                out.push(TAG_STR);
                write_varint(s.len() as u64, out);
                out.extend_from_slice(s.as_bytes());
            }
            Value::Bytes(b) => {
                out.push(TAG_BYTES);
                write_varint(b.len() as u64, out);
                out.extend_from_slice(b.as_bytes());
            }
            Value::Arr(a) => {
                // Packed fast paths for homogeneous number arrays — the
                // feature vectors and id lists that dominate SAFE traffic.
                // Non-finite elements drop to the generic path so they
                // encode as null exactly like JsonCodec.
                if !a.is_empty() && a.iter().all(|e| matches!(e, Value::Num(n) if n.is_finite())) {
                    let all_varint = a
                        .iter()
                        .all(|e| matches!(e, Value::Num(n) if is_varint_friendly(*n)));
                    if all_varint {
                        out.push(TAG_UINT_ARR);
                        write_varint(a.len() as u64, out);
                        for e in a {
                            if let Value::Num(n) = e {
                                write_varint(*n as u64, out);
                            }
                        }
                    } else {
                        out.push(TAG_F64_ARR);
                        write_varint(a.len() as u64, out);
                        for e in a {
                            if let Value::Num(n) = e {
                                out.extend_from_slice(&n.to_le_bytes());
                            }
                        }
                    }
                } else {
                    out.push(TAG_ARR);
                    write_varint(a.len() as u64, out);
                    for e in a {
                        Self::encode_value(e, out);
                    }
                }
            }
            Value::Obj(m) => {
                out.push(TAG_OBJ);
                write_varint(m.len() as u64, out);
                for (k, v) in m {
                    write_varint(k.len() as u64, out);
                    out.extend_from_slice(k.as_bytes());
                    Self::encode_value(v, out);
                }
            }
        }
    }
}

impl WireCodec for BinaryCodec {
    fn format(&self) -> WireFormat {
        WireFormat::Binary
    }

    fn content_type(&self) -> &'static str {
        CONTENT_TYPE_BINARY
    }

    fn encode(&self, body: &Value) -> Vec<u8> {
        let mut out = Vec::with_capacity(64);
        Self::encode_value(body, &mut out);
        out
    }

    fn decode(&self, bytes: &[u8]) -> Result<Value> {
        let mut r = Reader { bytes, pos: 0 };
        let v = r.read_value(0)?;
        if r.pos != bytes.len() {
            bail!("trailing bytes at offset {}", r.pos);
        }
        Ok(v)
    }
}

/// Nesting guard: protocol messages are ≤ 3 levels deep; 64 is paranoia.
const MAX_DEPTH: usize = 64;

struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn remaining(&self) -> usize {
        self.bytes.len() - self.pos
    }

    fn read_u8(&mut self) -> Result<u8> {
        let b = *self
            .bytes
            .get(self.pos)
            .context("unexpected end of binary message")?;
        self.pos += 1;
        Ok(b)
    }

    fn read_varint(&mut self) -> Result<u64> {
        crate::util::read_varint(self.bytes, &mut self.pos)
    }

    fn read_exact(&mut self, len: usize) -> Result<&'a [u8]> {
        if len > self.remaining() {
            bail!("truncated binary message: need {len} bytes, have {}", self.remaining());
        }
        let s = &self.bytes[self.pos..self.pos + len];
        self.pos += len;
        Ok(s)
    }

    fn read_f64(&mut self) -> Result<f64> {
        let b = self.read_exact(8)?;
        Ok(f64::from_le_bytes(b.try_into().unwrap()))
    }

    fn read_string(&mut self) -> Result<String> {
        let len = self.read_varint()? as usize;
        let raw = self.read_exact(len)?;
        Ok(std::str::from_utf8(raw)
            .context("binary string not UTF-8")?
            .to_string())
    }

    /// A TAG_UINT/TAG_UINT_ARR element: the encoder only emits varints
    /// below 2^53 (exact in f64), so anything larger is malformed —
    /// reject it rather than silently rounding through `as f64`.
    fn read_uint_f64(&mut self) -> Result<f64> {
        let n = self.read_varint()?;
        if n >= MAX_EXACT_INT as u64 {
            bail!("varint {n} exceeds the exact f64 integer range");
        }
        Ok(n as f64)
    }

    fn read_count(&mut self, min_elem_bytes: usize) -> Result<usize> {
        let count = self.read_varint()? as usize;
        // Every element costs ≥ min_elem_bytes, so a count the remaining
        // buffer cannot hold is malformed — reject before allocating.
        if count.checked_mul(min_elem_bytes).map_or(true, |need| need > self.remaining()) {
            bail!("binary message count {count} exceeds remaining bytes");
        }
        Ok(count)
    }

    fn read_value(&mut self, depth: usize) -> Result<Value> {
        if depth > MAX_DEPTH {
            bail!("binary message nested deeper than {MAX_DEPTH}");
        }
        match self.read_u8()? {
            TAG_NULL => Ok(Value::Null),
            TAG_FALSE => Ok(Value::Bool(false)),
            TAG_TRUE => Ok(Value::Bool(true)),
            TAG_F64 => Ok(Value::Num(self.read_f64()?)),
            TAG_UINT => Ok(Value::Num(self.read_uint_f64()?)),
            TAG_STR => Ok(Value::Str(self.read_string()?)),
            TAG_BYTES => {
                let len = self.read_varint()? as usize;
                Ok(Value::Bytes(Blob::from_slice(self.read_exact(len)?)))
            }
            TAG_ARR => {
                let count = self.read_count(1)?;
                let mut a = Vec::with_capacity(count);
                for _ in 0..count {
                    a.push(self.read_value(depth + 1)?);
                }
                Ok(Value::Arr(a))
            }
            TAG_OBJ => {
                let count = self.read_count(2)?;
                let mut m = std::collections::BTreeMap::new();
                for _ in 0..count {
                    let key = self.read_string()?;
                    let val = self.read_value(depth + 1)?;
                    m.insert(key, val);
                }
                Ok(Value::Obj(m))
            }
            TAG_F64_ARR => {
                let count = self.read_count(8)?;
                let mut a = Vec::with_capacity(count);
                for _ in 0..count {
                    a.push(Value::Num(self.read_f64()?));
                }
                Ok(Value::Arr(a))
            }
            TAG_UINT_ARR => {
                let count = self.read_count(1)?;
                let mut a = Vec::with_capacity(count);
                for _ in 0..count {
                    a.push(Value::Num(self.read_uint_f64()?));
                }
                Ok(Value::Arr(a))
            }
            t => bail!("unknown binary tag {t:#x} at offset {}", self.pos - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(v: &Value) {
        let enc = BinaryCodec.encode(v);
        let dec = BinaryCodec.decode(&enc).unwrap();
        assert_eq!(&dec, v, "binary roundtrip mismatch");
        // JSON agrees on the same message (the codecs share a model).
        let jenc = JsonCodec.encode(v);
        let jdec = JsonCodec.decode(&jenc).unwrap();
        assert_eq!(&jdec, v, "json roundtrip mismatch");
    }

    #[test]
    fn scalars_roundtrip() {
        roundtrip(&Value::Null);
        roundtrip(&Value::Bool(true));
        roundtrip(&Value::Bool(false));
        roundtrip(&Value::Num(0.0));
        roundtrip(&Value::Num(1.0));
        roundtrip(&Value::Num(-1.5));
        roundtrip(&Value::Num(1e300));
        roundtrip(&Value::Num(123456789.0));
        roundtrip(&Value::Str("".into()));
        roundtrip(&Value::Str("hello \"world\" \n é 😀".into()));
    }

    #[test]
    fn arrays_roundtrip_all_shapes() {
        roundtrip(&Value::Arr(vec![]));
        // uint-packed
        roundtrip(&Value::from(vec![1.0, 2.0, 300.0, 0.0]));
        // f64-packed
        roundtrip(&Value::from(vec![1.5, -2.0, 1e-300]));
        // mixed types → generic
        roundtrip(&Value::Arr(vec![
            Value::Num(1.0),
            Value::Str("x".into()),
            Value::Null,
            Value::Arr(vec![Value::Bool(true)]),
        ]));
    }

    #[test]
    fn objects_roundtrip() {
        let v = Value::object(vec![
            ("from_node", Value::from(1u64)),
            ("to_node", Value::from(2u64)),
            ("aggregate", Value::from("safe:QUJD:ZGVm")),
            ("vec", Value::from(vec![1.25, 2.5, -3.0])),
            ("nested", Value::object(vec![("a", Value::Arr(vec![]))])),
        ]);
        roundtrip(&v);
    }

    #[test]
    fn binary_smaller_for_float_vectors() {
        let avg: Vec<f64> = (0..1024).map(|i| i as f64 * 0.123456789 + 0.1).collect();
        let msg = Value::object(vec![
            ("average", Value::from(avg)),
            ("contributors", Value::from(8u64)),
            ("group", Value::from(1u64)),
            ("node", Value::from(1u64)),
        ]);
        let b = BinaryCodec.encode(&msg).len();
        let j = JsonCodec.encode(&msg).len();
        assert!(b < j, "binary {b} should beat json {j}");
        // Raw f64s: the payload itself is exactly 8 bytes per feature.
        assert!(b < 1024 * 8 + 64);
    }

    #[test]
    fn binary_smaller_for_b64_payload_messages() {
        let blob = "QUJDREVGRw==".repeat(800); // ~ a sealed 1024-feature aggregate
        let msg = Value::object(vec![
            ("aggregate", Value::from(blob.as_str())),
            ("from_node", Value::from(1u64)),
            ("group", Value::from(1u64)),
            ("round_id", Value::from(0u64)),
            ("to_node", Value::from(2u64)),
        ]);
        let b = BinaryCodec.encode(&msg).len();
        let j = JsonCodec.encode(&msg).len();
        assert!(b < j, "binary {b} should beat json {j}");
    }

    #[test]
    fn non_finite_floats_encode_as_null_like_json() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let v = Value::Num(bad);
            assert_eq!(BinaryCodec.decode(&BinaryCodec.encode(&v)).unwrap(), Value::Null);
            assert_eq!(JsonCodec.decode(&JsonCodec.encode(&v)).unwrap(), Value::Null);
            // Inside an array both codecs agree too: [1, null, 2].
            let arr = Value::Arr(vec![Value::Num(1.0), Value::Num(bad), Value::Num(2.0)]);
            let expect =
                Value::Arr(vec![Value::Num(1.0), Value::Null, Value::Num(2.0)]);
            assert_eq!(BinaryCodec.decode(&BinaryCodec.encode(&arr)).unwrap(), expect);
            assert_eq!(JsonCodec.decode(&JsonCodec.encode(&arr)).unwrap(), expect);
        }
    }

    #[test]
    fn varint_boundaries() {
        for n in [0u64, 1, 127, 128, 16383, 16384, (1u64 << 53) - 1] {
            let v = Value::Num(n as f64);
            let enc = BinaryCodec.encode(&v);
            assert_eq!(BinaryCodec.decode(&enc).unwrap(), v);
        }
        // 2^53 exactly must take the f64 path and still roundtrip.
        let v = Value::Num(MAX_EXACT_INT);
        assert_eq!(BinaryCodec.decode(&BinaryCodec.encode(&v)).unwrap(), v);
    }

    #[test]
    fn decode_rejects_malformed() {
        assert!(BinaryCodec.decode(&[]).is_err());
        assert!(BinaryCodec.decode(&[0xfe]).is_err()); // unknown tag
        assert!(BinaryCodec.decode(&[TAG_STR, 10, b'a']).is_err()); // truncated
        // Huge count with no payload must not allocate/panic.
        assert!(BinaryCodec.decode(&[TAG_F64_ARR, 0xff, 0xff, 0xff, 0x7f]).is_err());
        // Trailing garbage.
        assert!(BinaryCodec.decode(&[TAG_NULL, 0]).is_err());
        // Non-UTF-8 string.
        assert!(BinaryCodec.decode(&[TAG_STR, 1, 0xff]).is_err());
        // TAG_UINT varint at 2^53 (outside the encoder's invariant) is
        // rejected instead of silently rounding through `as f64`.
        let mut too_big = vec![TAG_UINT];
        super::write_varint(1u64 << 53, &mut too_big);
        assert!(BinaryCodec.decode(&too_big).is_err());
    }

    #[test]
    fn bytes_roundtrip_all_codecs_and_binary_skips_base64() {
        let blob = Blob::new((0..=255u8).collect());
        let v = Value::object(vec![
            ("aggregate", Value::Bytes(blob.clone())),
            ("from_node", Value::from(1u64)),
        ]);
        for fmt in WireFormat::ALL {
            let codec = fmt.codec();
            let dec = codec.decode(&codec.encode(&v)).unwrap();
            assert_eq!(dec, v, "{} roundtrip", fmt.name());
            assert_eq!(
                dec.blob_of("aggregate").unwrap().as_bytes(),
                blob.as_bytes(),
                "{} blob content",
                fmt.name()
            );
        }
        // Binary ships the blob raw; JSON pays the 4/3 base64 inflation.
        let b = BinaryCodec.encode(&v).len();
        let j = JsonCodec.encode(&v).len();
        assert!(b < 256 + 40, "binary must carry raw bytes, got {b}");
        assert!(j > 256 * 4 / 3, "json must carry base64 text, got {j}");
    }

    #[test]
    fn deflate_codecs_roundtrip_and_compress_text() {
        let avg: Vec<f64> = (0..512).map(|i| i as f64 * 0.001).collect();
        let v = Value::object(vec![("average", Value::from(avg))]);
        for fmt in [WireFormat::JsonDeflate, WireFormat::BinaryDeflate] {
            let codec = fmt.codec();
            assert_eq!(codec.format(), fmt);
            let enc = codec.encode(&v);
            assert_eq!(codec.decode(&enc).unwrap(), v, "{}", fmt.name());
        }
        // Decimal float text is highly compressible.
        let j = JsonCodec.encode(&v).len();
        let jd = JSON_DEFLATE.encode(&v).len();
        assert!(jd < j, "json+deflate {jd} must beat json {j}");
        // A deflated body is not valid input for the bare inner codec.
        assert!(JsonCodec.decode(&JSON_DEFLATE.encode(&v)).is_err());
        // Garbage is not valid DEFLATE.
        assert!(BINARY_DEFLATE.decode(&[0xff, 0x00, 0xab]).is_err());
    }

    #[test]
    fn bytes_decode_rejects_truncation() {
        assert!(BinaryCodec.decode(&[TAG_BYTES, 5, 1, 2]).is_err());
        assert!(BinaryCodec.decode(&[TAG_BYTES, 0xff, 0xff, 0xff, 0x7f]).is_err());
    }

    #[test]
    fn content_type_negotiation() {
        assert_eq!(WireFormat::from_content_type("application/json"), WireFormat::Json);
        assert_eq!(
            WireFormat::from_content_type("application/x-safe-binary"),
            WireFormat::Binary
        );
        // RFC 9110: media types are case-insensitive, parameters allowed.
        assert_eq!(
            WireFormat::from_content_type("Application/X-SAFE-Binary"),
            WireFormat::Binary
        );
        assert_eq!(
            WireFormat::from_content_type("application/x-safe-binary; charset=binary"),
            WireFormat::Binary
        );
        assert_eq!(WireFormat::from_content_type("text/plain"), WireFormat::Json);
        assert_eq!(WireFormat::from_name("binary"), Some(WireFormat::Binary));
        assert_eq!(WireFormat::default(), WireFormat::Json);
        // Deflate-wrapped stacks negotiate like any other format.
        assert_eq!(
            WireFormat::from_content_type(CONTENT_TYPE_JSON_DEFLATE),
            WireFormat::JsonDeflate
        );
        assert_eq!(
            WireFormat::from_content_type("Application/X-SAFE-Binary-Deflate"),
            WireFormat::BinaryDeflate
        );
        assert_eq!(
            WireFormat::from_name("json+deflate"),
            Some(WireFormat::JsonDeflate)
        );
        assert_eq!(
            WireFormat::from_name("binary+deflate"),
            Some(WireFormat::BinaryDeflate)
        );
        for fmt in WireFormat::ALL {
            assert_eq!(WireFormat::from_name(fmt.name()), Some(fmt));
            assert_eq!(
                WireFormat::from_content_type(fmt.codec().content_type()),
                fmt
            );
        }
    }
}
