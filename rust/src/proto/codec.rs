//! Pluggable wire codecs: how a message body becomes bytes on the wire.
//!
//! The paper's deployment speaks JSON (a Flask REST server), and JSON
//! stays the default so every paper-parity figure is produced by the same
//! wire format the paper measured. But the controller is "a mere message
//! broker", so the serialization tax *is* the system's hot path — and the
//! codec is a policy, not an assumption. Two implementations:
//!
//! * [`JsonCodec`] — the paper's format: UTF-8 JSON text, float vectors as
//!   decimal text, ciphertexts as base64 strings.
//! * [`BinaryCodec`] — a compact tagged binary encoding of the same
//!   message model: LEB128 varints for lengths and integral numbers,
//!   length-prefixed (unescaped) strings, and two packed array forms —
//!   raw little-endian `f64` for real-valued vectors and varint packing
//!   for id lists. A 10 000-feature average that costs ~170 KiB as JSON
//!   text is 80 KiB + a few bytes here, with no float formatting or
//!   parsing on either side.
//!
//! Both codecs encode the *same* [`Value`] message model, so every layer
//! above the transport (typed messages, controller dispatch, learner state
//! machines) is codec-agnostic. Transports pick a codec from
//! [`WireFormat`]; the HTTP layer negotiates it per-request via
//! `Content-Type` (see `transport::http`).

use anyhow::{bail, Context, Result};

use crate::json::Value;

/// Content type identifying JSON bodies on the HTTP transport.
pub const CONTENT_TYPE_JSON: &str = "application/json";
/// Content type identifying binary-codec bodies on the HTTP transport.
pub const CONTENT_TYPE_BINARY: &str = "application/x-safe-binary";

/// Which wire codec a session/transport uses. JSON is the default and
/// keeps the paper-parity benches byte-compatible with the seed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WireFormat {
    #[default]
    Json,
    Binary,
}

impl WireFormat {
    pub fn codec(self) -> &'static dyn WireCodec {
        match self {
            WireFormat::Json => &JsonCodec,
            WireFormat::Binary => &BinaryCodec,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            WireFormat::Json => "json",
            WireFormat::Binary => "binary",
        }
    }

    pub fn from_name(s: &str) -> Option<WireFormat> {
        match s {
            "json" => Some(WireFormat::Json),
            "binary" | "bin" => Some(WireFormat::Binary),
            _ => None,
        }
    }

    /// Map an HTTP `Content-Type` header to a format (JSON for anything
    /// unrecognized — the tolerant default a REST server needs). Media
    /// types are case-insensitive (RFC 9110) and may carry parameters.
    pub fn from_content_type(ct: &str) -> WireFormat {
        let media_type = ct.split(';').next().unwrap_or(ct).trim();
        if media_type.eq_ignore_ascii_case(CONTENT_TYPE_BINARY) {
            WireFormat::Binary
        } else {
            WireFormat::Json
        }
    }
}

/// A wire codec: turns message bodies into bytes and back. Implementations
/// must be pure (stateless) so one static instance serves every transport.
pub trait WireCodec: Send + Sync {
    fn format(&self) -> WireFormat;
    fn content_type(&self) -> &'static str;
    fn encode(&self, body: &Value) -> Vec<u8>;
    fn decode(&self, bytes: &[u8]) -> Result<Value>;
}

/// The paper's wire format: compact JSON text.
pub struct JsonCodec;

impl WireCodec for JsonCodec {
    fn format(&self) -> WireFormat {
        WireFormat::Json
    }

    fn content_type(&self) -> &'static str {
        CONTENT_TYPE_JSON
    }

    fn encode(&self, body: &Value) -> Vec<u8> {
        body.to_string().into_bytes()
    }

    fn decode(&self, bytes: &[u8]) -> Result<Value> {
        let text = std::str::from_utf8(bytes).context("JSON body not UTF-8")?;
        crate::json::parse(text)
    }
}

// Binary codec value tags. One byte each, followed by the tag-specific
// payload. Lengths and counts are LEB128 varints.
const TAG_NULL: u8 = 0;
const TAG_FALSE: u8 = 1;
const TAG_TRUE: u8 = 2;
/// Raw little-endian f64 (8 bytes).
const TAG_F64: u8 = 3;
/// Non-negative integral number < 2^53 as a varint.
const TAG_UINT: u8 = 4;
/// Length-prefixed UTF-8 string (no escaping).
const TAG_STR: u8 = 5;
/// Generic array: count + encoded elements.
const TAG_ARR: u8 = 6;
/// Object: count + (key-length, key bytes, encoded value) per entry.
const TAG_OBJ: u8 = 7;
/// All-number array with a fractional/large element: count + raw LE f64s.
const TAG_F64_ARR: u8 = 8;
/// All-number array of non-negative integrals < 2^53: count + varints.
const TAG_UINT_ARR: u8 = 9;

/// Largest f64 that is exactly representable as an integer (2^53); numbers
/// below this with zero fraction take the varint paths.
const MAX_EXACT_INT: f64 = 9_007_199_254_740_992.0;

fn is_varint_friendly(n: f64) -> bool {
    n >= 0.0 && n < MAX_EXACT_INT && n.fract() == 0.0
}

fn write_varint(mut n: u64, out: &mut Vec<u8>) {
    loop {
        let b = (n & 0x7f) as u8;
        n >>= 7;
        if n == 0 {
            out.push(b);
            break;
        }
        out.push(b | 0x80);
    }
}

/// Compact tagged binary codec (see module docs for the format).
pub struct BinaryCodec;

impl BinaryCodec {
    fn encode_value(v: &Value, out: &mut Vec<u8>) {
        match v {
            Value::Null => out.push(TAG_NULL),
            Value::Bool(false) => out.push(TAG_FALSE),
            Value::Bool(true) => out.push(TAG_TRUE),
            Value::Num(n) => {
                if !n.is_finite() {
                    // Match JsonCodec (which has no NaN/Inf and emits null)
                    // so both codecs encode the same message model and a
                    // session behaves identically under either wire format.
                    out.push(TAG_NULL);
                } else if is_varint_friendly(*n) {
                    out.push(TAG_UINT);
                    write_varint(*n as u64, out);
                } else {
                    out.push(TAG_F64);
                    out.extend_from_slice(&n.to_le_bytes());
                }
            }
            Value::Str(s) => {
                out.push(TAG_STR);
                write_varint(s.len() as u64, out);
                out.extend_from_slice(s.as_bytes());
            }
            Value::Arr(a) => {
                // Packed fast paths for homogeneous number arrays — the
                // feature vectors and id lists that dominate SAFE traffic.
                // Non-finite elements drop to the generic path so they
                // encode as null exactly like JsonCodec.
                if !a.is_empty() && a.iter().all(|e| matches!(e, Value::Num(n) if n.is_finite())) {
                    let all_varint = a
                        .iter()
                        .all(|e| matches!(e, Value::Num(n) if is_varint_friendly(*n)));
                    if all_varint {
                        out.push(TAG_UINT_ARR);
                        write_varint(a.len() as u64, out);
                        for e in a {
                            if let Value::Num(n) = e {
                                write_varint(*n as u64, out);
                            }
                        }
                    } else {
                        out.push(TAG_F64_ARR);
                        write_varint(a.len() as u64, out);
                        for e in a {
                            if let Value::Num(n) = e {
                                out.extend_from_slice(&n.to_le_bytes());
                            }
                        }
                    }
                } else {
                    out.push(TAG_ARR);
                    write_varint(a.len() as u64, out);
                    for e in a {
                        Self::encode_value(e, out);
                    }
                }
            }
            Value::Obj(m) => {
                out.push(TAG_OBJ);
                write_varint(m.len() as u64, out);
                for (k, v) in m {
                    write_varint(k.len() as u64, out);
                    out.extend_from_slice(k.as_bytes());
                    Self::encode_value(v, out);
                }
            }
        }
    }
}

impl WireCodec for BinaryCodec {
    fn format(&self) -> WireFormat {
        WireFormat::Binary
    }

    fn content_type(&self) -> &'static str {
        CONTENT_TYPE_BINARY
    }

    fn encode(&self, body: &Value) -> Vec<u8> {
        let mut out = Vec::with_capacity(64);
        Self::encode_value(body, &mut out);
        out
    }

    fn decode(&self, bytes: &[u8]) -> Result<Value> {
        let mut r = Reader { bytes, pos: 0 };
        let v = r.read_value(0)?;
        if r.pos != bytes.len() {
            bail!("trailing bytes at offset {}", r.pos);
        }
        Ok(v)
    }
}

/// Nesting guard: protocol messages are ≤ 3 levels deep; 64 is paranoia.
const MAX_DEPTH: usize = 64;

struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn remaining(&self) -> usize {
        self.bytes.len() - self.pos
    }

    fn read_u8(&mut self) -> Result<u8> {
        let b = *self
            .bytes
            .get(self.pos)
            .context("unexpected end of binary message")?;
        self.pos += 1;
        Ok(b)
    }

    fn read_varint(&mut self) -> Result<u64> {
        let mut n = 0u64;
        let mut shift = 0u32;
        loop {
            let b = self.read_u8()?;
            if shift >= 63 && b > 1 {
                bail!("varint overflows u64");
            }
            n |= ((b & 0x7f) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(n);
            }
            shift += 7;
            if shift > 63 {
                bail!("varint too long");
            }
        }
    }

    fn read_exact(&mut self, len: usize) -> Result<&'a [u8]> {
        if len > self.remaining() {
            bail!("truncated binary message: need {len} bytes, have {}", self.remaining());
        }
        let s = &self.bytes[self.pos..self.pos + len];
        self.pos += len;
        Ok(s)
    }

    fn read_f64(&mut self) -> Result<f64> {
        let b = self.read_exact(8)?;
        Ok(f64::from_le_bytes(b.try_into().unwrap()))
    }

    fn read_string(&mut self) -> Result<String> {
        let len = self.read_varint()? as usize;
        let raw = self.read_exact(len)?;
        Ok(std::str::from_utf8(raw)
            .context("binary string not UTF-8")?
            .to_string())
    }

    /// A TAG_UINT/TAG_UINT_ARR element: the encoder only emits varints
    /// below 2^53 (exact in f64), so anything larger is malformed —
    /// reject it rather than silently rounding through `as f64`.
    fn read_uint_f64(&mut self) -> Result<f64> {
        let n = self.read_varint()?;
        if n >= MAX_EXACT_INT as u64 {
            bail!("varint {n} exceeds the exact f64 integer range");
        }
        Ok(n as f64)
    }

    fn read_count(&mut self, min_elem_bytes: usize) -> Result<usize> {
        let count = self.read_varint()? as usize;
        // Every element costs ≥ min_elem_bytes, so a count the remaining
        // buffer cannot hold is malformed — reject before allocating.
        if count.checked_mul(min_elem_bytes).map_or(true, |need| need > self.remaining()) {
            bail!("binary message count {count} exceeds remaining bytes");
        }
        Ok(count)
    }

    fn read_value(&mut self, depth: usize) -> Result<Value> {
        if depth > MAX_DEPTH {
            bail!("binary message nested deeper than {MAX_DEPTH}");
        }
        match self.read_u8()? {
            TAG_NULL => Ok(Value::Null),
            TAG_FALSE => Ok(Value::Bool(false)),
            TAG_TRUE => Ok(Value::Bool(true)),
            TAG_F64 => Ok(Value::Num(self.read_f64()?)),
            TAG_UINT => Ok(Value::Num(self.read_uint_f64()?)),
            TAG_STR => Ok(Value::Str(self.read_string()?)),
            TAG_ARR => {
                let count = self.read_count(1)?;
                let mut a = Vec::with_capacity(count);
                for _ in 0..count {
                    a.push(self.read_value(depth + 1)?);
                }
                Ok(Value::Arr(a))
            }
            TAG_OBJ => {
                let count = self.read_count(2)?;
                let mut m = std::collections::BTreeMap::new();
                for _ in 0..count {
                    let key = self.read_string()?;
                    let val = self.read_value(depth + 1)?;
                    m.insert(key, val);
                }
                Ok(Value::Obj(m))
            }
            TAG_F64_ARR => {
                let count = self.read_count(8)?;
                let mut a = Vec::with_capacity(count);
                for _ in 0..count {
                    a.push(Value::Num(self.read_f64()?));
                }
                Ok(Value::Arr(a))
            }
            TAG_UINT_ARR => {
                let count = self.read_count(1)?;
                let mut a = Vec::with_capacity(count);
                for _ in 0..count {
                    a.push(Value::Num(self.read_uint_f64()?));
                }
                Ok(Value::Arr(a))
            }
            t => bail!("unknown binary tag {t:#x} at offset {}", self.pos - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(v: &Value) {
        let enc = BinaryCodec.encode(v);
        let dec = BinaryCodec.decode(&enc).unwrap();
        assert_eq!(&dec, v, "binary roundtrip mismatch");
        // JSON agrees on the same message (the codecs share a model).
        let jenc = JsonCodec.encode(v);
        let jdec = JsonCodec.decode(&jenc).unwrap();
        assert_eq!(&jdec, v, "json roundtrip mismatch");
    }

    #[test]
    fn scalars_roundtrip() {
        roundtrip(&Value::Null);
        roundtrip(&Value::Bool(true));
        roundtrip(&Value::Bool(false));
        roundtrip(&Value::Num(0.0));
        roundtrip(&Value::Num(1.0));
        roundtrip(&Value::Num(-1.5));
        roundtrip(&Value::Num(1e300));
        roundtrip(&Value::Num(123456789.0));
        roundtrip(&Value::Str("".into()));
        roundtrip(&Value::Str("hello \"world\" \n é 😀".into()));
    }

    #[test]
    fn arrays_roundtrip_all_shapes() {
        roundtrip(&Value::Arr(vec![]));
        // uint-packed
        roundtrip(&Value::from(vec![1.0, 2.0, 300.0, 0.0]));
        // f64-packed
        roundtrip(&Value::from(vec![1.5, -2.0, 1e-300]));
        // mixed types → generic
        roundtrip(&Value::Arr(vec![
            Value::Num(1.0),
            Value::Str("x".into()),
            Value::Null,
            Value::Arr(vec![Value::Bool(true)]),
        ]));
    }

    #[test]
    fn objects_roundtrip() {
        let v = Value::object(vec![
            ("from_node", Value::from(1u64)),
            ("to_node", Value::from(2u64)),
            ("aggregate", Value::from("safe:QUJD:ZGVm")),
            ("vec", Value::from(vec![1.25, 2.5, -3.0])),
            ("nested", Value::object(vec![("a", Value::Arr(vec![]))])),
        ]);
        roundtrip(&v);
    }

    #[test]
    fn binary_smaller_for_float_vectors() {
        let avg: Vec<f64> = (0..1024).map(|i| i as f64 * 0.123456789 + 0.1).collect();
        let msg = Value::object(vec![
            ("average", Value::from(avg)),
            ("contributors", Value::from(8u64)),
            ("group", Value::from(1u64)),
            ("node", Value::from(1u64)),
        ]);
        let b = BinaryCodec.encode(&msg).len();
        let j = JsonCodec.encode(&msg).len();
        assert!(b < j, "binary {b} should beat json {j}");
        // Raw f64s: the payload itself is exactly 8 bytes per feature.
        assert!(b < 1024 * 8 + 64);
    }

    #[test]
    fn binary_smaller_for_b64_payload_messages() {
        let blob = "QUJDREVGRw==".repeat(800); // ~ a sealed 1024-feature aggregate
        let msg = Value::object(vec![
            ("aggregate", Value::from(blob.as_str())),
            ("from_node", Value::from(1u64)),
            ("group", Value::from(1u64)),
            ("round_id", Value::from(0u64)),
            ("to_node", Value::from(2u64)),
        ]);
        let b = BinaryCodec.encode(&msg).len();
        let j = JsonCodec.encode(&msg).len();
        assert!(b < j, "binary {b} should beat json {j}");
    }

    #[test]
    fn non_finite_floats_encode_as_null_like_json() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let v = Value::Num(bad);
            assert_eq!(BinaryCodec.decode(&BinaryCodec.encode(&v)).unwrap(), Value::Null);
            assert_eq!(JsonCodec.decode(&JsonCodec.encode(&v)).unwrap(), Value::Null);
            // Inside an array both codecs agree too: [1, null, 2].
            let arr = Value::Arr(vec![Value::Num(1.0), Value::Num(bad), Value::Num(2.0)]);
            let expect =
                Value::Arr(vec![Value::Num(1.0), Value::Null, Value::Num(2.0)]);
            assert_eq!(BinaryCodec.decode(&BinaryCodec.encode(&arr)).unwrap(), expect);
            assert_eq!(JsonCodec.decode(&JsonCodec.encode(&arr)).unwrap(), expect);
        }
    }

    #[test]
    fn varint_boundaries() {
        for n in [0u64, 1, 127, 128, 16383, 16384, (1u64 << 53) - 1] {
            let v = Value::Num(n as f64);
            let enc = BinaryCodec.encode(&v);
            assert_eq!(BinaryCodec.decode(&enc).unwrap(), v);
        }
        // 2^53 exactly must take the f64 path and still roundtrip.
        let v = Value::Num(MAX_EXACT_INT);
        assert_eq!(BinaryCodec.decode(&BinaryCodec.encode(&v)).unwrap(), v);
    }

    #[test]
    fn decode_rejects_malformed() {
        assert!(BinaryCodec.decode(&[]).is_err());
        assert!(BinaryCodec.decode(&[0xfe]).is_err()); // unknown tag
        assert!(BinaryCodec.decode(&[TAG_STR, 10, b'a']).is_err()); // truncated
        // Huge count with no payload must not allocate/panic.
        assert!(BinaryCodec.decode(&[TAG_F64_ARR, 0xff, 0xff, 0xff, 0x7f]).is_err());
        // Trailing garbage.
        assert!(BinaryCodec.decode(&[TAG_NULL, 0]).is_err());
        // Non-UTF-8 string.
        assert!(BinaryCodec.decode(&[TAG_STR, 1, 0xff]).is_err());
        // TAG_UINT varint at 2^53 (outside the encoder's invariant) is
        // rejected instead of silently rounding through `as f64`.
        let mut too_big = vec![TAG_UINT];
        super::write_varint(1u64 << 53, &mut too_big);
        assert!(BinaryCodec.decode(&too_big).is_err());
    }

    #[test]
    fn content_type_negotiation() {
        assert_eq!(WireFormat::from_content_type("application/json"), WireFormat::Json);
        assert_eq!(
            WireFormat::from_content_type("application/x-safe-binary"),
            WireFormat::Binary
        );
        // RFC 9110: media types are case-insensitive, parameters allowed.
        assert_eq!(
            WireFormat::from_content_type("Application/X-SAFE-Binary"),
            WireFormat::Binary
        );
        assert_eq!(
            WireFormat::from_content_type("application/x-safe-binary; charset=binary"),
            WireFormat::Binary
        );
        assert_eq!(WireFormat::from_content_type("text/plain"), WireFormat::Json);
        assert_eq!(WireFormat::from_name("binary"), Some(WireFormat::Binary));
        assert_eq!(WireFormat::default(), WireFormat::Json);
    }
}
