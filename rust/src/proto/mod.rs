//! Wire protocol: REST paths and JSON body builders.
//!
//! One place that defines every operation name in the system, mirroring the
//! paper's controller API (§5.1.3 + Appendix A) plus the key-registry,
//! pre-negotiation (§5.8), INSEC and BON baseline endpoints.

use crate::json::Value;

// ---- SAFE controller ops (paper §5.1.3 / Appendix A) ----
pub const POST_AGGREGATE: &str = "/post_aggregate";
pub const CHECK_AGGREGATE: &str = "/check_aggregate";
pub const GET_AGGREGATE: &str = "/get_aggregate";
pub const POST_AVERAGE: &str = "/post_average";
pub const GET_AVERAGE: &str = "/get_average";
pub const SHOULD_INITIATE: &str = "/should_initiate";

// ---- key management (§5.1 Round 0, §5.8) ----
pub const REGISTER_KEY: &str = "/register_key";
pub const GET_KEY: &str = "/get_key";
pub const POST_PRENEG_KEYS: &str = "/post_preneg_keys";
pub const GET_PRENEG_KEY: &str = "/get_preneg_key";

// ---- session management ----
pub const CONFIGURE: &str = "/configure";
pub const RESET: &str = "/reset";
pub const PROGRESS_CHECK: &str = "/progress_check";
pub const STATUS: &str = "/status";

// ---- INSEC baseline ----
pub const INSEC_POST: &str = "/insec/post";
pub const INSEC_GET_AVERAGE: &str = "/insec/get_average";

// ---- BON (Bonawitz et al. 2017) baseline ----
pub const BON_ADVERTISE: &str = "/bon/advertise";
pub const BON_GET_KEYS: &str = "/bon/get_keys";
pub const BON_POST_SHARES: &str = "/bon/post_shares";
pub const BON_GET_SHARES: &str = "/bon/get_shares";
pub const BON_POST_MASKED: &str = "/bon/post_masked";
pub const BON_GET_SURVIVORS: &str = "/bon/get_survivors";
pub const BON_POST_UNMASK: &str = "/bon/post_unmask";
pub const BON_GET_AVERAGE: &str = "/bon/get_average";

// ---- hierarchical federation (§5.10) ----
pub const FED_POST_CHILD_AVERAGE: &str = "/fed/post_child_average";
pub const FED_GET_GLOBAL_AVERAGE: &str = "/fed/get_global_average";

/// Body for `post_aggregate(from, to, aggregate)`.
pub fn post_aggregate(from_node: u64, to_node: u64, aggregate: &str, group: u64) -> Value {
    Value::object(vec![
        ("from_node", Value::from(from_node)),
        ("to_node", Value::from(to_node)),
        ("aggregate", Value::from(aggregate)),
        ("group", Value::from(group)),
    ])
}

/// Body for the node-scoped polling ops (`check_aggregate`, `get_aggregate`,
/// `get_average`, `should_initiate`).
pub fn node_op(node: u64, group: u64) -> Value {
    Value::object(vec![("node", Value::from(node)), ("group", Value::from(group))])
}

pub fn post_average(node: u64, group: u64, average: &[f64], contributors: u64) -> Value {
    Value::object(vec![
        ("node", Value::from(node)),
        ("group", Value::from(group)),
        ("average", Value::from(average)),
        ("contributors", Value::from(contributors)),
    ])
}

/// Response helpers.
pub fn status(s: &str) -> Value {
    Value::object(vec![("status", Value::from(s))])
}

pub fn is_empty_status(v: &Value) -> bool {
    v.str_of("status") == Some("empty")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bodies_have_expected_fields() {
        let b = post_aggregate(1, 2, "safe:k:b", 1);
        assert_eq!(b.u64_of("from_node"), Some(1));
        assert_eq!(b.u64_of("to_node"), Some(2));
        assert_eq!(b.str_of("aggregate"), Some("safe:k:b"));
        let n = node_op(7, 2);
        assert_eq!(n.u64_of("node"), Some(7));
        assert_eq!(n.u64_of("group"), Some(2));
        let a = post_average(1, 1, &[1.5, 2.5], 3);
        assert_eq!(a.f64_arr_of("average").unwrap(), vec![1.5, 2.5]);
        assert_eq!(a.u64_of("contributors"), Some(3));
    }

    #[test]
    fn status_helpers() {
        assert!(is_empty_status(&status("empty")));
        assert!(!is_empty_status(&status("consumed")));
    }
}
