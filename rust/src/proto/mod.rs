//! Wire protocol: REST paths, typed messages, and pluggable codecs.
//!
//! One place that defines every operation in the system, mirroring the
//! paper's controller API (§5.1.3 + Appendix A) plus the key-registry,
//! pre-negotiation (§5.8), INSEC, BON and hierarchical-federation
//! endpoints. Three layers:
//!
//! * **Paths** — the `&'static str` operation names (`/post_aggregate`,
//!   …). One REST call = one protocol message, as counted by §5.2's
//!   formulas.
//! * **Typed messages** — request/response structs ([`PostAggregate`],
//!   [`NodeOp`], [`PostAverage`], [`AggregateDelivery`], …) with
//!   `to_value`/`from_value` conversions. The controller's dispatch and
//!   the learner state machines build and parse these instead of poking
//!   at ad-hoc JSON fields, so a message's shape is declared exactly once.
//! * **Codecs** — [`codec::WireCodec`] turns the shared [`Value`] message
//!   model into bytes: [`codec::JsonCodec`] (the paper's REST format, the
//!   default), [`codec::BinaryCodec`] (length-prefixed fields, raw
//!   little-endian `f64` vectors, raw [`Blob`] ciphertext framing) or
//!   either wrapped in [`codec::CompressedCodec`] for transparent DEFLATE.
//!   Transports select the codec per [`codec::WireFormat`]; see
//!   `transport` for the plumbing.
//!
//! The legacy builder functions ([`post_aggregate`], [`node_op`],
//! [`post_average`]) remain as thin wrappers over the typed structs for
//! tests and tooling.

pub mod codec;

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

pub use crate::blob::Blob;
use crate::json::Value;

// ---- SAFE controller ops (paper §5.1.3 / Appendix A) ----
pub const POST_AGGREGATE: &str = "/post_aggregate";
pub const CHECK_AGGREGATE: &str = "/check_aggregate";
pub const GET_AGGREGATE: &str = "/get_aggregate";
pub const POST_AVERAGE: &str = "/post_average";
pub const GET_AVERAGE: &str = "/get_average";
pub const SHOULD_INITIATE: &str = "/should_initiate";

// ---- key management (§5.1 Round 0, §5.8) ----
pub const REGISTER_KEY: &str = "/register_key";
pub const GET_KEY: &str = "/get_key";
pub const POST_PRENEG_KEYS: &str = "/post_preneg_keys";
pub const GET_PRENEG_KEY: &str = "/get_preneg_key";

// ---- session management ----
pub const CONFIGURE: &str = "/configure";
pub const BEGIN_ROUND: &str = "/begin_round";
pub const RESET: &str = "/reset";
pub const PROGRESS_CHECK: &str = "/progress_check";
pub const STATUS: &str = "/status";
/// Prometheus scrape endpoint: the controller answers with the session
/// registry's text exposition (over HTTP, served raw with the
/// `text/plain; version=0.0.4` content type; over the in-proc handler,
/// wrapped as the `"text"` field of a status object).
pub const METRICS: &str = "/metrics";

// ---- INSEC baseline ----
pub const INSEC_POST: &str = "/insec/post";
pub const INSEC_GET_AVERAGE: &str = "/insec/get_average";

// ---- BON (Bonawitz et al. 2017) baseline ----
pub const BON_ADVERTISE: &str = "/bon/advertise";
pub const BON_GET_KEYS: &str = "/bon/get_keys";
pub const BON_POST_SHARES: &str = "/bon/post_shares";
pub const BON_GET_SHARES: &str = "/bon/get_shares";
pub const BON_POST_MASKED: &str = "/bon/post_masked";
pub const BON_GET_SURVIVORS: &str = "/bon/get_survivors";
pub const BON_POST_UNMASK: &str = "/bon/post_unmask";
pub const BON_GET_AVERAGE: &str = "/bon/get_average";

// ---- hierarchical federation (§5.10) ----
pub const FED_POST_CHILD_AVERAGE: &str = "/fed/post_child_average";
pub const FED_GET_GLOBAL_AVERAGE: &str = "/fed/get_global_average";

// =====================================================================
// Typed requests
// =====================================================================

/// `post_aggregate(from, to, aggregate)` — park an (opaque, possibly
/// encrypted) aggregate for the next node on the chain.
#[derive(Debug, Clone, PartialEq)]
pub struct PostAggregate {
    pub from_node: u64,
    pub to_node: u64,
    pub group: u64,
    /// Framed envelope bytes (`Envelope::to_blob`) — opaque to the
    /// controller, which stores and forwards the same allocation. Raw on a
    /// binary wire; base64 only at the JSON boundary.
    pub aggregate: Blob,
    /// Round the message belongs to; stale rounds are rejected (§5.4).
    pub round_id: Option<u64>,
    /// Session round-epoch the message belongs to (multi-round engine);
    /// stale epochs are rejected so a straggler from round N can never
    /// pollute round N+1's mailboxes.
    pub epoch: Option<u64>,
    /// Attempt-dedup token: stable across retries of the same logical
    /// post, unique across posts. When a response-leg loss makes the
    /// client resend a post the controller already applied, the token
    /// lets the controller answer `duplicate` instead of double-counting.
    pub token: Option<u64>,
}

impl PostAggregate {
    pub fn to_value(&self) -> Value {
        let mut v = Value::object(vec![
            ("from_node", Value::from(self.from_node)),
            ("to_node", Value::from(self.to_node)),
            ("group", Value::from(self.group)),
            ("aggregate", Value::Bytes(self.aggregate.clone())),
        ]);
        if let Some(r) = self.round_id {
            v.set("round_id", Value::from(r));
        }
        if let Some(e) = self.epoch {
            v.set("epoch", Value::from(e));
        }
        if let Some(t) = self.token {
            v.set("token", Value::from(t));
        }
        v
    }

    pub fn from_value(v: &Value) -> Result<PostAggregate> {
        Ok(PostAggregate {
            from_node: v.u64_of("from_node").context("missing from_node")?,
            to_node: v.u64_of("to_node").context("missing to_node")?,
            group: v.u64_of("group").context("missing group")?,
            aggregate: aggregate_blob(v).context("missing aggregate")?,
            round_id: v.u64_of("round_id"),
            epoch: v.u64_of("epoch"),
            token: v.u64_of("token"),
        })
    }
}

/// `begin_round` — open a new session round-epoch (multi-round engine).
/// Resets every group's transient chain state (mailboxes, check statuses,
/// posters, averages, round ids) and installs the round's chains, while
/// the round-0 key registry, §5.8 pre-negotiated keys, HTTP state and
/// message statistics all survive. `configure` is the heavyweight cousin
/// used at session build; `begin_round` is the per-round reset.
#[derive(Debug, Clone, PartialEq)]
pub struct BeginRound {
    /// Monotonic session round-epoch (posts carrying an older epoch are
    /// rejected as `stale_epoch`).
    pub epoch: u64,
    /// group id → chain order for this round (absent/churned nodes are
    /// simply not listed — chain re-formation).
    pub groups: BTreeMap<u64, Vec<u64>>,
    /// Privacy-floor merging is enabled for this session: a mid-round
    /// floor violation should be answered with a `merge_groups` action
    /// (re-plan next round) rather than `abort_privacy_floor`, as long as
    /// another group exists to merge into.
    pub merge_floor: bool,
    /// The topology plan's per-node merge deltas for this round: every
    /// node aggregating under a group other than its configured home
    /// group. Informational for the controller (surfaced via `/status`);
    /// the re-key traffic these deltas imply is client-driven.
    pub reassigned: Vec<crate::topology::Reassignment>,
    /// This controller is a shard of a sharded plane: its global average
    /// arrives from the fan-in parent (`install_global_average`) instead
    /// of being computed locally, so the §5.5 barrier must not release
    /// `get_average` pollers on its own.
    pub fanin: bool,
    /// Fan-in parent only: the number of shard children expected to post
    /// a `FedChildAverage` this round (resets the federation barrier).
    pub fed_children: Option<u64>,
}

impl BeginRound {
    /// A plain epoch-reset request with no merge metadata (the shape
    /// pre-topology clients send; all optional fields default off).
    pub fn new(epoch: u64, groups: BTreeMap<u64, Vec<u64>>) -> BeginRound {
        BeginRound {
            epoch,
            groups,
            merge_floor: false,
            reassigned: Vec::new(),
            fanin: false,
            fed_children: None,
        }
    }

    pub fn to_value(&self) -> Value {
        let mut groups = Value::obj();
        for (gid, chain) in &self.groups {
            groups.set(
                &gid.to_string(),
                Value::Arr(chain.iter().map(|&n| Value::from(n)).collect()),
            );
        }
        let mut v = Value::object(vec![
            ("epoch", Value::from(self.epoch)),
            ("groups", groups),
            ("merge_floor", Value::from(self.merge_floor)),
        ]);
        if !self.reassigned.is_empty() {
            v.set(
                "reassigned",
                Value::Arr(self.reassigned.iter().map(|r| r.to_value()).collect()),
            );
        }
        if self.fanin {
            v.set("fanin", Value::from(true));
        }
        if let Some(children) = self.fed_children {
            v.set("fed_children", Value::from(children));
        }
        v
    }

    pub fn from_value(v: &Value) -> Result<BeginRound> {
        let epoch = v.u64_of("epoch").context("missing epoch")?;
        let mut groups = BTreeMap::new();
        match v.get("groups") {
            Some(Value::Obj(m)) => {
                for (gid_str, chain_v) in m {
                    let gid: u64 = gid_str.parse().context("bad group id")?;
                    let chain: Vec<u64> = chain_v
                        .as_arr()
                        .context("bad chain")?
                        .iter()
                        .filter_map(|e| e.as_u64())
                        .collect();
                    groups.insert(gid, chain);
                }
            }
            _ => bail!("missing groups"),
        }
        let reassigned = match v.get("reassigned").and_then(|r| r.as_arr()) {
            Some(arr) => arr
                .iter()
                .map(crate::topology::Reassignment::from_value)
                .collect::<Result<Vec<_>>>()?,
            None => Vec::new(),
        };
        Ok(BeginRound {
            epoch,
            groups,
            merge_floor: v.bool_of("merge_floor").unwrap_or(false),
            reassigned,
            fanin: v.bool_of("fanin").unwrap_or(false),
            fed_children: v.u64_of("fed_children"),
        })
    }
}

/// Read an `aggregate` field as a blob. Modern senders put a framed blob
/// here (raw bytes on a binary wire, base64 text on JSON). A legacy
/// paper/PR-1 JSON client instead sends the envelope's
/// `mode:keyB64:bodyB64` text, which is never valid base64 (the colons);
/// fall back to its raw UTF-8 bytes so `Envelope::from_blob` can sniff
/// and parse the text form — old clients keep working against the new
/// controller.
fn aggregate_blob(v: &Value) -> Option<Blob> {
    v.blob_of("aggregate")
        .or_else(|| v.str_of("aggregate").map(|s| Blob::from_slice(s.as_bytes())))
}

/// Render an aggregate blob for a response. The modern framed blob stays
/// an opaque [`Value::Bytes`] (zero-copy); a stored legacy text envelope
/// goes back out as a string so a legacy JSON poller can parse it.
fn aggregate_value(blob: Blob) -> Value {
    if looks_like_text_envelope(blob.as_bytes()) {
        if let Ok(s) = String::from_utf8(blob.as_bytes().to_vec()) {
            return Value::Str(s);
        }
    }
    Value::Bytes(blob)
}

/// Legacy text envelopes start with a mode word and a colon; the binary
/// framing starts with a sub-0x20 tag byte, so the forms cannot collide.
/// The mode words come from [`CipherMode::name`] so this stays in sync
/// with the envelope layer.
fn looks_like_text_envelope(b: &[u8]) -> bool {
    use crate::crypto::envelope::CipherMode;
    [
        CipherMode::None,
        CipherMode::RsaOnly,
        CipherMode::Hybrid,
        CipherMode::PreNegotiated,
    ]
    .iter()
    .any(|m| {
        let name = m.name().as_bytes();
        b.len() > name.len() && b.starts_with(name) && b[name.len()] == b':'
    })
}

/// Node-scoped polling ops (`check_aggregate`, `get_aggregate`,
/// `get_average`, `should_initiate`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeOp {
    pub node: u64,
    pub group: u64,
}

impl NodeOp {
    pub fn new(node: u64, group: u64) -> NodeOp {
        NodeOp { node, group }
    }

    pub fn to_value(&self) -> Value {
        Value::object(vec![
            ("node", Value::from(self.node)),
            ("group", Value::from(self.group)),
        ])
    }

    pub fn from_value(v: &Value) -> Result<NodeOp> {
        Ok(NodeOp {
            node: v.u64_of("node").context("missing node")?,
            group: v.u64_of("group").context("missing group")?,
        })
    }
}

/// `post_average` — the initiator publishes its group's unmasked average.
#[derive(Debug, Clone, PartialEq)]
pub struct PostAverage {
    pub node: u64,
    pub group: u64,
    pub average: Vec<f64>,
    pub contributors: u64,
}

impl PostAverage {
    /// Build the wire body straight from a borrowed average — the hot
    /// path (initiators publish every round) skips the intermediate
    /// `Vec` an owned struct would need.
    pub fn body(node: u64, group: u64, average: &[f64], contributors: u64) -> Value {
        Value::object(vec![
            ("node", Value::from(node)),
            ("group", Value::from(group)),
            ("average", Value::from(average)),
            ("contributors", Value::from(contributors)),
        ])
    }

    pub fn to_value(&self) -> Value {
        Self::body(self.node, self.group, &self.average, self.contributors)
    }

    pub fn from_value(v: &Value) -> Result<PostAverage> {
        Ok(PostAverage {
            node: v.u64_of("node").unwrap_or(0),
            group: v.u64_of("group").unwrap_or(1),
            average: v.f64_arr_of("average").context("missing average")?,
            contributors: v.u64_of("contributors").unwrap_or(0),
        })
    }
}

/// `register_key` — round-0 public key registration.
#[derive(Debug, Clone, PartialEq)]
pub struct RegisterKey {
    pub node: u64,
    /// Serialized public key (opaque JSON object, e.g. RSA `{n, e}`).
    pub key: Value,
}

impl RegisterKey {
    pub fn to_value(&self) -> Value {
        Value::object(vec![
            ("node", Value::from(self.node)),
            ("key", self.key.clone()),
        ])
    }

    pub fn from_value(v: &Value) -> Result<RegisterKey> {
        Ok(RegisterKey {
            node: v.u64_of("node").context("missing node")?,
            key: v.get("key").context("missing key")?.clone(),
        })
    }
}

/// `get_key` — fetch a peer's registered public key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GetKey {
    pub node: u64,
}

impl GetKey {
    pub fn to_value(&self) -> Value {
        Value::object(vec![("node", Value::from(self.node))])
    }

    pub fn from_value(v: &Value) -> Result<GetKey> {
        Ok(GetKey { node: v.u64_of("node").context("missing node")? })
    }
}

/// `post_preneg_keys` (§5.8) — one RSA-sealed symmetric key per peer.
#[derive(Debug, Clone, PartialEq)]
pub struct PostPrenegKeys {
    pub node: u64,
    /// peer node → RSA-sealed key material (raw ciphertext bytes).
    pub keys: BTreeMap<u64, Blob>,
}

impl PostPrenegKeys {
    pub fn to_value(&self) -> Value {
        let mut keys = Value::obj();
        for (peer, blob) in &self.keys {
            keys.set(&peer.to_string(), Value::Bytes(blob.clone()));
        }
        Value::object(vec![("node", Value::from(self.node)), ("keys", keys)])
    }

    pub fn from_value(v: &Value) -> Result<PostPrenegKeys> {
        let node = v.u64_of("node").context("missing node")?;
        let mut keys = BTreeMap::new();
        match v.get("keys") {
            Some(Value::Obj(m)) => {
                for (peer_str, blob) in m {
                    if let (Ok(peer), Some(b)) = (peer_str.parse::<u64>(), blob.as_blob()) {
                        keys.insert(peer, b);
                    }
                }
            }
            _ => bail!("missing keys"),
        }
        Ok(PostPrenegKeys { node, keys })
    }
}

/// `get_preneg_key` — fetch the key `owner` generated for `node`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GetPrenegKey {
    pub node: u64,
    pub owner: u64,
}

impl GetPrenegKey {
    pub fn to_value(&self) -> Value {
        Value::object(vec![
            ("node", Value::from(self.node)),
            ("owner", Value::from(self.owner)),
        ])
    }

    pub fn from_value(v: &Value) -> Result<GetPrenegKey> {
        Ok(GetPrenegKey {
            node: v.u64_of("node").context("missing node")?,
            owner: v.u64_of("owner").context("missing owner")?,
        })
    }
}

/// `insec/post` — the cleartext baseline's vector upload.
#[derive(Debug, Clone, PartialEq)]
pub struct InsecPost {
    pub node: u64,
    pub group: u64,
    pub vector: Vec<f64>,
}

impl InsecPost {
    pub fn to_value(&self) -> Value {
        Value::object(vec![
            ("node", Value::from(self.node)),
            ("group", Value::from(self.group)),
            ("vector", Value::from(&self.vector[..])),
        ])
    }

    pub fn from_value(v: &Value) -> Result<InsecPost> {
        Ok(InsecPost {
            node: v.u64_of("node").context("missing node")?,
            group: v.u64_of("group").context("missing group")?,
            vector: v.f64_arr_of("vector").context("missing vector")?,
        })
    }
}

/// `fed/post_child_average` (§5.10) — a child controller reports upward.
#[derive(Debug, Clone, PartialEq)]
pub struct FedChildAverage {
    pub child: u64,
    pub average: Vec<f64>,
    pub contributors: u64,
}

impl FedChildAverage {
    /// Borrowed-average builder (see [`PostAverage::body`]).
    pub fn body(child: u64, average: &[f64], contributors: u64) -> Value {
        Value::object(vec![
            ("child", Value::from(child)),
            ("average", Value::from(average)),
            ("contributors", Value::from(contributors)),
        ])
    }

    pub fn to_value(&self) -> Value {
        Self::body(self.child, &self.average, self.contributors)
    }

    pub fn from_value(v: &Value) -> Result<FedChildAverage> {
        Ok(FedChildAverage {
            child: v.u64_of("child").context("missing child")?,
            average: v.f64_arr_of("average").context("missing average")?,
            contributors: v.u64_of("contributors").unwrap_or(1),
        })
    }
}

/// `bon/advertise` — a BON participant's two DH public keys (round 0).
#[derive(Debug, Clone, PartialEq)]
pub struct BonAdvertise {
    pub node: u64,
    pub cpk: String,
    pub spk: String,
}

impl BonAdvertise {
    pub fn to_value(&self) -> Value {
        Value::object(vec![
            ("node", Value::from(self.node)),
            ("cpk", Value::from(self.cpk.as_str())),
            ("spk", Value::from(self.spk.as_str())),
        ])
    }

    pub fn from_value(v: &Value) -> Result<BonAdvertise> {
        Ok(BonAdvertise {
            node: v.u64_of("node").context("missing node")?,
            cpk: v.str_of("cpk").context("missing cpk")?.to_string(),
            spk: v.str_of("spk").context("missing spk")?.to_string(),
        })
    }
}

/// `bon/post_masked` — a BON participant's masked input y_u (round 2).
#[derive(Debug, Clone, PartialEq)]
pub struct BonPostMasked {
    pub node: u64,
    pub y: Vec<f64>,
}

impl BonPostMasked {
    pub fn to_value(&self) -> Value {
        Value::object(vec![
            ("node", Value::from(self.node)),
            ("y", Value::from(&self.y[..])),
        ])
    }

    pub fn from_value(v: &Value) -> Result<BonPostMasked> {
        Ok(BonPostMasked {
            node: v.u64_of("node").context("missing node")?,
            y: v.f64_arr_of("y").context("missing y")?,
        })
    }
}

// =====================================================================
// Typed responses
// =====================================================================

/// `get_aggregate` success: the parked aggregate plus chain bookkeeping.
#[derive(Debug, Clone, PartialEq)]
pub struct AggregateDelivery {
    /// The framed envelope, shared with the controller's mailbox — the
    /// same allocation that was posted (zero-copy pass-through).
    pub aggregate: Blob,
    pub from_node: u64,
    /// Distinct posters so far (the contributor count the initiator will
    /// divide by).
    pub posted: Option<u64>,
    pub round_id: Option<u64>,
}

impl AggregateDelivery {
    /// Consuming conversion — moves the sealed aggregate blob into the
    /// response (an `Arc` move, no byte copy). The controller serves one
    /// of these per node per round. A legacy text envelope (stored
    /// verbatim from a paper/PR-1 JSON client) is re-emitted as the text
    /// it arrived as, so legacy pollers can parse what they receive —
    /// compat is symmetric, at the cost of one copy on that path only.
    pub fn into_value(self) -> Value {
        let mut v = Value::object(vec![
            ("status", Value::from("ok")),
            ("aggregate", aggregate_value(self.aggregate)),
            ("from_node", Value::from(self.from_node)),
        ]);
        if let Some(p) = self.posted {
            v.set("posted", Value::from(p));
        }
        if let Some(r) = self.round_id {
            v.set("round_id", Value::from(r));
        }
        v
    }

    pub fn to_value(&self) -> Value {
        self.clone().into_value()
    }

    pub fn from_value(v: &Value) -> Result<AggregateDelivery> {
        Ok(AggregateDelivery {
            aggregate: aggregate_blob(v).context("missing aggregate")?,
            from_node: v.u64_of("from_node").unwrap_or(0),
            posted: v.u64_of("posted"),
            round_id: v.u64_of("round_id"),
        })
    }
}

/// `check_aggregate` non-empty outcomes (§5.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CheckOutcome {
    /// The checked node posted onward — the chain advanced through it.
    Consumed,
    /// The checked node was declared failed; re-encrypt for `to_node` and
    /// repost around it.
    Repost { to_node: u64 },
}

impl CheckOutcome {
    pub fn to_value(&self) -> Value {
        match self {
            CheckOutcome::Consumed => status("consumed"),
            CheckOutcome::Repost { to_node } => Value::object(vec![
                ("status", Value::from("repost")),
                ("to_node", Value::from(*to_node)),
            ]),
        }
    }

    pub fn from_value(v: &Value) -> Result<CheckOutcome> {
        match v.str_of("status") {
            Some("consumed") => Ok(CheckOutcome::Consumed),
            Some("repost") => Ok(CheckOutcome::Repost {
                to_node: v.u64_of("to_node").context("repost response missing to_node")?,
            }),
            other => bail!("unexpected check_aggregate status {:?}", other),
        }
    }
}

/// `get_average` / `insec/get_average` success: the published average.
#[derive(Debug, Clone, PartialEq)]
pub struct AverageReady {
    pub average: Vec<f64>,
    /// Groups folded into the mean (§5.5 barrier).
    pub groups: u64,
}

impl AverageReady {
    /// Consuming conversion — moves the float vector into the response
    /// (the controller serves one per polling learner per round).
    pub fn into_value(self) -> Value {
        Value::object(vec![
            ("status", Value::from("ok")),
            ("average", Value::from(self.average)),
            ("groups", Value::from(self.groups)),
        ])
    }

    pub fn to_value(&self) -> Value {
        self.clone().into_value()
    }

    pub fn from_value(v: &Value) -> Result<AverageReady> {
        Ok(AverageReady {
            average: v.f64_arr_of("average").context("missing average")?,
            groups: v.u64_of("groups").unwrap_or(1),
        })
    }
}

/// `should_initiate` verdict (§5.4 initiator failover election).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InitiateDecision {
    pub init: bool,
    pub round_id: u64,
}

impl InitiateDecision {
    pub fn to_value(&self) -> Value {
        Value::object(vec![
            ("init", Value::from(self.init)),
            ("round_id", Value::from(self.round_id)),
        ])
    }

    pub fn from_value(v: &Value) -> Result<InitiateDecision> {
        Ok(InitiateDecision {
            init: v.bool_of("init").unwrap_or(false),
            round_id: v.u64_of("round_id").unwrap_or(0),
        })
    }
}

/// `get_key` success.
#[derive(Debug, Clone, PartialEq)]
pub struct KeyDelivery {
    pub key: Value,
}

impl KeyDelivery {
    pub fn to_value(&self) -> Value {
        Value::object(vec![("status", Value::from("ok")), ("key", self.key.clone())])
    }

    pub fn from_value(v: &Value) -> Result<KeyDelivery> {
        Ok(KeyDelivery { key: v.get("key").context("peer key missing")?.clone() })
    }
}

/// `get_preneg_key` success.
#[derive(Debug, Clone, PartialEq)]
pub struct PrenegKeyDelivery {
    /// RSA-sealed symmetric key, raw ciphertext bytes.
    pub key: Blob,
}

impl PrenegKeyDelivery {
    pub fn to_value(&self) -> Value {
        Value::object(vec![
            ("status", Value::from("ok")),
            ("key", Value::Bytes(self.key.clone())),
        ])
    }

    pub fn from_value(v: &Value) -> Result<PrenegKeyDelivery> {
        Ok(PrenegKeyDelivery {
            key: v.blob_of("key").context("preneg key missing")?,
        })
    }
}

/// `fed/get_global_average` success (§5.10).
#[derive(Debug, Clone, PartialEq)]
pub struct FedGlobalAverage {
    pub average: Vec<f64>,
    pub contributors: u64,
}

impl FedGlobalAverage {
    /// Consuming conversion — moves the float vector into the response.
    pub fn into_value(self) -> Value {
        Value::object(vec![
            ("status", Value::from("ok")),
            ("average", Value::from(self.average)),
            ("contributors", Value::from(self.contributors)),
        ])
    }

    pub fn to_value(&self) -> Value {
        self.clone().into_value()
    }

    pub fn from_value(v: &Value) -> Result<FedGlobalAverage> {
        Ok(FedGlobalAverage {
            average: v.f64_arr_of("average").context("missing average")?,
            contributors: v.u64_of("contributors").unwrap_or(0),
        })
    }
}

// =====================================================================
// Legacy builders + status helpers
// =====================================================================

/// Body for `post_aggregate(from, to, aggregate)`.
pub fn post_aggregate(from_node: u64, to_node: u64, aggregate: &[u8], group: u64) -> Value {
    PostAggregate {
        from_node,
        to_node,
        group,
        aggregate: Blob::from_slice(aggregate),
        round_id: None,
        epoch: None,
        token: None,
    }
    .to_value()
}

/// Body for the node-scoped polling ops (`check_aggregate`, `get_aggregate`,
/// `get_average`, `should_initiate`).
pub fn node_op(node: u64, group: u64) -> Value {
    NodeOp::new(node, group).to_value()
}

pub fn post_average(node: u64, group: u64, average: &[f64], contributors: u64) -> Value {
    PostAverage::body(node, group, average, contributors)
}

/// Response helpers.
pub fn status(s: &str) -> Value {
    Value::object(vec![("status", Value::from(s))])
}

pub fn is_empty_status(v: &Value) -> bool {
    v.str_of("status") == Some("empty")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bodies_have_expected_fields() {
        let b = post_aggregate(1, 2, b"sealed-bytes", 1);
        assert_eq!(b.u64_of("from_node"), Some(1));
        assert_eq!(b.u64_of("to_node"), Some(2));
        assert_eq!(b.blob_of("aggregate").unwrap().as_bytes(), b"sealed-bytes");
        let n = node_op(7, 2);
        assert_eq!(n.u64_of("node"), Some(7));
        assert_eq!(n.u64_of("group"), Some(2));
        let a = post_average(1, 1, &[1.5, 2.5], 3);
        assert_eq!(a.f64_arr_of("average").unwrap(), vec![1.5, 2.5]);
        assert_eq!(a.u64_of("contributors"), Some(3));
    }

    #[test]
    fn status_helpers() {
        assert!(is_empty_status(&status("empty")));
        assert!(!is_empty_status(&status("consumed")));
    }

    #[test]
    fn typed_messages_roundtrip_via_value() {
        let pa = PostAggregate {
            from_node: 3,
            to_node: 4,
            group: 2,
            aggregate: Blob::from_slice(&[2, 4, 0xde, 0xad, 0xbe, 0xef]),
            round_id: Some(7),
            epoch: Some(2),
            token: Some(0x0030_0001),
        };
        assert_eq!(PostAggregate::from_value(&pa.to_value()).unwrap(), pa);

        let br = BeginRound::new(
            3,
            BTreeMap::from([(1u64, vec![1u64, 3, 5]), (2, vec![2, 4, 6])]),
        );
        assert_eq!(BeginRound::from_value(&br.to_value()).unwrap(), br);
        assert!(BeginRound::from_value(&Value::obj()).is_err());
        // Topology metadata (privacy-floor merges) rides along and
        // roundtrips; absent fields default off for legacy senders.
        let br = BeginRound {
            epoch: 4,
            groups: BTreeMap::from([(1u64, vec![1u64, 2, 3, 5, 6])]),
            merge_floor: true,
            reassigned: vec![
                crate::topology::Reassignment { node: 5, from_group: 2, to_group: 1 },
                crate::topology::Reassignment { node: 6, from_group: 2, to_group: 1 },
            ],
            fanin: true,
            fed_children: Some(2),
        };
        let rt = BeginRound::from_value(&br.to_value()).unwrap();
        assert_eq!(rt, br);
        assert!(rt.merge_floor);
        assert_eq!(rt.reassigned.len(), 2);
        assert!(rt.fanin);
        assert_eq!(rt.fed_children, Some(2));

        let no = NodeOp::new(5, 1);
        assert_eq!(NodeOp::from_value(&no.to_value()).unwrap(), no);

        let pv = PostAverage { node: 1, group: 1, average: vec![0.5, -2.0], contributors: 4 };
        assert_eq!(PostAverage::from_value(&pv.to_value()).unwrap(), pv);

        let del = AggregateDelivery {
            aggregate: Blob::from_slice(b"x"),
            from_node: 2,
            posted: Some(3),
            round_id: Some(0),
        };
        assert_eq!(AggregateDelivery::from_value(&del.to_value()).unwrap(), del);

        let co = CheckOutcome::Repost { to_node: 9 };
        assert_eq!(CheckOutcome::from_value(&co.to_value()).unwrap(), co);
        assert_eq!(
            CheckOutcome::from_value(&CheckOutcome::Consumed.to_value()).unwrap(),
            CheckOutcome::Consumed
        );
        assert!(CheckOutcome::from_value(&status("empty")).is_err());
    }

    #[test]
    fn typed_messages_reject_missing_fields() {
        assert!(PostAggregate::from_value(&Value::obj()).is_err());
        assert!(NodeOp::from_value(&Value::object(vec![("node", Value::from(1u64))])).is_err());
        assert!(PostAverage::from_value(&Value::obj()).is_err());
        assert!(InsecPost::from_value(&Value::obj()).is_err());
        assert!(BonAdvertise::from_value(&Value::obj()).is_err());
    }

    #[test]
    fn legacy_text_envelope_still_accepted_on_the_aggregate_field() {
        // A paper/PR-1 JSON client sends `mode:keyB64:bodyB64` text. The
        // colons make it invalid base64, so the fallback hands the raw
        // text bytes through — and Envelope::from_blob sniffs the text
        // form on the receiving side.
        let body = Value::object(vec![
            ("from_node", Value::from(1u64)),
            ("to_node", Value::from(2u64)),
            ("group", Value::from(1u64)),
            ("aggregate", Value::from("safe:QQ==:Ug==")),
        ]);
        let req = PostAggregate::from_value(&body).unwrap();
        let env = crate::crypto::envelope::Envelope::from_blob(&req.aggregate).unwrap();
        assert_eq!(env.mode, crate::crypto::envelope::CipherMode::Hybrid);
        assert_eq!(env.sealed_key, b"A".to_vec());
        assert_eq!(env.body, b"R".to_vec());
        // And the compat is symmetric: delivering that stored blob back
        // re-emits the text form, which a legacy poller can parse.
        let delivered = AggregateDelivery {
            aggregate: req.aggregate,
            from_node: 1,
            posted: Some(1),
            round_id: None,
        }
        .into_value();
        assert_eq!(delivered.str_of("aggregate"), Some("safe:QQ==:Ug=="));
        // Modern framed blobs stay opaque bytes.
        let modern = AggregateDelivery {
            aggregate: env.to_blob(),
            from_node: 1,
            posted: Some(1),
            round_id: None,
        }
        .into_value();
        assert!(matches!(modern.get("aggregate"), Some(Value::Bytes(_))));
    }

    #[test]
    fn preneg_keys_roundtrip() {
        let mut keys = BTreeMap::new();
        keys.insert(1u64, Blob::from_slice(b"sealed-a"));
        keys.insert(3u64, Blob::from_slice(&[0u8, 1, 254, 255]));
        let pk = PostPrenegKeys { node: 2, keys };
        assert_eq!(PostPrenegKeys::from_value(&pk.to_value()).unwrap(), pk);
    }
}
