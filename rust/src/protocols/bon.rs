//! BON baseline, client side — the full Bonawitz et al. 2017 protocol
//! (see `controller::bon` for the server half and the round summary).
//!
//! Per client u:
//!  * Round 0: generate DH keypairs (c_u, s_u); advertise both publics.
//!  * Round 1: draw self-mask seed b_u; Shamir-share b_u and s_u^SK with
//!    threshold t among all n peers; seal each peer's share pair with the
//!    pairwise channel key KDF(c_u^SK · c_v^PK); route through the server.
//!  * Round 2: post y_u = x_u + PRG(b_u) + Σ_{u<v} PRG(s_{u,v})
//!    − Σ_{v<u} PRG(s_{u,v}).
//!  * Round 3: learn the survivor set; reveal b-shares of survivors and
//!    s^SK-shares of dropped nodes; poll the unmasked average.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::config::{SessionConfig, TransportKind};
use crate::controller::bon::pairwise_seed;
use crate::controller::{Controller, ControllerConfig};
use crate::crypto::dh::{DhGroup, DhKeyPair};
use crate::crypto::rng::{prg_expand_f64, DeterministicRng, SecureRng, SystemRng};
use crate::crypto::shamir;
use crate::crypto::SymmetricKey;
use crate::crypto::{Big, DefaultBig, ModContext};
use crate::json::Value;
use crate::learner::faults::FaultPlan;
use crate::metrics::RoundMetrics;
use crate::proto;
use crate::transport::{ClientTransport, InProcTransport, MessageStats};
use crate::util::Stopwatch;

pub struct BonSession {
    pub cfg: SessionConfig,
    pub controller: Arc<Controller>,
    stats: Arc<MessageStats>,
    group: DhGroup,
}

impl BonSession {
    pub fn new(cfg: SessionConfig) -> Result<BonSession> {
        if !matches!(cfg.transport, TransportKind::InProc) {
            bail!("BonSession currently drives the in-proc transport only");
        }
        let controller = Arc::new(Controller::new(ControllerConfig {
            poll_time: cfg.poll_time,
            bon_round2_timeout: cfg.progress_timeout,
            ..Default::default()
        }));
        let stats = Arc::new(MessageStats::default());
        Ok(BonSession { cfg, controller, stats, group: DhGroup::standard() })
    }

    fn transport(&self) -> Arc<dyn ClientTransport> {
        Arc::new(
            InProcTransport::with_costs(
                self.controller.clone(),
                self.stats.clone(),
                self.cfg.profile.network_hop,
                self.cfg.profile.network_per_kib,
            )
            .with_wire_format(self.cfg.wire),
        )
    }

    pub fn run_round(&self, inputs: &[Vec<f64>], faults: &FaultPlan) -> Result<RoundMetrics> {
        if inputs.len() != self.cfg.n_nodes {
            bail!("need {} inputs", self.cfg.n_nodes);
        }
        let n = self.cfg.n_nodes as u64;
        // Configure expected participant set.
        let setup = self.transport();
        setup.call(
            proto::CONFIGURE,
            &Value::object(vec![
                (
                    "bon_nodes",
                    Value::Arr((1..=n).map(Value::from).collect()),
                ),
                (
                    "bon_round2_timeout_ms",
                    Value::from(self.cfg.progress_timeout.as_millis() as u64),
                ),
            ]),
        )?;
        let threshold = (2 * self.cfg.n_nodes + 2) / 3;

        let baseline = self.stats.total();
        let baseline_bytes = self.stats.bytes();
        let baseline_recv = self.stats.bytes_received();
        let watch = Stopwatch::start();
        let mut handles = Vec::new();
        for node in 1..=n {
            // A node that "fails" in BON completes the share distribution
            // (round 1) but never posts its masked input — the §6.3
            // dropout scenario that triggers mask recovery. NeverStart
            // nodes behave that way too: in BON there is no chain, so the
            // first three rounds are the key exchange being normalized
            // away; dying before round 2 is the comparable failure.
            let dies_before_round2 = faults.point(node).is_some();
            let transport = self.transport();
            let x = inputs[(node - 1) as usize].clone();
            let group = self.group.clone();
            let seed = self.cfg.seed;
            let poll_budget = self.cfg.aggregation_timeout;
            handles.push(std::thread::spawn(move || -> Result<Option<Vec<f64>>> {
                bon_client(
                    node,
                    n,
                    threshold,
                    &x,
                    &group,
                    seed,
                    transport,
                    dies_before_round2,
                    poll_budget,
                )
            }));
        }
        let mut averages = Vec::new();
        for h in handles {
            if let Some(avg) = h.join().map_err(|_| anyhow::anyhow!("bon node panicked"))?? {
                averages.push(avg);
            }
        }
        let wall_time = watch.elapsed();
        if averages.is_empty() {
            bail!("no surviving BON participants");
        }
        let reference = averages[0].clone();
        for a in &averages[1..] {
            for (x, y) in a.iter().zip(&reference) {
                if (x - y).abs() > 1e-9 {
                    bail!("BON participants disagree on the average");
                }
            }
        }
        Ok(RoundMetrics {
            wall_time,
            messages: self.stats.total() - baseline,
            bytes_sent: self.stats.bytes() - baseline_bytes,
            bytes_received: self.stats.bytes_received() - baseline_recv,
            average: reference,
            contributors: averages.len() as u64,
            progress_failovers: faults.failed_count() as u64,
            initiator_failovers: 0,
            rekey_messages: 0,
            merged_groups: 0,
            reassigned_nodes: 0,
            deadline_exceeded: 0,
            net_retries: 0,
            net_drops: 0,
            dedup_posts: 0,
            per_path: Default::default(),
            fanin_messages: 0,
            fanin_latency: std::time::Duration::ZERO,
            shard_messages: vec![],
        })
    }
}

#[allow(clippy::too_many_arguments)]
fn bon_client(
    node: u64,
    n: u64,
    threshold: usize,
    x: &[f64],
    group: &DhGroup,
    seed: Option<u64>,
    transport: Arc<dyn ClientTransport>,
    dies_before_round2: bool,
    poll_budget: Duration,
) -> Result<Option<Vec<f64>>> {
    let mut rng: Box<dyn SecureRng + Send> = match seed {
        Some(s) => Box::new(DeterministicRng::seed(s.wrapping_add(node * 65537))),
        None => Box::new(SystemRng::new()),
    };
    let deadline = std::time::Instant::now() + poll_budget;
    let wait = |path: &str, body: &Value| -> Result<Value> {
        loop {
            let resp = transport.call(path, body)?;
            if !proto::is_empty_status(&resp) {
                return Ok(resp);
            }
            if std::time::Instant::now() > deadline {
                bail!("BON node {node} timed out waiting on {path}");
            }
        }
    };

    // ---- Round 0: advertise DH public keys ----
    // One exponentiation context for the group modulus serves both
    // keygens, all n-1 channel agreements, and all n-1 pairwise-mask
    // exponentiations below.
    let gctx = group.ctx();
    let c_pair = DhKeyPair::generate_with(&gctx, group, rng.as_mut());
    let s_pair = DhKeyPair::generate_with(&gctx, group, rng.as_mut());
    transport.call(
        proto::BON_ADVERTISE,
        &proto::BonAdvertise {
            node,
            cpk: DefaultBig::to_hex(&c_pair.public),
            spk: DefaultBig::to_hex(&s_pair.public),
        }
        .to_value(),
    )?;
    let keys_resp = wait(proto::BON_GET_KEYS, &Value::object(vec![("node", Value::from(node))]))?;
    let keys_obj = keys_resp.get("keys").context("missing keys")?;
    let mut peer_cpk = BTreeMap::new();
    let mut peer_spk = BTreeMap::new();
    for v in 1..=n {
        if v == node {
            continue;
        }
        let entry = keys_obj.get(&v.to_string()).context("peer keys missing")?;
        peer_cpk.insert(v, DefaultBig::from_hex(entry.str_of("cpk").context("cpk")?)?);
        peer_spk.insert(v, DefaultBig::from_hex(entry.str_of("spk").context("spk")?)?);
    }

    // ---- Round 1: Shamir-share b_u and s_u^SK to every peer ----
    let mut b_seed = [0u8; 32];
    rng.fill_bytes(&mut b_seed);
    let xs: Vec<u64> = (1..=n).collect();
    let b_shares = shamir::share_secret(&b_seed, threshold, &xs, rng.as_mut())?;
    let s_sk_bytes = s_pair.secret.to_bytes_be();
    let s_shares = shamir::share_secret(&s_sk_bytes, threshold, &xs, rng.as_mut())?;
    let mut shares_obj = Value::obj();
    for v in 1..=n {
        if v == node {
            continue;
        }
        // Pairwise channel key: KDF(c_v^PK ^ c_u^SK).
        let chan = c_pair.agree_with(&gctx, &peer_cpk[&v]);
        let key = SymmetricKey::from_bytes(&chan)?;
        let payload = Value::object(vec![
            ("b", b_shares[(v - 1) as usize].to_json()),
            ("s", s_shares[(v - 1) as usize].to_json()),
        ])
        .to_string();
        let sealed = key.seal(payload.as_bytes(), rng.as_mut());
        shares_obj.set(&v.to_string(), Value::Bytes(crate::blob::Blob::new(sealed)));
    }
    transport.call(
        proto::BON_POST_SHARES,
        &Value::object(vec![("node", Value::from(node)), ("shares", shares_obj)]),
    )?;
    let got =
        wait(proto::BON_GET_SHARES, &Value::object(vec![("node", Value::from(node))]))?;
    let shares_in = got.get("shares").context("missing shares")?;
    // Decrypt & store the shares peers sent us (for round 3 reveals).
    let mut held_b: BTreeMap<u64, shamir::Share> = BTreeMap::new();
    let mut held_s: BTreeMap<u64, shamir::Share> = BTreeMap::new();
    // Our own shares of our own secrets (index node-1):
    held_b.insert(node, b_shares[(node - 1) as usize].clone());
    held_s.insert(node, s_shares[(node - 1) as usize].clone());
    for v in 1..=n {
        if v == node {
            continue;
        }
        let Some(blob) = shares_in.get(&v.to_string()).and_then(|b| b.as_blob()) else {
            continue;
        };
        let chan = c_pair.agree_with(&gctx, &peer_cpk[&v]);
        let key = SymmetricKey::from_bytes(&chan)?;
        let opened = key.open(blob.as_bytes())?;
        let payload = crate::json::parse(std::str::from_utf8(&opened)?)?;
        held_b.insert(v, shamir::Share::from_json(payload.get("b").context("b share")?)?);
        held_s.insert(v, shamir::Share::from_json(payload.get("s").context("s share")?)?);
    }

    if dies_before_round2 {
        return Ok(None);
    }

    // ---- Round 2: masked input ----
    let feat = x.len();
    let mut y = x.to_vec();
    let self_mask = prg_expand_f64(&b_seed, feat);
    for (a, m) in y.iter_mut().zip(&self_mask) {
        *a += m;
    }
    for v in 1..=n {
        if v == node {
            continue;
        }
        let shared = gctx.modpow(&peer_spk[&v], &s_pair.secret);
        let seed = pairwise_seed(&shared);
        let mask = prg_expand_f64(&seed, feat);
        if node < v {
            for (a, m) in y.iter_mut().zip(&mask) {
                *a += m;
            }
        } else {
            for (a, m) in y.iter_mut().zip(&mask) {
                *a -= m;
            }
        }
    }
    transport.call(proto::BON_POST_MASKED, &proto::BonPostMasked { node, y }.to_value())?;

    // ---- Round 3: unmasking ----
    let surv = wait(proto::BON_GET_SURVIVORS, &Value::object(vec![("node", Value::from(node))]))?;
    let survivors: Vec<u64> = surv
        .get("survivors")
        .and_then(|v| v.as_arr())
        .map(|a| a.iter().filter_map(|v| v.as_u64()).collect())
        .unwrap_or_default();
    let dropped: Vec<u64> = surv
        .get("dropped")
        .and_then(|v| v.as_arr())
        .map(|a| a.iter().filter_map(|v| v.as_u64()).collect())
        .unwrap_or_default();
    let mut b_obj = Value::obj();
    for u in &survivors {
        if let Some(share) = held_b.get(u) {
            b_obj.set(&u.to_string(), share.to_json());
        }
    }
    let mut s_obj = Value::obj();
    for d in &dropped {
        if let Some(share) = held_s.get(d) {
            s_obj.set(&d.to_string(), share.to_json());
        }
    }
    transport.call(
        proto::BON_POST_UNMASK,
        &Value::object(vec![
            ("node", Value::from(node)),
            ("b_shares", b_obj),
            ("s_shares", s_obj),
        ]),
    )?;
    let avg = wait(proto::BON_GET_AVERAGE, &Value::object(vec![("node", Value::from(node))]))?;
    Ok(Some(avg.f64_arr_of("average").context("missing average")?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceProfile;

    fn cfg(n: usize, features: usize) -> SessionConfig {
        SessionConfig {
            n_nodes: n,
            features,
            profile: DeviceProfile::instant(),
            poll_time: Duration::from_millis(200),
            aggregation_timeout: Duration::from_secs(30),
            progress_timeout: Duration::from_millis(700),
            ..Default::default()
        }
    }

    #[test]
    fn bon_full_round_no_failures() {
        let s = BonSession::new(cfg(4, 3)).unwrap();
        let inputs: Vec<Vec<f64>> =
            (1..=4).map(|i| (0..3).map(|f| i as f64 + f as f64).collect()).collect();
        let m = s.run_round(&inputs, &FaultPlan::none()).unwrap();
        assert_eq!(m.contributors, 4);
        let expect = vec![2.5, 3.5, 4.5];
        for (a, e) in m.average.iter().zip(&expect) {
            assert!((a - e).abs() < 1e-6, "{a} vs {e}");
        }
    }

    #[test]
    fn bon_recovers_from_dropout() {
        let s = BonSession::new(cfg(5, 2)).unwrap();
        let inputs: Vec<Vec<f64>> =
            (1..=5).map(|i| vec![i as f64, 2.0 * i as f64]).collect();
        // Node 4 drops after share distribution (the BON dropout case).
        let m = s.run_round(&inputs, &FaultPlan::kill_range(4, 4)).unwrap();
        assert_eq!(m.contributors, 4);
        // Mean over 1,2,3,5.
        let expect = vec![(1.0 + 2.0 + 3.0 + 5.0) / 4.0, (2.0 + 4.0 + 6.0 + 10.0) / 4.0];
        for (a, e) in m.average.iter().zip(&expect) {
            assert!((a - e).abs() < 1e-6, "{a} vs {e}");
        }
    }
}
