//! Hierarchical federation client (§5.10): after a local SAFE aggregation
//! completes, a bridge posts the (already anonymized) child average to a
//! parent controller and fetches the global cross-controller average.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::json::Value;
use crate::proto;
use crate::transport::ClientTransport;

/// Bridge one child controller's result up to the parent.
pub struct FederationBridge {
    pub child_id: u64,
    pub parent: Arc<dyn ClientTransport>,
}

impl FederationBridge {
    pub fn new(child_id: u64, parent: Arc<dyn ClientTransport>) -> Self {
        FederationBridge { child_id, parent }
    }

    /// Post this child's average (cleartext — it is already anonymized
    /// over ≥3 learners) with its contributor weight.
    pub fn post_child_average(&self, average: &[f64], contributors: u64) -> Result<()> {
        let resp = self.parent.call(
            proto::FED_POST_CHILD_AVERAGE,
            &proto::FedChildAverage::body(self.child_id, average, contributors),
        )?;
        if resp.str_of("status") != Some("ok") {
            bail!("parent rejected child average: {resp}");
        }
        Ok(())
    }

    /// Poll the parent for the global average.
    pub fn get_global_average(&self, timeout: Duration) -> Result<(Vec<f64>, u64)> {
        let deadline = Instant::now() + timeout;
        loop {
            let resp = self.parent.call(proto::FED_GET_GLOBAL_AVERAGE, &Value::obj())?;
            if !proto::is_empty_status(&resp) {
                let global = proto::FedGlobalAverage::from_value(&resp)?;
                return Ok((global.average, global.contributors));
            }
            if Instant::now() > deadline {
                bail!("global average not available within {timeout:?}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::{Controller, ControllerConfig};
    use crate::transport::{Handler, InProcTransport};

    #[test]
    fn two_children_federate() {
        let parent = Arc::new(Controller::new(ControllerConfig {
            poll_time: Duration::from_millis(100),
            ..Default::default()
        }));
        parent.handle(
            proto::CONFIGURE,
            &Value::object(vec![("fed_expected_children", Value::from(2u64))]),
        );
        let t1: Arc<dyn ClientTransport> = Arc::new(InProcTransport::new(parent.clone()));
        let t2: Arc<dyn ClientTransport> = Arc::new(InProcTransport::new(parent.clone()));
        let b1 = FederationBridge::new(1, t1);
        let b2 = FederationBridge::new(2, t2);
        b1.post_child_average(&[10.0], 4).unwrap();
        b2.post_child_average(&[20.0], 6).unwrap();
        let (avg, total) = b1.get_global_average(Duration::from_secs(2)).unwrap();
        assert_eq!(total, 10);
        assert!((avg[0] - 16.0).abs() < 1e-12); // (10*4 + 20*6)/10
    }
}
