//! Hierarchical federation client (§5.10): after a local SAFE aggregation
//! completes, a bridge posts the (already anonymized) child average to a
//! parent controller and fetches the global cross-controller average.
//!
//! The sharded aggregation plane runs one bridge per shard as its fan-in
//! worker: post the shard partial (1 message), long-poll the combined
//! global (1 message), install it back on the shard. Against an in-proc
//! parent the fetch is a completion-style long-poll — `submit` parks on
//! [`PollKey::FedGlobal`](crate::transport::PollKey) in the parent's
//! [`WaitHub`] and a condvar wait replaces the old sleep-poll loop, so
//! the fan-in tier costs exactly one request/response per shard per
//! round, fully accounted in [`MessageStats`](crate::transport::MessageStats).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::json::Value;
use crate::proto;
use crate::transport::{ClientTransport, InProcTransport, Submitted, WaitHub, WakeSink};

/// Condvar-backed [`WakeSink`]: the fan-in workers' side of the parent's
/// [`WaitHub`]. Each blocked `get_global_average` registers a waiter id;
/// a hub wake flips its flag and notifies the parked worker thread —
/// completion-style delivery without an event executor in the loop.
#[derive(Default)]
pub struct FanInWaiters {
    waiters: Mutex<BTreeMap<u64, Arc<(Mutex<bool>, Condvar)>>>,
    next_id: AtomicU64,
}

impl FanInWaiters {
    /// Allocate a waiter slot. The caller must `remove` it when done.
    fn register(&self) -> (u64, Arc<(Mutex<bool>, Condvar)>) {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let waiter = Arc::new((Mutex::new(false), Condvar::new()));
        self.waiters.lock().unwrap().insert(id, waiter.clone());
        (id, waiter)
    }

    fn remove(&self, id: u64) {
        self.waiters.lock().unwrap().remove(&id);
    }
}

impl WakeSink for FanInWaiters {
    fn wake(&self, task: u64, _generation: u64) {
        // Waiters re-probe after waking, so a stale generation is
        // harmless — the probe just parks again.
        if let Some(w) = self.waiters.lock().unwrap().get(&task).cloned() {
            *w.0.lock().unwrap() = true;
            w.1.notify_all();
        }
    }
}

/// The completion-style path to an in-proc parent: a transport with a
/// non-blocking handler attached, the parent's wait hub, and the shared
/// waiter registry installed as that hub's sink.
struct FanInCompletion {
    transport: Arc<InProcTransport>,
    hub: Arc<WaitHub>,
    waiters: Arc<FanInWaiters>,
}

/// Bridge one child controller's result up to the parent.
pub struct FederationBridge {
    pub child_id: u64,
    pub parent: Arc<dyn ClientTransport>,
    completion: Option<FanInCompletion>,
}

impl FederationBridge {
    /// Bridge over a plain transport (e.g. HTTP): `get_global_average`
    /// falls back to repeated server-side long-polls.
    pub fn new(child_id: u64, parent: Arc<dyn ClientTransport>) -> Self {
        FederationBridge { child_id, parent, completion: None }
    }

    /// Bridge over an in-proc parent in completion style: one submitted
    /// fetch parks on the parent's `hub` until the fan-in barrier wakes
    /// it. `waiters` must be installed as `hub`'s sink (shared by every
    /// shard's bridge).
    pub fn over_completion(
        child_id: u64,
        transport: Arc<InProcTransport>,
        hub: Arc<WaitHub>,
        waiters: Arc<FanInWaiters>,
    ) -> Self {
        FederationBridge {
            child_id,
            parent: transport.clone(),
            completion: Some(FanInCompletion { transport, hub, waiters }),
        }
    }

    /// Post this child's average (cleartext — it is already anonymized
    /// over ≥3 learners) with its contributor weight.
    pub fn post_child_average(&self, average: &[f64], contributors: u64) -> Result<()> {
        let resp = self.parent.call(
            proto::FED_POST_CHILD_AVERAGE,
            &proto::FedChildAverage::body(self.child_id, average, contributors),
        )?;
        if resp.str_of("status") != Some("ok") {
            bail!("parent rejected child average: {resp}");
        }
        Ok(())
    }

    /// Fetch the global average, waiting up to `timeout`; errors if the
    /// fan-in barrier does not complete in time.
    pub fn get_global_average(&self, timeout: Duration) -> Result<(Vec<f64>, u64)> {
        match self.try_get_global_average(timeout)? {
            Some(global) => Ok(global),
            None => bail!("global average not available within {timeout:?}"),
        }
    }

    /// Fetch the global average, waiting up to `timeout`; `None` when the
    /// barrier did not complete (the caller may degrade to
    /// [`FederationBridge::get_partial_global`]).
    pub fn try_get_global_average(
        &self,
        timeout: Duration,
    ) -> Result<Option<(Vec<f64>, u64)>> {
        if let Some(c) = &self.completion {
            return self.wait_completion(c, timeout);
        }
        // Blocking fallback: each iteration is one server-side long-poll
        // (the parent parks up to its poll_time before answering empty).
        let deadline = Instant::now() + timeout;
        loop {
            let resp = self.parent.call(proto::FED_GET_GLOBAL_AVERAGE, &Value::obj())?;
            if !proto::is_empty_status(&resp) {
                let global = proto::FedGlobalAverage::from_value(&resp)?;
                return Ok(Some((global.average, global.contributors)));
            }
            if Instant::now() > deadline {
                return Ok(None);
            }
        }
    }

    /// One submitted request, completed by a hub wake: no polling between
    /// submission and the barrier completing (or the deadline passing, in
    /// which case the pending request is closed with the same accounted
    /// empty response a blocking poll timeout produces).
    fn wait_completion(
        &self,
        c: &FanInCompletion,
        timeout: Duration,
    ) -> Result<Option<(Vec<f64>, u64)>> {
        let path = proto::FED_GET_GLOBAL_AVERAGE;
        let body = Value::obj();
        let deadline = Instant::now() + timeout;
        let key = match c.transport.submit(path, &body)? {
            Submitted::Ready(resp) => return Ok(Some(Self::parse_global(&resp)?)),
            Submitted::Pending(key) => key,
        };
        let (id, waiter) = c.waiters.register();
        let result = loop {
            // (Re-)register, then re-probe to close the lost-wakeup race:
            // the barrier may have completed between probe and register.
            c.hub.register(key, id, 0);
            if let Some(resp) = c.transport.try_complete(path, &body)? {
                break Some(resp);
            }
            let (lock, cv) = &*waiter;
            let mut woken = lock.lock().unwrap();
            let timed_out = loop {
                if *woken {
                    // Consume the wake; the outer loop re-probes (a stale
                    // wake — e.g. a round reset's wake_all — parks again).
                    *woken = false;
                    break false;
                }
                let now = Instant::now();
                if now >= deadline {
                    break true;
                }
                let (g, _) = cv.wait_timeout(woken, deadline - now).unwrap();
                woken = g;
            };
            if timed_out {
                break None;
            }
        };
        c.waiters.remove(id);
        match result {
            Some(resp) => Ok(Some(Self::parse_global(&resp)?)),
            None => {
                // Deadline: close the pending request with the accounted
                // empty response, same as a blocking poll timing out.
                let _ = c.transport.complete_empty(path)?;
                Ok(None)
            }
        }
    }

    /// Degraded fetch after a fan-in timeout: the combine over whichever
    /// children have posted (`None` when no child posted at all). The
    /// extra message only happens on degraded rounds.
    pub fn get_partial_global(&self) -> Result<Option<(Vec<f64>, u64)>> {
        let body = Value::object(vec![("partial", Value::from(true))]);
        let resp = self.parent.call(proto::FED_GET_GLOBAL_AVERAGE, &body)?;
        if proto::is_empty_status(&resp) {
            return Ok(None);
        }
        Ok(Some(Self::parse_global(&resp)?))
    }

    fn parse_global(resp: &Value) -> Result<(Vec<f64>, u64)> {
        let global = proto::FedGlobalAverage::from_value(resp)?;
        Ok((global.average, global.contributors))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::{Controller, ControllerConfig};
    use crate::transport::{Handler, MessageStats};

    fn parent_controller(children: u64) -> Arc<Controller> {
        let parent = Arc::new(Controller::new(ControllerConfig {
            poll_time: Duration::from_millis(100),
            ..Default::default()
        }));
        parent.handle(
            proto::CONFIGURE,
            &Value::object(vec![("fed_expected_children", Value::from(children))]),
        );
        parent
    }

    #[test]
    fn two_children_federate() {
        let parent = parent_controller(2);
        let t1: Arc<dyn ClientTransport> = Arc::new(InProcTransport::new(parent.clone()));
        let t2: Arc<dyn ClientTransport> = Arc::new(InProcTransport::new(parent.clone()));
        let b1 = FederationBridge::new(1, t1);
        let b2 = FederationBridge::new(2, t2);
        b1.post_child_average(&[10.0], 4).unwrap();
        b2.post_child_average(&[20.0], 6).unwrap();
        let (avg, total) = b1.get_global_average(Duration::from_secs(2)).unwrap();
        assert_eq!(total, 10);
        assert!((avg[0] - 16.0).abs() < 1e-12); // (10*4 + 20*6)/10
    }

    #[test]
    fn completion_long_poll_wakes_without_polling() {
        let parent = parent_controller(2);
        let stats = Arc::new(MessageStats::default());
        let hub = parent.wait_hub();
        let waiters = Arc::new(FanInWaiters::default());
        hub.set_sink(waiters.clone());
        let transport = |p: &Arc<Controller>| {
            Arc::new(
                InProcTransport::with_shared_stats(
                    p.clone(),
                    stats.clone(),
                    Duration::ZERO,
                )
                .with_completion(p.clone()),
            )
        };
        let b1 = FederationBridge::over_completion(
            1,
            transport(&parent),
            hub.clone(),
            waiters.clone(),
        );
        let b2 = FederationBridge::over_completion(2, transport(&parent), hub, waiters);
        b1.post_child_average(&[10.0], 4).unwrap();
        let fetcher = std::thread::spawn(move || {
            // Parked well past the parent's poll_time: a sleep-poll loop
            // would need several messages; the completion path uses one.
            b1.get_global_average(Duration::from_secs(5)).unwrap()
        });
        std::thread::sleep(Duration::from_millis(250));
        b2.post_child_average(&[20.0], 6).unwrap();
        let (avg, total) = fetcher.join().unwrap();
        assert_eq!(total, 10);
        assert!((avg[0] - 16.0).abs() < 1e-12);
        // Exactly 3 requests crossed the wire: two posts + ONE fetch.
        let per_path = stats.per_path();
        assert_eq!(per_path.get(proto::FED_POST_CHILD_AVERAGE), Some(&2));
        assert_eq!(per_path.get(proto::FED_GET_GLOBAL_AVERAGE), Some(&1));
        // And its response bytes were accounted like any other path.
        let fetch = &stats.per_path_stats()[proto::FED_GET_GLOBAL_AVERAGE];
        assert!(fetch.bytes_sent > 0 && fetch.bytes_received > 0);
    }

    #[test]
    fn completion_timeout_degrades_to_partial() {
        // Expected 2 children but only one posts (a dead shard): the
        // completion fetch times out with an accounted empty response and
        // the partial fetch serves the degraded combine.
        let parent = parent_controller(2);
        let stats = Arc::new(MessageStats::default());
        let hub = parent.wait_hub();
        let waiters = Arc::new(FanInWaiters::default());
        hub.set_sink(waiters.clone());
        let t = Arc::new(
            InProcTransport::with_shared_stats(parent.clone(), stats.clone(), Duration::ZERO)
                .with_completion(parent.clone()),
        );
        let b = FederationBridge::over_completion(1, t, hub, waiters);
        b.post_child_average(&[10.0], 4).unwrap();
        let start = Instant::now();
        let got = b.try_get_global_average(Duration::from_millis(200)).unwrap();
        assert!(got.is_none(), "barrier cannot complete with a dead shard");
        assert!(start.elapsed() >= Duration::from_millis(200));
        let (avg, total) = b.get_partial_global().unwrap().unwrap();
        assert_eq!(total, 4);
        assert!((avg[0] - 10.0).abs() < 1e-12);
        // One post + one (timed-out) fetch + one partial fetch.
        assert_eq!(stats.per_path().get(proto::FED_GET_GLOBAL_AVERAGE), Some(&2));
    }
}
