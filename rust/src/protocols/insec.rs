//! INSEC baseline session driver (§6): every node posts its cleartext
//! vector to the controller and polls for the average — 2 messages per
//! node, no privacy.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::{SessionConfig, TransportKind};
use crate::controller::{Controller, ControllerConfig};
use crate::json::Value;
use crate::learner::faults::{FailPoint, FaultPlan};
use crate::metrics::RoundMetrics;
use crate::proto;
use crate::topology::GroupPlanner;
use crate::transport::{ClientTransport, InProcTransport, MessageStats};
use crate::util::Stopwatch;

pub struct InsecSession {
    pub cfg: SessionConfig,
    pub controller: Arc<Controller>,
    stats: Arc<MessageStats>,
}

impl InsecSession {
    pub fn new(cfg: SessionConfig) -> Result<InsecSession> {
        if !matches!(cfg.transport, TransportKind::InProc) {
            bail!("InsecSession currently drives the in-proc transport only");
        }
        let controller = Arc::new(Controller::new(ControllerConfig {
            poll_time: cfg.poll_time,
            ..Default::default()
        }));
        let stats = Arc::new(MessageStats::default());
        Ok(InsecSession { cfg, controller, stats })
    }

    fn transport(&self) -> Arc<dyn ClientTransport> {
        Arc::new(
            InProcTransport::with_costs(
                self.controller.clone(),
                self.stats.clone(),
                self.cfg.profile.network_hop,
                self.cfg.profile.network_per_kib,
            )
            .with_wire_format(self.cfg.wire),
        )
    }

    pub fn run_round(&self, inputs: &[Vec<f64>], faults: &FaultPlan) -> Result<RoundMetrics> {
        if inputs.len() != self.cfg.n_nodes {
            bail!("need {} inputs", self.cfg.n_nodes);
        }
        // (Re)configure groups — resets insec state for the round. INSEC
        // has no privacy floor (it is the no-privacy baseline), so the
        // planner's configured base plan is used as-is.
        let plan = GroupPlanner::from_config(&self.cfg).base_plan();
        let chains = plan.groups().to_vec();
        let mut groups_obj = Value::obj();
        for (gid, chain) in &chains {
            groups_obj.set(
                &gid.to_string(),
                Value::Arr(chain.iter().map(|&n| Value::from(n)).collect()),
            );
        }
        let setup = self.transport();
        setup.call(proto::CONFIGURE, &Value::object(vec![("groups", groups_obj.clone())]))?;
        // INSEC has no failover: a dead node means the controller waits
        // forever, so the expected count must exclude planned failures
        // (the paper normalizes the same way in §6.3).
        if faults.failed_count() > 0 {
            let mut inner = self.controller.inner.lock().unwrap();
            for (gid, chain) in &chains {
                let alive = chain.iter().filter(|n| faults.point(**n).is_none()).count();
                inner.insec.configure_group(*gid, alive);
            }
        }

        let baseline = self.stats.total();
        let baseline_bytes = self.stats.bytes();
        let baseline_recv = self.stats.bytes_received();
        let watch = Stopwatch::start();
        let mut handles = Vec::new();
        for (gid, chain) in &chains {
            for &node in chain {
                if faults.fails_at(node, FailPoint::NeverStart) {
                    continue;
                }
                let transport = self.transport();
                let vector = inputs[(node - 1) as usize].clone();
                let gid = *gid;
                let poll_deadline = self.cfg.aggregation_timeout;
                handles.push(std::thread::spawn(move || -> Result<Vec<f64>> {
                    transport.call(
                        proto::INSEC_POST,
                        &proto::InsecPost { node, group: gid, vector }.to_value(),
                    )?;
                    let deadline = std::time::Instant::now() + poll_deadline;
                    loop {
                        let resp = transport.call(proto::INSEC_GET_AVERAGE, &Value::obj())?;
                        if !proto::is_empty_status(&resp) {
                            return Ok(proto::AverageReady::from_value(&resp)?.average);
                        }
                        if std::time::Instant::now() > deadline {
                            bail!("INSEC aggregation timed out");
                        }
                    }
                }));
            }
        }
        let mut averages = Vec::new();
        for h in handles {
            averages.push(h.join().map_err(|_| anyhow::anyhow!("insec node panicked"))??);
        }
        let wall_time = watch.elapsed();
        let reference = averages[0].clone();
        for a in &averages {
            if a != &reference {
                bail!("INSEC nodes disagree on the average");
            }
        }
        Ok(RoundMetrics {
            wall_time,
            messages: self.stats.total() - baseline,
            bytes_sent: self.stats.bytes() - baseline_bytes,
            bytes_received: self.stats.bytes_received() - baseline_recv,
            average: reference,
            contributors: averages.len() as u64,
            progress_failovers: 0,
            initiator_failovers: 0,
            rekey_messages: 0,
            merged_groups: 0,
            reassigned_nodes: 0,
            deadline_exceeded: 0,
            net_retries: 0,
            net_drops: 0,
            dedup_posts: 0,
            per_path: Default::default(),
            fanin_messages: 0,
            fanin_latency: std::time::Duration::ZERO,
            shard_messages: vec![],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceProfile;
    use std::time::Duration;

    fn cfg(n: usize, features: usize) -> SessionConfig {
        SessionConfig {
            n_nodes: n,
            features,
            profile: DeviceProfile::instant(),
            poll_time: Duration::from_millis(200),
            aggregation_timeout: Duration::from_secs(5),
            ..Default::default()
        }
    }

    #[test]
    fn insec_basic_average() {
        let s = InsecSession::new(cfg(4, 2)).unwrap();
        let inputs: Vec<Vec<f64>> =
            (1..=4).map(|i| vec![i as f64, 10.0 * i as f64]).collect();
        let m = s.run_round(&inputs, &FaultPlan::none()).unwrap();
        assert_eq!(m.average, vec![2.5, 25.0]);
        assert_eq!(m.contributors, 4);
        // 2 messages per node when polls don't retry.
        assert!(m.messages >= 8);
    }

    #[test]
    fn insec_with_failed_nodes_normalized() {
        let s = InsecSession::new(cfg(5, 1)).unwrap();
        let inputs: Vec<Vec<f64>> = (1..=5).map(|i| vec![i as f64]).collect();
        let m = s.run_round(&inputs, &FaultPlan::kill_range(4, 5)).unwrap();
        assert_eq!(m.contributors, 3);
        assert_eq!(m.average, vec![2.0]); // mean of 1,2,3
    }
}
