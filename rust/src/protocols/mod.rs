//! Protocol session drivers: one module per protocol the paper evaluates.
//!
//! * [`safe`] — the paper's contribution (chain aggregation, §5), covering
//!   SAF (no encryption), SAFE (hybrid encryption), RSA-only and §5.8
//!   pre-negotiated variants via [`crate::crypto::CipherMode`].
//! * [`insec`] — the cleartext post-to-controller baseline (§6).
//! * [`bon`] — Bonawitz et al. 2017 secure aggregation (client side; the
//!   server half lives in `controller::bon`).
//! * [`hierarchy`] — §5.10 child→parent controller bridging.
//! * [`weighted`] — §5.6 weighted-averaging vector encoding helpers.

pub mod bon;
pub mod hierarchy;
pub mod insec;
pub mod safe;
pub mod weighted;

pub use safe::{SafeRoundResult, SafeSession};
