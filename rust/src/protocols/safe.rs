//! SAFE session driver: builds the deployment (controller + learners +
//! monitor), performs round 0 (key exchange, §5.1 / pre-negotiation §5.8)
//! and runs aggregation rounds, measuring the paper's metrics.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};
use once_cell::sync::Lazy;

use crate::config::{RuntimeKind, SessionConfig, TransportKind, VectorEngine};
use crate::controller::{Controller, ControllerConfig};
use crate::protocols::hierarchy::{FanInWaiters, FederationBridge};
use crate::crypto::envelope::CipherMode;
use crate::crypto::rng::{DeterministicRng, SecureRng, SystemRng};
use crate::crypto::rsa::{RsaKeyPair, RsaPublicKey};
use crate::crypto::SymmetricKey;
use crate::json::Value;
use crate::learner::actor::LearnerActor;
use crate::learner::faults::{ChurnSchedule, FaultPlan};
use crate::learner::{LearnerContext, LearnerOutcome};
use crate::metrics::{RoundMetrics, SessionMetrics};
use crate::monitor::ProgressMonitor;
use crate::proto;
use crate::runtime::vector::{NativeMath, VectorMath};
use crate::runtime::{ArtifactRuntime, XlaMath};
use crate::runtime_exec::{EventExecutor, ExecutorConfig};
use crate::topology::{GroupPlanner, TopologyPlan};
use crate::transport::http::{HttpServer, HttpTransport};
use crate::transport::{ClientTransport, InProcTransport, MessageStats, NetFaults};
use crate::util::Stopwatch;

/// RSA keygen is the expensive part of round 0; benches re-create sessions
/// hundreds of times, so deterministic keypairs are cached process-wide
/// (sound: generation is a pure function of (seed, node, bits)).
static KEY_CACHE: Lazy<Mutex<BTreeMap<(u64, u64, usize), RsaKeyPair>>> =
    Lazy::new(|| Mutex::new(BTreeMap::new()));

pub fn keypair_for(seed: Option<u64>, node: u64, bits: usize) -> RsaKeyPair {
    match seed {
        Some(seed) => {
            let key = (seed, node, bits);
            let mut cache = KEY_CACHE.lock().unwrap();
            if let Some(kp) = cache.get(&key) {
                return kp.clone();
            }
            let mut rng = DeterministicRng::seed(seed ^ (node.wrapping_mul(0x9e3779b97f4a7c15)));
            let kp = RsaKeyPair::generate(bits, &mut rng);
            cache.insert(key, kp.clone());
            kp
        }
        None => {
            let mut rng = SystemRng::new();
            RsaKeyPair::generate(bits, &mut rng)
        }
    }
}

/// One fully-wired SAFE deployment.
pub struct SafeSession {
    pub cfg: SessionConfig,
    /// Shard 0's controller — *the* controller on an unsharded plane
    /// (`--shards 1`, the default), kept as a public field for tests and
    /// tooling that poke broker state directly.
    pub controller: Arc<Controller>,
    /// The aggregation plane: K shard controllers (`--shards K`), each a
    /// full message broker for its groups' chains, mailboxes and epoch
    /// state. Length 1 (aliasing `controller`) on an unsharded plane.
    shards: Vec<Arc<Controller>>,
    /// The fan-in tier (K > 1 only): a parent controller owning the key
    /// registry and combining contributor-weighted shard partials into
    /// the global average (§5.10 generalized).
    parent: Option<Arc<Controller>>,
    /// The topology subsystem: owns membership and produces one immutable
    /// [`TopologyPlan`] per round (chain re-formation, per-round
    /// permutation, privacy-floor merge re-balancing, shard assignment).
    planner: GroupPlanner,
    stats: Arc<MessageStats>,
    /// Per-shard learner-path counters (K > 1 only): chain traffic lands
    /// here while key-plane/monitor/fan-in traffic stays on the session
    /// counter; metrics sum both views. Empty when K = 1 so the single-
    /// shard wiring (and its message accounting) is untouched.
    shard_stats: Vec<Arc<MessageStats>>,
    /// Master per-node contexts: the long-lived key material and transport
    /// of every configured learner. Behind a mutex because a rejoin
    /// re-keys (replaces) individual entries mid-`run_rounds`; per-round
    /// views are cheap forks of these masters.
    contexts: Mutex<BTreeMap<u64, Arc<LearnerContext>>>,
    /// The worker-pool event runtime (`--runtime events`, the default for
    /// in-proc sessions). `None` under `--runtime threads` or an HTTP
    /// transport, where `run_rounds` falls back to thread-per-learner
    /// actors.
    executor: Option<Arc<EventExecutor>>,
    /// One monitor transport per shard (a single one when K = 1); also
    /// carries the per-round `begin_round` to its shard.
    monitor_transports: Vec<Arc<dyn ClientTransport>>,
    /// Session-counted transport to the fan-in parent (K > 1 only), for
    /// the per-round parent epoch reset.
    parent_transport: Option<Arc<dyn ClientTransport>>,
    /// Cached per-shard learner transports (K > 1 only): thread-runtime
    /// round forks route chain ops through their home shard here.
    shard_transports: Vec<Arc<dyn ClientTransport>>,
    /// One fan-in bridge per shard (K > 1 only), completion-wired to the
    /// parent: post the shard partial, long-poll the combined global.
    fanin_bridges: Vec<Arc<FederationBridge>>,
    /// Keep the loopback HTTP server alive for HTTP transport sessions.
    _http_server: Option<HttpServer>,
    /// Messages spent on round 0 (key exchange) — reported separately,
    /// like the paper (footnote 3: key exchange is not per-aggregation).
    pub round0_messages: u64,
    /// Aggregation rounds run so far (drives per-round chain shuffling).
    rounds_run: std::sync::atomic::AtomicU64,
    /// The observability plane: one registry serving every controller's
    /// `GET /metrics`, fed by scrape-time `MessageStats` mirrors,
    /// transport latency recorders, and per-round event pushes.
    metrics: Arc<SessionMetrics>,
}

/// Outcome of one aggregation round across all learners.
#[derive(Debug)]
pub struct SafeRoundResult {
    pub metrics: RoundMetrics,
    pub outcomes: Vec<LearnerOutcome>,
}

impl SafeRoundResult {
    /// The agreed average (validated identical across survivors), or
    /// `None` when every learner died — reachable via [`FaultPlan`], so
    /// callers must not assume a survivor exists.
    pub fn average(&self) -> Option<&[f64]> {
        self.outcomes
            .iter()
            .find(|o| !o.died)
            .map(|o| o.average.as_slice())
    }

    pub fn survivors(&self) -> Vec<&LearnerOutcome> {
        self.outcomes.iter().filter(|o| !o.died).collect()
    }
}

impl SafeSession {
    /// Session-wide message statistics: every message when K = 1; the
    /// key-plane/monitor/fan-in share when sharded (per-shard learner
    /// counters are summed into [`RoundMetrics`] separately). HTTP clients
    /// keep their own counters.
    pub fn stats(&self) -> Arc<MessageStats> {
        self.stats.clone()
    }

    /// Width of the aggregation plane (the `--shards` flag clamped to the
    /// configured group count).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The session's observability plane: the metric registry every
    /// controller's `GET /metrics` endpoint renders, plus the recording
    /// handles the engine pushes round events through.
    pub fn session_metrics(&self) -> &Arc<SessionMetrics> {
        &self.metrics
    }

    /// Every message counter the registry mirrors, under its mirror
    /// label: the session-wide counter as `"parent"` when K > 1 (it then
    /// carries the key-plane/monitor/fan-in share) or `"0"` on a
    /// single-shard plane, plus each shard's learner-path counter under
    /// its shard id. The reconciliation tests walk this list to hold the
    /// scraped `safe_requests_total`/byte series bit-for-bit equal to
    /// the accounting the formula tests pin.
    pub fn stats_by_mirror_label(&self) -> Vec<(String, Arc<MessageStats>)> {
        let session_label = if self.shards.len() > 1 { "parent" } else { "0" };
        let mut out = vec![(session_label.to_string(), self.stats.clone())];
        for (i, s) in self.shard_stats.iter().enumerate() {
            out.push((i.to_string(), s.clone()));
        }
        out
    }

    /// The plane's scrape targets: every shard controller labeled by its
    /// shard id, plus the fan-in parent (K > 1 only) labeled `"parent"`.
    /// Each serves the same session-wide registry on `GET /metrics`;
    /// series are distinguished by their `shard` label, not by which
    /// controller rendered them.
    pub fn plane_controllers(&self) -> Vec<(String, Arc<Controller>)> {
        let mut out: Vec<(String, Arc<Controller>)> = self
            .shards
            .iter()
            .enumerate()
            .map(|(i, c)| (i.to_string(), c.clone()))
            .collect();
        if let Some(p) = &self.parent {
            out.push(("parent".to_string(), p.clone()));
        }
        out
    }

    // Session-wide rollups: the shared counter plus every per-shard
    // counter. When K = 1 the shard list is empty, so each of these is
    // exactly the old single-counter read.
    fn total_messages(&self) -> u64 {
        self.stats.total() + self.shard_stats.iter().map(|s| s.total()).sum::<u64>()
    }

    fn total_bytes(&self) -> u64 {
        self.stats.bytes() + self.shard_stats.iter().map(|s| s.bytes()).sum::<u64>()
    }

    fn total_bytes_received(&self) -> u64 {
        self.stats.bytes_received()
            + self.shard_stats.iter().map(|s| s.bytes_received()).sum::<u64>()
    }

    fn total_retries(&self) -> u64 {
        self.stats.retries() + self.shard_stats.iter().map(|s| s.retries()).sum::<u64>()
    }

    fn total_drops(&self) -> u64 {
        self.stats.drops() + self.shard_stats.iter().map(|s| s.drops()).sum::<u64>()
    }

    fn total_dedup(&self) -> u64 {
        self.stats.dedup_posts()
            + self.shard_stats.iter().map(|s| s.dedup_posts()).sum::<u64>()
    }

    /// Per-path counts merged across the shared and per-shard counters.
    fn merged_per_path(&self) -> BTreeMap<String, u64> {
        let mut merged = self.stats.per_path();
        for s in &self.shard_stats {
            for (k, v) in s.per_path() {
                *merged.entry(k).or_insert(0) += v;
            }
        }
        merged
    }

    /// Build the deployment and run round 0 (key exchange).
    pub fn new(cfg: SessionConfig) -> Result<SafeSession> {
        let ctrl_cfg = ControllerConfig {
            poll_time: cfg.poll_time,
            aggregation_timeout: cfg.aggregation_timeout,
            progress_timeout: cfg.progress_timeout,
            bon_round2_timeout: cfg.progress_timeout,
        };
        // The topology subsystem fixes the effective plane width K up
        // front (`--shards` clamped to the configured group count — a
        // shard with no groups would idle forever).
        let planner = GroupPlanner::from_config(&cfg);
        let shard_count = planner.shards();
        if shard_count > 1 && !matches!(cfg.transport, TransportKind::InProc) {
            bail!(
                "--shards {shard_count} requires the in-proc transport \
                 (an HTTP deployment serves a single controller)"
            );
        }
        // The aggregation plane: K shard controllers plus (K > 1) one
        // fan-in parent owning the key registry and the cross-shard
        // combine. K = 1 is exactly the single-controller deployment.
        let shards: Vec<Arc<Controller>> = (0..shard_count)
            .map(|_| Arc::new(Controller::new(ctrl_cfg.clone())))
            .collect();
        let controller = shards[0].clone();
        let parent: Option<Arc<Controller>> =
            (shard_count > 1).then(|| Arc::new(Controller::new(ctrl_cfg.clone())));
        // Key-plane ops (round 0 + rekey) go to the parent when sharded —
        // one registry serves every shard — and to the controller itself
        // otherwise.
        let key_plane: Arc<Controller> = parent.clone().unwrap_or_else(|| controller.clone());
        let stats = Arc::new(MessageStats::default());
        let shard_stats: Vec<Arc<MessageStats>> = if shard_count > 1 {
            (0..shard_count).map(|_| Arc::new(MessageStats::default())).collect()
        } else {
            Vec::new()
        };
        // The observability plane: one registry for the whole session,
        // installed on every controller (each scrape serves the full
        // registry; the `shard` label distinguishes the series). The
        // session counter mirrors under the key-plane's label — "parent"
        // when sharded, "0" otherwise — and each per-shard counter under
        // its shard index, so registry totals reconcile with the round
        // accounting source-for-source.
        let metrics = SessionMetrics::new();
        for (s, shard) in shards.iter().enumerate() {
            shard.install_metrics(metrics.registry().clone(), &s.to_string());
        }
        if let Some(p) = &parent {
            p.install_metrics(metrics.registry().clone(), "parent");
        }
        let session_label = if shard_count > 1 { "parent" } else { "0" };
        stats.mirror_into(metrics.registry(), session_label);
        for (s, st) in shard_stats.iter().enumerate() {
            st.mirror_into(metrics.registry(), &s.to_string());
        }
        // Latency series are labeled by the controller a request targets.
        let plane_label = {
            let shards = shards.clone();
            let parent = parent.clone();
            move |target: &Arc<Controller>| -> String {
                if parent.as_ref().is_some_and(|p| Arc::ptr_eq(target, p)) {
                    "parent".to_string()
                } else {
                    shards
                        .iter()
                        .position(|s| Arc::ptr_eq(s, target))
                        .map_or_else(|| "0".to_string(), |i| i.to_string())
                }
            }
        };
        // Hostile-network injection (`--net`): one shared fault source for
        // every transport in the session. Per-link determinism is keyed
        // inside `NetFaults`; `None` keeps the ideal path byte-identical.
        let net: Option<Arc<NetFaults>> = if cfg.net.is_ideal() {
            None
        } else {
            Some(Arc::new(NetFaults::new(cfg.net.clone())))
        };
        // Session-counted in-proc transport to any member of the plane
        // (a shard or the parent), with a caller-chosen stats sink.
        let plane_transport = |target: &Arc<Controller>,
                               sink: &Arc<MessageStats>|
         -> Arc<dyn ClientTransport> {
            let mut t = InProcTransport::with_costs(
                target.clone(),
                sink.clone(),
                cfg.profile.network_hop,
                cfg.profile.network_per_kib,
            )
            .with_wire_format(cfg.wire)
            .with_latency_metrics(metrics.recorder(&plane_label(target)));
            if let Some(n) = &net {
                t = t.with_net(n.clone());
            }
            Arc::new(t)
        };

        // Transport factory per node (+ one for the monitor): the key
        // plane (parent when sharded).
        let mut http_server = None;
        let make_transport: Box<dyn Fn() -> Result<Arc<dyn ClientTransport>>> = match &cfg
            .transport
        {
            TransportKind::InProc => {
                let ctrl = key_plane.clone();
                let stats = stats.clone();
                let hop = cfg.profile.network_hop;
                let per_kib = cfg.profile.network_per_kib;
                let wire = cfg.wire;
                let net = net.clone();
                let recorder = metrics.recorder(session_label);
                Box::new(move || {
                    let mut t =
                        InProcTransport::with_costs(ctrl.clone(), stats.clone(), hop, per_kib)
                            .with_wire_format(wire)
                            .with_latency_metrics(recorder.clone());
                    if let Some(n) = &net {
                        t = t.with_net(n.clone());
                    }
                    Ok(Arc::new(t) as Arc<dyn ClientTransport>)
                })
            }
            TransportKind::Http { url } => {
                let url = if url == "spawn" {
                    // Spawn a loopback server serving this controller.
                    let server = HttpServer::start("127.0.0.1:0", controller.clone())?;
                    let u = server.url();
                    http_server = Some(server);
                    u
                } else {
                    url.clone()
                };
                let wire = cfg.wire;
                let recorder = metrics.recorder(session_label);
                Box::new(move || {
                    Ok(Arc::new(
                        HttpTransport::connect(&url)?
                            .with_wire_format(wire)
                            .with_latency_metrics(recorder.clone()),
                    ) as Arc<dyn ClientTransport>)
                })
            }
        };

        // Vector engine.
        let math: Arc<dyn VectorMath> = match cfg.engine {
            VectorEngine::Native => Arc::new(NativeMath),
            VectorEngine::Xla | VectorEngine::Auto => {
                let dir = ArtifactRuntime::default_dir();
                if ArtifactRuntime::available(&dir) {
                    Arc::new(XlaMath::new(Arc::new(ArtifactRuntime::new(dir)?)))
                } else if matches!(cfg.engine, VectorEngine::Auto) {
                    Arc::new(NativeMath)
                } else {
                    bail!("VectorEngine::Xla requested but artifacts/ not built");
                }
            }
        };

        // Configure the plane with the planner's configured topology
        // (the base plan: full membership, no churn, no merges).
        let base = planner.base_plan();
        let chains = base.groups().to_vec();
        for (_, chain) in &chains {
            if chain.len() < 3 {
                bail!(
                    "SAFE requires >= 3 nodes per group for privacy (got {})",
                    chain.len()
                );
            }
        }
        let timeout_fields = || {
            vec![
                (
                    "aggregation_timeout_ms",
                    Value::from(cfg.aggregation_timeout.as_millis() as u64),
                ),
                (
                    "progress_timeout_ms",
                    Value::from(cfg.progress_timeout.as_millis() as u64),
                ),
                ("poll_time_ms", Value::from(cfg.poll_time.as_millis() as u64)),
            ]
        };
        let setup_transport = make_transport()?;
        if shard_count == 1 {
            let mut groups_obj = Value::obj();
            for (gid, chain) in &chains {
                groups_obj.set(
                    &gid.to_string(),
                    Value::Arr(chain.iter().map(|&n| Value::from(n)).collect()),
                );
            }
            let mut fields = vec![("groups", groups_obj)];
            fields.extend(timeout_fields());
            setup_transport.call(proto::CONFIGURE, &Value::object(fields))?;
        } else {
            // Sharded plane: each shard controller is configured with its
            // groups only; the parent gets no chains — just timeouts and
            // the fan-in barrier width (re-announced every round for the
            // live shard count).
            for (s, shard) in shards.iter().enumerate() {
                let mut groups_obj = Value::obj();
                for (gid, chain) in base.groups_for_shard(s) {
                    groups_obj.set(
                        &gid.to_string(),
                        Value::Arr(chain.iter().map(|&n| Value::from(n)).collect()),
                    );
                }
                let mut fields = vec![("groups", groups_obj)];
                fields.extend(timeout_fields());
                plane_transport(shard, &stats).call(proto::CONFIGURE, &Value::object(fields))?;
            }
            let mut fields = timeout_fields();
            fields.push(("fed_expected_children", Value::from(shard_count as u64)));
            setup_transport.call(proto::CONFIGURE, &Value::object(fields))?;
        }

        // ---- Round 0: key generation + registry (§5.1, footnote 3) ----
        // SAF mode (CipherMode::None) never seals a payload, so per-node
        // keygen — the dominant round-0 cost at n=1,000+ — is pointless.
        // Every node shares one keypair and the registry still gets a key
        // per node (rekey accounting stays uniform across modes), but the
        // O(n) keygen and O(n·g) peer-key fetch are skipped.
        let shared_key = if cfg.mode == CipherMode::None {
            Some(Arc::new(keypair_for(cfg.seed, 0, cfg.rsa_bits)))
        } else {
            None
        };
        let mut node_keys: BTreeMap<u64, Arc<RsaKeyPair>> = BTreeMap::new();
        for (_, chain) in &chains {
            for &node in chain {
                let kp = match &shared_key {
                    Some(kp) => kp.clone(),
                    None => Arc::new(keypair_for(cfg.seed, node, cfg.rsa_bits)),
                };
                node_keys.insert(node, kp);
            }
        }
        for (&node, kp) in &node_keys {
            setup_transport.call(
                proto::REGISTER_KEY,
                &proto::RegisterKey { node, key: kp.public.to_json() }.to_value(),
            )?;
        }

        // Build learner contexts: fetch peer keys (and §5.8 symmetric
        // pre-negotiation when configured). SAF mode skips the fetch —
        // nothing is ever sealed, so peer keys would never be read.
        let mut contexts: BTreeMap<u64, Arc<LearnerContext>> = BTreeMap::new();
        for (gid, chain) in &chains {
            for &node in chain {
                let transport = make_transport()?;
                let mut peer_keys = BTreeMap::new();
                if cfg.mode != CipherMode::None {
                    for &peer in chain {
                        if peer == node {
                            continue;
                        }
                        let resp = transport
                            .call(proto::GET_KEY, &proto::GetKey { node: peer }.to_value())?;
                        let delivery = proto::KeyDelivery::from_value(&resp)?;
                        peer_keys.insert(peer, RsaPublicKey::from_json(&delivery.key)?);
                    }
                }
                let rng: Box<dyn SecureRng + Send> = match cfg.seed {
                    Some(s) => Box::new(DeterministicRng::seed(s.wrapping_add(node * 7919))),
                    None => Box::new(SystemRng::new()),
                };
                contexts.insert(node, Arc::new(LearnerContext {
                    node,
                    group: *gid,
                    chain: chain.clone(),
                    expected_total_nodes: cfg.n_nodes,
                    keys: node_keys[&node].clone(),
                    peer_keys: Arc::new(peer_keys),
                    send_keys: Arc::new(BTreeMap::new()),
                    recv_keys: Arc::new(BTreeMap::new()),
                    mode: cfg.mode,
                    compress: cfg.compress,
                    profile: cfg.profile.clone(),
                    transport,
                    math: math.clone(),
                    rng: Mutex::new(rng),
                    aggregation_timeout: cfg.aggregation_timeout,
                    single_seed_mask: cfg.profile.name == "deep-edge",
                    initial_initiator: chain[0],
                    stagger_delay: cfg
                        .stagger_step
                        .mul_f64(chain.iter().position(|&c| c == node).unwrap_or(0) as f64),
                    epoch: 0,
                    retry: cfg.net.retry_policy(),
                    stats: stats.clone(),
                    shard: base.shard_of_group(*gid).unwrap_or(0),
                    post_seq: std::sync::atomic::AtomicU64::new(0),
                    rsa_dec: once_cell::sync::OnceCell::new(),
                }));
            }
        }

        // §5.8 pre-negotiation: every node generates one symmetric key per
        // group peer (keys it will use to *receive* from that peer), seals
        // each with the peer's RSA public key, posts; peers pull + unseal.
        if cfg.mode == CipherMode::PreNegotiated {
            let mut generated: BTreeMap<u64, BTreeMap<u64, SymmetricKey>> = BTreeMap::new();
            for ctx in contexts.values() {
                let mut sealed_keys = BTreeMap::new();
                let mut mine = BTreeMap::new();
                {
                    let mut rng = ctx.rng.lock().unwrap();
                    for &peer in &ctx.chain {
                        if peer == ctx.node {
                            continue;
                        }
                        let k = SymmetricKey::generate(rng.as_mut());
                        let sealed = ctx.peer_keys[&peer].encrypt_block(&k.master, rng.as_mut())?;
                        sealed_keys.insert(peer, crate::blob::Blob::new(sealed));
                        mine.insert(peer, k);
                    }
                }
                ctx.transport.call(
                    proto::POST_PRENEG_KEYS,
                    &proto::PostPrenegKeys { node: ctx.node, keys: sealed_keys }.to_value(),
                )?;
                generated.insert(ctx.node, mine);
            }
            // Pull: send_keys[to] = key that `to` generated for me.
            for ctx in Vec::from_iter(contexts.values().cloned()) {
                let mut send_keys = BTreeMap::new();
                // One CRT context unseals every peer's delivery (§5.8:
                // n-1 pulls per node, all under our own modulus).
                let dec = ctx.rsa_dec.get_or_init(|| ctx.keys.private.decrypt_ctx());
                for &peer in &ctx.chain {
                    if peer == ctx.node {
                        continue;
                    }
                    let resp = ctx.transport.call(
                        proto::GET_PRENEG_KEY,
                        &proto::GetPrenegKey { node: ctx.node, owner: peer }.to_value(),
                    )?;
                    let delivery = proto::PrenegKeyDelivery::from_value(&resp)?;
                    let master = dec.decrypt_block(delivery.key.as_bytes())?;
                    send_keys.insert(peer, SymmetricKey::from_bytes(&master)?);
                }
                // Contexts are shared Arcs; rebuild with key maps filled.
                let old = contexts[&ctx.node].clone();
                let mut refreshed = old.fork(match cfg.seed {
                    Some(s) => Box::new(DeterministicRng::seed(s.wrapping_add(old.node * 104729)))
                        as Box<dyn SecureRng + Send>,
                    None => Box::new(SystemRng::new()),
                });
                refreshed.send_keys = Arc::new(send_keys);
                refreshed.recv_keys = Arc::new(generated.remove(&old.node).unwrap_or_default());
                contexts.insert(old.node, Arc::new(refreshed));
            }
        }

        let round0_messages = stats.total();
        // One monitor transport per shard (each shard runs §5.3 progress
        // detection over its own chains); the single-shard path keeps the
        // factory-built transport exactly as before.
        let monitor_transports: Vec<Arc<dyn ClientTransport>> = if shard_count > 1 {
            shards.iter().map(|s| plane_transport(s, &stats)).collect()
        } else {
            vec![make_transport()?]
        };
        let parent_transport: Option<Arc<dyn ClientTransport>> =
            parent.as_ref().map(|p| plane_transport(p, &stats));
        let shard_transports: Vec<Arc<dyn ClientTransport>> = shard_stats
            .iter()
            .enumerate()
            .map(|(s, st)| plane_transport(&shards[s], st))
            .collect();
        // Fan-in bridges (K > 1): one per shard, completion-wired to the
        // parent so the global-average fetch parks on the parent's wait
        // hub instead of sleep-polling. No `--net` faults here — the
        // fan-in tier models the inter-controller backbone, not the
        // hostile edge network the learners cross.
        let fanin_bridges: Vec<Arc<FederationBridge>> = match &parent {
            Some(p) => {
                let waiters = Arc::new(FanInWaiters::default());
                p.wait_hub().set_sink(waiters.clone());
                (0..shard_count)
                    .map(|s| {
                        let t = InProcTransport::with_costs(
                            p.clone(),
                            stats.clone(),
                            cfg.profile.network_hop,
                            cfg.profile.network_per_kib,
                        )
                        .with_wire_format(cfg.wire)
                        .with_completion(p.clone())
                        .with_latency_metrics(metrics.recorder("parent"));
                        Arc::new(FederationBridge::over_completion(
                            (s + 1) as u64,
                            Arc::new(t),
                            p.wait_hub(),
                            waiters.clone(),
                        ))
                    })
                    .collect()
            }
            None => Vec::new(),
        };

        // The event runtime needs the completion-style transport (submit /
        // try_complete) and each shard's wait hub — both in-proc-only, so
        // HTTP sessions fall back to the thread runtime. One worker pool
        // drives all K shard planes, routing each learner's calls through
        // its home shard's transport/hub pair.
        let executor = match (&cfg.transport, cfg.runtime) {
            (TransportKind::InProc, RuntimeKind::Events) => {
                let planes = shards
                    .iter()
                    .enumerate()
                    .map(|(s, shard)| {
                        let sink = shard_stats.get(s).cloned().unwrap_or_else(|| stats.clone());
                        let mut exec_transport = InProcTransport::with_costs(
                            shard.clone(),
                            sink,
                            cfg.profile.network_hop,
                            cfg.profile.network_per_kib,
                        )
                        .with_wire_format(cfg.wire)
                        .with_completion(shard.clone())
                        .with_latency_metrics(metrics.recorder(&s.to_string()));
                        if let Some(n) = &net {
                            exec_transport = exec_transport.with_net(n.clone());
                        }
                        (Arc::new(exec_transport), shard.wait_hub())
                    })
                    .collect();
                Some(EventExecutor::start_sharded(
                    planes,
                    ExecutorConfig {
                        workers: cfg.workers,
                        poll_time: cfg.poll_time,
                        retry: cfg.net.retry_policy(),
                    },
                ))
            }
            _ => None,
        };

        Ok(SafeSession {
            cfg,
            controller,
            shards,
            parent,
            planner,
            stats,
            shard_stats,
            contexts: Mutex::new(contexts),
            executor,
            monitor_transports,
            parent_transport,
            shard_transports,
            fanin_bridges,
            _http_server: http_server,
            round0_messages,
            rounds_run: std::sync::atomic::AtomicU64::new(0),
            metrics,
        })
    }

    /// Run one aggregation round. `inputs[i]` is node i+1's local vector
    /// (all must have `cfg.wire_features()` length). A thin wrapper over
    /// [`SafeSession::run_rounds`]: the [`FaultPlan`] is lifted to a
    /// one-round [`ChurnSchedule`].
    pub fn run_round(&self, inputs: &[Vec<f64>], faults: &FaultPlan) -> Result<SafeRoundResult> {
        let churn = ChurnSchedule::from_fault_plan(faults);
        let mut results = self.run_rounds(&[inputs.to_vec()], &churn)?;
        results.pop().context("one round in, one result out")
    }

    /// The multi-round session engine. Runs `inputs_per_round.len()`
    /// aggregation rounds over *persistent* learner actors (one thread
    /// per node, alive for the whole run; keys exchanged once at session
    /// build and reused every round, paper §5 footnote 3) and a single
    /// progress monitor. Between rounds the controller's mailboxes and
    /// chain state reset via a round-epoch (`begin_round`) — the HTTP
    /// listener, `MessageStats` and the key registry are never torn down.
    ///
    /// `churn` schedules cross-round membership: a node can die at a
    /// [`FailPoint`](crate::learner::faults::FailPoint) in round `r`, sit
    /// out following rounds (the chain re-forms without it), and rejoin
    /// later — re-running the key exchange for the returning node only,
    /// counted separately as [`RoundMetrics::rekey_messages`].
    pub fn run_rounds(
        &self,
        inputs_per_round: &[Vec<Vec<f64>>],
        churn: &ChurnSchedule,
    ) -> Result<Vec<SafeRoundResult>> {
        if inputs_per_round.is_empty() {
            return Ok(Vec::new());
        }
        // Persistent actors. Thread runtime: one OS thread per configured
        // node, parked on a task channel between rounds. Event runtime:
        // thin handles over the session's shared worker pool — no thread
        // per learner, which is what lets the scale harness reach
        // n=10,000.
        let mut actors: BTreeMap<u64, LearnerActor> = BTreeMap::new();
        {
            let masters = self.contexts.lock().unwrap();
            for &node in masters.keys() {
                let actor = match &self.executor {
                    Some(exec) => LearnerActor::event(node, exec.clone()),
                    None => LearnerActor::spawn(node)?,
                };
                actors.insert(node, actor);
            }
        }
        // One §5.3 progress monitor per shard plane (a single monitor when
        // K = 1, exactly as before).
        let mut monitors: Vec<ProgressMonitor> = self
            .monitor_transports
            .iter()
            .map(|t| {
                ProgressMonitor::start_with_metrics(
                    t.clone(),
                    self.cfg.monitor_interval,
                    Some(self.metrics.monitor_counters()),
                )
            })
            .collect();
        let mut results = Vec::with_capacity(inputs_per_round.len());
        for (i, inputs) in inputs_per_round.iter().enumerate() {
            let round = (i + 1) as u64;
            match self.run_engine_round(inputs, churn, round, &actors, &monitors) {
                Ok(r) => results.push(r),
                Err(e) => {
                    for m in &mut monitors {
                        m.stop();
                    }
                    return Err(e.context(format!("round {round}")));
                }
            }
        }
        for m in &mut monitors {
            m.stop();
        }
        Ok(results)
    }

    /// Deterministic per-(node, salt) RNG for a round's context fork.
    fn round_rng(&self, node: u64, salt: u64) -> Box<dyn SecureRng + Send> {
        match self.cfg.seed {
            Some(s) => Box::new(DeterministicRng::seed(
                s ^ (salt << 24) ^ node.wrapping_mul(0x9e3779b97f4a7c15),
            )),
            None => Box::new(SystemRng::new()),
        }
    }

    fn master_context(&self, node: u64) -> Result<Arc<LearnerContext>> {
        self.contexts
            .lock()
            .unwrap()
            .get(&node)
            .cloned()
            .with_context(|| format!("node {node} has no configured context"))
    }

    fn replace_context(&self, ctx: LearnerContext) {
        let mut masters = self.contexts.lock().unwrap();
        if let Some(slot) = masters.get_mut(&ctx.node) {
            *slot = Arc::new(ctx);
        }
    }

    /// One engine round: chain re-formation around churned-out nodes,
    /// round-epoch reset, rejoin re-key, fan-out to the actors, agreement
    /// validation and metrics.
    fn run_engine_round(
        &self,
        inputs: &[Vec<f64>],
        churn: &ChurnSchedule,
        churn_round: u64,
        actors: &BTreeMap<u64, LearnerActor>,
        monitors: &[ProgressMonitor],
    ) -> Result<SafeRoundResult> {
        if inputs.len() != self.cfg.n_nodes {
            bail!("need {} input vectors, got {}", self.cfg.n_nodes, inputs.len());
        }
        let engine_round = self
            .rounds_run
            .fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        let epoch = engine_round + 1;

        // Ask the topology planner for this round's plan: the configured
        // (possibly per-round permuted) chains minus churned-out nodes,
        // with under-floor groups merged into a neighbour (per-node
        // `Reassigned` deltas) and a privacy-floor abort only when the
        // total live population is below 3.
        let faults = churn.fault_plan_for(churn_round);
        let absent: std::collections::BTreeSet<u64> = self
            .planner
            .membership()
            .into_iter()
            .filter(|&n| churn.absent_in(churn_round, n))
            .collect();
        let plan = self.planner.plan_round(engine_round, &absent, &faults)?;
        let total_active = plan.total_live();

        // Open the round-epoch: mailbox/check/average state resets; the
        // key registry, HTTP state and MessageStats survive. The plan's
        // merge deltas ride along so the controller can answer mid-round
        // floor trips with `merge_groups` and surface reassignments.
        if self.parent.is_none() {
            let resp = self.monitor_transports[0].call(
                proto::BEGIN_ROUND,
                &proto::BeginRound {
                    epoch,
                    groups: plan.groups_map(),
                    merge_floor: self.cfg.merge_floor,
                    reassigned: plan.reassignments().to_vec(),
                    fanin: false,
                    fed_children: None,
                }
                .to_value(),
            )?;
            if resp.str_of("status") != Some("ok") {
                bail!("begin_round rejected: {:?}", resp.str_of("status"));
            }
        } else {
            // Sharded plane: each shard opens the epoch over its slice of
            // the plan (fan-in mode — the shard barrier feeds the parent
            // instead of publishing directly), and the parent opens the
            // combine epoch expecting one partial per live shard.
            for (s, t) in self.monitor_transports.iter().enumerate() {
                let reassigned: Vec<_> = plan
                    .reassignments()
                    .iter()
                    .filter(|r| plan.shard_of_group(r.to_group) == Some(s))
                    .cloned()
                    .collect();
                let resp = t.call(
                    proto::BEGIN_ROUND,
                    &proto::BeginRound {
                        epoch,
                        groups: plan.groups_for_shard(s),
                        merge_floor: self.cfg.merge_floor,
                        reassigned,
                        fanin: true,
                        fed_children: None,
                    }
                    .to_value(),
                )?;
                if resp.str_of("status") != Some("ok") {
                    bail!("shard {s} begin_round rejected: {:?}", resp.str_of("status"));
                }
            }
            let parent_t = self
                .parent_transport
                .as_ref()
                .context("sharded session missing parent transport")?;
            let resp = parent_t.call(
                proto::BEGIN_ROUND,
                &proto::BeginRound {
                    epoch,
                    groups: BTreeMap::new(),
                    merge_floor: false,
                    reassigned: Vec::new(),
                    fanin: false,
                    fed_children: Some(plan.live_shards().len() as u64),
                }
                .to_value(),
            )?;
            if resp.str_of("status") != Some("ok") {
                bail!("fan-in begin_round rejected: {:?}", resp.str_of("status"));
            }
        }

        let baseline_msgs = self.total_messages();
        let baseline_bytes = self.total_bytes();
        let baseline_recv = self.total_bytes_received();
        let baseline_retries = self.total_retries();
        let baseline_drops = self.total_drops();
        let baseline_dedup = self.total_dedup();
        let per_path_before = self.merged_per_path();
        let shard_base: Vec<u64> = self.shard_stats.iter().map(|s| s.total()).collect();

        // Key re-exchange for nodes returning this round — only their key
        // material moves; survivors' keys are reused untouched.
        let rejoiners: Vec<u64> = churn
            .rejoining_in(churn_round)
            .into_iter()
            .filter(|&j| plan.contains(j))
            .collect();
        if !rejoiners.is_empty() {
            self.rekey_rejoiners(&rejoiners, &plan, epoch)?;
        }
        // Merge re-balancing re-key: nodes the plan reassigned to another
        // group fetch keys for their *new* links only (and their new
        // peers fetch theirs). Links already keyed — including from a
        // previous round's merge — are skipped, so a repeated merge is
        // free.
        self.rekey_reassigned(&plan, epoch)?;
        // Count rekey traffic by key-exchange path, not by total delta:
        // the cross-round monitor keeps pinging `progress_check` through
        // the same counted transport, and a ping landing inside the rekey
        // window must not masquerade as (or double-subtract from) rekey.
        let per_path_rekey = self.merged_per_path();
        let rekey_messages: u64 = [
            proto::REGISTER_KEY,
            proto::GET_KEY,
            proto::POST_PRENEG_KEYS,
            proto::GET_PRENEG_KEY,
        ]
        .iter()
        .map(|p| {
            per_path_rekey.get(*p).copied().unwrap_or(0)
                - per_path_before.get(*p).copied().unwrap_or(0)
        })
        .sum();

        let reposts_before: u64 = monitors.iter().map(|m| m.reposts()).sum();
        let watch = Stopwatch::start();

        // Fan-in workers (K > 1): one thread per live shard waits on its
        // shard's barrier partial, posts it to the parent, long-polls the
        // combined global, and installs it back so the shard's learners
        // wake. Spawned before the learner fan-out so a shard finishing
        // early is collected immediately; exactly two counted messages per
        // live shard per healthy round (`≤ 2K` fan-in term).
        let mut fanin_workers = Vec::new();
        if !self.fanin_bridges.is_empty() {
            for &s in &plan.live_shards() {
                let shard_ctrl = self.shards[s].clone();
                let bridge = self.fanin_bridges[s].clone();
                let barrier = self.cfg.aggregation_timeout;
                fanin_workers.push(
                    std::thread::Builder::new()
                        .name(format!("fanin-shard-{s}"))
                        .spawn(move || -> Option<Duration> {
                            let (avg, contributors) = shard_ctrl.shard_partial(barrier)?;
                            let started = Instant::now();
                            bridge.post_child_average(&avg, contributors).ok()?;
                            let global = match bridge.try_get_global_average(barrier).ok()? {
                                Some(g) => g,
                                // Degraded round: a sibling shard never
                                // posted — combine whatever partials the
                                // parent holds so live shards still finish.
                                None => bridge.get_partial_global().ok().flatten()?,
                            };
                            shard_ctrl.install_global_average(global.0, global.1);
                            Some(started.elapsed())
                        })?,
                );
            }
        }

        // Fan out one per-round context fork to every active actor.
        let mut active = std::collections::BTreeSet::new();
        for (gid, chain) in plan.groups() {
            let shard = plan.shard_of_group(*gid).unwrap_or(0);
            for (pos, &node) in chain.iter().enumerate() {
                let master = self.master_context(node)?;
                let mut ctx = master.fork(self.round_rng(node, epoch));
                ctx.group = *gid;
                ctx.chain = chain.clone();
                ctx.expected_total_nodes = total_active;
                ctx.epoch = epoch;
                ctx.initial_initiator = chain[0];
                ctx.stagger_delay = self.cfg.stagger_step.mul_f64(pos as f64);
                // Route the learner to its home shard: its chain/mailbox
                // calls go through the shard's transport and count on the
                // shard's stats. K = 1 leaves the master wiring untouched.
                ctx.shard = shard;
                if let Some(t) = self.shard_transports.get(shard) {
                    ctx.transport = t.clone();
                    ctx.stats = self.shard_stats[shard].clone();
                }
                actors
                    .get(&node)
                    .with_context(|| format!("no actor for node {node}"))?
                    .dispatch(Arc::new(ctx), inputs[(node - 1) as usize].clone(), faults.clone())?;
                active.insert(node);
            }
        }
        debug_assert_eq!(active.len(), total_active);
        let mut outcomes = Vec::with_capacity(self.cfg.n_nodes);
        for &node in &active {
            outcomes.push(actors[&node].collect()?);
        }
        // Churned-out nodes are dead for this round's bookkeeping.
        for node in self.planner.membership() {
            if !active.contains(&node) {
                outcomes.push(LearnerOutcome::absent(node));
            }
        }
        outcomes.sort_by_key(|o| o.node);
        // Join the fan-in tier; its latency is the slowest shard's
        // post→install span (zero when K = 1).
        let mut fanin_latency = Duration::ZERO;
        for w in fanin_workers {
            if let Ok(Some(d)) = w.join() {
                fanin_latency = fanin_latency.max(d);
            }
        }
        let wall_time = watch.elapsed();

        // Validate agreement: every survivor holds the same average.
        let survivors: Vec<&LearnerOutcome> = outcomes.iter().filter(|o| !o.died).collect();
        if survivors.is_empty() {
            bail!("no surviving learners");
        }
        let reference = &survivors[0].average;
        for s in &survivors[1..] {
            if s.average.len() != reference.len() {
                bail!("learners disagree on average length");
            }
            for (a, b) in s.average.iter().zip(reference) {
                if (a - b).abs() > 1e-9 {
                    bail!("learners disagree on the average: {a} vs {b}");
                }
            }
        }

        let per_path_after = self.merged_per_path();
        let mut per_path = BTreeMap::new();
        for (k, v) in per_path_after {
            let before = per_path_before.get(&k).copied().unwrap_or(0);
            if v > before {
                per_path.insert(k, v - before);
            }
        }
        // The monitor's periodic pings are operational, not protocol,
        // traffic — exclude them from the message count like the paper's
        // formulas do. Rekey traffic is reported separately (footnote 3:
        // key exchange is not per-aggregation) but stays in `per_path`.
        // Fan-in traffic is likewise the sharding surcharge, not edge
        // protocol traffic: counted separately (`fanin_messages`, ≤ 2K)
        // and left visible in `per_path`. All three exclusions are driven
        // by the registry's path classification — one taxonomy shared
        // with the `class` label on every scraped series — instead of
        // naming individual paths here.
        let monitor_msgs: u64 = per_path
            .iter()
            .filter(|(p, _)| crate::metrics::path_class(p) == "monitor")
            .map(|(_, v)| *v)
            .sum();
        per_path.retain(|p, _| crate::metrics::path_class(p) != "monitor");
        let fanin_messages: u64 = per_path
            .iter()
            .filter(|(p, _)| crate::metrics::path_class(p) == "fanin")
            .map(|(_, v)| *v)
            .sum();
        let messages = self.total_messages()
            - baseline_msgs
            - monitor_msgs
            - rekey_messages
            - fanin_messages;
        let shard_messages: Vec<u64> = self
            .shard_stats
            .iter()
            .zip(&shard_base)
            .map(|(s, b)| s.total() - b)
            .collect();

        // Each group's initiator reports its group's contributor count;
        // sum across groups (one initiator per group).
        let initiator_sum: u64 = survivors
            .iter()
            .filter(|o| o.was_initiator)
            .map(|o| o.contributors)
            .sum();
        let contributors = if initiator_sum > 0 {
            initiator_sum
        } else {
            survivors.len() as u64
        };

        let metrics = RoundMetrics {
            wall_time,
            messages,
            bytes_sent: self.total_bytes() - baseline_bytes,
            bytes_received: self.total_bytes_received() - baseline_recv,
            average: reference.clone(),
            contributors,
            progress_failovers: monitors.iter().map(|m| m.reposts()).sum::<u64>()
                - reposts_before,
            initiator_failovers: outcomes.iter().map(|o| o.restarts).max().unwrap_or(0),
            rekey_messages,
            merged_groups: plan.merges().len() as u64,
            reassigned_nodes: plan.reassignments().len() as u64,
            deadline_exceeded: outcomes.iter().filter(|o| o.deadline_exceeded).count() as u64,
            net_retries: self.total_retries() - baseline_retries,
            net_drops: self.total_drops() - baseline_drops,
            dedup_posts: self.total_dedup() - baseline_dedup,
            per_path,
            fanin_messages,
            fanin_latency,
            shard_messages,
        };
        self.metrics.record_round(epoch as usize, &metrics);
        Ok(SafeRoundResult { metrics, outcomes })
    }

    /// Re-run the key exchange for nodes rejoining this round. Only key
    /// material *involving a rejoiner* moves: the rejoiner re-registers
    /// its public key and re-fetches its configured peers'; each active
    /// peer re-fetches the rejoiner's key; under §5.8 pre-negotiation,
    /// every symmetric key on a link touching a rejoiner is regenerated
    /// and re-pulled. Links between surviving nodes keep their existing
    /// keys — that reuse is the multi-round engine's amortization win.
    fn rekey_rejoiners(
        &self,
        rejoiners: &[u64],
        plan: &TopologyPlan,
        epoch: u64,
    ) -> Result<()> {
        use crate::blob::Blob;
        // Phase A: rejoiners re-register + re-fetch peer public keys. SAF
        // mode (no sealing) keeps the registration — so rekey accounting
        // stays visible — but skips the fetches nothing would ever read.
        for &j in rejoiners {
            let master = self.master_context(j)?;
            let full = plan
                .chain_containing(j)
                .context("rejoiner not in any planned group")?
                .to_vec();
            let key_node = if self.cfg.mode == CipherMode::None { 0 } else { j };
            let kp = keypair_for(self.cfg.seed, key_node, self.cfg.rsa_bits);
            master.transport.call(
                proto::REGISTER_KEY,
                &proto::RegisterKey { node: j, key: kp.public.to_json() }.to_value(),
            )?;
            let mut peer_keys = BTreeMap::new();
            if self.cfg.mode != CipherMode::None {
                for &peer in &full {
                    if peer == j {
                        continue;
                    }
                    let resp = master
                        .transport
                        .call(proto::GET_KEY, &proto::GetKey { node: peer }.to_value())?;
                    let delivery = proto::KeyDelivery::from_value(&resp)?;
                    peer_keys.insert(peer, RsaPublicKey::from_json(&delivery.key)?);
                }
            }
            let mut ctx = master.fork(self.round_rng(j, epoch ^ 0x5eed));
            ctx.keys = Arc::new(kp);
            // Fresh keypair ⇒ the forked decryption-context cache is stale.
            ctx.rsa_dec = once_cell::sync::OnceCell::new();
            ctx.peer_keys = Arc::new(peer_keys);
            ctx.chain = full;
            self.replace_context(ctx);
        }
        if self.cfg.mode == CipherMode::None {
            return Ok(());
        }
        // Active peers re-fetch each rejoiner's (possibly new) public key.
        for (_, chain) in plan.groups() {
            for &j in rejoiners {
                if !chain.contains(&j) {
                    continue;
                }
                for &peer in chain {
                    if peer == j || rejoiners.contains(&peer) {
                        continue; // rejoiners already refreshed in phase A
                    }
                    let master = self.master_context(peer)?;
                    let resp = master
                        .transport
                        .call(proto::GET_KEY, &proto::GetKey { node: j }.to_value())?;
                    let delivery = proto::KeyDelivery::from_value(&resp)?;
                    // Clone-on-write: only rekey ever rebuilds a key map.
                    let mut pk = (*master.peer_keys).clone();
                    pk.insert(j, RsaPublicKey::from_json(&delivery.key)?);
                    let mut ctx = master.fork(self.round_rng(peer, epoch ^ 0xbee));
                    ctx.peer_keys = Arc::new(pk);
                    self.replace_context(ctx);
                }
            }
        }
        if self.cfg.mode != CipherMode::PreNegotiated {
            return Ok(());
        }
        // Phase B (§5.8 sessions): refresh the symmetric keys on every
        // link touching a rejoiner.
        // B1: each rejoiner generates fresh receive-keys for all its
        // configured peers and posts them sealed.
        for &j in rejoiners {
            let master = self.master_context(j)?;
            let mut sealed = BTreeMap::new();
            let mut mine = BTreeMap::new();
            {
                let mut rng = master.rng.lock().unwrap();
                for &peer in &master.chain {
                    if peer == j {
                        continue;
                    }
                    let k = SymmetricKey::generate(rng.as_mut());
                    let s = master.peer_keys[&peer].encrypt_block(&k.master, rng.as_mut())?;
                    sealed.insert(peer, Blob::new(s));
                    mine.insert(peer, k);
                }
            }
            master.transport.call(
                proto::POST_PRENEG_KEYS,
                &proto::PostPrenegKeys { node: j, keys: sealed }.to_value(),
            )?;
            let mut ctx = master.fork(self.round_rng(j, epoch ^ 0x1a));
            ctx.recv_keys = Arc::new(mine);
            self.replace_context(ctx);
        }
        // B2: each active peer regenerates its receive-key for the
        // rejoiner, posts it, and pulls the rejoiner's fresh key for
        // itself.
        for (_, chain) in plan.groups() {
            for &j in rejoiners {
                if !chain.contains(&j) {
                    continue;
                }
                for &peer in chain {
                    if peer == j || rejoiners.contains(&peer) {
                        // Fellow rejoiners regenerate in B1 / pull in B3;
                        // regenerating here would desync the key versions.
                        continue;
                    }
                    self.preneg_peer_refresh(j, peer, epoch ^ 0x2b)?;
                }
            }
        }
        // B3: each rejoiner pulls every active peer's fresh key for it.
        for &j in rejoiners {
            let Some(chain) = plan.chain_containing(j) else {
                continue;
            };
            let master = self.master_context(j)?;
            let mut send_keys = (*master.send_keys).clone();
            let dec = master.rsa_dec.get_or_init(|| master.keys.private.decrypt_ctx());
            for &peer in chain {
                if peer == j {
                    continue;
                }
                let resp = master.transport.call(
                    proto::GET_PRENEG_KEY,
                    &proto::GetPrenegKey { node: j, owner: peer }.to_value(),
                )?;
                let delivery = proto::PrenegKeyDelivery::from_value(&resp)?;
                let m = dec.decrypt_block(delivery.key.as_bytes())?;
                send_keys.insert(peer, SymmetricKey::from_bytes(&m)?);
            }
            let mut ctx = master.fork(self.round_rng(j, epoch ^ 0x3c));
            ctx.send_keys = Arc::new(send_keys);
            self.replace_context(ctx);
        }
        Ok(())
    }

    /// §5.8 peer-side refresh of one symmetric link: `peer` generates a
    /// fresh receive-key for `j`, posts it sealed under `j`'s RSA key,
    /// and pulls the key `j` generated for it (which the caller must
    /// have posted beforehand). Shared by the rejoiner re-key (phase B2)
    /// and the merge-reassignment re-key, so the pairwise handshake and
    /// its message accounting exist in exactly one place.
    fn preneg_peer_refresh(&self, j: u64, peer: u64, rng_salt: u64) -> Result<()> {
        use crate::blob::Blob;
        let master = self.master_context(peer)?;
        let (sealed, k) = {
            let mut rng = master.rng.lock().unwrap();
            let k = SymmetricKey::generate(rng.as_mut());
            let s = master.peer_keys[&j].encrypt_block(&k.master, rng.as_mut())?;
            (Blob::new(s), k)
        };
        master.transport.call(
            proto::POST_PRENEG_KEYS,
            &proto::PostPrenegKeys { node: peer, keys: BTreeMap::from([(j, sealed)]) }
                .to_value(),
        )?;
        let resp = master.transport.call(
            proto::GET_PRENEG_KEY,
            &proto::GetPrenegKey { node: peer, owner: j }.to_value(),
        )?;
        let delivery = proto::PrenegKeyDelivery::from_value(&resp)?;
        let m = master
            .rsa_dec
            .get_or_init(|| master.keys.private.decrypt_ctx())
            .decrypt_block(delivery.key.as_bytes())?;
        let mut recv = (*master.recv_keys).clone();
        recv.insert(j, k);
        let mut send = (*master.send_keys).clone();
        send.insert(j, SymmetricKey::from_bytes(&m)?);
        let mut ctx = master.fork(self.round_rng(peer, rng_salt));
        ctx.recv_keys = Arc::new(recv);
        ctx.send_keys = Arc::new(send);
        self.replace_context(ctx);
        Ok(())
    }

    /// Key exchange for merge-reassigned nodes: when the planner merges a
    /// group's survivors into a neighbouring chain, the moved nodes and
    /// their new peers hold no key material for each other — fetch it,
    /// for the *new links only*. Links already keyed (same home group, a
    /// previous round's merge, or a rejoiner's full refresh) are skipped,
    /// so unmoved survivors never re-key — the same accounting discipline
    /// as rejoiner-only re-keys, extended to reassignment.
    fn rekey_reassigned(&self, plan: &TopologyPlan, epoch: u64) -> Result<()> {
        use crate::blob::Blob;
        if plan.reassignments().is_empty() || self.cfg.mode == CipherMode::None {
            // SAF mode holds no per-link key material, so a merge
            // reassignment moves nothing.
            return Ok(());
        }
        // RSA layer: each side of a new link fetches the other's public
        // key (both need it — predecessors seal *to* the moved node,
        // successors verify nothing but the moved node seals to them).
        for r in plan.reassignments() {
            let j = r.node;
            let chain = plan
                .chain(r.to_group)
                .context("reassignment targets a group missing from the plan")?
                .to_vec();
            let master = self.master_context(j)?;
            let mut pk = (*master.peer_keys).clone();
            let mut changed = false;
            for &peer in &chain {
                if peer == j || pk.contains_key(&peer) {
                    continue;
                }
                let resp = master
                    .transport
                    .call(proto::GET_KEY, &proto::GetKey { node: peer }.to_value())?;
                let delivery = proto::KeyDelivery::from_value(&resp)?;
                pk.insert(peer, RsaPublicKey::from_json(&delivery.key)?);
                changed = true;
            }
            if changed {
                let mut ctx = master.fork(self.round_rng(j, epoch ^ 0x4d));
                ctx.peer_keys = Arc::new(pk);
                self.replace_context(ctx);
            }
            for &peer in &chain {
                if peer == j {
                    continue;
                }
                let mp = self.master_context(peer)?;
                if mp.peer_keys.contains_key(&j) {
                    continue;
                }
                let resp = mp
                    .transport
                    .call(proto::GET_KEY, &proto::GetKey { node: j }.to_value())?;
                let delivery = proto::KeyDelivery::from_value(&resp)?;
                let mut pk = (*mp.peer_keys).clone();
                pk.insert(j, RsaPublicKey::from_json(&delivery.key)?);
                let mut ctx = mp.fork(self.round_rng(peer, epoch ^ 0x5e));
                ctx.peer_keys = Arc::new(pk);
                self.replace_context(ctx);
            }
        }
        if self.cfg.mode != CipherMode::PreNegotiated {
            return Ok(());
        }
        // §5.8 symmetric layer, new links only. For each moved node j and
        // unkeyed peer p: j generates its receive-key for p (one batched
        // post per moved node), p generates its receive-key for j and
        // posts it, then each pulls the other's fresh key.
        for r in plan.reassignments() {
            let j = r.node;
            let chain = plan
                .chain(r.to_group)
                .context("reassignment targets a group missing from the plan")?
                .to_vec();
            let master = self.master_context(j)?;
            let new_peers: Vec<u64> = chain
                .iter()
                .copied()
                .filter(|&p| p != j && !master.recv_keys.contains_key(&p))
                .collect();
            if new_peers.is_empty() {
                continue;
            }
            let mut sealed = BTreeMap::new();
            let mut mine = (*master.recv_keys).clone();
            {
                let mut rng = master.rng.lock().unwrap();
                for &peer in &new_peers {
                    let k = SymmetricKey::generate(rng.as_mut());
                    let s = master.peer_keys[&peer].encrypt_block(&k.master, rng.as_mut())?;
                    sealed.insert(peer, Blob::new(s));
                    mine.insert(peer, k);
                }
            }
            master.transport.call(
                proto::POST_PRENEG_KEYS,
                &proto::PostPrenegKeys { node: j, keys: sealed }.to_value(),
            )?;
            let mut ctx = master.fork(self.round_rng(j, epoch ^ 0x6f));
            ctx.recv_keys = Arc::new(mine);
            self.replace_context(ctx);
            // Each new peer reciprocates and the two sides pull.
            let mut send_keys = BTreeMap::new();
            for &peer in &new_peers {
                self.preneg_peer_refresh(j, peer, epoch ^ 0x70)?;
                // j pulls the key `peer` just generated for it.
                let master = self.master_context(j)?;
                let resp = master.transport.call(
                    proto::GET_PRENEG_KEY,
                    &proto::GetPrenegKey { node: j, owner: peer }.to_value(),
                )?;
                let delivery = proto::PrenegKeyDelivery::from_value(&resp)?;
                let m = master
                    .rsa_dec
                    .get_or_init(|| master.keys.private.decrypt_ctx())
                    .decrypt_block(delivery.key.as_bytes())?;
                send_keys.insert(peer, SymmetricKey::from_bytes(&m)?);
            }
            let master = self.master_context(j)?;
            let mut send = (*master.send_keys).clone();
            send.extend(send_keys);
            let mut ctx = master.fork(self.round_rng(j, epoch ^ 0x71));
            ctx.send_keys = Arc::new(send);
            self.replace_context(ctx);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceProfile;
    use std::time::Duration;

    fn quick_cfg(n: usize, features: usize, mode: CipherMode) -> SessionConfig {
        SessionConfig {
            n_nodes: n,
            features,
            mode,
            rsa_bits: 512, // fast for tests
            profile: DeviceProfile::instant(),
            poll_time: Duration::from_millis(100),
            aggregation_timeout: Duration::from_secs(10),
            progress_timeout: Duration::from_millis(400),
            monitor_interval: Duration::from_millis(50),
            ..Default::default()
        }
    }

    fn inputs(n: usize, features: usize) -> Vec<Vec<f64>> {
        (0..n)
            .map(|i| (0..features).map(|f| (i + 1) as f64 + f as f64 * 0.1).collect())
            .collect()
    }

    fn expected_average(inputs: &[Vec<f64>]) -> Vec<f64> {
        let n = inputs.len() as f64;
        let mut avg = vec![0.0; inputs[0].len()];
        for v in inputs {
            for (a, x) in avg.iter_mut().zip(v) {
                *a += x;
            }
        }
        avg.iter_mut().for_each(|a| *a /= n);
        avg
    }

    #[test]
    fn basic_round_all_modes() {
        for mode in [
            CipherMode::None,
            CipherMode::Hybrid,
            CipherMode::RsaOnly,
            CipherMode::PreNegotiated,
        ] {
            let session = SafeSession::new(quick_cfg(4, 3, mode)).unwrap();
            let ins = inputs(4, 3);
            let result = session.run_round(&ins, &FaultPlan::none()).unwrap();
            let expect = expected_average(&ins);
            for (a, e) in result.average().unwrap().iter().zip(&expect) {
                assert!((a - e).abs() < 1e-6, "{mode:?}: {a} vs {e}");
            }
            assert_eq!(result.metrics.contributors, 4, "{mode:?}");
            assert_eq!(result.metrics.progress_failovers, 0, "{mode:?}");
        }
    }

    #[test]
    fn message_count_is_4n_without_failures() {
        // §5.2: "an aggregation requires 4n messages". Long polls must not
        // retry for this to hold exactly, so poll_time is generous.
        let mut cfg = quick_cfg(5, 1, CipherMode::Hybrid);
        cfg.poll_time = Duration::from_secs(5);
        let session = SafeSession::new(cfg).unwrap();
        let ins = inputs(5, 1);
        let result = session.run_round(&ins, &FaultPlan::none()).unwrap();
        assert_eq!(result.metrics.messages, 4 * 5);
    }

    #[test]
    fn progress_failover_recovers_and_costs_2f_messages() {
        let mut cfg = quick_cfg(6, 2, CipherMode::Hybrid);
        cfg.poll_time = Duration::from_secs(5);
        cfg.progress_timeout = Duration::from_millis(300);
        let session = SafeSession::new(cfg).unwrap();
        let ins = inputs(6, 2);
        let faults = FaultPlan::kill_range(4, 4); // node 4 never starts
        let result = session.run_round(&ins, &faults).unwrap();
        // 5 contributors: all but node 4.
        assert_eq!(result.metrics.contributors, 5);
        assert_eq!(result.metrics.progress_failovers, 1);
        // Average over the 5 survivors' inputs.
        let mut expect = vec![0.0; 2];
        for (i, v) in ins.iter().enumerate() {
            if i + 1 == 4 {
                continue;
            }
            for (a, x) in expect.iter_mut().zip(v) {
                *a += x;
            }
        }
        expect.iter_mut().for_each(|a| *a /= 5.0);
        for (a, e) in result.average().unwrap().iter().zip(&expect) {
            assert!((a - e).abs() < 1e-6, "{a} vs {e}");
        }
        // §5.3: 4n + 2f — dead node sends nothing, so 4(n−1) + 2·1.
        assert_eq!(result.metrics.messages, 4 * 5 + 2);
    }

    #[test]
    fn subgroups_aggregate_in_parallel() {
        let mut cfg = quick_cfg(9, 2, CipherMode::Hybrid);
        cfg.groups = 3;
        cfg.poll_time = Duration::from_secs(5);
        let session = SafeSession::new(cfg).unwrap();
        let ins = inputs(9, 2);
        let result = session.run_round(&ins, &FaultPlan::none()).unwrap();
        // Equal group sizes ⇒ mean of group means == global mean.
        let expect = expected_average(&ins);
        for (a, e) in result.average().unwrap().iter().zip(&expect) {
            assert!((a - e).abs() < 1e-6, "{a} vs {e}");
        }
        // §5.5: one extra message per group (initiators pull the global
        // average): (4n) + g.
        assert_eq!(result.metrics.messages, 4 * 9 + 3);
    }

    #[test]
    fn run_rounds_reuses_keys_and_resets_state_between_rounds() {
        let mut cfg = quick_cfg(4, 2, CipherMode::Hybrid);
        cfg.poll_time = Duration::from_secs(5);
        let session = SafeSession::new(cfg).unwrap();
        let ins = inputs(4, 2);
        let per_round: Vec<Vec<Vec<f64>>> = (0..3).map(|_| ins.clone()).collect();
        let results = session.run_rounds(&per_round, &ChurnSchedule::none()).unwrap();
        assert_eq!(results.len(), 3);
        let expect = expected_average(&ins);
        for (i, r) in results.iter().enumerate() {
            for (a, e) in r.average().unwrap().iter().zip(&expect) {
                assert!((a - e).abs() < 1e-6, "round {i}: {a} vs {e}");
            }
            // §5.2 accounting holds every round — the round-epoch reset is
            // clean and costs no protocol messages.
            assert_eq!(r.metrics.messages, 4 * 4, "round {i}");
            assert_eq!(r.metrics.rekey_messages, 0, "round {i}");
            // Keys were exchanged once at session build; no key traffic in
            // any round.
            for path in [proto::REGISTER_KEY, proto::GET_KEY, proto::GET_PRENEG_KEY] {
                assert!(
                    !r.metrics.per_path.contains_key(path),
                    "round {i}: unexpected {path} traffic"
                );
            }
        }
    }

    #[test]
    fn run_rounds_empty_input_is_empty_output() {
        let session = SafeSession::new(quick_cfg(3, 1, CipherMode::None)).unwrap();
        assert!(session.run_rounds(&[], &ChurnSchedule::none()).unwrap().is_empty());
    }

    #[test]
    fn run_rounds_die_then_rejoin_rekeys_only_the_returner() {
        let mut cfg = quick_cfg(5, 1, CipherMode::Hybrid);
        cfg.poll_time = Duration::from_secs(5);
        cfg.progress_timeout = Duration::from_millis(300);
        let session = SafeSession::new(cfg).unwrap();
        let ins = inputs(5, 1);
        let per_round: Vec<Vec<Vec<f64>>> = (0..3).map(|_| ins.clone()).collect();
        let churn = ChurnSchedule::none()
            .die(4, 1, crate::learner::faults::FailPoint::NeverStart)
            .rejoin(4, 3);
        let results = session.run_rounds(&per_round, &churn).unwrap();
        assert_eq!(results.len(), 3);
        // Round 1: node 4 dies mid-round → failover, 4 contributors.
        assert_eq!(results[0].metrics.contributors, 4);
        assert_eq!(results[0].metrics.progress_failovers, 1);
        // Round 2: chain re-formed without node 4 — clean 4-node round.
        assert_eq!(results[1].metrics.contributors, 4);
        assert_eq!(results[1].metrics.progress_failovers, 0);
        assert_eq!(results[1].metrics.messages, 4 * 4);
        assert_eq!(results[1].metrics.rekey_messages, 0);
        // Round 3: node 4 rejoined — full membership again, and only its
        // key material moved: 1 register + 4 fetches by node 4 + 4 peers
        // re-fetching node 4's key.
        assert_eq!(results[2].metrics.contributors, 5);
        assert_eq!(results[2].metrics.messages, 4 * 5);
        assert_eq!(results[2].metrics.rekey_messages, 1 + 4 + 4);
        assert_eq!(results[2].metrics.per_path.get(proto::REGISTER_KEY), Some(&1));
        assert_eq!(results[2].metrics.per_path.get(proto::GET_KEY), Some(&8));
        let expect_r2: f64 = (1.0 + 2.0 + 3.0 + 5.0) / 4.0;
        assert!((results[1].average().unwrap()[0] - expect_r2).abs() < 1e-6);
        let expect_r3: f64 = (1.0 + 2.0 + 3.0 + 4.0 + 5.0) / 5.0;
        assert!((results[2].average().unwrap()[0] - expect_r3).abs() < 1e-6);
    }

    #[test]
    fn initiator_failover_elects_new_initiator() {
        let mut cfg = quick_cfg(4, 1, CipherMode::Hybrid);
        cfg.poll_time = Duration::from_millis(100);
        cfg.aggregation_timeout = Duration::from_millis(900);
        cfg.progress_timeout = Duration::from_millis(500);
        let session = SafeSession::new(cfg).unwrap();
        let ins = inputs(4, 1);
        let faults = FaultPlan::none().kill(1, crate::learner::faults::FailPoint::InitiatorAfterPost);
        let result = session.run_round(&ins, &faults).unwrap();
        assert!(result.metrics.initiator_failovers >= 1);
        let survivors = result.survivors();
        assert_eq!(survivors.len(), 3);
        // A new initiator emerged among 2..4.
        assert!(survivors.iter().any(|o| o.was_initiator && o.node != 1));
        // The average covers the 3 survivors (initiator's value lost with
        // it; it is skipped via progress failover on the second pass).
        let expect: f64 = (2.0 + 3.0 + 4.0) / 3.0;
        assert!((result.average().unwrap()[0] - expect).abs() < 1e-6);
    }
}
