//! Weighted averaging (§5.6): each learner contributes `x·w` plus its
//! weight `w` as one extra feature. The aggregation then yields
//! (mean(x·w), mean(w)); dividing recovers the true sample-weighted
//! average without revealing any node's sample count.

use anyhow::{bail, Result};

/// Encode a local average `x` computed from `weight` samples into the
/// wire vector: `[x₀·w, x₁·w, …, w]`.
pub fn encode(x: &[f64], weight: f64) -> Vec<f64> {
    assert!(weight > 0.0, "weight must be positive");
    let mut v: Vec<f64> = x.iter().map(|a| a * weight).collect();
    v.push(weight);
    v
}

/// Decode the aggregated average-of-encodings back into the weighted
/// average: `avg[i] = mean(xᵢ·w) / mean(w)`.
pub fn decode(agg: &[f64]) -> Result<Vec<f64>> {
    if agg.len() < 2 {
        bail!("weighted aggregate needs at least 2 features");
    }
    let mean_w = agg[agg.len() - 1];
    if mean_w <= 0.0 {
        bail!("non-positive mean weight {mean_w}");
    }
    Ok(agg[..agg.len() - 1].iter().map(|a| a / mean_w).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weighted_average_correctness() {
        // §5.6's example: one node averages 1000 samples, another 10000.
        // Node A: local mean 2.0 over 1000; Node B: local mean 5.0 over
        // 10000. True mean = (2*1000 + 5*10000) / 11000.
        let a = encode(&[2.0], 1000.0);
        let b = encode(&[5.0], 10000.0);
        // The chain computes the plain mean of the encoded vectors.
        let agg: Vec<f64> = a.iter().zip(&b).map(|(x, y)| (x + y) / 2.0).collect();
        let avg = decode(&agg).unwrap();
        let expect = (2.0 * 1000.0 + 5.0 * 10000.0) / 11000.0;
        assert!((avg[0] - expect).abs() < 1e-9, "{} vs {}", avg[0], expect);
    }

    #[test]
    fn equal_weights_reduce_to_plain_mean() {
        let vs = [vec![1.0, 4.0], vec![3.0, 8.0]];
        let encoded: Vec<Vec<f64>> = vs.iter().map(|v| encode(v, 7.0)).collect();
        let agg: Vec<f64> = (0..3)
            .map(|i| (encoded[0][i] + encoded[1][i]) / 2.0)
            .collect();
        let avg = decode(&agg).unwrap();
        assert!((avg[0] - 2.0).abs() < 1e-12);
        assert!((avg[1] - 6.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_degenerate_input() {
        assert!(decode(&[1.0]).is_err());
        assert!(decode(&[1.0, 0.0]).is_err());
    }
}
