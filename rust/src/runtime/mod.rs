//! PJRT runtime: load the AOT-compiled L1/L2 artifacts and execute them
//! from the Rust hot path.
//!
//! `python/compile/aot.py` lowers the JAX/Pallas compute graphs to HLO
//! *text* (xla_extension 0.5.1 rejects jax≥0.5 serialized protos — see
//! /opt/xla-example/README.md) under `artifacts/`. This module compiles
//! them once per process on the PJRT CPU client and exposes:
//!
//! * [`vector::VectorMath`] implementations — `NativeMath` (plain loops)
//!   and [`XlaMath`] (chain ops through the compiled kernels, bucketed by
//!   power-of-two feature size with zero padding);
//! * [`TrainStepExecutable`] — the L2 MLP train step used by the
//!   federated-learning harness (`fl`), so Python never runs at training
//!   time.

pub mod vector;
pub mod xla_exec;

pub use vector::{NativeMath, VectorMath};
pub use xla_exec::{ArtifactRuntime, TrainStepExecutable, XlaMath};
