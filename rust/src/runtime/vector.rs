//! Vector math engine used on the aggregation hot path.
//!
//! Learners do three vector operations per round: mask the local vector
//! (initiator), add the local vector into the running aggregate
//! (non-initiators), and unmask-and-divide (initiator finalize). The
//! engine trait lets the coordinator run these either natively or through
//! the AOT-compiled XLA artifacts (L1 Pallas kernels lowered by
//! `python/compile/aot.py`) — `runtime::xla` provides the latter, and the
//! `ablations` bench compares the two.

/// Engine for the chain's vector arithmetic.
pub trait VectorMath: Send + Sync {
    /// acc[i] += x[i]
    fn add_assign(&self, acc: &mut [f64], x: &[f64]);

    /// out[i] = x[i] + mask[i]  (initiator masking step)
    fn mask(&self, x: &[f64], mask: &[f64]) -> Vec<f64>;

    /// out[i] = (agg[i] − mask[i]) / divisor  (initiator finalize step)
    fn finalize(&self, agg: &[f64], mask: &[f64], divisor: f64) -> Vec<f64>;

    /// Human-readable engine name (for bench labels).
    fn name(&self) -> &'static str;
}

/// Plain Rust loops — the baseline engine.
pub struct NativeMath;

impl VectorMath for NativeMath {
    fn add_assign(&self, acc: &mut [f64], x: &[f64]) {
        assert_eq!(acc.len(), x.len(), "vector length mismatch");
        for (a, b) in acc.iter_mut().zip(x) {
            *a += b;
        }
    }

    fn mask(&self, x: &[f64], mask: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), mask.len(), "vector length mismatch");
        x.iter().zip(mask).map(|(a, b)| a + b).collect()
    }

    fn finalize(&self, agg: &[f64], mask: &[f64], divisor: f64) -> Vec<f64> {
        assert_eq!(agg.len(), mask.len(), "vector length mismatch");
        assert!(divisor != 0.0, "divide by zero contributors");
        agg.iter().zip(mask).map(|(a, m)| (a - m) / divisor).collect()
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_ops() {
        let m = NativeMath;
        let mut acc = vec![1.0, 2.0, 3.0];
        m.add_assign(&mut acc, &[10.0, 20.0, 30.0]);
        assert_eq!(acc, vec![11.0, 22.0, 33.0]);
        let masked = m.mask(&[1.0, 2.0], &[100.0, 200.0]);
        assert_eq!(masked, vec![101.0, 202.0]);
        let fin = m.finalize(&[103.0, 206.0], &[100.0, 200.0], 3.0);
        assert_eq!(fin, vec![1.0, 2.0]);
    }

    #[test]
    fn mask_then_finalize_is_identity_average() {
        // The protocol invariant: masking cancels exactly.
        let m = NativeMath;
        let x1 = vec![1.5, -2.0, 0.25];
        let x2 = vec![0.5, 4.0, 0.75];
        let mask = vec![9.9e9, -3.3e8, 1.1e7];
        let mut agg = m.mask(&x1, &mask);
        m.add_assign(&mut agg, &x2);
        let avg = m.finalize(&agg, &mask, 2.0);
        for (a, e) in avg.iter().zip([1.0, 1.0, 0.5]) {
            assert!((a - e).abs() < 1e-6, "{} vs {}", a, e);
        }
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn length_mismatch_panics() {
        NativeMath.add_assign(&mut [1.0][..].to_vec(), &[1.0, 2.0]);
    }
}
