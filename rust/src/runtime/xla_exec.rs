//! XLA artifact loading and execution (the L3↔L2 bridge).
//!
//! Artifacts are HLO text files produced once by `make artifacts`
//! (`python/compile/aot.py`); this module compiles them on the PJRT CPU
//! client at first use and caches the loaded executables. Feature vectors
//! are padded to the next bucket size because PJRT executables are
//! fixed-shape (see DESIGN.md §2).
//!
//! Threading: the `xla` crate's client/executable handles are `Rc`-based
//! and not `Send`/`Sync`, so a dedicated executor thread owns them; the
//! public [`ArtifactRuntime`] is a thread-safe facade that ships requests
//! over a channel. Execution is therefore serialized per runtime — one
//! more reason the learner-side [`XlaMath`] engine only wins for large
//! vectors (measured in the `ablations` bench).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Mutex;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::vector::VectorMath;

/// Feature-size buckets compiled by aot.py (f64 chain ops).
pub const BUCKETS: [usize; 4] = [16, 256, 4096, 16384];

/// Smallest bucket that fits `n` features, or None if it exceeds the max
/// bucket (callers then chunk by the max bucket).
pub fn bucket_for(n: usize) -> Option<usize> {
    BUCKETS.iter().copied().find(|&b| b >= n)
}

enum Request {
    ExecF64 {
        name: String,
        inputs: Vec<Vec<f64>>,
        reply: mpsc::SyncSender<Result<Vec<Vec<f64>>>>,
    },
    ExecF32 {
        name: String,
        inputs: Vec<Vec<f32>>,
        reply: mpsc::SyncSender<Result<Vec<Vec<f32>>>>,
    },
    Warm {
        name: String,
        reply: mpsc::SyncSender<Result<()>>,
    },
}

/// Thread-safe handle to the PJRT executor thread.
pub struct ArtifactRuntime {
    tx: Mutex<mpsc::Sender<Request>>,
    dir: PathBuf,
}

struct Executor {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Executor {
    fn load(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            if !path.exists() {
                bail!("artifact {:?} not found — run `make artifacts`", path);
            }
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("parse HLO {:?}: {e}", path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {:?}: {e}", path))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(self.cache.get(name).unwrap())
    }

    fn exec_literals(
        &mut self,
        name: &str,
        literals: Vec<xla::Literal>,
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.load(name)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("sync {name}: {e}"))?;
        result.to_tuple().map_err(|e| anyhow::anyhow!("tuple {name}: {e}"))
    }

    fn serve(mut self, rx: mpsc::Receiver<Request>) {
        while let Ok(req) = rx.recv() {
            match req {
                Request::ExecF64 { name, inputs, reply } => {
                    let literals: Vec<xla::Literal> =
                        inputs.iter().map(|v| xla::Literal::vec1(&v[..])).collect();
                    let out = self.exec_literals(&name, literals).and_then(|parts| {
                        parts
                            .into_iter()
                            .map(|l| {
                                l.to_vec::<f64>().map_err(|e| anyhow::anyhow!("read {name}: {e}"))
                            })
                            .collect()
                    });
                    let _ = reply.send(out);
                }
                Request::ExecF32 { name, inputs, reply } => {
                    let literals: Vec<xla::Literal> =
                        inputs.iter().map(|v| xla::Literal::vec1(&v[..])).collect();
                    let out = self.exec_literals(&name, literals).and_then(|parts| {
                        parts
                            .into_iter()
                            .map(|l| {
                                l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("read {name}: {e}"))
                            })
                            .collect()
                    });
                    let _ = reply.send(out);
                }
                Request::Warm { name, reply } => {
                    let _ = reply.send(self.load(&name).map(|_| ()));
                }
            }
        }
    }
}

impl ArtifactRuntime {
    /// Create a runtime rooted at `dir` (usually `artifacts/`).
    pub fn new(dir: impl AsRef<Path>) -> Result<ArtifactRuntime> {
        let dir = dir.as_ref().to_path_buf();
        let (tx, rx) = mpsc::channel();
        let (ready_tx, ready_rx) = mpsc::sync_channel(1);
        let dir2 = dir.clone();
        std::thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || match xla::PjRtClient::cpu() {
                Ok(client) => {
                    let _ = ready_tx.send(Ok(()));
                    Executor { client, dir: dir2, cache: HashMap::new() }.serve(rx);
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(anyhow::anyhow!("PJRT CPU client: {e}")));
                }
            })
            .context("spawn pjrt executor")?;
        ready_rx.recv().context("executor thread died")??;
        Ok(ArtifactRuntime { tx: Mutex::new(tx), dir })
    }

    /// True if `dir` looks like a built artifacts directory.
    pub fn available(dir: impl AsRef<Path>) -> bool {
        dir.as_ref().join("manifest.json").exists()
    }

    /// Locate the artifacts dir: `$SAFE_ARTIFACTS`, else `artifacts/`
    /// under the crate root.
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("SAFE_ARTIFACTS") {
            return PathBuf::from(d);
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    pub fn manifest(&self) -> Result<crate::json::Value> {
        let text = std::fs::read_to_string(self.dir.join("manifest.json"))
            .context("read artifacts/manifest.json — run `make artifacts` first")?;
        crate::json::parse(&text)
    }

    fn send(&self, req: Request) {
        self.tx.lock().unwrap().send(req).expect("pjrt executor thread is gone");
    }

    /// Compile `name` now so later calls never hit compilation.
    pub fn warm(&self, name: &str) -> Result<()> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.send(Request::Warm { name: name.to_string(), reply });
        rx.recv().context("executor dropped warm request")?
    }

    /// Execute `name` with f64 vector inputs; returns the flattened f64
    /// outputs of the result tuple.
    pub fn exec_f64(&self, name: &str, inputs: &[&[f64]]) -> Result<Vec<Vec<f64>>> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.send(Request::ExecF64 {
            name: name.to_string(),
            inputs: inputs.iter().map(|v| v.to_vec()).collect(),
            reply,
        });
        rx.recv().context("executor dropped exec request")?
    }

    /// Execute `name` with f32 inputs; returns flattened f32 outputs.
    pub fn exec_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.send(Request::ExecF32 {
            name: name.to_string(),
            inputs: inputs.iter().map(|v| v.to_vec()).collect(),
            reply,
        });
        rx.recv().context("executor dropped exec request")?
    }
}

/// [`VectorMath`] engine backed by the AOT Pallas kernels.
pub struct XlaMath {
    rt: Arc<ArtifactRuntime>,
}

impl XlaMath {
    pub fn new(rt: Arc<ArtifactRuntime>) -> Self {
        XlaMath { rt }
    }

    /// elementwise a+b through the chain_add kernel, chunked by bucket.
    fn add_vec(&self, a: &[f64], b: &[f64]) -> Vec<f64> {
        assert_eq!(a.len(), b.len(), "vector length mismatch");
        let mut out = Vec::with_capacity(a.len());
        let max = *BUCKETS.last().unwrap();
        for (ca, cb) in a.chunks(max).zip(b.chunks(max)) {
            let bucket = bucket_for(ca.len()).unwrap_or(max);
            let mut pa = ca.to_vec();
            let mut pb = cb.to_vec();
            pa.resize(bucket, 0.0);
            pb.resize(bucket, 0.0);
            let res = self
                .rt
                .exec_f64(&format!("chain_add_{bucket}"), &[&pa, &pb])
                .expect("chain_add artifact execution");
            out.extend_from_slice(&res[0][..ca.len()]);
        }
        out
    }
}

impl VectorMath for XlaMath {
    fn add_assign(&self, acc: &mut [f64], x: &[f64]) {
        let r = self.add_vec(acc, x);
        acc.copy_from_slice(&r);
    }

    fn mask(&self, x: &[f64], mask: &[f64]) -> Vec<f64> {
        self.add_vec(x, mask)
    }

    fn finalize(&self, agg: &[f64], mask: &[f64], divisor: f64) -> Vec<f64> {
        assert_eq!(agg.len(), mask.len(), "vector length mismatch");
        assert!(divisor != 0.0);
        let mut out = Vec::with_capacity(agg.len());
        let max = *BUCKETS.last().unwrap();
        let div = [divisor];
        for (ca, cm) in agg.chunks(max).zip(mask.chunks(max)) {
            let bucket = bucket_for(ca.len()).unwrap_or(max);
            let mut pa = ca.to_vec();
            let mut pm = cm.to_vec();
            pa.resize(bucket, 0.0);
            pm.resize(bucket, 0.0);
            let res = self
                .rt
                .exec_f64(&format!("finalize_{bucket}"), &[&pa, &pm, &div])
                .expect("finalize artifact execution");
            out.extend_from_slice(&res[0][..ca.len()]);
        }
        out
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// The L2 train step (one SGD update of the 2-layer MLP in
/// `python/compile/model.py`), executed through PJRT.
pub struct TrainStepExecutable {
    rt: Arc<ArtifactRuntime>,
    pub dim_in: usize,
    pub dim_hidden: usize,
    pub dim_out: usize,
    pub batch: usize,
}

impl TrainStepExecutable {
    pub fn load(rt: Arc<ArtifactRuntime>) -> Result<TrainStepExecutable> {
        let man = rt.manifest()?;
        let ts = man.get("train_step").context("manifest missing train_step")?;
        let dim_in = ts.u64_of("in").context("in")? as usize;
        let dim_hidden = ts.u64_of("hidden").context("hidden")? as usize;
        let dim_out = ts.u64_of("out").context("out")? as usize;
        let batch = ts.u64_of("batch").context("batch")? as usize;
        // Force compilation now so the hot loop never compiles.
        rt.warm("train_step")?;
        rt.warm("predict_loss")?;
        Ok(TrainStepExecutable { rt, dim_in, dim_hidden, dim_out, batch })
    }

    /// Total parameter count (the feature-vector length SAFE aggregates).
    pub fn param_count(&self) -> usize {
        self.dim_in * self.dim_hidden
            + self.dim_hidden
            + self.dim_hidden * self.dim_out
            + self.dim_out
    }

    fn split_params<'a>(&self, p: &'a [f32]) -> Vec<&'a [f32]> {
        let s1 = self.dim_in * self.dim_hidden;
        let s2 = s1 + self.dim_hidden;
        let s3 = s2 + self.dim_hidden * self.dim_out;
        let s4 = s3 + self.dim_out;
        vec![&p[..s1], &p[s1..s2], &p[s2..s3], &p[s3..s4]]
    }

    /// One SGD step: returns (updated params, batch loss).
    pub fn step(&self, params: &[f32], x: &[f32], y: &[f32], lr: f32) -> Result<(Vec<f32>, f32)> {
        assert_eq!(params.len(), self.param_count(), "param vector length");
        assert_eq!(x.len(), self.batch * self.dim_in, "x shape");
        assert_eq!(y.len(), self.batch * self.dim_out, "y shape");
        let lr_in = [lr];
        let mut inputs: Vec<&[f32]> = self.split_params(params);
        inputs.push(x);
        inputs.push(y);
        inputs.push(&lr_in);
        let out = self.rt.exec_f32("train_step", &inputs)?;
        if out.len() != 5 {
            bail!("train_step returned {} outputs, expected 5", out.len());
        }
        let mut new_params = Vec::with_capacity(self.param_count());
        for part in &out[..4] {
            new_params.extend_from_slice(part);
        }
        Ok((new_params, out[4][0]))
    }

    /// Evaluate loss without updating (for validation curves).
    pub fn loss(&self, params: &[f32], x: &[f32], y: &[f32]) -> Result<f32> {
        let mut inputs: Vec<&[f32]> = self.split_params(params);
        inputs.push(x);
        inputs.push(y);
        let out = self.rt.exec_f32("predict_loss", &inputs)?;
        Ok(out[0][0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Arc<ArtifactRuntime>> {
        let dir = ArtifactRuntime::default_dir();
        if !ArtifactRuntime::available(&dir) {
            eprintln!("artifacts not built; skipping XLA runtime test");
            return None;
        }
        Some(Arc::new(ArtifactRuntime::new(dir).unwrap()))
    }

    #[test]
    fn bucket_selection() {
        assert_eq!(bucket_for(1), Some(16));
        assert_eq!(bucket_for(16), Some(16));
        assert_eq!(bucket_for(17), Some(256));
        assert_eq!(bucket_for(10_000), Some(16384));
        assert_eq!(bucket_for(20_000), None);
    }

    #[test]
    fn xla_math_matches_native() {
        let Some(rt) = runtime() else { return };
        let xla = XlaMath::new(rt);
        let native = super::super::vector::NativeMath;
        for n in [1usize, 7, 16, 100, 5000, 20000] {
            let a: Vec<f64> = (0..n).map(|i| i as f64 * 0.25 - 3.0).collect();
            let b: Vec<f64> = (0..n).map(|i| (i as f64).sin() * 100.0).collect();
            let mut acc1 = a.clone();
            xla.add_assign(&mut acc1, &b);
            let mut acc2 = a.clone();
            native.add_assign(&mut acc2, &b);
            assert_eq!(acc1, acc2, "add n={n}");
            let f1 = xla.finalize(&a, &b, 7.0);
            let f2 = native.finalize(&a, &b, 7.0);
            for (x, y) in f1.iter().zip(&f2) {
                assert!((x - y).abs() < 1e-12, "finalize n={n}");
            }
        }
    }

    #[test]
    fn xla_math_usable_from_many_threads() {
        let Some(rt) = runtime() else { return };
        let xla = Arc::new(XlaMath::new(rt));
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let xla = xla.clone();
                std::thread::spawn(move || {
                    let a = vec![t as f64; 100];
                    let b = vec![1.0; 100];
                    let r = xla.mask(&a, &b);
                    assert_eq!(r[0], t as f64 + 1.0);
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
    }

    #[test]
    fn train_step_decreases_loss() {
        let Some(rt) = runtime() else { return };
        let ts = TrainStepExecutable::load(rt).unwrap();
        let mut rng = crate::crypto::DeterministicRng::seed(3);
        use crate::crypto::rng::SecureRng;
        let mut params: Vec<f32> =
            (0..ts.param_count()).map(|_| (rng.next_f64() as f32 - 0.5) * 0.2).collect();
        let x: Vec<f32> = (0..ts.batch * ts.dim_in).map(|_| rng.next_f64() as f32).collect();
        // Learnable target: y = mean(x) per row replicated.
        let y: Vec<f32> = (0..ts.batch)
            .flat_map(|r| {
                let m: f32 =
                    x[r * ts.dim_in..(r + 1) * ts.dim_in].iter().sum::<f32>() / ts.dim_in as f32;
                vec![m; ts.dim_out]
            })
            .collect();
        let l0 = ts.loss(&params, &x, &y).unwrap();
        for _ in 0..50 {
            let (p, _l) = ts.step(&params, &x, &y, 0.1).unwrap();
            params = p;
        }
        let l1 = ts.loss(&params, &x, &y).unwrap();
        assert!(l1 < l0 * 0.5, "loss did not decrease: {l0} -> {l1}");
    }
}
