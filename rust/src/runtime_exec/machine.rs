//! The learner as an explicit resumable state machine.
//!
//! [`LearnerStateMachine::on_event`] is a faithful transcription of the
//! blocking learner (`learner::run_learner` / `run_initiator` /
//! `run_non_initiator`): every point where the blocking code parks an OS
//! thread — a `wait_for` long-poll, `post_and_watch`'s check loop, the
//! §5.9 stagger sleep — becomes a returned [`Command`] and a later
//! [`MachineEvent`]. Control flow, fault-injection points, deadline
//! checks and message order are kept line-for-line equivalent so the two
//! runtimes produce bit-identical averages and message accounting (the
//! `runtime_differential` test pins this).

use std::sync::Arc;
use std::time::Instant;

use anyhow::anyhow;

use crate::crypto::envelope::Envelope;
use crate::json::Value;
use crate::learner::faults::{FailPoint, FaultPlan};
use crate::learner::{hard_deadline_for, post_body, LearnerContext, LearnerOutcome};
use crate::proto;

/// What the machine needs next from the executor.
pub enum Command {
    /// Submit `path` with `body`; resume with the response
    /// ([`MachineEvent::Response`]). Empty-status responses at poll
    /// timeout are delivered the same way, exactly as the blocking
    /// transport returns them.
    Call { path: &'static str, body: Value },
    /// Park until `until`, then resume with [`MachineEvent::TimerFired`]
    /// (§5.9 stagger, without occupying a worker).
    Sleep { until: Instant },
    /// Terminal: the learner finished (possibly dead / timed out).
    Finished(Box<LearnerOutcome>),
    /// Terminal: a protocol or crypto error (same errors the blocking
    /// path would return through `run_learner`).
    Failed(anyhow::Error),
}

/// What happened since the machine last returned.
pub enum MachineEvent {
    /// First event after spawn.
    Start,
    /// The response to the outstanding [`Command::Call`].
    Response(Value),
    /// The outstanding [`Command::Sleep`] elapsed.
    TimerFired,
}

/// Which role's `post_and_watch` we are inside (the step after the watch
/// completes differs).
#[derive(Clone, Copy)]
enum Role {
    Initiator,
    NonInitiator,
}

enum State {
    /// Not started yet.
    Idle,
    /// §5.9: holding off the first `get_aggregate` poll.
    Staggering { deadline: Instant },
    /// Non-initiator step 1: polling `get_aggregate`.
    AwaitAggregate { deadline: Instant },
    /// Waiting for the `post_aggregate` ack (response ignored, as in the
    /// blocking path).
    AwaitPostAck { vector: Vec<f64>, to: u64, msg_round: u64, deadline: Instant, role: Role },
    /// `post_and_watch`'s check loop: polling `check_aggregate(to)`.
    Watching { vector: Vec<f64>, to: u64, msg_round: u64, deadline: Instant, role: Role },
    /// Initiator step 3: polling `get_aggregate` for the chain's result.
    AwaitFinalAggregate { deadline: Instant },
    /// Waiting for the `post_average` ack.
    AwaitAveragePostAck { deadline: Instant, average: Vec<f64>, contributors: u64 },
    /// Initiator, subgroups: polling `get_average` for the global mean.
    AwaitGlobalAverage { deadline: Instant, contributors: u64 },
    /// Non-initiator step 3: polling `get_average`.
    AwaitAverage { deadline: Instant },
    /// Asked `should_initiate`; awaiting the election decision.
    AwaitElection,
    /// Terminal; any further event is a runtime bug.
    Finished,
}

pub struct LearnerStateMachine {
    ctx: Arc<LearnerContext>,
    local: Vec<f64>,
    faults: FaultPlan,
    state: State,
    restarts: u64,
    reposts: u64,
    round_id: u64,
    is_initiator: bool,
    /// The initiator's mask for the current attempt (regenerated on every
    /// restart, like the blocking path's per-call `gen_mask`).
    mask: Option<Vec<f64>>,
    started: Instant,
}

impl LearnerStateMachine {
    pub fn new(ctx: Arc<LearnerContext>, local: Vec<f64>, faults: FaultPlan) -> Self {
        let is_initiator = ctx.node == ctx.initial_initiator;
        LearnerStateMachine {
            ctx,
            local,
            faults,
            state: State::Idle,
            restarts: 0,
            reposts: 0,
            round_id: 0,
            is_initiator,
            mask: None,
            started: Instant::now(),
        }
    }

    pub fn node(&self) -> u64 {
        self.ctx.node
    }

    /// Advance the machine. Must be called with the event the previous
    /// [`Command`] asked for; the executor serializes calls per machine.
    pub fn on_event(&mut self, event: MachineEvent) -> Command {
        match event {
            MachineEvent::Start => self.start(),
            MachineEvent::TimerFired => self.timer_fired(),
            MachineEvent::Response(resp) => self.response(resp),
        }
    }

    fn start(&mut self) -> Command {
        if !matches!(self.state, State::Idle) {
            return self.bug("Start event on a running machine");
        }
        if self.faults.fails_at(self.ctx.node, FailPoint::NeverStart) {
            return self.finish(LearnerOutcome::dead(self.ctx.node));
        }
        self.started = Instant::now();
        self.begin_iteration()
    }

    fn timer_fired(&mut self) -> Command {
        match std::mem::replace(&mut self.state, State::Finished) {
            State::Staggering { deadline } => self.await_aggregate(deadline),
            _ => self.bug("TimerFired outside Staggering"),
        }
    }

    /// Top of the blocking path's outer `loop`: hard-deadline check, then
    /// one initiator or non-initiator attempt.
    fn begin_iteration(&mut self) -> Command {
        if Instant::now()
            > hard_deadline_for(self.started, self.ctx.aggregation_timeout, self.restarts)
        {
            return self.finish(LearnerOutcome::timed_out(
                self.ctx.node,
                self.reposts,
                self.restarts,
            ));
        }
        let deadline = Instant::now() + self.ctx.aggregation_timeout;
        if self.is_initiator {
            // §5.1.1 steps 1–2: mask with R, seal for the successor, post.
            let mask = self.ctx.gen_mask(self.local.len());
            let masked = self.ctx.math.mask(&self.local, &mask);
            self.mask = Some(mask);
            let next = self.ctx.successor(self.ctx.node);
            self.begin_post(masked, next, self.round_id, deadline, Role::Initiator)
        } else if !self.ctx.stagger_delay.is_zero() {
            // §5.9: same hold-off as the blocking `thread::sleep`, but as
            // a timer entry — the deadline clock starts now, before the
            // stagger, exactly like the blocking path.
            self.state = State::Staggering { deadline };
            Command::Sleep { until: Instant::now() + self.ctx.stagger_delay }
        } else {
            self.await_aggregate(deadline)
        }
    }

    fn await_aggregate(&mut self, deadline: Instant) -> Command {
        self.state = State::AwaitAggregate { deadline };
        Command::Call {
            path: proto::GET_AGGREGATE,
            body: proto::NodeOp::new(self.ctx.node, self.ctx.group).to_value(),
        }
    }

    /// Seal + post (`post_and_watch`'s entry): the watch starts when the
    /// post is acked.
    fn begin_post(
        &mut self,
        vector: Vec<f64>,
        to: u64,
        msg_round: u64,
        deadline: Instant,
        role: Role,
    ) -> Command {
        let env = match self.ctx.seal_for(&vector, to) {
            Ok(e) => e,
            Err(e) => return self.fail(e),
        };
        let body = post_body(&self.ctx, to, &env, msg_round);
        self.state = State::AwaitPostAck { vector, to, msg_round, deadline, role };
        Command::Call { path: proto::POST_AGGREGATE, body }
    }

    fn watch(&mut self, vector: Vec<f64>, to: u64, msg_round: u64, deadline: Instant, role: Role) -> Command {
        let body = proto::NodeOp::new(to, self.ctx.group).to_value();
        self.state = State::Watching { vector, to, msg_round, deadline, role };
        Command::Call { path: proto::CHECK_AGGREGATE, body }
    }

    /// §5.4: the aggregation deadline passed — ask to take over.
    fn election(&mut self) -> Command {
        self.state = State::AwaitElection;
        Command::Call {
            path: proto::SHOULD_INITIATE,
            body: proto::NodeOp::new(self.ctx.node, self.ctx.group).to_value(),
        }
    }

    fn response(&mut self, resp: Value) -> Command {
        match std::mem::replace(&mut self.state, State::Finished) {
            State::AwaitAggregate { deadline } => self.on_aggregate(resp, deadline),
            State::AwaitPostAck { vector, to, msg_round, deadline, role } => {
                // Post ack content is ignored (blocking path likewise).
                self.watch(vector, to, msg_round, deadline, role)
            }
            State::Watching { vector, to, msg_round, deadline, role } => {
                self.on_check(resp, vector, to, msg_round, deadline, role)
            }
            State::AwaitFinalAggregate { deadline } => self.on_final_aggregate(resp, deadline),
            State::AwaitAveragePostAck { deadline, average, contributors } => {
                // §5.5: with subgroups the initiator also pulls the global
                // cross-group average (the "+g" message).
                if self.ctx.multi_group() {
                    self.state = State::AwaitGlobalAverage { deadline, contributors };
                    Command::Call {
                        path: proto::GET_AVERAGE,
                        body: proto::NodeOp::new(self.ctx.node, self.ctx.group).to_value(),
                    }
                } else {
                    self.done(average, contributors)
                }
            }
            State::AwaitGlobalAverage { deadline, contributors } => {
                if proto::is_empty_status(&resp) {
                    return self.retry_or_elect(deadline, |m, d| {
                        m.state = State::AwaitGlobalAverage { deadline: d, contributors };
                        Command::Call {
                            path: proto::GET_AVERAGE,
                            body: proto::NodeOp::new(m.ctx.node, m.ctx.group).to_value(),
                        }
                    });
                }
                match proto::AverageReady::from_value(&resp) {
                    Ok(r) => self.done(r.average, contributors),
                    Err(e) => self.fail(e),
                }
            }
            State::AwaitAverage { deadline } => {
                if proto::is_empty_status(&resp) {
                    return self.retry_or_elect(deadline, |m, d| {
                        m.state = State::AwaitAverage { deadline: d };
                        Command::Call {
                            path: proto::GET_AVERAGE,
                            body: proto::NodeOp::new(m.ctx.node, m.ctx.group).to_value(),
                        }
                    });
                }
                match proto::AverageReady::from_value(&resp) {
                    Ok(r) => self.done(r.average, 0),
                    Err(e) => self.fail(e),
                }
            }
            State::AwaitElection => match proto::InitiateDecision::from_value(&resp) {
                Ok(decision) => {
                    self.restarts += 1;
                    self.is_initiator = decision.init;
                    self.round_id = decision.round_id;
                    self.begin_iteration()
                }
                Err(e) => self.fail(e),
            },
            State::Idle | State::Staggering { .. } | State::Finished => {
                self.bug("Response in a non-waiting state")
            }
        }
    }

    /// Non-initiator step 1 response (§5.1.2): decrypt, add, post onward.
    fn on_aggregate(&mut self, resp: Value, deadline: Instant) -> Command {
        if proto::is_empty_status(&resp) {
            return self.retry_or_elect(deadline, |m, d| m.await_aggregate(d));
        }
        if self.faults.fails_at(self.ctx.node, FailPoint::AfterGet) {
            return self.finish(LearnerOutcome::dead(self.ctx.node));
        }
        let delivery = match proto::AggregateDelivery::from_value(&resp) {
            Ok(d) => d,
            Err(e) => return self.fail(e),
        };
        let msg_round = delivery.round_id.unwrap_or(self.round_id);
        let env = match Envelope::from_blob(&delivery.aggregate) {
            Ok(e) => e,
            Err(e) => return self.fail(e),
        };
        let mut agg = match self.ctx.open_from(&env, delivery.from_node) {
            Ok(a) => a,
            Err(e) => return self.fail(e),
        };
        self.ctx.math.add_assign(&mut agg, &self.local);
        let next = self.ctx.successor(self.ctx.node);
        self.begin_post(agg, next, msg_round, deadline, Role::NonInitiator)
    }

    /// A `check_aggregate` response inside `post_and_watch`'s loop.
    fn on_check(
        &mut self,
        resp: Value,
        vector: Vec<f64>,
        to: u64,
        msg_round: u64,
        deadline: Instant,
        role: Role,
    ) -> Command {
        if proto::is_empty_status(&resp) {
            return self.retry_or_elect(deadline, move |m, d| m.watch(vector, to, msg_round, d, role));
        }
        match proto::CheckOutcome::from_value(&resp) {
            Err(e) => self.fail(e),
            Ok(proto::CheckOutcome::Consumed) => self.after_watch(deadline, role),
            Ok(proto::CheckOutcome::Repost { to_node: new_target }) => {
                // §5.3: re-encrypt for the node after the failed one.
                self.reposts += 1;
                self.begin_post(vector, new_target, msg_round, deadline, role)
            }
        }
    }

    /// `post_and_watch` returned true — continue the role's next step.
    fn after_watch(&mut self, deadline: Instant, role: Role) -> Command {
        match role {
            Role::Initiator => {
                if self.faults.fails_at(self.ctx.node, FailPoint::InitiatorAfterPost) {
                    return self.finish(LearnerOutcome::dead(self.ctx.node));
                }
                self.state = State::AwaitFinalAggregate { deadline };
                Command::Call {
                    path: proto::GET_AGGREGATE,
                    body: proto::NodeOp::new(self.ctx.node, self.ctx.group).to_value(),
                }
            }
            Role::NonInitiator => {
                if self.faults.fails_at(self.ctx.node, FailPoint::AfterPost) {
                    return self.finish(LearnerOutcome::dead(self.ctx.node));
                }
                self.state = State::AwaitAverage { deadline };
                Command::Call {
                    path: proto::GET_AVERAGE,
                    body: proto::NodeOp::new(self.ctx.node, self.ctx.group).to_value(),
                }
            }
        }
    }

    /// Initiator step 3–4 (§5.1.1): unmask, divide, publish.
    fn on_final_aggregate(&mut self, resp: Value, deadline: Instant) -> Command {
        if proto::is_empty_status(&resp) {
            return self.retry_or_elect(deadline, |m, d| {
                m.state = State::AwaitFinalAggregate { deadline: d };
                Command::Call {
                    path: proto::GET_AGGREGATE,
                    body: proto::NodeOp::new(m.ctx.node, m.ctx.group).to_value(),
                }
            });
        }
        let delivery = match proto::AggregateDelivery::from_value(&resp) {
            Ok(d) => d,
            Err(e) => return self.fail(e),
        };
        let contributors = delivery.posted.unwrap_or(self.ctx.chain.len() as u64);
        let env = match Envelope::from_blob(&delivery.aggregate) {
            Ok(e) => e,
            Err(e) => return self.fail(e),
        };
        let agg = match self.ctx.open_from(&env, delivery.from_node) {
            Ok(a) => a,
            Err(e) => return self.fail(e),
        };
        let mask = match self.mask.take() {
            Some(m) => m,
            None => return self.bug("initiator mask missing"),
        };
        let average = self.ctx.math.finalize(&agg, &mask, contributors as f64);
        let body = proto::PostAverage::body(self.ctx.node, self.ctx.group, &average, contributors);
        self.state = State::AwaitAveragePostAck { deadline, average, contributors };
        Command::Call { path: proto::POST_AVERAGE, body }
    }

    /// The blocking `wait_for` contract: on empty, give up only when the
    /// step deadline has passed (→ §5.4 election), otherwise re-issue the
    /// same poll.
    fn retry_or_elect(
        &mut self,
        deadline: Instant,
        retry: impl FnOnce(&mut Self, Instant) -> Command,
    ) -> Command {
        if Instant::now() >= deadline {
            self.election()
        } else {
            retry(self, deadline)
        }
    }

    fn done(&mut self, average: Vec<f64>, contributors: u64) -> Command {
        let outcome = LearnerOutcome {
            node: self.ctx.node,
            average,
            was_initiator: self.is_initiator,
            reposts: self.reposts,
            restarts: self.restarts,
            contributors,
            died: false,
            deadline_exceeded: false,
        };
        self.finish(outcome)
    }

    fn finish(&mut self, outcome: LearnerOutcome) -> Command {
        self.state = State::Finished;
        Command::Finished(Box::new(outcome))
    }

    fn fail(&mut self, err: anyhow::Error) -> Command {
        self.state = State::Finished;
        Command::Failed(err)
    }

    fn bug(&mut self, what: &str) -> Command {
        self.state = State::Finished;
        Command::Failed(anyhow!("learner {} runtime bug: {}", self.ctx.node, what))
    }
}
