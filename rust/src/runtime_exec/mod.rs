//! Event-driven learner runtime: a fixed worker pool multiplexing every
//! learner in a session over a handful of OS threads.
//!
//! The thread runtime (`learner::actor`) parks one OS thread per learner,
//! which caps the scale harness around n≈120 — far below the regime where
//! the paper's `4n + 2f` message complexity is interesting. Here each
//! learner is a resumable [`machine::LearnerStateMachine`]; its blocking
//! points become completion wakeups:
//!
//! * **long-polls** (`get_aggregate`, `check_aggregate`, `get_average`,
//!   `get_key`, `get_preneg_key`) are submitted non-blockingly through
//!   [`InProcTransport::submit`]; a miss parks the machine in the
//!   controller's [`WaitHub`] under the returned
//!   [`crate::transport::PollKey`] and arms a poll-window timer;
//! * **data arrival** wakes the hub key, which enqueues the task on the
//!   ready queue ([`WakeSink`]);
//! * **poll-window expiry** (the timer) synthesizes the same
//!   `status: "empty"` response the blocking server returns, so the
//!   machine's deadline/election logic is driven identically;
//! * **§5.9 stagger** sleeps become timer entries instead of a sleeping
//!   thread.
//!
//! The lost-wakeup race (data lands between a failed probe and the hub
//! registration) is closed by re-probing after registering; every wakeup
//! carries the submission generation, and stale wakeups are dropped.
//!
//! Lock order (outermost first): tasks map → task slot → controller
//! state → wait hub → ready queue / timer heap. Notifications only ever
//! enqueue; machines are driven exclusively by workers holding the slot.

pub mod machine;
pub mod timer;

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::learner::faults::FaultPlan;
use crate::learner::{LearnerContext, LearnerOutcome};
use crate::transport::{
    as_transport_error, InProcTransport, PollKey, RetryPolicy, Submitted, WaitHub, WakeSink,
};
use machine::{Command, LearnerStateMachine, MachineEvent};
use timer::{TimerKind, TimerWheel};

/// Executor sizing knobs.
#[derive(Debug, Clone)]
pub struct ExecutorConfig {
    /// Worker threads; 0 means "one per available CPU".
    pub workers: usize,
    /// Long-poll window: how long a pending submission waits before the
    /// synthetic `empty` completion (mirrors the controller's
    /// `poll_time`, so both runtimes poll at the same cadence).
    pub poll_time: Duration,
    /// Retry schedule for retryable transport faults. Backoffs are timer
    /// entries, never sleeping workers, so the pool stays full-throughput
    /// under loss. Mirrors the blocking learner's `LearnerContext::call`
    /// wrapper attempt-for-attempt.
    pub retry: RetryPolicy,
}

impl ExecutorConfig {
    /// Resolve `workers == 0` to the machine's parallelism.
    pub fn resolved_workers(&self) -> usize {
        resolve_workers(self.workers)
    }
}

pub fn resolve_workers(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|c| c.get()).unwrap_or(4)
    }
}

/// Why a task landed on the ready queue.
enum Cause {
    /// Freshly spawned: deliver [`MachineEvent::Start`].
    Start,
    /// The wait hub woke this task's pending submission.
    Wake { generation: u64 },
    /// The pending submission's poll window expired.
    PollTimeout { generation: u64 },
    /// A [`Command::Sleep`] elapsed.
    SleepDone { generation: u64 },
    /// A retry backoff elapsed — re-submit the stored call.
    Retry { generation: u64 },
}

/// An in-flight long-poll submission.
struct PendingCall {
    path: &'static str,
    body: crate::json::Value,
    key: PollKey,
    generation: u64,
    /// When this submission was handed to the transport — the event
    /// runtime's half of the latency-histogram observation: a parked
    /// call's span only closes at a later completion point, which the
    /// transport cannot see on its own.
    started: Instant,
}

/// A call parked on the timer wheel awaiting its retry backoff. The body
/// is re-sent verbatim, so a chain post keeps its dedup token and the
/// controller can absorb any duplicate.
struct RetryCall {
    path: &'static str,
    body: crate::json::Value,
    /// 0-based count of attempts already failed.
    attempt: u32,
    generation: u64,
}

/// Per-learner slot: the machine plus its wait state. Workers serialize
/// access through the slot mutex; `generation` increments at every new
/// submission or sleep so stale wakeups and timers are identifiable.
struct TaskSlot {
    machine: LearnerStateMachine,
    generation: u64,
    pending: Option<PendingCall>,
    sleeping: Option<u64>,
    retrying: Option<RetryCall>,
    /// Index into the executor's per-shard transport/hub pairs: the home
    /// controller shard brokering this learner's chain. Always 0 on a
    /// single-shard plane.
    shard: usize,
    outcome_tx: Sender<Result<LearnerOutcome>>,
}

struct Shared {
    queue: Mutex<VecDeque<(u64, Cause)>>,
    queue_cv: Condvar,
    shutdown: AtomicBool,
    tasks: Mutex<BTreeMap<u64, Arc<Mutex<TaskSlot>>>>,
    next_task: AtomicU64,
    /// One completion transport per controller shard; a task's calls all
    /// go through its home shard's transport (indexed by `TaskSlot::shard`).
    transports: Vec<Arc<InProcTransport>>,
    /// The matching per-shard wait hubs. Task ids are globally unique, so
    /// one [`QueueSink`] serves every hub.
    hubs: Vec<Arc<WaitHub>>,
    timer: TimerWheel,
    poll_time: Duration,
    retry: RetryPolicy,
}

impl Shared {
    fn transport(&self, shard: usize) -> &Arc<InProcTransport> {
        &self.transports[shard]
    }

    fn hub(&self, shard: usize) -> &Arc<WaitHub> {
        &self.hubs[shard]
    }

    fn enqueue(&self, task: u64, cause: Cause) {
        let mut q = self.queue.lock().unwrap();
        q.push_back((task, cause));
        self.queue_cv.notify_one();
    }

    fn dequeue(&self) -> Option<(u64, Cause)> {
        let mut q = self.queue.lock().unwrap();
        loop {
            if let Some(item) = q.pop_front() {
                return Some(item);
            }
            if self.shutdown.load(Ordering::SeqCst) {
                return None;
            }
            q = self.queue_cv.wait(q).unwrap();
        }
    }
}

/// Bridges the controller's [`WaitHub`] to the ready queue. Holds the
/// executor weakly: the hub outlives the executor (it belongs to the
/// controller), so wakeups after shutdown simply evaporate.
struct QueueSink {
    shared: Weak<Shared>,
}

impl WakeSink for QueueSink {
    fn wake(&self, task: u64, generation: u64) {
        if let Some(shared) = self.shared.upgrade() {
            shared.enqueue(task, Cause::Wake { generation });
        }
    }
}

/// The worker-pool executor. One per session; spawn learners with
/// [`EventExecutor::spawn_learner`] and collect each outcome from the
/// returned channel.
pub struct EventExecutor {
    shared: Arc<Shared>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    workers: usize,
}

impl EventExecutor {
    /// Start the pool over a single-shard plane. `transport` must have
    /// completion enabled (built with [`InProcTransport::with_completion`]);
    /// `hub` must be the completion handler's wait hub.
    pub fn start(
        transport: Arc<InProcTransport>,
        hub: Arc<WaitHub>,
        cfg: ExecutorConfig,
    ) -> Arc<EventExecutor> {
        Self::start_sharded(vec![(transport, hub)], cfg)
    }

    /// Start the pool over a sharded plane: one completion transport +
    /// wait hub pair per controller shard, all multiplexed over the same
    /// worker pool so K shards aggregate in parallel. Each spawned
    /// learner is driven against `planes[ctx.shard]`.
    pub fn start_sharded(
        planes: Vec<(Arc<InProcTransport>, Arc<WaitHub>)>,
        cfg: ExecutorConfig,
    ) -> Arc<EventExecutor> {
        assert!(!planes.is_empty(), "executor needs at least one shard plane");
        let workers = cfg.resolved_workers();
        let (transports, hubs): (Vec<_>, Vec<_>) = planes.into_iter().unzip();
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            tasks: Mutex::new(BTreeMap::new()),
            next_task: AtomicU64::new(1),
            transports,
            hubs,
            timer: TimerWheel::new(),
            poll_time: cfg.poll_time,
            retry: cfg.retry,
        });
        for hub in &shared.hubs {
            hub.set_sink(Arc::new(QueueSink { shared: Arc::downgrade(&shared) }));
        }
        let mut handles = Vec::with_capacity(workers + 1);
        for i in 0..workers {
            let s = shared.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("safe-worker-{i}"))
                    .spawn(move || worker_loop(s))
                    .expect("spawn worker"),
            );
        }
        let s = shared.clone();
        handles.push(
            std::thread::Builder::new()
                .name("safe-timer".into())
                .spawn(move || timer_loop(s))
                .expect("spawn timer"),
        );
        Arc::new(EventExecutor { shared, handles: Mutex::new(handles), workers })
    }

    /// Worker threads in the pool (after resolving `workers: 0`).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Enqueue one learner; the receiver yields its outcome (or error)
    /// exactly once.
    pub fn spawn_learner(
        &self,
        ctx: Arc<LearnerContext>,
        local: Vec<f64>,
        faults: FaultPlan,
    ) -> Receiver<Result<LearnerOutcome>> {
        let (tx, rx) = mpsc::channel();
        let id = self.shared.next_task.fetch_add(1, Ordering::SeqCst);
        // Clamp defensively: a context from a wider plane than this
        // executor was started with routes to the last shard rather than
        // panicking a worker.
        let shard = ctx.shard.min(self.shared.transports.len() - 1);
        let slot = TaskSlot {
            machine: LearnerStateMachine::new(ctx, local, faults),
            generation: 0,
            pending: None,
            sleeping: None,
            retrying: None,
            shard,
            outcome_tx: tx,
        };
        self.shared.tasks.lock().unwrap().insert(id, Arc::new(Mutex::new(slot)));
        self.shared.enqueue(id, Cause::Start);
        rx
    }
}

impl Drop for EventExecutor {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.queue_cv.notify_all();
        self.shared.timer.shutdown();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

fn timer_loop(shared: Arc<Shared>) {
    while let Some(entry) = shared.timer.next_due() {
        let cause = match entry.kind {
            TimerKind::Poll => Cause::PollTimeout { generation: entry.generation },
            TimerKind::Sleep => Cause::SleepDone { generation: entry.generation },
            TimerKind::Retry => Cause::Retry { generation: entry.generation },
        };
        shared.enqueue(entry.task, cause);
    }
}

/// Outcome of translating a queue item against the slot's wait state.
enum Step {
    /// Feed this event to the machine.
    Run(MachineEvent),
    /// Stale or spurious; task stays parked.
    Keep,
    /// Transport failure — abort the task with this error.
    Abort(anyhow::Error),
    /// The task terminated without the machine running again (e.g. retry
    /// exhaustion resolved to a live-failure outcome).
    Finish(Result<LearnerOutcome>),
}

fn worker_loop(shared: Arc<Shared>) {
    while let Some((task_id, cause)) = shared.dequeue() {
        let slot_arc = match shared.tasks.lock().unwrap().get(&task_id) {
            Some(s) => s.clone(),
            // Already finished (e.g. a stale timer for a removed task).
            None => continue,
        };
        let finished = {
            let mut slot = slot_arc.lock().unwrap();
            let step = match cause {
                Cause::Start => Step::Run(MachineEvent::Start),
                Cause::Wake { generation } => {
                    resolve_pending(&shared, task_id, &mut slot, generation, false)
                }
                Cause::PollTimeout { generation } => {
                    resolve_pending(&shared, task_id, &mut slot, generation, true)
                }
                Cause::SleepDone { generation } => {
                    if slot.sleeping == Some(generation) {
                        slot.sleeping = None;
                        Step::Run(MachineEvent::TimerFired)
                    } else {
                        Step::Keep
                    }
                }
                Cause::Retry { generation } => {
                    if matches!(&slot.retrying, Some(r) if r.generation == generation) {
                        let rc = slot.retrying.take().unwrap();
                        match submit_call(&shared, task_id, &mut slot, rc.path, rc.body, rc.attempt)
                        {
                            CallStep::Resp(resp) => Step::Run(MachineEvent::Response(resp)),
                            CallStep::Parked => Step::Keep,
                            CallStep::Done(r) => Step::Finish(r),
                        }
                    } else {
                        Step::Keep
                    }
                }
            };
            match step {
                Step::Keep => None,
                Step::Abort(e) => Some((slot.outcome_tx.clone(), Err(e))),
                Step::Finish(r) => Some((slot.outcome_tx.clone(), r)),
                Step::Run(event) => {
                    drive(&shared, task_id, &mut slot, event).map(|r| (slot.outcome_tx.clone(), r))
                }
            }
        };
        if let Some((tx, result)) = finished {
            // Slot lock released above: removal takes the map lock, and
            // map → slot is the only permitted nesting order.
            shared.tasks.lock().unwrap().remove(&task_id);
            let _ = tx.send(result);
        }
    }
}

/// Match a wakeup/timeout against the slot's pending submission and
/// probe the server. `timed_out` distinguishes the poll-window expiry
/// (which must synthesize the blocking server's `empty` response) from a
/// hub wake (which re-parks on a miss — e.g. after a broadcast wake).
fn resolve_pending(
    shared: &Shared,
    task_id: u64,
    slot: &mut TaskSlot,
    generation: u64,
    timed_out: bool,
) -> Step {
    if !matches!(&slot.pending, Some(p) if p.generation == generation) {
        return Step::Keep;
    }
    let transport = shared.transport(slot.shard).clone();
    let (path, key, started) = {
        let p = slot.pending.as_ref().unwrap();
        (p.path, p.key, p.started)
    };
    let probe = {
        let p = slot.pending.as_ref().unwrap();
        transport.try_complete(p.path, &p.body)
    };
    match probe {
        Err(e) => {
            slot.pending = None;
            transport.notify_unparked(path);
            Step::Abort(e)
        }
        Ok(Some(resp)) => {
            slot.pending = None;
            transport.notify_unparked(path);
            transport.observe_latency(path, started.elapsed());
            Step::Run(MachineEvent::Response(resp))
        }
        Ok(None) if timed_out => {
            // The poll window elapsed with nothing to deliver: complete
            // with the same (accounted) `empty` the blocking server
            // returns at poll timeout, and let the machine decide between
            // re-polling and a §5.4 election.
            slot.pending = None;
            transport.notify_unparked(path);
            match transport.complete_empty(path) {
                Ok(resp) => {
                    transport.observe_latency(path, started.elapsed());
                    Step::Run(MachineEvent::Response(resp))
                }
                Err(e) => Step::Abort(e),
            }
        }
        Ok(None) => {
            // Spurious wake (broadcast, or the data was for an earlier
            // consumer): re-park, then close the register/notify race
            // with one more probe. A now-stale registration is dropped
            // later by the generation check.
            shared.hub(slot.shard).register(key, task_id, generation);
            let reprobe = {
                let p = slot.pending.as_ref().unwrap();
                transport.try_complete(p.path, &p.body)
            };
            match reprobe {
                Err(e) => {
                    slot.pending = None;
                    transport.notify_unparked(path);
                    Step::Abort(e)
                }
                Ok(Some(resp)) => {
                    slot.pending = None;
                    transport.notify_unparked(path);
                    transport.observe_latency(path, started.elapsed());
                    Step::Run(MachineEvent::Response(resp))
                }
                // Original poll-window timer is still armed; keep waiting.
                Ok(None) => Step::Keep,
            }
        }
    }
}

/// How one (re-)submission of a call resolved.
enum CallStep {
    /// The call completed — feed this response to the machine.
    Resp(crate::json::Value),
    /// Parked: pending long-poll or a scheduled retry backoff.
    Parked,
    /// The task is over (transport fault resolved to an outcome, or a
    /// non-transport error aborted it).
    Done(Result<LearnerOutcome>),
}

/// Submit `path`/`body` once, translating failures through the retry
/// policy. `attempt` counts previously failed attempts of this same
/// logical call. A retryable fault with budget left schedules a
/// [`TimerKind::Retry`] (no worker sleeps); exhaustion — or a fatal
/// transport fault — degrades gracefully to a live-failure outcome so the
/// chain re-forms via §5.3/§5.4 instead of the session erroring out.
fn submit_call(
    shared: &Shared,
    task_id: u64,
    slot: &mut TaskSlot,
    path: &'static str,
    body: crate::json::Value,
    attempt: u32,
) -> CallStep {
    slot.generation += 1;
    let generation = slot.generation;
    let transport = shared.transport(slot.shard).clone();
    let started = Instant::now();
    match transport.submit(path, &body) {
        Err(e) => {
            let retryable = as_transport_error(&e).is_some_and(|t| t.retryable());
            if retryable && attempt + 1 < shared.retry.attempts.max(1) {
                transport.stats().record_retry();
                shared.timer.schedule(
                    Instant::now() + shared.retry.backoff(attempt),
                    task_id,
                    generation,
                    TimerKind::Retry,
                );
                slot.retrying = Some(RetryCall { path, body, attempt: attempt + 1, generation });
                CallStep::Parked
            } else if as_transport_error(&e).is_some() {
                CallStep::Done(Ok(LearnerOutcome::dead(slot.machine.node())))
            } else {
                CallStep::Done(Err(e))
            }
        }
        Ok(Submitted::Ready(resp)) => {
            transport.observe_latency(path, started.elapsed());
            CallStep::Resp(resp)
        }
        Ok(Submitted::Pending(key)) => {
            // Register first, probe again after: if the data raced in
            // between submit's probe and the registration, the second
            // probe finds it; the then-stale registration is
            // generation-filtered.
            shared.hub(slot.shard).register(key, task_id, generation);
            match transport.try_complete(path, &body) {
                Err(e) => CallStep::Done(Err(e)),
                Ok(Some(resp)) => {
                    transport.observe_latency(path, started.elapsed());
                    CallStep::Resp(resp)
                }
                Ok(None) => {
                    transport.notify_parked(path);
                    shared.timer.schedule(
                        Instant::now() + shared.poll_time,
                        task_id,
                        generation,
                        TimerKind::Poll,
                    );
                    slot.pending = Some(PendingCall { path, body, key, generation, started });
                    CallStep::Parked
                }
            }
        }
    }
}

/// Run the machine until it parks (pending call / sleep / retry backoff)
/// or terminates. Returns `Some(result)` when the task is done.
fn drive(
    shared: &Shared,
    task_id: u64,
    slot: &mut TaskSlot,
    first: MachineEvent,
) -> Option<Result<LearnerOutcome>> {
    let mut event = first;
    loop {
        match slot.machine.on_event(event) {
            Command::Call { path, body } => {
                match submit_call(shared, task_id, slot, path, body, 0) {
                    CallStep::Resp(resp) => event = MachineEvent::Response(resp),
                    CallStep::Parked => return None,
                    CallStep::Done(r) => return Some(r),
                }
            }
            Command::Sleep { until } => {
                slot.generation += 1;
                slot.sleeping = Some(slot.generation);
                shared.timer.schedule(until, task_id, slot.generation, TimerKind::Sleep);
                return None;
            }
            Command::Finished(outcome) => return Some(Ok(*outcome)),
            Command::Failed(e) => return Some(Err(e)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::Value;
    use crate::transport::Handler;

    struct Echo;
    impl Handler for Echo {
        fn handle(&self, _path: &str, body: &Value) -> Value {
            body.clone()
        }
    }

    #[test]
    fn resolve_workers_defaults_to_parallelism() {
        assert!(resolve_workers(0) >= 1);
        assert_eq!(resolve_workers(7), 7);
    }

    #[test]
    fn executor_starts_and_shuts_down_cleanly() {
        let transport = Arc::new(InProcTransport::new(Arc::new(Echo)));
        let hub = Arc::new(WaitHub::default());
        let exec = EventExecutor::start(
            transport,
            hub,
            ExecutorConfig {
                workers: 2,
                poll_time: Duration::from_millis(50),
                retry: RetryPolicy::default(),
            },
        );
        assert_eq!(exec.workers(), 2);
        drop(exec); // must join workers + timer without hanging
    }
}
