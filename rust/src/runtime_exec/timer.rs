//! Deadline scheduling for the event runtime: a single timer thread
//! holding a min-heap of `(when, task, generation)` entries. Poll-window
//! expiries and §5.9 stagger delays both land here, so a parked learner
//! costs one heap entry instead of one sleeping OS thread.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Why a timer was armed — decides which executor event fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum TimerKind {
    /// A pending long-poll's window expired (synthesize `empty`).
    Poll,
    /// A [`crate::runtime_exec::machine::Command::Sleep`] elapsed.
    Sleep,
    /// A retry backoff elapsed — re-submit the stored call (no worker
    /// thread ever sleeps for a retry).
    Retry,
}

/// Heap entry; `seq` breaks ties so ordering is total and FIFO among
/// entries armed for the same instant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct TimerEntry {
    pub at: Instant,
    pub seq: u64,
    pub task: u64,
    pub generation: u64,
    pub kind: TimerKind,
}

#[derive(Default)]
struct TimerQueue {
    heap: BinaryHeap<Reverse<TimerEntry>>,
    next_seq: u64,
    shutdown: bool,
}

/// Shared timer state; the owning executor spawns the thread that drains
/// it (see `timer_loop` in the parent module).
pub struct TimerWheel {
    queue: Mutex<TimerQueue>,
    cv: Condvar,
}

impl TimerWheel {
    pub fn new() -> Self {
        TimerWheel { queue: Mutex::new(TimerQueue::default()), cv: Condvar::new() }
    }

    /// Arm a timer. Stale entries (the task moved on, bumping its
    /// generation) fire harmlessly: the executor drops generation
    /// mismatches.
    pub fn schedule(&self, at: Instant, task: u64, generation: u64, kind: TimerKind) {
        let mut q = self.queue.lock().unwrap();
        let seq = q.next_seq;
        q.next_seq += 1;
        q.heap.push(Reverse(TimerEntry { at, seq, task, generation, kind }));
        self.cv.notify_all();
    }

    pub fn shutdown(&self) {
        self.queue.lock().unwrap().shutdown = true;
        self.cv.notify_all();
    }

    /// Block until an entry is due (returning it) or shutdown (returning
    /// `None`). Drives the timer thread's loop.
    pub fn next_due(&self) -> Option<TimerEntry> {
        let mut q = self.queue.lock().unwrap();
        loop {
            if q.shutdown {
                return None;
            }
            let now = Instant::now();
            match q.heap.peek() {
                None => {
                    q = self.cv.wait(q).unwrap();
                }
                Some(Reverse(entry)) if entry.at <= now => {
                    let entry = *entry;
                    q.heap.pop();
                    return Some(entry);
                }
                Some(Reverse(entry)) => {
                    let wait = entry.at - now;
                    let (guard, _) = self.cv.wait_timeout(q, wait).unwrap();
                    q = guard;
                }
            }
        }
    }
}

impl Default for TimerWheel {
    fn default() -> Self {
        TimerWheel::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn fires_in_deadline_order_not_insertion_order() {
        let w = TimerWheel::new();
        let now = Instant::now();
        w.schedule(now + Duration::from_millis(30), 2, 0, TimerKind::Poll);
        w.schedule(now + Duration::from_millis(10), 1, 0, TimerKind::Sleep);
        w.schedule(now + Duration::from_millis(20), 3, 0, TimerKind::Poll);
        let order: Vec<u64> = (0..3).map(|_| w.next_due().unwrap().task).collect();
        assert_eq!(order, vec![1, 3, 2]);
    }

    #[test]
    fn equal_deadlines_fire_fifo() {
        let w = TimerWheel::new();
        let at = Instant::now();
        for task in 1..=4u64 {
            w.schedule(at, task, 0, TimerKind::Sleep);
        }
        let order: Vec<u64> = (0..4).map(|_| w.next_due().unwrap().task).collect();
        assert_eq!(order, vec![1, 2, 3, 4]);
    }

    #[test]
    fn shutdown_unblocks() {
        let w = std::sync::Arc::new(TimerWheel::new());
        let w2 = w.clone();
        let t = std::thread::spawn(move || w2.next_due());
        std::thread::sleep(Duration::from_millis(20));
        w.shutdown();
        assert!(t.join().unwrap().is_none());
    }
}
