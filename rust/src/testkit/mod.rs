//! Property-testing mini-framework.
//!
//! `proptest` is not in the offline crate cache, so this provides the
//! subset the suite needs: seeded generators, N-case property checks with
//! the failing seed printed for reproduction, and a crude shrink loop for
//! vector-shaped inputs (halve until the property passes).

use crate::crypto::rng::{DeterministicRng, SecureRng};

/// Run `prop` on `cases` generated inputs. Panics on the first failure,
/// printing the case index and generator seed so the failure replays.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut DeterministicRng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let base_seed = 0x5AFE_0000u64;
    for case in 0..cases {
        let seed = base_seed + case as u64;
        let mut rng = DeterministicRng::seed(seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property {name:?} failed at case {case} (seed {seed:#x}); input: {input:?}"
            );
        }
    }
}

/// Like [`check`] but shrinks failing `Vec` inputs by halving before
/// reporting, to print a smaller counterexample.
pub fn check_vec<E: std::fmt::Debug + Clone>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut DeterministicRng) -> Vec<E>,
    mut prop: impl FnMut(&[E]) -> bool,
) {
    let base_seed = 0x5AFE_1000u64;
    for case in 0..cases {
        let seed = base_seed + case as u64;
        let mut rng = DeterministicRng::seed(seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            // Shrink: try halves repeatedly.
            let mut smallest = input.clone();
            let mut cur = input;
            loop {
                let half = cur.len() / 2;
                if half == 0 {
                    break;
                }
                let lo = cur[..half].to_vec();
                let hi = cur[half..].to_vec();
                if !prop(&lo) {
                    smallest = lo.clone();
                    cur = lo;
                } else if !prop(&hi) {
                    smallest = hi.clone();
                    cur = hi;
                } else {
                    break;
                }
            }
            panic!(
                "property {name:?} failed at case {case} (seed {seed:#x}); shrunk input ({} elems): {smallest:?}",
                smallest.len()
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use super::*;

    pub fn f64_vec(rng: &mut DeterministicRng, max_len: usize) -> Vec<f64> {
        let len = 1 + rng.next_below(max_len.max(1));
        (0..len).map(|_| (rng.next_f64() - 0.5) * 2000.0).collect()
    }

    pub fn bytes(rng: &mut DeterministicRng, max_len: usize) -> Vec<u8> {
        let len = rng.next_below(max_len + 1);
        let mut v = vec![0u8; len];
        rng.fill_bytes(&mut v);
        v
    }

    pub fn ascii_string(rng: &mut DeterministicRng, max_len: usize) -> String {
        let len = rng.next_below(max_len + 1);
        (0..len)
            .map(|_| (32 + rng.next_below(95) as u8) as char)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_valid_property() {
        check("add-commutes", 50, |r| (r.next_u64() % 1000, r.next_u64() % 1000), |&(a, b)| {
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "property \"always-false\" failed")]
    fn check_reports_failure() {
        check("always-false", 5, |r| r.next_u64(), |_| false);
    }

    #[test]
    #[should_panic(expected = "shrunk input")]
    fn check_vec_shrinks() {
        check_vec(
            "no-big-values",
            5,
            |r| gen::bytes(r, 64),
            |v| v.iter().all(|&b| b < 250),
        );
    }

    #[test]
    fn generators_respect_bounds() {
        let mut rng = DeterministicRng::seed(1);
        for _ in 0..100 {
            let v = gen::f64_vec(&mut rng, 10);
            assert!((1..=10).contains(&v.len()));
            let s = gen::ascii_string(&mut rng, 20);
            assert!(s.len() <= 20);
            assert!(s.chars().all(|c| c.is_ascii() && !c.is_ascii_control()));
        }
    }
}
