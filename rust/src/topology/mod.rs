//! Topology subsystem: group/chain planning as a first-class layer.
//!
//! The paper's scalability story (§5.3, §5.5, Figs 9/12) rests on
//! subgrouping: nodes are split into parallel chains, each with its own
//! initiator, and the controller folds the group averages into a global
//! mean. Until this subsystem existed, that split was a static even
//! partition recomputed ad hoc at every call site; now all group/chain
//! decisions flow through one planner:
//!
//! * [`GroupPlanner`] owns the configured membership and produces one
//!   [`TopologyPlan`] per round — an immutable snapshot of `group →
//!   ordered chain` and `node → group`.
//! * **Chain re-formation**: nodes a [`ChurnSchedule`] keeps out of a
//!   round are simply not in the plan; the chain closes around them.
//! * **Deterministic permutation**: with
//!   `SessionConfig::shuffle_chain_each_round`, each round's chain order
//!   is a seeded Fisher–Yates permutation (paper §8: randomizing the
//!   order limits what colluding neighbours learn across rounds).
//! * **Privacy-floor merge re-balancing** (the Turbo-Aggregate move):
//!   when churn leaves a group with fewer than [`PRIVACY_FLOOR`]
//!   projected-live nodes, the planner merges its survivors into the
//!   smallest neighbouring group instead of aborting, emitting one
//!   [`Reassignment`] per moved node so that *only moved nodes* re-key —
//!   the same accounting discipline as rejoiner-only re-keys. The abort
//!   path remains only when the *total* live population drops below the
//!   floor.
//! * **Head rotation**: a node scheduled to die this round (at any
//!   non-initiator fail point) is never placed at the chain head, so a
//!   scheduled death exercises progress failover (`2f` messages) rather
//!   than burning an aggregation-timeout initiator election.
//!
//! The session engine (`protocols::safe`) consumes plans for every round;
//! `BeginRound` carries the plan's reassignment deltas to the controller,
//! which answers mid-round privacy-floor trips with `merge_groups`
//! (re-plan and merge next round) when merging is possible and
//! `abort_privacy_floor` only as the fallback.
//!
//! [`ChurnSchedule`]: crate::learner::faults::ChurnSchedule

pub mod plan;
pub mod planner;

pub use plan::{MergeEvent, Reassignment, TopologyPlan};
pub use planner::{GroupPlanner, PRIVACY_FLOOR};
