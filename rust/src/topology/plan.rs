//! Immutable per-round topology snapshots.
//!
//! A [`TopologyPlan`] is what one aggregation round *actually looks
//! like*: which groups exist, each group's ordered chain, where every
//! node sits, and — when privacy-floor re-balancing kicked in — which
//! nodes were merged out of their home group ([`Reassignment`]) and
//! which groups were dissolved ([`MergeEvent`]). Plans are produced by
//! [`GroupPlanner::plan_round`](super::GroupPlanner::plan_round) and
//! never mutated; the session engine, the controller's `BeginRound`
//! message and the re-key accounting all read the same snapshot.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::json::Value;

/// One node aggregating under a group other than its configured home
/// group this round (the per-node delta of a privacy-floor merge).
///
/// Reassignments are the re-key unit: a moved node must hold keys for
/// its new chain peers (and they for it), but links between unmoved
/// survivors keep their existing keys — mirroring the rejoiner-only
/// re-key discipline of the multi-round engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Reassignment {
    /// The moved node.
    pub node: u64,
    /// Its configured home group.
    pub from_group: u64,
    /// The group whose chain it joins this round.
    pub to_group: u64,
}

impl Reassignment {
    /// Wire form (rides on `BeginRound.reassigned`).
    pub fn to_value(&self) -> Value {
        Value::object(vec![
            ("node", Value::from(self.node)),
            ("from_group", Value::from(self.from_group)),
            ("to_group", Value::from(self.to_group)),
        ])
    }

    /// Parse the wire form produced by [`Reassignment::to_value`].
    pub fn from_value(v: &Value) -> Result<Reassignment> {
        Ok(Reassignment {
            node: v.u64_of("node").context("reassignment missing node")?,
            from_group: v.u64_of("from_group").context("reassignment missing from_group")?,
            to_group: v.u64_of("to_group").context("reassignment missing to_group")?,
        })
    }
}

/// One privacy-floor merge: group `from_group` fell below the floor and
/// its present members were appended to `into_group`'s chain.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MergeEvent {
    /// The dissolved group.
    pub from_group: u64,
    /// The neighbouring group that absorbed it.
    pub into_group: u64,
    /// The nodes that moved (in their pre-merge chain order).
    pub moved: Vec<u64>,
}

/// Immutable snapshot of one round's group/chain topology.
#[derive(Debug, Clone, PartialEq)]
pub struct TopologyPlan {
    /// `(group id, ordered chain)` sorted by group id.
    groups: Vec<(u64, Vec<u64>)>,
    /// node → group id (derived index).
    group_of: BTreeMap<u64, u64>,
    /// Per-node merge deltas (final placement vs home group).
    reassignments: Vec<Reassignment>,
    /// The merges that produced this plan, in application order.
    merges: Vec<MergeEvent>,
    /// group id → controller shard index (home-shard assignment; stable
    /// across rounds for every configured group). Single-shard plans map
    /// every group to shard 0.
    shard_of: BTreeMap<u64, usize>,
    /// Width of the aggregation plane this plan targets (≥ 1).
    shard_count: usize,
}

impl TopologyPlan {
    pub(crate) fn new(
        groups: Vec<(u64, Vec<u64>)>,
        reassignments: Vec<Reassignment>,
        merges: Vec<MergeEvent>,
    ) -> TopologyPlan {
        let mut group_of = BTreeMap::new();
        let mut shard_of = BTreeMap::new();
        for (gid, chain) in &groups {
            for &node in chain {
                group_of.insert(node, *gid);
            }
            shard_of.insert(*gid, 0);
        }
        TopologyPlan { groups, group_of, reassignments, merges, shard_of, shard_count: 1 }
    }

    /// Attach the sharded-plane assignment: `shard_of` maps every group
    /// id in the plan to its home controller shard in `0..shard_count`.
    /// Groups the map does not name stay on shard 0.
    pub(crate) fn with_shards(
        mut self,
        shard_of: BTreeMap<u64, usize>,
        shard_count: usize,
    ) -> TopologyPlan {
        for (gid, shard) in shard_of {
            if let Some(s) = self.shard_of.get_mut(&gid) {
                *s = shard;
            }
        }
        self.shard_count = shard_count.max(1);
        self
    }

    /// The round's groups: `(group id, ordered chain)`, ascending id.
    pub fn groups(&self) -> &[(u64, Vec<u64>)] {
        &self.groups
    }

    /// The ordered chain of `group`, if it exists this round.
    pub fn chain(&self, group: u64) -> Option<&[u64]> {
        self.groups
            .iter()
            .find(|(gid, _)| *gid == group)
            .map(|(_, chain)| chain.as_slice())
    }

    /// The chain containing `node`, if it participates this round.
    pub fn chain_containing(&self, node: u64) -> Option<&[u64]> {
        self.chain(self.group_of(node)?)
    }

    /// The group `node` aggregates under this round.
    pub fn group_of(&self, node: u64) -> Option<u64> {
        self.group_of.get(&node).copied()
    }

    /// Does `node` participate in this round at all?
    pub fn contains(&self, node: u64) -> bool {
        self.group_of.contains_key(&node)
    }

    /// Total nodes across all chains (the round's active population).
    pub fn total_live(&self) -> usize {
        self.groups.iter().map(|(_, c)| c.len()).sum()
    }

    /// More than one group this round (drives the §5.5 `+g` pulls).
    pub fn is_multi_group(&self) -> bool {
        self.groups.len() > 1
    }

    /// `group id → chain` map (the `BeginRound.groups` wire shape).
    pub fn groups_map(&self) -> BTreeMap<u64, Vec<u64>> {
        self.groups.iter().cloned().collect()
    }

    /// Consume the plan into its `(group id, chain)` list.
    pub fn into_groups(self) -> Vec<(u64, Vec<u64>)> {
        self.groups
    }

    /// Per-node merge deltas: every node placed outside its home group,
    /// sorted by node id. Only these nodes re-key.
    pub fn reassignments(&self) -> &[Reassignment] {
        &self.reassignments
    }

    /// The privacy-floor merges applied while building this plan.
    pub fn merges(&self) -> &[MergeEvent] {
        &self.merges
    }

    /// Width of the aggregation plane (number of controller shards the
    /// plan was built for). Always ≥ 1; single-shard plans return 1.
    pub fn shard_count(&self) -> usize {
        self.shard_count
    }

    /// The home controller shard of `group`, if it exists this round.
    pub fn shard_of_group(&self, group: u64) -> Option<usize> {
        self.shard_of.get(&group).copied()
    }

    /// The shard brokering `node`'s chain this round (its group's home
    /// shard — reassigned nodes follow the group they aggregate under).
    pub fn shard_of_node(&self, node: u64) -> Option<usize> {
        self.shard_of_group(self.group_of(node)?)
    }

    /// `group id → chain` map restricted to the groups homed on `shard`
    /// (the per-shard `BeginRound.groups` wire shape).
    pub fn groups_for_shard(&self, shard: usize) -> BTreeMap<u64, Vec<u64>> {
        self.groups
            .iter()
            .filter(|(gid, _)| self.shard_of_group(*gid) == Some(shard))
            .cloned()
            .collect()
    }

    /// Shards owning at least one group this round (ascending). A shard
    /// whose every group dissolved contributes nothing to fan-in.
    pub fn live_shards(&self) -> Vec<usize> {
        let mut live: Vec<usize> = self
            .groups
            .iter()
            .filter_map(|(gid, _)| self.shard_of_group(*gid))
            .collect();
        live.sort_unstable();
        live.dedup();
        live
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan() -> TopologyPlan {
        TopologyPlan::new(
            vec![(1, vec![1, 2, 3, 7, 8]), (2, vec![4, 5, 6])],
            vec![
                Reassignment { node: 7, from_group: 3, to_group: 1 },
                Reassignment { node: 8, from_group: 3, to_group: 1 },
            ],
            vec![MergeEvent { from_group: 3, into_group: 1, moved: vec![7, 8] }],
        )
    }

    #[test]
    fn lookups_are_consistent() {
        let p = plan();
        assert_eq!(p.total_live(), 8);
        assert!(p.is_multi_group());
        assert_eq!(p.group_of(7), Some(1));
        assert_eq!(p.group_of(5), Some(2));
        assert_eq!(p.group_of(9), None);
        assert!(p.contains(4));
        assert!(!p.contains(9));
        assert_eq!(p.chain(2), Some(&[4u64, 5, 6][..]));
        assert_eq!(p.chain_containing(8), Some(&[1u64, 2, 3, 7, 8][..]));
        assert_eq!(p.chain(9), None);
        assert_eq!(p.reassignments().len(), 2);
        assert_eq!(p.merges()[0].into_group, 1);
        assert_eq!(p.groups_map().get(&2), Some(&vec![4, 5, 6]));
    }

    #[test]
    fn unsharded_plans_default_to_shard_zero() {
        let p = plan();
        assert_eq!(p.shard_count(), 1);
        assert_eq!(p.shard_of_group(1), Some(0));
        assert_eq!(p.shard_of_group(2), Some(0));
        assert_eq!(p.shard_of_group(9), None);
        assert_eq!(p.live_shards(), vec![0]);
        assert_eq!(p.groups_for_shard(0).len(), 2);
        assert!(p.groups_for_shard(1).is_empty());
    }

    #[test]
    fn shard_map_routes_groups_and_nodes() {
        let p = plan().with_shards([(1, 0), (2, 1)].into_iter().collect(), 2);
        assert_eq!(p.shard_count(), 2);
        assert_eq!(p.shard_of_group(1), Some(0));
        assert_eq!(p.shard_of_group(2), Some(1));
        // Node 7 is reassigned into group 1 — it follows its round group.
        assert_eq!(p.shard_of_node(7), Some(0));
        assert_eq!(p.shard_of_node(5), Some(1));
        assert_eq!(p.shard_of_node(9), None);
        assert_eq!(p.live_shards(), vec![0, 1]);
        assert_eq!(p.groups_for_shard(1).get(&2), Some(&vec![4, 5, 6]));
    }

    #[test]
    fn reassignment_value_roundtrip() {
        let r = Reassignment { node: 4, from_group: 2, to_group: 1 };
        assert_eq!(Reassignment::from_value(&r.to_value()).unwrap(), r);
        assert!(Reassignment::from_value(&Value::obj()).is_err());
    }
}
