//! The group planner: membership, per-round permutation, chain
//! re-formation and privacy-floor merge re-balancing.

use std::collections::BTreeSet;

use anyhow::{bail, Result};

use crate::config::SessionConfig;
use crate::crypto::rng::{DeterministicRng, SecureRng};
use crate::learner::faults::{FailPoint, FaultPlan};

use super::plan::{MergeEvent, Reassignment, TopologyPlan};

/// §5.3's `n − f ≥ 3`: a chain with fewer than 3 live nodes lets
/// neighbours infer each other's values, so no group may aggregate below
/// this population.
pub const PRIVACY_FLOOR: usize = 3;

/// Owns the configured group membership and produces one immutable
/// [`TopologyPlan`] per round.
///
/// Planning is a pure function of `(configured groups, seed, round salt,
/// absent set, fault plan)` — no wall clock, no global state — so the
/// same inputs always produce the same plan, which is what makes seeded
/// paper-scale churn runs reproducible.
#[derive(Debug, Clone)]
pub struct GroupPlanner {
    /// Configured home chains, ascending group id.
    groups: Vec<(u64, Vec<u64>)>,
    /// Seed for the per-round chain permutation (0 when unseeded).
    seed: u64,
    /// Permute each group's chain every round (paper §8).
    shuffle_each_round: bool,
    /// Merge under-floor groups instead of aborting.
    merge_floor: bool,
    /// Width of the aggregation plane (controller shards). Always
    /// clamped to `1..=groups.len()`; 1 = single-controller wiring.
    shards: usize,
}

impl GroupPlanner {
    /// Planner for `n_nodes` split evenly into `groups` chains, with all
    /// per-round behaviors (shuffle, merge) explicit.
    #[must_use]
    pub fn new(
        n_nodes: usize,
        groups: usize,
        seed: Option<u64>,
        shuffle_each_round: bool,
        merge_floor: bool,
    ) -> GroupPlanner {
        GroupPlanner {
            groups: Self::even_split(n_nodes, groups),
            seed: seed.unwrap_or(0),
            shuffle_each_round,
            merge_floor,
            shards: 1,
        }
    }

    /// Spread the plane over `shards` controller shards. Home shards are
    /// assigned round-robin by configured-group index (`idx % shards`),
    /// so adjacent-id groups land on different shards and a privacy-floor
    /// merge into a neighbouring group is usually a cross-shard move.
    /// Clamped to the configured group count; 1 restores today's wiring.
    #[must_use]
    pub fn with_shards(mut self, shards: usize) -> GroupPlanner {
        self.shards = shards.clamp(1, self.groups.len().max(1));
        self
    }

    /// Planner configured exactly as a [`SessionConfig`] describes.
    #[must_use]
    pub fn from_config(cfg: &SessionConfig) -> GroupPlanner {
        GroupPlanner::new(
            cfg.n_nodes,
            cfg.groups,
            cfg.seed,
            cfg.shuffle_chain_each_round,
            cfg.merge_floor,
        )
        .with_shards(cfg.shards)
    }

    /// The plane width this planner assigns home shards for.
    #[must_use]
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Home shard of every configured group: round-robin by the group's
    /// index in the configured (ascending-id) order. Deterministic and
    /// stable across rounds — churn and merges never move a surviving
    /// group off its home shard.
    fn shard_map(&self) -> std::collections::BTreeMap<u64, usize> {
        self.groups
            .iter()
            .enumerate()
            .map(|(idx, (gid, _))| (*gid, idx % self.shards))
            .collect()
    }

    /// Split nodes `1..=n_nodes` into `groups` contiguous chains (the
    /// paper's 2×6 / 3×4 / 4×3 groupings). Groups are numbered from 1;
    /// trailing groups may be one node shorter on uneven splits.
    #[must_use]
    pub fn even_split(n_nodes: usize, groups: usize) -> Vec<(u64, Vec<u64>)> {
        let groups = groups.max(1);
        let per = (n_nodes + groups - 1) / groups;
        let mut out = Vec::new();
        let mut next = 1u64;
        for g in 0..groups {
            let mut chain = Vec::new();
            for _ in 0..per {
                if next as usize > n_nodes {
                    break;
                }
                chain.push(next);
                next += 1;
            }
            if !chain.is_empty() {
                out.push(((g + 1) as u64, chain));
            }
        }
        out
    }

    /// Every configured node id, ascending.
    #[must_use]
    pub fn membership(&self) -> Vec<u64> {
        let mut all: Vec<u64> =
            self.groups.iter().flat_map(|(_, c)| c.iter().copied()).collect();
        all.sort_unstable();
        all
    }

    /// The configured home group of `node`.
    #[must_use]
    pub fn home_group(&self, node: u64) -> Option<u64> {
        self.groups
            .iter()
            .find(|(_, c)| c.contains(&node))
            .map(|(gid, _)| *gid)
    }

    /// The configured topology with full membership: no permutation, no
    /// absences, no merges. Used at session build (round 0 key exchange)
    /// and by the deprecated `SessionConfig::group_chains` shim.
    #[must_use]
    pub fn base_plan(&self) -> TopologyPlan {
        TopologyPlan::new(self.groups.clone(), Vec::new(), Vec::new())
            .with_shards(self.shard_map(), self.shards)
    }

    /// Build the plan for one round.
    ///
    /// * `permutation_salt` — monotone per-round value driving the
    ///   seeded chain shuffle (0 = the configured order, matching the
    ///   pre-subsystem behavior of round 0 never shuffling).
    /// * `absent` — nodes churned out of this round entirely (the chain
    ///   re-forms without them).
    /// * `faults` — deaths *scheduled within* this round. They stay in
    ///   the chain (their failover is in-round `2f` traffic) but count
    ///   against the privacy floor, are kept off the chain head (a dead
    ///   head would burn an aggregation-timeout election instead of a
    ///   cheap repost), and trigger proactive merges.
    ///
    /// Merge re-balancing: every group whose projected-live population
    /// (present minus in-round stalling deaths) is below
    /// [`PRIVACY_FLOOR`] is dissolved into its smallest neighbouring
    /// group (by projected-live size; ties to the earlier group), until
    /// all groups meet the floor. With merging disabled the same
    /// condition is an error; with or without merging, a total live
    /// population below the floor always aborts the round.
    pub fn plan_round(
        &self,
        permutation_salt: u64,
        absent: &BTreeSet<u64>,
        faults: &FaultPlan,
    ) -> Result<TopologyPlan> {
        let mut chains = self.groups.clone();
        // 1. Deterministic per-round permutation (paper §8).
        if self.shuffle_each_round && permutation_salt > 0 {
            for (gid, chain) in chains.iter_mut() {
                let mut rng =
                    DeterministicRng::seed(self.seed ^ (permutation_salt << 20) ^ *gid);
                for i in (1..chain.len()).rev() {
                    let j = rng.next_below(i + 1);
                    chain.swap(i, j);
                }
            }
        }
        // 2. Chain re-formation: drop churned-out nodes, then groups left
        //    with nobody present.
        for (_, chain) in chains.iter_mut() {
            chain.retain(|n| !absent.contains(n));
        }
        chains.retain(|(_, c)| !c.is_empty());

        // A death that stalls the chain (never participates, or pulls
        // and dies) removes the node from the round's effective
        // population; deaths after posting keep their contribution.
        let stalls = |node: u64| {
            matches!(
                faults.point(node),
                Some(FailPoint::NeverStart) | Some(FailPoint::AfterGet)
            )
        };
        let projected =
            |chain: &[u64]| chain.iter().filter(|&&n| !stalls(n)).count();

        // 3. Privacy-floor handling: merge (default) or abort.
        let mut merges = Vec::new();
        if self.merge_floor {
            while chains.len() > 1 {
                let Some(i) =
                    chains.iter().position(|(_, c)| projected(c) < PRIVACY_FLOOR)
                else {
                    break;
                };
                // Smallest neighbouring group by projected-live size;
                // ties go to the earlier neighbour.
                let target = match (i.checked_sub(1), (i + 1 < chains.len()).then_some(i + 1)) {
                    (Some(p), Some(nx)) => {
                        if projected(&chains[nx].1) < projected(&chains[p].1) {
                            nx
                        } else {
                            p
                        }
                    }
                    (Some(p), None) => p,
                    (None, Some(nx)) => nx,
                    (None, None) => unreachable!("len > 1"),
                };
                let (from_group, moved) = chains.remove(i);
                let target = if target > i { target - 1 } else { target };
                let into_group = chains[target].0;
                chains[target].1.extend(moved.iter().copied());
                merges.push(MergeEvent { from_group, into_group, moved });
            }
        } else if let Some((gid, chain)) =
            chains.iter().find(|(_, c)| projected(c) < PRIVACY_FLOOR)
        {
            bail!(
                "group {gid}: {} live nodes < {PRIVACY_FLOOR} (privacy floor, §5.3); \
                 merges disabled (--merge-floor off)",
                projected(chain)
            );
        }
        let total: usize = chains.iter().map(|(_, c)| projected(c)).sum();
        if total < PRIVACY_FLOOR {
            bail!(
                "{total} total live nodes < {PRIVACY_FLOOR} (privacy floor, §5.3); \
                 no merge can restore the floor"
            );
        }

        // 4. Head rotation: never start the chain on a node scheduled to
        //    die at a non-initiator fail point this round.
        let avoid_head = |node: u64| {
            matches!(
                faults.point(node),
                Some(FailPoint::NeverStart)
                    | Some(FailPoint::AfterGet)
                    | Some(FailPoint::AfterPost)
            )
        };
        for (_, chain) in chains.iter_mut() {
            if let Some(pos) = chain.iter().position(|&n| !avoid_head(n)) {
                chain.rotate_left(pos);
            }
        }

        // 5. Per-node deltas: final placement vs configured home group.
        let mut reassignments = Vec::new();
        for (gid, chain) in &chains {
            for &node in chain {
                if let Some(home) = self.home_group(node) {
                    if home != *gid {
                        reassignments.push(Reassignment {
                            node,
                            from_group: home,
                            to_group: *gid,
                        });
                    }
                }
            }
        }
        reassignments.sort_by_key(|r| r.node);
        Ok(TopologyPlan::new(chains, reassignments, merges)
            .with_shards(self.shard_map(), self.shards))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learner::faults::FaultPlan;

    fn planner(n: usize, g: usize) -> GroupPlanner {
        GroupPlanner::new(n, g, Some(42), false, true)
    }

    fn no_absent() -> BTreeSet<u64> {
        BTreeSet::new()
    }

    #[test]
    fn even_split_matches_paper_groupings() {
        let chains = GroupPlanner::even_split(12, 4);
        assert_eq!(chains.len(), 4);
        assert_eq!(chains[0], (1, vec![1, 2, 3]));
        assert_eq!(chains[3], (4, vec![10, 11, 12]));
        let uneven = GroupPlanner::even_split(7, 2);
        assert_eq!(uneven[0].1, vec![1, 2, 3, 4]);
        assert_eq!(uneven[1].1, vec![5, 6, 7]);
        assert_eq!(GroupPlanner::even_split(5, 1), vec![(1, vec![1, 2, 3, 4, 5])]);
    }

    #[test]
    fn base_plan_is_configured_membership() {
        let p = planner(9, 3);
        let base = p.base_plan();
        assert_eq!(base.groups().len(), 3);
        assert_eq!(base.total_live(), 9);
        assert!(base.reassignments().is_empty());
        assert_eq!(p.membership(), (1..=9).collect::<Vec<u64>>());
        assert_eq!(p.home_group(5), Some(2));
        assert_eq!(p.home_group(99), None);
    }

    #[test]
    fn absent_nodes_reform_the_chain() {
        let p = planner(6, 1);
        let plan = p
            .plan_round(0, &BTreeSet::from([3, 5]), &FaultPlan::none())
            .unwrap();
        assert_eq!(plan.chain(1), Some(&[1u64, 2, 4, 6][..]));
        assert!(plan.reassignments().is_empty());
    }

    #[test]
    fn under_floor_group_merges_into_smallest_neighbor() {
        // 9 nodes / 3 groups of 3; group 2 loses node 6 → {4,5} < 3.
        let p = planner(9, 3);
        let plan = p
            .plan_round(0, &BTreeSet::from([6]), &FaultPlan::none())
            .unwrap();
        assert_eq!(plan.groups().len(), 2);
        // Neighbours of group 2 are groups 1 and 3, both size 3: tie goes
        // to the earlier one.
        assert_eq!(plan.chain(1), Some(&[1u64, 2, 3, 4, 5][..]));
        assert_eq!(plan.chain(3), Some(&[7u64, 8, 9][..]));
        assert_eq!(plan.merges().len(), 1);
        assert_eq!(plan.merges()[0].from_group, 2);
        assert_eq!(plan.merges()[0].into_group, 1);
        assert_eq!(plan.merges()[0].moved, vec![4, 5]);
        let moved: Vec<u64> = plan.reassignments().iter().map(|r| r.node).collect();
        assert_eq!(moved, vec![4, 5]);
        assert!(plan
            .reassignments()
            .iter()
            .all(|r| r.from_group == 2 && r.to_group == 1));
    }

    #[test]
    fn merge_prefers_smaller_neighbor() {
        // 12 nodes / 4 groups of 3. Group 3 drops to 1 node; group 4 is
        // down to 2, group 2 still has 3 → group 3 merges into group 4.
        let p = planner(12, 4);
        let plan = p
            .plan_round(0, &BTreeSet::from([7, 8, 12]), &FaultPlan::none())
            .unwrap();
        // Group 3 ({9}) merges into group 4 ({10,11}) → {10,11,9}; both
        // survivors meet the floor.
        assert!(plan.chain(3).is_none());
        assert_eq!(plan.chain(4), Some(&[10u64, 11, 9][..]));
        assert_eq!(plan.merges().len(), 1);
        assert_eq!(plan.merges()[0].into_group, 4);
    }

    #[test]
    fn cascading_merges_until_floor_met() {
        // 8 nodes / 4 groups of 2: every group is under floor; merges
        // cascade until the floor is met.
        let p = planner(8, 4);
        let plan = p.plan_round(0, &no_absent(), &FaultPlan::none()).unwrap();
        assert!(plan.groups().iter().all(|(_, c)| c.len() >= PRIVACY_FLOOR));
        assert_eq!(plan.total_live(), 8);
        assert!(plan.merges().len() >= 2);
    }

    #[test]
    fn scheduled_stalling_deaths_count_against_the_floor() {
        // Group 2 has 3 present but one dies in-round before contributing
        // → projected 2 → proactively merged.
        let p = planner(6, 2);
        let faults = FaultPlan::none().kill(5, FailPoint::NeverStart);
        let plan = p.plan_round(0, &no_absent(), &faults).unwrap();
        assert_eq!(plan.groups().len(), 1);
        assert_eq!(plan.merges()[0].moved, vec![4, 5, 6]);
        // Deaths after posting don't stall the chain → no merge.
        let faults = FaultPlan::none().kill(5, FailPoint::AfterPost);
        let plan = p.plan_round(0, &no_absent(), &faults).unwrap();
        assert_eq!(plan.groups().len(), 2);
    }

    #[test]
    fn merges_disabled_bails_with_privacy_floor_error() {
        let p = GroupPlanner::new(6, 2, Some(1), false, false);
        let err = p
            .plan_round(0, &BTreeSet::from([6]), &FaultPlan::none())
            .unwrap_err();
        assert!(format!("{err:#}").contains("privacy floor"), "{err:#}");
    }

    #[test]
    fn total_below_floor_always_aborts() {
        let p = planner(4, 1);
        let err = p
            .plan_round(0, &BTreeSet::from([1, 4]), &FaultPlan::none())
            .unwrap_err();
        assert!(format!("{err:#}").contains("privacy floor"), "{err:#}");
        // Even with merging on, 2 total survivors across 2 groups abort.
        let p = planner(6, 2);
        let err = p
            .plan_round(0, &BTreeSet::from([1, 2, 4, 5]), &FaultPlan::none())
            .unwrap_err();
        assert!(format!("{err:#}").contains("privacy floor"), "{err:#}");
    }

    #[test]
    fn head_rotation_avoids_scheduled_deaths() {
        let p = planner(5, 1);
        let faults = FaultPlan::none()
            .kill(1, FailPoint::NeverStart)
            .kill(2, FailPoint::AfterGet);
        let plan = p.plan_round(0, &no_absent(), &faults).unwrap();
        // Head rotates past the two dying nodes; order is preserved.
        assert_eq!(plan.chain(1), Some(&[3u64, 4, 5, 1, 2][..]));
        // An initiator-after-post death is an initiator fault — it stays
        // eligible as head so the §5.4 failover path can be exercised.
        let faults = FaultPlan::none().kill(1, FailPoint::InitiatorAfterPost);
        let plan = p.plan_round(0, &no_absent(), &faults).unwrap();
        assert_eq!(plan.chain(1), Some(&[1u64, 2, 3, 4, 5][..]));
    }

    #[test]
    fn shard_assignment_is_round_robin_and_stable() {
        // 12 nodes / 4 groups, K=2 → groups 1,3 on shard 0; 2,4 on 1.
        let p = planner(12, 4).with_shards(2);
        assert_eq!(p.shards(), 2);
        let base = p.base_plan();
        assert_eq!(base.shard_count(), 2);
        assert_eq!(base.shard_of_group(1), Some(0));
        assert_eq!(base.shard_of_group(2), Some(1));
        assert_eq!(base.shard_of_group(3), Some(0));
        assert_eq!(base.shard_of_group(4), Some(1));
        // A dissolved group leaves the plan; survivors keep their home
        // shard — merging group 3 ({9}) into group 4 is a cross-shard
        // move for node 9.
        let plan = p
            .plan_round(0, &BTreeSet::from([7, 8, 12]), &FaultPlan::none())
            .unwrap();
        assert_eq!(plan.shard_of_group(3), None);
        assert_eq!(plan.shard_of_group(4), Some(1));
        assert_eq!(plan.shard_of_node(9), Some(1));
        assert_eq!(plan.live_shards(), vec![0, 1]);
        // Same inputs → same shard map (planning stays deterministic).
        let again = p
            .plan_round(0, &BTreeSet::from([7, 8, 12]), &FaultPlan::none())
            .unwrap();
        assert_eq!(plan, again);
    }

    #[test]
    fn shards_clamp_to_group_count() {
        let p = planner(9, 3).with_shards(8);
        assert_eq!(p.shards(), 3);
        assert_eq!(p.base_plan().live_shards(), vec![0, 1, 2]);
        let p = planner(9, 3).with_shards(0);
        assert_eq!(p.shards(), 1);
        // Default (no with_shards) keeps every group on shard 0.
        let base = planner(9, 3).base_plan();
        assert_eq!(base.shard_count(), 1);
        assert_eq!(base.live_shards(), vec![0]);
    }

    #[test]
    fn shuffle_is_deterministic_and_round_keyed() {
        let p = GroupPlanner::new(16, 2, Some(77), true, true);
        let a = p.plan_round(3, &no_absent(), &FaultPlan::none()).unwrap();
        let b = p.plan_round(3, &no_absent(), &FaultPlan::none()).unwrap();
        assert_eq!(a, b, "same salt → same permutation");
        let c = p.plan_round(4, &no_absent(), &FaultPlan::none()).unwrap();
        assert_ne!(a.groups(), c.groups(), "different rounds permute differently");
        // Salt 0 keeps the configured order (round 0 never shuffles).
        let base = p.plan_round(0, &no_absent(), &FaultPlan::none()).unwrap();
        assert_eq!(base.groups(), p.base_plan().groups());
        // Every permutation is a permutation of the same membership.
        let mut nodes: Vec<u64> =
            c.groups().iter().flat_map(|(_, c)| c.iter().copied()).collect();
        nodes.sort_unstable();
        assert_eq!(nodes, p.membership());
    }
}
