//! Typed transport failures, so retry policies can classify errors.
//!
//! Both transports surface link-level failures as a [`TransportError`]
//! wrapped in `anyhow::Error` (context layers preserved; callers classify
//! via `err.downcast_ref::<TransportError>()`). The split is *retryable*
//! (the request may or may not have reached the server — resending is
//! safe for idempotent ops, and `post_aggregate` carries a dedup token
//! precisely so a resend is safe there too) versus *fatal* (the server
//! answered and said no; resending the same bytes cannot succeed).

use std::fmt;

/// A link-level failure between a learner and the controller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportError {
    /// TCP connect to the controller failed (refused / unreachable).
    ConnectFailed,
    /// The connection closed before a complete response arrived.
    ConnectionClosed,
    /// A socket read/write failed mid-exchange (including read timeouts
    /// and unparseable HTTP framing, which force a reconnect).
    Io,
    /// The server answered with a non-200 HTTP status: the request was
    /// delivered and rejected, so resending the same bytes is pointless.
    BadStatus(u16),
    /// Injected fault: the request leg was dropped before the server saw
    /// it. The server state is untouched; retrying is always safe.
    LostRequest,
    /// Injected fault: the server processed the request but the response
    /// leg was dropped. Side effects may have landed — retrying is safe
    /// only for idempotent ops or posts carrying a dedup token.
    LostResponse,
}

impl TransportError {
    /// Whether a bounded retry of the same request can succeed.
    #[must_use]
    pub fn retryable(&self) -> bool {
        !matches!(self, TransportError::BadStatus(_))
    }
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportError::ConnectFailed => write!(f, "transport: connect failed"),
            TransportError::ConnectionClosed => write!(f, "transport: connection closed"),
            TransportError::Io => write!(f, "transport: io error"),
            TransportError::BadStatus(code) => write!(f, "transport: http status {code}"),
            TransportError::LostRequest => write!(f, "transport: request leg lost"),
            TransportError::LostResponse => write!(f, "transport: response leg lost"),
        }
    }
}

impl std::error::Error for TransportError {}

/// Classify an `anyhow` error chain: `Some(e)` when the root cause is a
/// [`TransportError`] (possibly wrapped in context layers).
#[must_use]
pub fn as_transport_error(err: &anyhow::Error) -> Option<TransportError> {
    err.downcast_ref::<TransportError>().copied()
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::Context;

    #[test]
    fn retryable_split() {
        assert!(TransportError::ConnectFailed.retryable());
        assert!(TransportError::ConnectionClosed.retryable());
        assert!(TransportError::Io.retryable());
        assert!(TransportError::LostRequest.retryable());
        assert!(TransportError::LostResponse.retryable());
        assert!(!TransportError::BadStatus(500).retryable());
    }

    #[test]
    fn classification_survives_context_layers() {
        let err: anyhow::Result<()> = Err(TransportError::ConnectFailed)
            .context("connect 127.0.0.1:1")
            .context("post_aggregate");
        let err = err.unwrap_err();
        assert_eq!(as_transport_error(&err), Some(TransportError::ConnectFailed));
        let plain = anyhow::anyhow!("some other failure");
        assert_eq!(as_transport_error(&plain), None);
    }

    #[test]
    fn display_names_the_variant() {
        assert!(TransportError::BadStatus(503).to_string().contains("503"));
        assert!(TransportError::LostRequest.to_string().contains("request"));
        assert!(TransportError::LostResponse.to_string().contains("response"));
    }
}
